"""Full-SVD (reflector-tape) overhead vs values-only pipeline.

Measures, per (n, bw) shape and batch size B:

  * ``values``  — ``svd_batched`` (sigma only);
  * ``vectors`` — ``svd_batched(..., compute_uv=True)`` (tape record +
    wavefront replay + stage-3 inverse iteration);

reporting the vectors/values time ratio in the derived column — the cost of
turning the paper's values-only chase into a full SVD.  The tape replay
shares the chase's wavefront batching, so the ratio should stay roughly
flat in B.

  PYTHONPATH=src python -m benchmarks.run --only vectors
  PYTHONPATH=src python -m benchmarks.run --only vectors --smoke
  PYTHONPATH=src python benchmarks/vectors.py
"""

from __future__ import annotations

import os
import sys

if __package__ in (None, ""):                 # direct script execution
    _REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _REPO)
    sys.path.insert(0, os.path.join(_REPO, "src"))

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit

FULL = dict(shapes=((48, 8), (96, 8)), batches=(1, 4), tw=4)
SMOKE = dict(shapes=((24, 6),), batches=(1, 2), tw=2)


def run(smoke: bool = False):
    from repro.core import svd as svdmod
    from repro.core.tuning import PipelineConfig

    p = SMOKE if smoke else FULL
    out = []
    rng = np.random.default_rng(0)
    for n, bw in p["shapes"]:
        cfg = PipelineConfig.resolve(bw=bw, tw=p["tw"], backend="ref",
                                     dtype=np.float64, n=n)
        for B in p["batches"]:
            mats = jnp.asarray(rng.standard_normal((B, n, n)))

            def values(ms=mats):
                return svdmod.svd_batched(ms, config=cfg)

            def vectors(ms=mats):
                return svdmod.svd_batched(ms, config=cfg, compute_uv=True)

            t_val = timeit(values)
            t_vec = timeit(vectors)
            out.append(row(f"vectors/values/n{n}/bw{bw}/B{B}", t_val * 1e6))
            out.append(row(f"vectors/full_svd/n{n}/bw{bw}/B{B}", t_vec * 1e6,
                           f"uv_overhead={t_vec / t_val:.2f}x"))
            # sanity: the result is an actual SVD (cheap shapes only)
            u, s, vt = (np.asarray(x) for x in vectors())
            err = np.abs(u[0] @ np.diag(s[0]) @ vt[0] - np.asarray(mats)[0]).max()
            out.append(row(f"vectors/recon_err/n{n}/bw{bw}/B{B}", 0.0,
                           f"max_abs_err={err:.2e}"))
    return out


if __name__ == "__main__":
    import jax

    jax.config.update("jax_enable_x64", True)
    print("name,us_per_call,derived")
    for line in run(smoke="--smoke" in sys.argv):
        print(line, flush=True)
