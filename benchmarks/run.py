"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig6,vectors] [--smoke] [--list]

``--only`` takes a comma-separated list of EXACT suite names (``--only
kernels_bench`` no longer also pulls in every suite containing the
substring); ``--list`` prints the registered suites; ``--smoke`` runs tiny
shapes — suites that support it are called with ``run(smoke=True)``, the
rest are skipped with a comment row (used as the non-blocking CI perf
probe).  Prints ``name,us_per_call,derived`` CSV rows.  The roofline tables
(EXPERIMENTS.md §Roofline) come from the dry-run artifacts instead:
``python -m repro.roofline.report`` after ``python -m repro.launch.dryrun``.
"""

from __future__ import annotations

import argparse
import inspect
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", True)

SUITES = ["accuracy", "hyperparams", "occupancy", "scaling", "precision",
          "kernels_bench", "batched", "vectors"]


def _supports_smoke(fn) -> bool:
    try:
        return "smoke" in inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated exact suite names (see --list)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes; suites without a smoke mode are skipped")
    ap.add_argument("--list", action="store_true", dest="list_suites",
                    help="print registered suite names and exit")
    args = ap.parse_args(argv)
    if args.list_suites:
        for name in SUITES:
            print(name)
        return
    selected = SUITES
    if args.only:
        wanted = [s.strip() for s in args.only.split(",") if s.strip()]
        unknown = sorted(set(wanted) - set(SUITES))
        if unknown:
            ap.error(f"unknown suite(s) {unknown}; registered: {SUITES}")
        selected = [s for s in SUITES if s in wanted]
    print("name,us_per_call,derived")
    for mod_name in selected:
        t0 = time.time()
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
        if args.smoke and not _supports_smoke(mod.run):
            print(f"# {mod_name} skipped (no smoke mode)", flush=True)
            continue
        lines = mod.run(smoke=True) if args.smoke else mod.run()
        for line in lines:
            print(line, flush=True)
        print(f"# {mod_name} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
