"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig6]

Prints ``name,us_per_call,derived`` CSV rows.  The roofline tables
(EXPERIMENTS.md §Roofline) come from the dry-run artifacts instead:
``python -m repro.roofline.report`` after ``python -m repro.launch.dryrun``.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", True)

SUITES = ["accuracy", "hyperparams", "occupancy", "scaling", "precision",
          "kernels_bench", "batched"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for mod_name in SUITES:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.time()
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
        for line in mod.run():
            print(line, flush=True)
        print(f"# {mod_name} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
