"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig6,vectors] [--smoke]
                                          [--list] [--json PATH]

``--only`` takes a comma-separated list of EXACT suite names (``--only
kernels_bench`` no longer also pulls in every suite containing the
substring); ``--list`` prints the registered suites; ``--smoke`` runs tiny
shapes — suites that support it are called with ``run(smoke=True)``, the
rest are skipped with a comment row (used as the non-blocking CI perf
probe).  Prints ``name,us_per_call,derived`` CSV rows; ``--json PATH``
additionally writes the same results machine-readably, grouped per suite
(the committed ``BENCH_stage2.json`` baseline and the CI workflow artifact
are produced this way).  The roofline tables
(EXPERIMENTS.md §Roofline) come from the dry-run artifacts instead:
``python -m repro.roofline.report`` after ``python -m repro.launch.dryrun``.
"""

from __future__ import annotations

import argparse
import inspect
import json
import os
import platform
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", True)

SUITES = ["accuracy", "hyperparams", "occupancy", "scaling", "precision",
          "kernels_bench", "fusion", "batched", "vectors"]


def _supports_smoke(fn) -> bool:
    try:
        return "smoke" in inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False


def _parse_row(line: str) -> dict:
    name, us, derived = line.split(",", 2)
    return {"name": name, "us_per_call": float(us), "derived": derived}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated exact suite names (see --list)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes; suites without a smoke mode are skipped")
    ap.add_argument("--list", action="store_true", dest="list_suites",
                    help="print registered suite names and exit")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="also write per-suite results as JSON to PATH")
    args = ap.parse_args(argv)
    if args.list_suites:
        for name in SUITES:
            print(name)
        return
    selected = SUITES
    if args.only:
        wanted = [s.strip() for s in args.only.split(",") if s.strip()]
        unknown = sorted(set(wanted) - set(SUITES))
        if unknown:
            ap.error(f"unknown suite(s) {unknown}; registered: {SUITES}")
        selected = [s for s in SUITES if s in wanted]
    print("name,us_per_call,derived")
    report = {
        "smoke": args.smoke,
        "backend": jax.devices()[0].platform,
        "jax": jax.__version__,
        "machine": platform.machine(),
        "suites": {},
    }
    for mod_name in selected:
        t0 = time.time()
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
        if args.smoke and not _supports_smoke(mod.run):
            print(f"# {mod_name} skipped (no smoke mode)", flush=True)
            continue
        lines = mod.run(smoke=True) if args.smoke else mod.run()
        for line in lines:
            print(line, flush=True)
        elapsed = time.time() - t0
        print(f"# {mod_name} done in {elapsed:.1f}s", flush=True)
        report["suites"][mod_name] = {
            "elapsed_s": round(elapsed, 1),
            "rows": [_parse_row(l) for l in lines],
        }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# json written to {args.json}", flush=True)


if __name__ == "__main__":
    main()
