"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig6,vectors] [--smoke]
                                          [--list] [--json PATH]
                                          [--compare BASELINE.json]

``--only`` takes a comma-separated list of EXACT suite names (``--only
kernels_bench`` no longer also pulls in every suite containing the
substring); ``--list`` prints the registered suites; ``--smoke`` runs tiny
shapes — suites that support it are called with ``run(smoke=True)``, the
rest are skipped with a comment row (used as the non-blocking CI perf
probe).  Prints ``name,us_per_call,derived`` CSV rows; ``--json PATH``
additionally writes the same results machine-readably, grouped per suite
plus host metadata (device_kind, device count, dtype defaults) so
baselines and autotune caches are comparable across hosts (the committed
``BENCH_stage2.json`` baseline and the CI workflow artifact are produced
this way).

``--compare BASELINE.json`` is the regression gate: rows are matched by
name against a previously committed ``--json`` report and the run FAILS
(exit 1) when any matched row regresses ``us_per_call`` by more than
``--compare-threshold`` percent (``--compare-warn-only`` downgrades the
failure to a warning — how CI runs it until the noise floor is known).
Rows present on only one side are reported but never fail the gate, and a
baseline recorded on different hardware (device_kind mismatch) downgrades
to warn-only automatically — cross-host numbers are not comparable.

The roofline tables (EXPERIMENTS.md §Roofline) come from the dry-run
artifacts instead: ``python -m repro.roofline.report`` after ``python -m
repro.launch.dryrun``.
"""

from __future__ import annotations

import argparse
import inspect
import json
import os
import platform
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

SUITES = ["accuracy", "hyperparams", "occupancy", "scaling", "precision",
          "kernels_bench", "fusion", "batched", "vectors", "fused_small",
          "serve_load", "stage3"]


def _supports_smoke(fn) -> bool:
    try:
        return "smoke" in inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False


def _parse_row(line: str) -> dict:
    name, us, derived = line.split(",", 2)
    return {"name": name, "us_per_call": float(us), "derived": derived}


def _cpu_model() -> str:
    """Host CPU identity.  ``device_kind`` is just "cpu" on EVERY CPU host,
    so CPU wall-clock baselines need the actual part number to know whether
    they are comparable (a TPU kind like "tpu v5e" already carries it)."""
    try:
        with open("/proc/cpuinfo") as f:
            for line in f:
                if line.startswith("model name"):
                    return line.split(":", 1)[1].strip()
    except OSError:
        pass
    return platform.processor() or ""


def _flat_rows(report: dict) -> dict[str, float]:
    """{row name: us_per_call} across every suite of a --json report."""
    flat = {}
    for suite in report.get("suites", {}).values():
        for r in suite.get("rows", []):
            flat[r["name"]] = float(r["us_per_call"])
    return flat


def compare_reports(baseline: dict, current: dict, *,
                    threshold_pct: float) -> tuple[list[str], list[str]]:
    """Match rows by name; return (report lines, failing row names).

    A row fails when its ``us_per_call`` regressed more than
    ``threshold_pct`` percent over the baseline.  Unmatched rows (renamed
    suites, new benchmarks) are listed but never fail.
    """
    base, cur = _flat_rows(baseline), _flat_rows(current)
    lines, failures = [], []
    for name in sorted(set(base) & set(cur)):
        old, new = base[name], cur[name]
        pct = 100.0 * (new - old) / old if old > 0 else 0.0
        verdict = "ok"
        if pct > threshold_pct:
            verdict = f"REGRESSION (> {threshold_pct:g}%)"
            failures.append(name)
        lines.append(f"# compare: {name}: {old:.1f} -> {new:.1f} us "
                     f"({pct:+.1f}%) {verdict}")
    for name in sorted(set(base) - set(cur)):
        lines.append(f"# compare: {name}: only in baseline (skipped)")
    for name in sorted(set(cur) - set(base)):
        lines.append(f"# compare: {name}: new row (no baseline)")
    return lines, failures


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated exact suite names (see --list)")
    ap.add_argument("--exclude", default="", metavar="NAMES",
                    help="comma-separated exact suite names to skip (e.g. a "
                         "suite a dedicated CI step already runs)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes; suites without a smoke mode are skipped")
    ap.add_argument("--list", action="store_true", dest="list_suites",
                    help="print registered suite names and exit")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="also write per-suite results as JSON to PATH")
    ap.add_argument("--compare", default="", metavar="BASELINE",
                    help="baseline --json report; fail on us_per_call "
                         "regressions beyond --compare-threshold")
    ap.add_argument("--compare-threshold", type=float, default=25.0,
                    metavar="PCT", help="max tolerated regression, percent "
                                        "(default: 25)")
    ap.add_argument("--compare-warn-only", action="store_true",
                    help="report regressions but always exit 0")
    args = ap.parse_args(argv)
    if args.list_suites:
        for name in SUITES:
            print(name)
        return
    selected = SUITES
    if args.only:
        wanted = [s.strip() for s in args.only.split(",") if s.strip()]
        unknown = sorted(set(wanted) - set(SUITES))
        if unknown:
            ap.error(f"unknown suite(s) {unknown}; registered: {SUITES}")
        selected = [s for s in SUITES if s in wanted]
    if args.exclude:
        excl = [s.strip() for s in args.exclude.split(",") if s.strip()]
        unknown = sorted(set(excl) - set(SUITES))
        if unknown:
            ap.error(f"unknown suite(s) {unknown}; registered: {SUITES}")
        selected = [s for s in selected if s not in excl]
    print("name,us_per_call,derived")
    from repro.autotune.model import device_kind

    report = {
        "smoke": args.smoke,
        "backend": jax.devices()[0].platform,
        # Host identity: what makes perf baselines (and autotune cache
        # entries, which share the device_kind key axis — hence the same
        # normalization) comparable.
        "device_kind": device_kind(),
        "cpu_model": _cpu_model(),
        "device_count": jax.device_count(),
        "x64": bool(jax.config.jax_enable_x64),
        "default_dtype": str(jnp.zeros(()).dtype),
        "jax": jax.__version__,
        "python": platform.python_version(),
        "machine": platform.machine(),
        "suites": {},
    }
    for mod_name in selected:
        t0 = time.time()
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
        if args.smoke and not _supports_smoke(mod.run):
            print(f"# {mod_name} skipped (no smoke mode)", flush=True)
            continue
        lines = mod.run(smoke=True) if args.smoke else mod.run()
        for line in lines:
            print(line, flush=True)
        elapsed = time.time() - t0
        print(f"# {mod_name} done in {elapsed:.1f}s", flush=True)
        report["suites"][mod_name] = {
            "elapsed_s": round(elapsed, 1),
            "rows": [_parse_row(l) for l in lines],
        }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# json written to {args.json}", flush=True)
    if args.compare:
        with open(args.compare) as f:
            baseline = json.load(f)
        warn_only = args.compare_warn_only
        base_kind = baseline.get("device_kind", "")
        mismatch = ""
        if base_kind != report["device_kind"]:
            # A pre-metadata baseline (no device_kind) is just as
            # non-comparable as a different device: downgrade either way
            # so the gate never blocks on numbers from an unknown host.
            mismatch = (f"baseline device_kind {base_kind!r} vs host "
                        f"{report['device_kind']!r}" if base_kind
                        else "baseline has no device_kind (pre-metadata "
                             "schema)")
        elif (base_kind == "cpu"
              and baseline.get("cpu_model", "") != report["cpu_model"]):
            # "cpu" matches on every CPU host; wall-clock between different
            # parts is not comparable, so the CPU identity is the model
            # string.  Re-baseline from a CI runner's uploaded
            # bench_smoke.json artifact to arm the gate on that hardware.
            mismatch = (f"baseline cpu_model "
                        f"{baseline.get('cpu_model', '')!r} vs host "
                        f"{report['cpu_model']!r}")
        if mismatch:
            print(f"# compare: {mismatch}; cross-host numbers are not "
                  f"comparable -> warn-only", flush=True)
            warn_only = True
        lines, failures = compare_reports(
            baseline, report, threshold_pct=args.compare_threshold)
        for line in lines:
            print(line, flush=True)
        if failures:
            print(f"# compare: {len(failures)} row(s) regressed beyond "
                  f"{args.compare_threshold:g}% vs {args.compare}",
                  flush=True)
            if not warn_only:
                sys.exit(1)
        else:
            print(f"# compare: no regression beyond "
                  f"{args.compare_threshold:g}% vs {args.compare}",
                  flush=True)


if __name__ == "__main__":
    main()
