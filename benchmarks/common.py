"""Shared benchmark utilities: timing, banded matrix generation, CSV rows.

Timing delegates to ``repro.autotune.measure.measure_seconds`` — the one
blocking/jit-warmup/median-of-k path shared with the autotuner, so the
hand-rolled sweeps and the on-device search compare like with like.
"""

from __future__ import annotations

import numpy as np

from repro.autotune.measure import measure_seconds


def banded(n: int, bw: int, seed: int = 0, dtype=np.float64) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = np.triu(rng.standard_normal((n, n)))
    return (np.triu(a) - np.triu(a, bw + 1)).astype(dtype)


def synthetic_spectrum(n: int, profile: str, seed: int = 0):
    rng = np.random.default_rng(seed)
    u, _ = np.linalg.qr(rng.standard_normal((n, n)))
    v, _ = np.linalg.qr(rng.standard_normal((n, n)))
    if profile == "arithmetic":
        s = np.linspace(1.0, 1.0 / n, n)
    elif profile == "logarithmic":
        s = np.logspace(0, -5, n)
    else:                                    # quartercircle
        x = (np.arange(n) + 0.5) / n
        s = np.sqrt(1 - x * x)
    return u @ np.diag(s) @ v.T, s


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds of fn(*args) (jax-blocking); the autotuner's
    ``measure_seconds`` under the historical benchmark-suite name."""
    return measure_seconds(fn, *args, warmup=warmup, iters=iters)


def row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"
