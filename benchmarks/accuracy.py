"""Paper Fig. 3: relative singular-value error of the GPU(-style) reduction
across precisions x spectrum profiles x (n, bw).

Protocol (as the paper): A = U diag(sigma) V^T with prescribed spectrum;
stage 1 in fp64; stage 2 (the paper's bulge chase) in the precision under
test; stage 3 in fp64; report ||sigma_hat - sigma|| / ||sigma||.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import synthetic_spectrum, row
from repro.core.stage1 import band_reduce
from repro.core import bulge_chasing as bc
from repro.core.bidiag_svd import bidiag_singular_values

CASES = [(64, 8), (128, 16)]
PROFILES = ["arithmetic", "logarithmic", "quartercircle"]
DTYPES = [("fp64", jnp.float64), ("fp32", jnp.float32), ("bf16", jnp.bfloat16)]


def run() -> list[str]:
    out = []
    for n, bw in CASES:
        for profile in PROFILES:
            a, s_true = synthetic_spectrum(n, profile, seed=3)
            banded = np.asarray(band_reduce(jnp.asarray(a), nb=bw))
            for name, dt in DTYPES:
                d, e = bc.bidiagonalize(jnp.asarray(banded, dt), bw=bw,
                                        tw=max(bw // 4, 1), backend="ref")
                sig = np.asarray(bidiag_singular_values(
                    jnp.asarray(d, jnp.float64), jnp.asarray(e, jnp.float64)))
                rel = np.linalg.norm(sig - s_true) / np.linalg.norm(s_true)
                out.append(row(f"fig3/{profile}/n{n}_bw{bw}/{name}", 0.0,
                               f"rel_err={rel:.2e}"))
    return out
