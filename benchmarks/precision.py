"""Paper Fig. 7: runtime across data precisions and bandwidths (portability/
precision-agnosticism of the single-source implementation).

Same jitted wavefront stage in fp64 / fp32 / bf16 at bandwidths 8 and 32 —
the kernel is dtype-polymorphic end to end (reflector accumulation promotes
to fp32 for half types).  Numerical sanity (sigma drift vs fp64) is reported
alongside runtime.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import banded, row, timeit
from repro.core import bulge_chasing as bc
from repro.core.bidiag_svd import bidiag_singular_values

N = 256
BWS = [8, 32]
DTYPES = [("fp64", jnp.float64), ("fp32", jnp.float32), ("bf16", jnp.bfloat16)]


def run() -> list[str]:
    out = []
    for bw in BWS:
        a = banded(N, bw, seed=4)
        tw = max(bw // 4, 1)
        ref_sig = None
        for name, dt in DTYPES:
            aj = jnp.asarray(a, dt)
            fn = lambda x: bc.bidiagonalize(x, bw=bw, tw=tw, backend="ref")
            t = timeit(fn, aj, warmup=1, iters=3)
            d, e = fn(aj)
            sig = np.asarray(bidiag_singular_values(
                jnp.asarray(d, jnp.float64), jnp.asarray(e, jnp.float64)))
            if ref_sig is None:
                ref_sig = sig
                drift = 0.0
            else:
                drift = float(np.linalg.norm(sig - ref_sig) /
                              np.linalg.norm(ref_sig))
            out.append(row(f"fig7/bw{bw}/{name}", t * 1e6,
                           f"sigma_drift_vs_fp64={drift:.2e}"))
    return out
