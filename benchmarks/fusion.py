"""Chase super-step fusion sweep (DESIGN.md §9): stage-2 time vs fuse depth.

For each (n, bw, tw) shape and fuse depth K the suite measures one stage-2
reduction (``reduce_stage_packed`` at the stage-head bandwidth) and reports

  * wall time per call (``us_per_call``);
  * ``cycles_per_s`` — executed chase cycles per second (the cycle count is
    fuse-invariant, so this is the honest throughput axis);
  * ``supercycles`` — kernel dispatches on the wavefront clock (the ~K-fold
    launch/gather saving the fusion buys);
  * ``speedup`` vs the K = 1 baseline of the same shape.

Full mode adds the end-to-end stage-2 pipeline (the whole bw -> 1 tile-width
plan via ``bidiagonalize_packed``) at every depth.  Smoke mode runs the
acceptance shape n=1024, bw=32 on the ref/CPU path — the committed
``BENCH_stage2.json`` baseline comes from ``run.py --smoke --json``.

  PYTHONPATH=src python -m benchmarks.run --only fusion
  PYTHONPATH=src python -m benchmarks.run --only fusion --smoke
  PYTHONPATH=src python benchmarks/fusion.py
"""

from __future__ import annotations

import os
import sys

if __package__ in (None, ""):                 # direct script execution
    _REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _REPO)
    sys.path.insert(0, os.path.join(_REPO, "src"))

import jax.numpy as jnp
import numpy as np

from benchmarks.common import banded, row, timeit

FULL = dict(shapes=((512, 32, 8), (1024, 32, 8)), depths=(1, 2, 4, 8),
            iters=2, e2e=True)
SMOKE = dict(shapes=((1024, 32, 8),), depths=(1, 2, 4), iters=1, e2e=False)


def run(smoke: bool = False):
    from repro.autotune.model import total_chase_cycles
    from repro.core import band as bandmod
    from repro.core import bulge_chasing as bc

    p = SMOKE if smoke else FULL
    out = []
    for n, bw, tw in p["shapes"]:
        a = banded(n, bw, seed=0, dtype=np.float32)
        packed = bandmod.pack(jnp.asarray(a), bw, tw)
        cyc = total_chase_cycles(n, bw, tw)
        base_t = None
        for k in p["depths"]:

            def stage(pk=packed, k=k):
                return bc.reduce_stage_packed(pk, n=n, b_in=bw, tw=tw,
                                              backend="ref", fuse=k)

            t = timeit(stage, warmup=1, iters=p["iters"])
            base_t = t if k == 1 else base_t
            _, supercycles, g = bc.stage_schedule(n, bw, tw, k)
            out.append(row(
                f"fusion/stage/n{n}/bw{bw}/tw{tw}/K{k}", t * 1e6,
                f"cycles_per_s={cyc / t:.0f};supercycles={supercycles};"
                f"wavefront={g};speedup={base_t / t:.2f}x"))
        if not p["e2e"]:
            continue
        base_t = None
        for k in p["depths"]:

            def e2e(pk=packed, k=k):
                return bc.bidiagonalize_packed(pk, n=n, bw=bw, tw=tw,
                                               backend="ref", fuse=k)

            t = timeit(e2e, warmup=1, iters=p["iters"])
            base_t = t if k == 1 else base_t
            out.append(row(f"fusion/e2e_stage2/n{n}/bw{bw}/tw{tw}/K{k}",
                           t * 1e6, f"speedup={base_t / t:.2f}x"))
    return out


if __name__ == "__main__":
    import jax

    jax.config.update("jax_enable_x64", True)
    print("name,us_per_call,derived")
    for line in run(smoke="--smoke" in sys.argv):
        print(line, flush=True)
