"""Fused small-n tier vs the staged pipeline (DESIGN.md §13).

Two measurements:

* **Per-n crossover sweep** — the same dense ``(B, n, n)`` stack through
  ``core.svd.svd_batched`` twice: ``backend="fused_small"`` (the whole
  per-matrix pipeline as one dispatch) vs the staged platform default.
  The derived column carries the speedup; the largest winning n is the
  measured crossover the autotuner persists
  (``python -m repro.autotune --fused-crossover``).

* **Serve p99 with the tier on vs off** — the serve_load Poisson harness
  run twice on the same small-n mix, ``fused_n_max`` at the default vs 0
  (tier disabled), isolating what the one-dispatch tier buys an actual
  B-heavy serving workload end to end.

  PYTHONPATH=src python -m benchmarks.run --only fused_small [--smoke]
  PYTHONPATH=src python benchmarks/fused_small.py
"""

from __future__ import annotations

import os
import sys

if __package__ in (None, ""):                 # direct script execution
    _REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _REPO)
    sys.path.insert(0, os.path.join(_REPO, "src"))

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit

SWEEP_NS = (16, 32, 64, 128, 256)
SMOKE_NS = (16, 32)
BW = 8
SMOKE_BW = 4
BATCH = 8


def sweep(ns, bw, *, batch=BATCH, dtype=np.float64, seed=0):
    """Fused vs staged per-matrix wall time over the n sweep."""
    from repro.core import svd as svdmod
    from repro.core.tuning import PipelineConfig

    out = []
    fused_n_max = 0
    for n in ns:
        bw_eff = max(1, min(bw, max(n - 1, 1)))
        mats = jnp.asarray(np.random.default_rng(seed)
                           .standard_normal((batch, n, n)).astype(dtype))
        cfg_f = PipelineConfig.resolve(bw=bw_eff, dtype=dtype, n=n,
                                       backend="fused_small")
        cfg_s = PipelineConfig.resolve(bw=bw_eff, dtype=dtype, n=n)

        t_fused = timeit(lambda m=mats, c=cfg_f: svdmod.svd_batched(m, c))
        t_staged = timeit(lambda m=mats, c=cfg_s: svdmod.svd_batched(m, c))
        speedup = t_staged / t_fused
        if t_fused < t_staged:
            fused_n_max = n
        out.append(row(f"fused_small/fused/n{n}/bw{bw_eff}/B{batch}",
                       t_fused / batch * 1e6,
                       f"mats_per_s={batch / t_fused:.2f};"
                       f"speedup={speedup:.2f}x"))
        out.append(row(f"fused_small/staged/n{n}/bw{bw_eff}/B{batch}",
                       t_staged / batch * 1e6,
                       f"mats_per_s={batch / t_staged:.2f}"))
    out.append(row(f"fused_small/crossover/bw{bw}", 0.0,
                   f"measured_fused_n_max={fused_n_max}"))
    return out


def serve_p99_on_off(*, smoke=True, seed=0):
    """Serve-tier p99 with the fused tier on (default routing) vs off
    (``fused_n_max=0``), same mix, same arrival process."""
    from benchmarks import serve_load

    mix = serve_load.SMOKE_MIX
    count, rate = (12, 120.0) if smoke else (48, 60.0)
    out = []
    for tag, fmax in (("on", None), ("off", 0)):
        prows, poi = serve_load.poisson_run(mix, count, rate, backend="ref",
                                            seed=seed, fused_n_max=fmax)
        p = poi["latency_ms"]
        tiers = poi["engine_metrics"].get("tiers", {})
        fused_b = tiers.get("fused", {}).get("batches", 0)
        out.append(row(f"fused_small/serve_p99/{tag}", p["p99"] * 1e3,
                       f"p50={p['p50']:.1f}ms;p99={p['p99']:.1f}ms;"
                       f"thpt={poi['throughput_rps']:.1f}rps;"
                       f"fused_batches={fused_b}"))
    return out


def run(smoke: bool = False):
    ns = SMOKE_NS if smoke else SWEEP_NS
    bw = SMOKE_BW if smoke else BW
    out = sweep(ns, bw)
    out += serve_p99_on_off(smoke=True)       # smoke-sized either way: the
    return out                                # sweep above owns the full axis


if __name__ == "__main__":
    import jax

    jax.config.update("jax_enable_x64", True)
    print("name,us_per_call,derived")
    for line in run():
        print(line, flush=True)
