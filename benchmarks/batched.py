"""Batched vs looped SVD throughput (the batch-native tentpole's payoff).

For small matrices a single chase wavefront cannot fill the machine (paper
Eq. 1: full utilization needs n / (3*CBW) >= execution units); batching B
independent problems multiplies the wavefront width with the SAME number of
global cycles.  This sweep measures matrices/second of

  * ``looped``  — per-matrix ``banded_singular_values`` calls in a host loop;
  * ``batched`` — one ``(B, n, n)`` batch-native pipeline call;

for B in BATCH_SIZES, reporting the speedup in the derived column.

  PYTHONPATH=src python -m benchmarks.run --only batched
  PYTHONPATH=src python benchmarks/batched.py
"""

from __future__ import annotations

import os
import sys

if __package__ in (None, ""):                 # direct script execution
    _REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _REPO)
    sys.path.insert(0, os.path.join(_REPO, "src"))

import jax.numpy as jnp
import numpy as np

from benchmarks.common import banded, row, timeit

BATCH_SIZES = (1, 4, 16)
SHAPES = ((96, 8), (128, 16))                     # (n, bw): Eq.-1-starved sizes
TW = 4


def run():
    from repro.core import svd as svdmod
    from repro.core.tuning import PipelineConfig, default_bucket_batch

    out = []
    for n, bw in SHAPES:
        cfg = PipelineConfig.resolve(bw=bw, tw=TW, backend="ref",
                                     dtype=np.float64, n=n)
        out.append(row(f"batched/bucket_hint/n{n}/bw{bw}",
                       0.0, f"default_bucket_batch={default_bucket_batch(n, bw)}"))
        for B in BATCH_SIZES:
            mats = jnp.asarray(np.stack([banded(n, bw, seed=s)
                                         for s in range(B)]))

            def looped(ms=mats):
                return [svdmod.banded_singular_values(ms[b], bw=bw, config=cfg)
                        for b in range(ms.shape[0])]

            def batched(ms=mats):
                return svdmod.banded_singular_values(ms, bw=bw, config=cfg)

            t_loop = timeit(looped)
            t_batch = timeit(batched)
            speedup = t_loop / t_batch
            out.append(row(f"batched/looped/n{n}/bw{bw}/B{B}",
                           t_loop * 1e6, f"mats_per_s={B / t_loop:.2f}"))
            out.append(row(f"batched/batched/n{n}/bw{bw}/B{B}",
                           t_batch * 1e6,
                           f"mats_per_s={B / t_batch:.2f};speedup={speedup:.2f}x"))
    return out


if __name__ == "__main__":
    import jax

    jax.config.update("jax_enable_x64", True)
    print("name,us_per_call,derived")
    for line in run():
        print(line, flush=True)
