"""Stage-3 bidiagonal solvers: Sturm bisection vs divide and conquer
(DESIGN.md §14).

Two measurements:

* **Per-n crossover sweep** — the same random bidiagonal ``(B, n)`` stack
  through ``core.bidiag_svd.bidiag_singular_values`` (the lockstep Sturm
  bisection: a fixed ``max_iter`` of full m^2 count sweeps, m = 2n) and
  ``core.bidiag_dc.bidiag_dc_singular_values`` (secular-equation merges
  with deflation).  The derived column carries the speedup and the sigma
  agreement; the smallest winning n is the measured crossover the
  autotuner persists (``python -m repro.autotune --stage3-crossover``)
  and ``stage3="auto"`` consumes.

* **Full-SVD variant** — the same sweep through ``bidiag_svd`` vs
  ``bidiag_dc_svd`` at a couple of sizes (both share the inverse-iteration
  vector machinery, so this isolates what the sigma solver contributes to
  the uv path).

``--check`` (implied in smoke mode) asserts dc-vs-bisect sigma agreement
<= 1e-12 relative at fp64 for every measured n and exits non-zero on
violation — the benchmark cannot report a speedup from a wrong answer.

  PYTHONPATH=src python -m benchmarks.run --only stage3 [--smoke]
  PYTHONPATH=src python benchmarks/stage3.py [--check]
"""

from __future__ import annotations

import argparse
import os
import sys

if __package__ in (None, ""):                 # direct script execution
    _REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _REPO)
    sys.path.insert(0, os.path.join(_REPO, "src"))

import numpy as np

from benchmarks.common import row, timeit

SWEEP_NS = (512, 1024, 2048, 4096)
SMOKE_NS = (128, 256)
UV_NS = (512, 1024)
SMOKE_UV_NS = (64,)
BATCH = 4
AGREE_TOL = 1e-12


def _stack(n, batch, seed=0, dtype=np.float64):
    """Random (d, e) stacks in the repo convention: e is (n,) with e[0]
    unused (e[i] = B[i-1, i])."""
    rng = np.random.default_rng(seed)
    d = rng.standard_normal((batch, n)).astype(dtype)
    e = rng.standard_normal((batch, n)).astype(dtype)
    return d, e


def sweep(ns, *, batch=BATCH, seed=0, check=False):
    """Bisect vs dc per-matrix wall time (values path) over the n sweep."""
    import jax
    import jax.numpy as jnp

    from repro.core import bidiag_dc as dc
    from repro.core import bidiag_svd as bs

    out, failures = [], []
    dc_n_min = 1 + max(ns)
    wins = []
    for n in ns:
        d, e = _stack(n, batch, seed)
        d, e = jnp.asarray(d), jnp.asarray(e)
        f_bi = jax.vmap(bs.bidiag_singular_values)
        # dc batches (B, n) natively; vmap would turn its deflation-skip
        # conds into both-branch selects and time a crippled solver.
        f_dc = dc.bidiag_dc_singular_values
        s_bi = jax.block_until_ready(f_bi(d, e))
        s_dc = jax.block_until_ready(f_dc(d, e))
        agree = float(jnp.max(jnp.abs(s_dc - s_bi)) / jnp.max(jnp.abs(s_bi)))
        if check and agree > AGREE_TOL:
            failures.append(f"n={n}: dc-vs-bisect sigma disagreement "
                            f"{agree:.2e} rel > {AGREE_TOL:g}")
        t_bi = timeit(lambda: f_bi(d, e))
        t_dc = timeit(lambda: f_dc(d, e))
        wins.append((n, t_dc < t_bi))
        out.append(row(f"stage3/dc/n{n}/B{batch}", t_dc / batch * 1e6,
                       f"speedup={t_bi / t_dc:.2f}x;agree={agree:.1e}"))
        out.append(row(f"stage3/bisect/n{n}/B{batch}", t_bi / batch * 1e6,
                       f"mats_per_s={batch / t_bi:.2f}"))
    for n, won in reversed(wins):
        if won:
            dc_n_min = n
        else:
            break
    out.append(row("stage3/crossover", 0.0, f"measured_dc_n_min={dc_n_min}"))
    return out, failures


def sweep_uv(ns, *, batch=2, seed=0, check=False):
    """Bisect vs dc through the full-SVD stage-3 path (vectors included)."""
    import jax
    import jax.numpy as jnp

    from repro.core import bidiag_dc as dc
    from repro.core import bidiag_svd as bs

    out, failures = [], []
    for n in ns:
        d, e = _stack(n, batch, seed)
        d, e = jnp.asarray(d), jnp.asarray(e)
        f_bi = jax.vmap(bs.bidiag_svd)
        f_dc = dc.bidiag_dc_svd         # native batching: see sweep()
        s_bi = jax.block_until_ready(f_bi(d, e))[1]
        s_dc = jax.block_until_ready(f_dc(d, e))[1]
        agree = float(jnp.max(jnp.abs(s_dc - s_bi)) / jnp.max(jnp.abs(s_bi)))
        if check and agree > AGREE_TOL:
            failures.append(f"uv n={n}: dc-vs-bisect sigma disagreement "
                            f"{agree:.2e} rel > {AGREE_TOL:g}")
        t_bi = timeit(lambda: f_bi(d, e))
        t_dc = timeit(lambda: f_dc(d, e))
        out.append(row(f"stage3/dc_uv/n{n}/B{batch}", t_dc / batch * 1e6,
                       f"speedup={t_bi / t_dc:.2f}x;agree={agree:.1e}"))
        out.append(row(f"stage3/bisect_uv/n{n}/B{batch}", t_bi / batch * 1e6,
                       f"mats_per_s={batch / t_bi:.2f}"))
    return out, failures


def run(smoke: bool = False):
    """benchmarks.run suite entry: CSV rows; smoke mode also CHECKS sigma
    agreement (raising on violation — the CI stage-3 gate rides here)."""
    rows, failures = sweep(SMOKE_NS if smoke else SWEEP_NS, check=smoke)
    urows, ufail = sweep_uv(SMOKE_UV_NS if smoke else UV_NS, check=smoke)
    failures += ufail
    if failures:
        raise AssertionError("; ".join(failures))
    return rows + urows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, seconds-scale")
    ap.add_argument("--check", action="store_true",
                    help="assert dc-vs-bisect sigma agreement <= 1e-12 rel")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    jax.config.update("jax_enable_x64", True)

    check = args.check or args.smoke
    print("name,us_per_call,derived")
    rows, failures = sweep(SMOKE_NS if args.smoke else SWEEP_NS,
                           seed=args.seed, check=check)
    urows, ufail = sweep_uv(SMOKE_UV_NS if args.smoke else UV_NS,
                            seed=args.seed, check=check)
    for line in rows + urows:
        print(line, flush=True)
    for f in failures + ufail:
        print(f"# STAGE3 GATE FAIL: {f}", flush=True)
    if failures + ufail:
        sys.exit(1)
    if check:
        print("# stage3 gate OK", flush=True)


if __name__ == "__main__":
    main()
