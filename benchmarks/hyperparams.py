"""Paper Fig. 4 / Table III: hyperparameter sweep of the chase kernel.

The paper's knobs map to (DESIGN.md §2): inner tilewidth TW (dominant),
rows-per-step (TPB) and max concurrent blocks (wavefront width, fixed by the
schedule here).  We sweep TW and report:

  * wall runtime of the jitted wavefront stage (CPU; work  traffic);
  * runtime / TW — the paper's "configurations with half the tilewidth run
    twice as often" normalization (Table III bold-face criterion);
  * the modeled VMEM working set per chase window (what the TPU kernel
    stages), and the number of cycles (kernel launches).
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import banded, row, timeit
from repro.core import band as bandmod
from repro.core import bulge_chasing as bc
from repro.core.tuning import vmem_working_set_bytes

N, BW = 384, 32
TWS = [1, 2, 4, 8, 16, 31]


def run() -> list[str]:
    out = []
    a = banded(N, BW, seed=1, dtype="float32")
    for tw in TWS:
        packed = bandmod.pack(jnp.asarray(a), BW, tw)
        fn = lambda p, tw=tw: bc.reduce_stage_packed(p, n=N, b_in=BW, tw=tw,
                                                     backend="ref")
        t = timeit(fn, packed, warmup=1, iters=3)
        nsweeps, cycles, conc = bc.stage_schedule(N, BW, tw)
        vmem = vmem_working_set_bytes(BW, tw, jnp.float32)
        stages_needed = -(-(BW - 1) // tw)
        out.append(row(
            f"fig4/tw{tw}", t * 1e6,
            f"t_per_tw_us={t * 1e6 / tw:.1f};stages_to_bidiag={stages_needed};"
            f"cycles={cycles};concurrency={conc};vmem_window_B={vmem}"))
    return out
