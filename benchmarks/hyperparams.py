"""Paper Fig. 4 / Table III: hyperparameter sweep of the chase kernel.

The paper's knobs map to (DESIGN.md §2): inner tilewidth TW (dominant),
rows-per-step (TPB) and max concurrent blocks (wavefront width, fixed by the
schedule here).  The sweep runs on the autotuner's shared timing path
(``repro.autotune.measure.time_stage2`` — the same harness the on-device
search uses, DESIGN.md §11) and reports per TW:

  * wall runtime of the jitted wavefront stage (CPU; work  traffic);
  * runtime / TW — the paper's "configurations with half the tilewidth run
    twice as often" normalization (Table III bold-face criterion);
  * the analytic cost model's prediction for the same configuration
    (``repro.autotune.model.stage_cost``) — eyeballing this column against
    the measured one is the sweep-level view of the autotuner's
    predicted-vs-measured validation table;
  * the modeled VMEM working set per chase window (what the TPU kernel
    stages), and the number of cycles (kernel launches).
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import row
from repro.autotune import measure, model
from repro.core import bulge_chasing as bc
from repro.core.tuning import vmem_working_set_bytes

N, BW = 384, 32
TWS = [1, 2, 4, 8, 16, 31]


def run() -> list[str]:
    out = []
    profile = model.profile_for()
    for tw in TWS:
        t = measure.time_stage2(N, BW, tw=tw, backend="ref",
                                dtype=jnp.float32, full=False, seed=1,
                                warmup=1, iters=3)
        pred = model.stage_cost(N, BW, tw, profile=profile)
        nsweeps, cycles, conc = bc.stage_schedule(N, BW, tw)
        vmem = vmem_working_set_bytes(BW, tw, jnp.float32)
        stages_needed = -(-(BW - 1) // tw)
        out.append(row(
            f"fig4/tw{tw}", t * 1e6,
            f"t_per_tw_us={t * 1e6 / tw:.1f};"
            f"model_us={pred.seconds * 1e6:.1f};"
            f"stages_to_bidiag={stages_needed};"
            f"cycles={cycles};concurrency={conc};vmem_window_B={vmem}"))
    return out
