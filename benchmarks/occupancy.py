"""Paper Table I + Eq. 1: matrix size for full occupancy, n >= 3*CBW*ALUs.

Reproduces the paper's table for the GPU parts and extends it with the TPU
pod targets of this framework (execution unit = TensorCore; batch dispatch
changes the constraint to #matrices >= cores, also shown).
"""

from __future__ import annotations

from benchmarks.common import row
from repro.core.tuning import occupancy_matrix_size

HW = [
    ("NVIDIA-H100", 132 * 4),        # SMs x warp schedulers (paper)
    ("AMD-MI300X", 304),
    ("Intel-PVC-1100", 56),
    ("TPU-v5e-pod-256chips", 256 * 2),   # 2 TensorCores/chip (this work)
    ("TPU-v5e-2pods-512chips", 512 * 2),
]

CBW = 32


def run() -> list[str]:
    out = []
    for name, alus in HW:
        n = occupancy_matrix_size(CBW, alus)
        out.append(row(f"table1/{name}", 0.0,
                       f"alus={alus};cbw={CBW};n_full_occupancy={n}"))
    out.append(row("table1/TPU-batch-dispatch", 0.0,
                   "note=batched spectra need #matrices>=cores instead; "
                   "wavefront occupancy applies within each matrix"))
    return out
