"""Paper Fig. 6: band-to-bidiagonal runtime scaling vs (n, bw), against the
host LAPACK baseline.

The paper compares its GPU GBBRD against PLASMA/SLATE on a 32-core Xeon
(offline here).  We report, per (n, bw): our wavefront GBBRD (stage 2+3,
f32) wall time on this host, the full-dense ``numpy.linalg.svd`` (LAPACK
gesdd) time, and the ratio — the same ratio-style table as Fig. 6.  On real
TPU hardware the GBBRD column is the one the roofline model (EXPERIMENTS.md
§Roofline-kernel) projects.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import banded, row, timeit
from repro.core.svd import banded_singular_values

CASES = [(256, 8), (256, 32), (512, 8), (512, 32)]


def run() -> list[str]:
    out = []
    for n, bw in CASES:
        a = banded(n, bw, seed=2, dtype="float32")
        aj = jnp.asarray(a)
        tw = max(bw // 4, 1)
        ours = lambda x: banded_singular_values(x, bw=bw, tw=tw, backend="ref")
        t_ours = timeit(ours, aj, warmup=1, iters=3)
        t_ref = timeit(lambda: np.linalg.svd(a, compute_uv=False), iters=3)
        out.append(row(f"fig6/n{n}_bw{bw}", t_ours * 1e6,
                       f"lapack_us={t_ref * 1e6:.0f};"
                       f"ratio_vs_lapack={t_ref / t_ours:.2f}"))
    return out
