"""Kernel-level microbenchmarks (paper Table III analogue, structural).

Wall-clock of one batched chase cycle (ref backend, jitted — the XLA-fused
CPU realization of the kernel math) across (b_in, tw, wavefront width), plus
the per-window VMEM bytes the Pallas kernel would stage on TPU.  Pallas
interpret-mode timing is NOT a performance signal (python interpreter), so
the TPU projection is the roofline entry in EXPERIMENTS.md.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.core.tuning import vmem_working_set_bytes
from repro.kernels import ops

CASES = [(32, 8, 4), (32, 8, 16), (64, 16, 8), (128, 32, 4), (128, 32, 16)]


def run() -> list[str]:
    out = []
    rng = np.random.default_rng(0)
    for b_in, tw, g in CASES:
        h, w = b_in + 2 * tw + 1, b_in + tw + 1
        win = jnp.asarray(rng.standard_normal((g, h, w)), jnp.float32)
        first = jnp.zeros((g,), bool)
        fn = lambda x, f: ops.chase_cycle(x, f, b_in=b_in, tw=tw, backend="ref")
        t = timeit(fn, win, first, warmup=2, iters=5)
        bytes_win = vmem_working_set_bytes(b_in, tw, jnp.float32)
        traffic = g * h * w * 4 * 2                      # load + store
        out.append(row(
            f"chase_cycle/b{b_in}_tw{tw}_g{g}", t * 1e6,
            f"vmem_window_B={bytes_win};hbm_traffic_B={traffic};"
            f"annihilated={g * 2 * tw}"))
    return out
