"""Kernel-level microbenchmarks (paper Table III analogue, structural).

Wall-clock of one batched chase cycle (ref backend, jitted — the XLA-fused
CPU realization of the kernel math) across (b_in, tw, wavefront width), plus
the per-window VMEM bytes the Pallas kernel would stage on TPU, plus the
kernel-dispatch launch-overhead probe that motivates the fuse-K super-steps
(``_launch_overhead``; DESIGN.md §9).  Pallas interpret-mode timing is NOT a
performance signal (python interpreter), so the TPU projection is the
roofline entry in EXPERIMENTS.md.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.core.tuning import vmem_working_set_bytes
from repro.kernels import ops

CASES = [(32, 8, 4), (32, 8, 16), (64, 16, 8), (128, 32, 4), (128, 32, 16)]
SMOKE_CASES = [(32, 8, 4), (64, 16, 8)]

# launch-overhead microbenchmark: (b_in, tw, fuse depths to amortize over)
LAUNCH_CASES = [(16, 4, (2, 4, 8)), (32, 8, (2, 4, 8))]
LAUNCH_SMOKE = [(16, 4, (4, 8))]


def _launch_overhead(cases) -> list[str]:
    """Fixed per-dispatch cost vs fused amortization (DESIGN.md §9).

    A single-slot K=1 ``chase_cycle`` call is almost pure dispatch overhead
    (one tiny window); the fused call retires K cycles per dispatch, so its
    per-cycle time bounds the overhead a super-step amortizes away.  The
    derived column reports us/cycle at each depth and the K=1 : fused
    per-cycle ratio — the CPU-visible analogue of the paper's
    kernel-launch-sync cost that motivates fusing.
    """
    out = []
    rng = np.random.default_rng(1)
    for b_in, tw, depths in cases:
        h, w = b_in + 2 * tw + 1, b_in + tw + 1
        win = jnp.asarray(rng.standard_normal((1, h, w)), jnp.float32)
        first = jnp.zeros((1,), bool)
        t1 = timeit(lambda: ops.chase_cycle(win, first, b_in=b_in, tw=tw,
                                            backend="ref"),
                    warmup=2, iters=5)
        parts = [f"us_per_cycle_K1={t1 * 1e6:.1f}"]
        for k in depths:
            wk = k * b_in + tw + 1
            blk = jnp.asarray(rng.standard_normal((1, h, wk)), jnp.float32)
            act = jnp.ones((1, k), bool)
            tk = timeit(lambda blk=blk, act=act, k=k: ops.chase_cycle(
                blk, first, b_in=b_in, tw=tw, fuse=k, active=act,
                backend="ref"), warmup=2, iters=5)
            parts.append(f"us_per_cycle_K{k}={tk / k * 1e6:.1f}")
            parts.append(f"overhead_ratio_K{k}={t1 * k / tk:.2f}x")
        out.append(row(f"chase_launch/b{b_in}_tw{tw}", t1 * 1e6,
                       ";".join(parts)))
    return out


def run(smoke: bool = False) -> list[str]:
    out = []
    rng = np.random.default_rng(0)
    for b_in, tw, g in (SMOKE_CASES if smoke else CASES):
        h, w = b_in + 2 * tw + 1, b_in + tw + 1
        win = jnp.asarray(rng.standard_normal((g, h, w)), jnp.float32)
        first = jnp.zeros((g,), bool)
        fn = lambda x, f: ops.chase_cycle(x, f, b_in=b_in, tw=tw, backend="ref")
        t = timeit(fn, win, first, warmup=2, iters=5)
        bytes_win = vmem_working_set_bytes(b_in, tw, jnp.float32)
        traffic = g * h * w * 4 * 2                      # load + store
        out.append(row(
            f"chase_cycle/b{b_in}_tw{tw}_g{g}", t * 1e6,
            f"vmem_window_B={bytes_win};hbm_traffic_B={traffic};"
            f"annihilated={g * 2 * tw}"))
    out += _launch_overhead(LAUNCH_SMOKE if smoke else LAUNCH_CASES)
    return out
