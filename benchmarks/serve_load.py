"""Serve-tier load generator: open-loop Poisson traffic vs the async engine.

Two measurements (DESIGN.md §12):

* **Throughput** — the same B-heavy mixed workload served two ways:
  ``serial`` (per-request values-only/full ``core.svd`` calls, the
  no-serving-tier baseline) vs ``engine`` (one ``AsyncSVDEngine`` burst,
  micro-batched into the bucketed pipeline).  The speedup is the paper's
  batching argument made service-shaped: concurrent small-matrix requests
  aggregate into the wide fused batches a single caller never forms.
  Results are cross-checked against the direct values-only path to 1e-12.

* **Latency under open-loop Poisson arrivals** — a submitter thread draws
  exponential inter-arrival gaps and NEVER waits for completions (open
  loop: arrival pressure is independent of service rate), mixed
  shape/dtype/compute_uv traffic; reports p50/p95/p99 latency, throughput,
  and the engine metrics snapshot.

CLI (the CI serve smoke step, blocking):

  PYTHONPATH=src python -m benchmarks.serve_load --smoke --json out.json

asserts zero dropped/timed-out/rejected requests and a p99 budget, and
exits non-zero on violation.  Full mode (``--check``, minutes) additionally
asserts the >= 3x engine-over-serial throughput acceptance bar.  As a
``benchmarks.run`` suite it emits the usual ``name,us_per_call,derived``
rows (us_per_call = mean per-request service/latency — the stable,
regression-gated column; percentiles ride in ``derived``).

``--chaos`` (DESIGN.md §15) re-runs the same measurement under a seeded
:class:`repro.serve.FaultPlan` — scripted + probabilistic dispatch errors
and NaN sigma corruption on the primary path — and asserts the fabric
absorbed every injected fault: ZERO client-visible failures, sigma still
within the oracle bar, p99 still within budget, and the plan actually
fired (a chaos gate that injected nothing would be a no-op gate).

Accounting is unified client-side (:func:`_client_account`): every
submitted request is classified from its FUTURE's resolution into exactly
one of ok / failed / timed_out / dropped — so the four always sum to
``submitted`` — and cross-checked against the engine's own counters
(completed / failed+rejected / timed_out), with any disagreement flagged
as ``consistent=False`` and failed by the gate.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import threading
import time

if __package__ in (None, ""):                 # direct script execution
    _REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _REPO)
    sys.path.insert(0, os.path.join(_REPO, "src"))

import numpy as np

# Workload mixes: (n, bw, dtype, compute_uv, weight).  B-heavy: the dominant
# entry concentrates traffic in one Eq.-1-starved bucket so micro-batching
# has a wavefront deficit to fill (weights need not sum to 1 exactly).
SMOKE_MIX = ((24, 4, "float64", False, 0.7),
             (24, 4, "float64", True, 0.15),
             (32, 4, "float64", False, 0.15))
FULL_MIX = ((96, 8, "float64", False, 0.7),
            (96, 8, "float64", True, 0.1),
            (64, 8, "float32", False, 0.2))


def _mix_cover(mix, seed=0):
    """One request per mix entry (warms every bucket/compile exactly once)."""
    from repro.serve import SVDRequest
    rng = np.random.default_rng(seed)
    return [SVDRequest(uid=-(i + 1),
                       matrix=rng.standard_normal((n, n)).astype(dt),
                       bw=bw, compute_uv=uv)
            for i, (n, bw, dt, uv, _w) in enumerate(mix)]


def _requests(mix, count, seed=0):
    """Materialize ``count`` requests drawn from the mix, round-robin-ish
    deterministic: weights -> per-entry counts, then shuffled."""
    from repro.serve import SVDRequest
    rng = np.random.default_rng(seed)
    total_w = sum(w for *_, w in mix)
    picks = rng.choice(len(mix), size=count,
                       p=[w / total_w for *_, w in mix])
    reqs = []
    for uid, i in enumerate(picks):
        n, bw, dtype, uv, _w = mix[int(i)]
        m = rng.standard_normal((n, n)).astype(dtype)
        reqs.append(SVDRequest(uid=uid, matrix=m, bw=bw, compute_uv=uv))
    return reqs


def _tune_bucket_cache(mix, *, backend="ref", seed=0):
    """Batch-axis autotune for every bucket in the mix (DESIGN.md §11).

    Full (non-smoke) mode only: searches ``(tw, fuse, batch)`` including
    the batch axis for each distinct ``(n, bw, dtype, uv)`` and persists
    the winners to one throwaway cache file; the engine then consumes it
    via ``autotune=True`` — the measured ``max_batch`` replaces the Eq.-1
    analytic bucket default, exactly the serve-tier integration the tuned
    cache exists for.
    """
    import tempfile
    from repro.autotune import cache as at_cache
    from repro.autotune import model as at_model
    from repro.autotune import run_search

    path = os.path.join(tempfile.mkdtemp(prefix="serve-load-at-"),
                        "cache.json")
    bests = []
    for n, bw, dtype, uv, _w in mix:
        res = run_search(n, bw, dtype=np.dtype(dtype), backend=backend,
                         compute_uv=uv, top_k=2, fuses=(1, 2),
                         batches=(4, 8, 16), iters=1, seed=seed)
        at_cache.store(res.to_entry(), device_kind=at_model.device_kind(),
                       n=n, bw=bw, dtype=np.dtype(dtype).name,
                       compute_uv=uv, backend=backend, path=path)
        bests.append(res.best)
    return path, bests


def _client_account(reqs, done_at, errors, snap):
    """Client-view accounting, unified for both drivers (DESIGN.md §15).

    Classifies every submitted request from its future's resolution into
    EXACTLY one of ``ok`` / ``failed`` / ``timed_out`` / ``dropped``, so
    the identity ``ok + failed + timed_out + dropped == submitted`` holds
    by construction.  The engine's own counters are a different view of
    the same run (admission rejections resolve the future but never reach
    ``_finish``, so they count ``rejected`` there and ``failed`` here);
    ``consistent`` is the cross-check that the two views describe the
    same requests:

    * client ``ok``        == engine ``completed``
    * client ``timed_out`` == engine ``timed_out``
    * client ``failed``    == engine ``failed`` + ``rejected``
    * client ``dropped``   == submitted - every engine-finished request

    The pre-fix bug this replaces: ``poisson_run`` reported the engine's
    ``failed`` next to a future-view ``dropped``, so an admission-rejected
    request was invisible in both columns and the totals did not add up.
    """
    ok = failed = timed_out = 0
    for r in reqs:
        if r.uid not in done_at:
            continue                              # dropped: never resolved
        exc = errors.get(r.uid)
        if exc is None:
            ok += 1
        elif isinstance(exc, TimeoutError):
            timed_out += 1
        else:
            failed += 1
    submitted = len(reqs)
    dropped = submitted - len([r for r in reqs if r.uid in done_at])
    engine_finished = (snap["completed"] + snap["failed"]
                       + snap["timed_out"] + snap["rejected"])
    return {
        "submitted": submitted, "ok": ok, "failed": failed,
        "timed_out": timed_out, "dropped": dropped,
        "consistent": (ok == snap["completed"]
                       and timed_out == snap["timed_out"]
                       and failed == snap["failed"] + snap["rejected"]
                       and dropped == submitted - engine_finished),
    }


def _serial_serve(reqs, cfgs):
    """The no-serving-tier baseline: one pipeline call per request."""
    import jax.numpy as jnp
    from repro.core import svd as svdmod
    out = []
    for r in reqs:
        cfg = cfgs[r.key()]
        m = jnp.asarray(r.matrix)
        if r.compute_uv:
            u, sig, vt = svdmod.svd(m, config=cfg, compute_uv=True)
            out.append(np.asarray(sig))
        else:
            out.append(np.asarray(svdmod.svd_batched(m[None], config=cfg)[0]))
    return out


def _engine_cfgs(eng, reqs):
    """Resolve (and memoize) every bucket config once, serial-compatible."""
    return {key: eng._cfg_for(key) for key in {r.key() for r in reqs}}


def throughput_compare(mix, count, *, backend="ref", seed=0, window_s=0.002,
                       autotune_cache=None, fused_n_max=None, dc_n_min=None,
                       faults=None, tracer=None):
    """Serial vs micro-batched engine throughput on an identical workload.

    Returns ``(rows, result)`` — CSV rows plus a dict with the speedup and
    the max |sigma - direct values-only sigma| cross-check.  With
    ``autotune_cache`` (see :func:`_tune_bucket_cache`) the engine buckets
    at the MEASURED per-bucket optimum instead of the analytic default;
    the serial baseline resolves through the same configs, so the speedup
    isolates batching, not knob differences.  ``faults`` (a seeded
    ``repro.serve.FaultPlan``, the ``--chaos`` path) is injected into the
    ENGINE only — the serial baseline stays the clean oracle the engine's
    fault-absorbed answers are checked against.
    """
    from benchmarks.common import row
    from repro.core import svd as svdmod
    from repro.serve import AsyncSVDEngine, ServeMetrics
    import jax.numpy as jnp

    reqs_serial = _requests(mix, count, seed)
    reqs_engine = _requests(mix, count, seed)      # same matrices, fresh reqs
    eng = AsyncSVDEngine(backend=backend, batch_window_s=window_s,
                         autotune=autotune_cache is not None,
                         autotune_cache=autotune_cache,
                         max_batch=32 if autotune_cache else None,
                         fused_n_max=fused_n_max, dc_n_min=dc_n_min,
                         faults=faults, tracer=tracer)
    cfgs = _engine_cfgs(eng, reqs_engine)

    # Warm every compiled program OUTSIDE the timed windows (bucket-capacity
    # batch for the engine, B=1 for the serial path) — one request per mix
    # entry so no bucket compiles inside a measurement.
    warm = _mix_cover(mix, seed + 1)
    _serial_serve(warm, _engine_cfgs(eng, warm))
    [f.result() for f in [eng.submit(r) for r in _mix_cover(mix, seed + 2)]]
    eng.metrics = ServeMetrics()         # report the timed burst, not warmup

    t0 = time.monotonic()
    serial_sig = _serial_serve(reqs_serial, cfgs)
    t_serial = time.monotonic() - t0

    t0 = time.monotonic()
    futs = [eng.submit(r) for r in reqs_engine]    # open-loop burst
    done, errors = [], {}
    for r, f in zip(reqs_engine, futs):
        try:
            done.append(f.result())
        except Exception as exc:                   # noqa: BLE001 — report,
            done.append(None)                      # don't abort the harness
            errors[r.uid] = exc
    t_engine = time.monotonic() - t0
    eng.stop()
    eng_failures = [repr(e) for e in errors.values()]

    # Correctness at equal precision: engine sigma vs the direct
    # values-only path on the same matrices.  The 1e-12 acceptance bar
    # applies at fp64; fp32 buckets are served at fp32 (B=1 vs B=16
    # programs may round differently at ~1e-6) and get their own bound.
    err64 = err32 = 0.0
    for r, s_direct in zip(done, serial_sig):
        if r is None:
            continue
        e = float(np.abs(np.asarray(r.sigma) - s_direct).max())
        if np.dtype(r.matrix.dtype) == np.float64:
            err64 = max(err64, e)
        else:
            err32 = max(err32, e)
    for r in done[:4]:
        if r is not None and r.compute_uv:
            cfg_vo = dataclasses.replace(cfgs[r.key()], compute_uv=False)
            s_vo = np.asarray(svdmod.svd_batched(
                jnp.asarray(r.matrix)[None], config=cfg_vo)[0])
            e = float(np.abs(np.asarray(r.sigma) - s_vo).max())
            if np.dtype(r.matrix.dtype) == np.float64:
                err64 = max(err64, e)
            else:
                err32 = max(err32, e)

    snap = eng.metrics.snapshot()
    speedup = t_serial / t_engine
    tag = f"x{count}"
    rows = [
        row(f"serve_load/serial/{tag}", t_serial / count * 1e6,
            f"mats_per_s={count / t_serial:.2f}"),
        row(f"serve_load/engine/{tag}", t_engine / count * 1e6,
            f"mats_per_s={count / t_engine:.2f};speedup={speedup:.2f}x;"
            f"fill={snap['batch_fill_ratio']:.2f};"
            f"batches={snap['batches']}"),
    ]
    # Unified client-view accounting (same classifier as poisson_run): a
    # burst driver resolves every future, so dropped is 0 here — but the
    # identity and the engine cross-check are asserted all the same.
    acct = _client_account(reqs_engine,
                           {r.uid: True for r in reqs_engine}, errors, snap)
    return rows, {"t_serial_s": t_serial, "t_engine_s": t_engine,
                  "speedup": speedup, "sigma_max_err": err64,
                  "sigma_max_err_f32": err32,
                  "engine_failures": eng_failures,
                  "accounting": acct,
                  "engine_metrics": snap}


def poisson_run(mix, count, rate, *, backend="ref", seed=0, window_s=0.005,
                timeout_s=None, autotune_cache=None, fused_n_max=None,
                dc_n_min=None, faults=None, tracer=None, metrics_server=None):
    """Open-loop Poisson arrivals at ``rate`` req/s; per-request latency.

    Returns ``(rows, result)``; ``result`` carries the latency percentiles,
    achieved throughput, the unified client-view accounting
    (:func:`_client_account` — ok/failed/timed_out/dropped summing to
    submitted, cross-checked against the engine counters), and the engine
    metrics snapshot the smoke gate asserts on (every request must
    COMPLETE: served or failed with an error on the request — never
    silently dropped).  ``faults`` injects a ``repro.serve.FaultPlan``
    into the engine's primary path (the ``--chaos`` gate).

    Latency percentiles are HISTOGRAM-driven (DESIGN.md §16): each
    successful completion streams its client-view latency into a
    fixed-log-bucket :class:`repro.obs.StreamingHistogram` inside the
    future callback — the reported p50/p95/p99 come from the histogram,
    not a raw-sample array.  A shadow list of exact samples is kept ONLY
    for the smoke gate's cross-check (``latency_exact_ms``), which asserts
    the histogram percentiles land within one bucket width of numpy's
    exact ones.  ``tracer`` (a :class:`repro.obs.Tracer`) threads into the
    engine for dispatch/retry/degraded spans; ``metrics_server`` (a
    :class:`repro.obs.MetricsServer`) gets the live engine metrics
    registered under ``"svd"`` before traffic starts, so the run is
    scrapeable while in flight.
    """
    from benchmarks.common import row
    from repro.obs import StreamingHistogram
    from repro.serve import AsyncSVDEngine, ServeMetrics

    rng = np.random.default_rng(seed + 7)
    reqs = _requests(mix, count, seed)
    eng = AsyncSVDEngine(backend=backend, batch_window_s=window_s,
                         default_timeout_s=timeout_s,
                         autotune=autotune_cache is not None,
                         autotune_cache=autotune_cache,
                         max_batch=32 if autotune_cache else None,
                         fused_n_max=fused_n_max, dc_n_min=dc_n_min,
                         faults=faults, tracer=tracer)
    # Warm every bucket's compile outside the timed run (never under the
    # engine's default deadline — compiles take seconds).
    [f.result() for f in [eng.submit(r, timeout_s=float("inf"))
                          for r in _mix_cover(mix, seed + 1)]]
    eng.metrics = ServeMetrics()         # report the timed run, not warmup
    if metrics_server is not None:
        metrics_server.register("svd", eng.metrics)

    done_at: dict[int, float] = {}
    errors: dict[int, Exception] = {}
    hist = StreamingHistogram()              # client-view latency, seconds
    exact_s: list[float] = []                # shadow samples (smoke check)
    ev = threading.Event()

    def _cb(req):
        def cb(fut):
            now = time.monotonic()
            done_at[req.uid] = now
            exc = fut.exception()
            if exc is not None:
                errors[req.uid] = exc
            elif req.arrived is not None:
                # Successful only — admission rejections never reach
                # _finish, so their req.error stays None while the future
                # carries the exception; counting them would skew the
                # percentiles low.
                lat = now - req.arrived
                hist.add(lat)
                exact_s.append(lat)
            if len(done_at) == count:
                ev.set()
        return cb

    gaps = rng.exponential(1.0 / rate, count)
    t0 = time.monotonic()
    for r, gap in zip(reqs, gaps):
        time.sleep(gap)                          # open loop: never waits
        eng.submit(r).add_done_callback(_cb(r))
    ev.wait(timeout=600)
    t_total = time.monotonic() - t0
    eng.stop()

    snap = eng.metrics.snapshot()
    lat = hist.summary()                     # histogram-driven percentiles
    # Client-view accounting (the unified classifier shared with
    # throughput_compare): ok + failed + timed_out + dropped == submitted,
    # with the engine-counter cross-check in acct["consistent"].
    acct = _client_account(reqs, done_at, errors, snap)
    result = {
        "requests": count, "rate_rps": rate,
        "completed": acct["ok"], "failed": acct["failed"],
        "timed_out": acct["timed_out"],
        "rejected": int(snap["rejected"]),
        "dropped": acct["dropped"],              # future never resolved
        "accounting": acct,
        "throughput_rps": hist.count / t_total if t_total > 0 else 0.0,
        "latency_ms": {"p50": lat["p50_ms"], "p95": lat["p95_ms"],
                       "p99": lat["p99_ms"], "mean": lat["mean_ms"],
                       "max": lat["max_ms"]},
        "latency_hist": hist.to_dict(),
        "latency_exact_ms": sorted(v * 1e3 for v in exact_s),
        "latency_bucket_ratio": hist.bucket_width_ratio(),
        "engine_metrics": snap,
    }
    # Gated column = per-request service interval from achieved THROUGHPUT
    # (stable across hosts); queueing latency diverges nonlinearly near
    # saturation under open-loop arrivals, so the percentiles ride in
    # ``derived`` where the regression gate never reads them.
    svc_us = (1e6 / result["throughput_rps"] if result["throughput_rps"]
              else 0.0)
    lm = result["latency_ms"]
    rows = [row(f"serve_load/poisson_thpt/x{count}@r{rate:g}", svc_us,
                f"p50={lm['p50']:.1f}ms;p95={lm['p95']:.1f}ms;"
                f"p99={lm['p99']:.1f}ms;"
                f"mean={lm['mean']:.1f}ms;"
                f"thpt={result['throughput_rps']:.1f}rps;"
                f"timed_out={result['timed_out']};"
                f"fill={snap['batch_fill_ratio']:.2f}")]
    return rows, result


def _dc_tier_smoke(*, backend="ref", seed=0):
    """Stage-3 D&C routing check for the smoke gate (DESIGN.md §14).

    The smoke mix is all small-n (fused-tier territory), so the D&C tier
    would never fire there; this runs a tiny dedicated burst with the
    fused tier off and the crossover pinned to 1 (``fused_n_max=0,
    dc_n_min=1``) — every staged bucket MUST route "staged-dc", and the
    served sigma must agree with ``numpy.linalg.svd`` to 1e-12 relative.
    Returns a list of failure strings (empty = pass).
    """
    from repro.serve import SVDEngine, SVDRequest

    rng = np.random.default_rng(seed + 11)
    eng = SVDEngine(backend=backend, fused_n_max=0, dc_n_min=1)
    mats = [rng.standard_normal((n, n)) for n in (24, 24, 48)]
    for i, m in enumerate(mats):
        eng.submit(SVDRequest(uid=i, matrix=m, bw=4))
    done = {r.uid: r for r in eng.run()}
    failures = []
    snap = eng.metrics.snapshot()
    for key, info in snap.get("bucket_tiers", {}).items():
        if info["tier"] != "staged-dc":
            failures.append(f"dc smoke: bucket {key} served on "
                            f"{info['tier']!r}, expected 'staged-dc'")
    if not snap.get("tiers", {}).get("staged-dc", {}).get("batches"):
        failures.append("dc smoke: no staged-dc dispatches recorded")
    for i, m in enumerate(mats):
        r = done.get(i)
        if r is None or r.error is not None:
            failures.append(f"dc smoke: request {i} failed: "
                            f"{r.error if r else 'missing'}")
            continue
        ref = np.linalg.svd(m, compute_uv=False)
        err = float(np.abs(np.asarray(r.sigma) - ref).max() / ref.max())
        if err > 1e-12:
            failures.append(f"dc smoke: sigma disagrees with LAPACK by "
                            f"{err:.2e} rel > 1e-12 (n={m.shape[0]})")
    return failures


def multihost_run(mix, count, rate, *, hosts=2, backend="ref", seed=0,
                  window_ms=25.0, timeout_s=None, kill_host=False,
                  jax_distributed=False, host_devices=0, snap_prefix=""):
    """Open-loop Poisson traffic through :class:`repro.serve.SVDRouter`
    over ``hosts`` real worker PROCESSES (DESIGN.md §17).

    The router lives in this process; each worker is a
    ``python -m repro.serve.worker`` subprocess running its own
    ``AsyncSVDEngine`` (optionally with ``host_devices`` forced host
    devices, optionally joined into one multi-process jax via
    ``jax_distributed`` — never combined with ``kill_host``: a killed
    peer fatally cascades through the XLA coordination service, which is
    exactly why the fabric's multi-processness lives at the socket
    level).

    ``kill_host`` SIGKILLs the worker that owns the dominant mix bucket
    immediately after a request for that bucket is submitted (the engine
    micro-batch window guarantees it is still in flight), exercising the
    full drop path: reader EOF -> host quarantine -> in-flight requeue to
    the survivor -> every future still resolves.  Warmup broadcasts every
    bucket to every host first, so requeued work never pays a compile.

    Returns ``(rows, result)``: the same client-view accounting identity
    as :func:`poisson_run` (ok + failed + timed_out + dropped ==
    submitted, cross-checked against the ROUTER's counters), the fp64
    sigma oracle error vs ``numpy.linalg.svd``, and the fleet view whose
    merged histogram the gate checks against pooled exact samples.  With
    ``snap_prefix`` the per-host engine snapshots and the fleet view are
    written as ``{prefix}.host-{id}.json`` / ``{prefix}.fleet.json`` (the
    CI artifacts).
    """
    from benchmarks.common import row
    from repro.obs import StreamingHistogram
    from repro.serve import SVDRouter
    from repro.serve.worker import spawn_worker_process

    if kill_host and jax_distributed:
        raise ValueError("kill_host + jax_distributed: a SIGKILLed peer "
                         "fatally cascades through the XLA coordination "
                         "service (DESIGN.md §17)")
    rng = np.random.default_rng(seed + 7)
    reqs = _requests(mix, count, seed)
    router = SVDRouter(heartbeat_s=0.25, heartbeat_timeout_s=2.0,
                       default_timeout_s=timeout_s)
    coordinator = ""
    if jax_distributed:
        import socket
        with socket.socket() as s:               # free rendezvous port
            s.bind(("127.0.0.1", 0))
            coordinator = f"127.0.0.1:{s.getsockname()[1]}"
    procs = {
        f"w{i}": spawn_worker_process(
            router.address, f"w{i}", backend=backend, window_ms=window_ms,
            devices=host_devices,
            coordinator=coordinator,
            num_processes=hosts if coordinator else 0,
            process_id=i if coordinator else -1)
        for i in range(hosts)}
    victim = None
    artifacts = []
    try:
        if not router.wait_for_hosts(hosts, timeout=240):
            raise RuntimeError(f"only {len(router.alive_hosts())}/{hosts} "
                               f"worker hosts connected")
        # Broadcast-warm every bucket on EVERY host (requeued requests
        # must never pay a compile), then report only the timed window.
        router.warm(_mix_cover(mix, seed + 1))
        router.reset_stats()

        done_at: dict[int, float] = {}
        errors: dict[int, Exception] = {}
        results: dict[int, object] = {}
        hist = StreamingHistogram()          # client-view shadow histogram
        exact_s: list[float] = []            # pooled exact samples (gate)
        ev = threading.Event()

        def _cb(req):
            def cb(fut):
                now = time.monotonic()
                done_at[req.uid] = now
                exc = fut.exception()
                if exc is not None:
                    errors[req.uid] = exc
                else:
                    results[req.uid] = fut.result()
                    lat = now - req.arrived
                    hist.add(lat)
                    exact_s.append(lat)
                if len(done_at) == count:
                    ev.set()
            return cb

        kill_after = int(count * 0.4) if kill_host else count + 1
        if kill_host:
            n0, bw0, dt0, uv0, _w = mix[0]
            victim = router.owner_of((n0, bw0, dt0, False, uv0))
        gaps = rng.exponential(1.0 / rate, count)
        t0 = time.monotonic()
        killed = False
        for idx, (r, gap) in enumerate(zip(reqs, gaps)):
            time.sleep(gap)                      # open loop: never waits
            router.submit(r).add_done_callback(_cb(r))
            if (not killed and idx + 1 >= kill_after and victim is not None
                    and router.owner_of(r.key()) == victim):
                # SIGKILL right behind a victim-owned submit: the worker's
                # micro-batch window still holds it, so the drop path has
                # guaranteed in-flight work to requeue.
                procs[victim].kill()
                killed = True
        ev.wait(timeout=600)
        t_total = time.monotonic() - t0

        host_stats = router.collect_host_stats()
        fleet = router.fleet()
        snap = fleet["router"]
        acct = _client_account(reqs, done_at, errors, snap)
        err64 = err32 = 0.0                      # sigma oracle, ALL results
        for r in reqs:
            res = results.get(r.uid)
            if res is None:
                continue
            ref = np.linalg.svd(r.matrix.astype(np.float64),
                                compute_uv=False)
            e = float(np.abs(np.asarray(res.sigma, dtype=np.float64)
                             - ref).max() / ref.max())
            if np.dtype(r.matrix.dtype) == np.float64:
                err64 = max(err64, e)
            else:
                err32 = max(err32, e)
        merged = fleet["latency"]["merged_summary"]
        if snap_prefix:
            for hid, payload in sorted(host_stats.items()):
                path = f"{snap_prefix}.host-{hid}.json"
                with open(path, "w") as f:
                    json.dump(payload, f, indent=2, sort_keys=True)
                artifacts.append(path)
            path = f"{snap_prefix}.fleet.json"
            with open(path, "w") as f:
                json.dump(fleet, f, indent=2, sort_keys=True)
            artifacts.append(path)
        result = {
            "hosts": hosts, "requests": count, "rate_rps": rate,
            "kill_host": bool(kill_host), "victim": victim,
            "victim_returncode": (procs[victim].poll()
                                  if victim is not None else None),
            "jax_distributed": bool(jax_distributed),
            "completed": acct["ok"], "failed": acct["failed"],
            "timed_out": acct["timed_out"],
            "rejected": int(snap["rejected"]),
            "dropped": acct["dropped"], "accounting": acct,
            "throughput_rps": hist.count / t_total if t_total > 0 else 0.0,
            "sigma_max_rel_err": err64, "sigma_max_rel_err_f32": err32,
            "latency_ms": {"p50": merged["p50_ms"], "p95": merged["p95_ms"],
                           "p99": merged["p99_ms"],
                           "mean": merged["mean_ms"],
                           "max": merged["max_ms"]},
            "latency_exact_ms": sorted(v * 1e3 for v in exact_s),
            "latency_bucket_ratio": fleet["latency"]["bucket_ratio"],
            "fleet": fleet,
            "host_stats_collected": sorted(host_stats),
            "artifacts": artifacts,
        }
    finally:
        router.stop()
        for p in procs.values():
            try:
                p.wait(timeout=30)
            except Exception:                    # noqa: BLE001 — cleanup
                p.kill()
    lm = result["latency_ms"]
    tag = (f"x{count}@h{hosts}"
           + ("+kill" if kill_host else "")
           + ("+dist" if jax_distributed else ""))
    svc_us = (1e6 / result["throughput_rps"] if result["throughput_rps"]
              else 0.0)
    rows = [row(f"serve_load/multihost/{tag}", svc_us,
                f"p50={lm['p50']:.1f}ms;p95={lm['p95']:.1f}ms;"
                f"p99={lm['p99']:.1f}ms;"
                f"thpt={result['throughput_rps']:.1f}rps;"
                f"retried={snap['retried']};"
                f"alive={len(fleet['alive_hosts'])}/{hosts}")]
    return rows, result


def main_multihost(args) -> None:
    """The ``--hosts N`` driver + blocking gate (the CI multihost step)."""
    mix = SMOKE_MIX if args.smoke else FULL_MIX
    count = args.requests or (24 if args.smoke else 96)
    rate = args.rate or (120.0 if args.smoke else 60.0)
    p99_budget = args.p99_ms or (8000.0 if args.smoke else 0.0)
    prefix = ""
    if args.json:
        prefix = (args.json[:-5] if args.json.endswith(".json")
                  else args.json)

    print("name,us_per_call,derived")
    rows, res = multihost_run(
        mix, count, rate, hosts=args.hosts, backend="ref", seed=args.seed,
        kill_host=args.kill_host, jax_distributed=args.jax_distributed,
        host_devices=args.host_devices, snap_prefix=prefix)
    for line in rows:
        print(line, flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# json written to {args.json}", flush=True)
        for path in res["artifacts"]:
            print(f"# artifact written to {path}", flush=True)

    failures = []
    fleet = res["fleet"]
    snap = fleet["router"]
    # Zero client-visible failures — the headline gate: every submitted
    # request resolved ok, even with a host SIGKILLed mid-run.
    for what in ("dropped", "timed_out", "rejected", "failed"):
        if res[what]:
            failures.append(f"{res[what]} request(s) {what} (must be 0)")
    if not res["accounting"]["consistent"]:
        failures.append(f"accounting inconsistent: client view "
                        f"{res['accounting']} vs router counters {snap}")
    if res["sigma_max_rel_err"] > 1e-12:
        failures.append(f"fp64 sigma mismatch vs numpy.linalg.svd: "
                        f"{res['sigma_max_rel_err']:.2e} rel > 1e-12")
    if res["sigma_max_rel_err_f32"] > 1e-4:
        failures.append(f"fp32 sigma mismatch vs numpy.linalg.svd: "
                        f"{res['sigma_max_rel_err_f32']:.2e} rel > 1e-4")
    # Merged-histogram fidelity (DESIGN.md §16/§17): the fleet percentiles
    # come from per-host histograms folded with StreamingHistogram.merge;
    # each must land within one log-bucket width of the POOLED exact
    # samples (numpy method="higher", the histogram's rank convention).
    exact = np.asarray(res["latency_exact_ms"])
    if exact.size:
        ratio = res["latency_bucket_ratio"]
        for q in (50, 95, 99):
            e = float(np.percentile(exact, q, method="higher"))
            h = res["latency_ms"][f"p{q}"]
            if not (e / ratio <= h <= e * ratio):
                failures.append(
                    f"merged histogram p{q}={h:.3f}ms off pooled exact "
                    f"{e:.3f}ms by more than one bucket width "
                    f"(r={ratio:.3f})")
    else:
        failures.append("no exact latency samples for the merged-histogram "
                        "fidelity check")
    if args.kill_host:
        # The drop path must have actually fired: the victim died, was
        # quarantined at host granularity, and its in-flight requests were
        # requeued (retried) onto a survivor.
        if res["victim"] is None:
            failures.append("kill gate: no victim host resolved")
        elif res["victim_returncode"] is None:
            failures.append(f"kill gate: victim {res['victim']} still "
                            f"running")
        elif res["victim"] not in fleet["dead_hosts"]:
            failures.append(f"kill gate: victim {res['victim']} not in "
                            f"dead_hosts {fleet['dead_hosts']}")
        if not snap["retried"]:
            failures.append("kill gate: no requests requeued (retried=0 — "
                            "the kill landed with nothing in flight)")
        if not snap["quarantined"]:
            failures.append("kill gate: no host quarantine recorded")
        requeued = sum(h.get("requeued", 0)
                       for hid, h in snap.get("hosts", {}).items()
                       if hid != res["victim"])
        if not requeued:
            failures.append("kill gate: no survivor host attributed with "
                            "requeued work")
    if args.jax_distributed:
        # The bootstrap gate: every worker joined one multi-process jax —
        # hello-reported process counts and the global/local device split
        # must be coherent (this is the serve_mesh local-devices premise).
        infos = {h: v for h, v in fleet["hosts"].items()}
        local_total = sum(v.get("devices", 0) for v in infos.values())
        for hid, v in sorted(infos.items()):
            if v.get("processes") != args.hosts:
                failures.append(f"distributed gate: host {hid} reports "
                                f"processes={v.get('processes')} != "
                                f"{args.hosts}")
            if v.get("global_devices") != local_total:
                failures.append(f"distributed gate: host {hid} reports "
                                f"global_devices={v.get('global_devices')} "
                                f"!= sum of local devices {local_total}")
        seen_idx = sorted(v.get("process_index", -1) for v in infos.values())
        if seen_idx != list(range(args.hosts)):
            failures.append(f"distributed gate: process indices {seen_idx} "
                            f"!= 0..{args.hosts - 1}")
    if p99_budget and res["latency_ms"]["p99"] > p99_budget:
        failures.append(f"p99 latency {res['latency_ms']['p99']:.1f}ms "
                        f"> budget {p99_budget:g}ms")
    print(f"# hosts={len(fleet['alive_hosts'])}/{args.hosts} alive "
          f"victim={res['victim']} retried={snap['retried']} "
          f"sigma_err={res['sigma_max_rel_err']:.2e} "
          f"p99={res['latency_ms']['p99']:.1f}ms "
          f"dropped={res['dropped']} timed_out={res['timed_out']}",
          flush=True)
    if failures:
        for f in failures:
            print(f"# SERVE GATE FAIL: {f}", flush=True)
        sys.exit(1)
    print("# serve gate OK", flush=True)


def run(smoke: bool = False):
    """benchmarks.run suite entry: CSV rows (CI gates only us_per_call)."""
    mix = SMOKE_MIX if smoke else FULL_MIX
    count = 24 if smoke else 96
    rate = 120.0 if smoke else 60.0
    cache = None if smoke else _tune_bucket_cache(mix)[0]
    rows, _ = throughput_compare(mix, count, backend="ref",
                                 autotune_cache=cache)
    prows, _ = poisson_run(mix, count if smoke else 48, rate, backend="ref",
                           autotune_cache=cache)
    return rows + prows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, seconds-scale (the CI serve gate)")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the full latency/throughput report to PATH")
    ap.add_argument("--requests", type=int, default=0,
                    help="override the workload size")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="override the Poisson arrival rate (req/s)")
    ap.add_argument("--p99-ms", type=float, default=0.0, metavar="MS",
                    help="p99 latency budget (default: 4000 smoke / none "
                         "full)")
    ap.add_argument("--check", action="store_true",
                    help="assert the >=3x engine-over-serial acceptance bar "
                         "(implied in --smoke the bar stays off: smoke "
                         "shapes are too small to be meaningful)")
    ap.add_argument("--chaos", action="store_true",
                    help="inject a seeded FaultPlan (scripted + 5%% dispatch "
                         "errors, 1%% NaN sigma) into the engines and assert "
                         "the fabric absorbed every fault (DESIGN.md §15)")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve Prometheus-format engine metrics on "
                         "127.0.0.1:PORT during the run (0 = ephemeral "
                         "port); the gate scrapes /metrics afterwards and "
                         "asserts the exposition is well-formed "
                         "(DESIGN.md §16)")
    ap.add_argument("--trace-jsonl", default="", metavar="PATH",
                    help="export engine dispatch/retry/degraded spans to "
                         "PATH as JSONL (repro.obs.Tracer; DESIGN.md §16)")
    ap.add_argument("--hosts", type=int, default=0, metavar="N",
                    help="multi-host mode (DESIGN.md §17): route the Poisson "
                         "run through repro.serve.SVDRouter over N worker "
                         "PROCESSES; gates zero client-visible failures, "
                         "the fp64 sigma oracle, and merged-histogram "
                         "fidelity across hosts")
    ap.add_argument("--kill-host", action="store_true",
                    help="[--hosts] SIGKILL the worker owning the dominant "
                         "bucket mid-run and assert the router requeued its "
                         "in-flight work with zero client-visible failures")
    ap.add_argument("--jax-distributed", action="store_true",
                    help="[--hosts] bootstrap the workers into one "
                         "multi-process jax (jax.distributed.initialize) "
                         "and assert the hello-reported process/device "
                         "topology; incompatible with --kill-host")
    ap.add_argument("--host-devices", type=int, default=0, metavar="D",
                    help="[--hosts] XLA_FLAGS-forced host device count per "
                         "worker (0: leave the workers' env alone)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.hosts >= 2:
        return main_multihost(args)

    import jax
    jax.config.update("jax_enable_x64", True)
    from repro.autotune.model import device_kind

    mix = SMOKE_MIX if args.smoke else FULL_MIX
    count = args.requests or (24 if args.smoke else 96)
    rate = args.rate or (120.0 if args.smoke else 60.0)
    p99_budget = args.p99_ms or (4000.0 if args.smoke else 0.0)

    print("name,us_per_call,derived")
    cache = None
    if not args.smoke:
        cache, bests = _tune_bucket_cache(mix, seed=args.seed)
        for (n, bw, dt, uv, _w), best in zip(mix, bests):
            print(f"# tuned bucket n={n} bw={bw} {dt} uv={int(uv)}: "
                  f"tw={best.tw} fuse={best.fuse} max_batch={best.batch}",
                  flush=True)
    faults_thr = faults_poi = None
    if args.chaos:
        # One plan per engine (each is stateful); scripted ordinals land
        # past the warmup dispatches (one per mix bucket) so at least one
        # dispatch error and one NaN corruption are GUARANTEED to hit the
        # measured run, on top of the probabilistic rates.
        from repro.serve import FaultPlan
        nwarm = len(mix)
        faults_thr = FaultPlan(seed=args.seed + 101,
                               dispatch_error_rate=0.05, nan_rate=0.01,
                               dispatch_errors_at=(nwarm,),
                               nan_at=(nwarm + 1,))
        faults_poi = FaultPlan(seed=args.seed + 202,
                               dispatch_error_rate=0.05, nan_rate=0.01,
                               dispatch_errors_at=(nwarm,),
                               nan_at=(nwarm + 1,))
    tracer = None
    if args.trace_jsonl:
        from repro.obs import Tracer
        tracer = Tracer("serve_load", jsonl=args.trace_jsonl)
    mserver = None
    if args.metrics_port is not None:
        from repro.obs import MetricsServer
        mserver = MetricsServer(port=args.metrics_port)
        print(f"# metrics endpoint: {mserver.url}", flush=True)
    t_rows, thr = throughput_compare(mix, count, backend="ref",
                                     seed=args.seed, autotune_cache=cache,
                                     faults=faults_thr, tracer=tracer)
    p_rows, poi = poisson_run(mix, max(count // 2, 12), rate, backend="ref",
                              seed=args.seed, autotune_cache=cache,
                              faults=faults_poi, tracer=tracer,
                              metrics_server=mserver)
    for line in t_rows + p_rows:
        print(line, flush=True)

    report = {
        "smoke": bool(args.smoke),
        "device_kind": device_kind(),
        "device_count": jax.device_count(),
        "jax": jax.__version__,
        "throughput": thr,
        "poisson": poi,
    }
    if args.chaos:
        report["chaos"] = {"throughput_faults": faults_thr.snapshot(),
                           "poisson_faults": faults_poi.snapshot()}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# json written to {args.json}", flush=True)

    failures = []
    for exc in thr["engine_failures"]:
        failures.append(f"engine request failed: {exc}")
    if thr["sigma_max_err"] > 1e-12:
        failures.append(f"fp64 sigma mismatch vs values-only path: "
                        f"{thr['sigma_max_err']:.2e} > 1e-12")
    if thr["sigma_max_err_f32"] > 1e-4:
        failures.append(f"fp32 sigma mismatch vs values-only path: "
                        f"{thr['sigma_max_err_f32']:.2e} > 1e-4")
    for what in ("dropped", "timed_out", "rejected", "failed"):
        if poi[what]:
            failures.append(f"{poi[what]} request(s) {what} "
                            f"(must be 0)")
    for name, res in (("throughput", thr), ("poisson", poi)):
        if not res["accounting"]["consistent"]:
            failures.append(f"{name} accounting inconsistent: client view "
                            f"{res['accounting']} vs engine counters "
                            f"{res['engine_metrics']}")
    if args.chaos:
        # The chaos gate (DESIGN.md §15): the plans must have actually
        # fired (an inert chaos run gates nothing), and everything above —
        # zero client-visible failures, the sigma oracle bar, the p99
        # budget — must STILL hold; the fault-tolerance counters show the
        # absorption happened on the fabric's retry/degraded paths.
        for name, plan in (("throughput", faults_thr), ("poisson", faults_poi)):
            snap_f = plan.snapshot()
            fired = (snap_f["dispatch_error"] + snap_f["device_loss"]
                     + snap_f["nan"] + snap_f["inf"])
            if not fired:
                failures.append(f"chaos: no faults injected into the "
                                f"{name} run ({snap_f})")
        absorbed = sum(res["engine_metrics"][k]
                       for res in (thr, poi)
                       for k in ("retried", "degraded"))
        if not absorbed:
            print("# chaos note: all injected faults landed outside the "
                  "measured window (absorbed during warmup)", flush=True)
    if args.smoke:
        # Fused-tier routing (DESIGN.md §13): every smoke-mix bucket is
        # small-n (n <= DEFAULT_FUSED_CROSSOVER), so the metrics MUST show
        # it served on the fused one-dispatch tier — this is the CI
        # assertion that the serve path actually exercises the tier, not
        # just that the backend exists.
        from repro.core.tuning import DEFAULT_FUSED_CROSSOVER
        snap = poi["engine_metrics"]
        for key, info in snap.get("bucket_tiers", {}).items():
            if info["n"] <= DEFAULT_FUSED_CROSSOVER and info["tier"] != "fused":
                failures.append(f"bucket {key} (n={info['n']}) served on "
                                f"{info['tier']!r}, expected 'fused'")
        if not snap.get("tiers", {}).get("fused", {}).get("batches"):
            failures.append("no fused-tier dispatches recorded in the smoke "
                            "run (tiers metrics empty)")
        # Stage-3 D&C routing (DESIGN.md §14): a dedicated tiny burst with
        # the crossover pinned low, asserting the staged-dc tier fires AND
        # its sigma agrees with LAPACK to 1e-12 — the CI assertion that the
        # serve path actually exercises the D&C solver.
        failures.extend(_dc_tier_smoke(seed=args.seed))
        # Histogram fidelity (DESIGN.md §16): the reported percentiles come
        # from the fixed-log-bucket histogram; assert each lands within one
        # bucket width (a factor of r) of the exact sample percentile.  The
        # histogram's rank convention matches numpy's method="higher", so
        # the only divergence is the bucket-midpoint quantization.
        exact = np.asarray(poi.get("latency_exact_ms", []))
        if exact.size:
            ratio = poi["latency_bucket_ratio"]
            for q in (50, 95, 99):
                e = float(np.percentile(exact, q, method="higher"))
                h = poi["latency_ms"][f"p{q}"]
                if not (e / ratio <= h <= e * ratio):
                    failures.append(
                        f"histogram p{q}={h:.3f}ms off exact {e:.3f}ms by "
                        f"more than one bucket width (r={ratio:.3f})")
        else:
            failures.append("no exact latency samples for the histogram "
                            "fidelity check")
    if mserver is not None:
        # Scrape gate (DESIGN.md §16): the endpoint must answer, carry the
        # serve series the run just produced, and every sample line must
        # parse as ``name{labels} value`` — the exposition is hand-emitted,
        # so CI asserts its shape, not just its existence.
        import urllib.request
        text = ""
        try:
            with urllib.request.urlopen(mserver.url, timeout=10) as resp:
                text = resp.read().decode("utf-8")
        except Exception as exc:                 # noqa: BLE001 — gate
            failures.append(f"metrics scrape failed: {exc!r}")
        for needed in ("repro_serve_requests_total",
                       "repro_serve_latency_seconds_bucket",
                       "repro_serve_queue_age_seconds_count",
                       "repro_serve_health_status"):
            if text and needed not in text:
                failures.append(f"metrics exposition missing {needed}")
        for line in text.splitlines():
            if not line or line.startswith("#"):
                continue
            name_part, _, value_part = line.rpartition(" ")
            try:
                float(value_part)
                ok_line = bool(name_part)
            except ValueError:
                ok_line = False
            if not ok_line:
                failures.append(f"malformed exposition line: {line!r}")
                break
        mserver.stop()
    if tracer is not None:
        print(f"# trace jsonl written to {args.trace_jsonl}", flush=True)
    if p99_budget and poi["latency_ms"]["p99"] > p99_budget:
        failures.append(f"p99 latency {poi['latency_ms']['p99']:.1f}ms "
                        f"> budget {p99_budget:g}ms")
    if args.check and not args.smoke and thr["speedup"] < 3.0:
        failures.append(f"engine speedup {thr['speedup']:.2f}x < 3x "
                        f"acceptance bar")
    chaos_tail = ""
    if args.chaos:
        tm, pm = thr["engine_metrics"], poi["engine_metrics"]
        chaos_tail = (f" chaos_retried={tm['retried'] + pm['retried']}"
                      f" chaos_degraded={tm['degraded'] + pm['degraded']}")
    print(f"# speedup={thr['speedup']:.2f}x "
          f"sigma_err={thr['sigma_max_err']:.2e} "
          f"p99={poi['latency_ms']['p99']:.1f}ms "
          f"timed_out={poi['timed_out']} dropped={poi['dropped']}"
          f"{chaos_tail}",
          flush=True)
    if failures:
        for f in failures:
            print(f"# SERVE GATE FAIL: {f}", flush=True)
        sys.exit(1)
    print("# serve gate OK", flush=True)


if __name__ == "__main__":
    main()
