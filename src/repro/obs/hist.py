"""Mergeable fixed-log-bucket streaming histograms (DESIGN.md §16).

The serve tier and the load harness used to keep EVERY per-request latency
sample in a Python list and hand it to ``np.percentile`` at the end — O(N)
memory for an open-loop workload whose whole point is sustained traffic.
:class:`StreamingHistogram` replaces that with a fixed array of
logarithmically spaced buckets: O(1) memory per stream, O(buckets) per
percentile query, and exact ``count/sum/min/max`` tracked on the side so
the summary stays honest at the distribution edges.

Bucket scheme: ``buckets_per_decade`` buckets per power of ten between
``lo`` and ``hi`` — bucket ``i`` covers ``(lo*r^i, lo*r^(i+1)]`` with
``r = 10^(1/buckets_per_decade)``.  The default (1 µs .. 10 000 s at 10
buckets/decade = 100 buckets) makes every bucket ~26% wide in relative
terms, so any reported percentile is within ONE bucket width (a factor of
``r``) of the exact sample percentile — the invariant the serve smoke gate
asserts.  Values outside ``[lo, hi]`` clamp into the edge buckets; the
exact min/max keeps the summary truthful anyway.

Histograms with identical bucket schemes merge by adding count arrays —
cross-thread and cross-engine aggregation is one vector add, which is why
the load harness keeps one histogram per worker thread and merges at the
end instead of sharing a lock on the hot path.
"""

from __future__ import annotations

import math
import threading

import numpy as np

__all__ = ["StreamingHistogram"]


class StreamingHistogram:
    """Bounded-memory log-bucket histogram with mergeable counts.

    Thread-safe: ``add``/``merge``/queries take an internal lock.  For
    hot loops prefer one histogram per thread plus a final ``merge`` —
    the lock exists so shared instances (e.g. on :class:`ServeMetrics`)
    are safe, not to make contention free.
    """

    def __init__(self, lo: float = 1e-6, hi: float = 1e4,
                 buckets_per_decade: int = 10) -> None:
        if not (0.0 < lo < hi):
            raise ValueError(f"need 0 < lo < hi, got lo={lo} hi={hi}")
        if buckets_per_decade < 1:
            raise ValueError("buckets_per_decade must be >= 1")
        self.lo = float(lo)
        self.hi = float(hi)
        self.buckets_per_decade = int(buckets_per_decade)
        decades = math.log10(self.hi / self.lo)
        self._nbuckets = max(1, int(math.ceil(
            decades * self.buckets_per_decade - 1e-9)))
        # Upper edge of bucket i is lo * r^(i+1); the last edge is >= hi.
        self._log_lo = math.log10(self.lo)
        self._inv_log_r = float(self.buckets_per_decade)  # 1/log10(r)
        self._counts = np.zeros(self._nbuckets, dtype=np.int64)
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    # bucket geometry

    @property
    def num_buckets(self) -> int:
        return self._nbuckets

    def bucket_width_ratio(self) -> float:
        """Multiplicative width ``r`` of one bucket: ``10^(1/bpd)``."""
        return 10.0 ** (1.0 / self.buckets_per_decade)

    def upper_edges(self) -> np.ndarray:
        """Upper bucket edges (ascending), length ``num_buckets``."""
        i = np.arange(1, self._nbuckets + 1, dtype=np.float64)
        return 10.0 ** (self._log_lo + i / self.buckets_per_decade)

    def _index(self, value: float) -> int:
        if value <= self.lo:
            return 0
        idx = int(math.ceil(
            (math.log10(value) - self._log_lo) * self._inv_log_r - 1e-12)) - 1
        return min(max(idx, 0), self._nbuckets - 1)

    # ------------------------------------------------------------------
    # ingestion

    def add(self, value: float) -> None:
        value = float(value)
        idx = self._index(value) if value > 0.0 else 0
        with self._lock:
            self._counts[idx] += 1
            self._count += 1
            self._sum += value
            if value < self._min:
                self._min = value
            if value > self._max:
                self._max = value

    def extend(self, values) -> None:
        """Vectorized ``add`` for a batch of samples."""
        arr = np.asarray(list(values) if not hasattr(values, "__len__")
                         else values, dtype=np.float64)
        if arr.size == 0:
            return
        clipped = np.clip(arr, self.lo * (1.0 + 1e-15), None)
        idx = np.ceil((np.log10(clipped) - self._log_lo)
                      * self._inv_log_r - 1e-12).astype(np.int64) - 1
        idx = np.clip(idx, 0, self._nbuckets - 1)
        binned = np.bincount(idx, minlength=self._nbuckets)
        with self._lock:
            self._counts += binned
            self._count += int(arr.size)
            self._sum += float(arr.sum())
            self._min = min(self._min, float(arr.min()))
            self._max = max(self._max, float(arr.max()))

    def merge(self, other: "StreamingHistogram") -> "StreamingHistogram":
        """Fold ``other`` into ``self`` (schemes must match). Returns self."""
        if (other.lo, other.hi, other.buckets_per_decade) != (
                self.lo, self.hi, self.buckets_per_decade):
            raise ValueError(
                "cannot merge histograms with different bucket schemes: "
                f"({self.lo},{self.hi},{self.buckets_per_decade}) vs "
                f"({other.lo},{other.hi},{other.buckets_per_decade})")
        with other._lock:
            counts = other._counts.copy()
            count, total = other._count, other._sum
            omin, omax = other._min, other._max
        with self._lock:
            self._counts += counts
            self._count += count
            self._sum += total
            self._min = min(self._min, omin)
            self._max = max(self._max, omax)
        return self

    @classmethod
    def merged(cls, items) -> "StreamingHistogram":
        """Fold an iterable of histograms and/or :meth:`to_dict` payloads
        into one fresh histogram (the cross-host aggregation primitive,
        DESIGN.md §17: workers ship dicts over the wire, the router holds
        live objects — both merge here).  An empty iterable yields an
        empty default-scheme histogram; mixed schemes raise, as in
        :meth:`merge`."""
        out = None
        for item in items:
            h = cls.from_dict(item) if isinstance(item, dict) else item
            if out is None:
                out = cls(lo=h.lo, hi=h.hi,
                          buckets_per_decade=h.buckets_per_decade)
            out.merge(h)
        return out if out is not None else cls()

    # ------------------------------------------------------------------
    # queries

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def min(self) -> float:
        return self._min if self._count else math.nan

    @property
    def max(self) -> float:
        return self._max if self._count else math.nan

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else math.nan

    def percentile(self, q: float) -> float:
        """Approximate q-th percentile (q in [0, 100]).

        Returns the geometric midpoint of the bucket holding the q-th
        sample, clamped to the exact observed [min, max] so edge
        percentiles never over/under-shoot the data.
        """
        with self._lock:
            count = self._count
            if count == 0:
                return math.nan
            counts = self._counts.copy()
            lo_exact, hi_exact = self._min, self._max
        rank = q / 100.0 * (count - 1) + 1.0  # 1-based rank, linear-ish
        cum = np.cumsum(counts)
        idx = int(np.searchsorted(cum, math.ceil(rank - 1e-9)))
        idx = min(idx, self._nbuckets - 1)
        # geometric midpoint of bucket idx: lo * r^(idx+0.5)
        mid = 10.0 ** (self._log_lo + (idx + 0.5) / self.buckets_per_decade)
        return float(min(max(mid, lo_exact), hi_exact))

    def counts(self) -> np.ndarray:
        """Copy of the per-bucket counts (length ``num_buckets``)."""
        with self._lock:
            return self._counts.copy()

    def cumulative(self) -> np.ndarray:
        """Cumulative counts per upper edge — Prometheus ``le`` series."""
        return np.cumsum(self.counts())

    def summary(self, unit_scale: float = 1e3) -> dict:
        """JSON-safe summary.  ``unit_scale=1e3`` reports seconds as ms."""
        if self._count == 0:
            return {"count": 0}
        return {
            "count": int(self._count),
            "mean_ms": float(self.mean * unit_scale),
            "min_ms": float(self.min * unit_scale),
            "max_ms": float(self.max * unit_scale),
            "p50_ms": float(self.percentile(50) * unit_scale),
            "p95_ms": float(self.percentile(95) * unit_scale),
            "p99_ms": float(self.percentile(99) * unit_scale),
        }

    # ------------------------------------------------------------------
    # serialization (JSONL traces, cross-process aggregation)

    def to_dict(self) -> dict:
        with self._lock:
            return {
                "lo": self.lo,
                "hi": self.hi,
                "buckets_per_decade": self.buckets_per_decade,
                "count": int(self._count),
                "sum": float(self._sum),
                "min": float(self._min) if self._count else None,
                "max": float(self._max) if self._count else None,
                "counts": self._counts.tolist(),
            }

    @classmethod
    def from_dict(cls, d: dict) -> "StreamingHistogram":
        h = cls(lo=d["lo"], hi=d["hi"],
                buckets_per_decade=d["buckets_per_decade"])
        counts = np.asarray(d["counts"], dtype=np.int64)
        if counts.shape != h._counts.shape:
            raise ValueError("counts length does not match bucket scheme")
        h._counts = counts
        h._count = int(d["count"])
        h._sum = float(d["sum"])
        h._min = float(d["min"]) if d.get("min") is not None else math.inf
        h._max = float(d["max"]) if d.get("max") is not None else -math.inf
        return h

    def __repr__(self) -> str:
        return (f"StreamingHistogram(count={self._count}, "
                f"buckets={self._nbuckets}, "
                f"p50={self.percentile(50):.3g})" if self._count else
                f"StreamingHistogram(count=0, buckets={self._nbuckets})")
