"""Prometheus-style text exposition over stdlib ``http.server``
(DESIGN.md §16).

No external client library: the text format (v0.0.4) is line-oriented
and trivial to emit — ``# HELP`` / ``# TYPE`` comments, then
``name{label="value"} number`` samples.  :func:`render_serve_metrics`
turns one :class:`~repro.serve.metrics.ServeMetrics` into exposition
text (counters, gauges, per-tier dispatch slices, and the per-tier /
per-bucket latency histograms as cumulative ``_bucket{le=...}`` series);
:class:`MetricsServer` serves any number of registered metrics objects
at ``GET /metrics`` from a daemon thread — opt-in via
``launch/serve.py --svd --metrics-port`` or
``benchmarks.serve_load --metrics-port``.
"""

from __future__ import annotations

import math
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

__all__ = ["render_serve_metrics", "render_fleet_metrics", "MetricsServer",
           "escape_label"]

_PREFIX = "repro_serve"
_FLEET = "repro_fleet"


def escape_label(v) -> str:
    """Escape a label value per the exposition format."""
    return (str(v).replace("\\", r"\\").replace('"', r"\"")
            .replace("\n", r"\n"))


def _fmt(value: float) -> str:
    if isinstance(value, float):
        if math.isnan(value):
            return "NaN"
        if math.isinf(value):
            return "+Inf" if value > 0 else "-Inf"
    return repr(float(value)) if isinstance(value, float) else str(value)


def _sample(name: str, labels: dict, value) -> str:
    if labels:
        body = ",".join(f'{k}="{escape_label(v)}"'
                        for k, v in sorted(labels.items()))
        return f"{name}{{{body}}} {_fmt(value)}"
    return f"{name} {_fmt(value)}"


def _render_hist(lines: list[str], name: str, labels: dict, hist) -> None:
    """Emit one histogram: cumulative buckets + sum + count."""
    cum = hist.cumulative()
    edges = hist.upper_edges()
    prev = -1
    for edge, c in zip(edges, cum):
        if int(c) == prev:
            continue                     # sparse: skip repeated cumulatives
        prev = int(c)
        lines.append(_sample(f"{name}_bucket",
                             {**labels, "le": f"{edge:.6g}"}, int(c)))
    lines.append(_sample(f"{name}_bucket", {**labels, "le": "+Inf"},
                         int(hist.count)))
    lines.append(_sample(f"{name}_sum", labels, float(hist.sum)))
    lines.append(_sample(f"{name}_count", labels, int(hist.count)))


def render_serve_metrics(metrics, *, engine: str = "svd") -> str:
    """Exposition text for one ServeMetrics instance."""
    labels = {"engine": engine}
    snap = metrics.snapshot()
    lines: list[str] = []

    counters = [name for name in metrics._COUNTERS]
    lines.append(f"# HELP {_PREFIX}_requests_total "
                 "Monotonic serve counters by event.")
    lines.append(f"# TYPE {_PREFIX}_requests_total counter")
    for name in counters:
        lines.append(_sample(f"{_PREFIX}_requests_total",
                             {**labels, "event": name}, int(snap[name])))

    lines.append(f"# HELP {_PREFIX}_queue_depth "
                 "Requests admitted but not yet dispatched.")
    lines.append(f"# TYPE {_PREFIX}_queue_depth gauge")
    lines.append(_sample(f"{_PREFIX}_queue_depth", labels,
                         int(snap["queue_depth"])))

    lines.append(f"# HELP {_PREFIX}_tier_slots_total "
                 "Per-tier dispatch slot accounting.")
    lines.append(f"# TYPE {_PREFIX}_tier_slots_total counter")
    for tier, row in sorted(snap.get("tiers", {}).items()):
        for field in ("batches", "served_slots", "padded_slots"):
            lines.append(_sample(
                f"{_PREFIX}_tier_slots_total",
                {**labels, "tier": tier, "kind": field}, int(row[field])))

    hists = metrics.histograms()
    lines.append(f"# HELP {_PREFIX}_latency_seconds "
                 "Client-view request latency by execution tier.")
    lines.append(f"# TYPE {_PREFIX}_latency_seconds histogram")
    for tier, h in sorted(hists["tiers"].items()):
        _render_hist(lines, f"{_PREFIX}_latency_seconds",
                     {**labels, "tier": tier}, h)

    lines.append(f"# HELP {_PREFIX}_bucket_latency_seconds "
                 "Client-view request latency by bucket key.")
    lines.append(f"# TYPE {_PREFIX}_bucket_latency_seconds histogram")
    for key, h in sorted(hists["buckets"].items()):
        _render_hist(lines, f"{_PREFIX}_bucket_latency_seconds",
                     {**labels, "bucket": key}, h)

    lines.append(f"# HELP {_PREFIX}_queue_age_seconds "
                 "Age of requests at dispatch time (admission to launch).")
    lines.append(f"# TYPE {_PREFIX}_queue_age_seconds histogram")
    _render_hist(lines, f"{_PREFIX}_queue_age_seconds", labels,
                 hists["queue_age"])

    health = metrics.health()
    status_code = {"ok": 0, "degraded": 1, "failing": 2}.get(
        health["status"], 2)
    lines.append(f"# HELP {_PREFIX}_health_status "
                 "0=ok 1=degraded 2=failing (DESIGN.md §15).")
    lines.append(f"# TYPE {_PREFIX}_health_status gauge")
    lines.append(_sample(f"{_PREFIX}_health_status", labels, status_code))
    return "\n".join(lines) + "\n"


def render_fleet_metrics(fleet: dict) -> str:
    """Exposition text for a fleet view (``SVDRouter.fleet()``,
    DESIGN.md §17): host liveness, per-host request attribution, and the
    per-host + merged client-view latency histograms.  Takes the plain
    dict — not the router — so a snapshot written to disk (the CI
    artifact) renders identically to a live scrape."""
    from repro.obs.hist import StreamingHistogram

    lines: list[str] = []
    hosts = fleet.get("hosts", {})
    lines.append(f"# HELP {_FLEET}_hosts_alive Worker hosts currently alive.")
    lines.append(f"# TYPE {_FLEET}_hosts_alive gauge")
    lines.append(_sample(f"{_FLEET}_hosts_alive", {},
                         len(fleet.get("alive_hosts", []))))
    lines.append(f"# HELP {_FLEET}_host_up Per-host liveness (1=alive).")
    lines.append(f"# TYPE {_FLEET}_host_up gauge")
    for hid, row in sorted(hosts.items()):
        lines.append(_sample(f"{_FLEET}_host_up", {"host": hid},
                             int(bool(row.get("alive")))))
    lines.append(f"# HELP {_FLEET}_host_requests_total "
                 "Per-host dispatch/completion/requeue attribution.")
    lines.append(f"# TYPE {_FLEET}_host_requests_total counter")
    for hid, row in sorted(fleet.get("router", {}).get("hosts", {}).items()):
        for event, v in sorted(row.items()):
            lines.append(_sample(f"{_FLEET}_host_requests_total",
                                 {"host": hid, "event": event}, int(v)))
    lines.append(f"# HELP {_FLEET}_router_requests_total "
                 "Fleet-level client-view serve counters.")
    lines.append(f"# TYPE {_FLEET}_router_requests_total counter")
    router = fleet.get("router", {})
    for event in ("submitted", "completed", "failed", "timed_out",
                  "rejected", "retried", "quarantined", "bucket_hits"):
        if event in router:
            lines.append(_sample(f"{_FLEET}_router_requests_total",
                                 {"event": event}, int(router[event])))
    lat = fleet.get("latency", {})
    lines.append(f"# HELP {_FLEET}_latency_seconds "
                 "Client-view latency by host, plus the cross-host merge.")
    lines.append(f"# TYPE {_FLEET}_latency_seconds histogram")
    for hid, payload in sorted(lat.get("per_host", {}).items()):
        _render_hist(lines, f"{_FLEET}_latency_seconds", {"host": hid},
                     StreamingHistogram.from_dict(payload))
    if lat.get("merged"):
        _render_hist(lines, f"{_FLEET}_latency_seconds",
                     {"host": "_merged"},
                     StreamingHistogram.from_dict(lat["merged"]))
    return "\n".join(lines) + "\n"


class MetricsServer:
    """Tiny /metrics endpoint on stdlib ``ThreadingHTTPServer``.

    ``port=0`` binds an ephemeral port (read back via ``.port`` — used by
    tests and the CI smoke, which scrape in-process).  ``register`` any
    number of (engine_name, ServeMetrics) pairs; every scrape re-renders
    from live metrics.  The server thread is a daemon: it never blocks
    interpreter exit, but call :meth:`stop` for deterministic shutdown.
    """

    def __init__(self, port: int = 0, host: str = "127.0.0.1") -> None:
        self._registry: dict[str, object] = {}
        self._providers: dict[str, object] = {}
        self._reg_lock = threading.Lock()
        registry, providers = self._registry, self._providers
        reg_lock = self._reg_lock

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self) -> None:  # noqa: N802 (stdlib API name)
                if self.path.rstrip("/") not in ("", "/metrics"):
                    self.send_error(404)
                    return
                with reg_lock:
                    items = list(registry.items())
                    provs = list(providers.items())
                body = "".join(render_serve_metrics(m, engine=name)
                               for name, m in items)
                for name, fn in provs:
                    try:
                        body += fn()
                    except Exception as exc:     # noqa: BLE001 — a broken
                        body += (f"# provider {name} failed: "
                                 f"{escape_label(exc)}\n")  # provider must
                if not items and not provs:      # not kill the scrape
                    body = "# no metrics registered\n"
                data = body.encode("utf-8")
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4; charset=utf-8")
                self.send_header("Content-Length", str(len(data)))
                self.end_headers()
                self.wfile.write(data)

            def log_message(self, *a) -> None:   # keep scrapes quiet
                pass

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self.port = self._httpd.server_address[1]
        self.host = host
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="repro-metrics",
            daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def register(self, name: str, metrics) -> None:
        with self._reg_lock:
            self._registry[name] = metrics

    def register_provider(self, name: str, fn) -> None:
        """Register a callable returning ready-made exposition text —
        how the router's fleet view joins a scrape
        (``server.register_provider("fleet", lambda:
        render_fleet_metrics(router.fleet()))``, DESIGN.md §17).  Called
        per scrape; a raising provider degrades to a comment line."""
        with self._reg_lock:
            self._providers[name] = fn

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)
