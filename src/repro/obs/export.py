"""JSONL span export + round-trip loader (DESIGN.md §16).

One JSON object per line, one line per CLOSED span (children close before
their parent, so a consumer streaming the file sees leaves first).  Each
record is flat — ``span_id``/``parent_id`` encode the tree — so the file
can be tailed, grepped, and merged across processes.  :func:`load_jsonl`
rebuilds the span forest for offline analysis and for the round-trip
test in ``tests/test_obs.py``.
"""

from __future__ import annotations

import json
import threading
from typing import Any, Optional

__all__ = ["JsonlExporter", "load_jsonl", "SpanRecord"]


def _jsonable(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    try:
        return float(v)          # numpy scalars, 0-d arrays
    except Exception:
        return repr(v)


class JsonlExporter:
    """Append-mode JSONL writer; thread-safe, flushes per span so traces
    survive a crashed run."""

    def __init__(self, path: str) -> None:
        self.path = path
        self._lock = threading.Lock()
        self._fh = open(path, "a", encoding="utf-8")

    def write_span(self, sp) -> None:
        rec = {
            "span_id": sp.span_id,
            "parent_id": sp.parent_id,
            "name": sp.name,
            "t0": sp.t0,
            "dur_s": sp.dur_s,
            "thread": sp.thread,
            "attrs": {k: _jsonable(v) for k, v in sp.attrs.items()},
        }
        line = json.dumps(rec, separators=(",", ":"))
        with self._lock:
            if self._fh.closed:
                return
            self._fh.write(line + "\n")
            self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if not self._fh.closed:
                self._fh.close()


class SpanRecord:
    """A span rebuilt from JSONL: same tree-shape API as a live Span."""

    __slots__ = ("span_id", "parent_id", "name", "t0", "dur_s", "thread",
                 "attrs", "children")

    def __init__(self, rec: dict) -> None:
        self.span_id = rec["span_id"]
        self.parent_id = rec.get("parent_id")
        self.name = rec["name"]
        self.t0 = rec["t0"]
        self.dur_s = rec["dur_s"]
        self.thread = rec.get("thread")
        self.attrs = dict(rec.get("attrs", {}))
        self.children: list[SpanRecord] = []

    def find(self, name: str) -> list["SpanRecord"]:
        out = [self] if self.name == name else []
        for c in self.children:
            out.extend(c.find(name))
        return out

    def total_child_seconds(self) -> float:
        return sum(c.dur_s for c in self.children)


def load_jsonl(path: str) -> list[SpanRecord]:
    """Rebuild the span forest from a JSONL trace: returns root spans
    with children re-attached (ordered by close time, i.e. file order)."""
    by_id: dict[int, SpanRecord] = {}
    order: list[SpanRecord] = []
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            sp = SpanRecord(json.loads(line))
            by_id[sp.span_id] = sp
            order.append(sp)
    roots: list[SpanRecord] = []
    for sp in order:
        parent: Optional[SpanRecord] = (
            by_id.get(sp.parent_id) if sp.parent_id is not None else None)
        if parent is not None:
            parent.children.append(sp)
        else:
            roots.append(sp)
    return roots
