"""Structured span tracer for the SVD pipeline (DESIGN.md §16).

A :class:`Span` is a named, attributed interval on the host monotonic
clock (``time.perf_counter``).  Spans nest per-thread (a thread-local
stack), carry arbitrary key/value attributes (``n``, ``bw``, ``dtype``,
``fuse``, ``backend``, ``tier``, ...), and — critically for an async
device runtime — **fence** at close: any JAX arrays registered on the
span are ``block_until_ready``'d before the closing timestamp is taken,
so device work launched inside the span is actually attributed to it
instead of leaking into whichever span happens to call ``np.asarray``
first.

Two integration rules keep the tracer zero-cost and jit-safe:

* **No ambient tracer → no-op.**  Instrumented code calls
  :func:`repro.obs.span`, which returns a singleton null context when no
  tracer is active.  Production paths pay one dict lookup.
* **Inside jit tracing → no-op.**  Host spans make no sense while JAX is
  abstractly tracing a function (the "times" would be trace times of
  symbolic values).  :func:`span` checks ``jax.core.trace_state_clean()``
  and degrades to the null span under tracing; device-side attribution
  inside jitted code uses ``jax.named_scope`` instead (§16).

Compile-vs-run attribution: JAX hides compilation inside the first call
of a jitted function.  :meth:`Tracer.jit_call` splits it — the first
dispatch per (name, static args, input avals) lowers and compiles under
an explicit ``<name>/compile`` child span, then executes the compiled
object under ``<name>/run``.  The compiled executable is memoized on the
tracer because (measured on jax 0.4.37) the AOT ``lower().compile()``
path does NOT populate the regular jit call cache — without the memo a
traced run would compile everything twice.

Each span also opens a ``jax.profiler.TraceAnnotation`` for its
duration, so host spans line up with device profiler traces when a
``jax.profiler.trace`` capture is active (DESIGN.md §16).
"""

from __future__ import annotations

import contextlib
import contextvars
import itertools
import threading
import time
from typing import Any, Callable, Optional

import jax

__all__ = [
    "Span",
    "Tracer",
    "current",
    "activated",
    "install",
    "span",
    "traced_jit_call",
]

_ids = itertools.count(1)


def _host_clean() -> bool:
    """True when we are NOT inside jax tracing (host spans are meaningful)."""
    try:
        return jax.core.trace_state_clean()
    except Exception:
        return True


class Span:
    """One timed interval.  Use via ``tracer.span(...)`` as a context
    manager; closing fences registered device values, records duration,
    tags errors, and attaches the span to its parent (or the tracer's
    root list)."""

    __slots__ = ("name", "attrs", "children", "span_id", "parent_id",
                 "thread", "t0", "dur_s", "_tracer", "_fence",
                 "_annotation")

    def __init__(self, tracer: "Tracer", name: str,
                 attrs: dict[str, Any]) -> None:
        self.name = name
        self.attrs = attrs
        self.children: list[Span] = []
        self.span_id = next(_ids)
        self.parent_id: Optional[int] = None
        self.thread = threading.get_ident()
        self.t0 = 0.0
        self.dur_s = 0.0
        self._tracer = tracer
        self._fence: list[Any] = []
        self._annotation = None

    def set(self, **attrs: Any) -> "Span":
        """Attach/overwrite attributes mid-span."""
        self.attrs.update(attrs)
        return self

    def fence(self, value: Any) -> Any:
        """Register a (pytree of) JAX array(s) to block on at span close,
        so its device work is attributed to THIS span.  Returns value."""
        if value is not None:
            self._fence.append(value)
        return value

    def __enter__(self) -> "Span":
        stack = self._tracer._stack()
        if stack:
            self.parent_id = stack[-1].span_id
        stack.append(self)
        try:
            self._annotation = jax.profiler.TraceAnnotation(self.name)
            self._annotation.__enter__()
        except Exception:
            self._annotation = None
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        try:
            if self._fence and exc_type is None:
                jax.block_until_ready(self._fence)
        except Exception:
            pass
        self.dur_s = time.perf_counter() - self.t0
        if self._annotation is not None:
            try:
                self._annotation.__exit__(exc_type, exc, tb)
            except Exception:
                pass
        if exc_type is not None:
            self.attrs["error"] = repr(exc)
        stack = self._tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        elif self in stack:          # defensive: unwind mis-nested exits
            stack.remove(self)
        parent = stack[-1] if stack else None
        self._tracer._record(self, parent)
        return False                 # never swallow exceptions

    # ------------------------------------------------------------------

    def total_child_seconds(self) -> float:
        return sum(c.dur_s for c in self.children)

    def find(self, name: str) -> list["Span"]:
        """All descendants (and self) whose name matches, pre-order."""
        out = [self] if self.name == name else []
        for c in self.children:
            out.extend(c.find(name))
        return out

    def to_dict(self) -> dict:
        return {
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "name": self.name,
            "t0": self.t0,
            "dur_s": self.dur_s,
            "thread": self.thread,
            "attrs": dict(self.attrs),
            "children": [c.to_dict() for c in self.children],
        }

    def format(self, indent: int = 0, *, min_ms: float = 0.0) -> str:
        """Human-readable tree: name, duration, attrs — one line per span."""
        pad = "  " * indent
        attrs = " ".join(f"{k}={v}" for k, v in self.attrs.items())
        line = f"{pad}{self.name:<24s} {self.dur_s * 1e3:9.3f} ms"
        if attrs:
            line += f"  [{attrs}]"
        lines = [line]
        for c in self.children:
            if c.dur_s * 1e3 >= min_ms:
                lines.append(c.format(indent + 1, min_ms=min_ms))
        return "\n".join(lines)


class _NullSpan:
    """Shared no-op span: returned when no tracer is active or jax is
    tracing.  Every method is a cheap no-op so instrumented code never
    branches on tracer presence."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs):
        return self

    def fence(self, value):
        return value


_NULL_SPAN = _NullSpan()


class Tracer:
    """Collects span trees (thread-safe) and optionally streams each
    closed span as one JSONL line.

    ``tracer.roots`` holds completed top-level spans (one tree per
    traced entry-point call, plus one per spans opened on threads with
    an empty stack — e.g. serve dispatcher threads).
    """

    def __init__(self, name: str = "trace",
                 jsonl: Optional[str] = None) -> None:
        self.name = name
        self.roots: list[Span] = []
        self._local = threading.local()
        self._lock = threading.Lock()
        self._compiled: dict[Any, Any] = {}   # AOT executable memo
        self._jsonl_path = jsonl
        self._jsonl_file = None
        if jsonl is not None:
            from .export import JsonlExporter
            self._jsonl_file = JsonlExporter(jsonl)

    # ------------------------------------------------------------------

    def _stack(self) -> list[Span]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _record(self, sp: Span, parent: Optional[Span]) -> None:
        if parent is not None:
            parent.children.append(sp)
        else:
            with self._lock:
                self.roots.append(sp)
        if self._jsonl_file is not None:
            self._jsonl_file.write_span(sp)

    def span(self, name: str, **attrs: Any):
        """Open a child span of the current thread's innermost span (or a
        new root).  Returns the no-op span while jax is tracing."""
        if not _host_clean():
            return _NULL_SPAN
        return Span(self, name, attrs)

    # ------------------------------------------------------------------
    # compile-vs-run attribution

    @staticmethod
    def _aval_key(x: Any):
        if hasattr(x, "shape") and hasattr(x, "dtype"):
            return ("aval", tuple(x.shape), str(x.dtype))
        if isinstance(x, (tuple, list)):
            return ("seq", tuple(Tracer._aval_key(v) for v in x))
        return ("lit", x)

    def jit_call(self, name: str, fn: Callable, *args: Any,
                 **static_kwargs: Any) -> Any:
        """Call a jitted ``fn(*args, **static_kwargs)`` with compile/run
        split.  First dispatch per (name, statics, arg avals) lowers and
        compiles under a ``<name>/compile`` child span and memoizes the
        executable (jax's AOT cache is separate from the call cache);
        later dispatches run the memoized executable directly.  Falls
        back to a plain call when ``fn`` has no AOT path.
        """
        if not _host_clean():
            return fn(*args, **static_kwargs)
        try:
            key = (name, tuple(sorted(static_kwargs.items(), key=str)),
                   tuple(self._aval_key(a) for a in args))
            hash(key)
        except TypeError:
            return fn(*args, **static_kwargs)
        compiled = self._compiled.get(key)
        if compiled is None:
            lower = getattr(fn, "lower", None)
            if lower is None:
                # Not a jit entry point — run plainly, mark the parent.
                stack = self._stack()
                if stack:
                    stack[-1].set(compile="unsplit")
                return fn(*args, **static_kwargs)
            try:
                with self.span(f"{name}/compile"):
                    compiled = lower(*args, **static_kwargs).compile()
            except Exception:
                return fn(*args, **static_kwargs)
            self._compiled[key] = compiled
            with self.span(f"{name}/run") as sp:
                return sp.fence(compiled(*args))
        return compiled(*args)

    # ------------------------------------------------------------------

    def format(self, *, min_ms: float = 0.0) -> str:
        with self._lock:
            roots = list(self.roots)
        return "\n".join(r.format(min_ms=min_ms) for r in roots)

    def close(self) -> None:
        if self._jsonl_file is not None:
            self._jsonl_file.close()


# ----------------------------------------------------------------------
# ambient ("current") tracer plumbing

_current: contextvars.ContextVar[Optional[Tracer]] = contextvars.ContextVar(
    "repro_obs_tracer", default=None)
_global: Optional[Tracer] = None


def current() -> Optional[Tracer]:
    """The active tracer: context-local first, process-global fallback."""
    tr = _current.get()
    return tr if tr is not None else _global


@contextlib.contextmanager
def activated(tracer: Optional[Tracer]):
    """Make ``tracer`` the ambient tracer within this context (and
    thread).  ``activated(None)`` is a no-op passthrough."""
    if tracer is None:
        yield None
        return
    token = _current.set(tracer)
    try:
        yield tracer
    finally:
        _current.reset(token)


def install(tracer: Optional[Tracer]) -> Optional[Tracer]:
    """Set (or clear, with None) the process-global fallback tracer —
    visible to ALL threads, unlike :func:`activated`.  Returns the
    previous global."""
    global _global
    prev, _global = _global, tracer
    return prev


def span(name: str, **attrs: Any):
    """Module-level convenience: a span on the ambient tracer, or the
    shared no-op span when none is active (or jax is tracing)."""
    tr = current()
    if tr is None:
        return _NULL_SPAN
    return tr.span(name, **attrs)


def traced_jit_call(name: str, fn: Callable, *args: Any,
                    **static_kwargs: Any) -> Any:
    """Module-level convenience: compile/run-split call on the ambient
    tracer, or a plain call when none is active."""
    tr = current()
    if tr is None:
        return fn(*args, **static_kwargs)
    return tr.jit_call(name, fn, *args, **static_kwargs)
