"""repro.obs — pipeline observability (DESIGN.md §16).

Three pieces, importable from this package root:

* :class:`Tracer` / :class:`Span` — thread-safe structured span tracing
  with ``block_until_ready`` fencing and compile-vs-run attribution
  (``trace.py``).  Ambient-tracer helpers: :func:`current`,
  :func:`activated`, :func:`install`, :func:`span`,
  :func:`traced_jit_call`.
* :class:`StreamingHistogram` — mergeable fixed-log-bucket latency
  histograms with bounded memory (``hist.py``).
* :class:`MetricsServer` / :func:`render_serve_metrics` — Prometheus
  text exposition over stdlib http.server (``prom.py``); JSONL span
  export/round-trip in ``export.py``.
"""

from .export import JsonlExporter, SpanRecord, load_jsonl
from .hist import StreamingHistogram
from .prom import MetricsServer, render_fleet_metrics, render_serve_metrics
from .trace import (
    Span,
    Tracer,
    activated,
    current,
    install,
    span,
    traced_jit_call,
)

__all__ = [
    "JsonlExporter",
    "SpanRecord",
    "load_jsonl",
    "StreamingHistogram",
    "MetricsServer",
    "render_fleet_metrics",
    "render_serve_metrics",
    "Span",
    "Tracer",
    "activated",
    "current",
    "install",
    "span",
    "traced_jit_call",
]
