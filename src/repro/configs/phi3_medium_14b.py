"""phi3-medium-14b [dense]: 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352.  RoPE SwiGLU GQA [arXiv:2404.14219]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(name="phi3-medium-14b", kind="dense", n_layers=40, d_model=5120,
                n_heads=40, n_kv=10, d_ff=17920, vocab=100352,
                rope_theta=10000.0),
    smoke=ModelConfig(name="phi3-medium-14b-smoke", kind="dense", n_layers=2,
                      d_model=80, n_heads=4, n_kv=2, d_ff=192, vocab=173,
                      dtype="float32", remat="none"),
)
