"""granite-3-2b [dense]: 40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155.
Tied embeddings + logit scaling [hf:ibm-granite/granite-3.0-2b-base]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(name="granite-3-2b", kind="dense", n_layers=40, d_model=2048,
                n_heads=32, n_kv=8, d_ff=8192, vocab=49155,
                tie_embeddings=True, rope_theta=10000.0),
    smoke=ModelConfig(name="granite-3-2b-smoke", kind="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=199,
                      tie_embeddings=True, dtype="float32", remat="none"),
)
