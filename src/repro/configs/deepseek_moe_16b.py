"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (kv=16) d_ff=1408/expert
vocab=102400, 2 shared + 64 routed top-6, fine-grained [arXiv:2401.06066]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(name="deepseek-moe-16b", kind="moe", n_layers=28, d_model=2048,
                n_heads=16, n_kv=16, d_ff=1408, vocab=102400, n_experts=64,
                n_shared_experts=2, top_k=6, rope_theta=10000.0),
    smoke=ModelConfig(name="deepseek-moe-16b-smoke", kind="moe", n_layers=2,
                      d_model=64, n_heads=4, n_kv=4, d_ff=32, vocab=163,
                      n_experts=8, n_shared_experts=2, top_k=2,
                      dtype="float32", remat="none"),
)
