"""whisper-medium [audio]: enc-dec, 24L(+24 enc) d_model=1024 16H (kv=16)
d_ff=4096 vocab=51865 [arXiv:2212.04356].  Conv/mel frontend is a stub:
input_specs() provides precomputed frame embeddings (1500 frames).
Decoder is the sequence axis for decode shapes; long_500k skipped."""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(name="whisper-medium", kind="encdec", n_layers=24,
                d_model=1024, n_heads=16, n_kv=16, d_ff=4096, vocab=51865,
                n_enc_layers=24, enc_seq=1500, rope_theta=10000.0),
    smoke=ModelConfig(name="whisper-medium-smoke", kind="encdec", n_layers=2,
                      d_model=64, n_heads=4, n_kv=4, d_ff=128, vocab=117,
                      n_enc_layers=2, enc_seq=24, dtype="float32",
                      remat="none"),
)
