"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16 — parallel attention + mamba heads per layer [arXiv:2411.13676].
Sub-quadratic SSM path -> long_500k decode runs for this arch."""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(name="hymba-1.5b", kind="hymba", n_layers=32, d_model=1600,
                n_heads=25, n_kv=5, d_ff=5504, vocab=32001, ssm_state=16,
                ssm_expand=2, subquadratic=True, rope_theta=10000.0),
    smoke=ModelConfig(name="hymba-1.5b-smoke", kind="hymba", n_layers=2,
                      d_model=64, n_heads=4, n_kv=2, d_ff=160, vocab=127,
                      ssm_state=4, ssm_expand=2, subquadratic=True,
                      dtype="float32", remat="none"),
)
