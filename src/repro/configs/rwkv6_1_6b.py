"""rwkv6-1.6b [ssm]: 24L d_model=2048 (attention-free) d_ff=7168 vocab=65536.
Finch: data-dependent decay [arXiv:2404.05892].  O(1)-state decode ->
long_500k runs for this arch."""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(name="rwkv6-1.6b", kind="rwkv", n_layers=24, d_model=2048,
                n_heads=0, n_kv=0, d_ff=7168, vocab=65536, rwkv_head=64,
                subquadratic=True),
    smoke=ModelConfig(name="rwkv6-1.6b-smoke", kind="rwkv", n_layers=2,
                      d_model=64, n_heads=0, n_kv=0, d_ff=160, vocab=131,
                      rwkv_head=16, subquadratic=True, dtype="float32",
                      remat="none"),
)
