"""Assigned input-shape suites (one set shared by all 10 LM archs).

  train_4k     seq 4096   gb 256   -> train_step
  prefill_32k  seq 32768  gb 32    -> prefill_step
  decode_32k   seq 32768  gb 128   -> serve_step (1 new token, seq-len cache)
  long_500k    seq 524288 gb 1     -> serve_step; sub-quadratic archs only

``cells(arch)`` enumerates the applicable (arch x shape) dry-run cells —
full-attention archs skip long_500k (quadratic; DESIGN.md §7); whisper's
decoder is its sequence axis (enc frames fixed at cfg.enc_seq).
"""

from __future__ import annotations

import dataclasses

__all__ = ["ShapeSuite", "SUITES", "cells", "applicable"]


@dataclasses.dataclass(frozen=True)
class ShapeSuite:
    name: str
    seq_len: int
    global_batch: int
    mode: str                  # "train" | "prefill" | "decode"


SUITES: dict[str, ShapeSuite] = {
    "train_4k": ShapeSuite("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSuite("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSuite("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSuite("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg, suite: ShapeSuite) -> bool:
    if suite.name == "long_500k":
        return cfg.subquadratic
    return True


def cells(cfg) -> list[ShapeSuite]:
    return [s for s in SUITES.values() if applicable(cfg, s)]
