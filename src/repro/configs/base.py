"""Architecture config schema + registry.

One file per assigned architecture lives next to this module; each registers a
``ModelConfig`` under its public id (``--arch <id>`` in the launchers) and a
``smoke`` variant (same family, tiny dims) used by the per-arch CPU tests.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp

__all__ = ["ModelConfig", "register", "get_config", "list_configs", "smoke_of"]

BlockKind = Literal["dense", "moe", "hymba", "rwkv", "encdec"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    kind: BlockKind                    # layer family
    n_layers: int
    d_model: int
    n_heads: int                       # query heads (0 for attention-free)
    n_kv: int                          # KV heads (GQA); == n_heads -> MHA
    d_ff: int
    vocab: int
    d_head: int = 0                    # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_zloss: float = 1e-3
    aux_loss_coef: float = 1e-2
    # --- SSM / linear recurrence ---
    ssm_state: int = 0                 # mamba state size N
    ssm_conv: int = 4                  # causal conv width
    ssm_expand: int = 2                # mamba inner expansion
    rwkv_head: int = 64                # rwkv6 head size
    # --- encoder-decoder (whisper) ---
    n_enc_layers: int = 0
    enc_seq: int = 0                   # encoder frames (precomputed stub embeds)
    # --- VLM stub ---
    n_img_tokens: int = 0              # prepended precomputed patch embeddings
    # --- misc knobs ---
    qkv_bias: bool = False
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    remat: str = "full"                # "none" | "full" — activation ckpt policy
    # long-context capability: attention-free/hybrid archs handle 500k decode
    subquadratic: bool = False

    # embedding tables are padded to a shardable multiple (production vocab
    # padding); logits carry the padded width, labels never reference the pad
    vocab_pad: int = 256

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab // self.vocab_pad) * self.vocab_pad

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // max(self.n_heads, 1))

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    def active_params(self) -> int:
        """Approximate active parameter count (MoE counts top_k experts)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd, nh, nkv = self.head_dim, self.n_heads, self.n_kv
        attn = d * hd * nh + 2 * d * hd * nkv + hd * nh * d
        if self.kind == "rwkv":
            attn = 4 * d * d
        if self.kind == "hymba":
            attn += 2 * d * d * self.ssm_expand
        ffn = 3 * d * f
        if self.n_experts:
            ffn = 3 * d * f * (self.top_k + self.n_shared_experts) + d * self.n_experts
        emb = v * d * (1 if self.tie_embeddings else 2)
        enc = self.n_enc_layers * (attn + ffn)
        return L * (attn + ffn) + emb + enc

    def total_params(self) -> int:
        if not self.n_experts:
            return self.active_params()
        d, f = self.d_model, self.d_ff
        per_layer_extra = 3 * d * f * (self.n_experts - self.top_k)
        return self.active_params() + self.n_layers * per_layer_extra


_REGISTRY: dict[str, ModelConfig] = {}
_SMOKE: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig, smoke: ModelConfig):
    _REGISTRY[cfg.name] = cfg
    _SMOKE[cfg.name] = smoke
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    return _REGISTRY[name]


def smoke_of(name: str) -> ModelConfig:
    _ensure_loaded()
    return _SMOKE[name]


def list_configs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    if _REGISTRY:
        return
    from repro.configs import (  # noqa: F401
        llama3_8b, granite_3_2b, codeqwen15_7b, phi3_medium_14b,
        granite_moe_3b_a800m, deepseek_moe_16b, hymba_1_5b, pixtral_12b,
        rwkv6_1_6b, whisper_medium,
    )
