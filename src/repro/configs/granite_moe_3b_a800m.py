"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512/expert
vocab=49155, 40 experts top-8 [hf:ibm-granite/granite-3.0 family]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(name="granite-moe-3b-a800m", kind="moe", n_layers=32,
                d_model=1536, n_heads=24, n_kv=8, d_ff=512, vocab=49155,
                n_experts=40, top_k=8, rope_theta=10000.0),
    smoke=ModelConfig(name="granite-moe-3b-a800m-smoke", kind="moe",
                      n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=48,
                      vocab=151, n_experts=8, top_k=2, dtype="float32",
                      remat="none"),
)
