"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
Backbone only; the pixtral-ViT frontend is a stub — input_specs() provides
precomputed patch embeddings (256 tokens) [hf:mistralai/Pixtral-12B-2409]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(name="pixtral-12b", kind="dense", n_layers=40, d_model=5120,
                n_heads=32, n_kv=8, d_ff=14336, vocab=131072,
                n_img_tokens=256, rope_theta=1000000000.0),
    smoke=ModelConfig(name="pixtral-12b-smoke", kind="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv=2, d_ff=160, vocab=193,
                      n_img_tokens=8, dtype="float32", remat="none"),
)
