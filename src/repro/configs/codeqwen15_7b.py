"""codeqwen1.5-7b [dense]: 32L d_model=4096 32H (GQA kv=32 == MHA) d_ff=13440
vocab=92416.  qwen1.5 arch: QKV bias [hf:Qwen/CodeQwen1.5-7B]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(name="codeqwen1.5-7b", kind="dense", n_layers=32, d_model=4096,
                n_heads=32, n_kv=32, d_ff=13440, vocab=92416, qkv_bias=True,
                rope_theta=1000000.0),
    smoke=ModelConfig(name="codeqwen1.5-7b-smoke", kind="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv=4, d_ff=160, vocab=211,
                      qkv_bias=True, dtype="float32", remat="none"),
)
