"""repro.configs — assigned architectures + shape suites."""
from repro.configs.base import ModelConfig, get_config, list_configs, smoke_of
from repro.configs.shapes import SUITES, ShapeSuite, cells, applicable
