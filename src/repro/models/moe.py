"""Mixture-of-Experts FFN.

Routing: top-k softmax.  Dispatch: *sort-based capacity bucketing per example*
(argsort tokens by expert, take the first ``cap`` per expert) — memory scales
as ``s * top_k * d`` (vs the (tokens, E, cap) blow-up of one-hot dispatch),
shapes stay static, and the expert dimension shards on the "model" axis (EP):
expert GEMMs are local to the expert shard while the per-example gather /
scatter stays local to the data shard; GSPMD inserts the all-to-all between
them.  Optional shared experts (DeepSeekMoE) + Switch aux loss + router z-loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import modules as nn
from repro.models.modules import param

__all__ = ["moe_params", "moe_ffn"]


def moe_params(cfg, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "router": param((d, e), jnp.float32, (None, "expert"), init="scaled"),
        "wi": param((e, d, 2 * f), dtype, ("expert", None, "dff")),
        "wo": param((e, f, d), dtype, ("expert", "dff", None)),
    }
    if cfg.n_shared_experts:
        p["shared"] = nn.swiglu_p(d, f * cfg.n_shared_experts, dtype)
    return p


def _capacity(s: int, cfg) -> int:
    cap = int(cfg.top_k * s * cfg.capacity_factor / cfg.n_experts) + 1
    return min(max(cap, min(4, s * cfg.top_k)), s)


def _route_one(xf, gate_idx, gate_vals, *, e: int, cap: int):
    """Per-example dispatch indices.  xf: (s, d); gate_*: (s, k).

    Returns (tok (e,cap) token ids, w (e,cap) combine weights, valid (e,cap)).
    Stable argsort by expert id groups slots; entries past an expert's
    capacity are dropped (first-come policy, as GShard/Switch).
    """
    s, k = gate_idx.shape
    flat_e = gate_idx.reshape(-1)                        # (s*k,), token-major
    flat_w = gate_vals.reshape(-1)
    flat_tok = jnp.arange(s * k, dtype=jnp.int32) // k
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_tok = flat_tok[order]
    sorted_w = flat_w[order]
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32),
                              jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    slot = starts[:, None] + jnp.arange(cap, dtype=jnp.int32)[None]   # (e, cap)
    valid = jnp.arange(cap)[None] < counts[:, None]
    slot = jnp.clip(slot, 0, s * k - 1)
    # safety: slots past the end of an expert's range belong to other experts
    valid &= sorted_e[slot] == jnp.arange(e, dtype=flat_e.dtype)[:, None]
    tok = sorted_tok[slot]
    w = jnp.where(valid, sorted_w[slot], 0.0)
    return tok, w, valid


def moe_ffn(x: jax.Array, p: dict, cfg) -> tuple[jax.Array, dict]:
    """x: (b, s, d) -> (out, {'aux_loss', 'router_zloss'})."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(s, cfg)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                 # (b, s, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    tok, w, valid = jax.vmap(
        lambda xi, gi, gv: _route_one(xi, gi, gv, e=e, cap=cap))(x, gate_idx,
                                                                 gate_vals)
    # gather: (b, e, cap, d), zeroed beyond capacity
    xe = jnp.take_along_axis(x[:, None], tok[..., None].astype(jnp.int32),
                             axis=2)
    xe = jnp.where(valid[..., None], xe, 0)
    xe = nn.act_shard(xe, ("batch", "expert", None, None))
    gu = jnp.einsum("becd,edf->becf", xe, p["wi"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    g, u = jnp.split(gu, 2, axis=-1)
    ye = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u, p["wo"],
                    preferred_element_type=jnp.float32)
    ye = ye * w[..., None]                                        # combine wts
    # scatter-add back to tokens (duplicates accumulate)
    def _combine_one(ye_i, tok_i):
        return jnp.zeros((s, d), jnp.float32).at[tok_i.reshape(-1)].add(
            ye_i.reshape(-1, d))
    out = jax.vmap(_combine_one)(ye, tok).astype(x.dtype)
    out = nn.act_shard(out, ("batch", None, None))

    if cfg.n_shared_experts:
        out = out + nn.swiglu(x, p["shared"])

    # Switch aux loss + router z-loss
    me = probs.mean((0, 1))                                       # (e,)
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)       # (b,s,k,e)
    ce = onehot.sum(2).mean((0, 1))
    aux = cfg.aux_loss_coef * e * jnp.sum(me * ce)
    zloss = cfg.router_zloss * jnp.mean(
        jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return out, {"aux_loss": aux, "router_zloss": zloss}
