"""Encoder-decoder assembly (whisper-medium backbone).

The conv/mel frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (b, enc_seq, d).  Encoder = bidirectional MHA
blocks; decoder = causal self-attention + cross-attention blocks.  RoPE is
used in place of whisper's learned positional embeddings (noted in DESIGN.md —
the backbone dims are what the assignment fixes).  Decode caches both the
self-attention KV and the per-layer cross KV (computed once at prefill).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import modules as nn
from repro.models.modules import param
from repro.models.transformer import lm_loss  # noqa: F401  (re-export)

__all__ = ["encdec_param_specs", "encode", "encdec_forward",
           "encdec_decode_step", "init_encdec_caches", "encdec_cache_logical",
           "cross_kv"]


def _mlp_p(d, f, dtype):
    return {"wi": param((d, f), dtype, (None, "dff")),
            "bi": param((f,), dtype, ("dff",), init="zeros"),
            "wo": param((f, d), dtype, ("dff", None)),
            "bo": param((d,), dtype, (None,), init="zeros")}


def _mlp(x, p):
    return nn.dense(jax.nn.gelu(nn.dense(x, p["wi"], p["bi"])), p["wo"], p["bo"])


def _xattn_p(cfg, dtype):
    d, hd, nh, nkv = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv
    return {"wq": param((d, nh * hd), dtype, (None, "heads")),
            "wk": param((d, nkv * hd), dtype, (None, "kv_heads")),
            "wv": param((d, nkv * hd), dtype, (None, "kv_heads")),
            "wo": param((nh * hd, d), dtype, ("heads", None))}


def _enc_layer_p(cfg, dtype):
    d = cfg.d_model
    return {"ln1": nn.rmsnorm_p(d, dtype), "attn": attn.attn_params(cfg, dtype),
            "ln2": nn.rmsnorm_p(d, dtype), "mlp": _mlp_p(d, cfg.d_ff, dtype)}


def _dec_layer_p(cfg, dtype):
    d = cfg.d_model
    return {"ln1": nn.rmsnorm_p(d, dtype), "attn": attn.attn_params(cfg, dtype),
            "lnx": nn.rmsnorm_p(d, dtype), "xattn": _xattn_p(cfg, dtype),
            "ln2": nn.rmsnorm_p(d, dtype), "mlp": _mlp_p(d, cfg.d_ff, dtype)}


def _stack(tree, L):
    return jax.tree_util.tree_map(
        lambda s: param((L,) + s.shape, s.dtype, (None,) + s.logical,
                        init=s.init, scale=s.scale),
        tree, is_leaf=lambda x: isinstance(x, nn.ParamSpec))


def encdec_param_specs(cfg) -> dict:
    dtype = cfg.param_dtype
    d = cfg.d_model
    return {
        "embed": nn.embedding_p(cfg.padded_vocab, d, dtype),
        "enc_layers": _stack(_enc_layer_p(cfg, dtype), cfg.n_enc_layers),
        "enc_norm": nn.rmsnorm_p(d, dtype),
        "dec_layers": _stack(_dec_layer_p(cfg, dtype), cfg.n_layers),
        "final_norm": nn.rmsnorm_p(d, dtype),
        "lm_head": param((d, cfg.padded_vocab), dtype, (None, "vocab")),
    }


def _bidir_attention(x, p, cfg):
    """Encoder self-attention: full (non-causal) with RoPE."""
    b, s, _ = x.shape
    hd, nh, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv
    q = nn.dense(x, p["wq"], p.get("bq")).reshape(b, s, nh, hd)
    k = nn.dense(x, p["wk"], p.get("bk")).reshape(b, s, nkv, hd)
    v = nn.dense(x, p["wv"], p.get("bv")).reshape(b, s, nkv, hd)
    pos = jnp.arange(s)[None, :]
    q, k = attn.rope(q, pos, cfg.rope_theta), attn.rope(k, pos, cfg.rope_theta)
    scores = attn._gqa_scores(q, k, cfg) / jnp.sqrt(hd).astype(jnp.float32)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = jnp.einsum("bngst,btnh->bsngh", w, v).reshape(b, s, nh * hd)
    return nn.dense(o, p["wo"])


def cross_kv(enc_out, p, cfg):
    b, t, _ = enc_out.shape
    hd, nkv = cfg.head_dim, cfg.n_kv
    k = nn.dense(enc_out, p["wk"]).reshape(b, t, nkv, hd)
    v = nn.dense(enc_out, p["wv"]).reshape(b, t, nkv, hd)
    return k, v


def _cross_attention(x, k, v, p, cfg):
    """q from decoder x, kv precomputed from encoder output (no RoPE)."""
    b, s, _ = x.shape
    hd, nh = cfg.head_dim, cfg.n_heads
    q = nn.dense(x, p["wq"]).reshape(b, s, nh, hd)
    scores = attn._gqa_scores(q, k, cfg) / jnp.sqrt(hd).astype(jnp.float32)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = jnp.einsum("bngst,btnh->bsngh", w, v).reshape(b, s, nh * hd)
    return nn.dense(o, p["wo"])


def encode(params, cfg, frames):
    """frames: (b, enc_seq, d) precomputed embeddings (stub frontend)."""
    x = nn.act_shard(frames.astype(cfg.param_dtype), ("batch", None, None))

    def body(carry, lp):
        carry = nn.act_shard(carry, ("batch", "seq_sp", None))
        h = carry + _bidir_attention(nn.rmsnorm(carry, lp["ln1"], cfg.norm_eps),
                                     lp["attn"], cfg)
        h = h + _mlp(nn.rmsnorm(h, lp["ln2"], cfg.norm_eps), lp["mlp"])
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat == "full" else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return nn.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def encdec_forward(params, cfg, tokens, frames):
    """Teacher-forced forward: (logits, aux)."""
    enc_out = encode(params, cfg, frames)
    x = params["embed"].astype(cfg.param_dtype)[tokens]
    x = nn.act_shard(x, ("batch", None, None))

    def body(carry, lp):
        carry = nn.act_shard(carry, ("batch", "seq_sp", None))
        h = carry + attn.attention(nn.rmsnorm(carry, lp["ln1"], cfg.norm_eps),
                                   lp["attn"], cfg)
        k, v = cross_kv(enc_out, lp["xattn"], cfg)
        h = h + _cross_attention(nn.rmsnorm(h, lp["lnx"], cfg.norm_eps),
                                 k, v, lp["xattn"], cfg)
        h = h + _mlp(nn.rmsnorm(h, lp["ln2"], cfg.norm_eps), lp["mlp"])
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat == "full" else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
    x = nn.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    aux = {"aux_loss": jnp.zeros((), jnp.float32),
           "router_zloss": jnp.zeros((), jnp.float32)}
    return nn.act_shard(logits, ("batch", None, "vocab")), aux


def init_encdec_caches(cfg, batch: int, max_seq: int, dtype) -> dict:
    hd, nkv, L = cfg.head_dim, cfg.n_kv, cfg.n_layers
    return {
        "kv": {"k": jnp.zeros((L, batch, max_seq, nkv, hd), dtype),
               "v": jnp.zeros((L, batch, max_seq, nkv, hd), dtype)},
        "xkv": {"k": jnp.zeros((L, batch, cfg.enc_seq, nkv, hd), dtype),
                "v": jnp.zeros((L, batch, cfg.enc_seq, nkv, hd), dtype)},
    }


def encdec_cache_logical(cfg) -> dict:
    kv = (None, "batch", None, "kv_heads", None)
    return {"kv": {"k": kv, "v": kv}, "xkv": {"k": kv, "v": kv}}


def fill_cross_cache(params, cfg, frames, caches):
    """Prefill step for decode: compute enc output and per-layer cross KV."""
    enc_out = encode(params, cfg, frames)

    def body(_, lp):
        k, v = cross_kv(enc_out, lp["xattn"], cfg)
        return None, {"k": k, "v": v}

    _, xkv = jax.lax.scan(body, None, params["dec_layers"])
    return dict(caches, xkv=xkv)


def encdec_decode_step(params, cfg, token, caches, pos):
    """One decoder token against self KV cache + static cross KV."""
    x = params["embed"].astype(cfg.param_dtype)[token]
    x = nn.act_shard(x, ("batch", None, None))

    def body(carry, xs):
        lp, kv, xkv = xs
        h, new_kv = attn.attention_decode(
            nn.rmsnorm(carry, lp["ln1"], cfg.norm_eps), lp["attn"], cfg, kv, pos)
        h = carry + h
        h = h + _cross_attention(nn.rmsnorm(h, lp["lnx"], cfg.norm_eps),
                                 xkv["k"], xkv["v"], lp["xattn"], cfg)
        h = h + _mlp(nn.rmsnorm(h, lp["ln2"], cfg.norm_eps), lp["mlp"])
        return h, new_kv

    x, new_kv = jax.lax.scan(body, x, (params["dec_layers"], caches["kv"],
                                       caches["xkv"]))
    x = nn.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return logits, dict(caches, kv=new_kv)
