"""repro.models — pure-JAX module substrate + assigned architectures."""
from repro.models.zoo import Model, build, input_specs, batch_logical
