"""Minimal functional module substrate (params are plain pytrees).

Every parameter is created through ``param(...)`` which records its *logical
sharding axes* alongside shape/dtype; ``init_tree`` materializes values while
``logical_tree`` extracts the matching sharding annotation pytree (used by the
launchers to build in_shardings for pjit).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.parallel.sharding import act_shard

__all__ = [
    "ParamSpec", "param", "init_tree", "logical_tree", "shape_tree",
    "dense", "rmsnorm_p", "rmsnorm", "layernorm_p", "layernorm",
    "embedding_p", "swiglu_p", "swiglu", "act_shard",
]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    dtype: object
    logical: tuple[str | None, ...]
    init: str = "normal"              # normal | zeros | ones | scaled
    scale: float = 1.0

    def materialize(self, key) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        fan_in = self.shape[0] if len(self.shape) > 1 else max(self.shape[0], 1)
        if self.init == "scaled":
            std = self.scale / math.sqrt(fan_in)
        else:
            std = 0.02
        return (jax.random.normal(key, self.shape, jnp.float32) * std).astype(self.dtype)


def param(shape, dtype, logical, init="scaled", scale=1.0) -> ParamSpec:
    assert len(logical) == len(shape), (shape, logical)
    return ParamSpec(tuple(shape), dtype, tuple(logical), init, scale)


def _is_spec(x):
    return isinstance(x, ParamSpec)


def init_tree(spec_tree, key) -> dict:
    """Materialize a pytree of ParamSpecs into arrays (stable key folding)."""
    leaves, treedef = jax.tree_util.tree_flatten(spec_tree, is_leaf=_is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))
    vals = [s.materialize(k) for s, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def logical_tree(spec_tree):
    return jax.tree_util.tree_map(lambda s: s.logical, spec_tree, is_leaf=_is_spec)


def shape_tree(spec_tree):
    return jax.tree_util.tree_map(lambda s: s.shape, spec_tree, is_leaf=_is_spec)


# ---------------------------------------------------------------------------
# primitive layers (functional)
# ---------------------------------------------------------------------------

def dense(x: jax.Array, w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    """x @ w, output in x.dtype.

    No explicit f32 upcast: MXU/dot hardware accumulates bf16 operands in f32
    internally, and an ``einsum(..., preferred_element_type=f32).astype(bf16)``
    chain makes every backward cotangent f32 — doubling all activation
    collectives and HBM traffic (§Perf iteration A2 in EXPERIMENTS.md)."""
    out = jnp.einsum("...i,io->...o", x, w.astype(x.dtype))
    if b is not None:
        out = out + b.astype(x.dtype)
    return out


def rmsnorm_p(d: int, dtype) -> ParamSpec:
    return param((d,), dtype, (None,), init="ones")


def rmsnorm(x: jax.Array, g: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * g.astype(x.dtype)


def layernorm_p(d: int, dtype) -> dict:
    return {"g": param((d,), dtype, (None,), init="ones"),
            "b": param((d,), dtype, (None,), init="zeros")}


def layernorm(x: jax.Array, p: dict, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * p["g"].astype(x.dtype) + p["b"].astype(x.dtype)


def embedding_p(vocab: int, d: int, dtype) -> ParamSpec:
    return param((vocab, d), dtype, ("vocab", None), init="normal")


def swiglu_p(d: int, f: int, dtype) -> dict:
    return {
        "wi": param((d, 2 * f), dtype, (None, "dff")),       # gate+up fused
        "wo": param((f, d), dtype, ("dff", None)),
    }


def swiglu(x: jax.Array, p: dict) -> jax.Array:
    gu = dense(x, p["wi"])
    g, u = jnp.split(gu, 2, axis=-1)
    return dense(jax.nn.silu(g) * u, p["wo"])
