"""GQA attention with RoPE — train/prefill (full-sequence) and decode
(single token against a KV cache) paths.  Head dims are sharded on the
"model" axis (Megatron-style); softmax in fp32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import modules as nn
from repro.models.modules import param

__all__ = ["attn_params", "rope", "attention", "attention_decode", "init_kv_cache"]


def attn_params(cfg, dtype) -> dict:
    d, hd, nh, nkv = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv
    p = {
        "wq": param((d, nh * hd), dtype, (None, "heads")),
        "wk": param((d, nkv * hd), dtype, (None, "kv_heads")),
        "wv": param((d, nkv * hd), dtype, (None, "kv_heads")),
        "wo": param((nh * hd, d), dtype, ("heads", None)),
    }
    if cfg.qkv_bias:
        p["bq"] = param((nh * hd,), dtype, ("heads",), init="zeros")
        p["bk"] = param((nkv * hd,), dtype, ("kv_heads",), init="zeros")
        p["bv"] = param((nkv * hd,), dtype, ("kv_heads",), init="zeros")
    return p


def rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); pos: (..., S) absolute positions."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[..., :, None, None].astype(jnp.float32) * freqs  # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def _qkv(x, p, cfg):
    b, s, _ = x.shape
    hd, nh, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv
    q = nn.dense(x, p["wq"], p.get("bq")).reshape(b, s, nh, hd)
    k = nn.dense(x, p["wk"], p.get("bk")).reshape(b, s, nkv, hd)
    v = nn.dense(x, p["wv"], p.get("bv")).reshape(b, s, nkv, hd)
    return q, k, v


def _gqa_scores(q, k, cfg):
    """q: (b,s,nh,hd), k: (b,t,nkv,hd) -> (b, nkv, group, s, t)."""
    b, s, nh, hd = q.shape
    nkv = cfg.n_kv
    q = q.reshape(b, s, nkv, nh // nkv, hd)
    return jnp.einsum("bsngh,btnh->bngst", q, k,
                      preferred_element_type=jnp.float32)


def attention(x: jax.Array, p: dict, cfg, *, pos0: int = 0) -> jax.Array:
    """Full-sequence causal attention (train / prefill).

    GQA is evaluated with the KV heads *explicitly repeated* to the query head
    count so every attention tensor is 4D with the same head axis, sharded on
    "model".  The 5D grouped-einsum formulation made GSPMD fall back to
    "involuntary full rematerialization" (replicating (b,s,kv,hd) tensors per
    layer) because kv=8 groups cannot split a 16-way model axis; repeating
    first turns the reshard into a cheap neighbor exchange (§Perf iteration
    A1 in EXPERIMENTS.md)."""
    b, s, _ = x.shape
    hd = cfg.head_dim
    g = cfg.n_heads // cfg.n_kv
    q, k, v = _qkv(x, p, cfg)
    pos = pos0 + jnp.arange(s)[None, :]
    q, k = rope(q, pos, cfg.rope_theta), rope(k, pos, cfg.rope_theta)
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    q = nn.act_shard(q, ("batch", None, "heads", None))
    k = nn.act_shard(k, ("batch", None, "heads", None))
    v = nn.act_shard(v, ("batch", None, "heads", None))
    scores = jnp.einsum("bsnh,btnh->bnst", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = jnp.einsum("bnst,btnh->bsnh", w, v)
    o = o.reshape(b, s, cfg.n_heads * hd)
    o = nn.act_shard(o, ("batch", None, "heads"))
    return nn.dense(o, p["wo"])


def init_kv_cache(cfg, batch: int, max_seq: int, dtype) -> dict:
    hd, nkv = cfg.head_dim, cfg.n_kv
    shape = (cfg.n_layers, batch, max_seq, nkv, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


KV_CACHE_LOGICAL = {"k": (None, "batch", None, "kv_heads", None),
                    "v": (None, "batch", None, "kv_heads", None)}


def attention_decode(x: jax.Array, p: dict, cfg, kv_layer: dict,
                     pos: jax.Array) -> tuple[jax.Array, dict]:
    """One-token decode: x (b, 1, d), kv_layer {'k','v'}: (b, S, nkv, hd),
    pos: scalar or per-sequence (b,) positions (continuous batching).
    Returns (out (b,1,d), updated kv)."""
    b, one, _ = x.shape
    hd, nh, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv
    pos = jnp.asarray(pos, jnp.int32)
    scalar_pos = pos.ndim == 0                 # pod decode: one shared position
    posv = jnp.broadcast_to(pos, (b,))[:, None]
    q, k_new, v_new = _qkv(x, p, cfg)
    q = rope(q, posv, cfg.rope_theta)
    k_new = rope(k_new, posv, cfg.rope_theta)
    if scalar_pos:
        # dynamic_update_slice keeps the sharded cache update local (the
        # batched scatter below makes GSPMD reshard — 2x decode collectives)
        kc = jax.lax.dynamic_update_slice_in_dim(kv_layer["k"], k_new, pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(kv_layer["v"], v_new, pos, axis=1)
    else:                                      # per-slot positions (engine)
        bidx = jnp.arange(b)
        kc = kv_layer["k"].at[bidx, pos].set(k_new[:, 0])
        vc = kv_layer["v"].at[bidx, pos].set(v_new[:, 0])
    s_max = kc.shape[1]
    # GQA decode with kv_heads < model axis: the cache lives head_dim-sharded
    # (launch/dryrun.py cache_specs fallback); matching q's layout makes the
    # score contraction local with one small (b,n,g,1,t) all-reduce instead
    # of an involuntary cache reshard (§Perf A5).  Only when the kv-head axis
    # cannot divide the model axis — otherwise the cache is kv-sharded and
    # this constraint would fight it.
    from repro.parallel.sharding import current_rules
    _r = current_rules()
    _msize = _r.mesh.shape.get("model", 1) if (_r and _r.mesh) else 1
    if _msize > 1 and cfg.n_kv % _msize != 0:
        q = nn.act_shard(q, ("batch", None, None, "model_in"))
    scores = _gqa_scores(q, kc, cfg) / jnp.sqrt(hd).astype(jnp.float32)
    valid = jnp.arange(s_max)[None, :] <= jnp.broadcast_to(pos, (b,))[:, None]
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = jnp.einsum("bngst,btnh->bsngh", w, vc).reshape(b, 1, nh * hd)
    return nn.dense(o, p["wo"]), {"k": kc, "v": vc}
