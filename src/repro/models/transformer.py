"""Decoder-only LM assembly: scan-over-stacked-layers (compile time independent
of depth), four block kinds (dense / moe / hymba / rwkv), full-sequence
(train / prefill) and single-token (decode) paths, optional remat.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import modules as nn
from repro.models import moe as moemod
from repro.models import rwkv as rwkvmod
from repro.models import ssm as ssmmod
from repro.models.modules import param

__all__ = ["decoder_param_specs", "stack_layer_specs", "decoder_forward",
           "decoder_decode_step", "lm_loss", "init_caches", "cache_logical"]


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def _layer_specs(cfg, dtype) -> dict:
    d = cfg.d_model
    if cfg.kind == "rwkv":
        p = rwkvmod.rwkv_params(cfg, dtype)
        p["ln1"] = nn.rmsnorm_p(d, dtype)
        p["ln2"] = nn.rmsnorm_p(d, dtype)
        return p
    p = {
        "ln1": nn.rmsnorm_p(d, dtype),
        "ln2": nn.rmsnorm_p(d, dtype),
        "attn": attn.attn_params(cfg, dtype),
    }
    if cfg.kind == "moe":
        p["moe"] = moemod.moe_params(cfg, dtype)
    else:
        p["mlp"] = nn.swiglu_p(d, cfg.d_ff, dtype)
    if cfg.kind == "hymba":
        p["mamba"] = ssmmod.mamba_params(cfg, dtype)
    return p


def stack_layer_specs(cfg, dtype, n_layers: int | None = None) -> dict:
    """Layer specs with a leading stacked (L,) axis for scan."""
    L = n_layers if n_layers is not None else cfg.n_layers
    one = _layer_specs(cfg, dtype)
    return jax.tree_util.tree_map(
        lambda s: param((L,) + s.shape, s.dtype, (None,) + s.logical,
                        init=s.init, scale=s.scale),
        one, is_leaf=lambda x: isinstance(x, nn.ParamSpec))


def decoder_param_specs(cfg) -> dict:
    dtype = cfg.param_dtype
    d = cfg.d_model
    specs = {
        "embed": nn.embedding_p(cfg.padded_vocab, d, dtype),
        "layers": stack_layer_specs(cfg, dtype),
        "final_norm": nn.rmsnorm_p(d, dtype),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = param((d, cfg.padded_vocab), dtype, (None, "vocab"))
    return specs


# ---------------------------------------------------------------------------
# block bodies
# ---------------------------------------------------------------------------

def _block(x, p, cfg, pos0=0):
    """Full-sequence block.  Returns (x, aux_losses).

    The block-entry residual is constrained to *sequence parallelism*
    (seq sharded on the model axis): the rematerialized per-layer saved
    buffers then live seq-sharded (Megatron-SP), and GSPMD inserts the
    all-gather into the head-sharded attention domain."""
    x = nn.act_shard(x, ("batch", "seq_sp", None))
    aux = {"aux_loss": jnp.zeros((), jnp.float32),
           "router_zloss": jnp.zeros((), jnp.float32)}
    if cfg.kind == "rwkv":
        x = x + rwkvmod.rwkv_time_mix(nn.rmsnorm(x, p["ln1"], cfg.norm_eps), p["tm"], cfg)
        x = x + rwkvmod.rwkv_channel_mix(nn.rmsnorm(x, p["ln2"], cfg.norm_eps), p["cm"], cfg)
        return x, aux
    h = nn.rmsnorm(x, p["ln1"], cfg.norm_eps)
    a = attn.attention(h, p["attn"], cfg, pos0=pos0)
    if cfg.kind == "hymba":
        a = a + ssmmod.mamba(h, p["mamba"], cfg)
    # constrain the row-parallel output to the seq-sharded layout *before*
    # the residual add so GSPMD forms reduce-scatter instead of
    # all-reduce + slice (§Perf iteration A3)
    a = nn.act_shard(a, ("batch", "seq_sp", None))
    x = x + a
    h = nn.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if cfg.kind == "moe":
        m, aux = moemod.moe_ffn(h, p["moe"], cfg)
    else:
        m = nn.swiglu(h, p["mlp"])
    m = nn.act_shard(m, ("batch", "seq_sp", None))
    return x + m, aux


def _block_decode(x, p, cfg, cache, pos):
    """Single-token block.  cache: this layer's slice.  Returns (x, cache)."""
    if cfg.kind == "rwkv":
        h = nn.rmsnorm(x, p["ln1"], cfg.norm_eps)
        o, x_tm, state = rwkvmod.rwkv_time_mix_decode(h, p["tm"], cfg,
                                                      cache["x_tm"], cache["state"])
        x = x + o
        h = nn.rmsnorm(x, p["ln2"], cfg.norm_eps)
        o, x_cm = rwkvmod.rwkv_channel_mix_decode(h, p["cm"], cfg, cache["x_cm"])
        return x + o, {"x_tm": x_tm, "x_cm": x_cm, "state": state}
    new_cache = {}
    h = nn.rmsnorm(x, p["ln1"], cfg.norm_eps)
    a, new_cache["kv"] = attn.attention_decode(h, p["attn"], cfg, cache["kv"], pos)
    if cfg.kind == "hymba":
        o, new_cache["mamba"] = ssmmod.mamba_decode(h, p["mamba"], cfg, cache["mamba"])
        a = a + o
    x = x + a
    h = nn.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if cfg.kind == "moe":
        m, _ = moemod.moe_ffn(h, p["moe"], cfg)
    else:
        m = nn.swiglu(h, p["mlp"])
    return x + m, new_cache


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _embed_in(params, cfg, tokens, extra_embeds=None):
    x = params["embed"].astype(cfg.param_dtype)[tokens]
    if extra_embeds is not None:                       # VLM stub: patch prefix
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    return nn.act_shard(x, ("batch", None, None))


def _logits_out(x, params, cfg):
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return nn.act_shard(logits, ("batch", None, "vocab"))


def decoder_forward(params, cfg, tokens, *, extra_embeds=None, pos0: int = 0):
    """tokens: (b, s) -> (logits (b, s', vocab), aux)."""
    x = _embed_in(params, cfg, tokens, extra_embeds)

    def body(carry, layer_p):
        y, aux = _block(carry, layer_p, cfg, pos0=pos0)
        return y, aux

    body_fn = jax.checkpoint(body) if cfg.remat == "full" else body
    x, auxs = jax.lax.scan(body_fn, x, params["layers"])
    x = nn.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    aux = jax.tree_util.tree_map(jnp.sum, auxs)
    return _logits_out(x, params, cfg), aux


def init_caches(cfg, batch: int, max_seq: int, dtype) -> dict:
    if cfg.kind == "rwkv":
        return rwkvmod.init_rwkv_cache(cfg, batch, dtype)
    kv = attn.init_kv_cache(cfg, batch, max_seq, dtype)
    cache = {"kv": {"k": kv["k"], "v": kv["v"]}}
    if cfg.kind == "hymba":
        cache["mamba"] = ssmmod.init_mamba_cache(cfg, batch, dtype)
    return cache


def cache_logical(cfg) -> dict:
    if cfg.kind == "rwkv":
        return dict(rwkvmod.RWKV_CACHE_LOGICAL)
    out = {"kv": dict(attn.KV_CACHE_LOGICAL)}
    if cfg.kind == "hymba":
        out["mamba"] = dict(ssmmod.MAMBA_CACHE_LOGICAL)
    return out


def decoder_decode_step(params, cfg, token, caches, pos):
    """token: (b, 1) -> (logits (b, 1, vocab), new caches).  ``caches`` carry a
    leading layer axis; the scan threads per-layer slices."""
    x = _embed_in(params, cfg, token)

    def body(carry, xs):
        layer_p, cache = xs
        y, new_cache = _block_decode(carry, layer_p, cfg, cache, pos)
        return y, new_cache

    x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    x = nn.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return _logits_out(x, params, cfg), new_caches


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def lm_loss(logits, labels, mask=None, aux=None):
    """Next-token CE (labels already shifted by the data pipeline)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    loss = jnp.sum(nll * mask) / jnp.clip(jnp.sum(mask), 1)
    metrics = {"ce": loss}
    if aux:
        for k, v in aux.items():
            loss = loss + v
            metrics[k] = v
    metrics["loss"] = loss
    return loss, metrics
