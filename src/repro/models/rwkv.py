"""RWKV6 ("Finch") block: data-dependent-decay linear recurrence (time-mix)
plus squared-ReLU channel-mix.  Attention-free — O(1) state per token, so the
``long_500k`` decode shape runs on this arch (DESIGN.md §7).

Time-mix follows the Finch formulation:
    y_t = r_t . (S_{t-1} + u (x) k_t v_t),   S_t = diag(w_t) S_{t-1} + k_t v_t
with w_t = exp(-exp(w0 + lora(x_mix))) per channel.  The sequence path reuses
``chunked_decay_scan`` via the shift trick (q.S_{t-1} == inclusive scan over
right-shifted (k, v, w)); decode is a single ``decay_step``.
Simplifications vs the reference implementation are documented in DESIGN.md
(static per-projection token-shift lerps instead of the per-step lora mix).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import modules as nn
from repro.models.modules import param
from repro.models.ssm import chunked_decay_scan

__all__ = ["rwkv_params", "rwkv_time_mix", "rwkv_channel_mix",
           "rwkv_time_mix_decode", "init_rwkv_cache", "RWKV_CACHE_LOGICAL"]

_LORA = 64


def rwkv_params(cfg, dtype) -> dict:
    d = cfg.d_model
    f = cfg.d_ff
    return {
        "tm": {
            "mu": param((5, d), dtype, (None, None), init="zeros"),  # r,k,v,w,g
            "wr": param((d, d), dtype, (None, "heads")),
            "wk": param((d, d), dtype, (None, "heads")),
            "wv": param((d, d), dtype, (None, "heads")),
            "wg": param((d, d), dtype, (None, "heads")),
            "w0": param((d,), jnp.float32, (None,), init="zeros"),
            "w_a": param((d, _LORA), dtype, (None, None)),
            "w_b": param((_LORA, d), dtype, (None, None), init="zeros"),
            "u": param((d,), jnp.float32, (None,), init="zeros"),
            "ln_g": param((d,), dtype, (None,), init="ones"),
            "wo": param((d, d), dtype, ("heads", None)),
        },
        "cm": {
            "mu": param((2, d), dtype, (None, None), init="zeros"),
            "wk": param((d, f), dtype, (None, "dff")),
            "wv": param((f, d), dtype, ("dff", None)),
            "wr": param((d, d), dtype, (None, None)),
        },
    }


def _shift(x):
    """Right-shift along seq axis with zero pad: x_{t-1}."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def _lerp(x, x_prev, mu):
    return x + (x_prev - x) * mu.astype(x.dtype)


def _heads(x, hsz):
    b, t, d = x.shape
    return x.reshape(b, t, d // hsz, hsz)


def _decay(xw, p):
    lora = jnp.tanh(nn.dense(xw, p["w_a"])) @ p["w_b"].astype(xw.dtype)
    return -jnp.exp(jnp.clip(p["w0"] + lora.astype(jnp.float32), -8, 4))


def rwkv_time_mix(x, p, cfg, *, chunk: int = 128):
    """x: (b, t, d) -> (b, t, d)."""
    hsz = cfg.rwkv_head
    xp = _shift(x)
    mu = p["mu"]
    xr, xk, xv, xw, xg = (_lerp(x, xp, mu[i]) for i in range(5))
    r = _heads(nn.dense(xr, p["wr"]), hsz)
    k = _heads(nn.dense(xk, p["wk"]), hsz)
    v = _heads(nn.dense(xv, p["wv"]), hsz)
    g = nn.dense(xg, p["wg"])
    log_w = _heads(_decay(xw, p), hsz)                      # (b,t,h,hsz) <= 0

    # shift trick: q . S_{t-1} == inclusive scan over shifted (k, v, w)
    ks, vs, ws = _shift(k.reshape(*k.shape[:2], -1)), _shift(
        v.reshape(*v.shape[:2], -1)), _shift(log_w.reshape(*log_w.shape[:2], -1))
    y, _ = chunked_decay_scan(r, _heads(ks, hsz), _heads(vs, hsz),
                              _heads(ws, hsz), chunk=chunk)
    u = p["u"].reshape(1, 1, -1, hsz)
    bonus = jnp.sum(r.astype(jnp.float32) * u * k.astype(jnp.float32), -1,
                    keepdims=True) * v.astype(jnp.float32)
    y = y.astype(jnp.float32) + bonus
    y = y.reshape(x.shape)
    # per-head group norm
    yh = y.reshape(*x.shape[:2], -1, hsz)
    yh = (yh - yh.mean(-1, keepdims=True)) * jax.lax.rsqrt(
        yh.var(-1, keepdims=True) + 1e-5)
    y = yh.reshape(x.shape).astype(x.dtype) * p["ln_g"].astype(x.dtype)
    return nn.dense(y * jax.nn.silu(g), p["wo"])


def rwkv_channel_mix(x, p, cfg):
    xp = _shift(x)
    xk = _lerp(x, xp, p["mu"][0])
    xr = _lerp(x, xp, p["mu"][1])
    k = jnp.square(jax.nn.relu(nn.dense(xk, p["wk"])))
    return jax.nn.sigmoid(nn.dense(xr, p["wr"])) * nn.dense(k, p["wv"])


def init_rwkv_cache(cfg, batch: int, dtype) -> dict:
    d = cfg.d_model
    hsz = cfg.rwkv_head
    h = d // hsz
    L = cfg.n_layers
    return {
        "x_tm": jnp.zeros((L, batch, d), dtype),       # token-shift (time mix)
        "x_cm": jnp.zeros((L, batch, d), dtype),       # token-shift (chan mix)
        "state": jnp.zeros((L, batch, h, hsz, hsz), jnp.float32),
    }


RWKV_CACHE_LOGICAL = {"x_tm": (None, "batch", None),
                      "x_cm": (None, "batch", None),
                      "state": (None, "batch", "heads", None, None)}


def rwkv_time_mix_decode(x, p, cfg, x_prev, state):
    """One token: x (b,1,d); x_prev (b,d); state (b,h,hsz,hsz)."""
    hsz = cfg.rwkv_head
    xp = x_prev[:, None]
    mu = p["mu"]
    xr, xk, xv, xw, xg = (_lerp(x, xp, mu[i]) for i in range(5))
    r = _heads(nn.dense(xr, p["wr"]), hsz)[:, 0]            # (b,h,hsz)
    k = _heads(nn.dense(xk, p["wk"]), hsz)[:, 0]
    v = _heads(nn.dense(xv, p["wv"]), hsz)[:, 0]
    g = nn.dense(xg, p["wg"])
    log_w = _heads(_decay(xw, p), hsz)[:, 0]
    u = p["u"].reshape(1, -1, hsz)
    rf, kf, vf = (z.astype(jnp.float32) for z in (r, k, v))
    y = jnp.einsum("bhk,bhkv->bhv", rf, state) + jnp.sum(
        rf * u * kf, -1, keepdims=True) * vf
    state = state * jnp.exp(log_w.astype(jnp.float32))[..., None] + \
        jnp.einsum("bhk,bhv->bhkv", kf, vf)
    y = (y - y.mean(-1, keepdims=True)) * jax.lax.rsqrt(
        y.var(-1, keepdims=True) + 1e-5)
    y = y.reshape(x.shape[0], 1, -1).astype(x.dtype) * p["ln_g"].astype(x.dtype)
    out = nn.dense(y * jax.nn.silu(g), p["wo"])
    return out, x[:, 0], state


def rwkv_channel_mix_decode(x, p, cfg, x_prev):
    xp = x_prev[:, None]
    xk = _lerp(x, xp, p["mu"][0])
    xr = _lerp(x, xp, p["mu"][1])
    k = jnp.square(jax.nn.relu(nn.dense(xk, p["wk"])))
    out = jax.nn.sigmoid(nn.dense(xr, p["wr"])) * nn.dense(k, p["wv"])
    return out, x[:, 0]
