"""Selective state-space (Mamba) block + shared chunked decay-scan machinery.

Two evaluators of the data-dependent-decay linear recurrence
``S_t = diag(a_t) S_{t-1} + k_t v_t^T ; y_t = S_t q_t``:

* ``chunked_decay_scan`` — multi-head (dk, dv) form used by RWKV6: intra-chunk
  quadratic form + inter-chunk state via ``lax.scan`` (O(T) memory,
  MXU-friendly (chunk x chunk) tiles).
* Mamba's per-channel form (h = d_inner, dk = ssm_state, dv = 1) expands the
  (t, d_inner, n) tensors *inside* the chunk loop — the full-sequence
  residency is only (b, t, d_inner), the TPU analogue of the fused selective
  scan kernel's memory behaviour.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import modules as nn
from repro.models.modules import param

__all__ = ["chunked_decay_scan", "decay_step", "mamba_params", "mamba",
           "mamba_decode", "init_mamba_cache", "MAMBA_CACHE_LOGICAL"]


def chunked_decay_scan(q, k, v, log_a, *, chunk: int = 128, state0=None):
    """Multi-head decay recurrence.  q, k: (b,t,h,dk); v: (b,t,h,dv);
    log_a: (b,t,h,dk) (<= 0).  Returns (y (b,t,h,dv), state (b,h,dk,dv))."""
    b, t, h, dk = q.shape
    dv = v.shape[-1]
    pad = (-t) % chunk
    if pad:
        q, k, v, log_a = (jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * 2)
                          for x in (q, k, v, log_a))
    tc = q.shape[1] // chunk
    qc, kc, vc, lac = (x.reshape(b, tc, chunk, h, x.shape[-1]).transpose(1, 0, 2, 3, 4)
                       for x in (q, k, v, log_a))
    if state0 is None:
        state0 = jnp.zeros((b, h, dk, dv), jnp.float32)

    def one_chunk(s_in, xs):
        qi, ki, vi, lai = xs                                  # (b,chunk,h,d*)
        lai = lai.astype(jnp.float32)
        acc = jnp.cumsum(lai, axis=1)                         # incl. self
        total = acc[:, -1:]
        q_s = qi.astype(jnp.float32) * jnp.exp(acc)
        k_tail = ki.astype(jnp.float32) * jnp.exp(total - acc)
        y_state = jnp.einsum("bchk,bhkv->bchv", q_s, s_in)
        k_r = ki.astype(jnp.float32) * jnp.exp(-acc)
        scores = jnp.einsum("bchk,bdhk->bhcd", q_s, k_r)
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        scores = jnp.where(causal[None, None], scores, 0.0)
        y_intra = jnp.einsum("bhcd,bdhv->bchv", scores, vi.astype(jnp.float32))
        s_out = s_in * jnp.exp(total).squeeze(1)[..., None] + jnp.einsum(
            "bchk,bchv->bhkv", k_tail, vi.astype(jnp.float32))
        return s_out, (y_state + y_intra).astype(v.dtype)

    state, ys = jax.lax.scan(one_chunk, state0, (qc, kc, vc, lac))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, tc * chunk, h, dv)
    return y[:, :t], state


def decay_step(q, k, v, log_a, state):
    """Single-token recurrence step (decode). q,k,log_a: (b,h,dk); v: (b,h,dv);
    state: (b,h,dk,dv)."""
    a = jnp.exp(log_a.astype(jnp.float32))[..., None]
    state = state * a + jnp.einsum("bhk,bhv->bhkv", k.astype(jnp.float32),
                                   v.astype(jnp.float32))
    y = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), state)
    return y.astype(v.dtype), state


# ---------------------------------------------------------------------------
# Mamba (selective SSM) block — the SSM path of hymba
# ---------------------------------------------------------------------------

def mamba_params(cfg, dtype) -> dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    return {
        "in_proj": param((d, 2 * di), dtype, (None, "dff")),
        "conv_w": param((cfg.ssm_conv, di), dtype, (None, "dff")),
        "conv_b": param((di,), dtype, ("dff",), init="zeros"),
        "w_b": param((di, n), dtype, ("dff", None)),      # x -> B (input gate)
        "w_c": param((di, n), dtype, ("dff", None)),      # x -> C (output gate)
        "w_dt": param((di, 1), dtype, ("dff", None)),
        "dt_bias": param((di,), jnp.float32, ("dff",), init="zeros"),
        "a_log": param((di, n), jnp.float32, ("dff", None), init="ones"),
        "d_skip": param((di,), jnp.float32, ("dff",), init="ones"),
        "out_proj": param((di, d), dtype, ("dff", None)),
    }


def _causal_conv(x, w, b, state=None):
    """x: (b, t, c); w: (k, c) depthwise causal conv; state: (b, k-1, c)."""
    kw = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (kw - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(kw))
    new_state = xp[:, -(kw - 1):] if kw > 1 else jnp.zeros_like(x[:, :0])
    return out + b.astype(x.dtype), new_state


def _dt_b_c(xc, p):
    """(b, *, di) -> dt (b,*,di), bmat/cmat (b,*,n) — cheap projections; the
    (di, n) expansion is deferred into the chunk loop."""
    bmat = nn.dense(xc, p["w_b"]).astype(jnp.float32)
    cmat = nn.dense(xc, p["w_c"]).astype(jnp.float32)
    # scalar dt per position, broadcast to per-channel via the bias (dt_rank=1)
    dt = jax.nn.softplus(nn.dense(xc, p["w_dt"]).astype(jnp.float32)
                         + p["dt_bias"])
    return dt, bmat, cmat


def _mamba_scan(xc, dt, bmat, cmat, a, *, chunk: int, state0):
    """Chunked selective scan.  xc: (b,t,di); dt: (b,t,di); bmat/cmat: (b,t,n);
    a: (di,n) negative.  state: (b,di,n).  Returns (y (b,t,di), state)."""
    b, t, di = xc.shape
    n = a.shape[-1]
    pad = (-t) % chunk
    if pad:
        xc, dt = (jnp.pad(x, ((0, 0), (0, pad), (0, 0))) for x in (xc, dt))
        bmat, cmat = (jnp.pad(x, ((0, 0), (0, pad), (0, 0))) for x in (bmat, cmat))
    tc = xc.shape[1] // chunk
    chunked = lambda x: x.reshape(b, tc, chunk, x.shape[-1]).transpose(1, 0, 2, 3)
    xcc, dtc, bc, cc = map(chunked, (xc, dt, bmat, cmat))

    def one_chunk(s_in, xs):
        xi, dti, bi, ci = xs                                  # (b,chunk,...)
        # scan inputs in the model compute dtype (bf16 in production; the
        # chunk-boundary state correction stays f32): halves the dominant
        # (b,c,di,n) HBM traffic — §Perf B1.  fp32 configs are unaffected.
        kv = ((dti * xi.astype(jnp.float32))[..., None]
              * bi[:, :, None, :]).astype(xi.dtype)
        # inclusive prefix states via associative scan over the chunk.
        # (§Perf B3 note: carrying the decay leg rank-1 as the (b,c,di)
        # dt-sum and expanding exp(dt (x) a) inside the combine measured
        # WORSE — the per-stage exp temporaries replace the saved A-leg
        # traffic; refuted, kept the direct form.)
        log_a = dti[..., None] * a                            # (b,c,di,n) f32
        def comb(l, r):
            al, sl = l
            ar, sr = r
            return al + ar, sl * jnp.exp(ar).astype(sl.dtype) + sr
        _, s_pref = jax.lax.associative_scan(comb, (log_a, kv), axis=1)
        # prefix states stay in compute dtype (feed the output gate only);
        # chunk-boundary corrections use the rank-1 dt cumsum ((b,c,di)
        # instead of (b,c,di,n) — §Perf B3b, the part of B3 that does win)
        acc_dt = jnp.cumsum(dti, axis=1)                      # (b,c,di)
        corr = jnp.exp(acc_dt[..., None] * a) * s_in[:, None]
        s_tot = s_pref + corr.astype(s_pref.dtype)
        y = jnp.einsum("bcdn,bcn->bcd", s_tot, ci.astype(s_tot.dtype))
        s_out = s_pref[:, -1].astype(jnp.float32) + \
            jnp.exp(acc_dt[:, -1][..., None] * a) * s_in
        return s_out, y.astype(xc.dtype)

    state, ys = jax.lax.scan(one_chunk, state0, (xcc, dtc, bc, cc))
    y = ys.transpose(1, 0, 2, 3).reshape(b, tc * chunk, di)
    return y[:, :t], state


def mamba(x, p, cfg, *, chunk: int = 128):
    """Full-sequence Mamba path. x: (b, t, d) -> (b, t, d)."""
    xz = nn.dense(x, p["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)
    xc, _ = _causal_conv(xi, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    xc = nn.act_shard(xc, ("batch", None, "dff"))
    dt, bmat, cmat = _dt_b_c(xc, p)
    a = -jnp.exp(p["a_log"])
    state0 = jnp.zeros((x.shape[0], p["a_log"].shape[0], cfg.ssm_state),
                       jnp.float32)
    y, _ = _mamba_scan(xc, dt, bmat, cmat, a, chunk=chunk, state0=state0)
    y = y.astype(jnp.float32) + p["d_skip"] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return nn.dense(y, p["out_proj"])


def init_mamba_cache(cfg, batch: int, dtype) -> dict:
    di = cfg.ssm_expand * cfg.d_model
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, di), dtype),
        "state": jnp.zeros((cfg.n_layers, batch, di, cfg.ssm_state),
                           jnp.float32),
    }


MAMBA_CACHE_LOGICAL = {"conv": (None, "batch", None, "dff"),
                       "state": (None, "batch", "dff", None)}


def mamba_decode(x, p, cfg, cache_layer):
    """One-token step. x: (b, 1, d) -> (out (b,1,d), new cache)."""
    xz = nn.dense(x, p["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(xi, p["conv_w"], p["conv_b"],
                                  state=cache_layer["conv"])
    xc = jax.nn.silu(xc)
    dt, bmat, cmat = _dt_b_c(xc[:, 0], p)                    # (b, di), (b, n)
    a = -jnp.exp(p["a_log"])
    log_a = dt[..., None] * a                                # (b, di, n)
    kv = (dt * xc[:, 0].astype(jnp.float32))[..., None] * bmat[:, None, :]
    state = cache_layer["state"] * jnp.exp(log_a) + kv
    y = jnp.einsum("bdn,bn->bd", state, cmat)
    y = y + p["d_skip"] * xc[:, 0].astype(jnp.float32)
    y = y[:, None].astype(x.dtype) * jax.nn.silu(z)
    out = nn.dense(y, p["out_proj"])
    return out, {"conv": conv_state, "state": state}
