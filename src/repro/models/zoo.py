"""Public model API: build any assigned architecture from its config.

``Model`` bundles init / loss / prefill / decode for one ``ModelConfig``;
``input_specs`` produces ShapeDtypeStruct stand-ins for every model input of a
(config x shape-suite) cell — the dry-run lowers against these without
allocating anything (same pattern for modality stubs: whisper gets precomputed
frame embeddings, pixtral precomputed patch embeddings).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeSuite
from repro.models import encdec as ed
from repro.models import modules as nn
from repro.models import transformer as tf

__all__ = ["Model", "build", "input_specs", "batch_logical"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any

    # ---- parameters -------------------------------------------------------
    def param_specs(self):
        if self.cfg.kind == "encdec":
            return ed.encdec_param_specs(self.cfg)
        return tf.decoder_param_specs(self.cfg)

    def init(self, key) -> dict:
        return nn.init_tree(self.param_specs(), key)

    def param_logical(self):
        return nn.logical_tree(self.param_specs())

    def param_shapes(self):
        return nn.shape_tree(self.param_specs())

    # ---- training ---------------------------------------------------------
    def forward(self, params, batch):
        cfg = self.cfg
        if cfg.kind == "encdec":
            return ed.encdec_forward(params, cfg, batch["tokens"], batch["frames"])
        logits, aux = tf.decoder_forward(
            params, cfg, batch["tokens"], extra_embeds=batch.get("images"))
        if cfg.n_img_tokens and "images" in batch:
            logits = logits[:, cfg.n_img_tokens:]
        return logits, aux

    def loss_fn(self, params, batch):
        logits, aux = self.forward(params, batch)
        return tf.lm_loss(logits, batch["labels"], batch.get("mask"), aux)

    # ---- serving ----------------------------------------------------------
    def init_caches(self, batch: int, max_seq: int):
        cfg = self.cfg
        dt = cfg.param_dtype
        if cfg.kind == "encdec":
            return ed.init_encdec_caches(cfg, batch, max_seq, dt)
        return tf.init_caches(cfg, batch, max_seq, dt)

    def cache_logical(self):
        if self.cfg.kind == "encdec":
            return ed.encdec_cache_logical(self.cfg)
        return tf.cache_logical(self.cfg)

    def prefill(self, params, batch):
        """Full-sequence forward for serving (logits over the prompt)."""
        return self.forward(params, batch)[0]

    def decode_step(self, params, token, caches, pos):
        cfg = self.cfg
        if cfg.kind == "encdec":
            return ed.encdec_decode_step(params, cfg, token, caches, pos)
        return tf.decoder_decode_step(params, cfg, token, caches, pos)


def build(cfg) -> Model:
    return Model(cfg)


def input_specs(cfg, suite: ShapeSuite, *, per_pod_batch: int | None = None
                ) -> dict:
    """ShapeDtypeStruct stand-ins for one (arch x shape) cell.

    train/prefill: token batch (+ labels/mask for train, + modality stubs).
    decode: one new token + position (caches are built separately — they are
    state, not inputs, but the dry-run passes them as donated args).
    """
    b = per_pod_batch or suite.global_batch
    s = suite.seq_len
    d = cfg.d_model
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if suite.mode == "decode":
        return {"token": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    out = {"tokens": tok}
    if suite.mode == "train":
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        out["mask"] = jax.ShapeDtypeStruct((b, s), jnp.float32)
    if cfg.kind == "encdec":
        out["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_seq, d), cfg.param_dtype)
    if cfg.n_img_tokens:
        out["images"] = jax.ShapeDtypeStruct((b, cfg.n_img_tokens, d),
                                             cfg.param_dtype)
        if suite.mode == "train":
            # labels cover token positions only
            out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return out


def batch_logical(cfg, suite: ShapeSuite) -> dict:
    """Logical sharding for each batch input (batch axis -> DP)."""
    if suite.mode == "decode":
        return {"token": ("batch", None)}
    out = {"tokens": ("batch", None)}
    if suite.mode == "train":
        out["labels"] = ("batch", None)
        out["mask"] = ("batch", None)
    if cfg.kind == "encdec":
        out["frames"] = ("batch", None, None)
    if cfg.n_img_tokens:
        out["images"] = ("batch", None, None)
    return out
