"""repro.core — the paper's contribution: memory-aware bulge-chasing
band->bidiagonal reduction, plus the surrounding three-stage SVD pipeline."""

from repro.core.band import pack, unpack, band_height, bandwidth_of
from repro.core.householder import make_reflector, apply_left, apply_right
from repro.core.bulge_chasing import (
    bidiagonalize, bidiagonalize_packed, reduce_stage_packed,
    reduce_stage_dense_ref, bidiagonalize_dense_ref, stage_schedule, tw_schedule,
)
from repro.core.stage1 import band_reduce
from repro.core.bidiag_svd import bidiag_singular_values
from repro.core.svd import (
    singular_values, banded_singular_values, bidiagonal_of,
    batched_singular_values, svd_batched,
)
from repro.core.tuning import (
    ChaseConfig, PipelineConfig, default_tilewidth, occupancy_matrix_size,
    stage_plan,
)
