"""repro.core — the paper's contribution: memory-aware bulge-chasing
band->bidiagonal reduction, plus the surrounding three-stage SVD pipeline."""

from repro.core.band import pack, unpack, band_height, bandwidth_of
from repro.core.householder import make_reflector, apply_left, apply_right
from repro.core.bulge_chasing import (
    bidiagonalize, bidiagonalize_packed, reduce_stage_packed,
    stage_schedule, tw_schedule,
)
from repro.core.stage1 import band_reduce
# (``repro.core.bidiag_svd.bidiag_svd`` — the stage-3 vector solver — is
# likewise accessed via its module to avoid shadowing the submodule name.)
from repro.core.bidiag_svd import bidiag_singular_values
# NOTE: the full-SVD entry point is ``repro.core.svd.svd`` — deliberately
# NOT re-exported here, where it would shadow the ``repro.core.svd``
# submodule binding (``from repro.core import svd`` must keep returning the
# module for existing callers).
from repro.core.svd import (
    singular_values, banded_singular_values, bidiagonal_of,
    batched_singular_values, svd_batched, banded_svd,
)
from repro.core.transforms import ChaseTape, accumulate_transforms
from repro.core.tuning import (
    ChaseConfig, PipelineConfig, default_tilewidth, occupancy_matrix_size,
    stage_plan,
)

# Numpy test oracles (core/reference.py) re-export lazily — PEP 562 — so
# importing the package never loads the oracle module on the hot path.
_LAZY_ORACLES = ("reduce_stage_dense_ref", "bidiagonalize_dense_ref",
                 "bidiagonalize_dense_ref_uv")


def __getattr__(name):
    if name in _LAZY_ORACLES:
        from repro.core import reference
        return getattr(reference, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
