"""Hyperparameter heuristics and the occupancy/performance model (paper §III-C/D).

The paper exposes three knobs — inner tilewidth TW, threads-per-block TPB, and
max concurrent blocks — and shows (Fig. 4) that the dominant one is TW, whose
optimum matches the cache-line width (32 for fp32, 16 for fp64 on 128-byte
lines).  The TPU translation:

* TW         -> still the dominant knob.  The analogue of "fill one cache line"
               is "fill one 128-lane vreg row": reflector length TW+1 padded to
               the lane count.  bf16 packs 2/lane-row, fp32 1.
* TPB        -> ROWS_PER_STEP: how many band rows one grid step applies the
               reflector to per VREG pass (sublane tiling, multiples of 8).
* max blocks -> MAX_CONCURRENT_SWEEPS per core (wavefront width hosted by one
               TensorCore's grid) — beyond it, sweeps serialize in the grid,
               trading occupancy for VMEM locality exactly like the paper's
               software loop unrolling.

The occupancy model (paper Eq. 1): full utilization needs
``n / (3 * CBW) >= execution_units``; for a TPU pod the execution unit is a
TensorCore (2 per chip on v5e-class parts).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

__all__ = [
    "default_tilewidth", "rows_per_step", "max_concurrent_sweeps",
    "occupancy_matrix_size", "vmem_working_set_bytes", "stage_plan",
    "default_bucket_batch", "ChaseConfig", "PipelineConfig",
]

LANE = 128          # TPU vector lane count
SUBLANE = 8         # TPU sublane count (f32)


def _bytes(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def default_tilewidth(bw: int, dtype=jnp.float32) -> int:
    """Paper Fig. 4: optimal TW fills one cache line; TPU: one lane row.

    Reflector length TW+1; we pick TW so the VMEM window stays small while the
    per-row apply saturates lanes.  Capped at bw-1 (cannot peel more than the
    band).  fp32 -> 32, bf16 -> 64, fp64 (CPU oracle) -> 16, matching the
    paper's per-precision optima scaled to the TPU lane granularity.
    """
    per_line = 128 // _bytes(dtype)      # elements per 128B GPU cache line
    tw = max(8, min(per_line, LANE // 2))
    return max(1, min(tw, bw - 1))


def rows_per_step(b_in: int, tw: int, dtype=jnp.float32) -> int:
    """TPB analogue: rows applied per VREG pass, sublane-aligned."""
    rows = b_in + tw + 1
    return min(64, max(SUBLANE, SUBLANE * (rows // SUBLANE)))


def max_concurrent_sweeps(n: int, b_in: int) -> int:
    """Wavefront width (paper: #blocks): ceil(n / (3*CBW - 1)) + 1 slots."""
    return max(1, -(-n // (3 * b_in - 1)) + 1)


def occupancy_matrix_size(cbw: int, execution_units: int) -> int:
    """Paper Eq. 1 / Table I: min n saturating all execution units."""
    return 3 * cbw * execution_units


def vmem_working_set_bytes(b_in: int, tw: int, dtype=jnp.float32) -> int:
    """One chase window (H x W) + reflectors, as staged in VMEM."""
    h = b_in + 2 * tw + 1
    w = b_in + tw + 1
    return (h * w + 2 * (tw + 1)) * _bytes(dtype)


def stage_plan(bw: int, tw: int) -> tuple[tuple[int, int], ...]:
    """Tile-width schedule: ((b_in, tw_i), ...) reducing bw -> 1, <= tw/stage."""
    plan = []
    b = bw
    while b > 1:
        twi = min(tw, b - 1)
        plan.append((b, twi))
        b -= twi
    return tuple(plan)


def default_bucket_batch(n: int, b_in: int, execution_units: int = 2,
                         oversub: int = 8) -> int:
    """Batch size that refills the wavefront when one matrix cannot (Eq. 1).

    A single matrix hosts ``max_concurrent_sweeps(n, b_in)`` concurrent
    windows; full utilization wants at least one per execution unit (paper
    Eq. 1), and ``oversub``x that to hide the gather/scatter latency between
    cycles (the paper's concurrent-blocks headroom).  Independent problems in
    a batch multiply the wavefront width, so the deficit is made up by
    batching.  Clamped to [1, 64].
    """
    per_matrix = max_concurrent_sweeps(n, b_in)
    want = execution_units * oversub
    return max(1, min(64, -(-want // per_matrix)))


@dataclasses.dataclass(frozen=True)
class ChaseConfig:
    """Resolved hyperparameters for one reduction stage."""
    b_in: int
    tw: int
    rows_per_step: int
    max_sweeps: int

    @staticmethod
    def resolve(n: int, b_in: int, dtype=jnp.float32, tw: int | None = None
                ) -> "ChaseConfig":
        tw = tw if tw is not None else default_tilewidth(b_in, dtype)
        return ChaseConfig(
            b_in=b_in, tw=tw,
            rows_per_step=rows_per_step(b_in, tw, dtype),
            max_sweeps=max_concurrent_sweeps(n, b_in),
        )


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Fully-resolved configuration for the three-stage pipeline.

    Extends :class:`ChaseConfig` from one reduction stage to the whole
    pipeline: it owns the concrete kernel backend (resolved once through the
    registry in ``kernels/ops.py`` — no "auto" strings survive resolution),
    the tile-width schedule ``bw -> 1``, and the serve-layer batch/bucket
    sizes.  It is hashable (all-primitive fields), so it can be a static jit
    argument, and it is the ONE object every layer accepts: ``core/svd.py``,
    ``core/bulge_chasing.py``, ``core/stage1.py``, ``kernels/ops.py`` and
    ``serve/engine.py`` all take ``config=`` instead of loose
    ``backend=``/``tw=`` strings (the legacy kwargs remain as overrides).
    """
    bw: int                     # stage-1 output / stage-2 input bandwidth
    tw: int                     # inner tilewidth (dominant knob, paper Fig. 4)
    backend: str                # concrete registry key ("ref", "pallas", ...)
    interpret: bool             # Pallas interpret mode (CPU correctness runs)
    dtype: str = "float32"      # working precision of stages 1-2
    max_batch: int = 8          # serve bucket capacity (leading batch axis B)
    unroll: int = 1             # fori_loop unroll of the wavefront stage
    compute_uv: bool = False    # full SVD: record + replay reflector tapes

    @property
    def plan(self) -> tuple[tuple[int, int], ...]:
        """The tile-width schedule ((b_in, tw_i), ...) down to bidiagonal."""
        return stage_plan(self.bw, self.tw)

    def kernel(self) -> "PipelineConfig":
        """Identity for the traced computation: serve-only fields (max_batch)
        are normalized so configs differing only in bucket sizing share one
        jit cache entry instead of recompiling the numeric pipeline."""
        return dataclasses.replace(self, max_batch=0)

    def chase(self, n: int, b_in: int | None = None) -> ChaseConfig:
        """Per-stage view (the legacy ChaseConfig) for a given problem size."""
        return ChaseConfig.resolve(n, b_in if b_in is not None else self.bw,
                                   jnp.dtype(self.dtype), tw=self.tw)

    @classmethod
    def resolve(cls, *, bw: int = 32, tw: int | None = None,
                backend: str = "auto", interpret: bool | None = None,
                dtype=jnp.float32, n: int | None = None,
                max_batch: int | None = None, unroll: int = 1,
                compute_uv: bool = False) -> "PipelineConfig":
        """Resolve every knob to a concrete value.

        ``backend="auto"`` and ``interpret=None`` are resolved by the backend
        registry (pallas on TPU, ref elsewhere; interpret off-TPU only);
        ``tw=None`` falls back to the cache-line/lane heuristic;
        ``max_batch=None`` uses the Eq.-1 occupancy deficit for (n, bw).
        ``bw`` is clamped to >= 1 (bw = 0 — e.g. a 1x1 problem — would zero
        the stage-1 panel width; a bw-1 "band" is already bidiagonal, so
        stage 2 is a no-op pass-through either way).
        """
        from repro.kernels import ops  # deferred: registry lives kernels-side

        bw = max(bw, 1)
        if n is not None:
            bw = min(bw, max(n, 1))
        tw = tw if tw is not None else default_tilewidth(bw, dtype)
        tw = max(1, min(tw, max(bw - 1, 1)))
        backend, interpret = ops.resolve_backend(backend, interpret)
        if max_batch is None:
            max_batch = default_bucket_batch(n, bw) if n else 8
        return cls(bw=bw, tw=tw, backend=backend, interpret=interpret,
                   dtype=jnp.dtype(dtype).name, max_batch=max_batch,
                   unroll=unroll, compute_uv=compute_uv)

    @classmethod
    def of(cls, config: "PipelineConfig | None", *, bw: int | None = None,
           tw: int | None = None, backend: str = "auto", dtype=jnp.float32,
           n: int | None = None) -> "PipelineConfig":
        """Adopt an already-resolved config, or resolve the legacy kwargs.

        Passing BOTH a config and a conflicting legacy kwarg (or input dtype)
        raises — the config is supposed to be the single source of truth, and
        silently preferring either side would mask the mistake at the call
        site.  The returned config is ``kernel()``-normalized (it feeds the
        jit static args of the numeric path).
        """
        if config is not None:
            if bw is not None and bw != config.bw:
                raise ValueError(f"bw={bw} conflicts with config.bw={config.bw}")
            if tw is not None and tw != config.tw:
                raise ValueError(f"tw={tw} conflicts with config.tw={config.tw}")
            if backend not in ("auto", config.backend):
                raise ValueError(f"backend={backend!r} conflicts with "
                                 f"config.backend={config.backend!r}")
            if dtype is not None and jnp.dtype(dtype).name != config.dtype:
                raise ValueError(f"input dtype {jnp.dtype(dtype).name} "
                                 f"conflicts with config.dtype={config.dtype}")
            return config.kernel()
        return cls.resolve(bw=bw if bw is not None else 32, tw=tw,
                           backend=backend, dtype=dtype, n=n).kernel()
