"""Hyperparameter heuristics and the occupancy/performance model (paper §III-C/D).

The paper exposes three knobs — inner tilewidth TW, threads-per-block TPB, and
max concurrent blocks — and shows (Fig. 4) that the dominant one is TW, whose
optimum matches the cache-line width (32 for fp32, 16 for fp64 on 128-byte
lines).  The TPU translation:

* TW         -> still the dominant knob.  The analogue of "fill one cache line"
               is "fill one 128-lane vreg row": reflector length TW+1 padded to
               the lane count.  bf16 packs 2/lane-row, fp32 1.
* TPB        -> ROWS_PER_STEP: how many band rows one grid step applies the
               reflector to per VREG pass (sublane tiling, multiples of 8).
* max blocks -> MAX_CONCURRENT_SWEEPS per core (wavefront width hosted by one
               TensorCore's grid) — beyond it, sweeps serialize in the grid,
               trading occupancy for VMEM locality exactly like the paper's
               software loop unrolling.

The occupancy model (paper Eq. 1): full utilization needs
``n / (3 * CBW) >= execution_units``; for a TPU pod the execution unit is a
TensorCore (2 per chip on v5e-class parts).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

__all__ = [
    "default_tilewidth", "rows_per_step", "max_concurrent_sweeps",
    "occupancy_matrix_size", "vmem_working_set_bytes", "ChaseConfig",
]

LANE = 128          # TPU vector lane count
SUBLANE = 8         # TPU sublane count (f32)


def _bytes(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def default_tilewidth(bw: int, dtype=jnp.float32) -> int:
    """Paper Fig. 4: optimal TW fills one cache line; TPU: one lane row.

    Reflector length TW+1; we pick TW so the VMEM window stays small while the
    per-row apply saturates lanes.  Capped at bw-1 (cannot peel more than the
    band).  fp32 -> 32, bf16 -> 64, fp64 (CPU oracle) -> 16, matching the
    paper's per-precision optima scaled to the TPU lane granularity.
    """
    per_line = 128 // _bytes(dtype)      # elements per 128B GPU cache line
    tw = max(8, min(per_line, LANE // 2))
    return max(1, min(tw, bw - 1))


def rows_per_step(b_in: int, tw: int, dtype=jnp.float32) -> int:
    """TPB analogue: rows applied per VREG pass, sublane-aligned."""
    rows = b_in + tw + 1
    return min(64, max(SUBLANE, SUBLANE * (rows // SUBLANE)))


def max_concurrent_sweeps(n: int, b_in: int) -> int:
    """Wavefront width (paper: #blocks): ceil(n / (3*CBW - 1)) + 1 slots."""
    return max(1, -(-n // (3 * b_in - 1)) + 1)


def occupancy_matrix_size(cbw: int, execution_units: int) -> int:
    """Paper Eq. 1 / Table I: min n saturating all execution units."""
    return 3 * cbw * execution_units


def vmem_working_set_bytes(b_in: int, tw: int, dtype=jnp.float32) -> int:
    """One chase window (H x W) + reflectors, as staged in VMEM."""
    h = b_in + 2 * tw + 1
    w = b_in + tw + 1
    return (h * w + 2 * (tw + 1)) * _bytes(dtype)


@dataclasses.dataclass(frozen=True)
class ChaseConfig:
    """Resolved hyperparameters for one reduction stage."""
    b_in: int
    tw: int
    rows_per_step: int
    max_sweeps: int

    @staticmethod
    def resolve(n: int, b_in: int, dtype=jnp.float32, tw: int | None = None
                ) -> "ChaseConfig":
        tw = tw if tw is not None else default_tilewidth(b_in, dtype)
        return ChaseConfig(
            b_in=b_in, tw=tw,
            rows_per_step=rows_per_step(b_in, tw, dtype),
            max_sweeps=max_concurrent_sweeps(n, b_in),
        )
