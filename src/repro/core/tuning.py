"""Hyperparameter heuristics and the occupancy/performance model (paper §III-C/D).

The paper exposes three knobs — inner tilewidth TW, threads-per-block TPB, and
max concurrent blocks — and shows (Fig. 4) that the dominant one is TW, whose
optimum matches the cache-line width (32 for fp32, 16 for fp64 on 128-byte
lines).  The TPU translation:

* TW         -> still the dominant knob.  The analogue of "fill one cache line"
               is "fill one 128-lane vreg row": reflector length TW+1 padded to
               the lane count.  bf16 packs 2/lane-row, fp32 1.
* TPB        -> ROWS_PER_STEP: how many band rows one grid step applies the
               reflector to per VREG pass (sublane tiling, multiples of 8).
* max blocks -> MAX_CONCURRENT_SWEEPS per core (wavefront width hosted by one
               TensorCore's grid) — beyond it, sweeps serialize in the grid,
               trading occupancy for VMEM locality exactly like the paper's
               software loop unrolling.

The occupancy model (paper Eq. 1): full utilization needs
``n / (3 * CBW) >= execution_units``; for a TPU pod the execution unit is a
TensorCore (2 per chip on v5e-class parts).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

__all__ = [
    "default_tilewidth", "rows_per_step", "sweep_separation",
    "max_concurrent_sweeps", "occupancy_matrix_size",
    "vmem_working_set_bytes", "default_fuse_depth", "check_vmem_budget",
    "fused_working_set_bytes", "check_fused_vmem_budget",
    "DEFAULT_FUSED_CROSSOVER", "STAGE3_CHOICES",
    "stage_plan", "default_bucket_batch", "ChaseConfig", "PipelineConfig",
]

LANE = 128          # TPU vector lane count
SUBLANE = 8         # TPU sublane count (f32)
VMEM_BUDGET_BYTES = 16 * 2 ** 20   # per-TensorCore VMEM (v4/v5-class parts)


def _bytes(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def default_tilewidth(bw: int, dtype=jnp.float32) -> int:
    """Paper Fig. 4: optimal TW fills one cache line; TPU: one lane row.

    Reflector length TW+1; we pick TW so the VMEM window stays small while the
    per-row apply saturates lanes.  Capped at bw-1 (cannot peel more than the
    band).  fp32 -> 32, bf16 -> 64, fp64 (CPU oracle) -> 16, matching the
    paper's per-precision optima scaled to the TPU lane granularity.
    """
    per_line = 128 // _bytes(dtype)      # elements per 128B GPU cache line
    tw = max(8, min(per_line, LANE // 2))
    return max(1, min(tw, bw - 1))


def rows_per_step(b_in: int, tw: int, dtype=jnp.float32) -> int:
    """TPB analogue: rows applied per VREG pass, sublane-aligned."""
    rows = b_in + tw + 1
    return min(64, max(SUBLANE, SUBLANE * (rows // SUBLANE)))


def sweep_separation(fuse: int = 1) -> int:
    """Sweep-start separation, in (super-)cycles, for fuse depth K.

    Concurrent fused windows are disjoint iff the pivot stride between
    adjacent in-flight sweeps, ``sep*K*b_in - 1``, is at least the fused
    window width ``W_K = K*b_in + tw + 1``.  K = 1 keeps the paper's 3-cycle
    rule (``3*b_in - 1 >= b_in + tw + 1`` for every valid ``tw <= b_in - 1``
    — strictly stronger than the bound requires when ``tw <= b_in - 2``, but
    it is the published schedule and the bit-exact baseline).  For K >= 2 a
    separation of 2 already suffices unconditionally:

        2*K*b_in - 1 >= K*b_in + tw + 1  <=>  K*b_in >= tw + 2,

    and ``K >= 2, b_in >= tw + 1`` give ``K*b_in >= 2*tw + 2 >= tw + 2``.
    ``tests/test_batched.py`` asserts the disjointness exhaustively for
    K in {1, 2, 4, 8}.
    """
    assert fuse >= 1, fuse
    return 3 if fuse == 1 else 2


def max_concurrent_sweeps(n: int, b_in: int, fuse: int = 1,
                          tw: int | None = None) -> int:
    """Wavefront width (paper: #blocks) for one stage.

    ``fuse=1`` is the paper's Eq.-1 analogue ``ceil(n / (3*CBW - 1)) + 1``
    (pivot-stride bound).  Fused super-steps advance K cycles per dispatch,
    so a sweep lives for only ``dur = ceil((j_max + 1)/K)`` super-cycles and
    slot ``g = js // sep`` never exceeds ``(dur - 1) // sep`` — a much
    tighter bound than the stride formula when K divides the sweep length
    down.  The tight bound needs the sweep length, hence ``tw`` (``b_out =
    b_in - tw`` fixes ``j_max``); it is what keeps the fused wavefront from
    carrying dead slots whose K windows would be chased and discarded.
    """
    if fuse == 1 or tw is None:
        stride = sweep_separation(fuse) * fuse * b_in - 1
        return max(1, -(-n // stride) + 1)
    j_max0 = max((n - 1 - (b_in - tw)) // b_in, 0)
    dur0 = -(-(j_max0 + 1) // fuse)
    return max(1, (dur0 - 1) // sweep_separation(fuse) + 1)


def occupancy_matrix_size(cbw: int, execution_units: int) -> int:
    """Paper Eq. 1 / Table I: min n saturating all execution units."""
    return 3 * cbw * execution_units


def vmem_working_set_bytes(b_in: int, tw: int, dtype=jnp.float32, *,
                           fuse: int = 1, tape: bool = False) -> int:
    """Per-slot VMEM working set of one chase super-step (paper §III-C).

    Counts everything one grid step keeps resident while chasing ``fuse``
    consecutive cycles:

    * the streamed band block ``(H, W_K)``, ``W_K = fuse*b_in + tw + 1``,
      **x2** for the double-buffered BlockSpec pipeline (Pallas prefetches
      step i+1's block while step i computes — the TPU analogue of the
      paper's L1 residency);
    * for ``fuse > 1``, the in-kernel rolled dense scratch
      ``(H + W_K - 1, W_K)`` (the shear workspace the fused kernel chases
      in — see kernels/bulge_chase.py);
    * one reflector pair per fused cycle;
    * with ``tape=True``, the double-buffered tape output blocks
      (``fuse`` pairs of ``(v, tau)`` per slot).

    Monotone in ``fuse`` — the knob ``default_fuse_depth`` searches.
    """
    h = b_in + 2 * tw + 1
    wk = fuse * b_in + tw + 1
    words = 2 * h * wk                       # double-buffered streamed block
    if fuse > 1:
        words += (h + wk - 1) * wk           # rolled dense scratch (shear)
    words += fuse * 2 * (tw + 1)             # reflector pairs
    if tape:
        words += 2 * fuse * 2 * (tw + 2)     # double-buffered (v, tau) blocks
    return words * _bytes(dtype)


def default_fuse_depth(b_in: int, tw: int, dtype=jnp.float32, *,
                       budget_bytes: int | None = None, tape: bool = False,
                       cap: int = 8) -> int:
    """Largest fuse depth K whose super-step working set fits the per-core
    VMEM budget (the paper's performance-model-guided tuning, §III-D,
    applied to the fuse knob).

    ``budget_bytes`` defaults to half of ``VMEM_BUDGET_BYTES`` — the other
    half is headroom for Pallas pipeline state and compiler spills.  Falls
    back to K = 1 when even K = 2 does not fit (the K = 1 path streams
    pre-rolled windows and needs no dense scratch).  The floor is HARD:
    under any budget — zero, negative, or a cap < 1 — the answer is 1,
    never 0 (a 0-depth schedule would execute no cycles and silently
    return the input band; whether even K = 1 is *feasible* is the
    separate ``check_vmem_budget`` guard that ``resolve`` runs).

    Scope: the model maximizes fast-memory residency per dispatch (the
    paper's axis), not wall-clock on a given host — launches stop falling
    past K = 2 (2*nsweeps super-cycles) while per-launch block width keeps
    growing, so on the CPU ref path the measured optimum can be a shallower
    K than the deepest that fits (see BENCH_stage2.json: K=2 beats K=4 at
    n=1024, bw=32).  Treat the result as the residency-feasible ceiling and
    ``benchmarks/fusion.py`` as the measured curve to pick from.
    """
    budget = VMEM_BUDGET_BYTES // 2 if budget_bytes is None else budget_bytes
    best = 1
    for cand in range(2, max(cap, 1) + 1):
        if vmem_working_set_bytes(b_in, tw, dtype, fuse=cand,
                                  tape=tape) <= budget:
            best = cand
    return max(best, 1)


def check_vmem_budget(b_in: int, tw: int, dtype=jnp.float32, *,
                      tape: bool = False,
                      budget_bytes: int | None = None) -> int:
    """Raise (clearly) when even the UNFUSED working set misses the budget.

    ``default_fuse_depth`` degrades gracefully to K = 1, but when
    ``vmem_working_set_bytes(b_in, tw, fuse=1)`` itself exceeds the budget
    there is no depth to retreat to — proceeding would silently mis-tile
    (the kernel's window could never be fast-memory resident, the exact
    regime the paper's model exists to exclude).  Called by
    ``ChaseConfig.resolve`` / ``PipelineConfig.resolve``; returns the
    working-set bytes on success so callers can report headroom.
    """
    budget = VMEM_BUDGET_BYTES if budget_bytes is None else budget_bytes
    need = vmem_working_set_bytes(b_in, tw, dtype, fuse=1, tape=tape)
    if need > budget:
        raise ValueError(
            f"chase window working set for b_in={b_in}, tw={tw}, "
            f"dtype={jnp.dtype(dtype).name} (tape={tape}) needs {need} B "
            f"of fast memory at fuse=1 but the budget is {budget} B; "
            f"reduce the tilewidth/bandwidth (tw <= {tw} shrinks the "
            f"window H x W = (b_in + 2*tw + 1) x (b_in + tw + 1)) or "
            f"raise budget_bytes")
    return need


# Default fused-vs-staged crossover (DESIGN.md §13): the ROADMAP names
# n <= 256 as the launch-bound serve regime; the autotuner's measured
# crossover (autotune.search.search_fused_crossover, persisted per
# device/dtype) replaces this when available.
DEFAULT_FUSED_CROSSOVER = 256

# Stage-3 solver policy values (DESIGN.md §14).  "bisect" is the lockstep
# Sturm bisection (O(n^2) work, bit-stable oracle), "dc" the batched
# divide-and-conquer solve (O(n log n) secular merges — wins for large n),
# "auto" picks per problem size via ``PipelineConfig.stage3_for``.
STAGE3_CHOICES = ("bisect", "dc", "auto")


def fused_working_set_bytes(n: int, dtype=jnp.float32, *,
                            compute_uv: bool = False) -> int:
    """VMEM bytes one fused_small grid step keeps resident (DESIGN.md §13).

    The whole (n, n) matrix lives in VMEM for the kernel's lifetime; the
    reflector scratch is a handful of (n,) vectors plus the (m = 2n-1)
    bisection state; ``compute_uv`` adds the two (n, n) transform
    accumulators.  Pallas double-buffers the block pipeline, hence the
    factor 2 on the streamed operands.
    """
    s = _bytes(dtype)
    mats = (3 if compute_uv else 1) * n * n
    scratch = 12 * n
    return 2 * mats * s + scratch * s


def check_fused_vmem_budget(n: int, dtype=jnp.float32, *,
                            compute_uv: bool = False,
                            budget_bytes: int | None = None) -> int:
    """Raise when one matrix cannot be VMEM-resident for the fused kernel.

    The fused tier has no fallback tiling — its whole point is the matrix
    never leaving fast memory — so an oversized n must be rejected up front
    (the engines then keep such buckets on the staged path).  Returns the
    working-set bytes on success.
    """
    budget = VMEM_BUDGET_BYTES if budget_bytes is None else budget_bytes
    need = fused_working_set_bytes(n, dtype, compute_uv=compute_uv)
    if need > budget:
        raise ValueError(
            f"fused_small working set for n={n}, "
            f"dtype={jnp.dtype(dtype).name} (compute_uv={compute_uv}) "
            f"needs {need} B of fast memory but the budget is {budget} B; "
            f"route this bucket to the staged pipeline instead")
    return need


def stage_plan(bw: int, tw: int) -> tuple[tuple[int, int], ...]:
    """Tile-width schedule: ((b_in, tw_i), ...) reducing bw -> 1, <= tw/stage."""
    plan = []
    b = bw
    while b > 1:
        twi = min(tw, b - 1)
        plan.append((b, twi))
        b -= twi
    return tuple(plan)


def default_bucket_batch(n: int, b_in: int, execution_units: int = 2,
                         oversub: int = 8) -> int:
    """Batch size that refills the wavefront when one matrix cannot (Eq. 1).

    A single matrix hosts ``max_concurrent_sweeps(n, b_in)`` concurrent
    windows; full utilization wants at least one per execution unit (paper
    Eq. 1), and ``oversub``x that to hide the gather/scatter latency between
    cycles (the paper's concurrent-blocks headroom).  Independent problems in
    a batch multiply the wavefront width, so the deficit is made up by
    batching.  Clamped to [1, 64].
    """
    per_matrix = max_concurrent_sweeps(n, b_in)
    want = execution_units * oversub
    return max(1, min(64, -(-want // per_matrix)))


@dataclasses.dataclass(frozen=True)
class ChaseConfig:
    """Resolved hyperparameters for one reduction stage."""
    b_in: int
    tw: int
    rows_per_step: int
    max_sweeps: int

    @staticmethod
    def resolve(n: int, b_in: int, dtype=jnp.float32, tw: int | None = None
                ) -> "ChaseConfig":
        tw = tw if tw is not None else default_tilewidth(b_in, dtype)
        check_vmem_budget(b_in, tw, dtype)
        return ChaseConfig(
            b_in=b_in, tw=tw,
            rows_per_step=rows_per_step(b_in, tw, dtype),
            max_sweeps=max_concurrent_sweeps(n, b_in),
        )


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Fully-resolved configuration for the three-stage pipeline.

    Extends :class:`ChaseConfig` from one reduction stage to the whole
    pipeline: it owns the concrete kernel backend (resolved once through the
    registry in ``kernels/ops.py`` — no "auto" strings survive resolution),
    the tile-width schedule ``bw -> 1``, and the serve-layer batch/bucket
    sizes.  It is hashable (all-primitive fields), so it can be a static jit
    argument, and it is the ONE object every layer accepts: ``core/svd.py``,
    ``core/bulge_chasing.py``, ``core/stage1.py``, ``kernels/ops.py`` and
    ``serve/engine.py`` all take ``config=`` instead of loose
    ``backend=``/``tw=`` strings (the legacy kwargs remain as overrides).
    """
    bw: int                     # stage-1 output / stage-2 input bandwidth
    tw: int                     # inner tilewidth (dominant knob, paper Fig. 4)
    backend: str                # concrete registry key ("ref", "pallas", ...)
    interpret: bool             # Pallas interpret mode (CPU correctness runs)
    dtype: str = "float32"      # working precision of stages 1-2
    max_batch: int = 8          # serve bucket capacity (leading batch axis B)
    unroll: int = 1             # fori_loop unroll of the wavefront stage
    compute_uv: bool = False    # full SVD: record + replay reflector tapes
    fuse: int = 1               # chase super-step depth K (cycles per launch)
    stage3: str = "bisect"      # bidiagonal solver: "bisect" | "dc" | "auto"
    dc_leaf_n: int = 32         # D&C recursion floor (leaves solve by bisection)
    dc_n_min: int = 2048        # "auto" routes n >= dc_n_min to "dc"

    @property
    def plan(self) -> tuple[tuple[int, int], ...]:
        """The tile-width schedule ((b_in, tw_i), ...) down to bidiagonal."""
        return stage_plan(self.bw, self.tw)

    def stage3_for(self, n: int) -> str:
        """Concrete stage-3 solver for a problem of size n.

        ``stage3="auto"`` survives :meth:`resolve` only when no ``n`` was
        known at resolution time (serve engines size buckets later); this is
        where it collapses: "dc" iff ``n >= dc_n_min`` (the measured or
        default crossover), else "bisect".  Explicit policies pass through.
        """
        if self.stage3 != "auto":
            return self.stage3
        return "dc" if n >= self.dc_n_min else "bisect"

    def kernel(self) -> "PipelineConfig":
        """Identity for the traced computation: serve-only fields (max_batch)
        are normalized so configs differing only in bucket sizing share one
        jit cache entry instead of recompiling the numeric pipeline."""
        return dataclasses.replace(self, max_batch=0)

    def chase(self, n: int, b_in: int | None = None) -> ChaseConfig:
        """Per-stage view (the legacy ChaseConfig) for a given problem size."""
        return ChaseConfig.resolve(n, b_in if b_in is not None else self.bw,
                                   jnp.dtype(self.dtype), tw=self.tw)

    @classmethod
    def resolve(cls, *, bw: int = 32, tw: int | None = None,
                backend: str = "auto", interpret: bool | None = None,
                dtype=jnp.float32, n: int | None = None,
                max_batch: int | None = None, unroll: int = 1,
                compute_uv: bool = False,
                fuse: int | None = 1, autotune: bool = False,
                autotune_cache: str | None = None,
                stage3: str = "bisect", dc_leaf_n: int | None = None,
                dc_n_min: int | None = None) -> "PipelineConfig":
        """Resolve every knob to a concrete value.

        ``backend="auto"`` and ``interpret=None`` are resolved by the backend
        registry (pallas on TPU, ref elsewhere; interpret off-TPU only);
        ``tw=None`` falls back to the cache-line/lane heuristic;
        ``max_batch=None`` uses the Eq.-1 occupancy deficit for (n, bw);
        ``fuse=None`` asks the VMEM model for the deepest super-step that
        fits (``default_fuse_depth``), ``fuse=1`` (the default) keeps the
        paper's one-launch-per-cycle schedule.
        ``bw`` is clamped to >= 1 (bw = 0 — e.g. a 1x1 problem — would zero
        the stage-1 panel width; a bw-1 "band" is already bidiagonal, so
        stage 2 is a no-op pass-through either way).  A (bw, tw) pair whose
        unfused chase window cannot be fast-memory resident raises
        (``check_vmem_budget``) instead of silently mis-tiling.

        ``autotune=True`` (DESIGN.md §11) consults the persistent tuned
        cache (``repro.autotune.cache``, keyed by device kind, n, bw,
        dtype, compute_uv and the RESOLVED backend) and uses the measured
        optimum for every knob still at its neutral default — ``tw=None``,
        ``fuse`` in (None, 1), ``max_batch=None``; explicit values always
        win.  On a cache miss (or without ``n``) the analytic defaults
        above apply unchanged.  ``autotune_cache`` overrides the cache
        path (else ``$REPRO_AUTOTUNE_CACHE`` / the XDG default).

        ``stage3`` picks the bidiagonal solver (DESIGN.md §14): "bisect"
        (the default — the lockstep Sturm oracle), "dc" (the batched
        divide-and-conquer solve of ``core.bidiag_dc``), or "auto" — "dc"
        iff ``n >= dc_n_min``.  ``dc_n_min=None`` takes the measured
        stage-3 crossover from the autotune cache when ``autotune=True``
        (``cache.lookup_stage3``), else the static default
        ``core.bidiag_dc.DEFAULT_DC_N_MIN``; ``dc_leaf_n=None`` means
        ``DEFAULT_DC_LEAF_N``.  With ``n`` known "auto" collapses here; on
        an n-free resolve the string survives and :meth:`stage3_for`
        collapses it per problem size (the serve engines' per-bucket path).
        """
        from repro.kernels import ops  # deferred: registry lives kernels-side

        bw = max(bw, 1)
        if n is not None:
            bw = min(bw, max(n, 1))
        backend, interpret = ops.resolve_backend(backend, interpret)
        tuned = None
        if autotune and n is not None:
            from repro.autotune import cache as _at_cache   # deferred: cycle
            from repro.autotune import model as _at_model
            tuned = _at_cache.lookup(
                device_kind=_at_model.device_kind(), n=n, bw=bw,
                dtype=jnp.dtype(dtype).name, compute_uv=compute_uv,
                backend=backend, path=autotune_cache)
        if tuned is not None:
            tw = tw if tw is not None else tuned["tw"]
            fuse = fuse if fuse not in (None, 1) else tuned["fuse"]
            if max_batch is None:
                # max_batch is only in the entry when the search actually
                # explored the batch axis; otherwise the Eq.-1 analytic
                # default below stays in charge of bucket sizing.
                max_batch = tuned.get("max_batch")
        tw = tw if tw is not None else default_tilewidth(bw, dtype)
        tw = max(1, min(tw, max(bw - 1, 1)))
        check_vmem_budget(bw, tw, dtype, tape=compute_uv)
        if backend == "fused_small" and n is not None:
            # the fused tier keeps the whole matrix VMEM-resident: infeasible
            # n must fail here, not silently spill inside the kernel
            check_fused_vmem_budget(n, dtype, compute_uv=compute_uv)
        if max_batch is None:
            max_batch = default_bucket_batch(n, bw) if n else 8
        if fuse is None:
            fuse = default_fuse_depth(bw, tw, dtype, tape=compute_uv)
        if stage3 not in STAGE3_CHOICES:
            raise ValueError(f"stage3 must be one of {STAGE3_CHOICES}, "
                             f"got {stage3!r}")
        from repro.core import bidiag_dc as _dc   # deferred: import cycle
        if dc_leaf_n is None:
            dc_leaf_n = _dc.DEFAULT_DC_LEAF_N
        if dc_n_min is None:
            tuned_x = None
            if autotune:
                from repro.autotune import cache as _at_cache
                from repro.autotune import model as _at_model
                tuned_x = _at_cache.lookup_stage3(
                    device_kind=_at_model.device_kind(),
                    dtype=jnp.dtype(dtype).name, compute_uv=compute_uv,
                    path=autotune_cache)
            dc_n_min = tuned_x if tuned_x is not None else _dc.DEFAULT_DC_N_MIN
        dc_leaf_n = max(int(dc_leaf_n), 1)
        dc_n_min = max(int(dc_n_min), 1)
        if stage3 == "auto" and n is not None:
            stage3 = "dc" if n >= dc_n_min else "bisect"
        return cls(bw=bw, tw=tw, backend=backend, interpret=interpret,
                   dtype=jnp.dtype(dtype).name, max_batch=max_batch,
                   unroll=unroll, compute_uv=compute_uv,
                   fuse=max(int(fuse), 1), stage3=stage3,
                   dc_leaf_n=dc_leaf_n, dc_n_min=dc_n_min)

    @classmethod
    def of(cls, config: "PipelineConfig | None", *, bw: int | None = None,
           tw: int | None = None, backend: str = "auto", dtype=jnp.float32,
           n: int | None = None) -> "PipelineConfig":
        """Adopt an already-resolved config, or resolve the legacy kwargs.

        Passing BOTH a config and a conflicting legacy kwarg (or input dtype)
        raises — the config is supposed to be the single source of truth, and
        silently preferring either side would mask the mistake at the call
        site.  The returned config is ``kernel()``-normalized (it feeds the
        jit static args of the numeric path).
        """
        if config is not None:
            if bw is not None and bw != config.bw:
                raise ValueError(f"bw={bw} conflicts with config.bw={config.bw}")
            if tw is not None and tw != config.tw:
                raise ValueError(f"tw={tw} conflicts with config.tw={config.tw}")
            if backend not in ("auto", config.backend):
                raise ValueError(f"backend={backend!r} conflicts with "
                                 f"config.backend={config.backend!r}")
            if dtype is not None and jnp.dtype(dtype).name != config.dtype:
                raise ValueError(f"input dtype {jnp.dtype(dtype).name} "
                                 f"conflicts with config.dtype={config.dtype}")
            return config.kernel()
        return cls.resolve(bw=bw if bw is not None else 32, tw=tw,
                           backend=backend, dtype=dtype, n=n).kernel()
