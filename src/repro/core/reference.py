"""Sequential numpy oracles for the band -> bidiagonal reduction (fp64).

These are the ground-truth implementations the JAX/Pallas paths are tested
against: full-range reflector applies, obviously orthogonally equivalent,
no scheduling cleverness.  They live apart from ``core/bulge_chasing.py``
so the hot module (jitted wavefront code) does not import numpy oracles;
``bulge_chasing`` re-exports them for back-compat.

* ``reduce_stage_dense_ref`` / ``bidiagonalize_dense_ref`` — values-only
  SBR oracle (paper Alg. 1, sequential).
* ``bidiagonalize_dense_ref_uv`` — the same chase with left/right transform
  accumulation (paper §VII future work): returns (d, e, U, V) with
  ``U^T A V == B``.  This is the oracle the reflector-tape pipeline
  (``core/transforms.py``) is verified against.
"""

from __future__ import annotations

import math

import numpy as np

__all__ = [
    "reduce_stage_dense_ref",
    "bidiagonalize_dense_ref",
    "bidiagonalize_dense_ref_uv",
]


def _np_reflector(x: np.ndarray):
    alpha = x[0]
    sigma = float(np.dot(x[1:], x[1:]))
    if sigma == 0.0:
        return None, 0.0, alpha
    mu = math.sqrt(alpha * alpha + sigma)
    beta = -mu if alpha >= 0 else mu
    tau = (beta - alpha) / beta
    v = np.concatenate([[1.0], x[1:] / (alpha - beta)])
    return v, tau, beta


def reduce_stage_dense_ref(a: np.ndarray, b_in: int, tw: int) -> np.ndarray:
    """One SBR stage, sequential, full-range applies. a: (n, n) float64."""
    a = np.array(a, dtype=np.float64)
    n = a.shape[0]
    b_out = b_in - tw
    assert b_out >= 1
    for R in range(0, max(n - 1 - b_out, 0)):
        p = R + b_out
        r = R
        while p <= n - 1:
            hi = min(p + tw + 1, n)
            # right reflector: annihilate a[r, p+1:hi]
            v, tau, beta = _np_reflector(a[r, p:hi])
            if tau != 0.0:
                w = a[:, p:hi] @ v
                a[:, p:hi] -= tau * np.outer(w, v)
                a[r, p + 1 : hi] = 0.0
                a[r, p] = beta
            # left reflector: annihilate a[p+1:hi, p]
            v, tau, beta = _np_reflector(a[p:hi, p])
            if tau != 0.0:
                w = v @ a[p:hi, :]
                a[p:hi, :] -= tau * np.outer(v, w)
                a[p + 1 : hi, p] = 0.0
                a[p, p] = beta
            r = p
            p = p + b_in
    return a


def bidiagonalize_dense_ref(a: np.ndarray, bw: int, tw: int):
    """Full SBR to bidiagonal: stages bw -> bw-tw -> ... -> 1. Returns (d, e, A)."""
    a = np.array(a, dtype=np.float64)
    b = bw
    while b > 1:
        twi = min(tw, b - 1)
        a = reduce_stage_dense_ref(a, b, twi)
        b -= twi
    n = a.shape[0]
    d = np.diagonal(a).copy()
    e = np.diagonal(a, 1).copy()
    return d, e, a


def bidiagonalize_dense_ref_uv(a: np.ndarray, bw: int, tw: int):
    """SBR with transform accumulation: A = U B V^T with B bidiagonal.

    The paper computes singular values only and names vector accumulation as
    future work (§VII); this oracle-level extension accumulates the left/right
    reflector products alongside the chase (each chase reflector also updates
    U's columns / V's columns — O(n * tw) extra per cycle, the same wavefront
    parallelism applies).  Returns (d, e, U, V) with U^T A V == B.
    """
    a = np.array(a, dtype=np.float64)
    n = a.shape[0]
    u = np.eye(n)
    v = np.eye(n)
    b = bw
    while b > 1:
        twi = min(tw, b - 1)
        b_out = b - twi
        for R in range(0, max(n - 1 - b_out, 0)):
            p = R + b_out
            r = R
            while p <= n - 1:
                hi = min(p + twi + 1, n)
                vec, tau, beta = _np_reflector(a[r, p:hi])
                if tau != 0.0:
                    w = a[:, p:hi] @ vec
                    a[:, p:hi] -= tau * np.outer(w, vec)
                    a[r, p + 1 : hi] = 0.0
                    a[r, p] = beta
                    wv = v[:, p:hi] @ vec
                    v[:, p:hi] -= tau * np.outer(wv, vec)
                vec, tau, beta = _np_reflector(a[p:hi, p])
                if tau != 0.0:
                    w = vec @ a[p:hi, :]
                    a[p:hi, :] -= tau * np.outer(vec, w)
                    a[p + 1 : hi, p] = 0.0
                    a[p, p] = beta
                    wu = u[:, p:hi] @ vec
                    u[:, p:hi] -= tau * np.outer(wu, vec)
                r = p
                p = p + b
        b -= twi
    d = np.diagonal(a).copy()
    e = np.diagonal(a, 1).copy()
    return d, e, u, v
