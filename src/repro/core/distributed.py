"""Distributed spectral computation (the framework-facing face of the paper).

Production use: during training we need singular values for *many* weight
matrices at once (spectral monitoring, low-rank gradient compression).  The
natural mapping at pod scale is **batch dispatch**: each device owns a slice of
the matrix batch and runs the full three-stage pipeline locally — zero
collectives during the chase (the paper's single-GPU residency argument,
lifted to one-matrix-per-core), one gather at the end.

``sharded_singular_values`` shard_maps over the mesh's data axes;
``spectrum_of_params`` walks a parameter pytree, groups same-shape matrices,
and returns per-leaf spectra.  Matrices are padded/truncated to a common
square size per group (spectral monitoring uses the top-k values, which
square padding preserves: sigma(pad(A)) = sigma(A) plus zeros).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import obs
from repro.core import svd as svdmod

__all__ = ["batched_singular_values", "sharded_singular_values",
           "sharded_svd", "sharded_pipeline_dispatch", "shard_pad",
           "spectrum_of_params", "square_embed", "process_info"]


def process_info() -> tuple[int, int]:
    """``(process_index, process_count)`` under multi-process JAX, or
    ``(0, 1)`` on any jax predating (or unconfigured for) the
    distributed runtime — callers (worker hello frames, mesh builders,
    DESIGN.md §17) never need their own hasattr dance."""
    try:
        return int(jax.process_index()), int(jax.process_count())
    except Exception:                        # noqa: BLE001 — single process
        return 0, 1


def square_embed(w: jax.Array, size: int) -> jax.Array:
    """Embed/crop a (m, k) matrix into (size, size); sigma is preserved for
    size >= max(m, k) (padding adds zero singular values only)."""
    m, k = w.shape
    if m < k:                       # sigma(A) == sigma(A^T); keep tall
        w = w.T
        m, k = k, m
    w = w[:size, :size]
    out = jnp.zeros((size, size), w.dtype)
    return out.at[: w.shape[0], : w.shape[1]].set(w)


def batched_singular_values(mats: jax.Array, *, bw: int | None = None,
                            tw: int | None = None, backend: str = "auto",
                            config=None, compute_uv: bool = False):
    """Batch-native three-stage pipeline: (B, n, n) -> (B, n) descending sigma.

    Delegates to ``core.svd`` (one fused wavefront over all B chases — the
    former vmapped-loop formulation is subsumed).  ``compute_uv=True``
    returns ``(U, sigma, V^T)`` via the reflector-tape pipeline.
    """
    if compute_uv:
        return svdmod.svd_batched(mats, config=config, compute_uv=True,
                                  bw=bw, tw=tw, backend=backend)
    return svdmod.batched_singular_values(mats, bw=bw, tw=tw, backend=backend,
                                          config=config)


def sharded_singular_values(mats: jax.Array, mesh: Mesh, *, bw: int = 32,
                            tw: int | None = None, backend: str = "auto",
                            batch_axes: tuple[str, ...] = ("data",),
                            compute_uv: bool = False, config=None):
    """Batch-dispatch spectra across the mesh: (B, n, n) -> (B, n).

    B must be divisible by the product of ``batch_axes`` sizes; each device
    group computes its matrices fully locally (GPU-residency -> core-residency).
    With ``compute_uv=True`` each shard additionally replays its reflector
    tapes locally — vector accumulation needs no collectives either (one
    matrix never crosses a core) — returning sharded ``(U, sigma, V^T)``.
    """
    if config is not None:
        # The resolved config is the single source of truth; dropping the
        # loose kwargs here keeps PipelineConfig.of's conflict check from
        # tripping on this function's own defaults.
        bw, tw, backend = None, None, "auto"
    spec = P(batch_axes)
    fn = functools.partial(batched_singular_values, bw=bw, tw=tw,
                           backend=backend, compute_uv=compute_uv,
                           config=config)
    out_specs = (spec, spec, spec) if compute_uv else spec
    shard_fn = jax.shard_map(fn, mesh=mesh, in_specs=(spec,),
                             out_specs=out_specs, check_vma=False)
    return shard_fn(mats)


def sharded_svd(mats: jax.Array, mesh: Mesh, *, bw: int = 32,
                tw: int | None = None, backend: str = "auto",
                batch_axes: tuple[str, ...] = ("data",)):
    """Full SVD batch-dispatched across the mesh: (B, n, n) ->
    ``(U (B, n, n), sigma (B, n), V^T (B, n, n))``, batch-sharded."""
    return sharded_singular_values(mats, mesh, bw=bw, tw=tw, backend=backend,
                                   batch_axes=batch_axes, compute_uv=True)


def shard_pad(b: int, shards: int) -> int:
    """Rows to append so a batch of ``b`` splits evenly over ``shards``."""
    assert shards >= 1, shards
    return (-b) % shards


def sharded_pipeline_dispatch(mats: jax.Array, mesh: Mesh, *, config,
                              banded: bool = False, compute_uv: bool = False,
                              batch_axes: tuple[str, ...] = ("data",),
                              faults=None, on_shard_retry=None):
    """Serve-tier mesh dispatch (DESIGN.md §12): pad the leading batch axis
    to shard divisibility, run the bucket's exact pipeline batch-sharded —
    every device chases its own sub-batch fully locally, zero collectives —
    and slice the padding back off the gathered result.

    ``config`` is the bucket's resolved :class:`PipelineConfig` (it closes
    over the shard_map body as a static value, so one compilation per bucket
    key survives sharding) — its ``stage3`` policy rides along, so a
    "dc"/"auto" bucket runs the divide-and-conquer bidiagonal solve on every
    shard with no extra plumbing here.  Mirrors the four local dispatch
    modes of ``serve.SVDEngine``: ``(banded, compute_uv)`` selects among
    ``svd_batched`` / ``banded_singular_values`` / ``svd`` / ``banded_svd``.
    Padding rows are independent zero matrices — sigma(0) = 0 — and are
    dropped before anyone sees them.

    Device-drop handling (DESIGN.md §15): a raising sharded dispatch (a
    real device/mesh failure takes the whole ``shard_map`` call down) is
    re-dispatched UNSHARDED through the same per-shard pipeline body — one
    compilation of the same program at full batch — so the batch still
    completes on whatever is left.  A *simulated* per-shard loss
    (``faults``, a :class:`~repro.serve.faults.FaultPlan` whose
    ``lost_shards`` names the dropped shard indices) voids the lost
    shards' slices and re-dispatches exactly those slices through the SAME
    compiled sharded program (the lost slice is tiled across the mesh and
    the victim shard's lane is read back) — the re-dispatched slice is
    therefore bitwise-identical to what the clean run would have produced,
    which ``tests/test_serve_faults.py`` asserts.  Every re-dispatched
    shard (and the all-shards unsharded fallback) is reported through
    ``on_shard_retry(count)`` — the engines wire it to
    ``ServeMetrics.sharded_retries``.
    """
    shards = 1
    for ax in batch_axes:
        shards *= mesh.shape[ax]
    b0 = mats.shape[0]
    pad = shard_pad(b0, shards)
    if pad:
        mats = jnp.concatenate(
            [mats, jnp.zeros((pad,) + mats.shape[1:], mats.dtype)])

    def local(ms):
        if compute_uv:
            fn = svdmod.banded_svd if banded else svdmod.svd
            return fn(ms, config=config, compute_uv=True)
        if banded:
            return svdmod.banded_singular_values(ms, bw=config.bw,
                                                 config=config)
        return svdmod.svd_batched(ms, config=config)

    spec = P(batch_axes)
    out_specs = (spec, spec, spec) if compute_uv else spec
    fn = jax.shard_map(local, mesh=mesh, in_specs=(spec,),
                       out_specs=out_specs, check_vma=False)
    # Host span for the whole mesh dispatch (DESIGN.md §16); the shard_map
    # body itself runs under jit tracing, where spans no-op by design.
    with obs.span("sharded_dispatch", shards=shards, pad=pad, batch=int(b0),
                  n=int(mats.shape[-1]), banded=banded,
                  compute_uv=compute_uv) as dsp:
        try:
            out = fn(mats)
        except Exception:                        # noqa: BLE001 — mesh down
            # Real failure path: the sharded dispatch is gone as a unit.
            # Re-dispatch the whole batch unsharded (same pipeline body).
            if on_shard_retry is not None:
                on_shard_retry(shards)
            with obs.span("sharded_fallback_unsharded", shards=shards) as sp:
                out = local(mats)
                sp.fence(out)
            dsp.set(fallback="unsharded")
        else:
            lost = faults.lost_shards(shards) if faults is not None else []
            if lost:
                per = mats.shape[0] // shards
                parts = list(out) if compute_uv else [out]
                for j in sorted(set(lost)):
                    sl = slice(j * per, (j + 1) * per)
                    # Void the lost shard's slice (its device's results are
                    # gone), then recompute it through the SAME compiled
                    # sharded program: tile the slice across the mesh so
                    # shard j sees exactly the bytes it saw in the clean run
                    # -> bitwise-identical recovery.
                    reps = (shards,) + (1,) * (mats.ndim - 1)
                    with obs.span("shard_retry", shard=j) as sp:
                        rout = fn(jnp.tile(mats[sl], reps))
                        sp.fence(rout)
                    rparts = list(rout) if compute_uv else [rout]
                    for i, (arr, rarr) in enumerate(zip(parts, rparts)):
                        voided = arr.at[sl].set(jnp.nan)
                        parts[i] = voided.at[sl].set(rarr[sl])
                    if on_shard_retry is not None:
                        on_shard_retry(1)
                out = tuple(parts) if compute_uv else parts[0]
        dsp.fence(out)
    if compute_uv:
        u, sig, vt = out
        return u[:b0], sig[:b0], vt[:b0]
    return out[:b0]


def spectrum_of_params(params, *, size: int = 256, bw: int = 32,
                       tw: int | None = None, mesh: Mesh | None = None,
                       backend: str = "auto"):
    """Top spectra for every >=2D leaf of a parameter pytree.

    Returns a pytree of the same structure whose matrix leaves map to their
    length-``size`` singular value vectors (descending); other leaves -> None.
    Leaves with more than 2 dims are flattened on leading axes (e.g. stacked
    scan layers contribute their *per-layer* matrices batched).
    """
    leaves, treedef = jax.tree_util.tree_flatten(params)
    mats, slots = [], []
    for i, leaf in enumerate(leaves):
        if not hasattr(leaf, "ndim") or leaf.ndim < 2:
            continue
        w = leaf.reshape((-1,) + leaf.shape[-2:]) if leaf.ndim > 2 else leaf[None]
        for b in range(w.shape[0]):
            mats.append(square_embed(w[b], size))
            slots.append((i, w.shape[0]))
    if not mats:
        return jax.tree_util.tree_unflatten(treedef, [None] * len(leaves))
    batch = jnp.stack(mats)
    if mesh is not None:
        total = 1
        for ax in ("data",):
            total *= mesh.shape[ax]
        pad = (-batch.shape[0]) % total
        if pad:
            batch = jnp.concatenate([batch, jnp.zeros((pad,) + batch.shape[1:], batch.dtype)])
        sig = sharded_singular_values(batch, mesh, bw=bw, tw=tw, backend=backend)
        sig = sig[: len(mats)]
    else:
        sig = batched_singular_values(batch, bw=bw, tw=tw, backend=backend)
    out_leaves: list = [None] * len(leaves)
    k = 0
    for i, leaf in enumerate(leaves):
        if not hasattr(leaf, "ndim") or leaf.ndim < 2:
            continue
        nmat = 1 if leaf.ndim == 2 else int(jnp.prod(jnp.asarray(leaf.shape[:-2])))
        vals = sig[k : k + nmat]
        out_leaves[i] = vals[0] if leaf.ndim == 2 else vals.reshape(leaf.shape[:-2] + (size,))
        k += nmat
    return jax.tree_util.tree_unflatten(treedef, out_leaves)


# ---------------------------------------------------------------------------
# Distributed single-matrix chase (beyond-paper: the paper's §VI note that
# "the GPU algorithm could equally be extended to take advantage of multiple
# nodes").  The packed band is sharded column-wise; each device executes the
# wavefront windows whose pivots fall in its column block, with a W-column
# halo exchanged by collective_permute each cycle.  The 3-cycle separation
# guarantees at most ONE window crosses each shard boundary per cycle and
# that its writes are disjoint from the neighbor's own windows — the halo
# merge is therefore a static-masked overwrite (no reductions).
# ---------------------------------------------------------------------------

def reduce_stage_sharded(band: jax.Array, *, n: int, b_in: int, tw: int,
                         mesh: Mesh, axis: str = "data") -> jax.Array:
    """One SBR stage with the band column-sharded over ``axis``.

    band: (b_in + 2*tw + 1, ncols) with ncols % mesh.shape[axis] == 0 and
    ncols >= n + W.  Returns the same-sharded reduced band.
    """
    from jax.sharding import PartitionSpec as P
    from repro.core import bulge_chasing as bc
    from repro.kernels import ops

    d = mesh.shape[axis]
    h = b_in + 2 * tw + 1
    w = b_in + tw + 1
    assert band.shape[0] == h
    nsweeps, total, g_max = bc.stage_schedule(n, b_in, tw)
    if nsweeps == 0:
        return band
    ncols = band.shape[1]
    assert ncols % d == 0 and ncols >= n + w, (ncols, d, n, w)
    c = ncols // d
    assert c >= w, "shard width must cover one chase window"

    yy = jnp.arange(h)[:, None]
    ww_ = jnp.arange(w)[None, :]
    d_gather = jnp.clip(h - 1 + ww_ - yy, 0, h - 1)
    gather_valid = yy >= ww_
    dd = jnp.arange(h)[:, None]
    y_back = jnp.clip(h - 1 + ww_ - dd, 0, h - 1)
    back_valid = dd >= ww_
    g_idx = jnp.arange(g_max)

    def shard_fn(local):                       # local: (h, c) per device
        dev = jax.lax.axis_index(axis)
        lo = dev * c

        def cycle(t, local):
            # fresh halo: right neighbor's leading W columns (last device: 0s)
            head = local[:, :w]
            halo = jax.lax.ppermute(head, axis,
                                    [(i + 1, i) for i in range(d - 1)])
            dump = jnp.zeros((h, g_max * w), local.dtype)
            ext = jnp.concatenate([local, halo, dump], axis=1)

            _, _, p, active, is_first = bc.chase_cycle_indices(
                t, g_idx, n, b_in, tw)
            mine = active & (p >= lo) & (p < lo + c)
            start = jnp.where(mine, p - lo, c + w + g_idx * w).astype(jnp.int32)
            cols = start[:, None] + jnp.arange(w, dtype=jnp.int32)[None, :]
            win = ext[d_gather[None], cols[:, None, :]]
            win = jnp.where(gather_valid[None], win, 0)
            out = ops.chase_cycle(win, is_first, b_in=b_in, tw=tw,
                                  backend="ref")
            out = jnp.where(mine[:, None, None], out, win)
            orig = ext[jnp.arange(h)[None, :, None], cols[:, None, :]]
            vals = out[g_idx[:, None, None], y_back[None], ww_[None]]
            vals = jnp.where(back_valid[None], vals, orig)
            ext = ext.at[jnp.arange(h)[None, :, None], cols[:, None, :]].set(vals)

            local_new = ext[:, :c]
            halo_out = ext[:, c : c + w]
            # send my updated halo right; receive the left neighbor's
            recv = jax.lax.ppermute(halo_out, axis,
                                    [(i, i + 1) for i in range(d - 1)])
            # how many of MY leading columns did the left neighbor write?
            # (its unique boundary-crossing window: pivot in (lo - w, lo))
            crossing = active & (p > lo - w) & (p < lo)
            m = jnp.max(jnp.where(crossing, p + w - lo, 0))
            take = jnp.arange(c) < m
            merged_head = jnp.where((jnp.arange(w) < m)[None, :],
                                    recv, local_new[:, :w])
            return local_new.at[:, :w].set(merged_head)

        return jax.lax.fori_loop(0, total, cycle, local)

    spec = P(None, axis)
    fn = jax.shard_map(shard_fn, mesh=mesh, in_specs=(spec,), out_specs=spec,
                       check_vma=False)
    return fn(band)


def bidiagonalize_sharded(a: jax.Array, *, bw: int, tw: int, mesh: Mesh,
                          axis: str = "data"):
    """Full distributed SBR: dense banded (n, n) -> (diag, superdiag),
    band column-sharded over ``axis`` between stages."""
    from repro.core import band as bandmod
    from repro.core import bulge_chasing as bc

    n = a.shape[0]
    d = mesh.shape[axis]
    plan = bc.tw_schedule(bw, tw)
    if not plan:
        packed = bandmod.pack(a, bw, 0)
        return (bandmod.band_extract_diag(packed, 0, 0, n),
                bandmod.band_extract_diag(packed, 0, 1, n))
    tw0 = plan[0][1]
    cur = bandmod.pack(a, bw, tw0)
    tw_cur = tw0
    for b_in, twi in plan:
        h_i = b_in + 2 * twi + 1
        start = tw_cur - twi
        if start != 0 or cur.shape[0] != h_i:
            cur = jax.lax.slice_in_dim(cur, start, start + h_i, axis=0)
        w_i = b_in + twi + 1
        ncols = -(-(n + w_i) // d) * d
        ncols = max(ncols, d * w_i)
        cur = bandmod.pad_columns(cur, ncols - cur.shape[1])
        cur = reduce_stage_sharded(cur, n=n, b_in=b_in, tw=twi, mesh=mesh,
                                   axis=axis)
        cur = cur[:, :n]
        tw_cur = twi
    dvec = bandmod.band_extract_diag(cur, tw_cur, 0, n)
    evec = bandmod.band_extract_diag(cur, tw_cur, 1, n)
    return dvec, evec
