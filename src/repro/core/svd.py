"""Three-stage singular value pipeline (paper §I):

  dense --stage1--> banded --stage2 (paper: bulge chasing)--> bidiagonal
        --stage3--> singular values

``singular_values`` runs all three stages on-device; ``banded_singular_values``
enters at stage 2 (the paper's direct use case: banded inputs from spectral
PDE methods etc.).  All functions are jit-friendly and dtype-polymorphic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import band as bandmod
from repro.core import bulge_chasing as bc
from repro.core import stage1 as s1
from repro.core import bidiag_svd as s3
from repro.core import tuning

__all__ = ["singular_values", "banded_singular_values", "bidiagonal_of"]


def bidiagonal_of(a: jax.Array, *, bw: int, tw: int | None = None,
                  backend: str = "auto") -> tuple[jax.Array, jax.Array]:
    """Stage 2 only: dense upper-banded (n,n) -> (diag, superdiag)."""
    n = a.shape[0]
    if tw is None:
        tw = tuning.default_tilewidth(bw, a.dtype)
    return bc.bidiagonalize(a, bw=bw, tw=tw, backend=backend)


def banded_singular_values(a: jax.Array, *, bw: int, tw: int | None = None,
                           backend: str = "auto") -> jax.Array:
    """Singular values of an upper-banded matrix (stages 2+3), descending."""
    d, e = bidiagonal_of(a, bw=bw, tw=tw, backend=backend)
    return s3.bidiag_singular_values(d, e)


@functools.partial(jax.jit, static_argnames=("bw", "tw", "backend"))
def singular_values(a: jax.Array, *, bw: int = 32, tw: int | None = None,
                    backend: str = "auto") -> jax.Array:
    """All singular values of a dense (n, n) matrix, descending (3 stages)."""
    banded = s1.band_reduce(a, nb=bw)
    return banded_singular_values(banded, bw=bw, tw=tw, backend=backend)
