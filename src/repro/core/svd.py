"""Three-stage SVD pipeline (paper §I), batch-native:

  dense --stage1--> banded --stage2 (paper: bulge chasing)--> bidiagonal
        --stage3--> singular values [+ vectors via reflector-tape replay]

``singular_values`` runs all three stages on-device; ``banded_singular_values``
enters at stage 2 (the paper's direct use case: banded inputs from spectral
PDE methods etc.).  All functions are jit-friendly, dtype-polymorphic, and
accept leading batch axes: a stacked ``(B, n, n)`` input runs the whole
pipeline batch-native — stage 2 merges all B wavefronts into one fused kernel
call per global cycle (grid ``(B·G,)``), which is how small matrices recover
the occupancy a single chase cannot reach (paper Eq. 1; DESIGN.md §4).
``batched_singular_values`` / ``svd_batched`` make the batched contract
explicit; the serve layer (``serve/engine.py``) buckets traffic onto them.

Full SVD (beyond-paper; the paper names transform accumulation as §VII
future work): ``svd(a)`` / ``svd_batched(..., compute_uv=True)`` /
``banded_svd(a)`` return ``(U, sigma, V^T)``.  Stages 1–2 run in ``tape``
mode (recording every Householder reflector, DESIGN.md §8),
``core/transforms.py`` replays the tapes into U/V^T with the chase's own
wavefront batching, and stage 3 adds the bidiagonal's vectors via inverse
iteration seeded by the same Sturm bisection — sigma is bit-identical to
the values-only path.

Configuration: every entry point takes ``config=``, a resolved
``tuning.PipelineConfig`` that owns the backend (kernel registry key), the
tile-width schedule, batch sizing, and the ``compute_uv`` default.  The
legacy ``bw=/tw=/backend=`` kwargs remain and are resolved into a config
internally; passing a kwarg that conflicts with a supplied config raises:

    cfg = PipelineConfig.resolve(bw=16, dtype=jnp.float32)   # once
    sigma = svd_batched(stacked, config=cfg)                 # everywhere
    u, s, vt = svd_batched(stacked, config=cfg, compute_uv=True)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import bulge_chasing as bc
from repro.core import stage1 as s1
from repro.core import bidiag_dc as s3dc
from repro.core import bidiag_svd as s3
from repro.core import transforms
from repro.core import tuning
from repro.kernels import ops

__all__ = ["singular_values", "banded_singular_values", "bidiagonal_of",
           "batched_singular_values", "svd_batched", "svd", "banded_svd",
           "NumericalFault", "validate_sigma", "validate_uv",
           "spot_check_svd"]


# ---------------------------------------------------------------------------
# Numerical-health guards (DESIGN.md §15)
# ---------------------------------------------------------------------------

class NumericalFault(ArithmeticError):
    """A pipeline result failed post-solve validation (non-finite,
    negative, or unsorted sigma; non-finite vectors; residual blow-up).

    Raised by :func:`validate_sigma` / :func:`validate_uv` /
    :func:`spot_check_svd` — and by the entry points below under
    ``check=True``.  The serve retry layer (DESIGN.md §15) treats it as
    retryable-once-then-degrade: a numerically-poisoned dispatch rarely
    heals on replay, so after one retry the request is re-served on the
    trusted ref tier instead of burning more attempts.
    """


def _sigma_tol(s: np.ndarray) -> float:
    """Slack for the non-negativity / descending-order checks: rounding
    may leave sigma off by a few ulps of the spectrum's scale."""
    if s.size == 0:
        return 0.0
    eps = np.finfo(s.dtype).eps if np.issubdtype(s.dtype, np.floating) else 0.0
    smax = float(np.max(np.abs(s[np.isfinite(s)]))) if np.isfinite(s).any() \
        else 1.0
    return 16.0 * eps * max(smax, 1.0)


def validate_sigma(sig, *, name: str = "sigma") -> None:
    """Cheap post-solve health check on a sigma block (any leading axes):
    every value finite, non-negative (to rounding slack), and descending
    along the last axis.  Raises :class:`NumericalFault` on violation.

    Runs on host (forces a device sync) — call it OUTSIDE jit, after the
    result is already needed on host anyway (the serve engines validate
    the numpy block they are about to hand to callers).
    """
    s = np.asarray(sig)
    if s.size == 0:
        return
    if not np.isfinite(s).all():
        bad = int(np.size(s) - np.count_nonzero(np.isfinite(s)))
        raise NumericalFault(f"{name}: {bad} non-finite value(s)")
    tol = _sigma_tol(s)
    mn = float(s.min())
    if mn < -tol:
        raise NumericalFault(f"{name}: negative value {mn:.3e} < -{tol:.1e}")
    if s.shape[-1] >= 2:
        rise = float((s[..., 1:] - s[..., :-1]).max())
        if rise > tol:
            raise NumericalFault(
                f"{name}: not descending (adjacent rise {rise:.3e} "
                f"> {tol:.1e})")


def validate_uv(u, vt, *, name: str = "uv") -> None:
    """Finiteness check on the accumulated singular-vector factors."""
    for tag, m in (("U", u), ("V^T", vt)):
        if m is None:
            continue
        a = np.asarray(m)
        if not np.isfinite(a).all():
            raise NumericalFault(f"{name}: non-finite entries in {tag}")


def spot_check_svd(a, u, sig, vt, *, rtol: float | None = None) -> None:
    """Residual spot-check ``||A - U diag(s) V^T||_F / ||A||_F`` on the
    FIRST matrix of a (possibly batched) full-SVD result — one small
    matmul, not a per-matrix sweep.  Raises :class:`NumericalFault` when
    the relative residual exceeds ``rtol`` (default: ``50 * n * eps`` of
    the working dtype, loose enough for every healthy backend)."""
    a = np.asarray(a).reshape((-1,) + np.asarray(a).shape[-2:])[0]
    u0 = np.asarray(u).reshape((-1,) + np.asarray(u).shape[-2:])[0]
    vt0 = np.asarray(vt).reshape((-1,) + np.asarray(vt).shape[-2:])[0]
    s0 = np.asarray(sig).reshape((-1, np.asarray(sig).shape[-1]))[0]
    n = a.shape[-1]
    if rtol is None:
        rtol = 50.0 * n * float(np.finfo(a.dtype).eps)
    denom = max(float(np.linalg.norm(a)), np.finfo(a.dtype).tiny)
    resid = float(np.linalg.norm(a - (u0 * s0) @ vt0)) / denom
    if not np.isfinite(resid) or resid > rtol:
        raise NumericalFault(
            f"residual spot-check failed: ||A - USV^T||/||A|| = "
            f"{resid:.3e} > {rtol:.1e} (n={n})")


def _stage3_values(d: jax.Array, e: jax.Array,
                   cfg: tuning.PipelineConfig) -> jax.Array:
    """Stage-3 dispatch (DESIGN.md §14): the config's ``stage3`` policy picks
    the bidiagonal solver — Sturm bisection (the oracle) or the batched
    divide-and-conquer solve, "auto" collapsing per problem size through
    ``stage3_for``.  Both accept leading batch axes and agree on sigma to
    ~1e-12 relative (gated by tests/test_bidiag_dc.py)."""
    if cfg.stage3_for(d.shape[-1]) == "dc":
        return s3dc.bidiag_dc_singular_values(d, e, leaf_n=cfg.dc_leaf_n)
    return s3.bidiag_singular_values(d, e)


def _stage3_svd(d: jax.Array, e: jax.Array, cfg: tuning.PipelineConfig):
    """Full-SVD stage-3 dispatch; both solvers share the inverse-iteration
    vector machinery, so (U, V^T) quality is policy-independent."""
    if cfg.stage3_for(d.shape[-1]) == "dc":
        return s3dc.bidiag_dc_svd(d, e, leaf_n=cfg.dc_leaf_n)
    return s3.bidiag_svd(d, e)


def _resolve_tracer(trace):
    """The tracer for this call: an explicit ``trace=`` wins, else the
    ambient one (``repro.obs.current()``), else None.  Host spans are only
    meaningful outside jax tracing (DESIGN.md §16)."""
    tr = trace if trace is not None else obs.current()
    if tr is None:
        return None
    try:
        if not jax.core.trace_state_clean():
            return None
    except Exception:
        pass
    return tr


def _span_attrs(a, cfg: tuning.PipelineConfig, **extra) -> dict:
    lead = a.shape[:-2]
    batch = 1
    for dim in lead:
        batch *= int(dim)
    return dict(n=int(a.shape[-1]), bw=cfg.bw, tw=cfg.tw, fuse=cfg.fuse,
                dtype=str(a.dtype), backend=cfg.backend, batch=batch,
                **extra)


def _stage3_values_traced(d: jax.Array, e: jax.Array,
                          cfg: tuning.PipelineConfig) -> jax.Array:
    """Values-mode stage 3 under an ambient tracer: same solver dispatch as
    :func:`_stage3_values`, but inside a ``stage3`` span with compile/run
    split and device fencing."""
    solver = cfg.stage3_for(d.shape[-1])
    with obs.span("stage3", solver=solver, n=int(d.shape[-1])) as sp:
        if solver == "dc":
            sig = obs.traced_jit_call("stage3_dc",
                                      s3dc.bidiag_dc_singular_values, d, e,
                                      leaf_n=cfg.dc_leaf_n)
        else:
            sig = obs.traced_jit_call("stage3_bisect",
                                      s3.bidiag_singular_values, d, e)
        sp.fence(sig)
    return sig


def _stage3_svd_traced(d: jax.Array, e: jax.Array,
                       cfg: tuning.PipelineConfig):
    solver = cfg.stage3_for(d.shape[-1])
    with obs.span("stage3", solver=solver, n=int(d.shape[-1]),
                  compute_uv=True) as sp:
        if solver == "dc":
            out = obs.traced_jit_call("stage3_dc_svd", s3dc.bidiag_dc_svd,
                                      d, e, leaf_n=cfg.dc_leaf_n)
        else:
            out = obs.traced_jit_call("stage3_svd", s3.bidiag_svd, d, e)
        sp.fence(out)
    return out


def _three_stage_traced(a: jax.Array, cfg: tuning.PipelineConfig
                        ) -> jax.Array:
    """Traced values path: the SAME per-stage jitted functions
    ``_three_stage`` composes, run eagerly so each stage gets its own
    fenced span (and its own compile-vs-run attribution).  Sigma is
    unchanged — the stage boundaries are already jit boundaries inside
    ``_three_stage``; only the outer fusion wrapper is dropped."""
    with obs.span("stage1", **_span_attrs(a, cfg)) as sp:
        banded = sp.fence(obs.traced_jit_call(
            "stage1", s1.band_reduce, a, nb=cfg.bw, config=cfg))
    with obs.span("stage2", **_span_attrs(a, cfg)) as sp:
        d, e = bc.bidiagonalize(banded, bw=cfg.bw, tw=cfg.tw, config=cfg)
        sp.fence((d, e))
    return _stage3_values_traced(d, e, cfg)


def _fused_path(a: jax.Array, cfg: tuning.PipelineConfig, *,
                compute_uv: bool):
    """DESIGN.md §13: the one-dispatch fused small-n tier.

    Any entry point whose resolved config says ``backend="fused_small"``
    lands here instead of the staged pipeline.  Banded inputs need no
    separate path — the in-kernel stage-1 reflectors are exact no-ops on
    already-zero tails.  Values mode is one dispatch end to end; uv mode is
    two (the fused reduction, then one batched ``bidiag_svd`` composing the
    vectors from the kernel's accumulated transforms).
    """
    lead = a.shape[:-2]
    n = a.shape[-1]
    mats = a.reshape((-1,) + a.shape[-2:])
    if not compute_uv:
        sig = ops.fused_svd(mats, bw=cfg.bw, compute_uv=False, config=cfg)
        return sig.reshape(lead + (n,))
    d, e, u2, vt2 = ops.fused_svd(mats, bw=cfg.bw, compute_uv=True,
                                  config=cfg)
    ub, sig, vtb = _stage3_svd(d, e, cfg)
    # A = U2 B V2^T and B = Ub S Vb^T  =>  U = U2 Ub, V^T = Vb^T V2^T.
    u = jnp.matmul(u2, ub)
    vt = jnp.matmul(vtb, vt2)
    return (u.reshape(lead + (n, n)), sig.reshape(lead + (n,)),
            vt.reshape(lead + (n, n)))


def bidiagonal_of(a: jax.Array, *, bw: int | None = None,
                  tw: int | None = None, backend: str = "auto",
                  config: tuning.PipelineConfig | None = None
                  ) -> tuple[jax.Array, jax.Array]:
    """Stage 2 only: dense upper-banded (..., n, n) -> (diag, superdiag)."""
    cfg = tuning.PipelineConfig.of(config, bw=bw, tw=tw, backend=backend,
                                   dtype=a.dtype, n=a.shape[-1])
    return bc.bidiagonalize(a, bw=cfg.bw, tw=cfg.tw, config=cfg)


def banded_singular_values(a: jax.Array, *, bw: int | None = None,
                           tw: int | None = None, backend: str = "auto",
                           config: tuning.PipelineConfig | None = None,
                           check: bool = False, trace=None) -> jax.Array:
    """Singular values of upper-banded (..., n, n) (stages 2+3), descending.

    ``check=True`` runs the post-solve health guard (:func:`validate_sigma`,
    DESIGN.md §15) on the result — raising :class:`NumericalFault` instead
    of returning garbage when a chase went numerically bad.  It forces a
    host sync, so leave it off inside jit-hot loops.

    ``trace=`` takes a :class:`repro.obs.Tracer` (DESIGN.md §16): stages
    run under fenced spans with per-stage compile/run attribution.  An
    ambient tracer (``obs.activated``/``obs.install``) traces too.
    """
    cfg = tuning.PipelineConfig.of(config, bw=bw, tw=tw, backend=backend,
                                   dtype=a.dtype, n=a.shape[-1])
    tr = _resolve_tracer(trace)
    if tr is not None:
        with obs.activated(tr), tr.span(
                "banded_singular_values", **_span_attrs(a, cfg)) as root:
            if cfg.backend == "fused_small":
                with obs.span("fused") as sp:
                    sig = sp.fence(_fused_path(a, cfg, compute_uv=False))
            else:
                with obs.span("stage2", **_span_attrs(a, cfg)) as sp:
                    d, e = bc.bidiagonalize(a, bw=cfg.bw, tw=cfg.tw,
                                            config=cfg)
                    sp.fence((d, e))
                sig = _stage3_values_traced(d, e, cfg)
            root.fence(sig)
    elif cfg.backend == "fused_small":
        sig = _fused_path(a, cfg, compute_uv=False)
    else:
        d, e = bidiagonal_of(a, config=cfg)
        sig = _stage3_values(d, e, cfg)
    if check:
        validate_sigma(sig)
    return sig


@functools.partial(jax.jit, static_argnames=("config",))
def _three_stage(a: jax.Array, *, config: tuning.PipelineConfig) -> jax.Array:
    banded = s1.band_reduce(a, nb=config.bw, config=config)
    d, e = bc.bidiagonalize(banded, bw=config.bw, tw=config.tw, config=config)
    return _stage3_values(d, e, config)


def singular_values(a: jax.Array, *, bw: int | None = None,
                    tw: int | None = None, backend: str = "auto",
                    config: tuning.PipelineConfig | None = None,
                    check: bool = False, trace=None) -> jax.Array:
    """All singular values of dense (..., n, n), descending (3 stages).

    ``bw`` defaults to 32 when neither it nor ``config`` is given; passing a
    legacy kwarg that CONFLICTS with a supplied config raises (no silent
    precedence).  Config resolution happens outside the jit boundary, and the
    config's serve-only fields are normalized out of the cache key, so
    configs differing only in bucket sizing do not recompile.

    ``check=True`` validates the result post-solve (finite, non-negative,
    descending — :func:`validate_sigma`) and raises
    :class:`NumericalFault` on violation (DESIGN.md §15).

    ``trace=`` (or an ambient ``repro.obs`` tracer) records a fenced span
    tree — stage1/stage2/stage3 children under one root, compile time
    split out on first dispatch (DESIGN.md §16).  The traced path runs
    the same per-stage jitted stages eagerly instead of the one fused
    ``_three_stage`` jit, so each stage is individually attributable.
    """
    cfg = tuning.PipelineConfig.of(config, bw=bw, tw=tw, backend=backend,
                                   dtype=a.dtype, n=a.shape[-1])
    tr = _resolve_tracer(trace)
    if tr is not None:
        with obs.activated(tr), tr.span(
                "singular_values", **_span_attrs(a, cfg)) as root:
            if cfg.backend == "fused_small":
                with obs.span("fused") as sp:
                    sig = sp.fence(_fused_path(a, cfg, compute_uv=False))
            else:
                sig = _three_stage_traced(a, cfg)
            root.fence(sig)
    elif cfg.backend == "fused_small":
        sig = _fused_path(a, cfg, compute_uv=False)
    else:
        sig = _three_stage(a, config=cfg)
    if check:
        validate_sigma(sig)
    return sig


def batched_singular_values(mats: jax.Array, *, bw: int | None = None,
                            tw: int | None = None, backend: str = "auto",
                            config: tuning.PipelineConfig | None = None,
                            check: bool = False, trace=None) -> jax.Array:
    """Batch-native three-stage pipeline: (B, n, n) -> (B, n) descending.

    Unlike a vmapped loop, the B chases share one wavefront: every global
    cycle issues a single fused kernel call over all B*G windows.  For small
    n this is the difference between an idle and a saturated chip.
    """
    assert mats.ndim == 3, f"expected stacked (B, n, n), got {mats.shape}"
    return singular_values(mats, bw=bw, tw=tw, backend=backend, config=config,
                           check=check, trace=trace)


def svd_batched(mats: jax.Array,
                config: tuning.PipelineConfig | None = None, *,
                compute_uv: bool | None = None, trace=None, **overrides):
    """Config-first batched entry point: ``svd_batched(stacked, cfg)``.

    Sugar over :func:`batched_singular_values` for callers that already hold
    a resolved :class:`tuning.PipelineConfig` (the serve engine, benchmarks).
    ``overrides`` are the legacy ``bw=/tw=/backend=`` kwargs (conflicts with
    the config raise).  ``compute_uv=True`` (or a config with
    ``compute_uv=True``) returns ``(U, sigma, V^T)`` instead of sigma alone;
    sigma is bit-identical between the two modes.
    """
    if compute_uv is None:
        compute_uv = config.compute_uv if config is not None else False
    if compute_uv:
        assert mats.ndim == 3, f"expected stacked (B, n, n), got {mats.shape}"
        return svd(mats, config=config, compute_uv=True, trace=trace,
                   **overrides)
    return batched_singular_values(mats, config=config, trace=trace,
                                   **overrides)


# ---------------------------------------------------------------------------
# Full SVD: reflector tapes -> (U, sigma, V^T)
# ---------------------------------------------------------------------------

def _uv_pipeline(a: jax.Array, *, config: tuning.PipelineConfig,
                 banded: bool):
    """Tape-mode pipeline: returns (U, sigma, V^T) with A = U diag(s) V^T.

    Stage-1/2 band arithmetic is identical to the values-only path (the tape
    is recorded alongside, never read by it), so (d, e) — and the bisection
    sigma — are bit-identical.  The tapes are then replayed into transposed
    accumulators through the ``tape_apply`` registry op, and stage 3's
    bidiagonal vectors are composed on top.
    """
    n = a.shape[-1]
    lead = a.shape[:-2]
    if banded:
        s1_tape = None
        band_in = a
    else:
        with obs.span("stage1", **_span_attrs(a, config, tape=True)) as sp:
            band_in, s1_tape = obs.traced_jit_call(
                "stage1_tape", s1.band_reduce, a, nb=config.bw,
                config=config, tape=True)
            sp.fence((band_in, s1_tape))
    with obs.span("stage2", **_span_attrs(a, config, tape=True)) as sp:
        d, e, chase_tapes = bc.bidiagonalize(band_in, bw=config.bw,
                                             tw=config.tw, config=config,
                                             tape=True)
        sp.fence((d, e))
    with obs.span("replay", n=int(n)) as sp:
        u2, vt2 = transforms.accumulate_transforms(
            n, s1_tape=s1_tape, chase_tapes=chase_tapes, lead=lead,
            dtype=a.dtype, config=config)
        sp.fence((u2, vt2))
    ub, sig, vtb = _stage3_svd_traced(d, e, config)
    # A = U2 B V2^T and B = Ub S Vb^T  =>  U = U2 Ub, V^T = Vb^T V2^T.
    with obs.span("compose") as sp:
        u = jnp.matmul(u2, ub)
        vt = jnp.matmul(vtb, vt2)
        sp.fence((u, vt))
    return u, sig, vt


def _checked_uv(a, out, *, check: bool):
    """Post-solve health guard for a full-SVD result (DESIGN.md §15):
    sigma invariants, U/V^T finiteness, and the one-matrix residual
    spot-check — the cheapest test that the FACTORS (not just the
    spectrum) are trustworthy."""
    if check:
        u, sig, vt = out
        validate_sigma(sig)
        validate_uv(u, vt)
        spot_check_svd(a, u, sig, vt)
    return out


def svd(a: jax.Array, *, bw: int | None = None, tw: int | None = None,
        backend: str = "auto", config: tuning.PipelineConfig | None = None,
        compute_uv: bool = True, check: bool = False, trace=None):
    """Full SVD of dense (..., n, n): ``(U, sigma, V^T)``, sigma descending.

    ``compute_uv=False`` degrades to :func:`singular_values` (and the sigma
    returned either way are bit-identical — the tape mode records reflectors
    alongside the same band arithmetic, it never alters it).  Batched inputs
    run batch-native end to end, including the tape replay (one fused
    ``tape_apply`` call over all B*G wavefront slots per cycle).

    ``check=True`` (DESIGN.md §15) validates sigma, checks U/V^T
    finiteness, and residual-spot-checks the first matrix; violations
    raise :class:`NumericalFault`.
    """
    cfg = tuning.PipelineConfig.of(config, bw=bw, tw=tw, backend=backend,
                                   dtype=a.dtype, n=a.shape[-1])
    if not compute_uv:
        return singular_values(a, config=cfg, check=check, trace=trace)
    tr = _resolve_tracer(trace)
    if tr is not None:
        with obs.activated(tr), tr.span(
                "svd", **_span_attrs(a, cfg, compute_uv=True)) as root:
            if cfg.backend == "fused_small":
                with obs.span("fused") as sp:
                    out = sp.fence(_fused_path(a, cfg, compute_uv=True))
            else:
                out = _uv_pipeline(a, config=cfg, banded=False)
            root.fence(out)
        return _checked_uv(a, out, check=check)
    if cfg.backend == "fused_small":
        return _checked_uv(a, _fused_path(a, cfg, compute_uv=True),
                           check=check)
    return _checked_uv(a, _uv_pipeline(a, config=cfg, banded=False),
                       check=check)


def banded_svd(a: jax.Array, *, bw: int | None = None, tw: int | None = None,
               backend: str = "auto",
               config: tuning.PipelineConfig | None = None,
               compute_uv: bool = True, check: bool = False, trace=None):
    """Full SVD of upper-banded (..., n, n) (stages 2+3 only); ``check=``
    as in :func:`svd`, ``trace=`` as in :func:`singular_values`."""
    cfg = tuning.PipelineConfig.of(config, bw=bw, tw=tw, backend=backend,
                                   dtype=a.dtype, n=a.shape[-1])
    if not compute_uv:
        return banded_singular_values(a, config=cfg, check=check,
                                      trace=trace)
    tr = _resolve_tracer(trace)
    if tr is not None:
        with obs.activated(tr), tr.span(
                "banded_svd", **_span_attrs(a, cfg, compute_uv=True)) as root:
            if cfg.backend == "fused_small":
                with obs.span("fused") as sp:
                    out = sp.fence(_fused_path(a, cfg, compute_uv=True))
            else:
                out = _uv_pipeline(a, config=cfg, banded=True)
            root.fence(out)
        return _checked_uv(a, out, check=check)
    if cfg.backend == "fused_small":
        return _checked_uv(a, _fused_path(a, cfg, compute_uv=True),
                           check=check)
    return _checked_uv(a, _uv_pipeline(a, config=cfg, banded=True),
                       check=check)
