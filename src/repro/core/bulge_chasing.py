"""Band -> bidiagonal reduction via memory-aware bulge chasing (paper Alg. 1).

``reduce_stage_packed`` / ``bidiagonalize_packed`` are the production JAX
path: static-shape wavefront execution on packed band storage.  Per global
cycle ``t`` every in-flight sweep executes one chase cycle; the paper's
3-cycle separation guarantees the per-sweep windows are disjoint
(stride between concurrent pivots = ``3*b_in - 1`` > window width
``b_in + tw + 1``), so all windows are gathered, processed by one batched
kernel call (Pallas on TPU / interpret or pure-jnp on CPU), and scattered
back race-free.

(The sequential numpy oracles — ``reduce_stage_dense_ref``,
``bidiagonalize_dense_ref``, ``bidiagonalize_dense_ref_uv`` — live in
``core/reference.py`` so this hot module stays numpy-free; they are
re-exported here for back-compat.)

Scheduling (stage reduces bandwidth ``b_in -> b_out = b_in - tw``):

  sweep R (R = 0..n-2-b_out) starts at global cycle 3R;
  at local cycle j it owns pivot column  p = R + b_out + j*b_in;
  cycle j=0 annihilates row R's outermost ``tw`` band elements
  (columns p+1..p+tw, pivot p) — paper Alg. 1 line 7 start correction;
  cycle j>0 annihilates the row bulge of row r = p - b_in;
  each cycle then annihilates the column bulge of pivot column p.

The window of one cycle covers matrix rows [p - b_in - tw, p + tw] and columns
[p, p + b_in + tw] — "1 + BW + TW consecutive elements" (paper §III-A) — and is
*rolled* so matrix rows align with window rows (dense tile), turning the
band-storage diagonal access pattern into contiguous VPU-friendly tiles.

Batch-native execution (DESIGN.md §4): every entry point below accepts a
leading batch axis — packed storage ``(B, H, ncols)``, dense input
``(B, n, n)``.  The schedule is shape-only, so all B problems share one
wavefront clock: per global cycle the gather produces ``(B, G, H, W)``
windows, flattened to one fused kernel call over ``B*G`` slots (grid
``(B·G,)``), and scattered back race-free.  This is how small matrices —
whose own wavefront ``G = ceil(n / (3*b_in - 1)) + 1`` cannot fill the
machine (paper Eq. 1) — recover occupancy: independent problems fill the
idle wavefront slots.

Reflector tapes (DESIGN.md §8): every entry point accepts ``tape=True``,
under which the chase additionally records each cycle's Householder pair
``(v, tau)`` per (global cycle, wavefront slot) into static-shape arrays —
the *reflector tape*.  ``core/transforms.py`` replays tapes into the left
and right transform accumulators (``U`` / ``V^T``) with the same wavefront
batching, which is what turns the values-only pipeline into a full SVD.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import band as bandmod

# Back-compat re-exports of the numpy oracles (historical home; the
# implementations moved to core/reference.py).  Lazy (PEP 562) so that
# importing this hot module does not pull in numpy or the oracle code —
# the point of the move.
_REFERENCE_EXPORTS = ("_np_reflector", "reduce_stage_dense_ref",
                      "bidiagonalize_dense_ref", "bidiagonalize_dense_ref_uv")


def __getattr__(name):
    if name in _REFERENCE_EXPORTS:
        from repro.core import reference
        return getattr(reference, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "reduce_stage_dense_ref",
    "bidiagonalize_dense_ref",
    "bidiagonalize_dense_ref_uv",
    "reduce_stage_packed",
    "bidiagonalize_packed",
    "bidiagonalize",
    "chase_cycle_indices",
    "stage_schedule",
]


# ---------------------------------------------------------------------------
# Wavefront schedule helpers
# ---------------------------------------------------------------------------

def stage_schedule(n: int, b_in: int, tw: int, fuse: int = 1
                   ) -> tuple[int, int, int]:
    """(n_sweeps, total_super_cycles, max_concurrent) for one stage.

    With fuse depth K, every super-cycle advances each in-flight sweep by K
    local cycles; sweep R starts at super-cycle ``sep*R`` where
    ``sep = tuning.sweep_separation(K)`` (3 at K=1 — the paper's 3-cycle
    rule — and 2 for K >= 2, which already keeps the wider fused windows
    disjoint).  Sweep finish times ``sep*R + ceil((j_max(R)+1)/K)`` are
    increasing in R (``sep >= 2`` while ``j_max`` drops by at most 1 per
    sweep), so the last sweep finishes last.  ``max_concurrent`` is
    ``tuning.max_concurrent_sweeps`` (single source of truth for the
    wavefront width), including for the degenerate 0-sweep case.
    """
    from repro.core import tuning
    conc = tuning.max_concurrent_sweeps(n, b_in, fuse, tw)
    b_out = b_in - tw
    nsweeps = max(n - 1 - b_out, 0)
    if nsweeps == 0:
        return 0, 0, conc
    last = nsweeps - 1
    max_j_last = max((n - 1 - last - b_out) // b_in, 0)
    sep = tuning.sweep_separation(fuse)
    total = sep * last + -(-(max_j_last + 1) // fuse)
    return nsweeps, total, conc


def chase_cycle_indices(t, g, n: int, b_in: int, tw: int, fuse: int = 1):
    """Vectorized slot -> (sweep, base local cycle, base pivot, active,
    is_first).

    Slot g at (super-)cycle t hosts sweep R = t//sep - g at base local cycle
    j = (t - sep*R) * fuse = (t%sep + sep*g) * fuse, where
    ``sep = tuning.sweep_separation(fuse)``; the super-step then executes
    local cycles j..j+fuse-1 with pivots ``p + i*b_in`` (cycle i active iff
    ``p + i*b_in <= n - 1`` — a prefix of the K cycles, so ``active`` below
    gates the whole slot via cycle 0).  ``fuse=1`` is the paper's schedule:
    R = t//3 - g, j = t%3 + 3g.  Works on traced or static ints.
    """
    from repro.core import tuning
    sep = tuning.sweep_separation(fuse)
    b_out = b_in - tw
    nsweeps = max(n - 1 - b_out, 0)
    R = t // sep - g
    j = (t - sep * R) * fuse
    p = R + b_out + j * b_in
    active = (R >= 0) & (R < nsweeps) & (p <= n - 1)
    return R, j, p, active, (j == 0)


# ---------------------------------------------------------------------------
# Packed wavefront stage (JAX)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n", "b_in", "tw", "backend",
                                             "unroll", "config", "tape",
                                             "fuse"))
def reduce_stage_packed(band: jax.Array, *, n: int, b_in: int, tw: int,
                        backend: str = "auto", unroll: int | None = None,
                        config=None, tape: bool = False,
                        fuse: int | None = None):
    """One SBR stage on packed band storage, batch-native.

    band: (..., b_in + 2*tw + 1, >= n) — any leading batch axes (flattened to
    one B internally).  Returns same-shape storage with bandwidth reduced to
    ``b_in - tw`` (bulge space zeroed).  All B problems advance on one
    wavefront clock: per global cycle the (B, G, H, W) window gather is
    flattened into ONE fused kernel call over B*G slots, so independent
    problems fill wavefront slots a single small matrix leaves idle.

    ``fuse=K`` (DESIGN.md §9) chases K consecutive local cycles per kernel
    dispatch: the wavefront clock ticks in super-cycles, each gathering one
    contiguous band-storage block ``(H, K*b_in + tw + 1)`` per slot — no
    per-cell shear indexing on the HBM side; the roll to dense windows
    happens inside the kernel, VMEM-resident.  Each chased cycle costs ~1/K
    of an HBM block round trip instead of one sheared window gather/scatter,
    and the launch count drops by the sweep-separation ratio (3*nsweeps ->
    2*nsweeps super-cycles; sweep starts, not per-sweep cycles, dominate the
    schedule).  Numerics are invariant in K: every cycle applies the same
    reflector pair in the same per-sweep order, so the output band (and any
    tape) matches ``fuse=1``.

    With ``tape=True`` the stage additionally records the reflector tape and
    returns ``(band, tape_v, tape_tau)`` with static shapes
    ``tape_v: (..., T, G, 2, tw+1)`` and ``tape_tau: (..., T, G, 2)`` at
    ``fuse=1``, and ``(..., T, G, K, 2, tw+1)`` / ``(..., T, G, K, 2)``
    fused (T = super-cycle count, K pairs per slot) — index 0 of the pair
    axis is the right reflector (accumulates into V), index 1 the left one
    (into U); inactive slots carry ``tau = 0`` (identity on replay).  The
    in-band arithmetic is byte-for-byte the same either way, so (d, e) —
    and hence sigma — do not change with the tape.

    Explicit ``backend=``/``unroll=``/``fuse=`` kwargs win over ``config``;
    the config fills whatever was left at its default ("auto" / None).
    Backend/interpret resolution itself is delegated to the kernel registry
    (ops._resolve) at the ``chase_cycle`` call — this function only resolves
    ``unroll`` and ``fuse``.
    """
    from repro.kernels import ops  # local import to avoid cycles

    if unroll is None:
        unroll = config.unroll if config is not None else 1
    if fuse is None:
        fuse = getattr(config, "fuse", 1) if config is not None else 1
    fuse = max(int(fuse), 1)

    b_out = b_in - tw
    assert b_out >= 1, (b_in, tw)
    H = b_in + 2 * tw + 1
    W = b_in + tw + 1
    assert band.ndim >= 2 and band.shape[-2] == H, (band.shape, H)
    lead = band.shape[:-2]
    band3 = band.reshape((-1,) + band.shape[-2:])
    B = band3.shape[0]
    nsweeps, T, G = stage_schedule(n, b_in, tw, fuse)
    if nsweeps == 0 or T == 0:
        if tape:
            pair = (G, 2) if fuse == 1 else (G, fuse, 2)
            empty_v = jnp.zeros(lead + (0,) + pair + (tw + 1,), band.dtype)
            empty_t = jnp.zeros(lead + (0,) + pair, band.dtype)
            return band, empty_v, empty_t
        return band

    ncols0 = band3.shape[-1]
    if fuse > 1:
        return _reduce_stage_superstep(band3, lead=lead, n=n, b_in=b_in,
                                       tw=tw, backend=backend, unroll=unroll,
                                       config=config, tape=tape, fuse=fuse,
                                       T=T, G=G)
    dump = n + W                      # start of per-slot dump zones (inactive slots)
    n_pad = dump + G * W
    bandp = bandmod.pad_columns(band3, max(n_pad - ncols0, 0))

    yy = jnp.arange(H)[:, None]                      # (H, 1)
    ww = jnp.arange(W)[None, :]                      # (1, W)
    d_gather = jnp.clip(H - 1 + ww - yy, 0, H - 1)   # (H, W) band row per window cell
    gather_valid = yy >= ww                          # window cell maps into storage
    dd = jnp.arange(H)[:, None]
    y_back = jnp.clip(H - 1 + ww - dd, 0, H - 1)     # (H, W) window row per band cell
    back_valid = dd >= ww
    g_idx = jnp.arange(G)
    rows = jnp.arange(H)[None, :, None]              # (1, H, 1) band row per cell

    def cycle(t, carry):
        bandp = carry[0] if tape else carry
        _, _, p, active, is_first = chase_cycle_indices(t, g_idx, n, b_in, tw)
        p_safe = jnp.where(active, p, dump + g_idx * W).astype(jnp.int32)
        cols = p_safe[:, None] + jnp.arange(W, dtype=jnp.int32)[None, :]   # (G, W)
        # gather rolled dense windows: (B, G, H, W)
        win = bandp[:, d_gather[None], cols[:, None, :]]
        win = jnp.where(gather_valid[None, None], win, 0)
        with jax.named_scope("chase_cycle"):
            res = ops.chase_cycle(win.reshape(B * G, H, W),
                                  jnp.tile(is_first, B), b_in=b_in, tw=tw,
                                  backend=backend, config=config,
                                  with_tape=tape)
        out = res[0] if tape else res
        out = out.reshape(B, G, H, W)
        out = jnp.where(active[None, :, None, None], out, win)
        # shear back to band coords and scatter (windows disjoint per matrix)
        orig = bandp[:, rows, cols[:, None, :]]                  # (B, G, H, W)
        vals = out[:, g_idx[:, None, None], y_back[None], ww[None]]
        vals = jnp.where(back_valid[None, None], vals, orig)
        bandp = bandp.at[:, rows, cols[:, None, :]].set(vals)
        if not tape:
            return bandp
        tape_v, tape_tau = carry[1], carry[2]
        vs = res[1].reshape(B, G, 2, tw + 1)
        ts = res[2].reshape(B, G, 2)
        ts = jnp.where(active[None, :, None], ts, 0)             # identity replay
        return (bandp, tape_v.at[:, t].set(vs), tape_tau.at[:, t].set(ts))

    if tape:
        tape_v0 = jnp.zeros((B, T, G, 2, tw + 1), band.dtype)
        tape_tau0 = jnp.zeros((B, T, G, 2), band.dtype)
        bandp, tape_v, tape_tau = jax.lax.fori_loop(
            0, T, cycle, (bandp, tape_v0, tape_tau0), unroll=unroll)
        out = bandp[..., :ncols0]
        return (out.reshape(lead + out.shape[-2:]),
                tape_v.reshape(lead + tape_v.shape[1:]),
                tape_tau.reshape(lead + tape_tau.shape[1:]))
    bandp = jax.lax.fori_loop(0, T, cycle, bandp, unroll=unroll)
    out = bandp[..., :ncols0]
    return out.reshape(lead + out.shape[-2:])


def _reduce_stage_superstep(band3: jax.Array, *, lead, n: int, b_in: int,
                            tw: int, backend: str, unroll: int, config,
                            tape: bool, fuse: int, T: int, G: int):
    """Fuse-depth-K super-step wavefront (DESIGN.md §9), fuse >= 2.

    Per super-cycle, each active slot owns one CONTIGUOUS band-storage block
    of ``W_K = K*b_in + tw + 1`` columns — the union of its K consecutive
    chase windows, which overlap by ``tw + 1`` columns.  The gather/scatter
    is therefore a plain column-block copy (the per-cell diagonal shear of
    the K=1 path moves inside the kernel, where it runs on VMEM-resident
    data); blocks of one super-cycle are pairwise disjoint by the
    generalized schedule (``tuning.sweep_separation``), so the scatter is
    race-free.
    """
    from repro.kernels import ops

    H = b_in + 2 * tw + 1
    WK = fuse * b_in + tw + 1
    B = band3.shape[0]
    ncols0 = band3.shape[-1]
    dump = n + WK                     # start of per-slot dump zones
    n_pad = dump + G * WK
    bandp = bandmod.pad_columns(band3, max(n_pad - ncols0, 0))

    g_idx = jnp.arange(G)
    rows = jnp.arange(H)[None, :, None]              # (1, H, 1)
    i_off = jnp.arange(fuse, dtype=jnp.int32) * b_in

    def supercycle(t, carry):
        bandp = carry[0] if tape else carry
        _, _, p, slot_on, is_first = chase_cycle_indices(t, g_idx, n, b_in,
                                                         tw, fuse)
        # per-fused-cycle activity: a prefix of the K cycles (pivot runs off
        # the band once p + i*b_in > n - 1)
        act = slot_on[:, None] & ((p[:, None] + i_off) <= n - 1)   # (G, K)
        p_safe = jnp.where(slot_on, p, dump + g_idx * WK).astype(jnp.int32)
        cols = p_safe[:, None] + jnp.arange(WK, dtype=jnp.int32)[None, :]
        blocks = bandp[:, rows, cols[:, None, :]]                  # (B, G, H, WK)
        with jax.named_scope("chase_supercycle"):
            res = ops.chase_cycle(blocks.reshape(B * G, H, WK),
                                  jnp.tile(is_first, B), b_in=b_in, tw=tw,
                                  fuse=fuse, active=jnp.tile(act, (B, 1)),
                                  backend=backend, config=config,
                                  with_tape=tape)
        out = (res[0] if tape else res).reshape(B, G, H, WK)
        out = jnp.where(slot_on[None, :, None, None], out, blocks)
        bandp = bandp.at[:, rows, cols[:, None, :]].set(out)
        if not tape:
            return bandp
        tape_v, tape_tau = carry[1], carry[2]
        vs = res[1].reshape(B, G, fuse, 2, tw + 1)
        ts = res[2].reshape(B, G, fuse, 2)
        ts = jnp.where(act[None, :, :, None], ts, 0)               # identity replay
        return (bandp, tape_v.at[:, t].set(vs), tape_tau.at[:, t].set(ts))

    if tape:
        tape_v0 = jnp.zeros((B, T, G, fuse, 2, tw + 1), band3.dtype)
        tape_tau0 = jnp.zeros((B, T, G, fuse, 2), band3.dtype)
        bandp, tape_v, tape_tau = jax.lax.fori_loop(
            0, T, supercycle, (bandp, tape_v0, tape_tau0), unroll=unroll)
        out = bandp[..., :ncols0]
        return (out.reshape(lead + out.shape[-2:]),
                tape_v.reshape(lead + tape_v.shape[1:]),
                tape_tau.reshape(lead + tape_tau.shape[1:]))
    bandp = jax.lax.fori_loop(0, T, supercycle, bandp, unroll=unroll)
    out = bandp[..., :ncols0]
    return out.reshape(lead + out.shape[-2:])


def tw_schedule(bw: int, tw: int) -> list[tuple[int, int]]:
    """[(b_in, tw_i), ...] stage plan reducing bw -> 1 by <= tw per stage.

    (Canonical implementation: ``tuning.stage_plan`` — the PipelineConfig's
    tile-width schedule; kept here as the historical alias.)
    """
    from repro.core import tuning
    return list(tuning.stage_plan(bw, tw))


def bidiagonalize_packed(band: jax.Array, *, n: int, bw: int, tw: int,
                         backend: str = "auto", config=None,
                         tape: bool = False, fuse: int | None = None):
    """Full SBR bw -> 1 on packed storage. Returns (diag, superdiag).

    ``band`` must be packed with tw_0 = min(tw, bw-1) sub rows, i.e. via
    ``band.pack(a, bw, min(tw, bw-1))``; a leading batch axis (B, H, ncols)
    is threaded through every stage.  Host loop over stages (static,
    <= ceil((bw-1)/tw) iterations); each stage jits once per shape.

    With ``tape=True`` returns ``(diag, superdiag, tapes)`` where ``tapes``
    is a static-length list of :class:`repro.core.transforms.ChaseTape`,
    one per stage of the tile-width plan, in execution order.  ``fuse=K``
    (explicit kwarg or ``config.fuse``) runs every stage in K-cycle
    super-steps; the tapes carry the fuse depth for replay.

    Storage layout invariant entering each stage (b_in, tw_i):
      tw_i sub rows | diag row | b_in + tw_i sup rows  ==  b_in + 2*tw_i + 1.
    Between stages the storage is re-sliced (outer diagonals are now zero).
    """
    if tape:
        from repro.core import transforms  # deferred: transforms imports us
    if fuse is None:
        fuse = getattr(config, "fuse", 1) if config is not None else 1
    fuse = max(int(fuse), 1)
    plan = tw_schedule(bw, tw)
    if not plan:
        h = band.shape[-2]
        tw0 = (h - 2) // 2 if h > 2 else 0
        d = bandmod.band_extract_diag(band, tw0, 0, n)
        e = (bandmod.band_extract_diag(band, tw0, 1, n) if bw >= 1
             else jnp.zeros(band.shape[:-2] + (n,), band.dtype))
        return (d, e, []) if tape else (d, e)
    cur = band
    tw_cur = plan[0][1]
    assert cur.shape[-2] == plan[0][0] + 2 * tw_cur + 1, (cur.shape, plan[0])
    tapes = []
    for b_in, twi in plan:
        # re-slice so exactly twi sub rows remain above the diagonal row
        h_i = b_in + 2 * twi + 1
        start = tw_cur - twi
        if start != 0 or cur.shape[-2] != h_i:
            cur = jax.lax.slice_in_dim(cur, start, start + h_i, axis=-2)
        # Span per stage of the tile-width plan (DESIGN.md §16): no-op
        # unless an ambient tracer is active AND we're outside jit tracing
        # (inside `_three_stage` this whole loop is traced symbolically).
        with obs.span("chase_stage", n=n, b_in=b_in, tw=twi, fuse=fuse,
                      tape=tape) as sp:
            if tape:
                cur, tv, tt = obs.traced_jit_call(
                    "chase_stage", reduce_stage_packed, cur, n=n, b_in=b_in,
                    tw=twi, backend=backend, config=config, tape=True,
                    fuse=fuse)
                tapes.append(transforms.ChaseTape(n=n, b_in=b_in, tw=twi,
                                                  v=tv, tau=tt, fuse=fuse))
            else:
                cur = obs.traced_jit_call(
                    "chase_stage", reduce_stage_packed, cur, n=n, b_in=b_in,
                    tw=twi, backend=backend, config=config, fuse=fuse)
            sp.fence(cur)
        tw_cur = twi
    d = bandmod.band_extract_diag(cur, tw_cur, 0, n)
    e = bandmod.band_extract_diag(cur, tw_cur, 1, n)
    return (d, e, tapes) if tape else (d, e)


def bidiagonalize(a: jax.Array, *, bw: int, tw: int, backend: str = "auto",
                  config=None, tape: bool = False, fuse: int | None = None):
    """Dense upper-banded (..., n, n) -> (..., n) diag + superdiag pair via
    packed wavefront SBR; a leading batch axis runs batch-native (one fused
    wavefront over all matrices), not as a vmapped loop.  ``tape=True``
    additionally returns the per-stage reflector tapes; ``fuse=K`` chases K
    cycles per kernel dispatch (see :func:`bidiagonalize_packed`)."""
    n = a.shape[-1]
    tw0 = min(tw, max(bw - 1, 1))
    packed = bandmod.pack(a, bw, tw0)
    return bidiagonalize_packed(packed, n=n, bw=bw, tw=tw, backend=backend,
                                config=config, tape=tape, fuse=fuse)
