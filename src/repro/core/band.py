"""Packed banded storage (paper §IV-b), batch-native.

The matrix entering stage 2 is upper-triangular banded: ``A[i, j] != 0`` only for
``0 <= j - i <= bw``.  During bulge chasing with inner tilewidth ``tw`` fill-in is
bounded by ``tw`` rows below the diagonal and ``tw`` columns beyond the band, so the
packed storage holds ``bw + 2*tw + 1`` diagonals (paper: "height of the matrix
bandwidth, increased by twice the inner tilewidth", column-major):

    band[tw + (j - i), j] = A[i, j]        for -tw <= j - i <= bw + tw

Row ``tw`` is the main diagonal; rows above it (d < tw) are subdiagonals (bulge
space); rows below it are superdiagonals (band + overhang bulge space).

All functions are shape-static, jit-friendly, and polymorphic over leading
batch axes: a dense ``(..., n, n)`` input packs to ``(..., band_height, n)``
and every helper below indexes the trailing two axes only, so a batch of B
independent problems is one array ``(B, H, n)`` — the layout the batched
wavefront stage gathers its ``(B, G, H, W)`` windows from.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "band_height",
    "pack",
    "unpack",
    "bandwidth_of",
    "band_extract_diag",
    "band_set_diag",
    "pad_columns",
]


def band_height(bw: int, tw: int) -> int:
    """Number of stored diagonals: tw sub + main + (bw + tw) super."""
    return bw + 2 * tw + 1


def pack(a: jax.Array, bw: int, tw: int) -> jax.Array:
    """Dense (..., n, n) -> packed band (..., band_height, n).

    Entries outside ``-tw <= j - i <= bw + tw`` are dropped (they must be zero for
    a well-formed banded input; `unpack(pack(a))` round-trips banded matrices).
    """
    n = a.shape[-1]
    h = band_height(bw, tw)
    d = jnp.arange(h)[:, None]          # storage diagonal index
    j = jnp.arange(n)[None, :]          # column
    i = j - (d - tw)                    # source row
    valid = (i >= 0) & (i < n)
    return jnp.where(valid, a[..., jnp.clip(i, 0, n - 1), j], 0).astype(a.dtype)


def unpack(band: jax.Array, bw: int, tw: int, n: int) -> jax.Array:
    """Packed band (..., band_height, >=n) -> dense (..., n, n)."""
    h = band_height(bw, tw)
    ncols = band.shape[-1]
    i = jnp.arange(n)[:, None]
    j = jnp.arange(n)[None, :]
    d = tw + (j - i)
    valid = (d >= 0) & (d < h)
    vals = band[..., jnp.clip(d, 0, h - 1), jnp.clip(j, 0, ncols - 1)]
    return jnp.where(valid, vals, 0)


def bandwidth_of(a: jax.Array, tol: float = 0.0) -> jax.Array:
    """Max |j - i| with |A[i,j]| > tol above the diagonal (upper bandwidth);
    reduces the trailing two axes (batched input -> per-matrix widths)."""
    n = a.shape[-1]
    i = jnp.arange(n)[:, None]
    j = jnp.arange(n)[None, :]
    nz = jnp.abs(a) > tol
    return jnp.max(jnp.where(nz, j - i, 0), axis=(-2, -1))


def band_extract_diag(band: jax.Array, tw: int, k: int, n: int) -> jax.Array:
    """Return diagonal k (k=0 main, k=1 first super) as a (..., n) vector
    (entries beyond the matrix edge are zero)."""
    row = band[..., tw + k, :n]
    j = jnp.arange(n)
    return jnp.where(j - k >= 0, row, 0)


def band_set_diag(band: jax.Array, tw: int, k: int, vals: jax.Array) -> jax.Array:
    return band.at[..., tw + k, : vals.shape[-1]].set(vals)


def pad_columns(band: jax.Array, pad: int) -> jax.Array:
    """Zero-pad columns on the right so chase windows never clamp at the edge."""
    widths = [(0, 0)] * (band.ndim - 1) + [(0, pad)]
    return jnp.pad(band, widths)
