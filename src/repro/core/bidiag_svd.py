"""Stage 3: singular values of an upper-bidiagonal matrix.

Golub–Kahan form: the permuted matrix [[0, B^T], [B, 0]] is symmetric
tridiagonal of size 2n with zero diagonal and off-diagonal sequence
``z = (d_1, e_1, d_2, e_2, ..., e_{n-1}, d_n)``; its eigenvalues are ±sigma.
We count eigenvalues below a shift with a Sturm / LDL^T negative-pivot count
(stable zero-diagonal recurrence, cf. LAPACK ``bdsvdx``) and bisect —
embarrassingly parallel over singular values (vmapped), branch-free
(lax.fori_loop), dtype-polymorphic.

This is the same third stage the paper delegates to LAPACK BDSDC; a native JAX
implementation keeps the full pipeline on-device.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["gk_offdiag", "sturm_count", "bidiag_singular_values"]


def gk_offdiag(d: jax.Array, e: jax.Array) -> jax.Array:
    """Interleave (d, e) -> Golub–Kahan off-diagonal z of length 2n-1.

    d: (n,) main diagonal; e: (n,) with e[0] unused (e[i] = B[i-1, i]).
    """
    n = d.shape[0]
    z = jnp.zeros((2 * n - 1,), d.dtype)
    z = z.at[0::2].set(d)
    z = z.at[1::2].set(e[1:])
    return z


def sturm_count(z: jax.Array, lam: jax.Array) -> jax.Array:
    """#eigenvalues of the zero-diagonal tridiagonal (offdiag z) below ``lam``.

    LDL^T pivot recurrence  t_k = -lam - z_{k-1}^2 / t_{k-1},  t_1 = -lam,
    counting negative pivots; division guarded against exact zeros.
    """
    acc = jnp.float32 if z.dtype in (jnp.bfloat16, jnp.float16) else z.dtype
    z = z.astype(acc)
    lam = lam.astype(acc)
    tiny = jnp.asarray(jnp.finfo(acc).tiny * 4, acc)
    m = z.shape[0] + 1

    def body(k, carry):
        t, cnt = carry
        t = jnp.where(jnp.abs(t) < tiny, jnp.where(t < 0, -tiny, tiny), t)
        t_next = -lam - (z[k - 1] * z[k - 1]) / t
        return t_next, cnt + (t_next < 0)

    t0 = -lam
    cnt0 = (t0 < 0).astype(jnp.int32)
    _, cnt = jax.lax.fori_loop(1, m, body, (t0, cnt0))
    return cnt


@functools.partial(jax.jit, static_argnames=("max_iter",))
def bidiag_singular_values(d: jax.Array, e: jax.Array, *, max_iter: int = 0) -> jax.Array:
    """All singular values of the bidiagonal (d, e), descending.

    e[0] is ignored (convention: e[i] = B[i-1, i]).  Bisection on [0, bound]
    where bound = ||T_GK||_inf via Gershgorin.  Accepts stacked bidiagonals
    ``(..., n)`` — bisection is embarrassingly parallel across both singular
    values and batch, so the batch axes simply vmap.
    """
    if d.ndim > 1:
        lead = d.shape[:-1]
        fn = jax.vmap(lambda dd, ee: bidiag_singular_values(dd, ee,
                                                            max_iter=max_iter))
        out = fn(d.reshape((-1, d.shape[-1])), e.reshape((-1, e.shape[-1])))
        return out.reshape(lead + (d.shape[-1],))
    n = d.shape[0]
    acc = jnp.float32 if d.dtype in (jnp.bfloat16, jnp.float16) else d.dtype
    z = gk_offdiag(d.astype(acc), e.astype(acc))
    az = jnp.abs(z)
    pad = jnp.concatenate([jnp.zeros(1, acc), az, jnp.zeros(1, acc)])
    bound = jnp.max(pad[:-1] + pad[1:]) + jnp.asarray(1, acc)
    if max_iter == 0:
        max_iter = 60 if acc == jnp.float64 else 40

    # sigma_k (1-indexed ascending) = inf{ lam : count_sigma(lam) >= k },
    # count_sigma(lam) = sturm_count(z, lam) - n   (the n eigenvalues -sigma).
    ks = jnp.arange(1, n + 1)

    def solve_one(k):
        def body(_, lo_hi):
            lo, hi = lo_hi
            mid = 0.5 * (lo + hi)
            c = sturm_count(z, mid) - n
            return jnp.where(c >= k, lo, mid), jnp.where(c >= k, mid, hi)

        lo, hi = jax.lax.fori_loop(0, max_iter, body,
                                   (jnp.asarray(0, acc), bound))
        return 0.5 * (lo + hi)

    sig = jax.vmap(solve_one)(ks)
    return sig[::-1].astype(d.dtype)
