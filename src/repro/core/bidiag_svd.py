"""Stage 3: singular values of an upper-bidiagonal matrix.

Golub–Kahan form: the permuted matrix [[0, B^T], [B, 0]] is symmetric
tridiagonal of size 2n with zero diagonal and off-diagonal sequence
``z = (d_1, e_1, d_2, e_2, ..., e_{n-1}, d_n)``; its eigenvalues are ±sigma.
We count eigenvalues below a shift with a Sturm / LDL^T negative-pivot count
(stable zero-diagonal recurrence, cf. LAPACK ``bdsvdx``) and bisect —
embarrassingly parallel over singular values (vmapped), branch-free
(lax.fori_loop), dtype-polymorphic.

This is the same third stage the paper delegates to LAPACK BDSDC; a native JAX
implementation keeps the full pipeline on-device.

Singular VECTORS (``bidiag_svd``): inverse iteration on the same Golub–Kahan
tridiagonal, seeded by the bisection values.  The eigenvector of T_GK at
``+sigma`` interleaves the right and left bidiagonal vectors —
``x = (v_1, u_1, v_2, u_2, ...)/sqrt(2)`` with ``B v = sigma u`` — so one
guarded tridiagonal (Thomas) solve per value recovers both.  Like the
values, this is embarrassingly parallel over (singular value, batch) and
vmaps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["gk_offdiag", "sturm_count", "bidiag_singular_values",
           "bidiag_svd", "default_bisect_iters"]


def default_bisect_iters(acc) -> int:
    """Bisection sweeps that take the Gershgorin bracket below 1 ulp:
    60 halvings cover fp64's 52-bit mantissa plus headroom, 40 cover fp32."""
    return 60 if acc == jnp.float64 else 40


def _check_max_iter(max_iter):
    if max_iter is not None and max_iter < 1:
        raise ValueError(
            f"max_iter must be None (auto) or >= 1, got {max_iter}")


def gk_offdiag(d: jax.Array, e: jax.Array) -> jax.Array:
    """Interleave (d, e) -> Golub–Kahan off-diagonal z of length 2n-1.

    d: (n,) main diagonal; e: (n,) with e[0] unused (e[i] = B[i-1, i]).
    """
    n = d.shape[0]
    if n == 1:
        # degenerate fast path: the (2n-1,) = (1,) off-diagonal is just d —
        # the interleave below would strided-set an empty e slice.
        return d
    z = jnp.zeros((2 * n - 1,), d.dtype)
    z = z.at[0::2].set(d)
    z = z.at[1::2].set(e[1:])
    return z


def sturm_count(z: jax.Array, lam: jax.Array) -> jax.Array:
    """#eigenvalues of the zero-diagonal tridiagonal (offdiag z) below ``lam``.

    LDL^T pivot recurrence  t_k = -lam - z_{k-1}^2 / t_{k-1},  t_1 = -lam,
    counting negative pivots; division guarded against exact zeros.
    """
    acc = jnp.float32 if z.dtype in (jnp.bfloat16, jnp.float16) else z.dtype
    z = z.astype(acc)
    lam = lam.astype(acc)
    tiny = jnp.asarray(jnp.finfo(acc).tiny * 4, acc)
    m = z.shape[0] + 1

    def body(k, carry):
        t, cnt = carry
        t = jnp.where(jnp.abs(t) < tiny, jnp.where(t < 0, -tiny, tiny), t)
        t_next = -lam - (z[k - 1] * z[k - 1]) / t
        return t_next, cnt + (t_next < 0)

    t0 = -lam
    cnt0 = (t0 < 0).astype(jnp.int32)
    _, cnt = jax.lax.fori_loop(1, m, body, (t0, cnt0))
    return cnt


def _gk_prescale(z: jax.Array) -> jax.Array:
    """Exact power-of-two scale of max|z| (1 when z == 0): dividing it out
    keeps z^2 inside the exponent range for 1e-300..1e300 inputs (the Sturm
    pivots square z) without touching any mantissa bits."""
    acc = z.dtype
    zmax = jnp.max(jnp.abs(z))
    expo = jnp.round(jnp.log2(jnp.where(zmax > 0, zmax, 1)))
    return jnp.exp2(expo).astype(acc)


@functools.partial(jax.jit, static_argnames=("max_iter",))
def bidiag_singular_values(d: jax.Array, e: jax.Array, *,
                           max_iter: int | None = None) -> jax.Array:
    """All singular values of the bidiagonal (d, e), descending.

    e[0] is ignored (convention: e[i] = B[i-1, i]).  Bisection on [0, bound]
    where bound = ||T_GK||_inf via Gershgorin, after a power-of-two prescale
    so extreme input magnitudes neither overflow the squared Sturm pivots
    nor drown in the bracket's absolute slack.  ``max_iter=None`` picks the
    dtype-matched sweep count (:func:`default_bisect_iters`); an explicit
    value must be >= 1.  Accepts stacked bidiagonals ``(..., n)`` —
    bisection is embarrassingly parallel across both singular values and
    batch, so the batch axes simply vmap.
    """
    _check_max_iter(max_iter)
    if d.ndim > 1:
        lead = d.shape[:-1]
        fn = jax.vmap(lambda dd, ee: bidiag_singular_values(dd, ee,
                                                            max_iter=max_iter))
        out = fn(d.reshape((-1, d.shape[-1])), e.reshape((-1, e.shape[-1])))
        return out.reshape(lead + (d.shape[-1],))
    n = d.shape[0]
    if n == 1:
        # degenerate fast path (B is 1x1): sigma = |d| exactly — bisection
        # on an empty Sturm recurrence would only approximate it.
        return jnp.abs(d)
    acc = jnp.float32 if d.dtype in (jnp.bfloat16, jnp.float16) else d.dtype
    z = gk_offdiag(d.astype(acc), e.astype(acc))
    sc = _gk_prescale(z)
    z = z / sc
    az = jnp.abs(z)
    pad = jnp.concatenate([jnp.zeros(1, acc), az, jnp.zeros(1, acc)])
    bound = jnp.max(pad[:-1] + pad[1:]) + jnp.asarray(1, acc)
    if max_iter is None:
        max_iter = default_bisect_iters(acc)

    # sigma_k (1-indexed ascending) = inf{ lam : count_sigma(lam) >= k },
    # count_sigma(lam) = sturm_count(z, lam) - n   (the n eigenvalues -sigma).
    ks = jnp.arange(1, n + 1)

    def solve_one(k):
        def body(_, lo_hi):
            lo, hi = lo_hi
            mid = 0.5 * (lo + hi)
            c = sturm_count(z, mid) - n
            return jnp.where(c >= k, lo, mid), jnp.where(c >= k, mid, hi)

        lo, hi = jax.lax.fori_loop(0, max_iter, body,
                                   (jnp.asarray(0, acc), bound))
        return 0.5 * (lo + hi)

    sig = jax.vmap(solve_one)(ks)
    return (sig[::-1] * sc).astype(d.dtype)


# ---------------------------------------------------------------------------
# Singular vectors: inverse iteration on the Golub–Kahan tridiagonal
# ---------------------------------------------------------------------------

def _tridiag_solve(z: jax.Array, lam: jax.Array, b: jax.Array) -> jax.Array:
    """Solve (T - lam*I) x = b, T the zero-diagonal tridiagonal with
    off-diagonal ``z`` (m = len(z)+1).  Thomas elimination with pivots
    guarded away from zero — near-singular shifts are the POINT of inverse
    iteration (the guarded solve just scales the eigen-direction up).
    """
    acc = z.dtype
    eps = jnp.finfo(acc).eps
    tiny = eps * jnp.maximum(jnp.max(jnp.abs(z)), 1)

    def guard(p):
        return jnp.where(jnp.abs(p) < tiny, jnp.where(p < 0, -tiny, tiny), p)

    piv0 = guard(-lam)
    y0 = b[0] / piv0

    def fwd(carry, inp):
        piv_prev, y_prev = carry
        z_im1, b_i = inp
        c_im1 = z_im1 / piv_prev                 # elimination multiplier
        piv = guard(-lam - z_im1 * c_im1)
        y = (b_i - z_im1 * y_prev) / piv
        return (piv, y), (y, c_im1)

    (_, _), (ys, cs) = jax.lax.scan(fwd, (piv0, y0), (z, b[1:]))
    ys_full = jnp.concatenate([y0[None], ys])    # y_0 .. y_{m-1}

    def bwd(x_next, inp):
        y_i, c_i = inp
        x = y_i - c_i * x_next
        return x, x

    x_last = ys_full[-1]
    _, xs = jax.lax.scan(bwd, x_last, (ys_full[:-1], cs), reverse=True)
    return jnp.concatenate([xs, x_last[None]])


def _vectors_from_sigma(d: jax.Array, e: jax.Array, sig: jax.Array, *,
                        inv_iters: int = 2):
    """(U, V^T) of the bidiagonal (d, e) given its singular values ``sig``
    (descending) — ``inv_iters`` rounds of inverse iteration on the
    Golub–Kahan tridiagonal at each sigma, whose eigenvector interleaves
    (v, u), then cluster reorthogonalization + left/right re-pairing.

    sigma-agnostic on purpose: the values may come from bisection OR from
    the divide-and-conquer path (``core.bidiag_dc``) — any sigma accurate
    to a few ulps seeds the same vector machinery.  1-D inputs, n >= 2;
    callers own batching and the n == 1 fast path.
    """
    n = d.shape[0]
    dt = d.dtype
    acc = jnp.float32 if dt in (jnp.bfloat16, jnp.float16) else dt
    z = gk_offdiag(d.astype(acc), e.astype(acc))
    sc = _gk_prescale(z)
    z = z / sc
    m = 2 * n
    dd = d.astype(acc)
    ee = e.astype(acc)

    def vectors_one(lam, kidx):
        # deterministic, k-dependent start: decorrelates degenerate clusters
        t = jnp.arange(1, m + 1, dtype=acc)
        b0 = jnp.sin(t * (kidx.astype(acc) + 1) * jnp.asarray(0.7, acc)) \
            + jnp.asarray(0.01, acc)
        x = b0 / jnp.linalg.norm(b0)
        for _ in range(inv_iters):
            x = _tridiag_solve(z, lam, x)
            x = x / jnp.maximum(jnp.linalg.norm(x), jnp.finfo(acc).tiny)
        v = x[0::2]
        u = x[1::2]
        nv = jnp.linalg.norm(v)
        nu = jnp.linalg.norm(u)
        ok = jnp.minimum(nv, nu) > jnp.asarray(1e-6, acc)
        onehot = (jnp.arange(n) == kidx).astype(acc)
        v = jnp.where(ok, v / jnp.where(ok, nv, 1), onehot)
        u = jnp.where(ok, u / jnp.where(ok, nu, 1), onehot)
        return u, v

    us, vs = jax.vmap(vectors_one)(sig.astype(acc) / sc, jnp.arange(n))
    us, vs = _orthonormalize_pairs(us, vs, sig.astype(acc), dd, ee)
    return us.T.astype(dt), vs.astype(dt)


@functools.partial(jax.jit, static_argnames=("max_iter", "inv_iters"))
def bidiag_svd(d: jax.Array, e: jax.Array, *, max_iter: int | None = None,
               inv_iters: int = 2):
    """Full SVD of the upper bidiagonal (d, e): returns (U, sigma, V^T).

    sigma comes from the SAME bisection as :func:`bidiag_singular_values`
    (bit-identical — the vector path never recomputes values); vectors come
    from :func:`_vectors_from_sigma` (inverse iteration seeded by sigma).
    ``max_iter=None`` picks the dtype-matched bisection sweep count; an
    explicit value must be >= 1.  Accepts stacked bidiagonals ``(..., n)``
    (vmapped).
    """
    _check_max_iter(max_iter)
    if d.ndim > 1:
        lead = d.shape[:-1]
        fn = jax.vmap(lambda dd, ee: bidiag_svd(dd, ee, max_iter=max_iter,
                                                inv_iters=inv_iters))
        u, s, vt = fn(d.reshape((-1, d.shape[-1])),
                      e.reshape((-1, e.shape[-1])))
        n = d.shape[-1]
        return (u.reshape(lead + (n, n)), s.reshape(lead + (n,)),
                vt.reshape(lead + (n, n)))

    n = d.shape[0]
    dt = d.dtype
    sig = bidiag_singular_values(d, e, max_iter=max_iter)       # descending
    if n == 1:
        # 1x1 fast path: d = u * sigma * v with u = 1, v = sign(d).
        sgn = jnp.where(d[0] < 0, -1.0, 1.0).astype(dt)
        return (jnp.ones((1, 1), dt), sig, sgn[None, None])

    u, vt = _vectors_from_sigma(d, e, sig, inv_iters=inv_iters)
    return (u, sig, vt)


def _orthonormalize_pairs(us, vs, sig, dd, ee):
    """Cluster reorthogonalization + left/right re-pairing (cf. LAPACK stein).

    Plain inverse iteration gives independent but NOT orthogonal vectors
    inside a repeated/clustered sigma group.  Sequentially (descending k):
    Gram-Schmidt v_k against every earlier v_j whose sigma falls in the same
    cluster (generous 1e-3 relative width — for well-separated values the
    subtracted projections are ~eps and harmless), then re-derive the left
    vector from the pairing identity ``u_k = B v_k / ||B v_k||`` (exact for a
    true right vector, and automatically sign-aligned: u^T B v > 0).  For
    sigma ~ 0 the identity degenerates, so the zero cluster orthogonalizes
    the u's directly instead.  Rows of us/vs are vectors; O(n^2) per step.
    """
    acc = vs.dtype
    n = sig.shape[0]
    eps = jnp.finfo(acc).eps
    scale = jnp.maximum(sig[0], jnp.asarray(1, acc))
    ctol = jnp.asarray(1e-3, acc) * scale        # cluster width (relative)
    stol = jnp.sqrt(eps) * scale                 # below this: zero cluster
    tiny = jnp.finfo(acc).tiny
    karr = jnp.arange(n)

    def mgs(k, rows, vec, kidx):
        """vec minus its projection on rows[j] for prior same-cluster j,
        renormalized; falls back to an orthogonalized one-hot on collapse."""
        mask = ((karr < k) & ((sig - sig[k]) < ctol)).astype(acc)

        def clean(w):
            w = w - (mask * (rows @ w)) @ rows
            return w, jnp.linalg.norm(w)

        w1, n1 = clean(vec)
        w2, n2 = clean((karr == kidx).astype(acc))
        good = n1 > jnp.asarray(0.01, acc)
        return jnp.where(good, w1 / jnp.maximum(n1, tiny),
                         w2 / jnp.maximum(n2, tiny))

    def body(k, uv):
        us, vs = uv
        v = mgs(k, vs, vs[k], k)
        bv = dd * v + jnp.concatenate([ee[1:] * v[1:], jnp.zeros(1, acc)])
        nbv = jnp.linalg.norm(bv)
        u_zero = mgs(k, us, us[k], k)            # sigma ~ 0: pair is free
        u = jnp.where(sig[k] > stol, bv / jnp.maximum(nbv, tiny), u_zero)
        return us.at[k].set(u), vs.at[k].set(v)

    return jax.lax.fori_loop(0, n, body, (us, vs))
