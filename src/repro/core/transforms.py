"""Reflector-tape replay: turn recorded Householder tapes into U / V^T.

The values-only pipeline discards its orthogonal transforms; with
``tape=True`` each stage records them instead (DESIGN.md §8):

* stage 1 (``core/stage1.py``) — per-panel compact-WY blocks
  ``(V_qr, T_qr, V_lq, T_lq)``;
* stage 2 (``core/bulge_chasing.py``) — per (global cycle, wavefront slot)
  Householder pairs ``(v, tau)`` with static shapes ``(T, G, 2, tw+1)``.

This module replays those tapes into accumulators, producing ``U`` and
``V^T`` with ``A = U B V^T`` (B the bidiagonal the chase produced).  Both
accumulators are kept TRANSPOSED (``U^T`` and ``V^T``) so every recorded
reflector — left or right — is replayed as the same primitive: a compact-WY
*left* apply ``X <- (I - V T V^T) X``, dispatched through the kernel
registry (``kernels/ops.py::tape_apply``, with ``ref`` and ``pallas``
impls in ``kernels/hh_apply.py``).

The chase replay preserves the wavefront batching of the chase itself: per
global cycle, the G per-slot row slices of all B problems are gathered into
one fused ``tape_apply`` call over ``B*G`` slots (grid ``(B·G, stripes)``)
and scattered back — the 3-cycle separation that makes chase windows
disjoint also makes the replayed row ranges ``[p, p+tw]`` disjoint, so the
scatter is race-free.  Memory cost of a stage tape is ``O(n·tw)`` per cycle
(two ``(tw+1)``-reflectors per slot, ``G ~ n / (3 b_in)`` slots).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import bulge_chasing as bc

__all__ = ["ChaseTape", "accumulate_transforms", "replay_stage1",
           "replay_chase"]


@dataclasses.dataclass(frozen=True)
class ChaseTape:
    """Reflector tape of one chase stage (static schedule metadata + arrays).

    ``v``: (..., T, G, 2, tw+1) reflectors, pair axis = (right -> V,
    left -> U); ``tau``: (..., T, G, 2) with tau = 0 on inactive slots.
    A stage chased with fuse depth K >= 2 (DESIGN.md §9) records K pairs
    per (super-cycle, slot) instead: ``v (..., T, G, K, 2, tw+1)`` /
    ``tau (..., T, G, K, 2)``, with ``fuse`` carrying K so replay can
    recompute each fused cycle's pivot from the generalized schedule.
    """
    n: int
    b_in: int
    tw: int
    v: jax.Array
    tau: jax.Array
    fuse: int = 1


def _acc_dtype(dt):
    return jnp.float32 if dt in (jnp.bfloat16, jnp.float16) else dt


@functools.partial(jax.jit, static_argnames=("config",))
def replay_stage1(ut: jax.Array, vt: jax.Array, tape, *, config=None):
    """Replay the stage-1 panel tape into the transposed accumulators.

    ut/vt: (B, n, n) holding U^T / V^T so far.  Panel k recorded
    ``Q_k = I - Vq Tq Vq^T`` (left, QR) and ``R_k = I - Vl Tl Vl^T``
    (right, LQ) with ``A_banded = Q_P^T ... Q_0^T A R_0 ... R_P``; replay
    therefore left-applies ``Q_k^T = I - Vq Tq^T Vq^T`` to U^T (and the
    R_k analogue to V^T) in panel order.
    """
    from repro.kernels import ops

    vq, tq, vl, tl = tape
    n_panels = vq.shape[-3]

    def body(k, carry):
        ut, vt = carry
        ut = ops.tape_apply(vq[:, k], jnp.swapaxes(tq[:, k], -1, -2), ut,
                            config=config)
        vt = ops.tape_apply(vl[:, k], jnp.swapaxes(tl[:, k], -1, -2), vt,
                            config=config)
        return ut, vt

    return jax.lax.fori_loop(0, n_panels, body, (ut, vt))


@functools.partial(jax.jit, static_argnames=("n", "b_in", "tw", "config",
                                             "fuse"))
def replay_chase(ut: jax.Array, vt: jax.Array, tape_v: jax.Array,
                 tape_tau: jax.Array, *, n: int, b_in: int, tw: int,
                 config=None, fuse: int = 1):
    """Replay one chase stage's tape into the transposed accumulators.

    ut/vt: (B, n, n).  Reuses the chase schedule (``chase_cycle_indices``)
    to recover each slot's pivot — the tape stores only (v, tau), the row
    ranges are shape-derived, exactly like the chase's own window gather.
    Inactive slots were recorded with tau = 0 and are routed to disjoint
    dump rows (identity applies on scratch space).

    With ``fuse=K`` the tape holds K pairs per (super-cycle, slot); fused
    cycle i's row range ``[p + i*b_in, p + i*b_in + tw]`` is disjoint from
    its neighbours' (``b_in >= tw + 1``) exactly like the slots' are, so the
    whole super-cycle replays as ONE fused ``tape_apply`` over ``B*G*K``
    slots — the replay batches K-fold with the chase.
    """
    from repro.kernels import ops

    nsweeps, T, G = bc.stage_schedule(n, b_in, tw, fuse)
    if nsweeps == 0 or T == 0:
        return ut, vt
    B = ut.shape[0]
    K = fuse
    W = b_in + tw + 1
    k = tw + 1
    dump = n + W
    n_pad = dump + G * K * W
    pad = ((0, 0), (0, n_pad - n), (0, 0))
    utp = jnp.pad(ut, pad)
    vtp = jnp.pad(vt, pad)
    g_idx = jnp.arange(G)
    i_off = jnp.arange(K, dtype=jnp.int32) * b_in
    off = jnp.arange(k, dtype=jnp.int32)
    # (G, K) dump rows: one disjoint scratch range per (slot, fused cycle)
    dump_rows = dump + (g_idx[:, None] * K + jnp.arange(K)[None, :]) * W

    def cycle(t, carry):
        utp, vtp = carry
        _, _, p, active, _ = bc.chase_cycle_indices(t, g_idx, n, b_in, tw,
                                                    fuse)
        p_i = p[:, None] + i_off[None, :]                         # (G, K)
        act = active[:, None] & (p_i <= n - 1)
        p_safe = jnp.where(act, p_i, dump_rows).astype(jnp.int32)
        rows = p_safe[..., None] + off[None, None, :]             # (G, K, k)
        vs = tape_v[:, t].reshape(B, G, K, 2, k)
        ts = tape_tau[:, t].reshape(B, G, K, 2)

        def apply(side, acc):
            v = vs[:, :, :, side].reshape(B * G * K, k, 1)
            tau = ts[:, :, :, side].reshape(B * G * K, 1, 1)
            sl = acc[:, rows].reshape(B * G * K, k, n)
            out = ops.tape_apply(v, tau, sl, config=config)
            return acc.at[:, rows].set(out.reshape(B, G, K, k, n))

        return apply(1, utp), apply(0, vtp)                       # left->U, right->V

    utp, vtp = jax.lax.fori_loop(0, T, cycle, (utp, vtp))
    return utp[:, :n], vtp[:, :n]


def accumulate_transforms(n: int, *, s1_tape=None, chase_tapes=(),
                          lead: tuple = (), dtype=jnp.float64, config=None):
    """Replay all tapes from identity: returns (u, vt) with A = U B V^T.

    ``lead`` is the batch shape; accumulators run in the fp32-or-better
    accumulation dtype of ``dtype`` and are cast back at the end.
    """
    acc = _acc_dtype(jnp.dtype(dtype))
    b = 1
    for s in lead:
        b *= s
    eye = jnp.broadcast_to(jnp.eye(n, dtype=acc), (b, n, n))
    ut, vt = eye, eye
    if s1_tape is not None:
        flat = tuple(x.reshape((b,) + x.shape[len(lead):]).astype(acc)
                     for x in s1_tape)
        with obs.span("replay_stage1", n=int(n), batch=b) as sp:
            ut, vt = obs.traced_jit_call("replay_stage1", replay_stage1,
                                         ut, vt, flat, config=config)
            sp.fence((ut, vt))
    for tape in chase_tapes:
        tv = tape.v.reshape((b,) + tape.v.shape[len(lead):]).astype(acc)
        tt = tape.tau.reshape((b,) + tape.tau.shape[len(lead):]).astype(acc)
        with obs.span("replay_chase", n=tape.n, b_in=tape.b_in, tw=tape.tw,
                      fuse=tape.fuse) as sp:
            ut, vt = obs.traced_jit_call(
                "replay_chase", replay_chase, ut, vt, tv, tt, n=tape.n,
                b_in=tape.b_in, tw=tape.tw, config=config, fuse=tape.fuse)
            sp.fence((ut, vt))
    u = jnp.swapaxes(ut, -1, -2)
    out_dt = jnp.dtype(dtype)
    return (u.reshape(lead + (n, n)).astype(out_dt),
            vt.reshape(lead + (n, n)).astype(out_dt))
