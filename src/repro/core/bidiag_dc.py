"""Stage 3, divide-and-conquer backend: bidiagonal singular values that
scale with n (DESIGN.md §14).

The bisection path (``core.bidiag_svd``) does 60 sequential Sturm sweeps of
depth 2n per singular value — its critical path grows like n even though the
roots are independent.  This module solves the same Golub–Kahan (GK)
tridiagonal ``[[0, B^T], [B, 0]]`` by Cuppen's divide-and-conquer instead:

  split   T = diag(T1', T2') + rho * u u^T  at the middle off-diagonal
          (rho = |b_mid|, u = e_p + sign(b_mid) e_{p+1}; the boundary
          diagonal entries of the halves absorb -rho),
  leaves  generalized Sturm bisection — the same guarded LDL^T pivot
          recurrence as the existing path, extended to a nonzero diagonal —
          below the ``leaf_n`` cutoff, plus guarded inverse iteration for
          the leaf eigenvector rows,
  merge   bottom-up through the secular equation
          1 + rho * sum_i z_i^2 / (d_i - mu) = 0: deflation first
          (negligible z components, then near-equal poles via a Givens
          scan), then a vectorized fixed-iteration-count safeguarded Newton
          solve across ALL batch x subproblem x root axes at once — every
          merge level is ONE dispatch, not a per-root loop.

Only the spectrum and the FIRST and LAST eigenvector rows (f, l) are carried
through the recursion — that is all a parent merge needs to form its z
vector (z = concat(l_left, sign * f_right)) — so the per-level state is
O(m), not O(m^2).  Stability of the merge follows Gu/Eisenstat: after the
roots are found, z is RECOMPUTED from the Loewner interlacing identity
(all factors positive, evaluated as log1p sums) so eigenvector weights stay
accurate even for tightly clustered poles.

Odd / non-power-of-two sizes are padded with decoupled sentinel poles below
the spectrum; they deflate for free at every merge and are sliced off at the
end.  Deflation is exploited STRUCTURALLY, not just numerically: actives
form a contiguous prefix after the merge partitions, so every full-width
pass (secular f evaluations, the Loewner product, the eigenvector-row sums)
runs as a blocked reduction whose all-deflated blocks are skipped by a
``lax.cond`` at run time — a random n=4k spectrum keeps ~1.5% of its poles
active at the top merge, and the skips turn that into wall-clock.  The cost
is that batches go through ``lax.map`` (sequential per matrix), not vmap:
vmap would lower the skip conds to both-branch selects.

``sigma``-agreement with the bisection oracle to <= 1e-12 (fp64) gates this
module in CI (tests/test_bidiag_dc.py, benchmarks/stage3.py --check).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from .bidiag_svd import (_gk_prescale, _vectors_from_sigma,
                         bidiag_singular_values, bidiag_svd,
                         default_bisect_iters, gk_offdiag)

__all__ = ["DEFAULT_DC_LEAF_N", "DEFAULT_DC_N_MIN",
           "bidiag_dc_singular_values", "bidiag_dc_svd"]

# Bidiagonal sizes at or below this solve with the existing bisection path
# outright; inside a D&C recursion it is also the leaf width (GK leaves are
# 2*leaf_n).  32 keeps the leaf bisection's sequential depth trivial while
# the merge tree stays shallow (log2(n/32) levels).
DEFAULT_DC_LEAF_N = 32

# Static crossover: below this n the bisection path wins (its critical path
# is short and it skips the merge-tree overhead); ``stage3="auto"`` uses the
# autotune-cache measurement instead when one exists (DESIGN.md §14).
DEFAULT_DC_N_MIN = 2048

# Roots per secular-solve block: bounds the (m, chunk) broadcast that each
# full-width secular pass materializes, so the top-level merge of an n=16k
# problem never asks for an O(m^2) temporary in one piece.
_SECULAR_CHUNK = 512

# Poles gathered around each root for the windowed model iteration: the
# middle-way updates run against the K index-nearest poles exactly plus a
# first-order (value + slope) far-field model frozen at the interval
# midpoint.  Far poles contribute a function that is smooth across one
# pole gap, so the linearization error sits orders of magnitude inside
# what the exact polish passes absorb, while the per-iteration work drops
# from m*m to m*K (~64x at n=8k).
_DC_WINDOW_K = 128

# Globally heaviest poles added to every root's window regardless of index
# distance.  GK eigenvectors of random bidiagonals localize, so z^2 spans
# many orders of magnitude and an index-far pole can carry O(1) of the
# rank-one mass — linearizing across such a pole is what breaks the
# far-field model (observed ~1e-3 model roots).  Gathering the top-K
# weights keeps the residual far field made of LIGHT poles only, for which
# the first-order model holds.
_DC_HEAVY_K = 32

# Cap on the exact full-width middle-way passes after the windowed
# iteration, run against the ORIGINAL safeguard bracket (the windowed
# phase brackets on MODEL signs, which must not constrain the true root).
# The loop exits as soon as EVERY active root's residual reaches the
# rounding floor of its secular sum — typically 3-5 passes from the
# windowed start — so the cap only bounds adversarial spectra.  These
# passes dominate large-n merge cost: the early exit is the dc-vs-bisect
# crossover lever.
_DC_POLISH_ITERS = 12


def _acc_dtype(dt):
    return jnp.float32 if dt in (jnp.bfloat16, jnp.float16) else dt


# ---------------------------------------------------------------------------
# Leaves: generalized Sturm bisection + inverse iteration
# ---------------------------------------------------------------------------

def _tridiag_count(a: jax.Array, b: jax.Array, lam: jax.Array) -> jax.Array:
    """#eigenvalues below ``lam`` of the symmetric tridiagonal (diag a,
    offdiag b) — the zero-diagonal ``sturm_count`` recurrence with the
    diagonal restored: q_k = (a_k - lam) - b_{k-1}^2 / q_{k-1}."""
    acc = a.dtype
    tiny = jnp.asarray(jnp.finfo(acc).tiny * 4, acc)
    m = a.shape[0]

    def body(k, carry):
        q, cnt = carry
        q = jnp.where(jnp.abs(q) < tiny, jnp.where(q < 0, -tiny, tiny), q)
        q_next = (a[k] - lam) - (b[k - 1] * b[k - 1]) / q
        return q_next, cnt + (q_next < 0)

    q0 = a[0] - lam
    cnt0 = (q0 < 0).astype(jnp.int32)
    _, cnt = jax.lax.fori_loop(1, m, body, (q0, cnt0))
    return cnt


def _tridiag_solve_diag(a: jax.Array, b: jax.Array, lam: jax.Array,
                        rhs: jax.Array) -> jax.Array:
    """Solve (T - lam*I) x = rhs for symmetric tridiagonal T (diag a, offdiag
    b): Thomas elimination with pivots guarded away from zero, exactly as the
    zero-diagonal ``_tridiag_solve`` — near-singular shifts are the point."""
    acc = a.dtype
    eps = jnp.finfo(acc).eps
    tiny = eps * jnp.maximum(
        jnp.maximum(jnp.max(jnp.abs(a)), jnp.max(jnp.abs(b))), 1)

    def guard(p):
        return jnp.where(jnp.abs(p) < tiny, jnp.where(p < 0, -tiny, tiny), p)

    piv0 = guard(a[0] - lam)
    y0 = rhs[0] / piv0

    def fwd(carry, inp):
        piv_prev, y_prev = carry
        a_i, b_im1, r_i = inp
        c_im1 = b_im1 / piv_prev
        piv = guard(a_i - lam - b_im1 * c_im1)
        y = (r_i - b_im1 * y_prev) / piv
        return (piv, y), (y, c_im1)

    (_, _), (ys, cs) = jax.lax.scan(fwd, (piv0, y0), (a[1:], b, rhs[1:]))
    ys_full = jnp.concatenate([y0[None], ys])

    def bwd(x_next, inp):
        y_i, c_i = inp
        x = y_i - c_i * x_next
        return x, x

    x_last = ys_full[-1]
    _, xs = jax.lax.scan(bwd, x_last, (ys_full[:-1], cs), reverse=True)
    return jnp.concatenate([xs, x_last[None]])


def _leaf_eigen(a: jax.Array, b: jax.Array, *, bisect_iters: int,
                inv_iters: int):
    """Full spectrum (ascending) + first/last eigenvector rows of one leaf.

    Values by the generalized Sturm bisection above (all eigenvalue indices
    bracket-refined in lockstep); vectors by guarded inverse iteration with
    deterministic k-dependent starts and a sequential same-cluster
    Gram-Schmidt (the leaf-size analog of ``_orthonormalize_pairs``).
    """
    acc = a.dtype
    lm = a.shape[0]
    ab = jnp.abs(b)
    pad = jnp.concatenate([jnp.zeros(1, acc), ab, jnp.zeros(1, acc)])
    rad = pad[:-1] + pad[1:]
    scale = jnp.maximum(jnp.max(jnp.abs(a) + rad), jnp.asarray(1, acc))
    lo0 = jnp.min(a - rad) - jnp.finfo(acc).eps * scale
    hi0 = jnp.max(a + rad) + jnp.finfo(acc).eps * scale
    ks = jnp.arange(lm)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = jax.vmap(lambda x: _tridiag_count(a, b, x))(mid)
        ge = cnt >= ks + 1
        return jnp.where(ge, lo, mid), jnp.where(ge, mid, hi)

    lo, hi = jax.lax.fori_loop(
        0, bisect_iters, body,
        (jnp.full((lm,), lo0, acc), jnp.full((lm,), hi0, acc)))
    lam = 0.5 * (lo + hi)

    def vec_one(lamk, kidx):
        t = jnp.arange(1, lm + 1, dtype=acc)
        x0 = jnp.sin(t * (kidx.astype(acc) + 1) * jnp.asarray(0.7, acc)) \
            + jnp.asarray(0.01, acc)
        x = x0 / jnp.linalg.norm(x0)
        for _ in range(inv_iters):
            x = _tridiag_solve_diag(a, b, lamk, x)
            x = x / jnp.maximum(jnp.linalg.norm(x), jnp.finfo(acc).tiny)
        return x

    vecs = jax.vmap(vec_one)(lam, ks)            # rows are eigenvectors

    # Sequential same-cluster Gram-Schmidt: inverse iteration returns
    # near-parallel vectors inside a (near-)degenerate group; project each
    # against its earlier cluster mates, with an orthogonalized one-hot as
    # the collapse fallback (mirrors _orthonormalize_pairs).
    eps = jnp.finfo(acc).eps
    ctol = jnp.maximum(jnp.asarray(1e-3, acc) * scale,
                       jnp.asarray(64, acc) * eps * scale)
    tiny = jnp.finfo(acc).tiny

    def body_k(k, rows):
        mask = ((ks < k) & (lam[k] - lam < ctol)).astype(acc)

        def clean(w):
            w = w - (mask * (rows @ w)) @ rows
            return w, jnp.linalg.norm(w)

        w1, n1 = clean(rows[k])
        w2, n2 = clean((ks == k).astype(acc))
        good = n1 > jnp.asarray(0.01, acc)
        v = jnp.where(good, w1 / jnp.maximum(n1, tiny),
                      w2 / jnp.maximum(n2, tiny))
        return rows.at[k].set(v)

    vecs = jax.lax.fori_loop(1, lm, body_k, vecs)
    return lam, vecs[:, 0], vecs[:, -1]


# ---------------------------------------------------------------------------
# Merge: deflation + one vectorized secular solve per level
# ---------------------------------------------------------------------------

def _chunked_cols(fn, tree, m: int):
    """Apply ``fn`` (pytree of (..., c) blocks -> pytree of (..., c) blocks)
    over the last axis in ``_SECULAR_CHUNK``-wide blocks via ``lax.map`` so
    the per-block broadcast stays bounded; single call when m is small."""
    if m <= _SECULAR_CHUNK:
        return fn(tree)
    nb = m // _SECULAR_CHUNK

    def reshape_in(x):
        blk = x.reshape(x.shape[:-1] + (nb, _SECULAR_CHUNK))
        return jnp.moveaxis(blk, -2, 0)

    def reshape_out(x):
        return jnp.moveaxis(x, 0, -2).reshape(
            x.shape[1:-1] + (nb * _SECULAR_CHUNK,))

    out = jax.lax.map(fn, jax.tree.map(reshape_in, tree))
    return jax.tree.map(reshape_out, out)


def _axis_blocks(tree, m: int):
    """Stack (..., m) leaves into (nb, ..., CH) reduction blocks (nb = 1
    when m fits one chunk) for a skip-capable blocked sum."""
    if m <= _SECULAR_CHUNK:
        return jax.tree.map(lambda x: x[None], tree)
    nb = m // _SECULAR_CHUNK

    def r(x):
        blk = x.reshape(x.shape[:-1] + (nb, _SECULAR_CHUNK))
        return jnp.moveaxis(blk, -2, 0)

    return jax.tree.map(r, tree)


def _skip_block_sum(fn, blocks, pred_fn, proto):
    """``sum_b fn(block_b)`` over the leading block axis, with blocks where
    ``pred_fn(block)`` is False contributing zeros WITHOUT doing the work.

    This is where deflation turns into wall-clock: active poles/roots form a
    contiguous prefix after the merge partitions, so all-deflated blocks —
    the vast majority at the top merge levels of a random spectrum — reduce
    to one predicate evaluation.  The predicate must stay a SCALAR for
    ``lax.cond`` to stay a branch (vmap would lower it to a select that runs
    both sides), which is why the drivers batch with ``lax.map``, not vmap.
    ``proto`` is a zeros pytree of one block's output."""
    def one(blk):
        return jax.lax.cond(pred_fn(blk), fn, lambda _: proto, blk)

    parts = jax.lax.map(one, blocks)
    return jax.tree.map(lambda x: jnp.sum(x, axis=0), parts)


def _secular_roots(d, w, gap, act, d_next, a_next, *, newton_iters: int):
    """Roots mu_j of 1 + sum_i w_i / (d_i - mu) = 0, one per ACTIVE pole,
    mu_j in (d_j, d_j + gap_j), returned as (anchor, tau) with
    mu_j = anchor_j + tau_j.

    Each root is anchored at its NEAREST pole (chosen from the sign of f at
    the interval midpoint, as dlaed4 does): a root hugging the upper pole is
    represented as a small negative shift from d_{j+1} instead of a
    nearly-cancelling ``gap + tiny`` shift from d_j, which is what keeps
    pole distances ``d_i - mu_j`` computable to full relative accuracy both
    here and in the downstream Loewner / eigenvector-row formulas.

    The iteration is the dlaed4 "middle way": fit
    ``c + s/(D1-eta) + S/(D2-eta)`` matching f AND f' at the current
    iterate (mass split by the psi'/phi' one-sided derivative sums between
    the two bracketing poles) and jump to the model root — quadratic near
    convergence, monotone globally.  A sign-driven bracket with midpoint
    fallback safeguards every step, and an iterate whose residual reaches
    the rounding floor of the secular sum is frozen so noise-level sign
    flips cannot un-converge it.  All roots advance in lockstep, so a merge
    level is one fixed-shape dispatch rather than a per-root loop.

    Cost shape (the reason dc beats bisection at large n): only ONE
    full-width secular evaluation per root (the midpoint pass, which picks
    the anchor AND freezes a first-order model of the far field) plus
    ``_DC_POLISH_ITERS`` exact passes at the end; the ``newton_iters``
    middle-way updates in between run against the ``_DC_WINDOW_K``
    index-nearest poles exactly with the far field linearized, m*K work
    instead of m*m.  Far-pole sums are smooth across one pole gap, so the
    model root lands within the linearization error and the bracketed
    exact polish converges it to the rounding floor."""
    acc = d.dtype
    eps = jnp.finfo(acc).eps
    m = d.shape[-1]
    one = jnp.asarray(1, acc)
    zero = jnp.asarray(0, acc)
    iarr = jnp.arange(m)
    kwin = min(_DC_WINDOW_K, m)
    # Pole-axis reduction blocks, shared by every full-width pass: blocks
    # whose weights are all zero (the deflated suffix) are skipped at run
    # time, so a heavily deflated merge pays for its ACTIVE poles only.
    pblocks = _axis_blocks({"d": d, "w": w, "i": iarr}, m)

    def active_block(blk):
        dj, gapj, actj, jidx, dnx, nxtj = (
            blk["d"], blk["gap"], blk["act"], blk["idx"], blk["dnx"],
            blk["nxt"])
        gap_safe = jnp.where(actj & (gapj > 0), gapj, one)
        half = 0.5 * gap_safe

        def full_sums(anc_, t):
            # One-sided sums at mu = anc + t: psi (poles i <= j, all terms
            # <= 0 since w >= 0 and d_i <= d_j < mu), phi (i > j, terms
            # >= 0), and their derivative splits.  The sign structure makes
            # the |.|-scale free (sum|terms| = phi - psi, an ADDITION of
            # magnitudes) and the w == 0 guard exact (0/1 == 0).  psi'/phi'
            # must stay separate masked reductions: both are positive, so
            # deriving one as ``total' - other'`` cancels catastrophically
            # for a root hugging one pole, and a garbage off-side slope
            # degrades the middle-way step to bracket bisection.
            tc = t[..., None, :]
            ancc = anc_[..., None, :]
            proto = (jnp.zeros_like(t),) * 4

            def one_blk(pb):
                wcb = pb["w"][..., :, None]
                leftb = pb["i"][..., :, None] <= jidx[..., None, :]
                denom = (pb["d"][..., :, None] - ancc) - tc
                safe = jnp.where(wcb == 0, one, denom)
                r = wcb / safe
                r2 = r / safe
                tot = jnp.sum(r, axis=-2)
                psi = jnp.sum(jnp.where(leftb, r, zero), axis=-2)
                psip = jnp.sum(jnp.where(leftb, r2, zero), axis=-2)
                phip = jnp.sum(jnp.where(leftb, zero, r2), axis=-2)
                return psi, tot - psi, psip, phip

            return _skip_block_sum(one_blk, pblocks,
                                   lambda pb: jnp.any(pb["w"] != 0), proto)

        # Index-nearest pole window per root (clipped at the spectrum ends;
        # out-of-range slots carry zero weight so they drop out of every
        # sum), plus the _DC_HEAVY_K globally heaviest poles (zeroed where
        # they duplicate an index-window slot).  Gathered once per block —
        # the windowed loop streams only (..., chunk, K) arrays.
        base = jidx[..., None] - (kwin // 2) + jnp.arange(kwin)
        gidx = jnp.clip(base, 0, m - 1)
        flat = gidx.reshape(gidx.shape[:-2] + (-1,))
        dw = jnp.take_along_axis(d, flat, axis=-1).reshape(gidx.shape)
        ww = jnp.take_along_axis(w, flat, axis=-1).reshape(gidx.shape)
        ww = jnp.where((base >= 0) & (base < m), ww, zero)
        leftw = base <= jidx[..., None]

        ktop = min(_DC_HEAVY_K, m)
        wt, hidx = jax.lax.top_k(w, ktop)                # (..., ktop)
        dh = jnp.take_along_axis(d, hidx, axis=-1)
        hcol = hidx[..., None, :]                        # (..., 1, ktop)
        bmin = jidx[..., None] - (kwin // 2)
        wh = jnp.where((hcol >= bmin) & (hcol < bmin + kwin),
                       zero, wt[..., None, :])           # (..., c, ktop)
        lefth = hcol <= jidx[..., None]

        def win_sums(deltaw, wwc, leftc, t):
            denomw = deltaw - t[..., None]
            safew = jnp.where(wwc == 0, one, denomw)
            rw = wwc / safew
            rw2 = rw / safew
            totw = jnp.sum(rw, axis=-1)
            psiw = jnp.sum(jnp.where(leftc, rw, zero), axis=-1)
            psipw = jnp.sum(jnp.where(leftc, rw2, zero), axis=-1)
            phipw = jnp.sum(jnp.where(leftc, zero, rw2), axis=-1)
            return psiw, totw - psiw, psipw, phipw

        def near_sums(dwin, dhvy, t):
            pw, fw, ppw, fpw = win_sums(dwin, ww, leftw, t)
            ph, fh, pph, fph = win_sums(dhvy, wh, lefth, t)
            return pw + ph, fw + fh, ppw + pph, fpw + fph

        def mw_update(f, fscale, psip, phip, t, lo, hi):
            # At |f| ~ eps * sum|terms| the root is resolved to rounding;
            # freeze it so a sign flip in the noise cannot un-converge t
            # (the midpoint fallback would teleport it back to mid-bracket).
            done = jnp.abs(f) <= 8 * eps * fscale
            upd = ~done
            lo = jnp.where(upd & (f < 0), t, lo)
            hi = jnp.where(upd & (f >= 0), t, hi)
            # Middle-way step: c*eta^2 - a*eta + b = 0 with
            #   a = (D1+D2) f - D1 D2 f',  b = D1 D2 f,
            #   c = f - D1 psi' - D2 phi',
            # D1/D2 the (anchor-relative) distances to the bracketing poles.
            d1 = -off - t
            d2 = (gap_safe - off) - t
            fp = psip + phip
            aq = (d1 + d2) * f - d1 * d2 * fp
            bq = d1 * d2 * f
            cq = f - d1 * psip - d2 * phip
            disc = jnp.sqrt(jnp.maximum(aq * aq - 4 * bq * cq, 0))
            eta_pos = 2 * bq / (aq + disc)
            eta_neg = (aq - disc) / (2 * jnp.where(cq == 0, one, cq))
            eta = jnp.where(aq > 0, eta_pos,
                            jnp.where(cq == 0,
                                      bq / jnp.where(aq == 0, one, aq),
                                      eta_neg))
            cand = t + eta
            inside = (cand > lo) & (cand < hi)
            t_new = jnp.where(inside, cand, 0.5 * (lo + hi))
            return jnp.where(done, t, t_new), lo, hi

        # THE full-width midpoint pass: f0's sign picks the nearest-pole
        # anchor, and subtracting the window's share leaves the far field's
        # value and slope at the midpoint mu0 = d_j + gap/2 — the frozen
        # linear model the windowed iteration adds to its exact near sums.
        # Sign clamps keep the far parts on the right side of zero when the
        # subtraction is all cancellation (window covers everything).
        psi0, phi0, psip0, phip0 = full_sums(dj, half)
        f0 = 1 + psi0 + phi0
        psiw0, phiw0, psipw0, phipw0 = near_sums(
            dw - dj[..., None], dh[..., None, :] - dj[..., None], half)
        psi_f = jnp.minimum(psi0 - psiw0, zero)
        phi_f = jnp.maximum(phi0 - phiw0, zero)
        psip_f = jnp.maximum(psip0 - psipw0, zero)
        phip_f = jnp.maximum(phip0 - phipw0, zero)

        # Nearest-pole anchor: f(mid) < 0 puts the root in the upper half,
        # so shift the origin to the next pole (when one exists; the top
        # root's upper end is the sum_w bound, not a pole — stay at d_j).
        upper = (f0 < 0) & nxtj
        anc = jnp.where(upper, dnx, dj)
        off = jnp.where(upper, gap_safe, zero)           # anc - d_j
        lo0 = jnp.where(upper, -half,
                        jnp.where(f0 < 0, half, zero))
        hi0 = jnp.where(upper, zero,
                        jnp.where(f0 < 0, gap_safe, half))

        deltaw = dw - anc[..., None]                     # exact: both poles
        deltah = dh[..., None, :] - anc[..., None]

        def wbody(_, state):
            t, lo, hi = state
            s = (off - half) + t                         # mu - mu0
            psiw, phiw, psipw, phipw = near_sums(deltaw, deltah, t)
            psi_m = psi_f + psip_f * s + psiw
            phi_m = phi_f + phip_f * s + phiw
            f = 1 + psi_m + phi_m
            fscale = 1 + jnp.abs(phi_m) + jnp.abs(psi_m)
            return mw_update(f, fscale, psip_f + psipw, phip_f + phipw,
                             t, lo, hi)

        t0 = 0.5 * (lo0 + hi0)
        t1, _, _ = jax.lax.fori_loop(0, newton_iters, wbody, (t0, lo0, hi0))
        # The windowed bracket moved on MODEL signs — discard it.  Polish
        # restarts from the original bracket; a model root that escaped it
        # (far-field error beyond the gap, only possible for near-deflated
        # noise roots) falls back to the midpoint.
        t1 = jnp.where((t1 > lo0) & (t1 < hi0), t1, t0)

        def pcond(state):
            it, _, _, _, quiet = state
            return (it < _DC_POLISH_ITERS) & ~quiet

        def pbody(state):
            it, t, lo, hi, _ = state
            psi, phi, psip, phip = full_sums(anc, t)
            f = 1 + psi + phi
            fscale = 1 + phi - psi
            t_new, lo, hi = mw_update(f, fscale, psip, phip, t, lo, hi)
            # Exit once every active root in the block is frozen at its
            # rounding floor — the freeze predicate inside mw_update, one
            # step behind (a root converging THIS pass exits NEXT pass).
            quiet = jnp.all((jnp.abs(f) <= 8 * eps * fscale) | ~actj)
            return it + 1, t_new, lo, hi, quiet

        _, t, _, _, _ = jax.lax.while_loop(
            pcond, pbody,
            (jnp.asarray(0), t1, lo0, hi0, jnp.asarray(False)))
        return {"anc": jnp.where(actj, anc, dj),
                "tau": jnp.where(actj, t, zero)}

    def solve_block(blk):
        # Root-chunk skip: active roots are a contiguous prefix, so chunks
        # past it (most of the spectrum at a heavily deflated merge) return
        # mu = d_j without touching the window gathers or any secular pass.
        return jax.lax.cond(
            jnp.any(blk["act"]), active_block,
            lambda b: {"anc": b["d"], "tau": jnp.zeros_like(b["d"])}, blk)

    tree = {"d": d, "gap": gap, "act": act, "dnx": d_next, "nxt": a_next,
            "idx": jnp.broadcast_to(iarr, d.shape)}
    out = _chunked_cols(solve_block, tree, m)
    return out["anc"], out["tau"]


def _merge_pair(d1, f1, l1, d2, f2, l2, rho_b, *, newton_iters: int,
                need_rows: bool = True):
    """One merge level: children (ascending spectra + first/last eigenvector
    rows, stacked on the leading axes) -> parent triple of twice the size.
    ``rho_b`` is the signed coupling off-diagonal.

    ``need_rows=False`` (the TOP level, whose output feeds no parent merge)
    skips the Loewner z-recomputation and the f/l row passes — two of the
    level's O(m^2) sweeps — and returns zero rows."""
    acc = d1.dtype
    eps = jnp.finfo(acc).eps
    h = d1.shape[-1]
    m = 2 * h
    rho = jnp.abs(rho_b)[..., None]                          # (..., 1)
    sgn = jnp.where(rho_b < 0, -1.0, 1.0).astype(acc)[..., None]

    d = jnp.concatenate([d1, d2], axis=-1)
    z = jnp.concatenate([l1, sgn * f2], axis=-1)
    fe = jnp.concatenate([f1, jnp.zeros_like(f2)], axis=-1)
    le = jnp.concatenate([jnp.zeros_like(l1), l2], axis=-1)

    order = jnp.argsort(d, axis=-1)
    take = lambda x: jnp.take_along_axis(x, order, axis=-1)  # noqa: E731
    d, z, fe, le = take(d), take(z), take(fe), take(le)

    norm_scale = jnp.max(jnp.abs(d), axis=-1, keepdims=True) + 2 * rho
    tol = jnp.maximum(8 * eps * norm_scale,
                      jnp.asarray(jnp.finfo(acc).tiny * 16, acc))

    # -- deflation pass 1: negligible rank-one weight ------------------------
    active = rho * jnp.abs(z) > tol

    # Partition: active poles first (still ascending — stable sort), deflated
    # last.  Adjacent-pole deflation and the secular brackets then only ever
    # look at neighbors inside a contiguous active prefix.
    part = jnp.argsort(jnp.where(active, 0, 1), axis=-1, stable=True)
    takep = lambda x: jnp.take_along_axis(x, part, axis=-1)  # noqa: E731
    d, z, fe, le, active = (takep(d), takep(z), takep(fe), takep(le),
                            takep(active))

    # -- deflation pass 2: near-equal poles (Givens scan) --------------------
    # Sequentially fold runs of near-equal active poles together: rotate the
    # pair so one z component vanishes, hand its (weighted) pole over as a
    # deflated eigenvalue, and keep accumulating mass in the survivor.  The
    # dropped off-diagonal |c*s*(d_i - d_c)| <= tol is the deflation error.
    def scan_step(carry, col):
        d_c, z_c, f_c, l_c, a_c = carry
        d_i, z_i, f_i, l_i, a_i = col
        r2 = z_c * z_c + z_i * z_i
        r = jnp.sqrt(r2)
        r_safe = jnp.where(r > 0, r, jnp.asarray(1, acc))
        cg = jnp.where(r > 0, z_i / r_safe, jnp.asarray(1, acc))
        sg = jnp.where(r > 0, z_c / r_safe, jnp.asarray(0, acc))
        off = jnp.abs(cg * sg * (d_i - d_c))
        mrg = a_c & a_i & (off <= tol[..., 0])
        emit = (jnp.where(mrg, cg * cg * d_c + sg * sg * d_i, d_c),
                jnp.where(mrg, jnp.asarray(0, acc), z_c),
                jnp.where(mrg, cg * f_c - sg * f_i, f_c),
                jnp.where(mrg, cg * l_c - sg * l_i, l_c),
                a_c & ~mrg)
        # The rotation moves BOTH diagonal entries (dlaed2 does the same):
        # the deflation criterion also fires for well-separated poles with
        # very imbalanced z, where the surviving pole lands near d_c, not
        # d_i — keeping d_i would hang the combined weight on the wrong
        # pole.  Both new values stay inside [d_c, d_i], so the ascending
        # active order survives.
        new = (jnp.where(mrg, sg * sg * d_c + cg * cg * d_i, d_i),
               jnp.where(mrg, r, z_i),
               jnp.where(mrg, sg * f_c + cg * f_i, f_i),
               jnp.where(mrg, sg * l_c + cg * l_i, l_i),
               a_i)
        return new, emit

    cols = tuple(jnp.moveaxis(x, -1, 0) for x in (d, z, fe, le, active))
    init = tuple(c[0] for c in cols)
    rest = tuple(c[1:] for c in cols)
    last, emitted = jax.lax.scan(scan_step, init, rest)
    d, z, fe, le, active = tuple(
        jnp.moveaxis(jnp.concatenate([em, la[None]], axis=0), 0, -1)
        for em, la in zip(emitted, last))

    # Re-partition: the Givens pass punches holes in the active prefix (an
    # emitted survivor pair leaves a deflated slot mid-prefix); without this
    # second stable partition a root below such a hole would see a_next ==
    # False and get the top-of-spectrum bracket instead of its real
    # next-active-pole gap.  The scan keeps d ascending among actives, so a
    # stable actives-first sort restores a contiguous ascending prefix.
    part = jnp.argsort(jnp.where(active, 0, 1), axis=-1, stable=True)
    d, z, fe, le, active = (takep(d), takep(z), takep(fe), takep(le),
                            takep(active))

    # -- secular solve over the active prefix --------------------------------
    w = jnp.where(active, rho * z * z, jnp.asarray(0, acc))
    sum_w = jnp.sum(w, axis=-1, keepdims=True)
    d_next = jnp.concatenate(
        [d[..., 1:], jnp.zeros_like(d[..., :1])], axis=-1)
    a_next = jnp.concatenate(
        [active[..., 1:], jnp.zeros_like(active[..., :1])], axis=-1)
    gap = jnp.where(a_next, d_next - d,
                    sum_w * (1 + 4 * eps) + 4 * eps * norm_scale)
    anc, tau = _secular_roots(d, w, gap, active, d_next, a_next,
                              newton_iters=newton_iters)
    mu = jnp.where(active, anc + tau, d)
    if not need_rows:
        order2 = jnp.argsort(mu, axis=-1)
        mu = jnp.take_along_axis(mu, order2, axis=-1)
        return mu, jnp.zeros_like(mu), jnp.zeros_like(mu)
    # Shift from each root's OWN pole (anc may be the next pole up);
    # accurate relative to far poles, cancellation-prone only where the
    # anchored form (anc - d_i) + tau takes over below.
    t = jnp.where(active, (anc - d) + tau, jnp.asarray(0, acc))

    # -- Loewner recomputation of z (Gu's trick) -----------------------------
    # rho * zhat_i^2 = t_i * prod_{j != i} (mu_j - d_i) / (d_j - d_i); every
    # ratio is positive by interlacing.  Far poles (ratio near 1) go through
    # log1p(t_j / (d_j - d_i)); near poles switch to the anchored numerator
    # (anc_j - d_i) + tau_j, which is exact at the anchor itself.
    m_all = d.shape[-1]
    tiny = jnp.asarray(jnp.finfo(acc).tiny, acc)
    # Root-axis reduction blocks for the Loewner product: deflated roots
    # contribute log(1) = 0, and they sit in a contiguous suffix, so whole
    # blocks of them are skipped at run time.
    rblocks = _axis_blocks(
        {"d": d, "t": t, "anc": anc, "tau": tau, "act": active}, m_all)

    def zhat_block(blk):
        def run(b):
            di, acti = b["d"], b["act"]

            def one_blk(rb):
                deltaji = rb["d"][..., :, None] - di[..., None, :]
                safe = jnp.where(deltaji == 0, jnp.asarray(1, acc), deltaji)
                x = rb["t"][..., :, None] / safe
                num = ((rb["anc"][..., :, None] - di[..., None, :])
                       + rb["tau"][..., :, None])
                ratio = num / safe
                logr = jnp.where(
                    jnp.abs(x) < 0.5,
                    jnp.log1p(jnp.maximum(x, jnp.asarray(-0.75, acc))),
                    jnp.log(jnp.maximum(ratio, tiny)))
                mask = (rb["act"][..., :, None] & acti[..., None, :] &
                        (deltaji != 0))
                return jnp.sum(jnp.where(mask, logr, jnp.asarray(0, acc)),
                               axis=-2)

            return _skip_block_sum(one_blk, rblocks,
                                   lambda rb: jnp.any(rb["act"]),
                                   jnp.zeros_like(di))

        # Target-chunk skip: deflated targets keep zhat = 0 regardless.
        return jax.lax.cond(jnp.any(blk["act"]), run,
                            lambda b: jnp.zeros_like(b["d"]), blk)

    logprod = _chunked_cols(zhat_block, {"d": d, "act": active}, m_all)
    rho_safe = jnp.where(rho > 0, rho, jnp.asarray(1, acc))
    zhat2 = jnp.where(active, t / rho_safe * jnp.exp(logprod),
                      jnp.asarray(0, acc))
    zhat = jnp.where(z < 0, -jnp.sqrt(zhat2), jnp.sqrt(zhat2))

    # -- parent first/last rows ----------------------------------------------
    # Pole-axis blocks: deflated poles carry zhat = 0 and contribute nothing
    # to the eigenvector sums — whole zero-weight blocks are skipped.
    vblocks = _axis_blocks({"d": d, "zh": zhat, "fe": fe, "le": le}, m_all)

    def fl_block(blk):
        def run(b):
            ancj, tj, actj = b["anc"], b["tau"], b["act"]

            def one_blk(pb):
                delta = pb["d"][..., :, None] - ancj[..., None, :]
                denom = delta - tj[..., None, :]              # d_i - mu_j
                zc = pb["zh"][..., :, None]
                bad = (zc == 0) | (denom == 0)
                safe = jnp.where(bad, jnp.asarray(1, acc), denom)
                wv = jnp.where(bad, jnp.asarray(0, acc), zc / safe)
                return (jnp.sum(wv * wv, axis=-2),
                        jnp.sum(pb["fe"][..., :, None] * wv, axis=-2),
                        jnp.sum(pb["le"][..., :, None] * wv, axis=-2))

            s2, sf, sl = _skip_block_sum(
                one_blk, vblocks, lambda pb: jnp.any(pb["zh"] != 0),
                (jnp.zeros_like(ancj),) * 3)
            nrm = jnp.sqrt(jnp.maximum(
                s2, jnp.asarray(jnp.finfo(acc).tiny, acc)))
            keep = ~actj
            return (jnp.where(keep, 0.0, sf / nrm),
                    jnp.where(keep, 0.0, sl / nrm))

        # Root-chunk skip: deflated roots keep their child rows verbatim.
        return jax.lax.cond(
            jnp.any(blk["act"]), run,
            lambda b: (jnp.zeros_like(b["anc"]), jnp.zeros_like(b["anc"])),
            blk)

    fj, lj = _chunked_cols(
        fl_block, {"anc": anc, "tau": tau, "act": active}, m_all)
    f_par = jnp.where(active, fj, fe)
    l_par = jnp.where(active, lj, le)

    order2 = jnp.argsort(mu, axis=-1)
    take2 = lambda x: jnp.take_along_axis(x, order2, axis=-1)  # noqa: E731
    return take2(mu), take2(f_par), take2(l_par)


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("leaf_n", "newton_iters", "inv_iters"))
def bidiag_dc_singular_values(d: jax.Array, e: jax.Array, *,
                              leaf_n: int = DEFAULT_DC_LEAF_N,
                              newton_iters: int = 30,
                              inv_iters: int = 2) -> jax.Array:
    """All singular values of the bidiagonal (d, e) by divide-and-conquer,
    descending — same contract as :func:`bidiag_singular_values` (e[0]
    ignored; stacked bidiagonals ``(..., n)`` vmap).

    n <= ``leaf_n`` short-circuits to the bisection path; larger problems
    pad the GK tridiagonal to a power-of-two leaf grid, bisect the leaves,
    and run log2(n/leaf_n) secular merge levels, each one batched dispatch.
    """
    if leaf_n < 2:
        raise ValueError(f"leaf_n must be >= 2, got {leaf_n}")
    if d.ndim > 1:
        lead = d.shape[:-1]
        # Sequential per-matrix batching, NOT vmap: the deflation skips in
        # the merges are lax.cond branches on scalar "any active here?"
        # predicates, and vmap would lower them to selects that compute BOTH
        # sides — erasing the entire skip win.  Within one matrix every
        # merge level stays fully batched over its subproblem axis, which is
        # where the device-level parallelism lives.
        out = jax.lax.map(
            lambda de: bidiag_dc_singular_values(
                de[0], de[1], leaf_n=leaf_n, newton_iters=newton_iters,
                inv_iters=inv_iters),
            (d.reshape((-1, d.shape[-1])), e.reshape((-1, e.shape[-1]))))
        return out.reshape(lead + (d.shape[-1],))
    n = d.shape[0]
    if n <= leaf_n:
        return bidiag_singular_values(d, e)
    dt = d.dtype
    acc = _acc_dtype(dt)
    z = gk_offdiag(d.astype(acc), e.astype(acc))
    sc = _gk_prescale(z)
    z = z / sc

    m = 2 * n
    lm = 2 * leaf_n
    levels = max(0, math.ceil(math.log2(m / lm)))
    big = lm << levels                                   # padded GK size
    bisect_iters = default_bisect_iters(acc)

    a = jnp.zeros((big,), acc)
    b = jnp.zeros((big - 1,), acc)
    b = b.at[: m - 1].set(z)
    if big > m:
        # Decoupled sentinel poles strictly below the (scaled) spectrum:
        # their z components are exactly zero at every merge, so they
        # deflate for free and sort to the bottom.
        bound = jnp.max(jnp.abs(z)) * 2 + 1
        a = a.at[m:].set(-(bound + jnp.arange(big - m, dtype=acc) + 1))

    # Cuppen boundary corrections for EVERY level at once: each interior
    # leaf boundary i is the split point of exactly one merge, whose rank-one
    # term absorbs rho = |b_i| from both touching diagonal entries.
    idx = jnp.arange(big - 1)
    corr = jnp.where((idx + 1) % lm == 0, jnp.abs(b), 0)
    a = a - jnp.concatenate([corr, jnp.zeros(1, acc)])
    a = a - jnp.concatenate([jnp.zeros(1, acc), corr])

    nleaf = big // lm
    a_leaf = a.reshape(nleaf, lm)
    b_leaf = jnp.concatenate([b, jnp.zeros(1, acc)]).reshape(
        nleaf, lm)[:, : lm - 1]
    with jax.named_scope("dc_leaves"):
        lam, f, el = jax.vmap(functools.partial(
            _leaf_eigen, bisect_iters=bisect_iters,
            inv_iters=inv_iters))(a_leaf, b_leaf)

    # Device-side attribution per merge level (DESIGN.md §16): this loop
    # runs under jit, so host spans are meaningless here — named_scope
    # labels each level's ops in `jax.profiler.trace` captures instead.
    for lev in range(levels):
        sz = lm << lev
        npair = big // (2 * sz)
        pos = (2 * jnp.arange(npair) + 1) * sz - 1
        rho_b = b[pos]
        lam2 = lam.reshape(npair, 2, sz)
        f2 = f.reshape(npair, 2, sz)
        l2 = el.reshape(npair, 2, sz)
        with jax.named_scope(f"dc_merge_level_{lev}"):
            lam, f, el = _merge_pair(
                lam2[:, 0], f2[:, 0], l2[:, 0],
                lam2[:, 1], f2[:, 1], l2[:, 1], rho_b,
                newton_iters=newton_iters, need_rows=lev + 1 < levels)

    lam = lam.reshape(big)
    sig = jnp.abs(lam[big - n:][::-1])                   # top n, descending
    return (sig * sc).astype(dt)


@functools.partial(jax.jit,
                   static_argnames=("leaf_n", "newton_iters", "inv_iters"))
def bidiag_dc_svd(d: jax.Array, e: jax.Array, *,
                  leaf_n: int = DEFAULT_DC_LEAF_N,
                  newton_iters: int = 30,
                  inv_iters: int = 2):
    """Full SVD of the bidiagonal (d, e) with divide-and-conquer values:
    (U, sigma, V^T), same contract as :func:`core.bidiag_svd.bidiag_svd`.

    sigma comes from :func:`bidiag_dc_singular_values`; vectors reuse the
    sigma-agnostic inverse-iteration machinery (``_vectors_from_sigma``) —
    any few-ulp-accurate sigma seeds the same guarded GK solves, so the
    vector path needs no D&C-specific code and U/V stay consistent with the
    bisection backend's.
    """
    if leaf_n < 2:
        raise ValueError(f"leaf_n must be >= 2, got {leaf_n}")
    if d.ndim > 1:
        lead = d.shape[:-1]
        # lax.map, not vmap: see bidiag_dc_singular_values — vmap would
        # turn the merge-level deflation skips into both-branch selects.
        u, s, vt = jax.lax.map(
            lambda de: bidiag_dc_svd(
                de[0], de[1], leaf_n=leaf_n, newton_iters=newton_iters,
                inv_iters=inv_iters),
            (d.reshape((-1, d.shape[-1])), e.reshape((-1, e.shape[-1]))))
        n = d.shape[-1]
        return (u.reshape(lead + (n, n)), s.reshape(lead + (n,)),
                vt.reshape(lead + (n, n)))
    n = d.shape[0]
    if n <= leaf_n:
        return bidiag_svd(d, e, inv_iters=inv_iters)
    sig = bidiag_dc_singular_values(
        d, e, leaf_n=leaf_n, newton_iters=newton_iters,
        inv_iters=inv_iters)
    u, vt = _vectors_from_sigma(d, e, sig, inv_iters=inv_iters)
    return (u, sig, vt)
