"""Householder reflector numerics (LAPACK ``larfg``-style, per paper §III-B:
"Details of the Householder reflector computation and the treatment of near-zero
elements are implemented according to prior work on tile-QR decomposition").

A reflector over ``x = [alpha, x2]`` produces ``(I - tau v v^T) x = [beta, 0]``
with ``v[0] = 1``.  Zero tails (``x2 == 0``) and fully-zero vectors yield
``tau = 0`` (identity) — this is what makes edge/padding handling in the chase
free: padded entries are exactly zero, so reflectors never touch them.

All functions are dtype-polymorphic (fp64/fp32/bf16) and vmap-safe.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["make_reflector", "apply_left", "apply_right", "reflector_matrix"]


def make_reflector(x: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Compute (v, tau, beta) for a length-L vector x (L static).

    v[0] == 1 whenever tau != 0. Safe for zero vectors: returns tau = 0,
    v = e_0, beta = x[0].
    """
    dt = x.dtype
    # Accumulate norms in f32 at minimum (bf16 sums are too lossy).
    acc = jnp.float32 if dt in (jnp.bfloat16, jnp.float16) else dt
    alpha = x[0].astype(acc)
    x2 = x[1:].astype(acc)
    sigma = jnp.sum(x2 * x2)
    mu = jnp.sqrt(alpha * alpha + sigma)
    # beta gets the sign opposite to alpha (avoids cancellation).
    beta = jnp.where(alpha >= 0, -mu, mu)
    denom = alpha - beta
    safe = sigma > 0
    denom = jnp.where(safe, denom, 1.0)
    tau = jnp.where(safe, (beta - alpha) / beta, 0.0)
    v2 = jnp.where(safe, x2 / denom, 0.0)
    v = jnp.concatenate([jnp.ones((1,), acc), v2])
    beta_out = jnp.where(safe, beta, alpha)
    return v.astype(dt), tau.astype(dt), beta_out.astype(dt)


def apply_left(v: jax.Array, tau: jax.Array, c: jax.Array) -> jax.Array:
    """C <- (I - tau v v^T) C,  v: (L,), C: (L, m)."""
    acc = jnp.float32 if c.dtype in (jnp.bfloat16, jnp.float16) else c.dtype
    vv = v.astype(acc)
    w = vv @ c.astype(acc)              # (m,)
    out = c.astype(acc) - tau.astype(acc) * jnp.outer(vv, w)
    return out.astype(c.dtype)


def apply_right(v: jax.Array, tau: jax.Array, c: jax.Array) -> jax.Array:
    """C <- C (I - tau v v^T),  v: (L,), C: (m, L)."""
    acc = jnp.float32 if c.dtype in (jnp.bfloat16, jnp.float16) else c.dtype
    vv = v.astype(acc)
    w = c.astype(acc) @ vv              # (m,)
    out = c.astype(acc) - tau.astype(acc) * jnp.outer(w, vv)
    return out.astype(c.dtype)


def reflector_matrix(v: jax.Array, tau: jax.Array) -> jax.Array:
    """Dense (I - tau v v^T) — test/debug helper."""
    return jnp.eye(v.shape[0], dtype=v.dtype) - tau * jnp.outer(v, v)
