"""Stage 1: dense -> upper-banded reduction (blocked two-sided Householder).

Alternating QR panel (zero below the diagonal in an ``nb``-column stripe) and
LQ panel (zero beyond the ``nb``-th superdiagonal in an ``nb``-row stripe),
with compact-WY blocked trailing updates — the GEMM/MXU-heavy stage of the
three-stage SVD (paper §I; our stage-2 bulge-chasing kernel consumes its
output).

Implementation notes (fixed shapes, single jit per (n, nb)):

* The matrix is zero-padded to a panel multiple so every stripe slice is
  aligned; padded reflectors are identity (tau = 0) by construction.
* Panels are factorized unblocked (rank-1 applies on the stripe); the blocked
  trailing update applies ``I - V T' V^T`` at full width with already-final
  columns masked out of the inner product — already-reduced regions hold exact
  structural zeros (re-established after every reflector, as LAPACK does), so
  full-width applies cannot corrupt them.
* Everything runs inside one ``lax.fori_loop`` over panels.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["band_reduce", "wy_t_factor"]


def _acc_dtype(dt):
    return jnp.float32 if dt in (jnp.bfloat16, jnp.float16) else dt


def _masked_reflector(col: jax.Array, pivot: jax.Array):
    """Householder (v, tau, beta) for entries of ``col`` at indices >= pivot.

    v[pivot] = 1, zeros above; tau = 0 (identity) when the tail below the
    pivot is zero (covers out-of-range / padded pivots, whose columns are 0).
    """
    m = col.shape[0]
    idx = jnp.arange(m)
    piv = jnp.clip(pivot, 0, m - 1)
    alpha = col[piv]
    tail = jnp.where(idx > pivot, col, 0)
    sigma = jnp.sum(tail * tail)
    mu = jnp.sqrt(alpha * alpha + sigma)
    beta = jnp.where(alpha >= 0, -mu, mu)
    safe = sigma > 0
    denom = jnp.where(safe, alpha - beta, 1)
    tau = jnp.where(safe, (beta - alpha) / jnp.where(beta == 0, 1, beta), 0)
    v = jnp.where(idx > pivot, col / denom, 0)
    v = v.at[piv].set(jnp.where(pivot < m, 1.0, 0.0))
    beta_out = jnp.where(safe, beta, alpha)
    return v, tau, beta_out


def wy_t_factor(v: jax.Array, taus: jax.Array) -> jax.Array:
    """Compact-WY T (upper triangular): H_0 H_1 ... H_{k-1} = I - V T V^T."""
    k = taus.shape[0]
    vtv = v.T @ v

    def body(j, t):
        col = -taus[j] * (t @ jnp.where(jnp.arange(k) < j, vtv[:, j], 0))
        col = col.at[j].set(taus[j])
        keep = jnp.arange(k) <= j
        return t.at[:, j].set(jnp.where(keep, col, 0))

    return jax.lax.fori_loop(0, k, body, jnp.zeros((k, k), v.dtype))


@functools.partial(jax.jit, static_argnames=("nb", "backend", "config",
                                             "tape"))
def band_reduce(a: jax.Array, *, nb: int, backend: str | None = None,
                config=None, tape: bool = False):
    """Reduce dense (..., n, n) to upper-banded form with bandwidth ``nb``.

    Singular values are preserved exactly (two-sided orthogonal transforms).
    Leading batch axes are vmapped (stage 1 is GEMM-bound; the MXU batches
    naturally — the wavefront trick is only needed for stage 2).
    ``backend="pallas"`` routes the blocked QR trailing update through the
    compact-WY Pallas kernel (kernels/hh_apply.py): the kernel applies at
    full width (already-final panel columns are restored afterwards — regions
    left of the panel hold exact zeros in V's row support, so the apply is a
    no-op there).  An explicit ``backend=`` wins; otherwise a resolved
    ``config`` supplies it; otherwise "ref".

    With ``tape=True`` returns ``(banded, (vq, tq, vl, tl))`` — the per-panel
    compact-WY reflector tape: ``vq/vl (..., P, n, nb)`` (QR / LQ reflector
    blocks, rows truncated to n — padding rows are structurally zero) and
    ``tq/tl (..., P, nb, nb)`` (their T factors).  Replayed into ``U``/``V^T``
    by ``core/transforms.py``; the banded output is bit-identical either way.
    """
    if backend is None:
        backend = config.backend if config is not None else "ref"
    if a.ndim > 2:
        fn = lambda m: _band_reduce_2d(m, nb=nb, backend=backend,
                                       config=config, tape=tape)
        for _ in range(a.ndim - 2):
            fn = jax.vmap(fn)
        return fn(a)
    return _band_reduce_2d(a, nb=nb, backend=backend, config=config, tape=tape)


def _band_reduce_2d(a: jax.Array, *, nb: int, backend: str,
                    config=None, tape: bool = False):
    n = a.shape[0]
    dt = a.dtype
    acc = _acc_dtype(dt)
    n_panels = max(1, -(-(n - 1) // nb))
    big = (n_panels + 2) * nb                  # padded size: all slices aligned
    a = jnp.zeros((big, big), acc).at[:n, :n].set(a.astype(acc))
    idx = jnp.arange(big)

    def panel(k, carry):
        a = carry[0] if tape else carry
        c0 = k * nb

        # -------- QR panel: columns [c0, c0+nb), pivot row c0+j --------------
        def qr_reflector(j, carry):
            a, v_blk, taus = carry
            c = c0 + j
            stripe = jax.lax.dynamic_slice(a, (0, c0), (big, nb))
            v, tau, beta = _masked_reflector(stripe[:, j], c)
            w = v @ stripe
            stripe = stripe - tau * jnp.outer(v, w)
            newcol = jnp.where(idx > c, 0.0, stripe[:, j])       # structural 0s
            newcol = newcol.at[c].set(jnp.where(tau != 0, beta, newcol[c]))
            stripe = stripe.at[:, j].set(newcol)
            a = jax.lax.dynamic_update_slice(a, stripe, (0, c0))
            return a, v_blk.at[:, j].set(v), taus.at[j].set(tau)

        v0 = jnp.zeros((big, nb), acc)
        t0 = jnp.zeros((nb,), acc)
        with jax.named_scope("stage1_qr_panel"):
            a, v_blk, taus = jax.lax.fori_loop(0, nb, qr_reflector,
                                               (a, v0, t0))
        t = wy_t_factor(v_blk, taus)
        # blocked trailing update (Q^T = I - V T^T V^T) on columns >= c0+nb
        if backend == "pallas":
            from repro.kernels import ops
            stripe = jax.lax.dynamic_slice(a, (0, c0), (big, nb))
            # config threads the resolved interpret flag; the explicit
            # backend kwarg still selects the kernel route.
            a = ops.hh_block_apply(v_blk, t.T, a, backend="pallas",
                                   config=config)
            # restore final panel columns (double-applied by the full-width
            # kernel); columns < c0 are exact-zero in V's row support, so the
            # kernel was a no-op there already.
            a = jax.lax.dynamic_update_slice(a, stripe, (0, c0))
        else:
            u = v_blk.T @ a
            u = jnp.where(idx[None, :] >= c0 + nb, u, 0)
            a = a - v_blk @ (t.T @ u)

        # -------- LQ panel: rows [c0, c0+nb), pivot col c0+nb+j --------------
        def lq_reflector(j, carry):
            a, v_blk, taus = carry
            r = c0 + j
            c_piv = c0 + nb + j
            stripe = jax.lax.dynamic_slice(a, (c0, 0), (nb, big))
            v, tau, beta = _masked_reflector(stripe[j, :], c_piv)
            w = stripe @ v
            stripe = stripe - tau * jnp.outer(w, v)
            newrow = jnp.where(idx > c_piv, 0.0, stripe[j, :])
            newrow = newrow.at[c_piv].set(jnp.where(tau != 0, beta, newrow[c_piv]))
            stripe = stripe.at[j, :].set(newrow)
            a = jax.lax.dynamic_update_slice(a, stripe, (c0, 0))
            return a, v_blk.at[:, j].set(v), taus.at[j].set(tau)

        with jax.named_scope("stage1_lq_panel"):
            a, vr_blk, taus_r = jax.lax.fori_loop(0, nb, lq_reflector,
                                                  (a, v0, t0))
        tr = wy_t_factor(vr_blk, taus_r)
        # blocked trailing update from the right on rows >= c0+nb
        w = a @ vr_blk
        w = jnp.where(idx[:, None] >= c0 + nb, w, 0)
        a = a - w @ (tr @ vr_blk.T)
        if not tape:
            return a
        vqs, tqs, vls, tls = carry[1:]
        return (a, vqs.at[k].set(v_blk), tqs.at[k].set(t),
                vls.at[k].set(vr_blk), tls.at[k].set(tr))

    if tape:
        z_v = jnp.zeros((n_panels, big, nb), acc)
        z_t = jnp.zeros((n_panels, nb, nb), acc)
        a, vqs, tqs, vls, tls = jax.lax.fori_loop(
            0, n_panels, panel, (a, z_v, z_t, z_v, z_t))
        # rows >= n of every reflector block are structurally zero (the
        # padded matrix region never becomes nonzero), so the tape can be
        # truncated to matrix rows — replay then lives in (n, n) space.
        return (a[:n, :n].astype(dt),
                (vqs[:, :n], tqs, vls[:, :n], tls))
    a = jax.lax.fori_loop(0, n_panels, panel, a)
    return a[:n, :n].astype(dt)
