"""jit'd public wrappers for the Pallas kernels, with backend dispatch.

Backends:
  "ref"       — pure-jnp oracle (kernels/ref.py), any platform.
  "pallas"    — Pallas TPU kernel; on CPU runs in interpret mode (correctness).
  "auto"      — pallas on TPU, ref elsewhere (CPU containers validate the
                kernels separately through the interpret-mode test sweeps).
"""

from __future__ import annotations

import functools

import jax

from repro.kernels import ref as _ref

__all__ = ["chase_cycle", "hh_block_apply", "flash_attention"]


def _platform() -> str:
    return jax.devices()[0].platform


@functools.partial(jax.jit, static_argnames=("b_in", "tw", "backend", "interpret"))
def chase_cycle(windows: jax.Array, is_first: jax.Array, *, b_in: int, tw: int,
                backend: str = "auto", interpret: bool | None = None) -> jax.Array:
    """Process one wavefront of bulge-chase cycles.

    windows: (G, H, W) rolled dense windows (disjoint); is_first: (G,) bool.
    """
    if backend == "auto":
        backend = "pallas" if _platform() == "tpu" else "ref"
    if backend == "ref":
        return _ref.chase_cycle_ref(windows, is_first, b_in=b_in, tw=tw)
    if backend == "pallas":
        from repro.kernels import bulge_chase
        if interpret is None:
            interpret = _platform() != "tpu"
        return bulge_chase.chase_cycle_pallas(
            windows, is_first, b_in=b_in, tw=tw, interpret=interpret)
    raise ValueError(f"unknown backend {backend!r}")


@functools.partial(jax.jit, static_argnames=("backend", "interpret", "block_cols"))
def hh_block_apply(v: jax.Array, t: jax.Array, c: jax.Array, *,
                   backend: str = "auto", interpret: bool | None = None,
                   block_cols: int = 512) -> jax.Array:
    """C <- (I - V T V^T) C — stage-1 WY blocked reflector apply."""
    if backend == "auto":
        backend = "pallas" if _platform() == "tpu" else "ref"
    if backend == "ref":
        return _ref.hh_block_apply_ref(v, t, c)
    if backend == "pallas":
        from repro.kernels import hh_apply
        if interpret is None:
            interpret = _platform() != "tpu"
        return hh_apply.hh_block_apply_pallas(v, t, c, interpret=interpret,
                                              block_cols=block_cols)
    raise ValueError(f"unknown backend {backend!r}")


@functools.partial(jax.jit, static_argnames=("backend", "interpret",
                                             "block_q", "block_k"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    backend: str = "auto", interpret: bool | None = None,
                    block_q: int = 128, block_k: int = 128) -> jax.Array:
    """Causal attention (BH, S, D): O(s*d) HBM traffic on TPU (Pallas)."""
    if backend == "auto":
        backend = "pallas" if _platform() == "tpu" else "ref"
    if backend == "ref":
        return _ref.flash_attention_ref(q, k, v)
    if backend == "pallas":
        from repro.kernels import flash_attention as fa
        if interpret is None:
            interpret = _platform() != "tpu"
        return fa.flash_attention_pallas(q, k, v, block_q=block_q,
                                         block_k=block_k, interpret=interpret)
    raise ValueError(f"unknown backend {backend!r}")
