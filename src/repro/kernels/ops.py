"""jit'd public wrappers for the Pallas kernels, with registry-based dispatch.

Backends are entries in a small registry (``register_backend``) mapping a name
to per-op implementations; dispatch is a dict lookup instead of if/elif chains,
so new backends (future: a Mosaic-GPU port, a cuSOLVER shim) plug in without
touching call sites.  Built-ins:

  "ref"       — pure-jnp oracle (kernels/ref.py), any platform.
  "pallas"    — Pallas TPU kernel; on CPU runs in interpret mode (correctness).

``resolve_backend`` turns the user-facing "auto" into a concrete registry key
(pallas on TPU, ref elsewhere) and is the single place platform sniffing
happens — ``tuning.PipelineConfig.resolve`` calls it so resolved configs never
carry "auto".  Every wrapper also accepts ``config=`` (a resolved
``PipelineConfig``) as the preferred way to select a backend.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax

from repro.kernels import ref as _ref

__all__ = ["chase_cycle", "hh_block_apply", "tape_apply", "flash_attention",
           "fused_svd", "register_backend", "resolve_backend",
           "backend_names"]


def _platform() -> str:
    return jax.devices()[0].platform


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, dict[str, Callable]] = {}


def register_backend(name: str, **impls: Callable) -> None:
    """Register (or extend) a backend: op name -> impl.

    Every impl takes the op's arrays plus its static kwargs and an
    ``interpret`` kwarg (ignored by non-Pallas backends).  ``chase_cycle``
    impls additionally always receive ``with_tape`` (record the reflector
    tape, static), ``fuse`` (super-step depth, static) and ``active`` (the
    per-fused-cycle mask operand, None at fuse=1).
    """
    _REGISTRY.setdefault(name, {}).update(impls)


def backend_names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_backend(backend: str = "auto", interpret: bool | None = None
                    ) -> tuple[str, bool]:
    """("auto", None) -> a concrete (registry key, interpret flag)."""
    if backend == "auto":
        backend = "pallas" if _platform() == "tpu" else "ref"
    if backend not in _REGISTRY:
        raise ValueError(
            f"unknown backend {backend!r}; registered: {backend_names()}")
    if interpret is None:
        interpret = _platform() != "tpu"
    return backend, bool(interpret)


def _impl(op: str, backend: str) -> Callable:
    table = _REGISTRY.get(backend)
    if table is None or op not in table:
        raise ValueError(
            f"backend {backend!r} does not implement {op!r}; "
            f"registered: {backend_names()}")
    return table[op]


def _resolve(backend: str, interpret: bool | None, config) -> tuple[str, bool]:
    """Explicit kwargs win; the config fills whatever is still at its
    "auto"/None default (so a resolved config's interpret flag survives even
    when the caller passes the concrete backend name alongside it)."""
    if config is not None:
        if backend == "auto":
            backend = config.backend
        if interpret is None:
            interpret = config.interpret
    return resolve_backend(backend, interpret)


# ---- built-in "ref" (pure jnp; interpret flag ignored) ---------------------

def _ref_chase(windows, is_first, *, b_in, tw, with_tape, interpret, fuse=1,
               active=None):
    if fuse == 1:
        return _ref.chase_cycle_ref(windows, is_first, b_in=b_in, tw=tw,
                                    with_tape=with_tape)
    return _ref.chase_superstep_ref(windows, is_first, active, b_in=b_in,
                                    tw=tw, fuse=fuse, with_tape=with_tape)


register_backend(
    "ref",
    chase_cycle=_ref_chase,
    hh_block_apply=lambda v, t, c, *, block_cols, interpret:
        _ref.hh_block_apply_ref(v, t, c),
    tape_apply=lambda v, t, c, *, block_cols, interpret:
        _ref.tape_apply_ref(v, t, c),
    flash_attention=lambda q, k, v, *, block_q, block_k, interpret:
        _ref.flash_attention_ref(q, k, v),
    fused_svd=lambda mats, *, bw, compute_uv, interpret:
        _ref.fused_small_svd_ref(mats, bw=bw, compute_uv=compute_uv),
)


# ---- built-in "pallas" (lazy kernel imports keep CPU-only paths light) -----

def _pallas_chase(windows, is_first, *, b_in, tw, with_tape, interpret,
                  fuse=1, active=None):
    from repro.kernels import bulge_chase
    if fuse == 1:
        return bulge_chase.chase_cycle_pallas(windows, is_first, b_in=b_in,
                                              tw=tw, interpret=interpret,
                                              with_tape=with_tape)
    return bulge_chase.chase_superstep_pallas(windows, is_first, active,
                                              b_in=b_in, tw=tw, fuse=fuse,
                                              interpret=interpret,
                                              with_tape=with_tape)


def _pallas_hh(v, t, c, *, block_cols, interpret):
    from repro.kernels import hh_apply
    return hh_apply.hh_block_apply_pallas(v, t, c, interpret=interpret,
                                          block_cols=block_cols)


def _pallas_tape(v, t, c, *, block_cols, interpret):
    from repro.kernels import hh_apply
    return hh_apply.tape_apply_pallas(v, t, c, interpret=interpret,
                                      block_cols=block_cols)


def _pallas_flash(q, k, v, *, block_q, block_k, interpret):
    from repro.kernels import flash_attention as fa
    return fa.flash_attention_pallas(q, k, v, block_q=block_q, block_k=block_k,
                                     interpret=interpret)


def _pallas_fused(mats, *, bw, compute_uv, interpret):
    from repro.kernels import fused_small
    return fused_small.fused_small_svd_pallas(mats, bw=bw,
                                              compute_uv=compute_uv,
                                              interpret=interpret)


register_backend("pallas", chase_cycle=_pallas_chase, hh_block_apply=_pallas_hh,
                 tape_apply=_pallas_tape, flash_attention=_pallas_flash,
                 fused_svd=_pallas_fused)


# ---- "fused_small" (DESIGN.md §13): the one-dispatch small-n SVD tier ------
#
# A complete backend, not just an op: ``PipelineConfig(backend="fused_small")``
# is valid anywhere a backend name goes (including inside shard_map's local
# function, so PR 5's sharded dispatch serves a whole shard bucket as one
# kernel launch).  ``fused_svd`` is platform-routed — the Pallas kernel where
# Pallas compiles (TPU), the jitted jnp twin elsewhere (one XLA dispatch on
# CPU; interpret-mode Pallas would eagerly step ~1e4 fori iterations per
# matrix).  The staged ops delegate to the platform default so a
# fused_small-configured pipeline can still run any staged stage it needs.

def _fused_small_delegate(op: str) -> Callable:
    def impl(*args, **kwargs):
        base = "pallas" if _platform() == "tpu" else "ref"
        return _impl(op, base)(*args, **kwargs)
    return impl


register_backend("fused_small",
                 **{op: _fused_small_delegate(op)
                    for op in ("chase_cycle", "hh_block_apply", "tape_apply",
                               "flash_attention", "fused_svd")})


# ---------------------------------------------------------------------------
# Public dispatching wrappers
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("b_in", "tw", "backend", "interpret",
                                    "config", "with_tape", "fuse"))
def chase_cycle(windows: jax.Array, is_first: jax.Array, *, b_in: int, tw: int,
                backend: str = "auto", interpret: bool | None = None,
                config=None, with_tape: bool = False, fuse: int = 1,
                active: jax.Array | None = None):
    """Process one wavefront of bulge-chase (super-)cycles.

    ``fuse=1`` (default): windows: (G, H, W) rolled dense windows
    (disjoint); is_first: (G,) bool.  With a leading batch axis folded in,
    G = B * G_matrix — independent problems simply widen the wavefront (one
    fused call either way).

    ``fuse=K >= 2`` (super-steps, DESIGN.md §9): the operand is instead the
    wavefront's CONTIGUOUS band-storage blocks (G, H, K*b_in + tw + 1) —
    K consecutive chase windows per slot, rolled to dense form inside the
    kernel — plus ``active`` (G, K), the per-fused-cycle liveness prefix
    mask.  Each slot chases its K cycles sequentially in fast memory, so a
    dispatch retires K times the cycles of a K=1 call.

    ``with_tape=True`` returns ``(windows, vs, taus)`` — the reflector-tape
    slice for this wavefront (right reflector at pair index 0, left at 1),
    recorded alongside the identical window update; shapes
    ``(G, 2, tw+1)``/``(G, 2)`` at fuse=1 and ``(G, K, 2, tw+1)``/
    ``(G, K, 2)`` fused.
    """
    backend, interpret = _resolve(backend, interpret, config)
    return _impl("chase_cycle", backend)(windows, is_first, b_in=b_in, tw=tw,
                                         with_tape=with_tape, fuse=fuse,
                                         active=active, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("backend", "interpret",
                                             "block_cols", "config"))
def tape_apply(v: jax.Array, t: jax.Array, c: jax.Array, *,
               backend: str = "auto", interpret: bool | None = None,
               block_cols: int = 512, config=None) -> jax.Array:
    """Slot-batched compact-WY left apply (the tape-replay workhorse):

        C[s] <- (I - V[s] T[s] V[s]^T) C[s]

    v: (S, m, k), t: (S, k, k), c: (S, m, w).  Chase-tape replay passes the
    rank-1 form (k = 1, t = tau); stage-1 panel replay passes k = nb blocks.
    """
    backend, interpret = _resolve(backend, interpret, config)
    return _impl("tape_apply", backend)(v, t, c, block_cols=block_cols,
                                        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("backend", "interpret",
                                             "block_cols", "config"))
def hh_block_apply(v: jax.Array, t: jax.Array, c: jax.Array, *,
                   backend: str = "auto", interpret: bool | None = None,
                   block_cols: int = 512, config=None) -> jax.Array:
    """C <- (I - V T V^T) C — stage-1 WY blocked reflector apply."""
    backend, interpret = _resolve(backend, interpret, config)
    return _impl("hh_block_apply", backend)(v, t, c, block_cols=block_cols,
                                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bw", "compute_uv", "backend",
                                             "interpret", "config"))
def fused_svd(mats: jax.Array, *, bw: int, compute_uv: bool = False,
              backend: str = "auto", interpret: bool | None = None,
              config=None):
    """Whole-pipeline small-n SVD, one dispatch per (B, n, n) stack.

    Values mode (default) returns sigma (B, n) descending.
    ``compute_uv=True`` returns ``(d, e, u2, vt2)`` — the bidiagonal plus
    the accumulated two-sided transforms; ``core.svd`` composes the final
    vectors with one batched ``bidiag_svd``.  ``backend="auto"`` follows the
    platform default; ``"fused_small"`` platform-routes (Pallas kernel on
    TPU, jitted jnp twin elsewhere).
    """
    backend, interpret = _resolve(backend, interpret, config)
    return _impl("fused_svd", backend)(mats, bw=bw, compute_uv=compute_uv,
                                       interpret=interpret)


@functools.partial(jax.jit, static_argnames=("backend", "interpret",
                                             "block_q", "block_k", "config"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    backend: str = "auto", interpret: bool | None = None,
                    block_q: int = 128, block_k: int = 128,
                    config=None) -> jax.Array:
    """Causal attention (BH, S, D): O(s*d) HBM traffic on TPU (Pallas)."""
    backend, interpret = _resolve(backend, interpret, config)
    return _impl("flash_attention", backend)(q, k, v, block_q=block_q,
                                             block_k=block_k,
                                             interpret=interpret)
