"""Pallas TPU kernel: compact-WY blocked reflector apply (stage-1 hotspot).

    C <- (I - V T V^T) C

V: (m, k) reflector block (k = panel width, small), T: (k, k), C: (m, n).
Grid tiles the columns of C; V and T stay VMEM-resident across grid steps
(their index_map is constant, so the pipeline fetches them once), while C
streams through in ``block_cols`` stripes — three MXU matmuls per stripe.
This is the GEMM-dense counterpart of the memory-bound chase kernel: stage 1
is where the paper's pipeline earns its "compute density" (paper §I).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["hh_block_apply_pallas"]


def _wy_kernel(v_ref, t_ref, c_ref, o_ref):
    acc = jnp.float32 if c_ref.dtype in (jnp.bfloat16, jnp.float16) else c_ref.dtype
    v = v_ref[...].astype(acc)
    t = t_ref[...].astype(acc)
    c = c_ref[...].astype(acc)
    w1 = jnp.dot(v.T, c, preferred_element_type=acc)       # (k, bc)
    w2 = jnp.dot(t, w1, preferred_element_type=acc)        # (k, bc)
    o_ref[...] = (c - jnp.dot(v, w2, preferred_element_type=acc)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_cols"))
def hh_block_apply_pallas(v: jax.Array, t: jax.Array, c: jax.Array, *,
                          interpret: bool = False, block_cols: int = 512
                          ) -> jax.Array:
    """C <- (I - V T V^T) C with column-striped pipelining."""
    m, k = v.shape
    n = c.shape[1]
    bc = min(block_cols, n)
    pad = (-n) % bc
    cp = jnp.pad(c, ((0, 0), (0, pad))) if pad else c
    grid = (cp.shape[1] // bc,)
    out = pl.pallas_call(
        _wy_kernel,
        out_shape=jax.ShapeDtypeStruct(cp.shape, c.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda i: (0, 0)),     # V resident
            pl.BlockSpec((k, k), lambda i: (0, 0)),     # T resident
            pl.BlockSpec((m, bc), lambda i: (0, i)),    # C streamed
        ],
        out_specs=pl.BlockSpec((m, bc), lambda i: (0, i)),
        interpret=interpret,
    )(v, t, cp)
    return out[:, :n] if pad else out
