"""Pallas TPU kernels: compact-WY blocked reflector applies.

``hh_block_apply_pallas`` (stage-1 hotspot):

    C <- (I - V T V^T) C

V: (m, k) reflector block (k = panel width, small), T: (k, k), C: (m, n).
Grid tiles the columns of C; V and T stay VMEM-resident across grid steps
(their index_map is constant, so the pipeline fetches them once), while C
streams through in ``block_cols`` stripes — three MXU matmuls per stripe.
This is the GEMM-dense counterpart of the memory-bound chase kernel: stage 1
is where the paper's pipeline earns its "compute density" (paper §I).

``tape_apply_pallas`` (tape replay, DESIGN.md §8) is the slot-batched
variant used by ``core/transforms.py`` to replay reflector tapes into
``U``/``V^T``: per wavefront slot ``s`` it applies ``(I - V_s T_s V_s^T)``
to that slot's accumulator slice, grid ``(S, column stripes)`` — the same
wavefront batching (``S = B*G``) as the chase itself.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["hh_block_apply_pallas", "tape_apply_pallas"]


@functools.partial(jax.jit, static_argnames=("interpret", "block_cols"))
def hh_block_apply_pallas(v: jax.Array, t: jax.Array, c: jax.Array, *,
                          interpret: bool = False, block_cols: int = 512
                          ) -> jax.Array:
    """C <- (I - V T V^T) C with column-striped pipelining.

    The single-problem view of :func:`tape_apply_pallas` (slot count 1) —
    one kernel serves both the stage-1 trailing update and the tape replay.
    """
    return tape_apply_pallas(v[None], t[None], c[None], interpret=interpret,
                             block_cols=block_cols)[0]


def _tape_kernel(v_ref, t_ref, c_ref, o_ref):
    acc = jnp.float32 if c_ref.dtype in (jnp.bfloat16, jnp.float16) else c_ref.dtype
    v = v_ref[0].astype(acc)                               # (m, k)
    t = t_ref[0].astype(acc)                               # (k, k)
    c = c_ref[0].astype(acc)                               # (m, bc)
    w1 = jnp.dot(v.T, c, preferred_element_type=acc)       # (k, bc)
    w2 = jnp.dot(t, w1, preferred_element_type=acc)        # (k, bc)
    o_ref[0] = (c - jnp.dot(v, w2, preferred_element_type=acc)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_cols"))
def tape_apply_pallas(v: jax.Array, t: jax.Array, c: jax.Array, *,
                      interpret: bool = False, block_cols: int = 512
                      ) -> jax.Array:
    """Per-slot C[s] <- (I - V[s] T[s] V[s]^T) C[s].

    v: (S, m, k), t: (S, k, k), c: (S, m, w).  V/T are VMEM-resident per
    slot; C streams in ``block_cols`` stripes, grid ``(S, stripes)``.
    """
    s, m, k = v.shape
    w = c.shape[-1]
    bc = min(block_cols, w)
    pad = (-w) % bc
    cp = jnp.pad(c, ((0, 0), (0, 0), (0, pad))) if pad else c
    grid = (s, cp.shape[-1] // bc)
    out = pl.pallas_call(
        _tape_kernel,
        out_shape=jax.ShapeDtypeStruct(cp.shape, c.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, m, k), lambda i, j: (i, 0, 0)),   # V per slot
            pl.BlockSpec((1, k, k), lambda i, j: (i, 0, 0)),   # T per slot
            pl.BlockSpec((1, m, bc), lambda i, j: (i, 0, j)),  # C streamed
        ],
        out_specs=pl.BlockSpec((1, m, bc), lambda i, j: (i, 0, j)),
        interpret=interpret,
    )(v, t, cp)
    return out[..., :w] if pad else out
