"""Pallas TPU kernel: causal flash attention (forward).

The §Perf A4 lever: materializing (s, s) score tensors makes dense-arch
prefill memory-bound (EXPERIMENTS.md §Roofline).  This kernel streams KV
blocks through VMEM with an online-softmax accumulator, so HBM traffic is
O(s·d) instead of O(s²) — the same "size the working set to the fastest
memory level" principle as the paper's chase kernel.

Layout: q, k, v: (BH, S, D) (batch*heads collapsed; GQA callers repeat KV
first).  Grid = (BH, S/bq); each step owns one q block in VMEM, loops over
the causal prefix of KV blocks with running (m, l, acc).  Forward only —
training integration needs the dq/dk/dv kernels (documented future work);
serving prefill is the integration point.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, scale: float):
    i = pl.program_id(1)
    d = q_ref.shape[-1]
    q = q_ref[0].astype(jnp.float32) * scale                  # (bq, d)

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, d), jnp.float32)

    def body(j, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (0, pl.dslice(j * bk, bk), slice(None))
                    ).astype(jnp.float32)                     # (bk, d)
        v = pl.load(v_ref, (0, pl.dslice(j * bk, bk), slice(None))
                    ).astype(jnp.float32)
        s = q @ k.T                                           # (bq, bk)
        q_pos = i * bq + jnp.arange(bq)[:, None]
        k_pos = j * bk + jnp.arange(bk)[None, :]
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=1)
        acc_new = acc * corr[:, None] + p @ v
        return m_new, l_new, acc_new

    # causal: only KV blocks up to and including this q block's diagonal
    # (ceil — when bk > bq the diagonal block still overlaps; masked in-body)
    n_blocks = ((i + 1) * bq + bk - 1) // bk
    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_q", "block_k", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """Causal attention, (BH, S, D) in/out.  S must divide by the blocks."""
    bh, s, d = q.shape
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0 and (bq % bk == 0 or bk % bq == 0)
    scale = 1.0 / (d ** 0.5)
    kern = functools.partial(_flash_kernel, bq=bq, bk=bk, scale=scale)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        grid=(bh, s // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),   # q block
            pl.BlockSpec((1, s, d), lambda b, i: (b, 0, 0)),    # k resident
            pl.BlockSpec((1, s, d), lambda b, i: (b, 0, 0)),    # v resident
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
        interpret=interpret,
    )(q, k, v)
