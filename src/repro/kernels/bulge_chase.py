"""Pallas TPU kernel for one wavefront of bulge-chase cycles (paper Alg. 2).

Memory mapping (GPU -> TPU, DESIGN.md §2):

* one thread block per sweep        -> one grid step per in-flight sweep
* reflector in shared memory (L1)   -> reflector in VMEM-resident window block
* TPB rows held in registers        -> row tiles materialized into VREGs from
                                       the VMEM window by the vector unit
* kernel-launch sync between cycles -> one ``pallas_call`` per K-cycle
                                       super-step (``chase_superstep_pallas``;
                                       K=1 is ``chase_cycle_pallas``)

Each grid step owns one *rolled dense window* (H, W) of the packed band
storage, H = b_in + 2*tw + 1, W = b_in + tw + 1 — the "1 + BW + TW" working
set of the paper, staged HBM -> VMEM by the BlockSpec pipeline (double-
buffered by Pallas, the TPU analogue of the paper's L1 residency), processed
entirely in VMEM, and written back.

Fused super-steps (DESIGN.md §9): with fuse depth K >= 2 a grid step owns
the CONTIGUOUS band-storage block (H, K*b_in + tw + 1) covering K
consecutive cycles of its sweep.  The diagonal shear that rolls band
storage into dense windows — done host-side per cycle at K=1 — moves inside
the kernel: one relayout (transpose + pad + reshape, the flatten shear)
builds a VMEM-resident dense workspace, the K cycles chase at static
offsets reusing the tw+1-column overlap between consecutive windows without
ever leaving VMEM, and one inverse relayout writes the block back.  HBM
sees one contiguous block load + store per K cycles instead of K sheared
gather/scatter round trips.

The kernel is batch-oblivious: a window neither knows nor cares which matrix
it came from, so the batch-native pipeline (DESIGN.md §4) simply flattens a
(B, G, H, W) wavefront into grid (B·G,) — independent problems widen the
wavefront that a single small matrix cannot fill (paper Eq. 1).

The kernel is data-precision-agnostic (fp32/bf16; accumulation in fp32),
mirroring the paper's precision-agnostic single-source claim.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["chase_cycle_pallas", "chase_superstep_pallas"]


def _reflector_in_kernel(x, acc):
    """larfg on a VREG-resident vector; tau=0 on zero tails (edge no-op)."""
    xa = x.astype(acc)
    alpha = xa[0]
    sigma = jnp.sum(xa[1:] * xa[1:])
    mu = jnp.sqrt(alpha * alpha + sigma)
    beta = jnp.where(alpha >= 0, -mu, mu)
    safe = sigma > 0
    denom = jnp.where(safe, alpha - beta, 1.0)
    tau = jnp.where(safe, (beta - alpha) / jnp.where(beta == 0, 1.0, beta), 0.0)
    v = jnp.where(jnp.arange(x.shape[0]) > 0, xa / denom, 1.0)
    return v, tau, jnp.where(safe, beta, alpha)


def _chase_window_vmem(win, first, *, b_in: int, tw: int):
    """One chase cycle on a VMEM-resident rolled dense window (H, W).

    Returns ``(win, (v, tau), (v2, tau2))`` — shared by the K=1 kernel and
    every fused cycle of the super-step kernel, so fusing changes data
    movement only, never an arithmetic operation.
    """
    h = b_in + 2 * tw + 1
    dt = win.dtype
    acc = jnp.float32 if dt in (jnp.bfloat16, jnp.float16) else dt

    # ---- right reflector: annihilate the TW-element row bulge ------------
    # overhang row: y = tw (steady) or y = 2*tw (sweep's first cycle); rows in
    # between are structurally zero in cols [0, tw], so the apply is a no-op
    # on them — select statically instead of dynamic-slicing.
    x = jnp.where(first, win[2 * tw, : tw + 1], win[tw, : tw + 1])
    v, tau, beta = _reflector_in_kernel(x, acc)
    blk = win[tw:, : tw + 1].astype(acc)               # rows [tw, H)
    wdot = blk @ v
    blk = blk - tau * wdot[:, None] * v[None, :]
    win = win.at[tw:, : tw + 1].set(blk.astype(dt))
    # structural zeros on the annihilated row
    fix = jnp.zeros((tw + 1,), acc).at[0].set(beta).astype(dt)
    hit = tau != 0
    win = win.at[tw, : tw + 1].set(
        jnp.where(hit & ~first, fix, win[tw, : tw + 1]))
    win = win.at[2 * tw, : tw + 1].set(
        jnp.where(hit & first, fix, win[2 * tw, : tw + 1]))

    # ---- left reflector: annihilate the TW-element column bulge ----------
    y0 = h - 1 - tw                                    # matrix row p (pivot)
    xc = win[y0:, 0]
    v2, tau2, beta2 = _reflector_in_kernel(xc, acc)
    blk2 = win[y0:, :].astype(acc)                     # (tw+1, W)
    w2 = v2 @ blk2
    blk2 = blk2 - tau2 * v2[:, None] * w2[None, :]
    colfix = jnp.zeros((tw + 1,), acc).at[0].set(beta2)
    blk2 = blk2.at[:, 0].set(jnp.where(tau2 != 0, colfix, blk2[:, 0]))
    win = win.at[y0:, :].set(blk2.astype(dt))
    return win, (v, tau), (v2, tau2)


def _chase_kernel(first_ref, win_ref, out_ref, *refs, b_in: int, tw: int):
    # refs: optionally (vs_ref, taus_ref) when the reflector tape is recorded.
    vs_ref, taus_ref = refs if refs else (None, None)
    dt = win_ref.dtype
    win = win_ref[0]                                   # (H, W) in VMEM
    first = first_ref[0, 0] != 0
    win, (v, tau), (v2, tau2) = _chase_window_vmem(win, first, b_in=b_in,
                                                   tw=tw)
    out_ref[0] = win
    if vs_ref is not None:
        # Reflector tape (DESIGN.md §8): the pair this cycle applied, written
        # alongside the in-place band update.  Row 0: right reflector (spans
        # matrix columns [p, p+tw], replayed into V); row 1: left (rows
        # [p, p+tw], into U).  Same VMEM-resident values the applies used.
        vs_ref[0] = jnp.stack([v.astype(dt), v2.astype(dt)])
        taus_ref[0] = jnp.stack([tau, tau2]).astype(dt)[:, None]


@functools.partial(jax.jit, static_argnames=("b_in", "tw", "interpret",
                                             "with_tape"))
def chase_cycle_pallas(windows: jax.Array, is_first: jax.Array, *, b_in: int,
                       tw: int, interpret: bool = False,
                       with_tape: bool = False):
    """windows: (G, H, W) disjoint rolled windows; is_first: (G,) bool.

    ``with_tape=True`` additionally returns the wavefront's reflector tape
    slice ``(vs (G, 2, tw+1), taus (G, 2))`` — the window update itself is
    computed by the identical instruction sequence either way."""
    g, h, w = windows.shape
    assert h == b_in + 2 * tw + 1 and w == b_in + tw + 1, (windows.shape, b_in, tw)
    first = is_first.astype(jnp.int32).reshape(g, 1)
    kern = functools.partial(_chase_kernel, b_in=b_in, tw=tw)
    out_shape = [jax.ShapeDtypeStruct(windows.shape, windows.dtype)]
    out_specs = [pl.BlockSpec((1, h, w), lambda i: (i, 0, 0))]
    if with_tape:
        out_shape += [jax.ShapeDtypeStruct((g, 2, tw + 1), windows.dtype),
                      jax.ShapeDtypeStruct((g, 2, 1), windows.dtype)]
        out_specs += [pl.BlockSpec((1, 2, tw + 1), lambda i: (i, 0, 0)),
                      pl.BlockSpec((1, 2, 1), lambda i: (i, 0, 0))]
    res = pl.pallas_call(
        kern,
        out_shape=tuple(out_shape),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),        # is_first scalar
            pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),  # window in VMEM
        ],
        out_specs=tuple(out_specs),
        input_output_aliases={1: 0},
        interpret=interpret,
    )(first, windows)
    if with_tape:
        out, vs, taus = res
        return out, vs, taus[..., 0]
    return res[0]


# ---------------------------------------------------------------------------
# Fuse-depth-K super-steps (DESIGN.md §9)
# ---------------------------------------------------------------------------

def _shear_roll(block):
    """Band block (H, WK) -> VMEM dense workspace (H + WK - 1, WK).

    ``dense[y, w] = rev[y - w, w]`` with ``rev = block[::-1]`` — the column
    shear that aligns matrix rows with workspace rows.  Implemented as the
    *flatten shear*: transpose, pad WK zero columns, and reinterpret the
    flat buffer at row pitch ``H + WK - 1`` — each row lands shifted by its
    index, zeros fill the off-parallelogram cells.  On TPU this lowers to
    relayout + reshape (no gather); the workspace height ``H + WK - 1``
    makes the shear a pure permutation, so roll -> unroll round-trips every
    block cell bit-exactly.
    """
    h, wk = block.shape
    hc = h + wk - 1
    bt = block[::-1].T                         # (WK, H): row w = reversed col w
    btp = jnp.pad(bt, ((0, 0), (0, wk)))       # (WK, H + WK)
    return btp.reshape(-1)[: wk * hc].reshape(wk, hc).T


def _shear_unroll(dense, h):
    """Inverse of :func:`_shear_roll`: (H + WK - 1, WK) -> (H, WK)."""
    hc, wk = dense.shape
    flat = jnp.pad(dense.T.reshape(-1), (0, wk))
    x = flat.reshape(wk, hc + 1)[:, :h]        # x[w, r] = dense[r + w, w]
    return x[:, ::-1].T


def _chase_superstep_kernel(first_ref, act_ref, blk_ref, out_ref, *refs,
                            b_in: int, tw: int, fuse: int):
    # refs: optionally (vs_ref, taus_ref) when the reflector tape is recorded.
    vs_ref, taus_ref = refs if refs else (None, None)
    h = b_in + 2 * tw + 1
    w = b_in + tw + 1
    dt = blk_ref.dtype
    block = blk_ref[0]                                 # (H, WK) in VMEM
    first = first_ref[0, 0] != 0
    dense = _shear_roll(block)                         # stays in VMEM
    vs, taus = [], []
    for i in range(fuse):
        # cycle i's window sits at static offset (i*b_in, i*b_in): the
        # tw+1-column overlap with cycle i-1's window is already updated in
        # the workspace — the residency the host round trip threw away.
        act = act_ref[0, i] != 0
        win = dense[i * b_in:i * b_in + h, i * b_in:i * b_in + w]
        new, (v, tau), (v2, tau2) = _chase_window_vmem(
            win, jnp.logical_and(first, i == 0), b_in=b_in, tw=tw)
        new = jnp.where(act, new, win)
        dense = dense.at[i * b_in:i * b_in + h, i * b_in:i * b_in + w].set(new)
        vs.append(jnp.stack([v.astype(dt), v2.astype(dt)]))
        taus.append(jnp.stack([tau, tau2]).astype(dt)[:, None])
    out_ref[0] = _shear_unroll(dense, h)
    if vs_ref is not None:
        vs_ref[0] = jnp.stack(vs)                      # (fuse, 2, tw+1)
        taus_ref[0] = jnp.stack(taus)                  # (fuse, 2, 1)


@functools.partial(jax.jit, static_argnames=("b_in", "tw", "fuse",
                                             "interpret", "with_tape"))
def chase_superstep_pallas(blocks: jax.Array, is_first: jax.Array,
                           active: jax.Array, *, b_in: int, tw: int,
                           fuse: int, interpret: bool = False,
                           with_tape: bool = False):
    """blocks: (G, H, WK) disjoint contiguous band blocks, WK = fuse*b_in +
    tw + 1; is_first: (G,) bool (fused cycle 0 is its sweep's first);
    active: (G, fuse) bool prefix mask of live cycles per slot.

    One grid step = one K-cycle super-step of one sweep, entirely
    VMEM-resident.  ``with_tape=True`` additionally returns the super-step's
    reflector tape slice ``(vs (G, fuse, 2, tw+1), taus (G, fuse, 2))``.
    """
    g, h, wk = blocks.shape
    assert h == b_in + 2 * tw + 1 and wk == fuse * b_in + tw + 1, (
        blocks.shape, b_in, tw, fuse)
    first = is_first.astype(jnp.int32).reshape(g, 1)
    act = active.astype(jnp.int32).reshape(g, fuse)
    kern = functools.partial(_chase_superstep_kernel, b_in=b_in, tw=tw,
                             fuse=fuse)
    out_shape = [jax.ShapeDtypeStruct(blocks.shape, blocks.dtype)]
    out_specs = [pl.BlockSpec((1, h, wk), lambda i: (i, 0, 0))]
    if with_tape:
        out_shape += [
            jax.ShapeDtypeStruct((g, fuse, 2, tw + 1), blocks.dtype),
            jax.ShapeDtypeStruct((g, fuse, 2, 1), blocks.dtype)]
        out_specs += [pl.BlockSpec((1, fuse, 2, tw + 1), lambda i: (i, 0, 0, 0)),
                      pl.BlockSpec((1, fuse, 2, 1), lambda i: (i, 0, 0, 0))]
    res = pl.pallas_call(
        kern,
        out_shape=tuple(out_shape),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),         # is_first scalar
            pl.BlockSpec((1, fuse), lambda i: (i, 0)),      # active mask
            pl.BlockSpec((1, h, wk), lambda i: (i, 0, 0)),  # band block in VMEM
        ],
        out_specs=tuple(out_specs),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(first, act, blocks)
    if with_tape:
        out, vs, taus = res
        return out, vs, taus[..., 0]
    return res[0]
