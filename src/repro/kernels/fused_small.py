"""One-dispatch fused small-n SVD kernel (DESIGN.md §13).

For the serve tier's dominant workload — thousands of small matrices per
step — the staged pipeline pays one kernel dispatch per chase super-step,
so launch overhead, not bandwidth, bounds latency.  Following the batched
small-size design point (Abdelfattah & Fasi, PAPERS.md: one thread block
per matrix, whole problem resident on chip), this module runs the ENTIRE
per-matrix reduction inside a single ``pallas_call`` over a ``(B,)`` grid:

* phase 1 — dense -> upper-banded(bw): per-column left reflector (zero the
  subdiagonal tail) + right reflector pivoted at ``j + bw`` (truncate the
  row to bw superdiagonals).  Already-banded inputs cost nothing extra:
  zero tails give ``tau = 0`` reflectors, exact no-ops (householder.py).
* phase 2 — band -> bidiagonal: ONE SBR stage with ``b_in = bw``,
  ``tw = bw - 1`` (b_out = 1), the same sweep/pivot walk as the numpy
  oracle ``core.reference.reduce_stage_dense_ref`` — every bulge-chase
  cycle runs in-kernel, no per-cycle dispatch, no host round-trips.
* phase 3 — singular values: the Golub–Kahan Sturm-count bisection of
  ``core.bidiag_svd.bidiag_singular_values`` inlined and vectorized over
  all n values at once (identical per-element arithmetic).

The (n, n) working set plus an (n,) scratch vector — and for
``compute_uv=True`` the two (n, n) accumulators — stay VMEM-resident for
the kernel's lifetime (budget math: ``core.tuning.fused_working_set_bytes``).
``compute_uv=True`` returns ``(d, e, U2, V2^T)`` instead: the bidiagonal
plus the accumulated two-sided transforms; the caller composes the final
vectors with one batched ``bidiag_svd`` call (two dispatches total — the
values path, the B-heavy serve workload, is the one-dispatch tier).

Reflectors use a *masked* variant of ``core.householder.make_reflector``:
full-length (n,) vectors with support ``[lo, hi]`` selected by iota masks,
so every loop iteration has static shapes (fori-able, Mosaic-friendly) and
inactive cycles (pivot past the edge) degenerate to exact no-ops through
the same ``tau = 0`` path that handles zero tails.

CPU CI runs this kernel under ``interpret=True`` (small n only — interpret
mode evaluates the bisection's fori steps eagerly); the production CPU path
is the jitted twin ``kernels.ref.fused_small_svd_ref`` which vmaps the same
`_reduce_single` body and delegates phase 3 to ``bidiag_singular_values``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fused_small_svd_pallas"]


# ---------------------------------------------------------------------------
# masked reflector + structural fixes (static shapes, iota masks)
# ---------------------------------------------------------------------------

def _masked_reflector(x, lo, hi, idx):
    """(v, tau, beta) for the reflector over ``x[lo:hi+1]`` (pivot ``lo``),
    returned as a full-length masked vector: ``v[lo] = 1``, support-only
    tail, zeros elsewhere.  Empty / out-of-range / zero-tail supports give
    ``tau = 0`` — same formulas and guards as ``householder.make_reflector``.
    """
    dt = x.dtype
    acc = jnp.float32 if dt in (jnp.bfloat16, jnp.float16) else dt
    xa = x.astype(acc)
    tail = (idx > lo) & (idx <= hi)
    alpha = jnp.sum(jnp.where(idx == lo, xa, 0))
    x2 = jnp.where(tail, xa, 0)
    sigma = jnp.sum(x2 * x2)
    mu = jnp.sqrt(alpha * alpha + sigma)
    beta = jnp.where(alpha >= 0, -mu, mu)
    safe = sigma > 0
    denom = jnp.where(safe, alpha - beta, 1.0)
    tau = jnp.where(safe, (beta - alpha) / beta, 0.0)
    v = jnp.where(safe, x2 / denom, 0.0) + jnp.where(idx == lo, 1.0, 0.0)
    beta_out = jnp.where(safe, beta, alpha)
    return v.astype(dt), tau.astype(dt), beta_out.astype(dt)


def _fix_row(a, rows2, cols2, r, lo, hi, beta, tau):
    """Post-right-reflector structural fix: row ``r`` gets exact zeros on
    ``(lo, hi]`` and ``beta`` at ``lo`` — gated on ``tau != 0`` exactly like
    the numpy oracle's ``if tau != 0.0`` branch."""
    inrow = rows2 == r
    fixed = jnp.where(inrow & (cols2 > lo) & (cols2 <= hi),
                      jnp.zeros_like(a), a)
    fixed = jnp.where(inrow & (cols2 == lo), beta, fixed)
    return jnp.where(tau != 0, fixed, a)


def _fix_col(a, rows2, cols2, c, lo, hi, beta, tau):
    incol = cols2 == c
    fixed = jnp.where(incol & (rows2 > lo) & (rows2 <= hi),
                      jnp.zeros_like(a), a)
    fixed = jnp.where(incol & (rows2 == lo), beta, fixed)
    return jnp.where(tau != 0, fixed, a)


# ---------------------------------------------------------------------------
# single-matrix whole-pipeline body (shared by the pallas kernel and the
# kernels/ref.py CPU twin)
# ---------------------------------------------------------------------------

def _reduce_single(a, *, bw, compute_uv):
    """Phases 1+2 on one (n, n) matrix: returns ``(a, u, v, d, e)`` with
    ``a`` bidiagonal, ``u^T a_in v`` bidiagonal when ``compute_uv`` (else
    ``u``/``v`` are (1, 1) dummies), and (d, e) in the e[0]-unused
    convention of ``bidiag_singular_values``."""
    n = a.shape[0]
    dt = a.dtype
    rows2 = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    cols2 = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    idx = cols2[0]
    zero = jnp.zeros_like(a)
    if compute_uv:
        u = (rows2 == cols2).astype(dt)
        v = (rows2 == cols2).astype(dt)
    else:
        u = v = jnp.zeros((1, 1), dt)

    def right(carry, r, lo, hi):
        a, u, v = carry
        row = jnp.sum(jnp.where(rows2 == r, a, zero), axis=0)
        vec, tau, beta = _masked_reflector(row, lo, hi, idx)
        a = a - tau * jnp.outer(a @ vec, vec)
        a = _fix_row(a, rows2, cols2, r, lo, hi, beta, tau)
        if compute_uv:
            v = v - tau * jnp.outer(v @ vec, vec)
        return a, u, v

    def left(carry, lo, hi):
        a, u, v = carry
        col = jnp.sum(jnp.where(cols2 == lo, a, zero), axis=1)
        vec, tau, beta = _masked_reflector(col, lo, hi, idx)
        a = a - tau * jnp.outer(vec, vec @ a)
        a = _fix_col(a, rows2, cols2, lo, lo, hi, beta, tau)
        if compute_uv:
            u = u - tau * jnp.outer(u @ vec, vec)
        return a, u, v

    # phase 1: dense -> upper-banded(bw).  Banded inputs: all tau = 0.
    def p1(j, carry):
        carry = left(carry, j, n - 1)          # zero a[j+1:, j]
        return right(carry, j, j + bw, n - 1)  # zero a[j, j+bw+1:]

    carry = jax.lax.fori_loop(0, max(n - 1, 0), p1, (a, u, v))

    # phase 2: one SBR stage b_in = bw, tw = bw - 1 (b_out = 1) — the
    # sweep/pivot walk of reference.reduce_stage_dense_ref, every cycle
    # in-kernel.  bw == 1 means phase 1 already left a bidiagonal.
    if bw >= 2 and n >= 3:
        ncyc = (n - 2) // bw + 1

        def cyc(R, jc, carry):
            p = R + 1 + jc * bw
            r = jnp.where(jc == 0, R, p - bw)
            hi = jnp.minimum(p + bw - 1, n - 1)
            carry = right(carry, r, p, hi)     # chase the bulge row
            return left(carry, p, hi)          # re-zero the bulge column

        def sweep(R, carry):
            return jax.lax.fori_loop(
                0, ncyc, lambda jc, c: cyc(R, jc, c), carry)

        carry = jax.lax.fori_loop(0, n - 2, sweep, carry)

    a, u, v = carry
    d = jnp.sum(jnp.where(rows2 == cols2, a, zero), axis=1)
    e = jnp.sum(jnp.where(cols2 == rows2 + 1, a, zero), axis=0)
    return a, u, v, d, e


def _sigma_from_bidiag(d, e, *, max_iter=None):
    """In-kernel phase 3: ``bidiag_singular_values`` arithmetic, vectorized
    over all n shift searches at once instead of vmapped (identical
    per-element float ops: same z, same power-of-two prescale, same bound,
    same Sturm recurrence and guards, same iteration count).
    ``max_iter=None`` picks the dtype default, mirroring the core path."""
    n = d.shape[0]
    dt = d.dtype
    if n == 1:
        return jnp.abs(d)
    acc = jnp.float32 if dt in (jnp.bfloat16, jnp.float16) else dt
    m = 2 * n - 1
    im = jax.lax.broadcasted_iota(jnp.int32, (m, n), 0)
    jn = jax.lax.broadcasted_iota(jnp.int32, (m, n), 1)
    # z = (d_1, e_1, d_2, ..., e_{n-1}, d_n): gk_offdiag via one-hot masks.
    da = d.astype(acc)
    ea = e.astype(acc)
    z = (jnp.sum(jnp.where(im == 2 * jn, da[None, :], 0), axis=1)
         + jnp.sum(jnp.where(im == 2 * jn - 1, ea[None, :], 0), axis=1))
    # Power-of-two prescale, mirroring core ``_gk_prescale``: keeps the
    # squared Sturm pivots in range for extreme input magnitudes while
    # changing no mantissa bits.
    zmax = jnp.max(jnp.abs(z))
    sc = jnp.exp2(jnp.round(
        jnp.log2(jnp.where(zmax > 0, zmax, 1)))).astype(acc)
    z = z / sc
    az = jnp.abs(z)
    # Gershgorin bound == max(pad[:-1] + pad[1:]) + 1 with zero end-padding.
    bound = jnp.maximum(jnp.max(az[:-1] + az[1:]),
                        jnp.maximum(az[0], az[-1])) + jnp.asarray(1, acc)
    if max_iter is None:
        max_iter = 60 if acc == jnp.float64 else 40
    tiny = jnp.asarray(jnp.finfo(acc).tiny * 4, acc)
    idxm = im[:, 0]
    ks = jn[0] + 1                                 # 1-indexed ascending

    def sturm_vec(lam):                            # lam: (n,) shifts
        def body(k, carry):
            t, cnt = carry
            t = jnp.where(jnp.abs(t) < tiny,
                          jnp.where(t < 0, -tiny, tiny), t)
            zk = jnp.sum(jnp.where(idxm == k - 1, z, 0))
            t_next = -lam - (zk * zk) / t
            return t_next, cnt + (t_next < 0)

        t0 = -lam
        _, cnt = jax.lax.fori_loop(1, m + 1, body,
                                   (t0, (t0 < 0).astype(jnp.int32)))
        return cnt

    def bis(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        ok = (sturm_vec(mid) - n) >= ks
        return jnp.where(ok, lo, mid), jnp.where(ok, mid, hi)

    lo, hi = jax.lax.fori_loop(0, max_iter, bis,
                               (jnp.zeros((n,), acc),
                                jnp.zeros((n,), acc) + bound))
    sig = 0.5 * (lo + hi)
    rev = (jn[0][:, None] + jn[0][None, :]) == (n - 1)
    return (jnp.sum(jnp.where(rev, sig[None, :], 0), axis=1) * sc).astype(dt)


# ---------------------------------------------------------------------------
# pallas kernel: grid (B,), one matrix per grid step, VMEM-resident
# ---------------------------------------------------------------------------

def _values_kernel(a_ref, sig_ref, *, bw, max_iter):
    a = a_ref[0]
    _, _, _, d, e = _reduce_single(a, bw=bw, compute_uv=False)
    sig_ref[0] = _sigma_from_bidiag(d, e, max_iter=max_iter)


def _uv_kernel(a_ref, d_ref, e_ref, u_ref, vt_ref, *, bw):
    a = a_ref[0]
    _, u, v, d, e = _reduce_single(a, bw=bw, compute_uv=True)
    d_ref[0] = d
    e_ref[0] = e
    u_ref[0] = u
    vt_ref[0] = v.T


def effective_bw(n: int, bw: int) -> int:
    """Clamp a requested bandwidth to the fused kernel's valid range
    (bw = 0 requests mean "pick for me" and become 1; bw beyond n - 1 is
    structurally meaningless for an n x n matrix)."""
    return int(max(1, min(int(bw), max(int(n) - 1, 1))))


@functools.partial(jax.jit,
                   static_argnames=("bw", "compute_uv", "interpret",
                                    "max_iter"))
def fused_small_svd_pallas(mats, *, bw, compute_uv=False, interpret=False,
                           max_iter=None):
    """Whole-pipeline SVD of a (B, n, n) stack, one grid step per matrix.

    Values mode returns sigma (B, n) descending — ONE dispatch end to end.
    ``compute_uv=True`` returns ``(d, e, u2, vt2)``; compose vectors with
    one batched ``bidiag_svd`` (see ``core.svd``).  ``max_iter=None`` picks
    the dtype-default bisection sweeps; an explicit value must be >= 1.
    """
    if max_iter is not None and max_iter < 1:
        raise ValueError(
            f"max_iter must be None (auto) or >= 1, got {max_iter}")
    mats = jnp.asarray(mats)
    assert mats.ndim == 3 and mats.shape[-1] == mats.shape[-2], mats.shape
    b, n, _ = mats.shape
    bw_eff = effective_bw(n, bw)
    in_specs = [pl.BlockSpec((1, n, n), lambda i: (i, 0, 0))]
    if compute_uv:
        kern = functools.partial(_uv_kernel, bw=bw_eff)
        out_shape = (jax.ShapeDtypeStruct((b, n), mats.dtype),
                     jax.ShapeDtypeStruct((b, n), mats.dtype),
                     jax.ShapeDtypeStruct((b, n, n), mats.dtype),
                     jax.ShapeDtypeStruct((b, n, n), mats.dtype))
        out_specs = (pl.BlockSpec((1, n), lambda i: (i, 0)),
                     pl.BlockSpec((1, n), lambda i: (i, 0)),
                     pl.BlockSpec((1, n, n), lambda i: (i, 0, 0)),
                     pl.BlockSpec((1, n, n), lambda i: (i, 0, 0)))
    else:
        kern = functools.partial(_values_kernel, bw=bw_eff,
                                 max_iter=max_iter)
        out_shape = jax.ShapeDtypeStruct((b, n), mats.dtype)
        out_specs = pl.BlockSpec((1, n), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        out_shape=out_shape,
        grid=(b,),
        in_specs=in_specs,
        out_specs=out_specs,
        interpret=interpret,
    )(mats)
