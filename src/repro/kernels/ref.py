"""Pure-jnp oracles for the Pallas kernels.

``chase_cycle_ref`` is the reference for ``kernels/bulge_chase.py``; it operates on
*rolled dense windows* of the packed band storage (see core/bulge_chasing.py for the
rolling scheme).  One window = one bulge-chase cycle of one sweep (paper Alg. 2):

  window[y, w] = A[i0 + y, p + w],   i0 = p - b_in - tw,
  H = b_in + 2*tw + 1,  W = b_in + tw + 1   ("1 + BW + TW consecutive elements")

Cycle = (1) right reflector annihilating the TW-element row bulge of row
``r = p - b_in`` (or ``r = R = p - b_out`` on a sweep's first cycle — paper Alg. 1
line 7), then (2) left reflector annihilating the TW-element column bulge of the
pivot column ``p``, applied to all W window columns.

``hh_block_apply_ref`` is the oracle for the stage-1 WY blocked reflector apply;
``tape_apply_ref`` for the batched compact-WY tape replay (core/transforms.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.householder import make_reflector

__all__ = ["chase_window_ref", "chase_cycle_ref", "chase_superstep_ref",
           "hh_block_apply_ref", "tape_apply_ref", "flash_attention_ref",
           "fused_small_svd_ref"]


def _chase_window(window: jax.Array, is_first: jax.Array, *, b_in: int,
                  tw: int):
    """One chase cycle on a rolled dense window, returning the reflector pair.

    window: (H, W) with H = b_in + 2*tw + 1, W = b_in + tw + 1.
    is_first: scalar bool — first cycle of its sweep (overhang row at y=2*tw
    instead of y=tw; the rows in between are already-reduced zeros, so the
    unconditional apply over y >= tw is a no-op on them).

    Returns ``(window, (v, tau), (v2, tau2))`` — the right reflector (spans
    matrix columns [p, p+tw], accumulates into V on replay) and the left one
    (spans matrix rows [p, p+tw], accumulates into U).
    """
    H, W = window.shape
    assert H == b_in + 2 * tw + 1 and W == b_in + tw + 1, (H, W, b_in, tw)
    dt = window.dtype

    # ---- right reflector: annihilate row bulge, columns [0, tw] of row y_r ----
    y_r = jnp.where(is_first, 2 * tw, tw)
    x = jax.lax.dynamic_slice(window, (y_r, 0), (1, tw + 1))[0]
    v, tau, beta = make_reflector(x)
    blk = window[tw:, : tw + 1]                                   # rows [tw, H)
    w_dot = blk @ v                                               # (H - tw,)
    blk = blk - tau * jnp.outer(w_dot, v)
    window = window.at[tw:, : tw + 1].set(blk.astype(dt))
    # structural zeros for the annihilated row (avoid round-off debris)
    row_fix = jnp.zeros((1, tw + 1), dt).at[0, 0].set(beta)
    keep = jax.lax.dynamic_slice(window, (y_r, 0), (1, tw + 1))
    row_fix = jnp.where(tau != 0, row_fix, keep)
    window = jax.lax.dynamic_update_slice(window, row_fix, (y_r, 0))

    # ---- left reflector: annihilate column bulge of pivot column (w=0) ----
    y0 = H - 1 - tw                                               # matrix row p
    xc = window[y0:, 0]
    v2, tau2, beta2 = make_reflector(xc)
    blk2 = window[y0:, :]                                         # (tw+1, W)
    w2 = v2 @ blk2
    blk2 = blk2 - tau2 * jnp.outer(v2, w2)
    col_fix = jnp.zeros((tw + 1,), dt).at[0].set(beta2)
    col_fix = jnp.where(tau2 != 0, col_fix, blk2[:, 0].astype(dt))
    blk2 = blk2.astype(dt).at[:, 0].set(col_fix)
    window = window.at[y0:, :].set(blk2)
    return window, (v.astype(dt), tau.astype(dt)), (v2.astype(dt),
                                                    tau2.astype(dt))


def chase_window_ref(window: jax.Array, is_first: jax.Array, *, b_in: int, tw: int) -> jax.Array:
    """Process one chase cycle on a rolled dense window (values only)."""
    out, _, _ = _chase_window(window, is_first, b_in=b_in, tw=tw)
    return out


def chase_cycle_ref(windows: jax.Array, is_first: jax.Array, *, b_in: int,
                    tw: int, with_tape: bool = False):
    """vmapped oracle over a batch of disjoint windows: (G, H, W).

    ``with_tape=True`` additionally returns the reflector tape slice for the
    wavefront: ``vs (G, 2, tw+1)`` and ``taus (G, 2)`` (pair axis: right
    reflector first, then left)."""
    def fn(w, f):
        out, (v, tau), (v2, tau2) = _chase_window(w, f, b_in=b_in, tw=tw)
        return out, jnp.stack([v, v2]), jnp.stack([tau, tau2])

    out, vs, taus = jax.vmap(fn)(windows, is_first)
    if with_tape:
        return out, vs, taus
    return out


def chase_superstep_ref(blocks: jax.Array, is_first: jax.Array,
                        active: jax.Array, *, b_in: int, tw: int, fuse: int,
                        with_tape: bool = False):
    """Fuse-depth-K super-step oracle on contiguous band-storage blocks.

    blocks: (G, H, WK) with WK = fuse*b_in + tw + 1 — each slot's K
    consecutive chase windows as ONE column block of the packed storage;
    is_first: (G,) — fused cycle 0 is its sweep's first cycle;
    active: (G, fuse) — per-fused-cycle activity (a prefix mask; inactive
    cycles leave the block untouched and their recorded pair is discarded
    by the caller via ``tau = 0``).

    The roll to dense windows happens HERE (the fast-memory-resident
    analogue of the host-side K=1 gather): window i of a slot is the shear
    ``win_i[y, w] = rev[y - w, i*b_in + w]`` (``rev = block[::-1]``, zero
    above the diagonal ``y < w``), all K gathered in ONE indexed read.  The
    K cycles then chase sequentially; consecutive windows overlap in a
    ``(2*tw+1, tw+1)`` dense corner, and because the overlaps are *nested*
    (window i's intersection with ANY earlier window lies inside window
    i-1's footprint), patching that single corner from cycle i-1's output
    forwards every earlier update — the ``tw+1``-column overlap reuse of
    DESIGN.md §9.  One static select per block cell (latest covering
    window, else the untouched input) shears everything back.  Reflector
    math is :func:`_chase_window`, identical to the K=1 path, so fusing
    does not change a single arithmetic operation.

    ``with_tape=True`` additionally returns ``vs (G, fuse, 2, tw+1)`` and
    ``taus (G, fuse, 2)`` (pair axis: right reflector first, then left).
    """
    G, H, WK = blocks.shape
    assert H == b_in + 2 * tw + 1 and WK == fuse * b_in + tw + 1, (
        blocks.shape, b_in, tw, fuse)
    W = b_in + tw + 1
    K = fuse

    # static shear indices: all K windows of one block in one gather
    ii = jnp.arange(K)[:, None, None]                 # (K, 1, 1)
    yy = jnp.arange(H)[None, :, None]                 # (1, H, 1)
    ww = jnp.arange(W)[None, None, :]                 # (1, 1, W)
    win_rows = jnp.clip(yy - ww, 0, H - 1)            # rev row per window cell
    win_cols = ii * b_in + ww
    win_valid = yy >= ww
    # static un-shear: latest window covering each block cell (else input)
    dd = jnp.arange(H)[:, None]
    cc = jnp.arange(WK)[None, :]
    y_dense = cc + (H - 1 - dd)                       # dense row of band cell
    i_hi = jnp.minimum(jnp.minimum(y_dense // b_in, cc // b_in), K - 1)
    i_lo = jnp.maximum(jnp.maximum(-((H - 1 - y_dense) // b_in),
                                   -((W - 1 - cc) // b_in)), 0)
    covered = i_hi >= i_lo
    sel = jnp.clip(i_hi, 0, K - 1)
    sel_y = jnp.clip(y_dense - sel * b_in, 0, H - 1)
    sel_w = jnp.clip(cc - sel * b_in, 0, W - 1)

    def one(block, first, act):
        rev = block[::-1]
        wins = jnp.where(win_valid, rev[win_rows, win_cols], 0)   # (K, H, W)
        outs, vs, taus = [], [], []
        for i in range(K):
            win = wins[i]
            if i > 0:
                # nested-overlap patch: window i's shared cells with every
                # earlier window lie inside window i-1's footprint, so one
                # corner copy forwards all pending updates.
                win = win.at[:H - b_in, :W - b_in].set(
                    outs[-1][b_in:, b_in:])
            out, (v, tau), (v2, tau2) = _chase_window(
                win, first if i == 0 else jnp.bool_(False), b_in=b_in, tw=tw)
            out = jnp.where(act[i], out, win)
            outs.append(out)
            vs.append(jnp.stack([v, v2]))
            taus.append(jnp.stack([tau, tau2]))
        stacked = jnp.stack(outs)                                 # (K, H, W)
        block_out = jnp.where(covered, stacked[sel, sel_y, sel_w],
                              block)
        return block_out, jnp.stack(vs), jnp.stack(taus)

    out, vs, taus = jax.vmap(one)(blocks, is_first, active)
    if with_tape:
        return out, vs, taus
    return out


def hh_block_apply_ref(v: jax.Array, t: jax.Array, c: jax.Array) -> jax.Array:
    """WY blocked reflector apply oracle:  C <- (I - V T V^T) C.

    v: (m, k) unit-lower-trapezoidal reflector block, t: (k, k) upper-triangular
    compact-WY factor, c: (m, ncols).  The single-slot view of
    :func:`tape_apply_ref` — one oracle serves both.
    """
    return tape_apply_ref(v[None], t[None], c[None])[0]


def tape_apply_ref(v: jax.Array, t: jax.Array, c: jax.Array) -> jax.Array:
    """Batched compact-WY left apply oracle: per slot s,

        C[s] <- (I - V[s] T[s] V[s]^T) C[s]

    v: (S, m, k), t: (S, k, k), c: (S, m, w).  The tape-replay workhorse
    (core/transforms.py): stage-1 panels use k = nb blocks, the chase tape
    uses k = 1 (rank-1 Householder, t = tau).
    """
    acc = jnp.float32 if c.dtype in (jnp.bfloat16, jnp.float16) else c.dtype
    vv, tt, cc = v.astype(acc), t.astype(acc), c.astype(acc)
    w1 = jnp.einsum("smk,smw->skw", vv, cc)
    out = cc - jnp.einsum("smk,skw->smw", vv, jnp.einsum("skj,sjw->skw", tt, w1))
    return out.astype(c.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Oracle for kernels/flash_attention.py: plain causal softmax attention.

    q, k, v: (BH, S, D)."""
    s_len = q.shape[1]
    scale = 1.0 / (q.shape[-1] ** 0.5)
    scores = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = jnp.tril(jnp.ones((s_len, s_len), bool))
    scores = jnp.where(mask[None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bst,btd->bsd", w, v.astype(jnp.float32)).astype(q.dtype)


def fused_small_svd_ref(mats, *, bw: int, compute_uv: bool = False,
                        max_iter: int | None = None):
    """CPU/interpret twin of ``fused_small.fused_small_svd_pallas``.

    vmaps the SAME single-matrix whole-pipeline body (`_reduce_single`,
    phases 1+2) over the batch but delegates phase 3 to the existing
    vmapped ``core.bidiag_svd.bidiag_singular_values`` — on CPU one jitted
    XLA computation replaces the kernel's grid, which is exactly the fused
    tier's point (one dispatch per bucket, no per-cycle launches).  Values
    mode returns sigma (B, n) descending; ``compute_uv=True`` returns
    ``(d, e, u2, vt2)`` like the pallas kernel.
    """
    import functools

    from repro.core import bidiag_svd as _s3
    from repro.kernels import fused_small as _fs

    mats = jnp.asarray(mats)
    assert mats.ndim == 3 and mats.shape[-1] == mats.shape[-2], mats.shape
    n = mats.shape[-1]
    bw_eff = _fs.effective_bw(n, bw)
    red = jax.vmap(functools.partial(_fs._reduce_single, bw=bw_eff,
                                     compute_uv=compute_uv))
    _, u, v, d, e = red(mats)
    if compute_uv:
        return d, e, u, jnp.swapaxes(v, -1, -2)
    return _s3.bidiag_singular_values(d, e, max_iter=max_iter)
