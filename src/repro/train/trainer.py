"""Trainer: builds the jitted, sharded train_step for any zoo model.

Features:
* microbatch gradient accumulation (lax.scan, memory-flat);
* logical->physical sharding for params / optimizer state (ZeRO-1) / batch;
* optional spectral gradient clipping fed by the SpectralMonitor (the paper's
  SVD engine);
* optional PowerSGD gradient compression over the DP axes (shard_map with the
  model axis left automatic);
* state donation (params/opt buffers reused in place).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import batch_logical
from repro.parallel import compression as comp
from repro.parallel.sharding import (AxisRules, param_shardings, use_rules,
                                     zero1_shardings)
from repro.train import optimizer as optim

__all__ = ["Trainer"]


@dataclasses.dataclass
class Trainer:
    model: Any
    opt_cfg: optim.AdamWConfig
    mesh: Any = None
    rules: AxisRules | None = None
    accum: int = 1
    compression: comp.CompressionConfig | None = None
    dp_axes: tuple[str, ...] = ("data",)

    # ---------------- state -----------------------------------------------
    def _n_dp(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.dp_axes:
            n *= self.mesh.shape.get(a, 1)
        return n

    def init_state(self, key) -> dict:
        params = self.model.init(key)
        state = {"params": params, "opt": optim.adamw_init(params)}
        if self.compression is not None:
            state["comp"] = comp.compression_init(self.compression, params,
                                                  n_workers=self._n_dp())
        return state

    def state_shardings(self, state=None):
        if self.rules is None or self.mesh is None:
            return None
        logical = self.model.param_logical()
        shapes = self.model.param_shapes()
        p_sh = param_shardings(logical, self.rules)
        m_sh = zero1_shardings(logical, shapes, self.rules, self.dp_axes)
        rep = NamedSharding(self.mesh, P())
        out = {"params": p_sh,
               "opt": {"step": rep, "m": m_sh, "v": m_sh}}
        if self.compression is not None and state is not None:
            dp = tuple(a for a in self.dp_axes if a in self.mesh.shape)
            err_sh = NamedSharding(self.mesh, P(dp))

            def comp_sh(path, leaf):
                last = str(path[-1].key) if path else ""
                return err_sh if last == "err" else rep
            out["comp"] = jax.tree_util.tree_map_with_path(
                comp_sh, state["comp"], is_leaf=lambda x: x is None)
        return out

    def batch_shardings(self, suite):
        if self.rules is None or self.mesh is None:
            return None
        logical = batch_logical(self.model.cfg, suite)
        return jax.tree_util.tree_map(
            lambda l: NamedSharding(self.mesh, self.rules.spec(l)),
            logical, is_leaf=lambda x: isinstance(x, tuple))

    # ---------------- step ------------------------------------------------
    def _grads(self, params, batch):
        """Loss + grads, with microbatch accumulation if accum > 1."""
        loss_fn = lambda p, b: self.model.loss_fn(p, b)
        if self.accum <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads

        def micro(carry, mb):
            acc_g, acc_l = carry
            (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb)
            acc_g = jax.tree_util.tree_map(
                lambda a, x: a + x.astype(jnp.float32), acc_g, g)
            return (acc_g, acc_l + loss), metrics

        split = jax.tree_util.tree_map(
            lambda x: x.reshape((self.accum, x.shape[0] // self.accum)
                                + x.shape[1:]), batch)
        zero_g = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), metrics = jax.lax.scan(micro, (zero_g, 0.0), split)
        grads = jax.tree_util.tree_map(lambda g: g / self.accum, grads)
        metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        return loss_sum / self.accum, metrics, grads

    def make_train_step(self):
        if self.compression is not None:
            return self._make_compressed_step()

        def step(state, batch, sigma_tree=None):
            with use_rules(self.rules):
                loss, metrics, grads = self._grads(state["params"], batch)
                params, opt, opt_metrics = optim.adamw_update(
                    state["params"], grads, state["opt"], self.opt_cfg,
                    sigma_tree)
            return {"params": params, "opt": opt}, dict(metrics, **opt_metrics)
        return step

    def _make_compressed_step(self):
        """The whole step runs manual-over-DP: grads are computed *per data
        shard* (never full-gradient-synced), PowerSGD factors are the only
        cross-DP traffic, error feedback stays worker-local.  The model axis
        remains automatic (TP sharding untouched)."""
        mesh = self.mesh
        assert mesh is not None and self.rules is not None
        dp = tuple(a for a in self.dp_axes if a in mesh.shape)
        # inside the manual region the batch dim is already per-shard: strip
        # the "batch" rule so act_shard doesn't reference manual axes
        inner_rules = dataclasses.replace(
            self.rules, rules=tuple((k, None if k == "batch" else v)
                                    for k, v in self.rules.rules))

        def local_step(state, batch):
            with use_rules(inner_rules):
                loss, metrics, grads = self._grads(state["params"], batch)
                grads, new_comp, stats = comp.compress_and_sync(
                    grads, state["comp"], cfg=self.compression, axis_names=dp)
                params, opt, opt_metrics = optim.adamw_update(
                    state["params"], grads, state["opt"], self.opt_cfg, None)
            metrics = dict(metrics, **opt_metrics, **stats)
            metrics = jax.tree_util.tree_map(
                lambda m: jax.lax.pmean(m, dp), metrics)
            return {"params": params, "opt": opt, "comp": new_comp}, metrics

        def state_specs(state):
            def one_comp(path, leaf):
                last = str(path[-1].key) if path else ""
                return P(dp) if last == "err" else P()
            return {
                "params": jax.tree_util.tree_map(lambda _: P(), state["params"]),
                "opt": jax.tree_util.tree_map(lambda _: P(), state["opt"]),
                "comp": jax.tree_util.tree_map_with_path(
                    one_comp, state["comp"], is_leaf=lambda x: x is None),
            }

        def step(state, batch, sigma_tree=None):
            sspec = state_specs(state)
            bspec = jax.tree_util.tree_map(lambda _: P(dp), batch)
            return jax.shard_map(
                local_step, mesh=mesh, in_specs=(sspec, bspec),
                out_specs=(sspec, P()), check_vma=False,
                axis_names=frozenset(dp))(state, batch)
        return step

    def jit_train_step(self, suite=None, state=None, *, with_sigma=False):
        step = self.make_train_step()
        if not with_sigma:
            inner = step
            step = lambda state, batch: inner(state, batch, None)
        if self.mesh is None or self.rules is None:
            return jax.jit(step)
        st_sh = self.state_shardings(state)
        b_sh = self.batch_shardings(suite) if suite is not None else None
        in_sh = (st_sh, b_sh, None) if with_sigma else (st_sh, b_sh)
        return jax.jit(step, in_shardings=in_sh,
                       out_shardings=(st_sh, None), donate_argnums=(0,))
