"""repro.train — optimizer, data, checkpointing, fault tolerance, spectral."""
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, cosine_lr
from repro.train.trainer import Trainer
from repro.train.data import DataConfig, batch_at, Prefetcher
from repro.train import checkpoint
from repro.train.ft import StragglerMonitor, FailureInjector, run_with_restarts
from repro.train.spectral import SpectralMonitor, SpectralMonitorConfig
