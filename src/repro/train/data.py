"""Deterministic synthetic LM data pipeline.

Design constraints that matter at pod scale:

* **Pure function of (seed, step)** — ``batch_at`` is stateless, so restart /
  elastic re-shard / straggler skip-ahead all reduce to "evaluate at step k";
  no data-loader state to checkpoint beyond the step counter.
* **Host sharding**: each host materializes only its slice of the global
  batch (``host_slice``); the launcher device_puts with the batch sharding.
* **Prefetch**: a tiny background-thread double buffer (CPU container: 1-deep).

Tokens emulate packed documents: per-sequence doc lengths drawn from the
seeded generator, EOS-delimited, labels = next-token shift, mask excludes
padding after the final EOS.
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import jax.numpy as jnp
import numpy as np

__all__ = ["DataConfig", "batch_at", "host_slice", "Prefetcher"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    eos_id: int = 0
    mean_doc_len: int = 512


def _rng_for(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, 0xB1D1A6]))


def batch_at(cfg: DataConfig, step: int) -> dict:
    """Global batch for ``step`` (numpy; pure function of (cfg, step))."""
    rng = _rng_for(cfg, step)
    b, s = cfg.global_batch, cfg.seq_len
    toks = rng.integers(1, cfg.vocab, size=(b, s + 1), dtype=np.int32)
    # EOS-delimit pseudo documents (geometric lengths)
    doc_end = rng.random((b, s + 1)) < (1.0 / max(cfg.mean_doc_len, 2))
    toks = np.where(doc_end, cfg.eos_id, toks)
    tokens = toks[:, :-1]
    labels = toks[:, 1:].astype(np.int32)
    mask = np.ones((b, s), np.float32)
    return {"tokens": tokens, "labels": labels, "mask": mask}


def host_slice(batch: dict, host_id: int, n_hosts: int) -> dict:
    """Each host materializes only its contiguous slice of the global batch."""
    def sl(x):
        per = x.shape[0] // n_hosts
        return x[host_id * per : (host_id + 1) * per]
    return {k: sl(v) for k, v in batch.items()}


class Prefetcher:
    """Background-thread batch prefetch (depth-1 double buffering)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, depth: int = 2,
                 to_device=None):
        self.cfg = cfg
        self.to_device = to_device or (lambda b: {k: jnp.asarray(v)
                                                  for k, v in b.items()})
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = batch_at(self.cfg, step)
            try:
                self._q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def next(self) -> tuple[int, dict]:
        step, batch = self._q.get()
        return step, self.to_device(batch)

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
