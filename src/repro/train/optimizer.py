"""AdamW optimizer (pure pytree functions) + schedules + clipping.

ZeRO-1 is realized at the *sharding* level: the launcher places ``m``/``v``
with ``parallel.sharding.zero1_shardings`` (scattered over the DP axes); the
update math below is sharding-agnostic — GSPMD inserts the gather/scatter.

``spectral_clip`` consumes the paper's SVD engine: per-leaf gradient spectral
norms (exact banded-SVD sigma_max, refreshed every N steps by the trainer)
bound each 2D update's spectral norm — the distributed-optimization face of
the banded bidiagonalization pipeline (see train/spectral.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_lr",
           "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 200
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    spectral_clip: float = 0.0      # 0 = off; else max sigma ratio per update


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr + 0.5 * (cfg.peak_lr - cfg.min_lr) * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree_util.tree_map(lambda x: (x.astype(jnp.float32) * scale
                                             ).astype(x.dtype), tree), g


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 sigma_tree: Any | None = None):
    """One AdamW step.  Returns (params, state, metrics).

    sigma_tree: optional per-leaf sigma_max(grad) estimates (from the spectral
    monitor); when cfg.spectral_clip > 0, 2D leaves' gradients are rescaled so
    their spectral norm <= spectral_clip * sigma_ref.
    """
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)
    grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
    if cfg.spectral_clip > 0 and sigma_tree is not None:
        def sclip(g, sig):
            if sig is None or g.ndim < 2:
                return g
            # stacked (scan) leaves carry per-layer sigma on leading axes
            sig = jnp.reshape(sig, sig.shape + (1,) * (g.ndim - sig.ndim))
            limit = cfg.spectral_clip * jnp.maximum(sig, 1e-9)
            # current spectral norm approx == refreshed sigma; rescale factor
            return g * jnp.minimum(1.0, limit / jnp.maximum(sig, 1e-9))
        grads = jax.tree_util.tree_map(sclip, grads, sigma_tree,
                                       is_leaf=lambda x: x is None)
    if cfg.clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2 and cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_state = {"step": step, "m": new_m, "v": new_v}
    return new_p, new_state, {"lr": lr, "grad_norm": gnorm}
