"""Spectral monitoring — the paper's kernel as a first-class training feature.

Every ``every`` steps the monitor computes singular-value spectra of selected
weight (or gradient) matrices **on device** through the three-stage pipeline
(stage 2 = the paper's bulge-chasing kernel), batch-dispatched across the mesh
(core/distributed.py).  Consumers:

* health metrics: sigma_max, stable rank ``||W||_F^2 / sigma_max^2``,
  spectral entropy — the muP-style per-layer diagnostics;
* ``sigma_tree`` feeding the optimizer's spectral gradient clipping
  (optimizer.adamw_update).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.distributed import spectrum_of_params

__all__ = ["SpectralMonitorConfig", "SpectralMonitor", "spectral_metrics"]


@dataclasses.dataclass(frozen=True)
class SpectralMonitorConfig:
    every: int = 100            # refresh period (steps)
    size: int = 128             # square-embed size (top-k spectrum window)
    bw: int = 16                # stage-1 target bandwidth
    tw: int | None = None       # stage-2 inner tilewidth (None -> tuned)
    backend: str = "auto"


def spectral_metrics(sigma: jax.Array) -> dict:
    """Summary stats from one descending spectrum."""
    s = sigma.astype(jnp.float32)
    smax = s[0]
    fro2 = jnp.sum(s * s)
    stable_rank = fro2 / jnp.clip(smax * smax, 1e-20)
    p = s * s / jnp.clip(fro2, 1e-20)
    entropy = -jnp.sum(jnp.where(p > 0, p * jnp.log(jnp.clip(p, 1e-20)), 0.0))
    return {"sigma_max": smax, "stable_rank": stable_rank,
            "spectral_entropy": entropy}


class SpectralMonitor:
    def __init__(self, cfg: SpectralMonitorConfig, mesh=None):
        self.cfg = cfg
        self.mesh = mesh
        self.sigma_tree: Any = None
        self.last_refresh: int = -1

    def maybe_refresh(self, step: int, tree) -> bool:
        """Recompute spectra if due.  ``tree``: params or grads pytree."""
        if self.last_refresh >= 0 and step - self.last_refresh < self.cfg.every:
            return False
        c = self.cfg
        self.sigma_tree = spectrum_of_params(
            tree, size=c.size, bw=c.bw, tw=c.tw, mesh=self.mesh,
            backend=c.backend)
        self.last_refresh = step
        return True

    def sigma_max_tree(self):
        """Per-leaf sigma_max (None for non-matrix leaves) for the optimizer."""
        if self.sigma_tree is None:
            return None
        return jax.tree_util.tree_map(
            lambda s: None if s is None else s[..., 0],
            self.sigma_tree, is_leaf=lambda x: x is None)

    def metrics(self) -> dict:
        out = {}
        if self.sigma_tree is None:
            return out
        flat = jax.tree_util.tree_flatten_with_path(
            self.sigma_tree, is_leaf=lambda x: x is None)[0]
        for path, sig in flat:
            if sig is None:
                continue
            name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                            for p in path)
            vec = sig.reshape(-1, sig.shape[-1])[0]      # first of stacked
            for k, v in spectral_metrics(vec).items():
                out[f"spectral/{name}/{k}"] = float(v)
        return out
