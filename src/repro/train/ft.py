"""Fault tolerance: straggler detection, failure injection, restart loop.

At 1000+ nodes the failure model is "some step will die / stall every few
hours".  The pieces here:

* ``StragglerMonitor`` — rolling-median step timing; a step slower than
  ``threshold x median`` is flagged (at pod scale the action is to page the
  scheduler / trigger preemptive checkpoint; here we record + callback).
* ``run_with_restarts`` — the crash-safe training driver: on any step
  exception it restores the latest complete checkpoint and resumes.  Because
  the data pipeline is a pure function of (seed, step) and checkpoints are
  atomic, the post-restart trajectory is bit-identical to an uninterrupted
  run (tested in tests/test_train_substrate.py).
* ``FailureInjector`` — deterministic fault injection for tests/drills.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

from repro.train import checkpoint as ckpt

__all__ = ["StragglerMonitor", "FailureInjector", "run_with_restarts"]


class StragglerMonitor:
    def __init__(self, threshold: float = 3.0, window: int = 32,
                 min_seconds: float = 0.05,
                 on_straggler: Callable[[int, float, float], None] | None = None):
        self.threshold = threshold
        self.window = window
        self.min_seconds = min_seconds
        self.on_straggler = on_straggler
        self.times: list[float] = []
        self.flagged: list[int] = []

    def record(self, step: int, seconds: float) -> bool:
        hist = self.times[-self.window:]
        is_straggler = False
        if len(hist) >= 8:
            med = sorted(hist)[len(hist) // 2]
            if seconds > self.threshold * med and seconds > self.min_seconds:
                is_straggler = True
                self.flagged.append(step)
                if self.on_straggler:
                    self.on_straggler(step, seconds, med)
        self.times.append(seconds)
        return is_straggler


@dataclasses.dataclass
class FailureInjector:
    """Raise at the given steps — once each (simulated node failure)."""
    fail_at: tuple[int, ...] = ()
    _fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise RuntimeError(f"injected failure at step {step}")


def run_with_restarts(*, total_steps: int, ckpt_dir: str, make_state,
                      restore_state, step_fn, save_every: int = 10,
                      keep: int = 3, max_restarts: int = 10,
                      injector: FailureInjector | None = None,
                      monitor: StragglerMonitor | None = None):
    """Crash-safe driver.

    make_state() -> fresh state pytree (step 0);
    restore_state(step, template) -> state at ``step`` (from checkpoint);
    step_fn(step, state) -> (state, metrics) — one training step.

    Returns (state, history list of (step, metrics), n_restarts).
    """
    restarts = 0
    history: list = []
    while True:
        last = ckpt.latest_step(ckpt_dir)
        if last is None:
            state, step = make_state(), 0
        else:
            state, step = restore_state(last, make_state()), last
        try:
            while step < total_steps:
                t0 = time.monotonic()
                if injector is not None:
                    injector.maybe_fail(step)
                state, metrics = step_fn(step, state)
                dt = time.monotonic() - t0
                if monitor is not None:
                    monitor.record(step, dt)
                history.append((step, metrics))
                step += 1
                if step % save_every == 0 or step == total_steps:
                    ckpt.save(ckpt_dir, step, state, keep=keep)
            return state, history, restarts
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            # fall through: restore from the latest complete checkpoint
