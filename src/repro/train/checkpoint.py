"""Fault-tolerant checkpointing: atomic, keep-N, async, elastic re-shard.

Layout: ``<dir>/step_<k>/state.npz`` holding every pytree leaf under its
flattened key path, plus a ``DONE`` marker written *after* a successful fsync
— a partially-written checkpoint is never eligible for restore (atomicity).
Restore re-shards transparently: arrays are loaded host-side and device_put
with the *current* shardings, so a run restarted on a different mesh shape
(elastic scaling) resumes bit-exact.

(Production multi-host would write per-host shard files / tensorstore; the
single-process container gathers to host — interface kept compatible.)
"""

from __future__ import annotations

import os
import queue
import shutil
import threading

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer"]

_SEP = "|"


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(ckpt_dir: str, step: int, state: dict, *, keep: int = 3) -> str:
    """Atomically persist ``state`` (pytree) for ``step``; prune old ones."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state)
    path = os.path.join(tmp, "state.npz")
    with open(path, "wb") as f:
        np.savez(f, **flat)
        f.flush()
        os.fsync(f.fileno())
    with open(os.path.join(tmp, "DONE"), "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int):
    steps = sorted(_complete_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def _complete_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "DONE")):
                out.append(int(name.split("_")[1]))
    return out


def latest_step(ckpt_dir: str) -> int | None:
    steps = _complete_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, template: dict, shardings=None) -> dict:
    """Load ``step`` into the structure of ``template``; device_put with
    ``shardings`` (pytree of NamedSharding) when given — elastic re-shard."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "state.npz")
    with np.load(path) as z:
        loaded = {k: z[k] for k in z.files}
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(flat))
    for (pathk, leaf), shard in zip(flat, shard_leaves):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in pathk)
        arr = loaded[key].astype(leaf.dtype) if hasattr(leaf, "dtype") else loaded[key]
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class AsyncCheckpointer:
    """Latest-wins background writer: the train loop never blocks on I/O."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._err: Exception | None = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def submit(self, step: int, state: dict):
        host_state = jax.tree_util.tree_map(np.asarray, state)  # gather now
        try:
            self._q.put_nowait((step, host_state))
        except queue.Full:                   # drop the stale pending write
            try:
                self._q.get_nowait()
            except queue.Empty:
                pass
            self._q.put_nowait((step, host_state))

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, state = item
            try:
                save(self.ckpt_dir, step, state, keep=self.keep)
            except Exception as e:           # surfaced on close()
                self._err = e

    def close(self):
        self._q.put(None)
        self._thread.join()
        if self._err:
            raise self._err
