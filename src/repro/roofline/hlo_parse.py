"""Loop-aware HLO text walker.

XLA's HloCostAnalysis visits ``while`` bodies once (verified empirically), so
for scan-over-layers models the reported flops/bytes/collectives undercount by
the trip count.  The compiled HLO text carries the exact trip counts
(``backend_config={"known_trip_count":{"n":"32"}}``), so we walk the module:

  * split into computations; build a symbol table (name -> dtype/shape) per
    computation;
  * build the call graph: while(cond, body) edges weighted by trip count,
    fusion/call edges weighted 1;
  * per computation, account dot flops (2 * prod(result) * K_contracted),
    collective traffic (ring model per hw.COLLECTIVE_FACTORS) and an HBM
    traffic proxy (result + operand bytes of top-level non-trivial ops);
  * aggregate along the call graph from ENTRY with multipliers.

This yields loop-scaled HLO_FLOPs, HLO_bytes, and per-collective-kind bytes
per device — the inputs to the three-term roofline.
"""

from __future__ import annotations

import dataclasses
import re

from repro.roofline import hw

__all__ = ["parse_module", "ModuleCosts"]

# computation headers start at column 0 (ops are indented); params may contain
# nested tuple parens, so only anchor on the name and the trailing '{'
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s*\(.*\{\s*$")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*([a-z0-9]+)\[([0-9,]*)\][^\s]*\s+"
    r"([\w\-]+)\(([^\n]*)$")
_TUPLE_LINE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*\(")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_OLD = re.compile(r"replica_groups=\{\{([^}]*)\}")
_OPERAND = re.compile(r"%[\w\.\-]+")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "copy", "copy-start", "copy-done", "after-all", "partition-id",
    "replica-id", "iota",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


@dataclasses.dataclass
class CompCosts:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_counts: dict = dataclasses.field(
        default_factory=lambda: {k: 0 for k in _COLLECTIVES})
    calls: list = dataclasses.field(default_factory=list)  # (callee, mult)


@dataclasses.dataclass
class ModuleCosts:
    dot_flops: float
    hbm_bytes: float
    coll_bytes: dict           # kind -> ring-model bytes per device
    coll_counts: dict
    n_while: int

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


def _shape_bytes(dtype: str, dims: str) -> tuple[float, list[int]]:
    bs = hw.DTYPE_BYTES.get(dtype)
    if bs is None:
        return 0.0, []
    shape = [int(x) for x in dims.split(",") if x] if dims else []
    n = 1
    for d in shape:
        n *= d
    return float(n * bs), shape


def parse_module(text: str) -> ModuleCosts:
    comps: dict[str, CompCosts] = {}
    symtab: dict[str, dict[str, tuple[str, str]]] = {}
    fusion_bodies: set[str] = set()
    entry = None
    cur = None
    n_while = 0

    for raw in text.splitlines():
        hdr = _COMP_HDR.match(raw)
        if hdr:
            cur = hdr.group(1).lstrip("%")
            comps[cur] = CompCosts()
            symtab[cur] = {}
            if raw.startswith("ENTRY"):
                entry = cur
            continue
        if cur is None:
            continue
        if raw.strip() == "}":
            cur = None
            continue
        # while ops are tuple-typed -> handled before the shaped-op regex
        if " while(" in raw:
            n_while += 1
            t = _TRIP.search(raw)
            trip = int(t.group(1)) if t else 1
            cm = re.search(r"body=(%[\w\.\-]+)", raw)
            if cm:
                comps[cur].calls.append((cm.group(1).lstrip("%"), trip))
            continue
        if " conditional(" in raw:
            cm = re.search(r"branch_computations=\{([^}]*)\}", raw)
            if cm:
                for bname in _OPERAND.findall(cm.group(1)):
                    comps[cur].calls.append((bname.lstrip("%"), 1))
            continue
        m = _OP_LINE.match(raw)
        if not m:
            continue
        name, dtype, dims, op, rest = m.groups()
        symtab[cur][name] = (dtype, dims)
        cc = comps[cur]

        if op == "call":
            cm = re.search(r"to_apply=(%[\w\.\-]+)", raw)
            if cm:
                cc.calls.append((cm.group(1).lstrip("%"), 1))
        cm = re.search(r"calls=(%[\w\.\-]+)", raw)
        if cm:
            callee = cm.group(1).lstrip("%")
            cc.calls.append((callee, 1))
            if op == "fusion":
                # fusion internals never touch HBM: keep their dot flops,
                # drop their byte accounting (the fusion op at the call site
                # already accounts result+operand HBM traffic)
                fusion_bodies.add(callee)
        cm = re.search(r"branch_computations=\{([^}]*)\}", raw)
        if cm:
            for b in _OPERAND.findall(cm.group(1)):
                cc.calls.append((b.lstrip("%"), 1))

        rbytes, rshape = _shape_bytes(dtype, dims)

        if op == "dot":
            k = _contracted(rest, symtab[cur], rshape)
            cc.dot_flops += 2.0 * (rbytes / max(hw.DTYPE_BYTES.get(dtype, 1), 1)) * k
        if op in _COLLECTIVES:
            g = _group_size(raw)
            factor = hw.COLLECTIVE_FACTORS[op](g)
            payload = rbytes
            if op == "all-gather":                 # operand = result / g
                payload = rbytes / max(g, 1)
                factor = (g - 1)                   # receives (g-1) shards
            cc.coll_bytes[op] += payload * factor
            cc.coll_counts[op] += 1
        if op not in _SKIP_BYTES_OPS and op != "while":
            opbytes = 0.0
            for oname in _OPERAND.findall(rest.split(", calls=")[0])[:8]:
                if oname in symtab[cur]:
                    od, odims = symtab[cur][oname]
                    b, _ = _shape_bytes(od, odims)
                    opbytes += b
            cc.hbm_bytes += rbytes + opbytes

    if entry is None:
        entry = next(iter(comps))

    memo: dict[str, tuple] = {}

    def roll(name: str) -> tuple:
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None:
            return 0.0, 0.0, {k: 0.0 for k in _COLLECTIVES}, {k: 0 for k in _COLLECTIVES}
        memo[name] = (0.0, 0.0, {k: 0.0 for k in _COLLECTIVES},
                      {k: 0 for k in _COLLECTIVES})      # cycle guard
        f = c.dot_flops
        b = 0.0 if name in fusion_bodies else c.hbm_bytes
        cb = dict(c.coll_bytes)
        cn = dict(c.coll_counts)
        for callee, mult in c.calls:
            cf, cbb, ccb, ccn = roll(callee)
            f += mult * cf
            b += mult * cbb
            for k in cb:
                cb[k] += mult * ccb[k]
                cn[k] += mult * ccn[k]
        memo[name] = (f, b, cb, cn)
        return memo[name]

    f, b, cb, cn = roll(entry)
    return ModuleCosts(dot_flops=f, hbm_bytes=b, coll_bytes=cb,
                       coll_counts=cn, n_while=n_while)


def _contracted(rest: str, table: dict, rshape: list[int]) -> float:
    """Contracted-dim product for a dot: from lhs shape + contracting dims."""
    ops = _OPERAND.findall(rest)
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rest)
    if not ops or not cm or ops[0] not in table:
        return 1.0
    _, dims = table[ops[0]]
    shape = [int(x) for x in dims.split(",") if x] if dims else []
    k = 1.0
    for i in (int(x) for x in cm.group(1).split(",") if x):
        if i < len(shape):
            k *= shape[i]
    return k


def _group_size(line: str) -> int:
    m = _GROUPS.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_OLD.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1
