"""TPU v5e-class hardware constants for the roofline model (task-specified).

Collective traffic factors follow the ring model: an all-reduce moves
2(g-1)/g bytes per participating chip per payload byte, all-gather /
reduce-scatter / all-to-all move (g-1)/g, collective-permute moves 1.
"""

from __future__ import annotations

PEAK_FLOPS_BF16 = 197e12         # per chip
HBM_BW = 819e9                   # bytes/s per chip
ICI_BW = 50e9                    # bytes/s per link (~ICI)

CHIPS_SINGLE_POD = 256
CHIPS_MULTI_POD = 512

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVE_FACTORS = {
    "all-reduce": lambda g: 2 * (g - 1) / max(g, 1),
    "all-gather": lambda g: (g - 1) / max(g, 1),
    "reduce-scatter": lambda g: (g - 1) / max(g, 1),
    "all-to-all": lambda g: (g - 1) / max(g, 1),
    "collective-permute": lambda g: 1.0,
}
