"""Three-term roofline from a compiled dry-run artifact (task §Roofline).

  compute    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory     = HLO_bytes / (chips * HBM_bw)
  collective = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from the loop-aware HLO walker (hlo_parse — XLA's
own cost_analysis counts while bodies once; we also record its raw numbers for
reference).  The walker works on the *per-device* SPMD module, so flops/bytes
are already per-chip: the "/(chips * X)" normalization is folded in by NOT
re-multiplying by chips.  MODEL_FLOPS uses 6·N·D (training) / 2·N·D
(inference) with N = active params.
"""

from __future__ import annotations

import dataclasses

from repro.roofline import hw
from repro.roofline.hlo_parse import parse_module

__all__ = ["RooflineReport", "analyze", "model_flops"]


@dataclasses.dataclass
class RooflineReport:
    arch: str
    suite: str
    mesh: str
    chips: int
    # per-device, loop-scaled
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: dict
    coll_counts: dict
    # raw XLA numbers (loop-undercounted; reference only)
    xla_flops: float
    xla_bytes: float
    # memory_analysis
    bytes_per_device: float
    # derived terms (seconds)
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops_global: float
    useful_ratio: float
    bottleneck: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def model_flops(cfg, suite) -> float:
    """Analytic MODEL_FLOPS for one step: 6·N_active·D train, 2·N_active·D
    inference (D = processed tokens; decode: one token per sequence)."""
    n = cfg.active_params()
    if suite.mode == "train":
        tokens = suite.global_batch * suite.seq_len
        return 6.0 * n * tokens
    if suite.mode == "prefill":
        tokens = suite.global_batch * suite.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * suite.global_batch          # decode: 1 token/seq


def analyze(*, arch: str, suite, mesh_name: str, chips: int, hlo_text: str,
            cost: dict, mem: object | None, cfg) -> RooflineReport:
    parsed = parse_module(hlo_text)
    mf = model_flops(cfg, suite)

    t_comp = parsed.dot_flops / hw.PEAK_FLOPS_BF16
    t_mem = parsed.hbm_bytes / hw.HBM_BW
    t_coll = parsed.total_coll_bytes / hw.ICI_BW

    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)

    bytes_dev = 0.0
    if mem is not None:
        try:
            bytes_dev = float(mem.argument_size_in_bytes +
                              mem.output_size_in_bytes +
                              mem.temp_size_in_bytes +
                              mem.generated_code_size_in_bytes)
        except Exception:
            bytes_dev = 0.0

    useful = mf / max(parsed.dot_flops * chips, 1.0)
    return RooflineReport(
        arch=arch, suite=suite.name, mesh=mesh_name, chips=chips,
        hlo_flops=parsed.dot_flops, hlo_bytes=parsed.hbm_bytes,
        coll_bytes=parsed.coll_bytes, coll_counts=parsed.coll_counts,
        xla_flops=float(cost.get("flops", 0.0)),
        xla_bytes=float(cost.get("bytes accessed", 0.0)),
        bytes_per_device=bytes_dev,
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        model_flops_global=mf, useful_ratio=useful, bottleneck=bottleneck)
