"""Render the §Dry-run / §Roofline tables from reports/dryrun/*.json.

  PYTHONPATH=src python -m repro.roofline.report [--dir reports/dryrun]
"""

from __future__ import annotations

import argparse
import json
import os

from repro.roofline import hw

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dir_: str) -> list[dict]:
    out = []
    for name in sorted(os.listdir(dir_)):
        if name.endswith(".json"):
            with open(os.path.join(dir_, name)) as f:
                out.append(json.load(f))
    return out


def fmt_s(x) -> str:
    return f"{x:.3g}"


def roofline_table(cells: list[dict], mesh: str = "single") -> str:
    rows = [c for c in cells if c.get("mesh") == mesh and c["status"] == "ok"]
    rows.sort(key=lambda c: (c["arch"], ORDER.index(c["suite"])))
    hdr = ("| arch | shape | compute s | memory s | collective s | bottleneck "
           "| MODEL_FLOPS | useful | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for c in rows:
        tmax = max(c["t_compute"], c["t_memory"], c["t_collective"])
        frac = c["t_compute"] / tmax if tmax > 0 else 0.0
        out.append(
            f"| {c['arch']} | {c['suite']} | {fmt_s(c['t_compute'])} "
            f"| {fmt_s(c['t_memory'])} | {fmt_s(c['t_collective'])} "
            f"| {c['bottleneck']} | {c['model_flops_global']:.2e} "
            f"| {c['useful_ratio']:.2f} | {frac:.3f} |\n")
    return "".join(out)


def dryrun_table(cells: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | chips | HLO GFLOP/dev | HBM GB/dev "
           "| coll GB/dev | ar/ag/rs/a2a/cp counts | status |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for c in sorted(cells, key=lambda c: (c["arch"],
                                          ORDER.index(c.get("suite", "train_4k"))
                                          if c.get("suite") in ORDER else 9,
                                          c.get("mesh", ""))):
        if c["status"] != "ok":
            out.append(f"| {c['cell']} | | | | | | | | ERROR |\n")
            continue
        cb = c["coll_bytes"]
        cn = c["coll_counts"]
        counts = "/".join(str(cn.get(k, 0)) for k in
                          ("all-reduce", "all-gather", "reduce-scatter",
                           "all-to-all", "collective-permute"))
        out.append(
            f"| {c['arch']} | {c['suite']} | {c['mesh']} | {c['chips']} "
            f"| {c['hlo_flops'] / 1e9:.1f} | {c['hlo_bytes'] / 1e9:.2f} "
            f"| {sum(cb.values()) / 1e9:.3f} | {counts} | ok |\n")
    return "".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join("reports", "dryrun"))
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--kind", default="roofline",
                    choices=["roofline", "dryrun", "both"])
    args = ap.parse_args()
    cells = load(args.dir)
    n_ok = sum(c["status"] == "ok" for c in cells)
    print(f"cells: {n_ok}/{len(cells)} ok\n")
    if args.kind in ("roofline", "both"):
        print(f"### Roofline ({args.mesh}-pod, {hw.PEAK_FLOPS_BF16/1e12:.0f} "
              f"TFLOP/s, {hw.HBM_BW/1e9:.0f} GB/s HBM, "
              f"{hw.ICI_BW/1e9:.0f} GB/s link)\n")
        print(roofline_table(cells, args.mesh))
    if args.kind in ("dryrun", "both"):
        print("### Dry-run inventory\n")
        print(dryrun_table(cells))


if __name__ == "__main__":
    main()
