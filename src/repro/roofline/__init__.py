"""repro.roofline — loop-aware HLO cost extraction + 3-term roofline."""
from repro.roofline.analysis import RooflineReport, analyze, model_flops
from repro.roofline.hlo_parse import parse_module
from repro.roofline import hw
