"""repro.parallel — mesh-aware sharding rules, collectives, compression."""

from repro.parallel.sharding import (
    AxisRules, set_rules, current_rules, act_shard, logical_spec,
    param_shardings, zero1_shardings, DEFAULT_RULES, MULTIPOD_RULES,
)
