"""Low-rank gradient compression with error feedback (PowerSGD-style).

Cuts data-parallel all-reduce bytes for matrix-shaped gradients from ``m*n``
to ``r*(m+n)`` per matrix: one subspace-iteration round

    P = G Q ; P <- mean_dp(P) ; P <- orth(P) ; Q' = G^T P ; Q' <- mean_dp(Q')
    G_hat = P Q'^T ;  e <- G - G_hat   (error feedback, carried per worker)

Used inside a ``shard_map`` whose manual axes are the DP axes (model axes stay
auto), so the two small factor all-reduces replace the full-gradient one.
Leaves with >= 2 dims are compressed *per trailing matrix* (scan-stacked
layer weights (L, m, n) are L independent matrices, batched through the same
einsums); everything else falls back to a plain psum-mean.  The projection
basis Q warm-starts from the previous step's factors, as PowerSGD prescribes.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["CompressionConfig", "compression_init", "compress_and_sync"]


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    rank: int = 8
    min_dim: int = 64           # compress only if both trailing dims >= this
    seed: int = 0


def _eligible(leaf, min_dim: int) -> bool:
    return leaf.ndim >= 2 and min(leaf.shape[-2:]) >= min_dim


def compression_init(cfg: CompressionConfig, grads_template,
                     n_workers: int = 1) -> dict:
    """Per-leaf state: warm-start Q (..., n, r) — identical on every DP worker
    — and the per-worker error-feedback buffer (leading n_workers axis,
    sharded over the DP axes at rest)."""
    def one(i, g):
        if not _eligible(g, cfg.min_dim):
            return None
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), i)
        q = jax.random.normal(key, g.shape[:-2] + (g.shape[-1], cfg.rank),
                              jnp.float32)
        return {"q": q, "err": jnp.zeros((n_workers,) + g.shape, jnp.float32)}

    leaves, treedef = jax.tree_util.tree_flatten(grads_template)
    states = [one(i, g) for i, g in enumerate(leaves)]
    return jax.tree_util.tree_unflatten(treedef, states)


def _orth(p):
    """Batched Gram-Schmidt via QR (r is tiny)."""
    q, _ = jnp.linalg.qr(p.astype(jnp.float32))
    return q


def compress_and_sync(grads, comp_state, cfg: CompressionConfig,
                      axis_names: tuple[str, ...]):
    """Inside shard_map (manual over ``axis_names``): sync grads across DP.

    Returns (synced grads, new comp_state, stats).
    """
    psum_mean = lambda x: jax.lax.pmean(x, axis_names)
    bytes_full = 0
    bytes_sent = 0

    def one(g, st):
        nonlocal bytes_full, bytes_sent
        gb = g.size * 4
        bytes_full += gb
        if st is None:
            bytes_sent += gb
            return psum_mean(g), st
        gf = g.astype(jnp.float32) + st["err"][0]         # local error feedback
        p = jnp.einsum("...mn,...nr->...mr", gf, st["q"])
        p = psum_mean(p)
        p = _orth(p)
        qn = jnp.einsum("...mn,...mr->...nr", gf, p)
        qn = psum_mean(qn)
        ghat = jnp.einsum("...mr,...nr->...mn", p, qn)
        err = gf - ghat
        bytes_sent += (p.size + qn.size) * 4
        return ghat.astype(g.dtype), {"q": qn, "err": err[None]}

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_s = treedef.flatten_up_to(comp_state)
    out = [one(g, s) for g, s in zip(flat_g, flat_s)]
    new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_s = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    stats = {"compression_ratio": bytes_full / max(bytes_sent, 1)}
    return new_g, new_s, stats
