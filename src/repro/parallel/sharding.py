"""Logical-axis sharding rules (Megatron/GSPMD style).

Model code annotates tensors with *logical* axis names ("batch", "vocab",
"model_in", ...); the launch layer installs an ``AxisRules`` mapping those to
physical mesh axes.  This keeps model definitions mesh-agnostic: the same
code lowers on a single-pod (data, model) mesh, a multi-pod
(pod, data, model) mesh, or a 1-device CPU test with no rules installed
(annotations become no-ops).

Rules used by this framework:

  batch     -> ("pod", "data")  (DP over pod x data; hierarchical all-reduce)
  model_in  -> "model"          (column-parallel weight input dim)
  model_out -> "model"          (row-parallel weight output dim)
  vocab     -> "model"          (vocab-parallel embedding + lm head)
  heads/kv  -> "model"          (attention-head parallelism)
  expert    -> "model"          (expert parallelism for MoE)
  seq       -> "model" only inside sequence-parallel sections (opt-in)
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "AxisRules", "set_rules", "current_rules", "act_shard", "logical_spec",
    "param_shardings", "zero1_shardings", "DEFAULT_RULES", "MULTIPOD_RULES",
]


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """logical name -> mesh axis (or tuple of axes, or None)."""
    rules: tuple[tuple[str, tuple[str, ...] | str | None], ...]
    mesh: Mesh | None = None

    def lookup(self, name: str | None):
        if name is None:
            return None
        for k, v in self.rules:
            if k == name:
                return v
        return None

    def spec(self, logical: tuple[str | None, ...]) -> P:
        phys = []
        used: set[str] = set()
        for name in logical:
            ax = self.lookup(name)
            if isinstance(ax, tuple):
                ax = tuple(a for a in ax if self._has(a) and a not in used)
                ax = ax if ax else None
            elif ax is not None and (not self._has(ax) or ax in used):
                ax = None
            if ax is not None:
                used.update(ax if isinstance(ax, tuple) else (ax,))
            phys.append(ax)
        return P(*phys)

    def _has(self, axis: str) -> bool:
        return self.mesh is None or axis in self.mesh.shape


_SINGLE = (
    ("batch", ("data",)),
    ("seq_kv", ("data",)),        # long-context decode: shard cache seq, not batch
    ("model_in", "model"),
    ("model_out", "model"),
    ("vocab", "model"),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("expert", "model"),
    ("dff", "model"),
    ("seq_sp", "model"),
)
_MULTI = (("batch", ("pod", "data")),
          ("seq_kv", ("pod", "data"))) + _SINGLE[2:]

DEFAULT_RULES = AxisRules(_SINGLE)
MULTIPOD_RULES = AxisRules(_MULTI)

_tls = threading.local()


def set_rules(rules: AxisRules | None):
    _tls.rules = rules


def current_rules() -> AxisRules | None:
    return getattr(_tls, "rules", None)


@contextlib.contextmanager
def use_rules(rules: AxisRules | None):
    prev = current_rules()
    set_rules(rules)
    try:
        yield
    finally:
        set_rules(prev)


def logical_spec(logical: tuple[str | None, ...]) -> P:
    r = current_rules()
    return r.spec(logical) if r is not None else P()


def act_shard(x: jax.Array, logical: tuple[str | None, ...]) -> jax.Array:
    """Annotate an activation with its logical sharding (no-op w/o rules)."""
    r = current_rules()
    if r is None or r.mesh is None:
        return x
    spec = r.spec(logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))


def param_shardings(logical_tree, rules: AxisRules):
    """Pytree of logical tuples -> pytree of NamedShardings."""
    assert rules.mesh is not None

    def one(logical):
        return NamedSharding(rules.mesh, rules.spec(logical))

    return jax.tree_util.tree_map(one, logical_tree,
                                  is_leaf=lambda x: isinstance(x, tuple))


def zero1_shardings(logical_tree, shape_tree, rules: AxisRules,
                    dp_axes: tuple[str, ...] = ("data",)):
    """ZeRO-1: optimizer-state shardings = param sharding + DP sharding on the
    first still-unsharded, divisible dimension (states live scattered over the
    data-parallel group; XLA inserts the gather in the update)."""
    assert rules.mesh is not None
    dp_axes = tuple(a for a in dp_axes if a in rules.mesh.shape)
    dp = 1
    for a in dp_axes:
        dp *= rules.mesh.shape[a]

    def one(logical, shape):
        spec = list(rules.spec(logical))
        spec += [None] * (len(shape) - len(spec))
        if dp > 1:
            for i, (ax, dim) in enumerate(zip(spec, shape)):
                if ax is None and dim % dp == 0 and dim >= dp:
                    spec[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
                    break
        return NamedSharding(rules.mesh, P(*spec))

    return jax.tree_util.tree_map(
        one, logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
