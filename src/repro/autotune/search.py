"""Model-pruned on-device search over the ``(tw, fuse, batch)`` grid.

The paper's tuning methodology, end to end: the analytic model
(``autotune/model.py``) ranks the FULL candidate grid by predicted cost;
only the top-K candidates — plus the static analytic default, always — are
actually timed (``autotune/measure.py``); the winner is whatever measured
fastest *per matrix*.  Because the default is always in the measured set,
the returned config beats or ties it by construction, and because every
measured candidate carries its prediction, the result reports
predicted-vs-measured error and the model's rank of the measured best —
the model is falsifiable (a bad model shows up as the winner ranked deep
in the list, or as large errors in the validation table).

``SearchResult.to_entry()`` is the persistent-cache payload
(``autotune/cache.py``); ``python -m repro.autotune`` drives this module.
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from repro.autotune import measure as measure_mod
from repro.autotune import model as model_mod
from repro.core import tuning

__all__ = ["Candidate", "SearchResult", "candidate_grid", "search",
           "FusedCrossoverResult", "search_fused_crossover",
           "Stage3CrossoverResult", "search_stage3_crossover"]


@dataclasses.dataclass
class Candidate:
    """One grid point; times are seconds PER MATRIX (batched call / batch)."""
    tw: int
    fuse: int
    batch: int
    predicted_s: float
    measured_s: float | None = None

    @property
    def error_pct(self) -> float | None:
        """Signed prediction error vs measurement, in % of measured."""
        if self.measured_s is None or not math.isfinite(self.predicted_s):
            return None
        return 100.0 * (self.predicted_s - self.measured_s) / self.measured_s

    def label(self) -> str:
        return f"tw={self.tw} fuse={self.fuse} B={self.batch}"


def candidate_grid(n: int, bw: int, *, dtype=jnp.float32,
                   fuses: tuple[int, ...] = (1, 2, 4, 8),
                   batches: tuple[int, ...] = (1,),
                   tws: tuple[int, ...] | None = None
                   ) -> list[tuple[int, int, int]]:
    """The full (tw, fuse, batch) grid for one shape.

    ``tws`` defaults to the powers of two below ``bw`` plus the two anchors
    that matter: the cache-line default and the single-stage width
    ``bw - 1`` (paper Fig. 4 sweeps the same axis).
    """
    if tws is None:
        cand = {1, bw - 1, tuning.default_tilewidth(bw, dtype)}
        p = 2
        while p < bw:
            cand.add(p)
            p *= 2
        tws = tuple(sorted(t for t in cand if 1 <= t <= max(bw - 1, 1)))
    return [(t, k, b) for t in tws for k in fuses if k >= 1
            for b in batches if b >= 1]


@dataclasses.dataclass
class SearchResult:
    n: int
    bw: int
    dtype: str
    backend: str
    compute_uv: bool
    device_kind: str
    top_k: int
    candidates: list[Candidate]          # full grid, predicted order
    measured: list[Candidate]            # timed subset (top-K + default)
    best: Candidate                      # measured argmin (per matrix)
    default: Candidate                   # the static analytic default
    batch_searched: bool = False         # batch axis had > 1 grid value

    def model_rank_of_best(self) -> int:
        """1-based rank of the measured-best candidate in the model's
        predicted ordering (1 = the model nailed it)."""
        for i, c in enumerate(self.candidates):
            if (c.tw, c.fuse, c.batch) == (self.best.tw, self.best.fuse,
                                           self.best.batch):
                return i + 1
        return len(self.candidates) + 1     # default-only winner, off-grid

    def table(self) -> str:
        """The predicted-vs-measured validation table (CLI output)."""
        hdr = (f"shape n={self.n} bw={self.bw} dtype={self.dtype} "
               f"backend={self.backend} uv={self.compute_uv} "
               f"device={self.device_kind}")
        lines = [hdr,
                 f"{'rank':>4} {'tw':>4} {'fuse':>4} {'B':>3} "
                 f"{'predicted_us':>13} {'measured_us':>12} {'err%':>7}"]
        by_key = {(c.tw, c.fuse, c.batch): c for c in self.measured}
        shown = 0
        for i, c in enumerate(self.candidates):
            m = by_key.pop((c.tw, c.fuse, c.batch), None)
            if m is None and shown >= self.top_k:
                continue
            shown += 1
            mu = f"{m.measured_s * 1e6:12.1f}" if m else f"{'-':>12}"
            err = (f"{m.error_pct:6.1f}%" if m and m.error_pct is not None
                   else f"{'-':>7}")
            pred = (f"{c.predicted_s * 1e6:13.1f}"
                    if math.isfinite(c.predicted_s) else f"{'vmem-cliff':>13}")
            mark = " <- best" if (c.tw, c.fuse, c.batch) == (
                self.best.tw, self.best.fuse, self.best.batch) else ""
            dflt = " (default)" if (c.tw, c.fuse, c.batch) == (
                self.default.tw, self.default.fuse, self.default.batch) else ""
            lines.append(f"{i + 1:>4} {c.tw:>4} {c.fuse:>4} {c.batch:>3} "
                         f"{pred} {mu} {err}{mark}{dflt}")
        lines.append(f"model rank of measured best: "
                     f"{self.model_rank_of_best()} of {len(self.candidates)} "
                     f"(top_k={self.top_k})")
        return "\n".join(lines)

    def to_entry(self) -> dict:
        """The persistent-cache payload for the winning config.

        ``max_batch`` is included ONLY when the batch axis was actually
        searched (> 1 grid value): a batches=(1,) run never compared batch
        sizes, and persisting its trivial ``batch=1`` would make
        ``resolve(autotune=True)`` serialize serve-side bucketing that the
        Eq.-1 analytic default would have batched.  Consumers treat a
        missing ``max_batch`` as "not tuned — use the analytic default".
        """
        entry = {
            "tw": int(self.best.tw),
            "fuse": int(self.best.fuse),
            "measured_us": round(self.best.measured_s * 1e6, 3),
            "predicted_us": (round(self.best.predicted_s * 1e6, 3)
                             if math.isfinite(self.best.predicted_s)
                             else None),
            "default_measured_us": (round(self.default.measured_s * 1e6, 3)
                                    if self.default.measured_s is not None
                                    else None),
            "model_rank_of_best": self.model_rank_of_best(),
            "schema": 1,
        }
        if self.batch_searched:
            entry["max_batch"] = int(self.best.batch)
        return entry


def _static_default(n: int, bw: int, dtype) -> tuple[int, int, int]:
    """The knobs ``PipelineConfig.resolve`` picks with no cache: cache-line
    tilewidth, the paper's unfused schedule, the Eq.-1 bucket batch."""
    tw = max(1, min(tuning.default_tilewidth(bw, dtype), max(bw - 1, 1)))
    return tw, 1, tuning.default_bucket_batch(n, bw)


def search(n: int, bw: int, *, dtype=jnp.float32, backend: str = "ref",
           compute_uv: bool = False, top_k: int = 4,
           fuses: tuple[int, ...] = (1, 2, 4, 8),
           batches: tuple[int, ...] = (1,),
           profile: model_mod.DeviceProfile | None = None,
           warmup: int = 1, iters: int = 2, seed: int = 0,
           measure_fn=None) -> SearchResult:
    """Tune one shape: rank the grid by the model, time top-K + default.

    ``measure_fn(tw, fuse, batch) -> seconds (whole batched call)`` is
    injectable for tests; the real path is ``measure.time_stage2`` on the
    full ``bw -> 1`` reduction (so small tilewidths pay for the extra
    stages they force — the honest objective).
    """
    if not batches or not fuses:
        raise ValueError(f"batches={batches!r} and fuses={fuses!r} must be "
                         f"non-empty")
    prof = profile if profile is not None else model_mod.profile_for()
    dname = jnp.dtype(dtype).name
    if measure_fn is None:
        def measure_fn(tw, fuse, batch):
            return measure_mod.time_stage2(
                n, bw, tw=tw, fuse=fuse, batch=batch, backend=backend,
                dtype=dtype, tape=compute_uv, full=True, warmup=warmup,
                iters=iters, seed=seed)

    grid = candidate_grid(n, bw, dtype=dtype, fuses=fuses, batches=batches)
    d_tw, d_fuse, d_batch = _static_default(n, bw, dtype)
    d_batch = d_batch if d_batch in batches else min(batches)
    if (d_tw, d_fuse, d_batch) not in grid:
        grid.append((d_tw, d_fuse, d_batch))

    cands = [Candidate(t, k, b, predicted_s=model_mod.pipeline_cost(
        n, bw, t, fuse=k, batch=b, dtype=dtype, profile=prof,
        tape=compute_uv) / b) for (t, k, b) in grid]
    cands.sort(key=lambda c: (c.predicted_s, c.tw, c.fuse, c.batch))

    to_time = [c for c in cands if math.isfinite(c.predicted_s)][:top_k]
    default = next(c for c in cands if (c.tw, c.fuse, c.batch) ==
                   (d_tw, d_fuse, d_batch))
    if default not in to_time:
        to_time.append(default)
    for c in to_time:
        c.measured_s = measure_fn(c.tw, c.fuse, c.batch) / c.batch
    best = min(to_time, key=lambda c: c.measured_s)
    return SearchResult(n=n, bw=bw, dtype=dname, backend=backend,
                        compute_uv=compute_uv,
                        device_kind=model_mod.device_kind(), top_k=top_k,
                        candidates=cands, measured=to_time, best=best,
                        default=default,
                        batch_searched=len(set(batches)) > 1)


# ---------------------------------------------------------------------------
# Fused-tier crossover search (DESIGN.md §13)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FusedCrossoverResult:
    """Measured fused-vs-staged crossover for one (device, dtype, uv, bw).

    ``points`` holds ``(n, fused_s, staged_s)`` per-matrix seconds for every
    n actually measured; ``fused_n_max`` is the largest measured n where the
    fused tier won (0 = never — the staged pipeline wins everywhere).
    ``predicted_n_max`` is the analytic model's figure
    (``model.predicted_crossover``) for the same setting, kept alongside so
    a wildly wrong model is visible in the cache entry itself.
    """
    bw: int
    dtype: str
    compute_uv: bool
    device_kind: str
    points: list[tuple[int, float, float]]
    fused_n_max: int
    predicted_n_max: int

    def table(self) -> str:
        lines = [f"fused crossover bw={self.bw} dtype={self.dtype} "
                 f"uv={self.compute_uv} device={self.device_kind}",
                 f"{'n':>5} {'fused_us':>10} {'staged_us':>10} {'winner':>7}"]
        for n, fused_s, staged_s in self.points:
            win = "fused" if fused_s < staged_s else "staged"
            lines.append(f"{n:>5} {fused_s * 1e6:10.1f} "
                         f"{staged_s * 1e6:10.1f} {win:>7}")
        lines.append(f"measured fused_n_max={self.fused_n_max} "
                     f"(model predicted {self.predicted_n_max})")
        return "\n".join(lines)

    def to_entry(self) -> dict:
        """The persistent-cache payload (``cache.store_crossover``)."""
        return {
            "fused_n_max": int(self.fused_n_max),
            "predicted_n_max": int(self.predicted_n_max),
            "points": [{"n": int(n),
                        "fused_us": round(f * 1e6, 3),
                        "staged_us": round(s * 1e6, 3)}
                       for n, f, s in self.points],
            "schema": 1,
        }


def search_fused_crossover(bw: int, *, dtype=jnp.float32,
                           compute_uv: bool = False,
                           ns: tuple[int, ...] = (16, 32, 64, 128, 256,
                                                  384, 512),
                           batch: int = 8, warmup: int = 1, iters: int = 2,
                           seed: int = 0,
                           profile: model_mod.DeviceProfile | None = None,
                           measure_fn=None) -> FusedCrossoverResult:
    """Measure the fused-vs-staged per-matrix crossover on this device.

    Walks ``ns`` ascending, timing the SAME dense random stack through the
    whole pipeline twice — once with ``backend="fused_small"``, once with
    the staged platform default — via ``core.svd.svd_batched``.  Stops at
    the first n the fused VMEM budget rejects (larger n only get worse).
    ``measure_fn(n, fused) -> seconds (whole batched call)`` is injectable
    for tests.  The result's ``.to_entry()`` feeds
    ``cache.store_crossover``; the serve engines consume it through
    ``cache.lookup_crossover``.
    """
    from repro.core import svd as svd_mod   # deferred: keep import light

    prof = profile if profile is not None else model_mod.profile_for()
    dname = jnp.dtype(dtype).name

    if measure_fn is None:
        import numpy as np

        def measure_fn(n, fused):
            bw_eff = max(1, min(bw, max(n - 1, 1)))
            cfg = tuning.PipelineConfig.resolve(
                bw=bw_eff, dtype=dtype, n=n, compute_uv=compute_uv,
                backend="fused_small" if fused else "auto")
            rng = np.random.default_rng(seed)
            a = jnp.asarray(rng.standard_normal((batch, n, n)).astype(dname))

            def call():
                return svd_mod.svd_batched(a, cfg, compute_uv=compute_uv)

            return measure_mod.measure_seconds(call, warmup=warmup,
                                               iters=iters)

    points: list[tuple[int, float, float]] = []
    fused_n_max = 0
    for n in sorted(set(int(x) for x in ns)):
        if n < 1:
            continue
        try:
            tuning.check_fused_vmem_budget(n, dtype, compute_uv=compute_uv)
        except ValueError:
            break                      # ascending ns: larger n only worse
        fused_s = measure_fn(n, True) / batch
        staged_s = measure_fn(n, False) / batch
        points.append((n, float(fused_s), float(staged_s)))
        if fused_s < staged_s:
            fused_n_max = n
    predicted = model_mod.predicted_crossover(bw, dtype=dtype, batch=batch,
                                              profile=prof,
                                              compute_uv=compute_uv)
    return FusedCrossoverResult(bw=bw, dtype=dname, compute_uv=compute_uv,
                                device_kind=model_mod.device_kind(),
                                points=points, fused_n_max=fused_n_max,
                                predicted_n_max=predicted)


# ---------------------------------------------------------------------------
# Stage-3 solver crossover search (DESIGN.md §14)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Stage3CrossoverResult:
    """Measured bisect-vs-D&C stage-3 crossover for one (device, dtype, uv).

    ``points`` holds ``(n, bisect_s, dc_s, agree)`` per-matrix seconds plus
    the max |sigma_dc - sigma_bisect| / sigma_max agreement for every n
    measured — the numerical check rides along with the timing so a cache
    entry can never enshrine a fast-but-wrong solver.  ``dc_n_min`` is the
    smallest measured n from which D&C stayed faster through the top of the
    sweep; when D&C never won it is ``1 + max(ns)`` — a beyond-any-measured-n
    threshold (``PipelineConfig`` "auto" then keeps bisection), NOT a cache
    miss.  ``predicted_n_min`` is ``model.predicted_stage3_crossover`` for
    the same setting, kept alongside so a wildly wrong model is visible in
    the cache entry itself.
    """
    dtype: str
    compute_uv: bool
    device_kind: str
    points: list[tuple[int, float, float, float]]
    dc_n_min: int
    predicted_n_min: int

    def table(self) -> str:
        lines = [f"stage3 crossover dtype={self.dtype} uv={self.compute_uv} "
                 f"device={self.device_kind}",
                 f"{'n':>6} {'bisect_us':>11} {'dc_us':>11} {'agree':>9} "
                 f"{'winner':>7}"]
        for n, bi_s, dc_s, agree in self.points:
            win = "dc" if dc_s < bi_s else "bisect"
            lines.append(f"{n:>6} {bi_s * 1e6:11.1f} {dc_s * 1e6:11.1f} "
                         f"{agree:9.1e} {win:>7}")
        lines.append(f"measured dc_n_min={self.dc_n_min} "
                     f"(model predicted {self.predicted_n_min})")
        return "\n".join(lines)

    def to_entry(self) -> dict:
        """The persistent-cache payload (``cache.store_stage3``)."""
        return {
            "dc_n_min": int(self.dc_n_min),
            "predicted_n_min": int(self.predicted_n_min),
            "points": [{"n": int(n),
                        "bisect_us": round(b * 1e6, 3),
                        "dc_us": round(d * 1e6, 3),
                        "agree": float(a)}
                       for n, b, d, a in self.points],
            "schema": 1,
        }


def search_stage3_crossover(*, dtype=jnp.float64, compute_uv: bool = False,
                            ns: tuple[int, ...] = (256, 512, 1024, 2048,
                                                   4096),
                            batch: int = 4, warmup: int = 1, iters: int = 2,
                            seed: int = 0, leaf_n: int | None = None,
                            profile: model_mod.DeviceProfile | None = None,
                            measure_fn=None) -> Stage3CrossoverResult:
    """Measure the stage-3 bisect-vs-D&C per-matrix crossover on this device.

    Walks ``ns`` ascending, timing the SAME random bidiagonal stack
    ``(batch, n)`` through ``core.bidiag_svd`` (bisection) and
    ``core.bidiag_dc`` (divide and conquer) — the values path, or the full
    ``compute_uv`` solve when asked — and recording the sigma agreement of
    the two.  ``measure_fn(n, dc) -> (seconds, agree)`` (whole batched
    call; agree only needs to be meaningful on one of the two variants) is
    injectable for tests.  ``.to_entry()`` feeds ``cache.store_stage3``;
    ``PipelineConfig.resolve(autotune=True)`` and the serve engines consume
    it through ``cache.lookup_stage3``.
    """
    import jax

    from repro.core import bidiag_dc as dc_mod     # deferred: keep import
    from repro.core import bidiag_svd as bs_mod    # light for --help paths

    prof = profile if profile is not None else model_mod.profile_for()
    dname = jnp.dtype(dtype).name
    leaf = leaf_n if leaf_n is not None else dc_mod.DEFAULT_DC_LEAF_N

    if measure_fn is None:
        import numpy as np

        def measure_fn(n, dc):
            rng = np.random.default_rng(seed)
            # repo convention: e is (n,) with e[0] unused (e[i] = B[i-1, i])
            d = jnp.asarray(rng.standard_normal((batch, n)).astype(dname))
            e = jnp.asarray(rng.standard_normal((batch, n)).astype(dname))
            if dc:
                # The dc entry points batch (B, n) stacks natively (lax.map
                # per matrix) — wrapping them in vmap would lower the
                # deflation-skip conds to both-branch selects and measure a
                # crippled solver.
                if compute_uv:
                    fn = lambda dd, ee: dc_mod.bidiag_dc_svd(  # noqa: E731
                        dd, ee, leaf_n=leaf)[1]
                else:
                    fn = lambda dd, ee: dc_mod.bidiag_dc_singular_values(  # noqa: E731
                        dd, ee, leaf_n=leaf)
            else:
                if compute_uv:
                    fn = jax.vmap(lambda dd, ee: bs_mod.bidiag_svd(dd, ee)[1])
                else:
                    fn = jax.vmap(bs_mod.bidiag_singular_values)
            sig = jax.block_until_ready(fn(d, e))
            ref = jax.block_until_ready(
                jax.vmap(bs_mod.bidiag_singular_values)(d, e))
            scale = float(jnp.max(jnp.abs(ref))) or 1.0
            agree = float(jnp.max(jnp.abs(sig - ref))) / scale
            secs = measure_mod.measure_seconds(lambda: fn(d, e),
                                               warmup=warmup, iters=iters)
            return secs, agree

    points: list[tuple[int, float, float, float]] = []
    probe = sorted(set(int(x) for x in ns if x >= 1))
    for n in probe:
        bi_s, _ = measure_fn(n, False)
        dc_s, agree = measure_fn(n, True)
        points.append((n, float(bi_s) / batch, float(dc_s) / batch,
                       float(agree)))
    dc_n_min = 1 + (max(probe) if probe else 0)
    for n, bi_s, dc_s, _ in reversed(points):
        if dc_s < bi_s:
            dc_n_min = n
        else:
            break
    predicted = model_mod.predicted_stage3_crossover(
        dtype=dtype, batch=batch, profile=prof, leaf_n=leaf)
    return Stage3CrossoverResult(dtype=dname, compute_uv=compute_uv,
                                 device_kind=model_mod.device_kind(),
                                 points=points, dc_n_min=dc_n_min,
                                 predicted_n_min=predicted)
