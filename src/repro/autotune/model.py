"""Analytic stage-2 cost model (the paper's §III-C/D performance model,
made falsifiable).

The paper's methodological core is a *hardware-aware performance model* that
ranks configurations before any kernel runs; measurement then only has to
confirm (or refute) the top of the ranking.  This module is that model for
our wavefront chase: given a candidate ``(tw, fuse, batch)`` it composes

* **bytes moved** — from the packed-band layout: one fused super-step
  streams the contiguous block ``(H, W_K)``, ``H = b_in + 2*tw + 1``,
  ``W_K = fuse*b_in + tw + 1``, through fast memory once per K retired
  cycles, i.e. each chase cycle costs ``2*H*W_K/K`` words of slow-memory
  round trip (gather + scatter; the amortized form of DESIGN.md §9 — the
  sub-leading ceil waste of partially-dead final super-steps is ignored so
  the model stays strictly monotone in the knobs it ranks);
* **launch overhead** — one fused dispatch per super-cycle ``T`` regardless
  of batch (the batch axis folds into the same grid), amortized by ``fuse``
  through the super-cycle count ``T(K) ~ sep(K)*nsweeps``;
* **wavefront occupancy** — paper Eq. 1: achieved bandwidth scales with the
  fraction of execution units the ``batch * G`` concurrent windows cover,
  saturating at 1;
* **feasibility** — a candidate whose ``tuning.vmem_working_set_bytes``
  exceeds the profile's fast-memory budget is infeasible (``inf`` cost):
  the VMEM cliff.

roofline-composed with a per-device :class:`DeviceProfile` table that
generalizes the hard-coded v5e constants of ``roofline/hw.py``.  The model
is deliberately cheap (pure ints/floats, no jax arrays) so the search can
rank the full grid and measure only the top-K (``autotune/search.py``),
printing predicted-vs-measured error — the model is falsifiable, not
decorative.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import tuning
from repro.roofline import hw

__all__ = [
    "DeviceProfile", "PROFILES", "device_kind", "profile_for",
    "total_chase_cycles", "CostBreakdown", "stage_cost", "pipeline_cost",
    "fused_cost", "predicted_crossover", "FUSED_FAST_BW_RATIO",
    "stage3_cost", "predicted_stage3_crossover", "DC_DEFLATION_FACTOR",
]


# ---------------------------------------------------------------------------
# Per-device profile table (generalizes roofline/hw.py beyond v5e)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """What the cost model needs to know about one device kind.

    ``mem_bw`` is the achievable slow-memory stream bandwidth feeding the
    chase (HBM on TPU/GPU; DRAM on the CPU ref path), ``launch_overhead_s``
    the per-dispatch fixed cost being amortized by ``fuse`` (measured by
    ``benchmarks/kernels_bench.py::_launch_overhead``), ``fast_mem_bytes``
    the per-core budget the working set must fit (VMEM on TPU; the model
    reuses it as the residency cliff on every platform), and
    ``execution_units`` the number of cores the wavefront must cover for
    full occupancy (paper Eq. 1; TensorCores on TPU).
    """
    device_kind: str
    mem_bw: float                   # bytes/s
    launch_overhead_s: float        # per fused dispatch
    fast_mem_bytes: int             # residency budget per core
    execution_units: int


PROFILES: dict[str, DeviceProfile] = {
    # v5e constants are the roofline/hw.py values (single source of truth).
    "tpu v5e": DeviceProfile("tpu v5e", mem_bw=hw.HBM_BW,
                             launch_overhead_s=3e-6,
                             fast_mem_bytes=tuning.VMEM_BUDGET_BYTES,
                             execution_units=2),
    "tpu v4": DeviceProfile("tpu v4", mem_bw=1.2e12, launch_overhead_s=3e-6,
                            fast_mem_bytes=tuning.VMEM_BUDGET_BYTES,
                            execution_units=2),
    "tpu v5p": DeviceProfile("tpu v5p", mem_bw=2.765e12,
                             launch_overhead_s=3e-6,
                             fast_mem_bytes=tuning.VMEM_BUDGET_BYTES,
                             execution_units=2),
    # Generic GPU entry: the paper's native target; kept so cached entries
    # from a CUDA host carry a sane profile even though our kernels are
    # TPU/ref.  fast_mem ~ L2-resident working set.
    "gpu": DeviceProfile("gpu", mem_bw=1.0e12, launch_overhead_s=5e-6,
                         fast_mem_bytes=32 * 2 ** 20, execution_units=64),
    # CPU ref path: the "launch" is one fori_loop super-cycle of the jnp
    # wavefront (~hundreds of us — see BENCH_stage2.json chase_launch rows),
    # which dominates; mem_bw is a DRAM-stream figure.
    "cpu": DeviceProfile("cpu", mem_bw=2.0e10, launch_overhead_s=250e-6,
                         fast_mem_bytes=32 * 2 ** 20, execution_units=1),
}


def device_kind(device=None) -> str:
    """Cache-key identity of the default (or given) jax device."""
    dev = device if device is not None else jax.devices()[0]
    kind = getattr(dev, "device_kind", "") or dev.platform
    return str(kind).lower()


def profile_for(kind: str | None = None) -> DeviceProfile:
    """Best-effort profile for a device kind string (normalized prefix
    match: "TPU v5 lite" and "tpu v5e" both hit the v5e row); unknown kinds
    fall back by platform family, ultimately to the cpu row."""
    k = (kind if kind is not None else device_kind()).lower()
    norm = k.replace("tpu v5 lite", "tpu v5e").replace("tpu v5litepod",
                                                       "tpu v5e")
    for name, prof in PROFILES.items():
        if norm.startswith(name) or name.startswith(norm):
            return prof
    if "tpu" in norm:
        return PROFILES["tpu v5e"]
    if any(tag in norm for tag in ("gpu", "cuda", "rocm", "nvidia")):
        return PROFILES["gpu"]
    return PROFILES["cpu"]


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------

def total_chase_cycles(n: int, b_in: int, tw: int) -> int:
    """Fuse-invariant count of chase cycles one stage executes.

    Sweep R runs local cycles 0..j_max(R), ``j_max = (n-1-R-b_out)//b_in``
    (canonical home of the count; ``benchmarks/fusion.py`` reports it as the
    honest throughput axis).
    """
    b_out = b_in - tw
    return sum((n - 1 - r - b_out) // b_in + 1
               for r in range(max(n - 1 - b_out, 0)))


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    """One stage's predicted cost, decomposed for the validation table."""
    seconds: float                  # total for the batched call (inf: cliff)
    mem_seconds: float
    launch_seconds: float
    bytes_moved: float              # slow-memory round-trip bytes, all slots
    cycles: int                     # chase cycles (fuse-invariant)
    supercycles: int                # fused dispatches
    wavefront: int                  # concurrent windows per matrix (G)
    occupancy: float                # Eq.-1 utilization in [1/eu, 1]
    vmem_bytes: int                 # per-slot working set vs the budget
    feasible: bool

    @property
    def per_matrix_seconds(self) -> float:
        return self.seconds          # callers divide by batch explicitly


def stage_cost(n: int, b_in: int, tw: int, *, fuse: int = 1, batch: int = 1,
               dtype=jnp.float32, profile: DeviceProfile | None = None,
               tape: bool = False) -> CostBreakdown:
    """Predicted wall seconds of ONE batched stage reduction ``b_in ->
    b_in - tw`` at super-step depth ``fuse`` (the model of the module
    docstring).  Infeasible working sets return ``seconds=inf``."""
    from repro.core import bulge_chasing as bc

    prof = profile if profile is not None else profile_for()
    assert 1 <= tw <= b_in - 1 or b_in == 1, (b_in, tw)
    assert fuse >= 1 and batch >= 1, (fuse, batch)
    s = jnp.dtype(dtype).itemsize
    h = b_in + 2 * tw + 1
    wk = fuse * b_in + tw + 1
    cycles = total_chase_cycles(n, b_in, tw)
    _, supercycles, g = bc.stage_schedule(n, b_in, tw, fuse)
    vmem = tuning.vmem_working_set_bytes(b_in, tw, dtype, fuse=fuse,
                                         tape=tape)
    feasible = vmem <= prof.fast_mem_bytes
    # Amortized slow-memory traffic: each cycle costs 1/K of a contiguous
    # (H, W_K) block round trip (gather + scatter), plus its tape slice.
    words_per_cycle = 2.0 * h * wk / fuse
    if tape:
        words_per_cycle += 2.0 * (tw + 2)      # (v, tau) pair per cycle
    bytes_moved = batch * cycles * words_per_cycle * s
    occupancy = min(1.0, batch * max(g, 1) / prof.execution_units)
    occupancy = max(occupancy, 1.0 / prof.execution_units)
    t_mem = bytes_moved / (prof.mem_bw * occupancy)
    t_launch = supercycles * prof.launch_overhead_s
    total = (t_mem + t_launch) if feasible else math.inf
    return CostBreakdown(seconds=total, mem_seconds=t_mem,
                         launch_seconds=t_launch, bytes_moved=bytes_moved,
                         cycles=cycles, supercycles=supercycles, wavefront=g,
                         occupancy=occupancy, vmem_bytes=vmem,
                         feasible=feasible)


def pipeline_cost(n: int, bw: int, tw: int, *, fuse: int = 1, batch: int = 1,
                  dtype=jnp.float32, profile: DeviceProfile | None = None,
                  tape: bool = False) -> float:
    """Predicted seconds of the whole stage-2 reduction ``bw -> 1`` — the
    sum over ``tuning.stage_plan(bw, tw)`` stage costs (what
    ``measure.time_stage2(full=True)`` times, hence what the search ranks).
    ``inf`` as soon as any stage's working set misses the budget."""
    total = 0.0
    for b_in, twi in tuning.stage_plan(bw, tw):
        c = stage_cost(n, b_in, twi, fuse=fuse, batch=batch, dtype=dtype,
                       profile=profile, tape=tape)
        if not c.feasible:
            return math.inf
        total += c.seconds
    return total


# ---------------------------------------------------------------------------
# Fused small-n tier (DESIGN.md §13)
# ---------------------------------------------------------------------------

# Fast-memory (VMEM / L1-resident) streaming advantage over slow memory the
# fused kernel's in-place reflector applies enjoy.  Deliberately coarse —
# the term it scales is only compared against the staged path's
# launch-dominated cost, where the crossover is decided by the dispatch
# count, not by a few percent of compute time.
FUSED_FAST_BW_RATIO = 8.0


def fused_cost(n: int, bw: int, *, batch: int = 1, dtype=jnp.float32,
               profile: DeviceProfile | None = None,
               compute_uv: bool = False) -> CostBreakdown:
    """Predicted wall seconds of ONE fused_small dispatch over a (B, n, n)
    stack — the whole pipeline (stage 1 + every chase cycle + bisection)
    as a single launch with the matrix fast-memory resident.

    * ONE ``launch_overhead_s`` total — the entire point of the tier; the
      staged path pays one per super-cycle (``stage_cost``).
    * slow-memory traffic: the stack streamed in and the results out, once.
    * in-kernel work: each reflector cycle touches the (n, n) working set a
      few times (extract, matvec, rank-1 update, fix) served from fast
      memory at ``FUSED_FAST_BW_RATIO * mem_bw``; ``compute_uv`` triples it
      (A plus the two accumulators); the values path adds the vectorized
      bisection sweep.
    * infeasible when ``tuning.fused_working_set_bytes`` misses the
      profile's fast-memory budget (no fallback tiling in this tier).
    """
    prof = profile if profile is not None else profile_for()
    assert batch >= 1, batch
    s = jnp.dtype(dtype).itemsize
    bw_eff = max(1, min(bw, max(n - 1, 1)))
    vmem = tuning.fused_working_set_bytes(n, dtype, compute_uv=compute_uv)
    feasible = vmem <= prof.fast_mem_bytes
    cyc2 = (total_chase_cycles(n, bw_eff, bw_eff - 1)
            if bw_eff >= 2 and n >= 3 else 0)
    cycles = max(n - 1, 0) + cyc2
    io_words = n * n + n + (2 * n * n + 2 * n if compute_uv else 0)
    bytes_moved = float(batch) * io_words * s
    work_words = cycles * 6.0 * n * n * (3.0 if compute_uv else 1.0)
    if not compute_uv:
        max_iter = 60 if jnp.dtype(dtype).itemsize == 8 else 40
        work_words += max_iter * (2.0 * n) * (2.0 * n)   # Sturm bisection
    par = max(1.0, min(float(batch), float(prof.execution_units)))
    occupancy = max(min(1.0, batch / prof.execution_units),
                    1.0 / prof.execution_units)
    t_mem = bytes_moved / prof.mem_bw
    t_compute = (batch * work_words * s
                 / (FUSED_FAST_BW_RATIO * prof.mem_bw) / par)
    t_launch = prof.launch_overhead_s
    total = (t_mem + t_compute + t_launch) if feasible else math.inf
    return CostBreakdown(seconds=total, mem_seconds=t_mem + t_compute,
                         launch_seconds=t_launch, bytes_moved=bytes_moved,
                         cycles=cycles, supercycles=1, wavefront=1,
                         occupancy=occupancy, vmem_bytes=vmem,
                         feasible=feasible)


def predicted_crossover(bw: int, *, dtype=jnp.float32, batch: int = 8,
                        profile: DeviceProfile | None = None,
                        compute_uv: bool = False,
                        ns: tuple[int, ...] = (8, 16, 24, 32, 48, 64, 96,
                                               128, 192, 256, 384, 512, 768,
                                               1024)) -> int:
    """Model-predicted fused-vs-staged crossover: the largest n in ``ns``
    where the fused tier's per-matrix cost beats the staged stage-2 cost.

    Conservative by construction — the staged side is charged for stage 2
    only (its dispatch-dominated core) while the fused side carries the
    whole pipeline, so a real measurement can only move the crossover UP.
    Seeds ``search.search_fused_crossover``; 0 means "never fused".
    """
    prof = profile if profile is not None else profile_for()
    best = 0
    for n in sorted(ns):
        bw_eff = max(1, min(bw, max(n - 1, 1)))
        fc = fused_cost(n, bw_eff, batch=batch, dtype=dtype, profile=prof,
                        compute_uv=compute_uv)
        if not fc.feasible:
            break
        tw = max(1, min(tuning.default_tilewidth(bw_eff, dtype),
                        max(bw_eff - 1, 1)))
        staged = pipeline_cost(n, bw_eff, tw, fuse=1, batch=batch,
                               dtype=dtype, profile=prof, tape=compute_uv)
        if fc.seconds < staged:
            best = n
    return best


# ---------------------------------------------------------------------------
# Stage-3 solver tier (DESIGN.md §14)
# ---------------------------------------------------------------------------

# Fraction of a merge's poles/roots that stay ACTIVE after deflation in a
# typical D&C merge.  Deliberately coarse (real spectra deflate anywhere
# from ~0 to ~99%); since the solver skips all-deflated blocks on BOTH the
# root and the pole axis, the surviving quadratic work scales with the
# SQUARE of this fraction, and the measured search
# (``search.search_stage3_crossover``) overrides the prediction anyway.
DC_DEFLATION_FACTOR = 0.35

# Full-width secular passes per merge: the midpoint/anchor pass plus the
# handful of adaptive exact-polish trips the early exit typically allows
# (the windowed middle-way iterations in between are O(active * K), not
# O(m^2), and ride in the level bookkeeping below).
_DC_FULL_PASSES = 6.0

# Streaming passes one D&C merge level makes over the padded problem
# (sort, two stable partitions, Givens scan, window/heavy-pole gathers,
# z-hat recompute, vector assembly) — the O(big) bookkeeping between
# secular solves.
_DC_LEVEL_PASSES = 64.0

# Fixed word-equivalent cost per merge level, independent of problem size:
# the latency-bound parts (sequential Givens scan steps, top_k, argsorts,
# gather setup) do not stream at memory bandwidth, and at small n they, not
# the quadratic secular work, are what keeps D&C behind bisection.  5e7
# words ~ 2.5 ms on the cpu profile — calibrated so the predicted crossover
# tracks the measured one (~2048 on the dev container, fp64).
_DC_LEVEL_FLOOR_WORDS = 5.0e7


def stage3_cost(n: int, *, solver: str, dtype=jnp.float64, batch: int = 1,
                profile: DeviceProfile | None = None, leaf_n: int = 32,
                newton_iters: int = 30) -> CostBreakdown:
    """Predicted wall seconds of ONE batched stage-3 bidiagonal solve.

    Both solvers work on the Golub–Kahan tridiagonal of size ``m = 2n`` and
    are single dispatches (one jit call); they differ only in arithmetic
    volume, modeled as fast-memory streaming words:

    * ``solver="bisect"``: the lockstep Sturm sweep — ``max_iter`` fixed
      iterations, each scanning all m poles for all m roots
      (``max_iter * m^2`` words; max_iter = 60 fp64 / 40 fp32, matching
      ``core.bidiag_svd.default_bisect_iters``).
    * ``solver="dc"``: leaves solved by the same bisection at size
      ``lm ~ 2*leaf_n`` (``max_iter * lm * big`` words across all leaves),
      then ``levels = ceil(log2(big/lm))`` secular merges.  Merge sizes
      double up to ``big``, so the full-width secular passes telescope to
      ``~2 * _DC_FULL_PASSES * big^2`` scaled by the SQUARED deflation
      survival fraction (all-deflated blocks are skipped on both the root
      and the pole axis; the windowed middle-way iterations are O(m*K) and
      fold into the bookkeeping), plus ``_DC_LEVEL_PASSES * big`` streaming
      and a ``_DC_LEVEL_FLOOR_WORDS`` latency floor per level.  ``big``
      carries the power-of-two padding (up to 2x of m).

    The decisive structural difference at large n is the constant:
    ``_DC_FULL_PASSES * DC_DEFLATION_FACTOR^2`` of quadratic work against
    bisection's ``max_iter`` — below the crossover the padding and
    per-level passes make D&C the loser.  Seeds
    ``predicted_stage3_crossover``.
    """
    prof = profile if profile is not None else profile_for()
    assert solver in ("bisect", "dc"), solver
    assert batch >= 1, batch
    s = jnp.dtype(dtype).itemsize
    max_iter = 60 if s == 8 else 40
    m = max(2 * n, 1)
    if solver == "bisect":
        words = float(max_iter) * m * m
        vmem = 4 * m * s
    else:
        lm = max(1, min(2 * leaf_n, m))
        levels = 0
        big = lm
        while big < m:
            big *= 2
            levels += 1
        words = float(max_iter) * lm * big                  # leaf bisection
        alive = DC_DEFLATION_FACTOR * DC_DEFLATION_FACTOR
        words += 2.0 * _DC_FULL_PASSES * alive * big * big
        # windowed iterations: K = 128 index-nearest + 32 heavy poles/root
        words += 2.0 * newton_iters * 160.0 * DC_DEFLATION_FACTOR * big
        words += levels * (_DC_LEVEL_PASSES * big + _DC_LEVEL_FLOOR_WORDS)
        vmem = 3 * big * big * s        # eigvec two-sided products per level
    occupancy = max(min(1.0, batch / prof.execution_units),
                    1.0 / prof.execution_units)
    bytes_moved = batch * words * s
    t_mem = bytes_moved / (FUSED_FAST_BW_RATIO * prof.mem_bw) / max(
        1.0, min(float(batch), float(prof.execution_units)))
    t_launch = prof.launch_overhead_s
    return CostBreakdown(seconds=t_mem + t_launch, mem_seconds=t_mem,
                         launch_seconds=t_launch, bytes_moved=bytes_moved,
                         cycles=max_iter if solver == "bisect" else newton_iters,
                         supercycles=1, wavefront=1, occupancy=occupancy,
                         vmem_bytes=vmem, feasible=True)


def predicted_stage3_crossover(*, dtype=jnp.float64, batch: int = 1,
                               profile: DeviceProfile | None = None,
                               leaf_n: int = 32,
                               ns: tuple[int, ...] = (128, 256, 512, 1024,
                                                      2048, 4096, 8192)
                               ) -> int:
    """Model-predicted bisect-vs-D&C crossover: the smallest n in ``ns``
    from which D&C stays cheaper for every larger probed n (both curves are
    monotone in the model, so "first win that never flips back" is exact).
    Returns ``1 + max(ns)`` when D&C never wins — a beyond-any-probed-n
    threshold, NOT a miss, so ``PipelineConfig`` "auto" keeps bisection.
    Seeds ``search.search_stage3_crossover``.
    """
    prof = profile if profile is not None else profile_for()
    probe = sorted(set(int(x) for x in ns if x >= 1))
    best = 1 + (max(probe) if probe else 0)
    for n in reversed(probe):
        dc = stage3_cost(n, solver="dc", dtype=dtype, batch=batch,
                         profile=prof, leaf_n=leaf_n)
        bi = stage3_cost(n, solver="bisect", dtype=dtype, batch=batch,
                         profile=prof, leaf_n=leaf_n)
        if dc.seconds < bi.seconds:
            best = n
        else:
            break
    return best
