"""Persistent tuned-config cache: one JSON file, atomic writes.

Entries are keyed by everything that shifts the optimum —
``(device_kind, n, bw, dtype, compute_uv, backend)`` — and hold the tuned
knobs ``(tw, fuse, max_batch)`` plus the provenance needed to audit them
(measured/predicted times, tuner version, jax version, timestamp).
``PipelineConfig.resolve(autotune=True)`` looks entries up and falls back
to the analytic defaults on a miss; ``python -m repro.autotune`` writes
them.

The cache location is ``$REPRO_AUTOTUNE_CACHE`` when set, else
``~/.cache/repro-autotune/cache.json`` (``$XDG_CACHE_HOME`` honored).
Writes are atomic (tempfile + ``os.replace`` in the destination directory)
and read-modify-write merges, so concurrent tuners lose at worst one
entry, never the file.  A corrupt or truncated cache file reads as empty —
tuning degrades to the analytic defaults instead of crashing the caller.
"""

from __future__ import annotations

import json
import os
import tempfile
import time

__all__ = ["ENV_VAR", "SCHEMA_VERSION", "cache_path", "make_key",
           "load", "lookup", "store", "crossover_key", "lookup_crossover",
           "store_crossover", "stage3_key", "lookup_stage3", "store_stage3"]

ENV_VAR = "REPRO_AUTOTUNE_CACHE"
SCHEMA_VERSION = 1


def cache_path(path: str | None = None) -> str:
    """Resolve the cache file path: explicit arg > env var > XDG default."""
    if path:
        return path
    env = os.environ.get(ENV_VAR, "")
    if env:
        return env
    base = os.environ.get("XDG_CACHE_HOME",
                          os.path.join(os.path.expanduser("~"), ".cache"))
    return os.path.join(base, "repro-autotune", "cache.json")


def make_key(*, device_kind: str, n: int, bw: int, dtype: str,
             compute_uv: bool, backend: str) -> str:
    """Flat string key (JSON objects can't key on tuples)."""
    return (f"device={device_kind}|n={int(n)}|bw={int(bw)}|dtype={dtype}"
            f"|uv={int(bool(compute_uv))}|backend={backend}")


def load(path: str | None = None) -> dict:
    """The whole cache as a dict (``{"version": .., "entries": {key: ..}}``);
    missing, corrupt, or schema-mismatched files read as empty."""
    p = cache_path(path)
    try:
        with open(p) as f:
            doc = json.load(f)
        if (not isinstance(doc, dict)
                or not isinstance(doc.get("entries"), dict)
                or doc.get("version") != SCHEMA_VERSION):
            return {"version": SCHEMA_VERSION, "entries": {}}
        return doc
    except (OSError, ValueError):
        return {"version": SCHEMA_VERSION, "entries": {}}


def lookup(*, device_kind: str, n: int, bw: int, dtype: str,
           compute_uv: bool, backend: str, path: str | None = None
           ) -> dict | None:
    """The tuned entry for a pipeline key, or None (fall back to defaults).

    Entries missing either kernel knob (``tw``, ``fuse``) are treated as
    corrupt (None) so a half-written record can never half-configure a
    pipeline.  ``max_batch`` is OPTIONAL — the search only persists it
    when the batch axis was actually explored; when present it must be a
    valid int >= 1 or the whole entry is rejected.
    """
    entry = load(path)["entries"].get(make_key(
        device_kind=device_kind, n=n, bw=bw, dtype=dtype,
        compute_uv=compute_uv, backend=backend))
    if not isinstance(entry, dict):
        return None
    if not all(isinstance(entry.get(k), int) and entry[k] >= 1
               for k in ("tw", "fuse")):
        return None
    if "max_batch" in entry and not (isinstance(entry["max_batch"], int)
                                     and entry["max_batch"] >= 1):
        return None
    return entry


def store(entry: dict, *, device_kind: str, n: int, bw: int, dtype: str,
          compute_uv: bool, backend: str, path: str | None = None) -> str:
    """Merge one tuned entry into the cache, atomically; returns the path.

    Read-modify-write: existing entries under other keys survive.  The
    temp file lives in the destination directory so ``os.replace`` stays
    on one filesystem (atomic rename).
    """
    p = cache_path(path)
    os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
    doc = load(p)
    entry = dict(entry)
    entry.setdefault("tuned_at_unix", int(time.time()))
    doc["entries"][make_key(device_kind=device_kind, n=n, bw=bw, dtype=dtype,
                            compute_uv=compute_uv, backend=backend)] = entry
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(p) or ".",
                               prefix=".cache-", suffix=".json.tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, p)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return p


# ---------------------------------------------------------------------------
# Fused-tier crossover entries (DESIGN.md §13)
# ---------------------------------------------------------------------------
#
# The fused-vs-staged crossover is a property of (device, dtype, uv[, bw]),
# not of one (n, bw) shape, so it gets its own key family in the SAME
# entries dict ("crossover|..." never collides with make_key's "device=..."
# namespace, and the per-shape ``lookup`` validation — which demands tw/fuse
# — never sees these entries).

def crossover_key(*, device_kind: str, dtype: str, compute_uv: bool,
                  bw: int | None = None) -> str:
    key = (f"crossover|device={device_kind}|dtype={dtype}"
           f"|uv={int(bool(compute_uv))}")
    if bw is not None:
        key += f"|bw={int(bw)}"
    return key


def lookup_crossover(*, device_kind: str, dtype: str, compute_uv: bool,
                     bw: int | None = None, path: str | None = None
                     ) -> int | None:
    """The tuned fused-tier crossover n, or None (use the static default).

    Looks for the bw-specific entry first, then the device/dtype-wide one —
    a tuner run with ``--fused-crossover`` stores under the exact bw it
    measured AND the wide key, so engines serving other bandwidths still
    get a measured figure.
    """
    entries = load(path)["entries"]
    keys = []
    if bw is not None:
        keys.append(crossover_key(device_kind=device_kind, dtype=dtype,
                                  compute_uv=compute_uv, bw=bw))
    keys.append(crossover_key(device_kind=device_kind, dtype=dtype,
                              compute_uv=compute_uv))
    for key in keys:
        entry = entries.get(key)
        if (isinstance(entry, dict)
                and isinstance(entry.get("fused_n_max"), int)
                and entry["fused_n_max"] >= 0):
            return entry["fused_n_max"]
    return None


def store_crossover(entry: dict, *, device_kind: str, dtype: str,
                    compute_uv: bool, bw: int | None = None,
                    path: str | None = None) -> str:
    """Merge one crossover entry (``{"fused_n_max": int, ...}``) into the
    cache, atomically, under the (optionally bw-specific) crossover key."""
    assert isinstance(entry.get("fused_n_max"), int), entry
    p = cache_path(path)
    os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
    doc = load(p)
    entry = dict(entry)
    entry.setdefault("tuned_at_unix", int(time.time()))
    doc["entries"][crossover_key(device_kind=device_kind, dtype=dtype,
                                 compute_uv=compute_uv, bw=bw)] = entry
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(p) or ".",
                               prefix=".cache-", suffix=".json.tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, p)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return p


# ---------------------------------------------------------------------------
# Stage-3 solver crossover entries (DESIGN.md §14)
# ---------------------------------------------------------------------------
#
# The bisect-vs-dc crossover for the bidiagonal solve is a property of
# (device, dtype, uv) — stage 3 never sees the band, so there is no bw axis.
# Same single-file entries dict, its own "stage3|..." prefix (collides with
# neither make_key's "device=..." nor the "crossover|..." family).

def stage3_key(*, device_kind: str, dtype: str, compute_uv: bool) -> str:
    return (f"stage3|device={device_kind}|dtype={dtype}"
            f"|uv={int(bool(compute_uv))}")


def lookup_stage3(*, device_kind: str, dtype: str, compute_uv: bool,
                  path: str | None = None) -> int | None:
    """The measured D&C crossover ``dc_n_min`` (smallest n where the D&C
    stage-3 solve beat bisection on this device), or None (use the static
    ``core.bidiag_dc.DEFAULT_DC_N_MIN``).  A tuner that saw D&C lose at
    every measured n stores a beyond-any-n sentinel, so "never" round-trips
    as a valid (huge) threshold rather than a miss."""
    entry = load(path)["entries"].get(stage3_key(
        device_kind=device_kind, dtype=dtype, compute_uv=compute_uv))
    if (isinstance(entry, dict) and isinstance(entry.get("dc_n_min"), int)
            and entry["dc_n_min"] >= 1):
        return entry["dc_n_min"]
    return None


def store_stage3(entry: dict, *, device_kind: str, dtype: str,
                 compute_uv: bool, path: str | None = None) -> str:
    """Merge one stage-3 crossover entry (``{"dc_n_min": int, ...}``) into
    the cache, atomically, under the (device, dtype, uv) stage3 key."""
    assert isinstance(entry.get("dc_n_min"), int), entry
    p = cache_path(path)
    os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
    doc = load(p)
    entry = dict(entry)
    entry.setdefault("tuned_at_unix", int(time.time()))
    doc["entries"][stage3_key(device_kind=device_kind, dtype=dtype,
                              compute_uv=compute_uv)] = entry
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(p) or ".",
                               prefix=".cache-", suffix=".json.tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, p)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return p
