"""Hardware-aware autotuning subsystem (DESIGN.md §11).

Four modules compose the paper's §III-D tuning methodology into something
persistent and falsifiable:

* :mod:`repro.autotune.model`   — analytic stage-2 cost model + per-device
  profile table (bytes moved, launch amortization, Eq.-1 occupancy,
  VMEM-cliff feasibility);
* :mod:`repro.autotune.measure` — the one blocking/jit-warmup timing
  harness (the ``benchmarks/`` suites reuse it);
* :mod:`repro.autotune.search`  — model-pruned search: rank the full
  ``(tw, fuse, batch)`` grid by predicted cost, time only the top-K (plus
  the static default), report predicted-vs-measured error;
* :mod:`repro.autotune.cache`   — persistent JSON cache keyed by
  ``(device_kind, n, bw, dtype, compute_uv, backend)``; atomic writes,
  ``$REPRO_AUTOTUNE_CACHE``-overridable path.

Entry points: ``python -m repro.autotune --shapes n=512:bw=32 --backend
ref`` tunes and persists; ``tuning.PipelineConfig.resolve(autotune=True)``
consumes the cache (analytic defaults on a miss).
"""

from repro.autotune import cache, measure, model, search
from repro.autotune.cache import (cache_path, lookup, lookup_crossover,
                                  store, store_crossover)
from repro.autotune.measure import measure_seconds, time_stage2
from repro.autotune.model import (DeviceProfile, PROFILES, device_kind,
                                  fused_cost, pipeline_cost,
                                  predicted_crossover, profile_for,
                                  stage_cost, total_chase_cycles)
from repro.autotune.search import (Candidate, FusedCrossoverResult,
                                   SearchResult, search as run_search,
                                   search_fused_crossover)

__all__ = [
    "cache", "measure", "model", "search",
    "cache_path", "lookup", "store", "lookup_crossover", "store_crossover",
    "measure_seconds", "time_stage2",
    "DeviceProfile", "PROFILES", "device_kind", "pipeline_cost",
    "profile_for", "stage_cost", "total_chase_cycles",
    "fused_cost", "predicted_crossover",
    "Candidate", "SearchResult", "run_search",
    "FusedCrossoverResult", "search_fused_crossover",
]
