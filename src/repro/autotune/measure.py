"""Blocking timing harness — the ONE wall-clock path shared by the
autotuner and the ``benchmarks/`` suites.

``measure_seconds`` is the primitive: jit-warmup then median-of-k
``block_until_ready`` wall times (``benchmarks/common.timeit`` delegates
here, so a fix to the methodology lands everywhere at once).
``time_stage2`` is the autotuner's workload: one batched stage-2 reduction
at a candidate ``(tw, fuse, batch)`` — either the full ``bw -> 1``
tile-width plan (``full=True``, what the search ranks: it charges small
``tw`` for the extra stages it forces) or a single stage at the entry
bandwidth (``full=False``, what ``benchmarks/hyperparams.py`` sweeps).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

__all__ = ["measure_seconds", "banded_input", "time_stage2"]


def measure_seconds(fn, *args, warmup: int = 1, iters: int = 3,
                    label: str = "measure") -> float:
    """Median wall seconds of ``fn(*args)`` (jax-blocking).

    ``warmup`` calls are discarded (jit compilation + device spin-up);
    ``iters`` timed calls then give a median — robust to the one-off
    scheduling hiccups a mean would smear in.

    With an ambient :class:`repro.obs.Tracer` active, every call emits a
    span tree under ``label``: a ``warmup`` child per discarded call (the
    FIRST warmup is where jit compilation lands, so its duration is the
    compile-dominated one — the tracer attributes it ``compile="warmup0"``)
    and a ``rep`` child per timed call, so a tuning run's trace shows
    exactly what the reported median was computed from.
    """
    with obs.span(label, warmup=warmup, iters=iters) as sp:
        for i in range(max(warmup, 0)):
            with obs.span("warmup", i=i) as w:
                if i == 0:
                    w.set(compile="warmup0")
                jax.block_until_ready(fn(*args))
        ts = []
        for i in range(max(iters, 1)):
            with obs.span("rep", i=i):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(*args))
                ts.append(time.perf_counter() - t0)
        med = sorted(ts)[len(ts) // 2]
        sp.set(median_s=med)
    return med


def banded_input(n: int, bw: int, *, batch: int = 1, dtype=jnp.float32,
                 seed: int = 0) -> jax.Array:
    """Dense upper-banded test matrices ``(batch, n, n)`` (batch=1 squeezed
    to ``(n, n)``), same construction as ``benchmarks/common.banded``."""
    rng = np.random.default_rng(seed)
    shape = (batch, n, n) if batch > 1 else (n, n)
    a = np.triu(rng.standard_normal(shape))
    a = np.triu(a) - np.triu(a, bw + 1)
    return jnp.asarray(a.astype(jnp.dtype(dtype).name))


def time_stage2(n: int, bw: int, *, tw: int, fuse: int = 1, batch: int = 1,
                backend: str = "ref", dtype=jnp.float32, tape: bool = False,
                full: bool = True, warmup: int = 1, iters: int = 3,
                seed: int = 0) -> float:
    """Median seconds of ONE batched stage-2 call at the candidate config.

    Returns the time of the whole batched call — divide by ``batch`` for
    the per-matrix figure the search compares.  The packed input is built
    once outside the timed region (the serve layer amortizes packing the
    same way).
    """
    from repro.core import band as bandmod
    from repro.core import bulge_chasing as bc

    a = banded_input(n, bw, batch=batch, dtype=dtype, seed=seed)
    tw0 = min(tw, max(bw - 1, 1))
    packed = bandmod.pack(a, bw, tw0)

    if full:
        def call():
            out = bc.bidiagonalize_packed(packed, n=n, bw=bw, tw=tw,
                                          backend=backend, tape=tape,
                                          fuse=fuse)
            return out[:2] if tape else out
    else:
        def call():
            return bc.reduce_stage_packed(packed, n=n, b_in=bw, tw=tw0,
                                          backend=backend, tape=tape,
                                          fuse=fuse)

    return measure_seconds(
        call, warmup=warmup, iters=iters,
        label=f"time_stage2/n{n}/bw{bw}/tw{tw}/fuse{fuse}/b{batch}")
