"""Autotuner CLI: tune a shape list, print the model-validation table,
persist the winners to the tuned-config cache.

  PYTHONPATH=src python -m repro.autotune --shapes n=512:bw=32 --backend ref
  PYTHONPATH=src python -m repro.autotune \\
      --shapes n=256:bw=16,n=512:bw=32 --backend ref --top-k 3 --iters 2

Each ``--shapes`` item is ``n=<int>:bw=<int>``.  The winning
``(tw, fuse, max_batch)`` per shape is merged into the cache at
``--cache`` / ``$REPRO_AUTOTUNE_CACHE`` / the XDG default, keyed by
``(device_kind, n, bw, dtype, compute_uv, backend)`` — exactly the key
``PipelineConfig.resolve(autotune=True)`` then looks up.  ``--no-store``
runs the search and table without touching the cache.
"""

from __future__ import annotations

import argparse
import sys

import jax.numpy as jnp

from repro.autotune import cache as cache_mod
from repro.autotune import model as model_mod
from repro.autotune import search as search_mod
from repro.kernels import ops


def parse_shapes(spec: str) -> list[tuple[int, int]]:
    """"n=512:bw=32,n=256:bw=16" -> [(512, 32), (256, 16)]."""
    shapes = []
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        fields = dict(kv.split("=", 1) for kv in item.split(":"))
        try:
            shapes.append((int(fields["n"]), int(fields["bw"])))
        except (KeyError, ValueError) as e:
            raise SystemExit(f"bad --shapes item {item!r} "
                             f"(want n=<int>:bw=<int>): {e}")
    if not shapes:
        raise SystemExit("--shapes parsed to nothing")
    return shapes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.autotune",
        description="Tune (tw, fuse, batch) per shape; persist the winners.")
    ap.add_argument("--shapes", required=True,
                    help="comma list of n=<int>:bw=<int> items")
    ap.add_argument("--backend", default="auto",
                    help="kernel registry key (auto/ref/pallas)")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--compute-uv", action="store_true",
                    help="tune the tape-mode (full SVD) pipeline")
    ap.add_argument("--top-k", type=int, default=3,
                    help="measured candidates per shape (model-ranked)")
    ap.add_argument("--batches", default="1",
                    help="comma list of batch sizes to include in the grid")
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--iters", type=int, default=1,
                    help="timed repetitions per candidate (median)")
    ap.add_argument("--cache", default="",
                    help=f"cache path (default: ${cache_mod.ENV_VAR} or "
                         f"{cache_mod.cache_path()})")
    ap.add_argument("--no-store", action="store_true",
                    help="print the table only; do not write the cache")
    ap.add_argument("--fused-crossover", action="store_true",
                    help="instead of the (tw, fuse, batch) grid, measure the "
                         "fused-vs-staged crossover per --shapes bw "
                         "(DESIGN.md §13) and persist fused_n_max")
    ap.add_argument("--stage3-crossover", action="store_true",
                    help="instead of the (tw, fuse, batch) grid, measure the "
                         "stage-3 bisect-vs-dc crossover up to the largest "
                         "--shapes n (DESIGN.md §14) and persist dc_n_min")
    ap.add_argument("--trace-jsonl", default="", metavar="PATH",
                    help="export measurement spans (warmup vs timed reps, "
                         "compile attribution) to PATH as JSONL "
                         "(repro.obs; DESIGN.md §16)")
    args = ap.parse_args(argv)

    if args.trace_jsonl:
        from repro import obs
        obs.install(obs.Tracer("autotune", jsonl=args.trace_jsonl))
        print(f"# tracing measurement spans to {args.trace_jsonl}",
              flush=True)

    dtype = jnp.dtype(args.dtype)
    if dtype.itemsize == 8:
        # Without x64, float64 measurement arrays silently degrade to
        # fp32 — timings for the wrong precision, and the crossover
        # searches' sigma-agreement column reads ~1e-5 instead of ~1e-16.
        import jax
        jax.config.update("jax_enable_x64", True)
    backend, _ = ops.resolve_backend(args.backend)
    try:
        batches = tuple(sorted({int(b) for b in args.batches.split(",")
                                if b.strip()}))
    except ValueError as e:
        raise SystemExit(f"bad --batches {args.batches!r} "
                         f"(want a comma list of ints): {e}")
    if not batches or min(batches) < 1:
        raise SystemExit(f"bad --batches {args.batches!r}: need at least "
                         f"one batch size >= 1")
    path = args.cache or None
    kind = model_mod.device_kind()
    prof = model_mod.profile_for(kind)
    print(f"# autotune device={kind} profile={prof.device_kind} "
          f"backend={backend} dtype={dtype.name}", flush=True)

    if args.fused_crossover:
        # One sweep per distinct bw; the shape's n caps the sweep.  The
        # result is stored under BOTH the bw-specific and the device-wide
        # crossover key (lookup_crossover prefers the specific one).
        caps: dict[int, int] = {}
        for n, bw in parse_shapes(args.shapes):
            caps[bw] = max(caps.get(bw, 0), n)
        for bw, n_cap in sorted(caps.items()):
            ns = tuple(x for x in (16, 32, 64, 128, 256, 384, 512)
                       if x <= n_cap) or (n_cap,)
            res = search_mod.search_fused_crossover(
                bw, dtype=dtype, compute_uv=args.compute_uv, ns=ns,
                batch=max(batches), profile=prof, warmup=args.warmup,
                iters=args.iters)
            print(res.table(), flush=True)
            if args.no_store:
                continue
            for key_bw in (bw, None):
                dest = cache_mod.store_crossover(
                    res.to_entry(), device_kind=kind, dtype=dtype.name,
                    compute_uv=args.compute_uv, bw=key_bw, path=path)
            print(f"# cached fused_n_max={res.fused_n_max} -> {dest}",
                  flush=True)
        return 0

    if args.stage3_crossover:
        # One sweep, capped by the largest --shapes n; bw is irrelevant
        # (stage 3 never sees the band).  The key is (device, dtype, uv).
        n_cap = max(n for n, _ in parse_shapes(args.shapes))
        ns = tuple(x for x in (256, 512, 1024, 2048, 4096, 8192)
                   if x <= n_cap) or (n_cap,)
        res = search_mod.search_stage3_crossover(
            dtype=dtype, compute_uv=args.compute_uv, ns=ns,
            batch=max(batches), profile=prof, warmup=args.warmup,
            iters=args.iters)
        print(res.table(), flush=True)
        if not args.no_store:
            dest = cache_mod.store_stage3(
                res.to_entry(), device_kind=kind, dtype=dtype.name,
                compute_uv=args.compute_uv, path=path)
            print(f"# cached dc_n_min={res.dc_n_min} -> {dest}", flush=True)
        return 0

    for n, bw in parse_shapes(args.shapes):
        res = search_mod.search(n, bw, dtype=dtype, backend=backend,
                                compute_uv=args.compute_uv,
                                top_k=args.top_k, batches=batches,
                                profile=prof, warmup=args.warmup,
                                iters=args.iters)
        print(res.table(), flush=True)
        if args.no_store:
            continue
        dest = cache_mod.store(res.to_entry(), device_kind=kind, n=n, bw=bw,
                               dtype=dtype.name, compute_uv=args.compute_uv,
                               backend=backend, path=path)
        print(f"# cached {res.best.label()} -> {dest}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
