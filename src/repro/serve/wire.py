"""Wire protocol for the multi-host serve fabric (DESIGN.md §17).

Stdlib-only framing shared by the front-end router (``serve/router.py``)
and the worker hosts (``serve/worker.py``): no new dependencies, no
pickling (a router must never ``eval`` bytes a worker sent it), and no
jax at module scope — like ``serve/faults.py``, the protocol layer must
be importable where the accelerator stack is broken.

One frame is::

    u32 header_len | u32 payload_len | header (JSON, utf-8) | payload

``header`` is a JSON object whose ``"type"`` field names the message
(table in DESIGN.md §17); numpy arrays ride in ``payload`` as raw
C-contiguous bytes, described by the header's ``"_arrays"`` manifest
(``[{name, dtype, shape}]``, offsets implied by order).  fp64 sigma
therefore crosses the wire bit-exactly — the cross-host σ-agreement gate
depends on that.

Sockets are used full-duplex: exactly one reader per connection end, any
number of writers serialized by the caller's send lock (``send_msg``
itself writes the frame with a single ``sendall``).
"""

from __future__ import annotations

import json
import socket
import struct

import numpy as np

__all__ = ["send_msg", "recv_msg", "WireClosed", "MAX_FRAME_BYTES"]

_HDR = struct.Struct(">II")

# A frame larger than this is a protocol error, not a big matrix: refuse it
# rather than let a corrupt length prefix trigger a multi-GB allocation.
MAX_FRAME_BYTES = 1 << 31


class WireClosed(ConnectionError):
    """The peer closed (or broke) the connection mid-protocol."""


def send_msg(sock: socket.socket, header: dict,
             arrays: dict[str, np.ndarray] | None = None) -> None:
    """Send one frame: JSON ``header`` plus named numpy ``arrays``.

    The caller must serialize concurrent senders on one socket (both
    router and worker keep a per-connection send lock); the frame itself
    goes out in a single ``sendall`` so a crash between writers never
    interleaves two frames.
    """
    header = dict(header)
    chunks: list[bytes] = []
    manifest = []
    for name, arr in (arrays or {}).items():
        a = np.ascontiguousarray(arr)
        manifest.append({"name": name, "dtype": a.dtype.name,
                         "shape": list(a.shape)})
        chunks.append(a.tobytes())
    if manifest:
        header["_arrays"] = manifest
    hbytes = json.dumps(header, separators=(",", ":")).encode("utf-8")
    payload = b"".join(chunks)
    sock.sendall(_HDR.pack(len(hbytes), len(payload)) + hbytes + payload)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        try:
            chunk = sock.recv(n - len(buf))
        except OSError as exc:
            raise WireClosed(f"recv failed: {exc}") from exc
        if not chunk:
            raise WireClosed("peer closed the connection")
        buf += chunk
    return bytes(buf)


def recv_msg(sock: socket.socket) -> tuple[dict, dict[str, np.ndarray]]:
    """Receive one frame; returns ``(header, arrays)``.

    Raises :class:`WireClosed` on EOF / reset — the reader loops in the
    router and worker treat that as "this peer is gone", which is the
    host-drop detection signal (DESIGN.md §17), not an error to retry.
    """
    hlen, plen = _HDR.unpack(_recv_exact(sock, _HDR.size))
    if hlen + plen > MAX_FRAME_BYTES:
        raise WireClosed(f"oversized frame ({hlen + plen} bytes): "
                         "corrupt length prefix or misbehaving peer")
    header = json.loads(_recv_exact(sock, hlen).decode("utf-8"))
    payload = _recv_exact(sock, plen) if plen else b""
    arrays: dict[str, np.ndarray] = {}
    off = 0
    for m in header.pop("_arrays", []):
        a = np.frombuffer(payload, dtype=np.dtype(m["dtype"]), offset=off,
                          count=int(np.prod(m["shape"], dtype=np.int64)))
        arrays[m["name"]] = a.reshape(m["shape"]).copy()
        off += a.nbytes
    return header, arrays
