"""Cross-process serve router: the multi-host front end (DESIGN.md §17).

:class:`SVDRouter` owns ADMISSION for a fleet of worker hosts
(``serve/worker.py``, each wrapping one
:class:`~repro.serve.AsyncSVDEngine`): clients call
``submit() -> Future`` exactly as on the single-host engine, and the
router shards traffic across hosts by *bucket key* — rendezvous
(highest-random-weight) hashing pins every ``(n, bw, dtype, banded,
compute_uv)`` key to one owner host, so a bucket's traffic keeps
aggregating in one engine's micro-batch window instead of being diluted
round-robin across the fleet.  Ownership is recomputed over the *alive*
set only, so a host drop moves each orphaned bucket wholesale to one
survivor and every other bucket stays put.

Host-drop handling is the single-host §15 ladder lifted one level:

* **Detection** — each worker connection has a dedicated reader thread
  (a broken socket is an immediate drop signal) plus a heartbeat
  ping/pong with a staleness bound (a hung-but-connected worker is a
  drop too).  A seeded :class:`~repro.serve.faults.FaultPlan` with
  ``host_loss_rate``/``host_loss_at`` injects drops deterministically at
  heartbeat ticks — same philosophy as every other fault hook.
* **Quarantine** — dead hosts go through a
  :class:`~repro.serve.faults.BucketQuarantine` keyed by host id
  (``threshold=1``: one detected death trips immediately; a reconnect
  under the same host id is the HALF-OPEN recovery).
* **Requeue** — the dropped host's in-flight requests are re-dispatched
  to the surviving owners through the same future plumbing; every
  client future resolves EXACTLY once (a global in-flight table popped
  under the router lock makes late duplicate results unresolvable), and
  the retries are attributed to the surviving host in the metrics.

Cross-host observability (DESIGN.md §16 reused): the router keeps the
fleet-level :class:`~repro.serve.ServeMetrics` (client-view counters,
per-host dispatch/completion/requeue attribution via ``add_host``, and
per-host client-view latency histograms whose
:meth:`~repro.obs.StreamingHistogram.merge` is the fleet histogram);
workers ship their own engine snapshots/histograms over ``stats``
frames for per-host artifacts.

The router itself never touches a device — all compute lives in the
workers; it runs happily in a process whose jax sees zero accelerators.
"""

from __future__ import annotations

import copy
import hashlib
import itertools
import threading
import time
from concurrent.futures import CancelledError, Future

import numpy as np

from repro.obs.hist import StreamingHistogram
from repro.serve.async_engine import QueueFullError
from repro.serve.faults import BucketQuarantine
from repro.serve.metrics import ServeMetrics, bucket_key_str
from repro.serve.wire import WireClosed, recv_msg, send_msg

__all__ = ["SVDRouter", "HostDownError"]


class HostDownError(ConnectionError):
    """A dispatch raced a host death (internal: always requeued, never
    surfaced to a client while any host survives)."""


class _Host:
    __slots__ = ("host_id", "sock", "send_lock", "alive", "last_seen",
                 "info", "pending_hint", "health", "reader", "stats")

    def __init__(self, host_id: str, sock, info: dict):
        self.host_id = host_id
        self.sock = sock
        self.send_lock = threading.Lock()
        self.alive = True
        self.last_seen = time.monotonic()
        self.info = info                      # hello payload (pid, devices…)
        self.pending_hint = 0                 # from the latest pong
        self.health = "unknown"
        self.reader: threading.Thread | None = None
        self.stats: dict | None = None        # latest stats_res payload


class _Pending:
    __slots__ = ("rid", "req", "future", "deadline", "host", "arrived",
                 "requeues", "resolved")

    def __init__(self, rid: int, req, future: Future,
                 deadline: float | None):
        self.rid = rid
        self.req = req
        self.future = future
        self.deadline = deadline
        self.host: str | None = None
        self.arrived = time.monotonic()
        self.requeues = 0
        self.resolved = False


class SVDRouter:
    """Admission front end sharding shape-buckets across worker hosts.

    >>> router = SVDRouter()
    >>> procs = [spawn_worker_process(router.address, f"w{i}")
    ...          for i in range(2)]
    >>> router.wait_for_hosts(2)
    >>> sigma = router.submit(SVDRequest(uid=0, matrix=a, bw=8)).result().sigma

    Admission mirrors :class:`~repro.serve.AsyncSVDEngine.submit`
    exactly — refusals (stopped router, ``max_pending`` exceeded,
    non-square input) resolve the returned future, never raise — so the
    load harness's client-view accounting works unchanged against either
    tier.  ``heartbeat_s``/``heartbeat_timeout_s`` bound drop-detection
    latency; ``faults`` injects host loss (heartbeat-tick granularity).
    """

    def __init__(self, *, listen=("127.0.0.1", 0),
                 default_timeout_s: float | None = None,
                 max_pending: int = 4096,
                 heartbeat_s: float = 0.25,
                 heartbeat_timeout_s: float = 3.0,
                 faults=None, metrics: ServeMetrics | None = None):
        import socket
        self.default_timeout_s = default_timeout_s
        self.max_pending = int(max_pending)
        self.heartbeat_s = float(heartbeat_s)
        self.heartbeat_timeout_s = float(heartbeat_timeout_s)
        self.faults = faults
        self.metrics = metrics if metrics is not None else ServeMetrics()
        # Host-granularity circuit breaker (§15 semantics, §17 scope):
        # threshold=1 — one detected death is definitive, unlike a flaky
        # bucket dispatch; cooldown only gates how soon a same-id
        # reconnect is trusted again.
        self.quarantine = BucketQuarantine(
            threshold=1, cooldown_s=self.heartbeat_timeout_s)
        self._lock = threading.RLock()
        self._host_seen = threading.Condition(self._lock)
        self._hosts: dict[str, _Host] = {}
        self._inflight: dict[int, _Pending] = {}
        self._unrouted: list[_Pending] = []
        self._host_lat: dict[str, StreamingHistogram] = {}
        self._seen_keys: set = set()
        self._rid = itertools.count(1)
        self._stats_waits: dict[int, tuple[threading.Event, dict]] = {}
        self._stats_token = itertools.count(1)
        self._stopping = False
        self._listener = socket.create_server(listen)
        self.address = self._listener.getsockname()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="SVDRouter-accept", daemon=True)
        self._accept_thread.start()
        self._hb_thread = threading.Thread(
            target=self._heartbeat_loop, name="SVDRouter-heartbeat",
            daemon=True)
        self._hb_thread.start()

    # ------------------------------------------------------------------
    # topology
    # ------------------------------------------------------------------

    def alive_hosts(self) -> list[str]:
        with self._lock:
            return sorted(h for h, st in self._hosts.items() if st.alive)

    def wait_for_hosts(self, n: int, timeout: float = 60.0) -> bool:
        """Block until ``n`` hosts are alive (True) or ``timeout`` (False)."""
        deadline = time.monotonic() + timeout
        with self._host_seen:
            while len([h for h in self._hosts.values() if h.alive]) < n:
                left = deadline - time.monotonic()
                if left <= 0:
                    return False
                self._host_seen.wait(timeout=left)
        return True

    def owner_of(self, key) -> str | None:
        """The alive host owning ``key`` under rendezvous hashing (stable:
        removing a host only moves THAT host's buckets)."""
        with self._lock:
            return self._owner_locked(key)

    def _owner_locked(self, key) -> str | None:
        kstr = bucket_key_str(key)
        best, best_w = None, b""
        for hid, st in self._hosts.items():
            if not st.alive:
                continue
            w = hashlib.sha256(f"{hid}|{kstr}".encode()).digest()
            if best is None or w > best_w:
                best, best_w = hid, w
        return best

    # ------------------------------------------------------------------
    # admission (mirrors AsyncSVDEngine.submit)
    # ------------------------------------------------------------------

    def submit(self, req, *, timeout_s: float | None = None) -> Future:
        """Enqueue one request fleet-wide; returns a future resolving to
        the completed request.  Refusals resolve the future, never raise."""
        fut: Future = Future()
        req.future = fut
        now = time.monotonic()
        req.arrived = now
        t = timeout_s if timeout_s is not None else self.default_timeout_s
        if t is not None and req.deadline is None:
            req.deadline = now + float(t)
        m = req.matrix
        if not (hasattr(m, "ndim") and m.ndim == 2
                and m.shape[0] == m.shape[1]):
            self.metrics.add(rejected=1)
            fut.set_exception(ValueError(
                f"SVDRequest.matrix must be square 2-D, got shape "
                f"{getattr(m, 'shape', None)}"))
            return fut
        with self._lock:
            if self._stopping:
                self.metrics.add(rejected=1)
                fut.set_exception(RuntimeError("router is stopped"))
                return fut
            if len(self._inflight) + len(self._unrouted) >= self.max_pending:
                self.metrics.add(rejected=1)
                fut.set_exception(QueueFullError(
                    f"{self.max_pending} requests already pending "
                    f"fleet-wide"))
                return fut
            key = req.key()
            self.metrics.add(submitted=1,
                             bucket_hits=int(key in self._seen_keys))
            self._seen_keys.add(key)
            p = _Pending(next(self._rid), req, fut, req.deadline)
            host = self._owner_locked(key)
            if host is None:
                self._unrouted.append(p)     # no host yet: parked, the
                return fut                   # heartbeat loop re-routes
            self._inflight[p.rid] = p
            p.host = host
        self._forward(p, host)
        return fut

    def submit_to(self, host_id: str, req, *,
                  timeout_s: float | None = None) -> Future:
        """Pin one request to a specific host, bypassing rendezvous
        routing — used by :meth:`warm` to pre-compile every bucket on
        every host (so a post-drop requeue never pays a compile under a
        deadline) and by tests."""
        fut: Future = Future()
        req.future = fut
        req.arrived = time.monotonic()
        if timeout_s is not None and req.deadline is None:
            req.deadline = req.arrived + float(timeout_s)
        with self._lock:
            if self._stopping or host_id not in self._hosts \
                    or not self._hosts[host_id].alive:
                fut.set_exception(RuntimeError(
                    f"host {host_id!r} is not alive"))
                return fut
            self._seen_keys.add(req.key())
            p = _Pending(next(self._rid), req, fut, req.deadline)
            self._inflight[p.rid] = p
            p.host = host_id
        self._forward(p, host_id)
        return fut

    def warm(self, reqs, timeout: float = 300.0) -> None:
        """Broadcast ``reqs`` (one per bucket key, e.g. the load
        harness's mix cover) to EVERY alive host and wait: each host
        compiles each bucket exactly once, outside any deadline."""
        futs = []
        for hid in self.alive_hosts():
            for r in reqs:
                futs.append(self.submit_to(hid, copy.copy(r)))
        for f in futs:
            f.result(timeout=timeout)

    # ------------------------------------------------------------------
    # dispatch / completion
    # ------------------------------------------------------------------

    def _forward(self, p: _Pending, host_id: str) -> None:
        """Send one request frame to ``host_id``; a send failure is a
        host-down signal, and the request rides the requeue path."""
        with self._lock:
            st = self._hosts.get(host_id)
        req = p.req
        header = {"type": "req", "rid": p.rid, "uid": req.uid,
                  "bw": req.bw, "banded": req.banded,
                  "compute_uv": req.compute_uv}
        if p.deadline is not None:
            remaining = p.deadline - time.monotonic()
            if remaining <= 0:
                if self._pop_pending(p.rid) is not None:
                    self._resolve_error(p, TimeoutError(
                        f"request {req.uid} expired before dispatch"))
                return
            header["timeout_s"] = remaining
        ok = st is not None and st.alive
        if ok:
            try:
                with st.send_lock:
                    send_msg(st.sock, header,
                             {"matrix": np.asarray(req.matrix)})
            except (OSError, WireClosed):
                ok = False
        if ok:
            self.metrics.add_host(host_id, dispatched=1)
        else:
            self._host_down(host_id, "send failed")

    def _pop_pending(self, rid: int) -> _Pending | None:
        """Claim one in-flight entry — the exactly-once gate: whichever
        of result-arrival and host-drop-requeue pops the rid first owns
        the request; the loser finds nothing and drops its copy."""
        with self._lock:
            return self._inflight.pop(rid, None)

    def _on_result(self, host_id: str, header: dict, arrays: dict) -> None:
        p = self._pop_pending(int(header["rid"]))
        if p is None:
            return                            # late duplicate: requeued
        req = p.req
        if header.get("ok"):
            req.sigma = arrays.get("sigma")
            if req.compute_uv:
                req.u, req.vt = arrays.get("u"), arrays.get("vt")
            now = time.monotonic()
            if p.deadline is not None and now > p.deadline:
                self._resolve_error(p, TimeoutError(
                    f"request {req.uid} completed after its deadline; "
                    f"late results remain on the request"))
                return
            req.done = True
            self.metrics.add(completed=1)
            self.metrics.add_host(host_id, completed=1)
            lat = now - p.arrived
            tier = header.get("tier") or "unknown"
            self.metrics.observe_latency(tier, req.key(), lat)
            with self._lock:
                h = self._host_lat.setdefault(host_id, StreamingHistogram())
            h.add(lat)
            try:
                p.future.set_result(req)
            except Exception:                # noqa: BLE001 — cancelled
                pass
            return
        # Worker-side refusal/failure past its own fault ladder.
        etype = header.get("error_type", "")
        msg = f"[host {host_id}] {header.get('error', 'unknown error')}"
        exc: Exception
        if etype == "TimeoutError":
            exc = TimeoutError(msg)
        elif etype == "QueueFullError":
            exc = QueueFullError(msg)
        else:
            exc = RuntimeError(f"{etype}: {msg}" if etype else msg)
        self.metrics.add_host(host_id, failed=1)
        self._resolve_error(p, exc)

    def _resolve_error(self, p: _Pending, exc: Exception) -> None:
        p.req.error = exc
        p.req.done = True
        if isinstance(exc, TimeoutError):
            self.metrics.add(timed_out=1)
        else:
            self.metrics.add(failed=1)
        try:
            p.future.set_exception(exc)
        except Exception:                    # noqa: BLE001 — cancelled
            pass

    # ------------------------------------------------------------------
    # host lifecycle
    # ------------------------------------------------------------------

    def _accept_loop(self) -> None:
        while True:
            try:
                sock, _addr = self._listener.accept()
            except OSError:
                return                       # listener closed by stop()
            threading.Thread(target=self._handshake, args=(sock,),
                             name="SVDRouter-handshake", daemon=True).start()

    def _handshake(self, sock) -> None:
        try:
            header, _ = recv_msg(sock)
        except WireClosed:
            sock.close()
            return
        if header.get("type") != "hello" or "host_id" not in header:
            sock.close()
            return
        hid = str(header["host_id"])
        st = _Host(hid, sock, {k: v for k, v in header.items()
                               if k not in ("type", "host_id")})
        with self._host_seen:
            old = self._hosts.get(hid)
            if old is not None and old.alive:
                old.alive = False            # same-id replacement wins
                try:
                    old.sock.close()
                except OSError:
                    pass
            self._hosts[hid] = st
            # A reconnect under a quarantined id is the HALF-OPEN
            # recovery trial succeeding (§15 semantics at host scope).
            if self.quarantine.record_success(hid):
                self.metrics.set_bucket_quarantined(f"host:{hid}", False)
            self._host_seen.notify_all()
        st.reader = threading.Thread(
            target=self._reader_loop, args=(st,),
            name=f"SVDRouter-reader-{hid}", daemon=True)
        st.reader.start()
        self._drain_unrouted()

    def _reader_loop(self, st: _Host) -> None:
        while True:
            try:
                header, arrays = recv_msg(st.sock)
            except WireClosed:
                if st.alive:
                    self._host_down(st.host_id, "connection lost")
                return
            t = header.get("type")
            if t == "res":
                self._on_result(st.host_id, header, arrays)
            elif t == "pong":
                with self._lock:
                    st.last_seen = time.monotonic()
                    st.pending_hint = int(header.get("pending", 0))
                    st.health = header.get("health", "unknown")
            elif t == "stats_res":
                with self._lock:
                    st.stats = header
                    wait = self._stats_waits.get(int(header.get("token", 0)))
                if wait is not None:
                    ev, out = wait
                    out[st.host_id] = header
                    ev.set()

    def _host_down(self, host_id: str, reason: str) -> None:
        """Quarantine a dead host and requeue its in-flight requests to
        the surviving owners — zero client-visible failures while any
        host survives (DESIGN.md §17)."""
        with self._lock:
            st = self._hosts.get(host_id)
            if st is None or not st.alive:
                return                       # already handled
            st.alive = False
            orphans = [p for p in self._inflight.values()
                       if p.host == host_id]
            for p in orphans:
                del self._inflight[p.rid]
        try:
            st.sock.close()                  # wakes the reader thread too
        except OSError:
            pass
        if self.quarantine.record_failure(host_id):
            self.metrics.add(quarantined=1)
            self.metrics.set_bucket_quarantined(f"host:{host_id}", True)
        self.metrics.set_bucket_error(
            f"host:{host_id}", HostDownError(reason))
        for p in orphans:
            self._requeue(p)

    def _requeue(self, p: _Pending) -> None:
        """Re-dispatch one orphaned request under a FRESH rid (the old
        rid is gone from the in-flight table, so a late result from the
        dead host can never double-resolve the future)."""
        if p.deadline is not None and time.monotonic() >= p.deadline:
            self._resolve_error(p, TimeoutError(
                f"request {p.req.uid} expired while host "
                f"{p.host!r} was being replaced"))
            return
        with self._lock:
            host = self._owner_locked(p.req.key())
            if host is None:
                p.host = None
                self._unrouted.append(p)     # whole fleet down: parked
                return
            p.rid = next(self._rid)
            p.requeues += 1
            p.host = host
            self._inflight[p.rid] = p
        # Retry attribution (§15 taxonomy at fleet scope): the requeue is
        # counted on the SURVIVING host that absorbs the work.
        self.metrics.add(retried=1)
        self.metrics.add_host(host, requeued=1)
        self._forward(p, host)

    def _drain_unrouted(self) -> None:
        with self._lock:
            parked, self._unrouted = self._unrouted, []
        for p in parked:
            with self._lock:
                host = self._owner_locked(p.req.key())
                if host is None:
                    self._unrouted.append(p)
                    continue
                p.rid = next(self._rid)
                p.host = host
                self._inflight[p.rid] = p
            self._forward(p, host)

    def _heartbeat_loop(self) -> None:
        seq = 0
        while not self._stopping:
            time.sleep(self.heartbeat_s)
            if self._stopping:
                return
            seq += 1
            self._heartbeat_tick(seq)

    def _heartbeat_tick(self, seq: int = 0) -> None:
        """One heartbeat round: fault consultation, staleness detection,
        pings, parked-request expiry.  Split from the loop so tests can
        fire a deterministic tick without racing wall-clock sleeps."""
        now = time.monotonic()
        with self._lock:
            alive = [(hid, st) for hid, st in self._hosts.items()
                     if st.alive]
        if self.faults is not None and alive:
            victim = self.faults.lose_host([hid for hid, _ in alive])
            if victim is not None:
                self._host_down(victim, "injected host loss")
                with self._lock:
                    alive = [(h, s) for h, s in alive if s.alive]
        for hid, st in alive:
            if now - st.last_seen > self.heartbeat_timeout_s:
                self._host_down(hid, "heartbeat timeout")
                continue
            try:
                with st.send_lock:
                    send_msg(st.sock, {"type": "ping", "seq": seq})
            except (OSError, WireClosed):
                self._host_down(hid, "ping send failed")
        # Expire parked requests whose deadline passed while no host
        # could take them; re-route the rest if hosts (re)appeared.
        with self._lock:
            expired = [p for p in self._unrouted
                       if p.deadline is not None and now >= p.deadline]
            self._unrouted = [p for p in self._unrouted
                              if p not in expired]
        for p in expired:
            self._resolve_error(p, TimeoutError(
                f"request {p.req.uid} expired with no host available"))
        if self.alive_hosts():
            self._drain_unrouted()

    # ------------------------------------------------------------------
    # observability (DESIGN.md §16 across hosts)
    # ------------------------------------------------------------------

    def collect_host_stats(self, timeout: float = 10.0) -> dict:
        """Request each alive worker's full engine snapshot + histogram
        dicts (``stats`` frames); returns ``{host_id: payload}`` for the
        hosts that answered in time — the per-host CI artifacts."""
        token = next(self._stats_token)
        ev = threading.Event()
        out: dict[str, dict] = {}
        with self._lock:
            alive = [(hid, st) for hid, st in self._hosts.items()
                     if st.alive]
            self._stats_waits[token] = (ev, out)
        try:
            for _hid, st in alive:
                try:
                    with st.send_lock:
                        send_msg(st.sock, {"type": "stats", "token": token})
                except (OSError, WireClosed):
                    pass
            deadline = time.monotonic() + timeout
            while len(out) < len(alive) and time.monotonic() < deadline:
                ev.wait(timeout=0.05)
                ev.clear()
        finally:
            with self._lock:
                self._stats_waits.pop(token, None)
        return dict(out)

    def host_latency_histograms(self) -> dict[str, StreamingHistogram]:
        """Per-host client-view latency histograms (router-observed)."""
        with self._lock:
            return dict(self._host_lat)

    def reset_stats(self) -> None:
        """Fresh counters + latency histograms.  Harness hook: measure the
        timed window, not warmup compiles (mirrors the engines'
        ``eng.metrics = ServeMetrics()`` reset).  Quarantine state is NOT
        reset — an unhealthy host stays unhealthy across the boundary."""
        with self._lock:
            self.metrics = ServeMetrics()
            self._host_lat = {}

    def fleet(self) -> dict:
        """The fleet-level view: router counters, per-host status +
        attribution, and the per-host/merged latency histograms (the
        cross-host ``merge()`` invariant: the merged histogram's counts
        are exactly the sum of the per-host counts, so its percentiles
        stay within one log-bucket width of the pooled exact samples)."""
        snap = self.metrics.snapshot()
        now = time.monotonic()
        with self._lock:
            hosts = {
                hid: {"alive": st.alive,
                      "last_seen_age_s": now - st.last_seen,
                      "pending_hint": st.pending_hint,
                      "health": st.health, **st.info,
                      **snap.get("hosts", {}).get(hid, {})}
                for hid, st in self._hosts.items()}
            lat = dict(self._host_lat)
        merged = StreamingHistogram.merged(lat.values())
        return {
            "alive_hosts": sorted(h for h, v in hosts.items() if v["alive"]),
            "dead_hosts": sorted(h for h, v in hosts.items()
                                 if not v["alive"]),
            "hosts": hosts,
            "router": snap,
            "latency": {
                "per_host": {h: hh.to_dict() for h, hh in lat.items()},
                "per_host_summary": {h: hh.summary()
                                     for h, hh in lat.items()},
                "merged": merged.to_dict(),
                "merged_summary": merged.summary(),
                "bucket_ratio": merged.bucket_width_ratio(),
            },
        }

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def pending(self) -> int:
        with self._lock:
            return len(self._inflight) + len(self._unrouted)

    def stop(self, *, drain: bool = True, timeout: float = 30.0) -> None:
        """Stop the fleet: optionally wait for in-flight work, tell every
        worker to drain-and-exit, fail whatever is left with
        :class:`CancelledError`, and close the fabric."""
        with self._lock:
            self._stopping = True
        if drain:
            deadline = time.monotonic() + timeout
            while self.pending() and time.monotonic() < deadline:
                time.sleep(0.01)
        with self._lock:
            leftovers = list(self._inflight.values()) + self._unrouted
            self._inflight.clear()
            self._unrouted = []
            hosts = list(self._hosts.values())
        for p in leftovers:
            self._resolve_error(p, CancelledError(
                "router stopped before completion"))
        for st in hosts:
            if st.alive:
                try:
                    with st.send_lock:
                        send_msg(st.sock, {"type": "stop"})
                except (OSError, WireClosed):
                    pass
        try:
            self._listener.close()
        except OSError:
            pass
        for st in hosts:
            try:
                st.sock.close()
            except OSError:
                pass
