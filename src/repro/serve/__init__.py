"""repro.serve — static-shape continuous-batching engines (tokens + SVD)."""
from repro.serve.engine import (Engine, Request, ServeConfig,
                                SVDEngine, SVDRequest)
