"""repro.serve — static-shape continuous-batching engines (tokens + SVD).

Sync tier: ``Engine`` (tokens) and ``SVDEngine`` (spectral, shape-bucketed).
Async tier (DESIGN.md §12): ``AsyncSVDEngine`` — thread-safe micro-batching
queue, deadline-aware admission, futures-based delivery, optional
multi-device (mesh) dispatch; ``ServeMetrics`` counters live on every
engine as ``.metrics``.
"""
from repro.serve.async_engine import AsyncSVDEngine, QueueFullError
from repro.serve.engine import (Engine, Request, ServeConfig,
                                SVDEngine, SVDRequest)
from repro.serve.metrics import ServeMetrics

__all__ = ["Engine", "Request", "ServeConfig", "SVDEngine", "SVDRequest",
           "AsyncSVDEngine", "QueueFullError", "ServeMetrics"]
