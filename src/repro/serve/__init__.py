"""repro.serve — static-shape continuous-batching engines (tokens + SVD).

Sync tier: ``Engine`` (tokens) and ``SVDEngine`` (spectral, shape-bucketed).
Async tier (DESIGN.md §12): ``AsyncSVDEngine`` — thread-safe micro-batching
queue, deadline-aware admission, futures-based delivery, optional
multi-device (mesh) dispatch; ``ServeMetrics`` counters live on every
engine as ``.metrics``.  Fault tolerance (DESIGN.md §15): ``FaultPlan``
(deterministic injection), ``RetryPolicy`` (backoff ladder),
``BucketQuarantine`` (per-bucket circuit breaker) in ``serve/faults.py``;
the typed ``NumericalFault`` lives in ``core/svd.py`` and is re-exported
here for serve-side callers.  Multi-host tier (DESIGN.md §17):
``SVDRouter`` (cross-process admission front end, ``serve/router.py``)
over ``ServeWorker`` hosts (``serve/worker.py``) speaking the
``serve/wire.py`` frame protocol.
"""
from repro.core.svd import NumericalFault
from repro.serve.async_engine import AsyncSVDEngine, QueueFullError
from repro.serve.engine import (Engine, Request, ServeConfig,
                                SVDEngine, SVDRequest)
from repro.serve.faults import (BucketQuarantine, FaultPlan,
                                InjectedDeviceLoss, InjectedDispatchError,
                                InjectedFault, RetryPolicy)
from repro.serve.metrics import ServeMetrics, bucket_key_str
from repro.serve.router import HostDownError, SVDRouter
from repro.serve.worker import (ServeWorker, spawn_worker_process,
                                start_inprocess_worker)

__all__ = ["Engine", "Request", "ServeConfig", "SVDEngine", "SVDRequest",
           "AsyncSVDEngine", "QueueFullError", "ServeMetrics",
           "bucket_key_str",
           "SVDRouter", "HostDownError", "ServeWorker",
           "start_inprocess_worker", "spawn_worker_process",
           "FaultPlan", "RetryPolicy", "BucketQuarantine", "NumericalFault",
           "InjectedFault", "InjectedDispatchError", "InjectedDeviceLoss"]
