"""repro.serve — static-shape continuous-batching engine."""
from repro.serve.engine import Engine, Request, ServeConfig
