"""Asynchronous micro-batching SVD serving tier (DESIGN.md §12).

The synchronous :class:`~repro.serve.engine.SVDEngine` batches only as fast
as one thread submits-then-steps; under live traffic the batch axis — the
thing PR 1/3/4 made fast — would sit empty.  :class:`AsyncSVDEngine` puts a
thread-safe queue and a background dispatcher between callers and the
batched pipeline:

* ``submit() -> concurrent.futures.Future`` — callers never block on other
  requests; results are delivered through the future (the resolved value is
  the completed :class:`SVDRequest`).  ``submit_async()`` wraps the same
  future for ``await``-style callers (asyncio + thread-pool bridge).
* **Micro-batching window** — a bucket is dispatched the moment it reaches
  its capacity (``max_batch`` from the tuned per-bucket config, DESIGN.md
  §11), or once its oldest request has waited ``batch_window_s``: bounded
  added latency, maximal batch fill under load.
* **Deadline/timeout-aware admission** — per-request (or engine-default)
  timeouts become absolute deadlines; a request still queued past its
  deadline is failed with :class:`TimeoutError` *before* dispatch (no work
  is burned on an answer nobody is waiting for).  A full queue
  (``max_pending``) refuses admission with :class:`QueueFullError` instead
  of buffering unboundedly.
* **Oversize splitting** — a burst larger than a bucket's capacity is
  served as back-to-back full batches, FIFO.
* **Multi-device dispatch** — with a ``mesh`` (see
  ``repro.launch.mesh.serve_mesh``), full buckets are batch-sharded across
  all local devices through ``core.distributed.sharded_pipeline_dispatch``.
* **Fault tolerance** (DESIGN.md §15) — inherited from the sync engine:
  numerical-health guards on every result, the retry/backoff ladder, the
  per-bucket quarantine circuit breaker, and the degraded ref tier.  Two
  async-specific points: (1) deadlines are re-checked at COMPLETION, not
  only at admission — a request finished past its deadline resolves its
  future with :class:`TimeoutError` (counted ``timed_out``; the late
  results stay on the request object); (2) backoff sleeps run on the
  dispatcher thread, so a retrying bucket briefly delays its neighbors —
  backoffs are capped (``RetryPolicy.backoff_max_s``, 100 ms default)
  precisely so a sick bucket cannot stall the fabric, and a repeatedly
  sick bucket trips its breaker and stops retrying altogether.

The dispatcher itself is the ONE consumer of the buckets; the compute
happens outside the engine lock, so admission keeps flowing while a batch
is on device.  Do not mix the inherited synchronous ``step()``/``run()``
with a started async engine — they assume single-threaded bucket access.
"""

from __future__ import annotations

import asyncio
import collections
import threading
import time
from concurrent.futures import CancelledError, Future

from repro.serve.engine import SVDEngine, SVDRequest

__all__ = ["AsyncSVDEngine", "QueueFullError"]


class QueueFullError(RuntimeError):
    """Admission refused: the engine already holds ``max_pending`` requests."""


class AsyncSVDEngine(SVDEngine):
    """Thread-safe, micro-batching, future-returning SVD serving engine.

    >>> with AsyncSVDEngine(backend="ref", batch_window_s=0.005) as eng:
    ...     futs = [eng.submit(SVDRequest(uid=i, matrix=a, bw=8))
    ...             for i, a in enumerate(mats)]
    ...     sigmas = [f.result().sigma for f in futs]

    Construction kwargs extend :class:`SVDEngine` (config / backend /
    autotune / mesh) with the serving knobs: ``batch_window_s`` (max extra
    latency a lone request pays waiting for co-batchable traffic),
    ``default_timeout_s`` (deadline applied to requests submitted without
    one; ``None`` = wait forever), and ``max_pending`` (admission bound).

    Results are delivered through futures, so — unlike the sync engine,
    whose callers consume ``run()``'s return — nobody drains
    ``finished``; it is therefore a BOUNDED deque here
    (``finished_history`` most recent completions, for inspection), not
    an unbounded ledger that would leak one matrix per request in a
    long-running service.
    """

    def __init__(self, config=None, *, backend: str = "auto",
                 max_batch: int | None = None, autotune: bool = False,
                 autotune_cache: str | None = None, mesh=None,
                 batch_window_s: float = 0.01,
                 default_timeout_s: float | None = None,
                 max_pending: int = 4096, finished_history: int = 1024,
                 fused_n_max: int | None = None,
                 dc_n_min: int | None = None,
                 faults=None, retry=None, residual_check: bool = False,
                 tracer=None):
        super().__init__(config, backend=backend, max_batch=max_batch,
                         autotune=autotune, autotune_cache=autotune_cache,
                         mesh=mesh, fused_n_max=fused_n_max,
                         dc_n_min=dc_n_min, faults=faults, retry=retry,
                         residual_check=residual_check, tracer=tracer)
        self.finished = collections.deque(maxlen=int(finished_history))
        self.batch_window_s = float(batch_window_s)
        self.default_timeout_s = default_timeout_s
        self.max_pending = int(max_pending)
        self._cond = threading.Condition()
        self._thread: threading.Thread | None = None
        self._stopping = False

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------

    def submit(self, req: SVDRequest, *, timeout_s: float | None = None
               ) -> Future:
        """Enqueue one request; returns a future resolving to the completed
        request.  Refusals (stopped engine, full queue, non-square input)
        are delivered through the future too — an open-loop caller never
        has to try/except the submit path itself."""
        fut: Future = Future()
        req.future = fut
        now = time.monotonic()
        req.arrived = now
        t = timeout_s if timeout_s is not None else self.default_timeout_s
        if t is not None and req.deadline is None:
            req.deadline = now + float(t)
        m = req.matrix
        if not (hasattr(m, "ndim") and m.ndim == 2 and m.shape[0] == m.shape[1]):
            self.metrics.add(rejected=1)
            fut.set_exception(ValueError(
                f"SVDRequest.matrix must be square 2-D, got shape "
                f"{getattr(m, 'shape', None)}"))
            return fut
        with self._cond:
            if self._stopping:
                self.metrics.add(rejected=1)
                fut.set_exception(RuntimeError("engine is stopped"))
                return fut
            if self.pending() >= self.max_pending:
                self.metrics.add(rejected=1)
                fut.set_exception(QueueFullError(
                    f"{self.max_pending} requests already pending"))
                return fut
            SVDEngine.submit(self, req)
            if self._thread is None:
                self._start_locked()
            self._cond.notify()
        return fut

    def submit_async(self, req: SVDRequest, *, timeout_s: float | None = None):
        """``await``-able variant: the same future bridged into the calling
        asyncio event loop (``asyncio.wrap_future``)."""
        return asyncio.wrap_future(self.submit(req, timeout_s=timeout_s))

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def start(self) -> "AsyncSVDEngine":
        """Start the dispatcher now (otherwise the first submit starts it)."""
        with self._cond:
            if self._thread is None and not self._stopping:
                self._start_locked()
        return self

    def stop(self, *, drain: bool = True, timeout: float | None = None) -> None:
        """Stop the dispatcher.  ``drain=True`` (default) serves everything
        still queued first — without the micro-batch wait; ``drain=False``
        fails queued requests with :class:`CancelledError`."""
        cancelled = []
        with self._cond:
            self._stopping = True
            if not drain:
                for key in list(self.buckets):
                    cancelled += self._pop(key, len(self.buckets[key]))
            self._cond.notify_all()
            t = self._thread
        for r in cancelled:                      # futures resolve OUTSIDE
            self._finish(r, error=CancelledError(  # the lock (callbacks!)
                "engine stopped before dispatch"))
        if t is not None:
            t.join(timeout)

    def __enter__(self) -> "AsyncSVDEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _start_locked(self) -> None:
        self._thread = threading.Thread(target=self._worker,
                                        name="AsyncSVDEngine", daemon=True)
        self._thread.start()

    # ------------------------------------------------------------------
    # dispatcher
    # ------------------------------------------------------------------

    def _expire_locked(self, now: float) -> list:
        """Dequeue every request whose deadline has already passed; the
        CALLER fails them outside the lock (futures run user callbacks)."""
        expired = []
        for key in list(self.buckets):
            if any(r.deadline is not None and now >= r.deadline
                   for r in self.buckets[key]):
                alive = []
                for r in self._pop(key, len(self.buckets[key])):
                    (expired if r.deadline is not None and now >= r.deadline
                     else alive).append(r)
                if alive:
                    self.buckets[key] = alive + self.buckets.get(key, [])
                    self.metrics.set_queue_depth(self.pending())
        return expired

    def _admit_locked(self, now: float):
        """Pick what to dispatch: ``(key, cfg, reqs, delay, to_fail)``.
        ``reqs`` non-None -> serve now; otherwise sleep ``delay`` until the
        next edge (window expiry or nearest deadline).  ``to_fail`` are
        ``(request, error)`` pairs the caller completes OUTSIDE the lock —
        resolving a future runs arbitrary user callbacks, which must never
        execute while the engine lock is held."""
        to_fail = [(r, TimeoutError(
            f"request {r.uid} expired after "
            f"{now - (r.arrived or now):.3f}s in queue"))
            for r in self._expire_locked(now)]
        cfgs = {}
        for key in list(self.buckets):
            try:
                cfgs[key] = self._cfg_for(key)
            except Exception as exc:             # noqa: BLE001 — per-bucket
                to_fail += [(r, exc)
                            for r in self._pop(key, len(self.buckets[key]))]
        if not self.buckets:
            return None, None, None, None, to_fail
        # Window bound FIRST: when the globally oldest head has waited past
        # batch_window_s, its bucket dispatches even if another bucket is
        # full — a continuously-refilled hot bucket must not starve a lone
        # request elsewhere past the documented latency bound.
        oldest = min(self.buckets,
                     key=lambda k: self.buckets[k][0].arrived or now)
        head = self.buckets[oldest][0]
        ripe_at = (head.arrived or now) + self.batch_window_s
        if self._stopping or now >= ripe_at:
            return (oldest, cfgs[oldest],
                    self._pop(oldest, cfgs[oldest].max_batch), 0.0, to_fail)
        # Fresh traffic: any bucket at capacity dispatches immediately.
        for key in list(self.buckets):
            if len(self.buckets[key]) >= cfgs[key].max_batch:
                return (key, cfgs[key], self._pop(key, cfgs[key].max_batch),
                        0.0, to_fail)
        deadlines = [r.deadline for rs in self.buckets.values() for r in rs
                     if r.deadline is not None]
        wake_at = min([ripe_at] + deadlines)
        return None, None, None, max(wake_at - now, 1e-4), to_fail

    def _worker(self) -> None:
        while True:
            with self._cond:
                while not self.buckets and not self._stopping:
                    self._cond.wait()
                if not self.buckets and self._stopping:
                    return
                key, cfg, reqs, delay, to_fail = self._admit_locked(
                    time.monotonic())
                if reqs is None and not to_fail and delay is not None:
                    self._cond.wait(timeout=delay)
                    continue
            # Everything below runs OUTSIDE the lock: admission keeps
            # flowing while a batch is on device, and future callbacks
            # (user code) never execute under the engine lock.
            for r, exc in to_fail:
                self._finish(r, error=exc)
            if reqs:
                # Async queue age (admission -> dispatch) is observed here —
                # the inherited step() path is unused on a started engine.
                now = time.monotonic()
                for r in reqs:
                    if r.arrived is not None:
                        self.metrics.observe_queue_age(now - r.arrived)
                self._serve_batch(key, cfg, reqs)
