"""Batched serving engines: continuous batching with static shapes.

Two workloads share the same philosophy (static shapes, one fused device call
per round, queue-fed slots):

* ``Engine`` — token serving.  Requests queue up; up to ``max_batch`` live in
  fixed KV-cache slots with *per-slot positions* (decode_step takes a (b,)
  position vector).  Every round issues ONE batched decode step: prefilling
  slots feed their next prompt token, generating slots feed their last sampled
  token, finished slots are refilled from the queue.  Greedy sampling; the
  padded-vocab tail is masked at sample time.

* ``SVDEngine`` — spectral serving over the batch-native SVD pipeline.
  Requests are bucketed by compilation key ``(n, bw, dtype, banded,
  compute_uv)``; each flush pads one bucket to the config's ``max_batch``
  and issues ONE batched pipeline call (``core.svd.svd_batched``, in
  reflector-tape mode for ``compute_uv`` buckets), so heavy small-matrix
  traffic saturates the chase wavefront that a single matrix cannot (paper
  Eq. 1).  Padding keeps shapes static — one compilation per bucket key,
  ever.

The asynchronous tier (thread-safe queue, micro-batch window, futures,
deadlines, mesh dispatch) lives in ``serve/async_engine.py`` and extends
``SVDEngine``; metrics counters shared by both live in
``serve/metrics.py`` (DESIGN.md §12).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.serve.faults import BucketQuarantine, RetryPolicy
from repro.serve.metrics import ServeMetrics, bucket_key_str

__all__ = ["Request", "ServeConfig", "Engine",
           "SVDRequest", "SVDEngine"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    frames: np.ndarray | None = None          # enc-dec (whisper) stub input
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 4
    max_seq: int = 128
    eos_id: int = -1                          # -1: never stop early


class _Slot:
    __slots__ = ("req", "pos", "k", "next_tok")

    def __init__(self, req):
        self.req = req
        self.pos = 0                          # next cache position to write
        self.k = 0                            # prompt cursor
        self.next_tok = req.prompt[0]


class Engine:
    def __init__(self, model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._decode = jax.jit(model.decode_step)
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self.slots: list[_Slot | None] = [None] * cfg.max_batch
        self.caches = model.init_caches(cfg.max_batch, cfg.max_seq)
        self._is_encdec = model.cfg.kind == "encdec"
        if self._is_encdec:
            d = model.cfg.d_model
            self._frames = np.zeros((cfg.max_batch, model.cfg.enc_seq, d),
                                    np.float32)

    def submit(self, req: Request):
        assert len(req.prompt) >= 1
        self.queue.append(req)

    def _admit(self):
        refreshed = False
        for i in range(self.cfg.max_batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = _Slot(req)
                if self._is_encdec:
                    fr = req.frames if req.frames is not None else 0.0
                    self._frames[i] = fr
                    refreshed = True
        if refreshed:
            from repro.models.encdec import fill_cross_cache
            self.caches = fill_cross_cache(
                self.params, self.model.cfg, jnp.asarray(self._frames),
                self.caches)

    def step(self) -> int:
        """One batched decode round.  Returns number of active slots."""
        self._admit()
        act = [i for i, s in enumerate(self.slots) if s is not None]
        if not act:
            return 0
        b = self.cfg.max_batch
        toks = np.zeros((b, 1), np.int32)
        pos = np.zeros((b,), np.int32)
        for i in act:
            s = self.slots[i]
            toks[i, 0] = s.next_tok
            pos[i] = s.pos
        logits, self.caches = self._decode(
            self.params, jnp.asarray(toks), self.caches, jnp.asarray(pos))
        v = self.model.cfg.vocab
        nxt = np.asarray(jnp.argmax(logits[:, 0, :v], axis=-1))
        for i in act:
            s = self.slots[i]
            s.pos += 1
            s.k += 1
            if s.k < len(s.req.prompt):           # still prefilling
                s.next_tok = int(s.req.prompt[s.k])
                continue
            tok = int(nxt[i])
            s.req.output.append(tok)
            s.next_tok = tok
            if (tok == self.cfg.eos_id
                    or len(s.req.output) >= s.req.max_new_tokens
                    or s.pos >= self.cfg.max_seq - 1):
                s.req.done = True
                self.finished.append(s.req)
                self.slots[i] = None
        return len(act)

    def run(self, max_rounds: int = 10_000) -> list[Request]:
        rounds = 0
        while (self.queue or any(self.slots)) and rounds < max_rounds:
            self.step()
            rounds += 1
        return self.finished


# ---------------------------------------------------------------------------
# Batched SVD serving (shape-bucketed, batch-native pipeline)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SVDRequest:
    """One spectral query: singular values (and optionally vectors) of a
    square (or banded) matrix.

    A request always COMPLETES (``done=True``) exactly once: either with
    results (``sigma`` and, for ``compute_uv``, ``u``/``vt``) or with
    ``error`` set to the exception that failed it — engines never raise a
    per-request problem out of a whole batched step.  ``deadline`` (an
    absolute ``time.monotonic()`` instant) is honored by the async engine:
    a request still queued past its deadline is failed with
    :class:`TimeoutError` instead of being dispatched.
    """
    uid: int
    matrix: np.ndarray                         # (n, n); upper-banded if banded
    bw: int = 32                               # stage-1 target / band bandwidth
    banded: bool = False                       # True: skip stage 1
    compute_uv: bool = False                   # True: full SVD (U, sigma, Vt)
    sigma: np.ndarray | None = None            # (n,) result, descending
    u: np.ndarray | None = None                # (n, n) left vectors (compute_uv)
    vt: np.ndarray | None = None               # (n, n) right vectors^T
    done: bool = False
    error: Exception | None = None             # set instead of raising
    deadline: float | None = None              # absolute monotonic() instant
    arrived: float | None = None               # set at submit (monotonic())
    future: object | None = dataclasses.field(default=None, repr=False)

    def key(self) -> tuple:
        """Bucket/compilation key: everything that shapes the pipeline.

        ``compute_uv`` is part of the key — the tape-mode pipeline is a
        different compiled program (and a values-only request must not pay
        for a co-bucketed full-SVD one).
        """
        return (self.matrix.shape[-1], self.bw, np.dtype(self.matrix.dtype).name,
                self.banded, self.compute_uv)


class SVDEngine:
    """Shape-bucketing batched SVD server.

    Queued requests are grouped by ``SVDRequest.key()``; ``step`` flushes the
    fullest bucket as ONE batched pipeline call, padded to the bucket capacity
    (``PipelineConfig.max_batch``) so every key compiles exactly once.  Results
    are numerically identical to a direct ``svd_batched`` call on the same
    stack — padding rows are independent problems and are sliced off.

    >>> eng = SVDEngine(PipelineConfig.resolve(bw=8, dtype=np.float64))
    >>> eng.submit(SVDRequest(uid=0, matrix=a, bw=8))
    >>> done = eng.run()

    ``autotune=True`` resolves each bucket's pipeline against the
    persistent tuned-config cache (DESIGN.md §11): the first flush of a
    bucket key looks up the measured optimum for that exact ``(device, n,
    bw, dtype, compute_uv, backend)``.  Precedence is explicit: opting in
    means a cache HIT overrides the engine config's ``tw``/``fuse`` for
    that bucket — per-bucket measured optima are the point of the flag,
    and the engine config's knobs were resolved for its own default
    shape, not this bucket's; on a MISS the engine's own config stays in
    charge (it is never silently swapped for the analytic defaults).  Pin
    knobs for every bucket by keeping ``autotune=False`` (the default).
    The resolved config is memoized per key (one lookup — and one jit
    compilation — per bucket, ever).  The engine-level ``max_batch``
    stays a hard CAP either way.

    ``mesh`` (a ``jax.sharding.Mesh`` with a ``"data"`` axis, e.g. from
    ``repro.launch.mesh.serve_mesh()``) switches every batched dispatch to
    the multi-device path: the padded bucket is batch-sharded through
    ``core.distributed.sharded_pipeline_dispatch`` so one engine saturates
    all local devices (DESIGN.md §12).  ``metrics`` (a
    :class:`~repro.serve.metrics.ServeMetrics`) counts queue depth,
    batch-fill ratio, and bucket hit-rate.

    ``fused_n_max`` governs the one-dispatch fused small-n tier
    (DESIGN.md §13): buckets with ``n <= fused_n_max`` resolve with
    ``backend="fused_small"`` — the whole per-matrix pipeline as a single
    kernel dispatch — and everything larger stays on the staged pipeline.
    ``None`` (the default) uses the tuned crossover from the cache when
    ``autotune=True``, else ``tuning.DEFAULT_FUSED_CROSSOVER``; ``0``
    disables the tier; an int pins it.  Per-bucket routing is visible in
    ``metrics.snapshot()["bucket_tiers"]`` and the dispatch counters in
    ``["tiers"]`` — the serve smoke gate asserts on both.

    ``dc_n_min`` is the same idea at the other end of the size axis
    (DESIGN.md §14): staged buckets with ``n >= dc_n_min`` resolve with
    the divide-and-conquer stage 3 (``stage3="dc"``) instead of the
    O(n^2-iteration) Sturm bisection, so the bidiagonal solve stops
    dominating large-n serve latency.  ``None`` (the default) uses the
    measured crossover persisted by ``python -m repro.autotune
    --stage3-crossover`` when ``autotune=True``, else
    ``core.bidiag_dc.DEFAULT_DC_N_MIN``; ``0`` disables the D&C tier
    (every bucket bisects); an int >= 1 pins the crossover.  Routing shows
    up as the ``"staged-dc"`` tier in the same metrics surfaces.

    **Fault tolerance (DESIGN.md §15).**  Every dispatched result passes
    the numerical-health guard (``core.svd.validate_sigma`` + vector
    finiteness; ``residual_check=True`` adds the per-batch residual
    spot-check for ``compute_uv`` buckets) — a NaN-producing chase raises
    ``NumericalFault`` instead of returning garbage.  A failed dispatch
    enters the ``retry`` ladder (:class:`~repro.serve.faults.RetryPolicy`:
    bounded attempts, capped exponential backoff, deadline-aware — a
    backoff that would outlive the request's deadline is never slept);
    exhausted requests are re-served on the DEGRADED tier — the bucket's
    shape on the trusted ``ref`` backend with the bisection stage 3 —
    attributed as ``"degraded-ref"`` in the metrics.  Repeated
    primary-path failures trip the bucket's circuit breaker
    (:class:`~repro.serve.faults.BucketQuarantine`): an OPEN bucket routes
    straight to the degraded tier until the cooldown elapses, then one
    HALF-OPEN primary trial decides recovery.  ``faults`` (a
    :class:`~repro.serve.faults.FaultPlan`) injects deterministic
    failures into the primary path for testing; the degraded tier is
    never injected.
    """

    def __init__(self, config=None, *, backend: str = "auto",
                 max_batch: int | None = None, autotune: bool = False,
                 autotune_cache: str | None = None, mesh=None,
                 fused_n_max: int | None = None,
                 dc_n_min: int | None = None,
                 faults=None, retry: RetryPolicy | None = None,
                 residual_check: bool = False, tracer=None):
        from repro.core import tuning
        if config is None:
            config = tuning.PipelineConfig.resolve(backend=backend)
        if max_batch is not None:
            config = dataclasses.replace(config, max_batch=max_batch)
        self.config = config
        self.autotune = autotune
        self.autotune_cache = autotune_cache
        self.fused_n_max = fused_n_max           # fused-tier crossover, §13
        self.dc_n_min = dc_n_min                 # stage-3 D&C crossover, §14
        self.mesh = mesh                         # multi-device dispatch, §12
        self.faults = faults                     # fault injection hook, §15
        self.retry = retry if retry is not None else RetryPolicy()
        self.residual_check = bool(residual_check)
        self.quarantine = BucketQuarantine(
            threshold=self.retry.quarantine_threshold,
            cooldown_s=self.retry.quarantine_cooldown_s)
        self.buckets: dict[tuple, list[SVDRequest]] = {}
        self.finished: list[SVDRequest] = []
        self.calls = 0                           # batched pipeline invocations
        self.metrics = ServeMetrics()
        self.tracer = tracer                     # obs.Tracer or None, §16
        self._cfg_memo: dict[tuple, object] = {}  # bucket key -> resolved cfg
        self._degraded_memo: dict[tuple, object] = {}  # key -> ref-tier cfg

    def _resolve_tracer(self):
        return self.tracer if self.tracer is not None else obs.current()

    def _span(self, name: str, **attrs):
        """A span on the engine's tracer (explicit or ambient) — the
        shared no-op span when neither exists (DESIGN.md §16)."""
        tr = self._resolve_tracer()
        if tr is None:
            return obs.span(name, **attrs)       # -> null span
        return tr.span(name, **attrs)

    def submit(self, req: SVDRequest) -> None:
        assert req.matrix.ndim == 2 and req.matrix.shape[0] == req.matrix.shape[1]
        if req.arrived is None:
            req.arrived = time.monotonic()       # queue-age/latency clock, §16
        key = req.key()
        self.metrics.add(submitted=1,
                         bucket_hits=int(key in self._cfg_memo
                                         or key in self.buckets))
        self.buckets.setdefault(key, []).append(req)
        self.metrics.set_queue_depth(self.pending())

    def pending(self) -> int:
        return sum(len(v) for v in self.buckets.values())

    def _fused_n_max_for(self, key: tuple) -> int:
        """The fused-tier crossover governing this bucket (DESIGN.md §13).

        Precedence: an explicit engine ``fused_n_max`` pins it (0 disables
        the tier entirely); otherwise ``autotune=True`` consults the
        MEASURED crossover persisted by ``python -m repro.autotune
        --fused-crossover`` (bw-specific entry first, then the device-wide
        one); otherwise the static default
        ``tuning.DEFAULT_FUSED_CROSSOVER`` — the paper's small-n regime.
        """
        if self.fused_n_max is not None:
            return int(self.fused_n_max)
        _n, bw, dtype, _banded, compute_uv = key
        if self.autotune:
            from repro.autotune import cache as at_cache
            from repro.autotune import model as at_model
            tuned = at_cache.lookup_crossover(
                device_kind=at_model.device_kind(),
                dtype=np.dtype(dtype).name, compute_uv=compute_uv, bw=bw,
                path=self.autotune_cache)
            if tuned is not None:
                return tuned
        from repro.core import tuning
        return tuning.DEFAULT_FUSED_CROSSOVER

    def _dc_n_min_for(self, key: tuple) -> int:
        """The stage-3 D&C crossover governing this bucket (DESIGN.md §14).

        Precedence mirrors ``_fused_n_max_for``: an explicit engine
        ``dc_n_min`` pins it (0 disables the D&C tier); otherwise
        ``autotune=True`` consults the MEASURED crossover persisted by
        ``python -m repro.autotune --stage3-crossover``; otherwise the
        static default ``core.bidiag_dc.DEFAULT_DC_N_MIN``.
        """
        if self.dc_n_min is not None:
            return int(self.dc_n_min)
        _n, _bw, dtype, _banded, compute_uv = key
        if self.autotune:
            from repro.autotune import cache as at_cache
            from repro.autotune import model as at_model
            tuned = at_cache.lookup_stage3(
                device_kind=at_model.device_kind(),
                dtype=np.dtype(dtype).name, compute_uv=compute_uv,
                path=self.autotune_cache)
            if tuned is not None:
                return tuned
        from repro.core import bidiag_dc
        return bidiag_dc.DEFAULT_DC_N_MIN

    def _cfg_for(self, key: tuple):
        from repro.core import tuning
        if key in self._cfg_memo:
            return self._cfg_memo[key]
        n, bw, dtype, _banded, compute_uv = key
        entry = None
        if self.autotune:
            from repro.autotune import cache as at_cache
            from repro.autotune import model as at_model
            entry = at_cache.lookup(
                device_kind=at_model.device_kind(), n=n, bw=bw,
                dtype=np.dtype(dtype).name, compute_uv=compute_uv,
                backend=self.config.backend, path=self.autotune_cache)
        if entry is not None:
            # Tuned bucket: the measured optimum decides tw/fuse (and
            # max_batch when the search explored the batch axis — absent
            # otherwise, leaving the Eq.-1 default in charge).  The engine
            # max_batch remains a cap.
            eff = min(self.config.max_batch,
                      entry.get("max_batch")
                      or tuning.default_bucket_batch(n, bw))
            tw, fuse = entry["tw"], entry["fuse"]
        else:
            # Cache miss (or autotune off): the engine's own resolved
            # config stays in charge — an explicitly-configured tw/fuse is
            # never silently discarded.  The engine's max_batch is a CAP;
            # per bucket it is tightened by the Eq.-1 occupancy default so
            # large matrices (whose own wavefront already saturates the
            # chip) are not zero-padded 8x for nothing.
            eff = min(self.config.max_batch,
                      tuning.default_bucket_batch(n, bw))
            tw, fuse = self.config.tw, self.config.fuse

        # Stage-3 policy (§14): "auto" + the bucket's crossover collapses to
        # a concrete solver inside resolve (n is known here); dc_n_min < 1
        # means "D&C disabled" — pin bisection outright.
        dmin = self._dc_n_min_for(key)
        stage3 = "bisect" if dmin < 1 else "auto"

        def resolve(backend: str):
            return tuning.PipelineConfig.resolve(
                bw=bw, tw=tw, backend=backend,
                interpret=self.config.interpret, dtype=np.dtype(dtype), n=n,
                max_batch=max(1, eff), unroll=self.config.unroll,
                compute_uv=compute_uv, fuse=fuse, stage3=stage3,
                dc_leaf_n=self.config.dc_leaf_n, dc_n_min=max(dmin, 1))

        cfg = None
        if n <= self._fused_n_max_for(key):
            # Fused small-n tier (DESIGN.md §13): the whole per-matrix
            # pipeline as one dispatch.  A VMEM-infeasible n falls back to
            # the staged pipeline instead of failing the bucket.
            try:
                cfg = resolve("fused_small")
            except ValueError:
                cfg = None
        if cfg is None:
            cfg = resolve(self.config.backend)
        self.metrics.set_bucket_tier(key, self._tier_of(cfg, n), n=n,
                                     backend=cfg.backend)
        self._cfg_memo[key] = cfg
        return cfg

    @staticmethod
    def _tier_of(cfg, n: int) -> str:
        """Metrics attribution label for a resolved bucket config:
        "fused" (§13 one-dispatch tier), "staged-dc" (staged pipeline with
        the §14 D&C stage 3), or "staged" (bisection stage 3)."""
        if cfg.backend == "fused_small":
            return "fused"
        return "staged-dc" if cfg.stage3_for(n) == "dc" else "staged"

    def _pop(self, key: tuple, cap: int) -> list[SVDRequest]:
        """Dequeue up to ``cap`` requests of one bucket, submission order."""
        reqs = self.buckets[key][:cap]
        self.buckets[key] = self.buckets[key][cap:]
        if not self.buckets[key]:
            del self.buckets[key]
        self.metrics.set_queue_depth(self.pending())
        return reqs

    def _finish(self, req: SVDRequest, error: Exception | None = None, *,
                tier: str | None = None) -> None:
        """Complete one request exactly once: results already on it, or
        ``error``; resolve its future (async callers) either way.

        Deadline semantics are re-checked HERE, not only at admission: a
        request admitted in time but completed after its deadline is a
        timeout to the caller (nobody is waiting anymore) and counts in
        ``timed_out`` — its results stay on the request object for
        observability (the future resolves with :class:`TimeoutError`,
        ``req.sigma`` keeps the late answer).

        Successful completions feed the per-tier and per-bucket latency
        histograms (DESIGN.md §16) with the CLIENT-view latency
        (``submit`` -> completion); ``tier`` attributes it (falling back
        to the bucket's resolved tier when the caller doesn't know)."""
        if (error is None and req.deadline is not None
                and time.monotonic() > req.deadline):
            error = TimeoutError(
                f"request {req.uid} completed after its deadline "
                f"({time.monotonic() - req.deadline:.3f}s late); late "
                f"results remain on the request")
        req.error = error
        req.done = True
        self.finished.append(req)
        if error is None:
            self.metrics.add(completed=1)
            if req.arrived is not None:
                key = req.key()
                self.metrics.observe_latency(
                    tier or self.metrics.tier_of_bucket(key), key,
                    time.monotonic() - req.arrived)
        elif isinstance(error, TimeoutError):
            self.metrics.add(timed_out=1)        # serving failure, not pipeline
        else:
            self.metrics.add(failed=1)
        if req.future is not None:
            try:
                if error is not None:
                    req.future.set_exception(error)
                else:
                    req.future.set_result(req)
            except Exception:                    # noqa: BLE001 — caller
                pass                             # cancelled; result stays on req

    def _pipeline_call(self, key: tuple, cfg, mats: list[np.ndarray], *,
                       tier: str | None = None, inject: bool = True):
        """ONE batched pipeline dispatch for ``mats`` (padded to the bucket
        capacity): returns np ``(sigma, u, vt)`` sliced to ``len(mats)``
        (``u``/``vt`` None for values-only buckets).  Routes through the
        mesh (``core.distributed``) when the engine owns one.

        Fault-tolerance plumbing (DESIGN.md §15): when the engine owns a
        :class:`~repro.serve.faults.FaultPlan` and ``inject`` is True
        (primary path only — degraded dispatches pass ``inject=False``),
        the plan may delay/raise before dispatch and corrupt the sigma
        block after it.  Every result — injected or not — then passes the
        numerical-health guard, raising ``NumericalFault`` on garbage."""
        tr = self._resolve_tracer()
        if tr is None:
            return self._pipeline_call_inner(key, cfg, mats, tier=tier,
                                             inject=inject)
        # Dispatch span (DESIGN.md §16): activating the tracer lets the
        # pipeline's own stage spans nest under this one — the engine
        # needs no per-call trace= plumbing into core.
        with obs.activated(tr), tr.span(
                "serve/dispatch", bucket=bucket_key_str(key),
                tier=tier or self._tier_of(cfg, key[0]), n=key[0],
                batch=len(mats), backend=cfg.backend, inject=inject):
            return self._pipeline_call_inner(key, cfg, mats, tier=tier,
                                             inject=inject)

    def _pipeline_call_inner(self, key: tuple, cfg, mats: list[np.ndarray],
                             *, tier: str | None = None, inject: bool = True):
        from repro.core import svd as svdmod
        n, _bw, dtype, banded, compute_uv = key
        faults = self.faults if inject else None
        if faults is not None:
            faults.before_dispatch(key)          # may sleep and/or raise
        batch = np.zeros((cfg.max_batch, n, n), dtype)       # pad: zero matrices
        for i, m in enumerate(mats):
            batch[i] = m
        stacked = jnp.asarray(batch)
        if stacked.dtype != np.dtype(dtype):
            # jax_enable_x64 is off: fp64 requests are silently downcast by
            # jnp.asarray — serve at the effective precision instead of
            # tripping the config/input dtype-conflict check.
            cfg = dataclasses.replace(cfg, dtype=jnp.dtype(stacked.dtype).name)
        u = vt = None
        if self.mesh is not None:
            from repro.core import distributed
            out = distributed.sharded_pipeline_dispatch(
                stacked, self.mesh, config=cfg, banded=banded,
                compute_uv=compute_uv, faults=faults,
                on_shard_retry=lambda k_: self.metrics.add(sharded_retries=k_))
            if compute_uv:
                u, sig, vt = out
            else:
                sig = out
            self.metrics.add(sharded_batches=1)
        elif compute_uv:
            fn = svdmod.banded_svd if banded else svdmod.svd
            u, sig, vt = fn(stacked, config=cfg, compute_uv=True)
        elif banded:
            sig = svdmod.banded_singular_values(stacked, bw=cfg.bw, config=cfg)
        else:
            sig = svdmod.svd_batched(stacked, config=cfg)
        self.calls += 1
        self.metrics.add(batches=1, served_slots=len(mats),
                         padded_slots=cfg.max_batch - len(mats))
        self.metrics.add_tier(
            tier or self._tier_of(cfg, n), batches=1, served_slots=len(mats),
            padded_slots=cfg.max_batch - len(mats))
        k = len(mats)
        sig = np.asarray(sig)[:k]
        if compute_uv:
            u, vt = np.asarray(u)[:k], np.asarray(vt)[:k]
        if faults is not None:
            sig = faults.corrupt_sigma(sig)
        # Numerical-health guard (§15): a NaN/Inf/garbage sigma must raise
        # NumericalFault here — never reach a caller as a silent answer.
        svdmod.validate_sigma(sig)
        if compute_uv:
            svdmod.validate_uv(u, vt)
            if self.residual_check:
                svdmod.spot_check_svd(batch[:k], u, sig, vt)
        return sig, u, vt

    # ------------------------------------------------------------------
    # fault-tolerant dispatch (DESIGN.md §15)
    # ------------------------------------------------------------------

    def _degraded_cfg(self, key: tuple):
        """The degraded-tier config for a bucket: same shapes, trusted
        ``ref`` backend, bisection stage 3 (the oracle solver).  Memoized
        per key — one resolution and one compile ever, like the primary."""
        from repro.core import tuning
        if key not in self._degraded_memo:
            n, bw, dtype, _banded, compute_uv = key
            self._degraded_memo[key] = tuning.PipelineConfig.resolve(
                bw=bw, backend="ref", dtype=np.dtype(dtype), n=n,
                max_batch=self.config.max_batch, unroll=self.config.unroll,
                compute_uv=compute_uv, stage3="bisect")
        return self._degraded_memo[key]

    def _note_failure(self, key: tuple, exc: Exception) -> None:
        """Record one primary-path failure: last-error attribution plus
        the circuit breaker's consecutive-failure count."""
        self.metrics.set_bucket_error(key, exc)
        if self.quarantine.record_failure(key):
            self.metrics.add(quarantined=1)
            self.metrics.set_bucket_quarantined(key, True)

    def _note_success(self, key: tuple) -> None:
        if self.quarantine.record_success(key):
            self.metrics.set_bucket_quarantined(key, False)

    def _deliver(self, key: tuple, reqs: list[SVDRequest], sig, u, vt,
                 tier: str | None = None) -> None:
        """Copy one dispatch's results onto its requests and complete them
        in submission (FIFO) order."""
        _n, _bw, _dtype, _banded, compute_uv = key
        for i, r in enumerate(reqs):
            r.sigma = sig[i]
            if compute_uv:
                r.u, r.vt = u[i], vt[i]
            self._finish(r, tier=tier)

    def _serve_degraded(self, key: tuple, reqs: list[SVDRequest],
                        cause: Exception | None) -> int:
        """Serve ``reqs`` on the degraded ref tier (quarantined bucket, or
        a request whose primary-path retries are exhausted).  The degraded
        dispatch is never fault-injected and still passes the numerical
        guard; if even the ref tier fails, the request finally surfaces
        ``cause`` (the primary-path error — more actionable than the
        fallback's own)."""
        with self._span("serve/degraded", bucket=bucket_key_str(key),
                        batch=len(reqs),
                        cause=repr(cause) if cause is not None else None):
            try:
                dcfg = self._degraded_cfg(key)
                sig, u, vt = self._pipeline_call(key, dcfg,
                                                 [r.matrix for r in reqs],
                                                 tier="degraded-ref",
                                                 inject=False)
            except Exception as exc:             # noqa: BLE001 — last resort
                for r in reqs:
                    self._finish(r, error=cause if cause is not None else exc)
                return len(reqs)
            self.metrics.add(degraded=len(reqs))
            self._deliver(key, reqs, sig, u, vt, tier="degraded-ref")
            return len(reqs)

    def _retry_request(self, key: tuple, cfg, req: SVDRequest,
                       exc: Exception) -> int:
        """The per-request retry ladder (DESIGN.md §15): after a failed
        primary attempt, retry with capped exponential backoff up to the
        policy's attempt bound (tighter for ``NumericalFault``), never
        sleeping past the request's deadline; on exhaustion fall through
        to the degraded ref tier."""
        policy = self.retry
        failures = 1
        self._note_failure(key, exc)
        while failures < policy.attempts_for(exc):
            delay = policy.backoff_for(failures, deadline=req.deadline,
                                       now=time.monotonic())
            if delay is None:                    # would sleep past deadline
                break
            if delay > 0:
                time.sleep(delay)
            if self.quarantine.active(key):      # tripped meanwhile
                break
            self.metrics.add(retried=1)
            try:
                with self._span("serve/retry", bucket=bucket_key_str(key),
                                attempt=failures, backoff_s=delay):
                    sig, u, vt = self._pipeline_call(key, cfg, [req.matrix])
            except Exception as exc2:            # noqa: BLE001 — ladder
                exc = exc2
                failures += 1
                self._note_failure(key, exc)
                continue
            self._note_success(key)
            self._deliver(key, [req], sig, u, vt)
            return 1
        return self._serve_degraded(key, [req], cause=exc)

    def _serve_batch(self, key: tuple, cfg, reqs: list[SVDRequest]) -> int:
        """Serve one dequeued batch; every request in ``reqs`` COMPLETES, in
        submission (FIFO) order — a failure is surfaced on the request
        (``req.error``) rather than raised out of the step.  A batch-level
        failure falls back to per-request dispatches (isolating poison
        requests), each of which enters the retry/backoff/degrade ladder
        (§15); a quarantined bucket skips the primary path entirely."""
        if self.quarantine.active(key):
            return self._serve_degraded(key, reqs, cause=None)
        try:
            sig, u, vt = self._pipeline_call(key, cfg,
                                             [r.matrix for r in reqs])
        except Exception as exc:                 # noqa: BLE001 — isolate below
            if len(reqs) == 1:
                return self._retry_request(key, cfg, reqs[0], exc)
            for r in reqs:                       # FIFO order preserved
                self._serve_batch(key, cfg, [r])
            return len(reqs)
        self._note_success(key)
        self._deliver(key, reqs, sig, u, vt)
        return len(reqs)

    def step(self) -> int:
        """Flush the fullest bucket with one batched call; #requests served.

        An empty engine is a no-op (returns 0, no dispatch).  Oversize
        buckets split at the bucket capacity: each step serves at most
        ``max_batch`` requests and leaves the tail queued, FIFO."""
        if not self.buckets:
            return 0
        key = max(self.buckets, key=lambda k: len(self.buckets[k]))
        try:
            cfg = self._cfg_for(key)
        except Exception as exc:                 # noqa: BLE001
            # The whole bucket shares the un-resolvable key (e.g. a
            # VMEM-infeasible (bw, tw)): fail its requests, keep serving
            # the other buckets.
            for r in self._pop(key, len(self.buckets[key])):
                self._finish(r, error=exc)
            return 0
        reqs = self._pop(key, cfg.max_batch)
        # Queue age is observed exactly once per request, here at dispatch
        # (the per-request fallback inside _serve_batch re-enters with the
        # same requests and must not re-observe).
        now = time.monotonic()
        for r in reqs:
            if r.arrived is not None:
                self.metrics.observe_queue_age(now - r.arrived)
        return self._serve_batch(key, cfg, reqs)

    def run(self, max_rounds: int = 10_000) -> list[SVDRequest]:
        rounds = 0
        while self.buckets and rounds < max_rounds:
            self.step()
            rounds += 1
        return self.finished
