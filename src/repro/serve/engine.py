"""Batched serving engine: continuous batching with static shapes.

Requests queue up; up to ``max_batch`` live in fixed KV-cache slots with
*per-slot positions* (decode_step takes a (b,) position vector).  Every round
issues ONE batched decode step: prefilling slots feed their next prompt token,
generating slots feed their last sampled token, finished slots are refilled
from the queue.  This is the static-shape (TPU-friendly) formulation of
continuous batching — no recompilation as requests come and go.

Greedy sampling; the padded-vocab tail is masked at sample time.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Request", "ServeConfig", "Engine"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    frames: np.ndarray | None = None          # enc-dec (whisper) stub input
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 4
    max_seq: int = 128
    eos_id: int = -1                          # -1: never stop early


class _Slot:
    __slots__ = ("req", "pos", "k", "next_tok")

    def __init__(self, req):
        self.req = req
        self.pos = 0                          # next cache position to write
        self.k = 0                            # prompt cursor
        self.next_tok = req.prompt[0]


class Engine:
    def __init__(self, model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._decode = jax.jit(model.decode_step)
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self.slots: list[_Slot | None] = [None] * cfg.max_batch
        self.caches = model.init_caches(cfg.max_batch, cfg.max_seq)
        self._is_encdec = model.cfg.kind == "encdec"
        if self._is_encdec:
            d = model.cfg.d_model
            self._frames = np.zeros((cfg.max_batch, model.cfg.enc_seq, d),
                                    np.float32)

    def submit(self, req: Request):
        assert len(req.prompt) >= 1
        self.queue.append(req)

    def _admit(self):
        refreshed = False
        for i in range(self.cfg.max_batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = _Slot(req)
                if self._is_encdec:
                    fr = req.frames if req.frames is not None else 0.0
                    self._frames[i] = fr
                    refreshed = True
        if refreshed:
            from repro.models.encdec import fill_cross_cache
            self.caches = fill_cross_cache(
                self.params, self.model.cfg, jnp.asarray(self._frames),
                self.caches)

    def step(self) -> int:
        """One batched decode round.  Returns number of active slots."""
        self._admit()
        act = [i for i, s in enumerate(self.slots) if s is not None]
        if not act:
            return 0
        b = self.cfg.max_batch
        toks = np.zeros((b, 1), np.int32)
        pos = np.zeros((b,), np.int32)
        for i in act:
            s = self.slots[i]
            toks[i, 0] = s.next_tok
            pos[i] = s.pos
        logits, self.caches = self._decode(
            self.params, jnp.asarray(toks), self.caches, jnp.asarray(pos))
        v = self.model.cfg.vocab
        nxt = np.asarray(jnp.argmax(logits[:, 0, :v], axis=-1))
        for i in act:
            s = self.slots[i]
            s.pos += 1
            s.k += 1
            if s.k < len(s.req.prompt):           # still prefilling
                s.next_tok = int(s.req.prompt[s.k])
                continue
            tok = int(nxt[i])
            s.req.output.append(tok)
            s.next_tok = tok
            if (tok == self.cfg.eos_id
                    or len(s.req.output) >= s.req.max_new_tokens
                    or s.pos >= self.cfg.max_seq - 1):
                s.req.done = True
                self.finished.append(s.req)
                self.slots[i] = None
        return len(act)

    def run(self, max_rounds: int = 10_000) -> list[Request]:
        rounds = 0
        while (self.queue or any(self.slots)) and rounds < max_rounds:
            self.step()
            rounds += 1
        return self.finished
