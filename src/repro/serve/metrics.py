"""Per-engine serving metrics (DESIGN.md §12 glossary).

One :class:`ServeMetrics` instance lives on every SVD engine (sync and
async).  Counters are plain monotonic totals guarded by one lock — cheap
enough to update on every submit/dispatch, and a consistent ``snapshot()``
is what the load generator (``benchmarks/serve_load.py``), the serve smoke
CI step, and operators read.

Glossary (all derivable from the raw counters, but pre-computed in the
snapshot because every consumer wants them):

* ``queue_depth``      — requests admitted but not yet dispatched (gauge).
* ``batch_fill_ratio`` — served requests / dispatched slots: 1.0 means
  every batched call was full, low values mean the bucket capacity (or the
  micro-batch window) is mis-sized and padding rows dominate.
* ``bucket_hit_rate``  — submits that landed in an already-resolved bucket
  key / total submits: the fraction of traffic that paid ZERO config
  resolution or jit compilation (each bucket key compiles exactly once).

Backend attribution (DESIGN.md §13/§14): dispatches are ALSO tallied per
execution tier — ``"fused"`` (the one-dispatch fused_small backend),
``"staged"`` (the three-stage pipeline with the bisection stage 3), or
``"staged-dc"`` (staged with the divide-and-conquer stage 3 for large-n
buckets) — via :meth:`add_tier`, and every
bucket records which tier its resolved config routed it to
(:meth:`set_bucket_tier`).  The snapshot exposes both: ``"tiers"`` holds
per-tier batches/served_slots/padded_slots (+ fill ratio), and
``"bucket_tiers"`` maps the bucket key to ``{"tier", "n", "backend"}`` —
sliceable proof of WHERE each size class actually ran, which the serve
smoke gate asserts on.
"""

from __future__ import annotations

import threading

__all__ = ["ServeMetrics"]


class ServeMetrics:
    """Thread-safe monotonic counters + gauges for one serving engine."""

    _COUNTERS = (
        "submitted",          # requests accepted into a bucket
        "completed",          # requests finished with a result
        "failed",             # requests finished with req.error set
        "timed_out",          # requests dropped at dispatch: deadline passed
        "rejected",           # requests refused at admission (queue full)
        "batches",            # batched pipeline dispatches
        "sharded_batches",    # dispatches that went through the mesh path
        "served_slots",       # sum of len(reqs) over dispatches
        "padded_slots",       # sum of (capacity - len(reqs)) over dispatches
        "bucket_hits",        # submits into an already-seen bucket key
    )

    # per-tier slice of the dispatch counters ("fused" vs "staged")
    _TIER_COUNTERS = ("batches", "served_slots", "padded_slots")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        for name in self._COUNTERS:
            setattr(self, name, 0)
        self.queue_depth = 0                  # gauge, set by the engine
        self._tiers: dict[str, dict[str, int]] = {}
        self._bucket_tiers: dict[str, dict] = {}

    def add(self, **deltas: int) -> None:
        """Atomically bump counters: ``metrics.add(submitted=1, ...)``."""
        with self._lock:
            for name, delta in deltas.items():
                assert name in self._COUNTERS, name
                setattr(self, name, getattr(self, name) + int(delta))

    def add_tier(self, tier: str, **deltas: int) -> None:
        """Bump the per-tier dispatch slice: ``add_tier("fused", batches=1,
        served_slots=3, padded_slots=1)``.  Tiers are created on first use
        so a fused-disabled engine reports no empty "fused" row."""
        with self._lock:
            row = self._tiers.setdefault(
                tier, {name: 0 for name in self._TIER_COUNTERS})
            for name, delta in deltas.items():
                assert name in self._TIER_COUNTERS, name
                row[name] += int(delta)

    def set_bucket_tier(self, key, tier: str, *, n: int,
                        backend: str) -> None:
        """Record which tier a bucket's resolved config routed it to.

        Keyed by ``str(key)`` (bucket keys are tuples; snapshots must stay
        JSON-serializable).  Idempotent per bucket — the engine calls this
        once at config-resolution time."""
        with self._lock:
            self._bucket_tiers[str(key)] = {"tier": tier, "n": int(n),
                                            "backend": backend}

    def set_queue_depth(self, depth: int) -> None:
        with self._lock:
            self.queue_depth = int(depth)

    def snapshot(self) -> dict:
        """Consistent point-in-time view: raw counters + derived ratios."""
        with self._lock:
            snap = {name: getattr(self, name) for name in self._COUNTERS}
            snap["queue_depth"] = self.queue_depth
            tiers = {t: dict(row) for t, row in self._tiers.items()}
            snap["bucket_tiers"] = {k: dict(v)
                                    for k, v in self._bucket_tiers.items()}
        slots = snap["served_slots"] + snap["padded_slots"]
        snap["batch_fill_ratio"] = (snap["served_slots"] / slots
                                    if slots else 0.0)
        snap["bucket_hit_rate"] = (snap["bucket_hits"] / snap["submitted"]
                                   if snap["submitted"] else 0.0)
        for row in tiers.values():
            tslots = row["served_slots"] + row["padded_slots"]
            row["batch_fill_ratio"] = (row["served_slots"] / tslots
                                       if tslots else 0.0)
        snap["tiers"] = tiers
        return snap

    def __repr__(self) -> str:
        snap = self.snapshot()
        body = ", ".join(f"{k}={v:.3g}" if isinstance(v, float)
                         else f"{k}={v}" for k, v in sorted(snap.items()))
        return f"ServeMetrics({body})"
