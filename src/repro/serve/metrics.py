"""Per-engine serving metrics (DESIGN.md §12 glossary).

One :class:`ServeMetrics` instance lives on every SVD engine (sync and
async).  Counters are plain monotonic totals guarded by one lock — cheap
enough to update on every submit/dispatch, and a consistent ``snapshot()``
is what the load generator (``benchmarks/serve_load.py``), the serve smoke
CI step, and operators read.

Glossary (all derivable from the raw counters, but pre-computed in the
snapshot because every consumer wants them):

* ``queue_depth``      — requests admitted but not yet dispatched (gauge).
* ``batch_fill_ratio`` — served requests / dispatched slots: 1.0 means
  every batched call was full, low values mean the bucket capacity (or the
  micro-batch window) is mis-sized and padding rows dominate.
* ``bucket_hit_rate``  — submits that landed in an already-resolved bucket
  key / total submits: the fraction of traffic that paid ZERO config
  resolution or jit compilation (each bucket key compiles exactly once).

Backend attribution (DESIGN.md §13/§14): dispatches are ALSO tallied per
execution tier — ``"fused"`` (the one-dispatch fused_small backend),
``"staged"`` (the three-stage pipeline with the bisection stage 3),
``"staged-dc"`` (staged with the divide-and-conquer stage 3 for large-n
buckets), or ``"degraded-ref"`` (the §15 fault-tolerance fallback) — via
:meth:`add_tier`, and every
bucket records which tier its resolved config routed it to
(:meth:`set_bucket_tier`).  The snapshot exposes both: ``"tiers"`` holds
per-tier batches/served_slots/padded_slots (+ fill ratio), and
``"bucket_tiers"`` maps the bucket key to ``{"tier", "n", "backend"}`` —
sliceable proof of WHERE each size class actually ran, which the serve
smoke gate asserts on.

Failure taxonomy (DESIGN.md §15): ``retried`` / ``quarantined`` /
``degraded`` / ``sharded_retries`` count the fault-tolerance layer's
interventions, ``set_bucket_error`` keeps the LAST error (+ a running
count) per bucket key, ``set_bucket_quarantined`` tracks which buckets
are circuit-broken right now, and :meth:`health` condenses it all into
the one dict an operator (or ``launch/serve.py --svd``) wants to read.
"""

from __future__ import annotations

import threading

from repro.obs import StreamingHistogram

__all__ = ["ServeMetrics", "bucket_key_str"]


def bucket_key_str(key) -> str:
    """Canonical string form of a bucket key (DESIGN.md §16).

    Engine bucket keys are the 5-tuple ``(n, bw, dtype, banded,
    compute_uv)`` (``SVDRequest.key()``); the historical ``str(key)``
    rendering was fragile (whitespace/quoting of ``repr``) and could
    collide with user-supplied string keys.  Tuples map to the stable
    ``n=..,bw=..,dtype=..,banded=..,uv=..`` form — which no ``str(tuple)``
    can equal — strings pass through unchanged, and anything else falls
    back to ``repr``.  Used by every keyed surface on
    :class:`ServeMetrics` (``bucket_tiers``, ``bucket_errors``,
    quarantine membership, per-bucket latency histograms).
    """
    if isinstance(key, str):
        return key
    if isinstance(key, tuple) and len(key) == 5:
        n, bw, dtype, banded, uv = key
        return (f"n={n},bw={bw},dtype={dtype},"
                f"banded={int(bool(banded))},uv={int(bool(uv))}")
    return repr(key)


class ServeMetrics:
    """Thread-safe monotonic counters + gauges for one serving engine."""

    _COUNTERS = (
        "submitted",          # requests accepted into a bucket
        "completed",          # requests finished with a result
        "failed",             # requests finished with req.error set
        "timed_out",          # requests dropped at dispatch: deadline passed
        "rejected",           # requests refused at admission (queue full)
        "batches",            # batched pipeline dispatches
        "sharded_batches",    # dispatches that went through the mesh path
        "served_slots",       # sum of len(reqs) over dispatches
        "padded_slots",       # sum of (capacity - len(reqs)) over dispatches
        "bucket_hits",        # submits into an already-seen bucket key
        # --- failure taxonomy (DESIGN.md §15) ---
        "retried",            # primary-path retry attempts (backoff ladder)
        "quarantined",        # bucket circuit-breaker trips (not requests)
        "degraded",           # requests served on the degraded ref tier
        "sharded_retries",    # mesh shards re-dispatched after a loss
    )

    # per-tier slice of the dispatch counters ("fused" vs "staged")
    _TIER_COUNTERS = ("batches", "served_slots", "padded_slots")

    # per-host slice for the multi-host router (DESIGN.md §17): where each
    # request was dispatched, where it completed/failed, and which
    # SURVIVING host absorbed a dead host's requeued work
    _HOST_COUNTERS = ("dispatched", "completed", "failed", "requeued")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        for name in self._COUNTERS:
            setattr(self, name, 0)
        self.queue_depth = 0                  # gauge, set by the engine
        self._tiers: dict[str, dict[str, int]] = {}
        self._hosts: dict[str, dict[str, int]] = {}
        self._bucket_tiers: dict[str, dict] = {}
        self._bucket_errors: dict[str, dict] = {}   # key -> last_error+count
        self._quarantined: set[str] = set()         # keys circuit-broken now
        # Latency/queue-age histograms (DESIGN.md §16): fixed-log-bucket,
        # bounded memory — no raw samples are ever buffered here.
        self._tier_lat: dict[str, StreamingHistogram] = {}
        self._bucket_lat: dict[str, StreamingHistogram] = {}
        self._queue_age = StreamingHistogram()

    def add(self, **deltas: int) -> None:
        """Atomically bump counters: ``metrics.add(submitted=1, ...)``."""
        with self._lock:
            for name, delta in deltas.items():
                assert name in self._COUNTERS, name
                setattr(self, name, getattr(self, name) + int(delta))

    def add_tier(self, tier: str, **deltas: int) -> None:
        """Bump the per-tier dispatch slice: ``add_tier("fused", batches=1,
        served_slots=3, padded_slots=1)``.  Tiers are created on first use
        so a fused-disabled engine reports no empty "fused" row."""
        with self._lock:
            row = self._tiers.setdefault(
                tier, {name: 0 for name in self._TIER_COUNTERS})
            for name, delta in deltas.items():
                assert name in self._TIER_COUNTERS, name
                row[name] += int(delta)

    def add_host(self, host: str, **deltas: int) -> None:
        """Bump the per-host attribution slice (router-side, DESIGN.md
        §17): ``add_host("w0", dispatched=1)``.  Hosts are created on
        first use, like tiers."""
        with self._lock:
            row = self._hosts.setdefault(
                str(host), {name: 0 for name in self._HOST_COUNTERS})
            for name, delta in deltas.items():
                assert name in self._HOST_COUNTERS, name
                row[name] += int(delta)

    def set_bucket_tier(self, key, tier: str, *, n: int,
                        backend: str) -> None:
        """Record which tier a bucket's resolved config routed it to.

        Keyed by :func:`bucket_key_str` (snapshots must stay
        JSON-serializable).  Idempotent per bucket — the engine calls this
        once at config-resolution time."""
        with self._lock:
            self._bucket_tiers[bucket_key_str(key)] = {
                "tier": tier, "n": int(n), "backend": backend}

    def set_bucket_error(self, key, exc: BaseException) -> None:
        """Record the latest failure for a bucket key (DESIGN.md §15):
        ``last_error`` is the repr of the most recent exception, ``count``
        the number of recorded failures for that key since engine start."""
        with self._lock:
            row = self._bucket_errors.setdefault(
                bucket_key_str(key), {"last_error": "", "count": 0})
            row["last_error"] = repr(exc)
            row["count"] += 1

    def set_bucket_quarantined(self, key, active: bool) -> None:
        """Track circuit-breaker membership: ``active=True`` when a bucket
        trips OPEN, ``False`` when a primary-path success recovers it."""
        with self._lock:
            if active:
                self._quarantined.add(bucket_key_str(key))
            else:
                self._quarantined.discard(bucket_key_str(key))

    # ------------------------------------------------------------------
    # latency histograms (DESIGN.md §16)

    def tier_of_bucket(self, key) -> str:
        """Resolved tier for a bucket key, or ``"unknown"`` pre-resolution."""
        with self._lock:
            row = self._bucket_tiers.get(bucket_key_str(key))
        return row["tier"] if row else "unknown"

    def observe_latency(self, tier: str, key, seconds: float) -> None:
        """Record one request's client-view latency into the per-tier AND
        per-bucket streaming histograms.  O(1) memory per tier/bucket —
        the engines call this at completion time for every served
        request."""
        kstr = bucket_key_str(key)
        with self._lock:
            th = self._tier_lat.setdefault(tier, StreamingHistogram())
            bh = self._bucket_lat.setdefault(kstr, StreamingHistogram())
        th.add(seconds)
        bh.add(seconds)

    def observe_queue_age(self, seconds: float) -> None:
        """Record a request's age at dispatch (admission -> launch)."""
        self._queue_age.add(seconds)

    def histograms(self) -> dict:
        """Live histogram objects for exposition (``repro.obs.prom``):
        ``{"tiers": {...}, "buckets": {...}, "queue_age": hist}``."""
        with self._lock:
            return {"tiers": dict(self._tier_lat),
                    "buckets": dict(self._bucket_lat),
                    "queue_age": self._queue_age}

    def set_queue_depth(self, depth: int) -> None:
        with self._lock:
            self.queue_depth = int(depth)

    def snapshot(self) -> dict:
        """Consistent point-in-time view: raw counters + derived ratios."""
        with self._lock:
            snap = {name: getattr(self, name) for name in self._COUNTERS}
            snap["queue_depth"] = self.queue_depth
            tiers = {t: dict(row) for t, row in self._tiers.items()}
            snap["bucket_tiers"] = {k: dict(v)
                                    for k, v in self._bucket_tiers.items()}
            snap["bucket_errors"] = {k: dict(v)
                                     for k, v in self._bucket_errors.items()}
            snap["quarantined_buckets"] = sorted(self._quarantined)
            snap["hosts"] = {h: dict(row) for h, row in self._hosts.items()}
            tier_lat = dict(self._tier_lat)
            bucket_lat = dict(self._bucket_lat)
        snap["latency"] = {
            "tiers": {t: h.summary() for t, h in tier_lat.items()},
            "buckets": {k: h.summary() for k, h in bucket_lat.items()},
            "queue_age": self._queue_age.summary(),
        }
        slots = snap["served_slots"] + snap["padded_slots"]
        snap["batch_fill_ratio"] = (snap["served_slots"] / slots
                                    if slots else 0.0)
        snap["bucket_hit_rate"] = (snap["bucket_hits"] / snap["submitted"]
                                   if snap["submitted"] else 0.0)
        for row in tiers.values():
            tslots = row["served_slots"] + row["padded_slots"]
            row["batch_fill_ratio"] = (row["served_slots"] / tslots
                                       if tslots else 0.0)
        snap["tiers"] = tiers
        return snap

    def health(self) -> dict:
        """Operator-facing condensed view of the failure taxonomy
        (DESIGN.md §15).  ``status`` is the headline:

        * ``"ok"``       — no client-visible failures, no open quarantines,
          no degraded traffic (retries may have happened and healed).
        * ``"degraded"`` — everyone is still getting answers, but some
          through the ref fallback tier and/or with buckets circuit-broken.
        * ``"failing"``  — requests have surfaced errors to clients.
        """
        snap = self.snapshot()
        finished = snap["completed"] + snap["failed"] + snap["timed_out"]
        if snap["failed"]:
            status = "failing"
        elif snap["degraded"] or snap["quarantined_buckets"]:
            status = "degraded"
        else:
            status = "ok"
        return {
            "status": status,
            "submitted": snap["submitted"],
            "completed": snap["completed"],
            "client_error_rate": ((snap["failed"] + snap["timed_out"])
                                  / finished if finished else 0.0),
            "retried": snap["retried"],
            "degraded": snap["degraded"],
            "quarantined": snap["quarantined"],
            "sharded_retries": snap["sharded_retries"],
            "timed_out": snap["timed_out"],
            "rejected": snap["rejected"],
            "quarantined_buckets": snap["quarantined_buckets"],
            "bucket_errors": snap["bucket_errors"],
            "latency_p99_ms": {
                t: row.get("p99_ms")
                for t, row in snap["latency"]["tiers"].items()},
        }

    def __repr__(self) -> str:
        snap = self.snapshot()
        body = ", ".join(f"{k}={v:.3g}" if isinstance(v, float)
                         else f"{k}={v}" for k, v in sorted(snap.items()))
        return f"ServeMetrics({body})"
