"""Fault-tolerance primitives for the serve fabric (DESIGN.md §15).

Three pieces, shared by both engines and the sharded dispatch path:

* :class:`FaultPlan` — a deterministic, seedable fault-injection harness.
  Production failure modes (a dispatch that raises, a device that stalls,
  a chase that returns NaN sigma, a mesh shard that drops) are rare and
  hardware-bound; the plan makes every one of them reproducible on a
  laptop.  Engines accept ``faults=`` and consult the plan's hooks around
  every *primary-path* dispatch; ``core.distributed
  .sharded_pipeline_dispatch`` consults :meth:`FaultPlan.lost_shards`.
  Degraded-tier (ref fallback) dispatches are never injected — the
  degraded tier models the known-good path the fabric falls back TO, so
  injecting there would make "graceful degradation" untestable.

* :class:`RetryPolicy` — how failures are absorbed: bounded attempts,
  exponential backoff with a cap, and *deadline-awareness* (a backoff
  sleep that would land past a request's deadline is never taken — the
  request degrades or fails immediately instead of burning its budget
  asleep).  :class:`~repro.core.svd.NumericalFault` gets its own (lower)
  attempt bound: a numerically-poisoned bucket rarely heals on replay,
  so it is retried once and then degraded.

* :class:`BucketQuarantine` — a per-bucket-key circuit breaker.  After
  ``threshold`` consecutive primary-path failures a ``(n, bw, dtype,
  banded, compute_uv)`` bucket is OPEN: its traffic routes straight to
  the degraded ref tier (no primary attempts, no backoff) until
  ``cooldown_s`` elapses; the first primary trial after cooldown
  (HALF-OPEN) either closes the breaker or re-trips it.

Everything here is plain Python (no jax imports at module scope): the
harness must be importable and runnable even where the accelerator stack
is broken — that is the point.
"""

from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

__all__ = ["FaultPlan", "RetryPolicy", "BucketQuarantine",
           "InjectedFault", "InjectedDispatchError", "InjectedDeviceLoss"]


class InjectedFault(RuntimeError):
    """Base marker for every exception raised by a :class:`FaultPlan` —
    lets tests and accounting distinguish injected failures from real
    ones (production code must NOT special-case it: to the retry layer an
    injected fault is indistinguishable from the failure it simulates)."""


class InjectedDispatchError(InjectedFault):
    """Simulated transient dispatch failure (XLA launch error, OOM retry,
    preempted kernel).  Retryable: the next attempt usually succeeds."""


class InjectedDeviceLoss(InjectedFault):
    """Simulated loss of the device under a whole dispatch (unplugged
    accelerator, dead host process).  The retry ladder treats it like any
    other dispatch failure; the sharded path re-dispatches per shard."""


@dataclasses.dataclass
class FaultPlan:
    """Deterministic, seedable fault injection for the serve stack.

    Probabilistic knobs (``*_rate``) draw from one seeded
    ``numpy.random.Generator`` under a lock — the i-th dispatch sees the
    i-th draw, so a given ``(seed, dispatch ordinal)`` always injects the
    same fault.  Scripted knobs (``*_at``) name exact ordinals and fire
    regardless of the rates — use them when a test (or the CI chaos gate)
    must be *guaranteed* to exercise a path at least once.

    Hooks (all thread-safe):

    * :meth:`before_dispatch` — called by engines before every primary
      pipeline dispatch; may sleep (``latency_s``) and may raise
      :class:`InjectedDispatchError` / :class:`InjectedDeviceLoss`.
    * :meth:`corrupt_sigma`   — called on the freshly-computed sigma
      block; may overwrite entries with NaN/Inf (returns a corrupted
      copy; the input is never mutated).
    * :meth:`lost_shards`     — called by ``sharded_pipeline_dispatch``;
      returns the shard indices "lost" under the current dispatch.

    ``max_faults`` bounds the TOTAL number of injections (scripted ones
    included) so a high-rate plan cannot starve a retry ladder forever.
    ``injected`` is a running tally per fault kind for reporting.
    """

    seed: int = 0
    dispatch_error_rate: float = 0.0     # InjectedDispatchError before dispatch
    device_loss_rate: float = 0.0        # InjectedDeviceLoss before dispatch
    nan_rate: float = 0.0                # one sigma entry -> NaN per result
    inf_rate: float = 0.0                # one sigma entry -> Inf per result
    latency_rate: float = 0.0            # sleep latency_s before dispatch
    latency_s: float = 0.0
    shard_loss_rate: float = 0.0         # per-shard loss in sharded dispatch
    host_loss_rate: float = 0.0          # whole-host loss per heartbeat tick
    dispatch_errors_at: tuple = ()       # scripted dispatch ordinals (0-based)
    device_loss_at: tuple = ()
    nan_at: tuple = ()                   # scripted result ordinals
    shard_loss_at: tuple = ()            # scripted sharded-dispatch ordinals
    host_loss_at: tuple = ()             # scripted heartbeat-tick ordinals
    max_faults: int | None = None

    def __post_init__(self) -> None:
        self._lock = threading.Lock()
        self._rng = np.random.default_rng(self.seed)
        self._dispatches = 0             # before_dispatch ordinal
        self._results = 0                # corrupt_sigma ordinal
        self._sharded = 0                # lost_shards ordinal
        self._host_ticks = 0             # lose_host ordinal (heartbeats)
        self.injected: dict[str, int] = {
            "dispatch_error": 0, "device_loss": 0, "nan": 0, "inf": 0,
            "latency": 0, "shard_loss": 0, "host_loss": 0}

    # ------------------------------------------------------------------

    def _budget_left(self) -> bool:
        return (self.max_faults is None
                or sum(self.injected.values()) < self.max_faults)

    def _count(self, kind: str) -> None:
        self.injected[kind] += 1

    def before_dispatch(self, key=None) -> None:
        """May sleep (latency fault) and/or raise an injected dispatch
        exception.  Exactly three uniform draws are consumed per call, so
        the stream stays aligned whatever the configured rates."""
        with self._lock:
            i = self._dispatches
            self._dispatches += 1
            u_lat, u_err, u_loss = self._rng.uniform(size=3)
            if not self._budget_left():
                return
            sleep_s = 0.0
            if self.latency_s > 0 and u_lat < self.latency_rate:
                self._count("latency")
                sleep_s = self.latency_s
            exc = None
            if i in self.device_loss_at or u_loss < self.device_loss_rate:
                self._count("device_loss")
                exc = InjectedDeviceLoss(
                    f"injected device loss at dispatch {i} (key={key})")
            elif i in self.dispatch_errors_at or u_err < self.dispatch_error_rate:
                self._count("dispatch_error")
                exc = InjectedDispatchError(
                    f"injected dispatch error at dispatch {i} (key={key})")
        if sleep_s:
            time.sleep(sleep_s)          # outside the lock
        if exc is not None:
            raise exc

    def corrupt_sigma(self, sig: np.ndarray) -> np.ndarray:
        """Possibly overwrite one entry of ``sig`` with NaN/Inf; returns a
        (corrupted) copy, never mutating the input.  One flat index draw
        plus two uniforms per call, seed-deterministic."""
        sig = np.asarray(sig)
        with self._lock:
            i = self._results
            self._results += 1
            u_nan, u_inf = self._rng.uniform(size=2)
            flat = int(self._rng.integers(max(sig.size, 1)))
            if sig.size == 0 or not self._budget_left():
                return sig
            val = None
            if i in self.nan_at or u_nan < self.nan_rate:
                self._count("nan")
                val = np.nan
            elif u_inf < self.inf_rate:
                self._count("inf")
                val = np.inf
            if val is None:
                return sig
        out = sig.copy()
        out.flat[flat] = val
        return out

    def lost_shards(self, shards: int) -> list[int]:
        """Shard indices lost under the current sharded dispatch (possibly
        empty).  Scripted ordinals lose shard ``ordinal % shards``."""
        with self._lock:
            i = self._sharded
            self._sharded += 1
            draws = self._rng.uniform(size=max(shards, 1))
            if not self._budget_left():
                return []
            lost = [j for j in range(shards)
                    if draws[j] < self.shard_loss_rate]
            if i in self.shard_loss_at and (i % shards) not in lost:
                lost.append(i % shards)
            for _ in lost:
                self._count("shard_loss")
            return sorted(lost)

    def lose_host(self, host_ids) -> str | None:
        """Host id to drop at this heartbeat tick, or ``None``
        (consulted by :class:`~repro.serve.router.SVDRouter` once per
        tick — DESIGN.md §17).  Exactly one uniform plus one integer
        draw per call keeps the stream aligned whatever fires; scripted
        ``host_loss_at`` ordinals index heartbeat TICKS, and the victim
        is chosen by the integer draw over the alive set."""
        host_ids = list(host_ids)
        with self._lock:
            i = self._host_ticks
            self._host_ticks += 1
            u = float(self._rng.uniform())
            j = int(self._rng.integers(max(len(host_ids), 1)))
            if not host_ids or not self._budget_left():
                return None
            if i in self.host_loss_at or u < self.host_loss_rate:
                self._count("host_loss")
                return host_ids[j % len(host_ids)]
            return None

    def snapshot(self) -> dict:
        """Tally of injections so far (for reports and gate assertions)."""
        with self._lock:
            return {"dispatches": self._dispatches, "results": self._results,
                    "sharded": self._sharded,
                    "host_ticks": self._host_ticks, **dict(self.injected)}


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """How the serve fabric absorbs a failed dispatch (DESIGN.md §15).

    ``max_attempts`` bounds TOTAL primary-path attempts per request (the
    first dispatch counts); ``numerical_max_attempts`` is the tighter
    bound applied when the latest failure is a
    :class:`~repro.core.svd.NumericalFault` — retry once, then degrade
    (a poisoned spectrum rarely heals on replay, and the ref tier is the
    trustworthy answer).  Backoff before retry k (k = failures so far) is
    ``backoff_base_s * backoff_factor**(k-1)`` capped at
    ``backoff_max_s`` — and is *deadline-aware*: a sleep that would end
    past the request's deadline is never taken (see :meth:`backoff_for`).

    The quarantine knobs parameterize the per-bucket circuit breaker the
    engine builds from this policy (:class:`BucketQuarantine`).
    """

    max_attempts: int = 3
    numerical_max_attempts: int = 2
    backoff_base_s: float = 0.002
    backoff_factor: float = 2.0
    backoff_max_s: float = 0.100
    quarantine_threshold: int = 3
    quarantine_cooldown_s: float = 30.0

    def attempts_for(self, exc: BaseException) -> int:
        """Attempt bound given the latest failure's type."""
        from repro.core.svd import NumericalFault
        if isinstance(exc, NumericalFault):
            return max(int(self.numerical_max_attempts), 1)
        return max(int(self.max_attempts), 1)

    def backoff_for(self, failures: int, *, deadline: float | None,
                    now: float) -> float | None:
        """Backoff sleep before the next attempt, or ``None`` when no
        further attempt is allowed to sleep: the delay would land at or
        past ``deadline`` (an absolute ``time.monotonic`` instant).
        ``failures`` is the number of failed attempts so far (>= 1)."""
        delay = min(self.backoff_base_s
                    * self.backoff_factor ** max(failures - 1, 0),
                    self.backoff_max_s)
        if deadline is not None and now + delay >= deadline:
            return None
        return max(delay, 0.0)


class BucketQuarantine:
    """Per-bucket-key circuit breaker: CLOSED -> OPEN -> HALF-OPEN.

    ``record_failure`` counts *consecutive* primary-path failures per
    key; at ``threshold`` the key trips OPEN (``active`` -> True) for
    ``cooldown_s``.  While OPEN the engine routes the bucket straight to
    the degraded tier.  After cooldown ``active`` returns False again
    (HALF-OPEN): the next primary trial either closes the breaker
    (``record_success``) or re-trips it for another full cooldown.
    Thread-safe; ``clock`` is injectable for tests.
    """

    def __init__(self, *, threshold: int = 3, cooldown_s: float = 30.0,
                 clock=time.monotonic):
        self.threshold = max(int(threshold), 1)
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self._lock = threading.Lock()
        self._failures: dict = {}        # key -> consecutive failure count
        self._open_at: dict = {}         # key -> trip instant (monotonic)

    def record_failure(self, key) -> bool:
        """One primary-path failure; True iff the key newly tripped OPEN
        (a HALF-OPEN trial failure re-arms the cooldown, not reported as
        a new trip)."""
        with self._lock:
            self._failures[key] = self._failures.get(key, 0) + 1
            if key in self._open_at:                 # HALF-OPEN trial failed
                self._open_at[key] = self._clock()
                return False
            if self._failures[key] >= self.threshold:
                self._open_at[key] = self._clock()
                return True
            return False

    def record_success(self, key) -> bool:
        """One primary-path success; resets the key to CLOSED.  True iff
        the key was OPEN/HALF-OPEN (i.e. this success RECOVERED it)."""
        with self._lock:
            self._failures.pop(key, None)
            return self._open_at.pop(key, None) is not None

    def active(self, key) -> bool:
        """True while the key is OPEN (inside its cooldown window).  After
        cooldown the key is HALF-OPEN: this returns False so ONE primary
        trial flows; the trial's outcome closes or re-trips."""
        with self._lock:
            t = self._open_at.get(key)
            if t is None:
                return False
            return (self._clock() - t) < self.cooldown_s

    def open_keys(self) -> list:
        """Keys currently OPEN or HALF-OPEN (tripped, not yet recovered)."""
        with self._lock:
            return list(self._open_at)
