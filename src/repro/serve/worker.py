"""Worker host for the multi-host serve fabric (DESIGN.md §17).

One :class:`ServeWorker` wraps one :class:`~repro.serve.AsyncSVDEngine`
(today's full single-host fabric: micro-batching, fault ladder,
quarantine, degraded tier) behind a wire connection to the front-end
router (``serve/router.py``).  The worker is the *server of compute* but
the *client of the socket*: it dials the router's listen address, sends
one ``hello``, then answers ``req``/``ping``/``stats``/``stop`` frames
until the connection closes.  A closed connection means the router is
gone — the worker drains nothing (nobody is listening for results) and
exits.

Deliberately NOT coupled to ``jax.distributed``: the fabric's
multi-processness lives at the socket level, so killing one worker can
never cascade through the XLA coordination service and take the
survivors with it (measured: a dead peer under an active
``jax.distributed`` client fatally terminates every other process).
``--coordinator`` opts a worker in to the multi-process JAX bootstrap
(``launch.mesh.init_distributed``) for deployments that want
process-spanning meshes — tested in CI *without* kill chaos.

Three entry points:

* :class:`ServeWorker` — the protocol loop over an existing socket.
* :func:`start_inprocess_worker` — worker on a daemon thread in THIS
  process (tier-1-safe router tests: full wire protocol, no subprocess).
* :func:`spawn_worker_process` / ``python -m repro.serve.worker`` — a
  real worker process (the CI multihost gate and ``serve_load --hosts``).
"""

from __future__ import annotations

import argparse
import os
import socket
import subprocess
import sys
import threading

import numpy as np

from repro.serve.wire import WireClosed, recv_msg, send_msg

__all__ = ["ServeWorker", "start_inprocess_worker", "spawn_worker_process"]


class ServeWorker:
    """Protocol loop: one engine, one router connection.

    ``engine`` defaults to a fresh ``AsyncSVDEngine(**engine_kwargs)``
    built lazily in :meth:`serve_forever` (keeps construction — and the
    jax import — off the caller's thread for in-process workers).
    """

    def __init__(self, sock: socket.socket, *, host_id: str,
                 engine=None, engine_kwargs: dict | None = None):
        self.sock = sock
        self.host_id = str(host_id)
        self.engine = engine
        self.engine_kwargs = dict(engine_kwargs or {})
        self._send_lock = threading.Lock()
        self._pings = 0

    # ------------------------------------------------------------------

    def _send(self, header: dict, arrays=None) -> bool:
        """Send one frame; False (never raises) once the router is gone —
        a result with nobody to deliver it to is not a worker failure."""
        try:
            with self._send_lock:
                send_msg(self.sock, header, arrays)
            return True
        except (OSError, WireClosed):
            return False

    def _hello(self) -> None:
        import jax
        from repro.core.distributed import process_info
        pid_idx, nproc = process_info()
        self._send({"type": "hello", "host_id": self.host_id,
                    "pid": os.getpid(),
                    "devices": len(jax.local_devices()),
                    "global_devices": jax.device_count(),
                    "process_index": pid_idx, "processes": nproc})

    def _on_request(self, header: dict, arrays: dict) -> None:
        from repro.serve.engine import SVDRequest
        rid = int(header["rid"])
        req = SVDRequest(uid=int(header.get("uid", rid)),
                         matrix=arrays["matrix"],
                         bw=int(header.get("bw", 32)),
                         banded=bool(header.get("banded", False)),
                         compute_uv=bool(header.get("compute_uv", False)))
        fut = self.engine.submit(req, timeout_s=header.get("timeout_s"))
        fut.add_done_callback(lambda f, rid=rid, req=req:
                              self._send_result(rid, req, f))

    def _send_result(self, rid: int, req, fut) -> None:
        exc = fut.exception()
        if exc is not None:
            self._send({"type": "res", "rid": rid, "ok": False,
                        "error": str(exc),
                        "error_type": type(exc).__name__})
            return
        arrays = {"sigma": np.asarray(req.sigma)}
        if req.compute_uv:
            arrays["u"] = np.asarray(req.u)
            arrays["vt"] = np.asarray(req.vt)
        self._send({"type": "res", "rid": rid, "ok": True,
                    "tier": self.engine.metrics.tier_of_bucket(req.key())},
                   arrays)

    def _on_stats(self, header: dict) -> None:
        """Per-host observability payload: the engine's full metrics
        snapshot plus the latency histograms as mergeable dicts — the
        router folds these into the fleet view (DESIGN.md §16/§17)."""
        hists = self.engine.metrics.histograms()
        self._send({"type": "stats_res", "host_id": self.host_id,
                    "token": header.get("token"),
                    "snapshot": self.engine.metrics.snapshot(),
                    "histograms": {
                        "tiers": {t: h.to_dict()
                                  for t, h in hists["tiers"].items()},
                        "queue_age": hists["queue_age"].to_dict()},
                    "faults": (self.engine.faults.snapshot()
                               if self.engine.faults is not None else None)})

    # ------------------------------------------------------------------

    def serve_forever(self) -> None:
        """Run the protocol until ``stop`` or the router disconnects."""
        if self.engine is None:
            from repro.serve.async_engine import AsyncSVDEngine
            self.engine = AsyncSVDEngine(**self.engine_kwargs)
        self.engine.start()
        self._hello()
        drain = False
        try:
            while True:
                try:
                    header, arrays = recv_msg(self.sock)
                except WireClosed:
                    break                    # router gone: no drain target
                t = header.get("type")
                if t == "req":
                    self._on_request(header, arrays)
                elif t == "ping":
                    self._pings += 1
                    self._send({"type": "pong", "host_id": self.host_id,
                                "seq": header.get("seq"),
                                "pending": self.engine.pending(),
                                "health": self.engine.metrics.health()[
                                    "status"]})
                elif t == "stats":
                    self._on_stats(header)
                elif t == "stop":
                    drain = True
                    break
        finally:
            try:
                self.engine.stop(drain=drain)
            finally:
                try:
                    self.sock.close()
                except OSError:
                    pass


def start_inprocess_worker(address, host_id: str, *,
                           engine_kwargs: dict | None = None):
    """Run a worker on a daemon thread in this process, dialed into the
    router at ``address`` — the full wire protocol with no subprocess
    (tier-1-safe tests; the CI multihost gate uses real processes)."""
    sock = socket.create_connection(address, timeout=30)
    sock.settimeout(None)
    worker = ServeWorker(sock, host_id=host_id, engine_kwargs=engine_kwargs)
    thread = threading.Thread(target=worker.serve_forever,
                              name=f"ServeWorker-{host_id}", daemon=True)
    thread.start()
    return worker, thread


def spawn_worker_process(address, host_id: str, *, backend: str = "ref",
                         window_ms: float = 5.0, devices: int = 0,
                         coordinator: str = "", num_processes: int = 0,
                         process_id: int = -1,
                         env: dict | None = None) -> subprocess.Popen:
    """Launch ``python -m repro.serve.worker`` as a real process.

    ``devices > 0`` forces that many host-platform XLA devices in the
    child (the SNIPPETS.md multi-process idiom); ``coordinator`` opts the
    child in to ``jax.distributed`` bootstrap.  The child inherits this
    interpreter and ``PYTHONPATH`` — callers outside ``src`` (the
    benchmark driver, CI) need no extra wiring."""
    host, port = address
    # `-c` entry rather than `-m repro.serve.worker`: the package __init__
    # already imports this module, so runpy would warn about (and shadow)
    # the copy in sys.modules.
    cmd = [sys.executable, "-c",
           "from repro.serve.worker import main; main()",
           "--connect", f"{host}:{port}", "--host-id", str(host_id),
           "--backend", backend, "--window-ms", str(window_ms)]
    if coordinator:
        cmd += ["--coordinator", coordinator,
                "--num-processes", str(num_processes),
                "--process-id", str(process_id)]
    child_env = dict(os.environ if env is None else env)
    if devices > 0:
        child_env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={devices} "
            + child_env.get("XLA_FLAGS", "")).strip()
    src = os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    child_env["PYTHONPATH"] = (src + os.pathsep
                               + child_env.get("PYTHONPATH", "")).rstrip(
                                   os.pathsep)
    return subprocess.Popen(cmd, env=child_env)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Serve-fabric worker host (DESIGN.md §17)")
    ap.add_argument("--connect", required=True, metavar="HOST:PORT",
                    help="router listen address to dial")
    ap.add_argument("--host-id", required=True)
    ap.add_argument("--backend", default="ref")
    ap.add_argument("--window-ms", type=float, default=5.0,
                    help="engine micro-batch window")
    ap.add_argument("--max-pending", type=int, default=4096)
    ap.add_argument("--coordinator", default="", metavar="HOST:PORT",
                    help="opt-in jax.distributed coordinator address "
                         "(multi-process JAX bootstrap; never combined "
                         "with kill chaos — see module docstring)")
    ap.add_argument("--num-processes", type=int, default=0)
    ap.add_argument("--process-id", type=int, default=-1)
    args = ap.parse_args(argv)

    # Bootstrap BEFORE the first jax device query locks the backend.
    if args.coordinator:
        from repro.launch.mesh import init_distributed
        init_distributed(coordinator=args.coordinator,
                         num_processes=args.num_processes,
                         process_id=args.process_id)
    import jax
    jax.config.update("jax_enable_x64", True)
    from repro.launch.mesh import serve_mesh

    host, _, port = args.connect.rpartition(":")
    sock = socket.create_connection((host, int(port)), timeout=60)
    sock.settimeout(None)
    worker = ServeWorker(sock, host_id=args.host_id, engine_kwargs=dict(
        backend=args.backend, batch_window_s=args.window_ms / 1e3,
        max_pending=args.max_pending, mesh=serve_mesh()))
    worker.serve_forever()
    return 0


if __name__ == "__main__":
    sys.exit(main())
