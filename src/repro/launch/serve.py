"""Serving driver: batched requests through the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --requests 8
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.configs.base import get_config, smoke_of
from repro.models import build
from repro.serve import Engine, Request, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--full", action="store_true",
                    help="full config (default: smoke, CPU-runnable)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full else smoke_of(args.arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, ServeConfig(max_batch=args.max_batch,
                                            max_seq=args.max_seq))
    rng = np.random.default_rng(0)
    for uid in range(args.requests):
        prompt = list(map(int, rng.integers(1, cfg.vocab,
                                            int(rng.integers(2, 9)))))
        frames = (rng.standard_normal((cfg.enc_seq, cfg.d_model)).astype("f")
                  if cfg.kind == "encdec" else None)
        eng.submit(Request(uid=uid, prompt=prompt,
                           max_new_tokens=args.new_tokens, frames=frames))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    ntok = sum(len(r.output) for r in done)
    for r in done[:4]:
        print(f"req {r.uid}: {r.output}")
    print(f"served {len(done)} requests / {ntok} tokens in {dt:.1f}s "
          f"({ntok / max(dt, 1e-9):.1f} tok/s)")


if __name__ == "__main__":
    main()
