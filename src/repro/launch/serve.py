"""Serving drivers: batched token decoding, and the async SVD serve tier.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --requests 8
  PYTHONPATH=src python -m repro.launch.serve --svd --requests 32 --rate 200

The ``--svd`` mode drives :class:`repro.serve.AsyncSVDEngine` with an
open-loop request stream (arrivals do not wait for completions) and prints
latency percentiles plus the engine metrics snapshot.  With
``REPRO_SERVE_MESH`` set (see ``repro.launch.mesh.serve_mesh``) full
buckets are batch-sharded across all configured local devices.
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.configs.base import get_config, smoke_of
from repro.models import build
from repro.serve import Engine, Request, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--full", action="store_true",
                    help="full config (default: smoke, CPU-runnable)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--svd", action="store_true",
                    help="drive the async SVD serve tier instead of the "
                         "token engine")
    ap.add_argument("--svd-n", type=int, default=64, metavar="N",
                    help="[--svd] matrix size")
    ap.add_argument("--svd-bw", type=int, default=8, metavar="BW",
                    help="[--svd] stage-1 target bandwidth")
    ap.add_argument("--rate", type=float, default=100.0,
                    help="[--svd] open-loop Poisson arrival rate, req/s")
    ap.add_argument("--timeout-ms", type=float, default=0.0,
                    help="[--svd] per-request deadline (0: none)")
    ap.add_argument("--autotune", action="store_true",
                    help="[--svd] per-bucket tuned-config cache (DESIGN.md "
                         "§11)")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="[--svd] serve Prometheus-format engine metrics at "
                         "127.0.0.1:PORT/metrics for the lifetime of the "
                         "run (0 = ephemeral port; DESIGN.md §16)")
    ap.add_argument("--hosts", type=int, default=0, metavar="N",
                    help="[--svd] multi-host mode: spawn N worker processes "
                         "and route through repro.serve.SVDRouter "
                         "(DESIGN.md §17)")
    args = ap.parse_args(argv)
    if args.svd and args.hosts >= 2:
        return main_svd_multihost(args)
    if args.svd:
        return main_svd(args)

    cfg = get_config(args.arch) if args.full else smoke_of(args.arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, ServeConfig(max_batch=args.max_batch,
                                            max_seq=args.max_seq))
    rng = np.random.default_rng(0)
    for uid in range(args.requests):
        prompt = list(map(int, rng.integers(1, cfg.vocab,
                                            int(rng.integers(2, 9)))))
        frames = (rng.standard_normal((cfg.enc_seq, cfg.d_model)).astype("f")
                  if cfg.kind == "encdec" else None)
        eng.submit(Request(uid=uid, prompt=prompt,
                           max_new_tokens=args.new_tokens, frames=frames))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    ntok = sum(len(r.output) for r in done)
    for r in done[:4]:
        print(f"req {r.uid}: {r.output}")
    print(f"served {len(done)} requests / {ntok} tokens in {dt:.1f}s "
          f"({ntok / max(dt, 1e-9):.1f} tok/s)")


def main_svd(args):
    """Open-loop async SVD serving demo (DESIGN.md §12)."""
    jax.config.update("jax_enable_x64", True)
    from repro.launch.mesh import serve_mesh
    from repro.serve import AsyncSVDEngine, SVDRequest

    mesh = serve_mesh()
    n, bw = args.svd_n, args.svd_bw
    rng = np.random.default_rng(0)
    eng = AsyncSVDEngine(
        backend="auto", autotune=args.autotune, mesh=mesh,
        default_timeout_s=(args.timeout_ms / 1e3 or None))
    mserver = None
    if args.metrics_port is not None:
        from repro.obs import MetricsServer
        mserver = MetricsServer(port=args.metrics_port)
        mserver.register("svd", eng.metrics)
        print(f"metrics endpoint: {mserver.url}")
    # Warm the bucket (one compile) outside the timed window — never under
    # the engine's default deadline (compiles take seconds).
    eng.submit(SVDRequest(uid=-1, matrix=rng.standard_normal((n, n)),
                          bw=bw), timeout_s=float("inf")).result()
    # Hand-rolled open loop rather than benchmarks/serve_load.py's
    # poisson_run on purpose: src/ must stay importable with PYTHONPATH=src
    # alone (benchmarks/ lives outside the package).  The harness over
    # there is the canonical measurement tool; this is the demo.
    gaps = rng.exponential(1.0 / args.rate, args.requests)
    futs, lat, resolved = [], [], []

    def _stamp(req):
        # Latency must be sampled INSIDE the callback (when the future
        # resolves), not when the loop below gets around to reading it;
        # `resolved` counts every outcome so the wait below has a barrier.
        def cb(fut):
            if fut.exception() is None:
                lat.append(time.monotonic() - req.arrived)
            resolved.append(req.uid)
        return cb

    t0 = time.time()
    for uid in range(args.requests):
        time.sleep(gaps[uid])
        r = SVDRequest(uid=uid, matrix=rng.standard_normal((n, n)), bw=bw)
        f = eng.submit(r)
        f.add_done_callback(_stamp(r))
        futs.append(f)
    settle = time.time() + 600
    while len(resolved) < args.requests and time.time() < settle:
        time.sleep(0.01)
    for f in futs:
        try:
            f.result()
        except Exception as exc:                 # noqa: BLE001 — demo report
            print(f"request failed: {exc!r}")
    dt = time.time() - t0
    eng.stop()
    snap = eng.metrics.snapshot()
    if lat:
        p50, p95, p99 = np.percentile(np.asarray(lat) * 1e3, [50, 95, 99])
        print(f"served {len(lat)}/{args.requests} requests in {dt:.2f}s "
              f"({len(lat) / dt:.1f} req/s) on "
              f"{'mesh ' + str(mesh.shape) if mesh else 'one device'}")
        print(f"latency p50/p95/p99 = {p50:.1f}/{p95:.1f}/{p99:.1f} ms")
    print("metrics:", {k: round(v, 3) if isinstance(v, float) else v
                       for k, v in sorted(snap.items())})
    # Operator health view (DESIGN.md §15): headline status plus the
    # failure-taxonomy counters (retries, quarantines, degraded traffic).
    health = eng.metrics.health()
    print("health:", {k: round(v, 4) if isinstance(v, float) else v
                      for k, v in health.items()})
    if mserver is not None:
        mserver.stop()


def main_svd_multihost(args):
    """Two-plus-process serve demo (DESIGN.md §17): a router in this
    process, ``--hosts`` worker processes, the same open loop as
    :func:`main_svd` routed fleet-wide.  The canonical measurement tool
    is ``benchmarks/serve_load.py --hosts N``; this is the demo."""
    from repro.serve import SVDRequest
    from repro.serve.router import SVDRouter
    from repro.serve.worker import spawn_worker_process

    n, bw = args.svd_n, args.svd_bw
    rng = np.random.default_rng(0)
    router = SVDRouter(
        default_timeout_s=(args.timeout_ms / 1e3 or None))
    procs = [spawn_worker_process(router.address, f"w{i}", backend="auto")
             for i in range(args.hosts)]
    mserver = None
    try:
        if not router.wait_for_hosts(args.hosts, timeout=120):
            raise RuntimeError(
                f"only {len(router.alive_hosts())}/{args.hosts} worker "
                f"hosts connected")
        if args.metrics_port is not None:
            from repro.obs import MetricsServer, render_fleet_metrics
            mserver = MetricsServer(port=args.metrics_port)
            mserver.register("router", router.metrics)
            mserver.register_provider(
                "fleet", lambda: render_fleet_metrics(router.fleet()))
            print(f"metrics endpoint: {mserver.url}")
        # Warm every host's bucket compile outside the timed window.
        router.warm([SVDRequest(uid=-1,
                                matrix=rng.standard_normal((n, n)), bw=bw)])
        gaps = rng.exponential(1.0 / args.rate, args.requests)
        futs, lat = [], []
        t0 = time.time()
        for uid in range(args.requests):
            time.sleep(gaps[uid])
            r = SVDRequest(uid=uid, matrix=rng.standard_normal((n, n)),
                           bw=bw)
            futs.append((r, router.submit(r)))
        for r, f in futs:
            try:
                f.result(timeout=600)
                lat.append(time.monotonic() - r.arrived)
            except Exception as exc:             # noqa: BLE001 — demo report
                print(f"request {r.uid} failed: {exc!r}")
        dt = time.time() - t0
        fleet = router.fleet()
        if lat:
            p50, p95, p99 = np.percentile(np.asarray(lat) * 1e3,
                                          [50, 95, 99])
            print(f"served {len(lat)}/{args.requests} requests in "
                  f"{dt:.2f}s ({len(lat) / dt:.1f} req/s) across "
                  f"{len(fleet['alive_hosts'])} hosts")
            print(f"latency p50/p95/p99 = {p50:.1f}/{p95:.1f}/{p99:.1f} ms")
        print("fleet hosts:", {h: row for h, row
                               in fleet["router"]["hosts"].items()})
        print("merged latency:", fleet["latency"]["merged_summary"])
    finally:
        router.stop()
        if mserver is not None:
            mserver.stop()
        for p in procs:
            try:
                p.wait(timeout=15)
            except Exception:                    # noqa: BLE001 — cleanup
                p.kill()


if __name__ == "__main__":
    main()
