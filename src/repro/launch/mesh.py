"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (device counts are locked at first jax init, and tests /
benches must see the real single device while the dry-run sees 512 host
devices via its own XLA_FLAGS).
"""

from __future__ import annotations

import jax

from repro.parallel.sharding import AxisRules, DEFAULT_RULES, MULTIPOD_RULES

__all__ = ["make_production_mesh", "rules_for"]


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) = (data, model) single pod; (2, 16, 16) = (pod, data, model)
    for the 2-pod, 512-chip production target."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def rules_for(mesh) -> AxisRules:
    import dataclasses
    base = MULTIPOD_RULES if "pod" in mesh.shape else DEFAULT_RULES
    return dataclasses.replace(base, mesh=mesh)
