"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (device counts are locked at first jax init, and tests /
benches must see the real single device while the dry-run sees 512 host
devices via its own XLA_FLAGS).
"""

from __future__ import annotations

import os
import warnings

import jax

from repro.parallel.sharding import AxisRules, DEFAULT_RULES, MULTIPOD_RULES

__all__ = ["make_production_mesh", "rules_for", "serve_mesh",
           "init_distributed"]

_DIST_INITIALIZED = False


def init_distributed(*, coordinator: str | None = None,
                     num_processes: int | None = None,
                     process_id: int | None = None) -> bool:
    """Best-effort multi-process JAX bootstrap (DESIGN.md §17).

    Calls ``jax.distributed.initialize`` with the given (or
    ``$REPRO_DIST_COORDINATOR`` / ``$REPRO_DIST_NUM_PROCESSES`` /
    ``$REPRO_DIST_PROCESS_ID``) rendezvous parameters; returns True iff
    the bootstrap ran.  Never raises: an unset/partial config returns
    False (single-process operation is the default, not an error), and a
    failed initialize warns and returns False — the serve fabric's
    multi-processness lives at the socket level (``serve/router.py``),
    so a worker that cannot join the XLA coordination service still
    serves on its local devices.  Must run before the first device query
    locks the backend; idempotent (a second call is a no-op True).
    """
    global _DIST_INITIALIZED
    if _DIST_INITIALIZED:
        return True
    env = os.environ.get
    coordinator = coordinator or env("REPRO_DIST_COORDINATOR", "")
    nproc = (num_processes if num_processes
             else int(env("REPRO_DIST_NUM_PROCESSES", "0") or 0))
    pid = (process_id if process_id is not None and process_id >= 0
           else int(env("REPRO_DIST_PROCESS_ID", "-1") or -1))
    if not coordinator or nproc < 2 or pid < 0:
        return False
    try:
        jax.distributed.initialize(coordinator_address=coordinator,
                                   num_processes=nproc, process_id=pid)
    except Exception as exc:                 # noqa: BLE001 — best-effort
        warnings.warn(f"jax.distributed.initialize failed "
                      f"(serving single-process): {exc!r}", stacklevel=2)
        return False
    _DIST_INITIALIZED = True
    return True


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) = (data, model) single pod; (2, 16, 16) = (pod, data, model)
    for the 2-pod, 512-chip production target."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def rules_for(mesh) -> AxisRules:
    import dataclasses
    base = MULTIPOD_RULES if "pod" in mesh.shape else DEFAULT_RULES
    return dataclasses.replace(base, mesh=mesh)


def serve_mesh(*, env_var: str = "REPRO_SERVE_MESH"):
    """Serve-tier dispatch mesh from ``$REPRO_SERVE_MESH``, or ``None``.

    The env var configures how many local devices the serving engines'
    sharded dispatch (DESIGN.md §12) spreads full buckets over:

    * unset / empty — ``None``: engines dispatch locally (single device);
    * ``"auto"``    — every visible device on one ``("data",)`` axis;
    * an integer    — that many devices (clamped to the visible count).

    Returns ``None`` — engines then degrade gracefully to local dispatch —
    when fewer than 2 devices would participate, or when the installed jax
    predates the ``jax.shard_map``/``AxisType`` surface the sharded paths
    target (the environment-gated seed condition, DESIGN.md §10).  A value
    that parses as neither ``"auto"`` nor an integer raises — a typo'd
    explicit config should be loud, not silently single-device.  Like
    every mesh here this is a FUNCTION: importing the module never touches
    jax device state.
    """
    spec = os.environ.get(env_var, "").strip().lower()
    if not spec:
        return None
    if spec != "auto":
        try:
            int(spec)
        except ValueError:
            raise ValueError(
                f"${env_var}={spec!r}: expected unset, 'auto', or a device "
                f"count") from None
    if not (hasattr(jax, "shard_map") and hasattr(jax.sharding, "AxisType")):
        return None
    # LOCAL devices only: the serve engines' sharded dispatch feeds host
    # arrays to this process's addressable devices.  Under multi-process
    # JAX (init_distributed) jax.device_count() is GLOBAL — building the
    # mesh from it would double-count every remote host's devices and
    # dispatch onto devices this process cannot feed (DESIGN.md §17).
    local = jax.local_devices()
    ndev = len(local) if spec == "auto" else int(spec)
    ndev = min(ndev, len(local))
    if ndev < 2:
        return None
    return jax.make_mesh((ndev,), ("data",), devices=local[:ndev],
                         axis_types=(jax.sharding.AxisType.Auto,))
