"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (device counts are locked at first jax init, and tests /
benches must see the real single device while the dry-run sees 512 host
devices via its own XLA_FLAGS).
"""

from __future__ import annotations

import os

import jax

from repro.parallel.sharding import AxisRules, DEFAULT_RULES, MULTIPOD_RULES

__all__ = ["make_production_mesh", "rules_for", "serve_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) = (data, model) single pod; (2, 16, 16) = (pod, data, model)
    for the 2-pod, 512-chip production target."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def rules_for(mesh) -> AxisRules:
    import dataclasses
    base = MULTIPOD_RULES if "pod" in mesh.shape else DEFAULT_RULES
    return dataclasses.replace(base, mesh=mesh)


def serve_mesh(*, env_var: str = "REPRO_SERVE_MESH"):
    """Serve-tier dispatch mesh from ``$REPRO_SERVE_MESH``, or ``None``.

    The env var configures how many local devices the serving engines'
    sharded dispatch (DESIGN.md §12) spreads full buckets over:

    * unset / empty — ``None``: engines dispatch locally (single device);
    * ``"auto"``    — every visible device on one ``("data",)`` axis;
    * an integer    — that many devices (clamped to the visible count).

    Returns ``None`` — engines then degrade gracefully to local dispatch —
    when fewer than 2 devices would participate, or when the installed jax
    predates the ``jax.shard_map``/``AxisType`` surface the sharded paths
    target (the environment-gated seed condition, DESIGN.md §10).  A value
    that parses as neither ``"auto"`` nor an integer raises — a typo'd
    explicit config should be loud, not silently single-device.  Like
    every mesh here this is a FUNCTION: importing the module never touches
    jax device state.
    """
    spec = os.environ.get(env_var, "").strip().lower()
    if not spec:
        return None
    if spec != "auto":
        try:
            int(spec)
        except ValueError:
            raise ValueError(
                f"${env_var}={spec!r}: expected unset, 'auto', or a device "
                f"count") from None
    if not (hasattr(jax, "shard_map") and hasattr(jax.sharding, "AxisType")):
        return None
    ndev = jax.device_count() if spec == "auto" else int(spec)
    ndev = min(ndev, jax.device_count())
    if ndev < 2:
        return None
    return jax.make_mesh((ndev,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
