import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST run in a fresh process (the XLA flag above is read at first jax init —
it is set before ANY other import, including jax).  For each cell we:

  1. build ShapeDtypeStruct stand-ins for params / optimizer state / batch /
     caches (no allocation),
  2. jit the step with explicit in/out shardings from the logical rules,
  3. ``.lower().compile()`` on the production mesh,
  4. record memory_analysis / cost_analysis / loop-scaled HLO costs +
     collective schedule (repro.roofline) into reports/dryrun/<cell>.json.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import get_config, list_configs
from repro.configs.shapes import SUITES, cells
from repro.launch.mesh import make_production_mesh, rules_for
from repro.models import batch_logical, build, input_specs
from repro.parallel.sharding import use_rules, zero1_shardings
from repro.roofline import analyze, hw
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports", "dryrun")


def _prune_spec(spec: P, shape, mesh) -> P:
    """Drop sharding on dims the mesh axes don't divide (in_shardings must be
    exactly divisible; GSPMD-padded uneven sharding only applies to internal
    constraints, not argument layouts)."""
    out = []
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for dim, ax in zip(shape, entries):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        out.append(ax if dim % n == 0 else None)
    return P(*out)


def _sds(shape_tree, logical_tree, dtype_fn, rules):
    """ShapeDtypeStruct tree with NamedShardings from logical axes (pruned to
    divisible dims)."""
    mesh = rules.mesh

    def one(shp, logical):
        spec = _prune_spec(rules.spec(logical), shp, mesh)
        return jax.ShapeDtypeStruct(shp, dtype_fn(shp),
                                    sharding=NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(
        one, shape_tree, logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, int) for e in x))


def state_specs(model, trainer, rules):
    """Abstract train state (params + AdamW moments) with shardings."""
    cfg = model.cfg
    mesh = rules.mesh
    logical = model.param_logical()
    shapes = model.param_shapes()
    m_sh = zero1_shardings(logical, shapes, rules, trainer.dp_axes)
    dt = cfg.param_dtype

    is_shape = lambda x: isinstance(x, tuple) and all(isinstance(e, int) for e in x)
    flat_shapes, treedef = jax.tree_util.tree_flatten(shapes, is_leaf=is_shape)
    flat_log = treedef.flatten_up_to(logical)
    flat_msh = treedef.flatten_up_to(m_sh)
    params, moments = [], []
    for shp, log, msh in zip(flat_shapes, flat_log, flat_msh):
        pspec = _prune_spec(rules.spec(log), shp, mesh)
        mspec = _prune_spec(msh.spec, shp, mesh)
        params.append(jax.ShapeDtypeStruct(
            shp, dt, sharding=NamedSharding(mesh, pspec)))
        moments.append(jax.ShapeDtypeStruct(
            shp, jnp.float32, sharding=NamedSharding(mesh, mspec)))
    params = jax.tree_util.tree_unflatten(treedef, params)
    moments = jax.tree_util.tree_unflatten(treedef, moments)
    rep = NamedSharding(mesh, P())
    opt = {"step": jax.ShapeDtypeStruct((), jnp.int32, sharding=rep),
           "m": moments, "v": moments}
    return {"params": params, "opt": opt}


def batch_specs(cfg, suite, rules):
    specs = input_specs(cfg, suite)
    logical = batch_logical(cfg, suite)
    gb_ok = suite.global_batch % _dp_size(rules) == 0

    def one(s, l):
        if not gb_ok:                      # tiny global batch: replicate
            l = tuple(None for _ in l)
        spec = _prune_spec(rules.spec(l), s.shape, rules.mesh)
        return jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(rules.mesh, spec))

    return jax.tree_util.tree_map(
        one, specs, logical,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _dp_size(rules):
    n = 1
    for a in ("pod", "data"):
        n *= rules.mesh.shape.get(a, 1)
    return n


def cache_specs(model, suite, rules):
    """Abstract decode caches.

    * When the global batch can't cover the DP axes (long_500k: batch 1), the
      KV *sequence* axis is sharded instead (logical 'seq_kv').
    * When kv_heads doesn't divide the model axis (GQA kv < 16), the KV cache
      falls back to head-dim sharding ('model_in'): attention contracts over
      head_dim, so GSPMD turns it into partial sums + a small score
      all-reduce instead of replicating the cache.
    """
    cfg = model.cfg
    b = suite.global_batch
    mesh = rules.mesh
    shapes = jax.eval_shape(lambda: model.init_caches(b, suite.seq_len))
    logical = model.cache_logical()
    shard_seq = b % _dp_size(rules) != 0
    model_size = mesh.shape.get("model", 1)

    def one(sds, log):
        log = list(log) + [None] * (len(sds.shape) - len(log))
        if shard_seq:
            log = [None if l == "batch" else l for l in log]
            if len(sds.shape) >= 3 and sds.shape[2] == suite.seq_len:
                log[2] = "seq_kv"
        # GQA fallback: kv head axis unshardable -> shard head_dim
        for i, l in enumerate(log):
            if l == "kv_heads" and sds.shape[i] % model_size != 0:
                log[i] = None
                if sds.shape[-1] % model_size == 0 and log[-1] is None:
                    log[-1] = "model_in"
        spec = _prune_spec(rules.spec(tuple(log)), sds.shape, mesh)
        return jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(
        one, shapes, logical,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def lower_cell(arch: str, suite_name: str, mesh_name: str):
    """Returns (lowered, compiled, cfg, suite, chips)."""
    cfg = get_config(arch)
    suite = SUITES[suite_name]
    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    rules = rules_for(mesh)
    chips = hw.CHIPS_MULTI_POD if multi else hw.CHIPS_SINGLE_POD
    model = build(cfg)

    with mesh, use_rules(rules):
        if suite.mode == "train":
            trainer = Trainer(model, AdamWConfig(), mesh=mesh, rules=rules,
                              dp_axes=("pod", "data") if multi else ("data",))
            st = state_specs(model, trainer, rules)
            bt = batch_specs(cfg, suite, rules)
            step = trainer.make_train_step()
            fn = jax.jit(lambda s, b: step(s, b, None),
                         donate_argnums=(0,))
            lowered = fn.lower(st, bt)
        elif suite.mode == "prefill":
            pt = _sds(model.param_shapes(), model.param_logical(),
                      lambda _: cfg.param_dtype, rules)
            bt = batch_specs(cfg, suite, rules)
            fn = jax.jit(lambda p, b: model.prefill(p, b))
            lowered = fn.lower(pt, bt)
        else:                                   # decode
            pt = _sds(model.param_shapes(), model.param_logical(),
                      lambda _: cfg.param_dtype, rules)
            bt = batch_specs(cfg, suite, rules)
            ct = cache_specs(model, suite, rules)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            fn = jax.jit(lambda p, t, c, i: model.decode_step(p, t, c, i),
                         donate_argnums=(2,))
            lowered = fn.lower(pt, bt["token"], ct, pos)
        compiled = lowered.compile()
    return lowered, compiled, cfg, suite, chips


def run_cell(arch: str, suite_name: str, mesh_name: str, *, force=False,
             out_dir=REPORT_DIR) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    key = f"{arch}__{suite_name}__{mesh_name}"
    path = os.path.join(out_dir, key + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    t0 = time.time()
    try:
        lowered, compiled, cfg, suite, chips = lower_cell(arch, suite_name,
                                                          mesh_name)
        cost = dict(compiled.cost_analysis())
        try:
            mem = compiled.memory_analysis()
        except Exception:
            mem = None
        report = analyze(arch=arch, suite=suite, mesh_name=mesh_name,
                         chips=chips, hlo_text=compiled.as_text(),
                         cost=cost, mem=mem, cfg=cfg)
        out = {"status": "ok", "cell": key, "seconds": time.time() - t0,
               **report.to_dict(),
               "memory_analysis": repr(mem), "xla_cost_keys": sorted(cost)[:8]}
    except Exception as e:
        out = {"status": "error", "cell": key, "seconds": time.time() - t0,
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    with open(path, "w") as f:
        json.dump(out, f, indent=1, default=str)
    return out


def all_cells(mesh_names):
    out = []
    for arch in list_configs():
        cfg = get_config(arch)
        for suite in cells(cfg):
            for m in mesh_names:
                out.append((arch, suite.name, m))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=REPORT_DIR)
    args = ap.parse_args()
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    todo = (all_cells(meshes) if args.all
            else [(args.arch, args.shape, m) for m in meshes])
    n_ok = 0
    for arch, shape, m in todo:
        out = run_cell(arch, shape, m, force=args.force, out_dir=args.out)
        ok = out["status"] == "ok"
        n_ok += ok
        msg = (f"bottleneck={out.get('bottleneck')} "
               f"t=({out.get('t_compute', 0):.2e},{out.get('t_memory', 0):.2e},"
               f"{out.get('t_collective', 0):.2e})s" if ok
               else out.get("error", "?"))
        print(f"[{'OK' if ok else 'FAIL'}] {arch} x {shape} x {m} "
              f"({out['seconds']:.0f}s) {msg}", flush=True)
    print(f"{n_ok}/{len(todo)} cells OK")
    return 0 if n_ok == len(todo) else 1


if __name__ == "__main__":
    raise SystemExit(main())
