"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --steps 200 --ckpt-dir /tmp/run1

Wires together: config -> model -> Trainer (sharded when a mesh is requested)
-> deterministic data pipeline -> crash-safe restart loop (ft.py) ->
spectral monitor (the paper's SVD engine) -> checkpoints.  ``--smoke`` uses
the reduced config (CPU-runnable); otherwise the full assigned config
(requires real accelerators or the 512-device dry-run environment).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, smoke_of
from repro.models import build
from repro.parallel.compression import CompressionConfig
from repro.train import (AdamWConfig, DataConfig, StragglerMonitor, Trainer,
                         batch_at, checkpoint)
from repro.train.spectral import SpectralMonitor, SpectralMonitorConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--spectral-every", type=int, default=0,
                    help="refresh spectral monitor every N steps (0=off)")
    ap.add_argument("--compress-rank", type=int, default=0,
                    help="PowerSGD gradient compression rank (0=off)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = smoke_of(args.arch) if args.smoke else get_config(args.arch)
    model = build(cfg)
    opt = AdamWConfig(peak_lr=args.lr, warmup_steps=min(20, args.steps // 10 + 1),
                      total_steps=args.steps,
                      spectral_clip=2.0 if args.spectral_every else 0.0)
    compression = (CompressionConfig(rank=args.compress_rank)
                   if args.compress_rank else None)
    trainer = Trainer(model, opt, accum=args.accum, compression=compression)
    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                    global_batch=args.batch, seed=17)
    monitor = (SpectralMonitor(SpectralMonitorConfig(every=args.spectral_every,
                                                     size=64, bw=16,
                                                     backend="ref"))
               if args.spectral_every else None)
    straggler = StragglerMonitor(
        on_straggler=lambda s, t, m: print(
            f"[straggler] step {s}: {t:.2f}s vs median {m:.2f}s", flush=True))

    with_sigma = monitor is not None
    jstep = jax.jit(trainer.make_train_step()) if with_sigma else \
        jax.jit(lambda s, b: trainer.make_train_step()(s, b, None))

    # ---- resume or init ----------------------------------------------------
    start = 0
    state = trainer.init_state(jax.random.PRNGKey(0))
    if args.ckpt_dir:
        last = checkpoint.latest_step(args.ckpt_dir)
        if last is not None:
            state = checkpoint.restore(args.ckpt_dir, last, state)
            start = last
            print(f"resumed from step {start}", flush=True)

    t_start = time.time()
    for step in range(start, args.steps):
        t0 = time.monotonic()
        batch = {k: jnp.asarray(v) for k, v in batch_at(dc, step).items()}
        if monitor is not None:
            monitor.maybe_refresh(step, state["params"])
            state, metrics = jstep(state, batch, monitor.sigma_max_tree())
        else:
            state, metrics = jstep(state, batch)
        straggler.record(step, time.monotonic() - t0)
        if step % args.log_every == 0 or step == args.steps - 1:
            line = {"step": step,
                    "loss": round(float(metrics["loss"]), 4),
                    "grad_norm": round(float(metrics["grad_norm"]), 3),
                    "lr": float(metrics["lr"])}
            if monitor is not None:
                sm = monitor.metrics()
                if sm:
                    k = sorted(sm)[0]
                    line["sigma0"] = round(sm[k], 3)
            print(json.dumps(line), flush=True)
        if args.ckpt_dir and (step + 1) % args.save_every == 0:
            checkpoint.save(args.ckpt_dir, step + 1, state)
    if args.ckpt_dir:
        checkpoint.save(args.ckpt_dir, args.steps, state)
    dt = time.time() - t_start
    print(f"done: {args.steps - start} steps in {dt:.1f}s "
          f"({(args.steps - start) / max(dt, 1e-9):.2f} it/s)", flush=True)


if __name__ == "__main__":
    main()
