"""Quickstart: the paper's contribution in five lines, then the pipeline.

Computes all singular values of (1) a banded matrix via the memory-aware
bulge-chasing reduction (the paper's stage 2 + stage 3), (2) a dense matrix
via the full three-stage pipeline, (3) a stacked batch of matrices via
the batch-native pipeline + resolved PipelineConfig, and (4) a FULL SVD
(U, sigma, V^T) via the reflector-tape pipeline (compute_uv=True) —
validated against numpy on the spot.  Runs on CPU in seconds.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from repro.core import banded_singular_values, singular_values, svd_batched
from repro.core.tuning import ChaseConfig, PipelineConfig

# --- 1. banded matrix -> singular values (the paper's direct use case) ------
n, bw = 256, 16
rng = np.random.default_rng(0)
a = np.triu(rng.standard_normal((n, n)))
a = np.triu(a) - np.triu(a, bw + 1)                  # upper banded, bw=16

cfg = ChaseConfig.resolve(n, bw, jnp.float64)
print(f"banded {n}x{n}, bandwidth {bw}: tilewidth={cfg.tw}, "
      f"max concurrent sweeps={cfg.max_sweeps}")

sigma = banded_singular_values(jnp.asarray(a), bw=bw, tw=cfg.tw, backend="ref")
ref = np.linalg.svd(a, compute_uv=False)
err = np.max(np.abs(np.asarray(sigma) - ref)) / ref[0]
print(f"sigma[0..4] = {np.asarray(sigma[:5]).round(4)}")
print(f"max rel err vs LAPACK: {err:.2e}")
assert err < 1e-10

# --- 2. dense matrix -> three-stage pipeline ---------------------------------
m = 128
d = rng.standard_normal((m, m))
sigma2 = singular_values(jnp.asarray(d), bw=16, tw=8, backend="ref")
ref2 = np.linalg.svd(d, compute_uv=False)
err2 = np.max(np.abs(np.asarray(sigma2) - ref2)) / ref2[0]
print(f"dense {m}x{m} three-stage pipeline: max rel err {err2:.2e}")
assert err2 < 1e-10

# --- 3. batched: a stack of matrices through one fused wavefront -------------
# Small matrices cannot fill the machine alone (paper Eq. 1); a (B, n, n)
# stack shares one wavefront clock, so every chase cycle is one fused kernel
# call over all B*G windows.  PipelineConfig resolves every knob (tilewidth,
# backend, bucket size) once; it is the one argument every layer accepts.
B, k = 8, 64
cfg = PipelineConfig.resolve(bw=8, dtype=jnp.float64, n=k)
print(f"batched {B}x{k}x{k}: config {cfg}")
stack = rng.standard_normal((B, k, k))
sigma3 = np.asarray(svd_batched(jnp.asarray(stack), config=cfg))
err3 = max(np.max(np.abs(sigma3[b] - np.linalg.svd(stack[b], compute_uv=False)))
           / sigma3[b][0] for b in range(B))
print(f"batch of {B}: max rel err vs LAPACK {err3:.2e}")
assert err3 < 1e-10

# --- 4. full SVD: U, sigma, V^T via the reflector tape (compute_uv=True) ----
# The paper computes values only (vector accumulation is its §VII future
# work); with compute_uv=True stages 1-2 record every Householder reflector
# into a static-shape tape, replayed into U/V^T with the chase's own
# wavefront batching (DESIGN.md §8).  sigma is bit-identical to case 3.
u, sigma4, vt = svd_batched(jnp.asarray(stack), config=cfg, compute_uv=True)
u, sigma4, vt = np.asarray(u), np.asarray(sigma4), np.asarray(vt)
recon = max(np.abs(u[b] @ np.diag(sigma4[b]) @ vt[b] - stack[b]).max()
            for b in range(B))
orth = max(np.abs(u[b].T @ u[b] - np.eye(k)).max() for b in range(B))
print(f"full SVD: max recon err {recon:.2e}, max |U^T U - I| {orth:.2e}, "
      f"sigma bit-identical: {np.array_equal(sigma3, sigma4)}")
assert recon < 1e-10 and orth < 1e-12
assert np.array_equal(sigma3, sigma4)

# --- 5. cycle-fused chase super-steps (PipelineConfig.fuse) ------------------
# fuse=K chases K consecutive cycles of each sweep per kernel dispatch inside
# one VMEM-resident (H, K*b_in + tw + 1) band block: each cycle costs ~1/K of
# a contiguous HBM block round trip instead of its own sheared window
# gather/scatter, launches drop 3*nsweeps -> 2*nsweeps, and numerics are
# invariant (DESIGN.md §9).  fuse=None asks the VMEM performance model for
# the deepest super-step that fits (tuning.default_fuse_depth).
import dataclasses
fused_cfg = dataclasses.replace(cfg, fuse=4)
sigma5 = np.asarray(svd_batched(jnp.asarray(stack), config=fused_cfg))
auto = PipelineConfig.resolve(bw=8, dtype=jnp.float64, n=k, fuse=None)
print(f"fuse=4 max |sigma - sigma(fuse=1)| = "
      f"{np.abs(sigma5 - sigma3).max():.2e}; "
      f"VMEM-model default fuse depth for bw=8: {auto.fuse}")
assert np.abs(sigma5 - sigma3).max() < 1e-12
print("OK")

# --- 6. hardware-aware autotuning (DESIGN.md §11) ----------------------------
# The closed-form defaults above are a guess about this host; the autotuner
# measures the truth.  The analytic cost model ranks the (tw, fuse, batch)
# grid, only the top-K (plus the static default) are timed, and the winner is
# persisted to a JSON cache keyed by (device, n, bw, dtype, uv, backend) —
# which resolve(autotune=True) then consults.  CLI equivalent:
#   python -m repro.autotune --shapes n=64:bw=8 --backend ref
import os
import tempfile
from repro.autotune import cache as at_cache, model as at_model, run_search

cache_file = os.path.join(tempfile.mkdtemp(), "autotune.json")
res = run_search(64, 8, backend="ref", top_k=2, fuses=(1, 2), iters=1)
print(res.table())
at_cache.store(res.to_entry(), device_kind=at_model.device_kind(), n=64,
               bw=8, dtype="float32", compute_uv=False, backend="ref",
               path=cache_file)
tuned = PipelineConfig.resolve(n=64, bw=8, backend="ref", autotune=True,
                               autotune_cache=cache_file)
assert (tuned.tw, tuned.fuse) == (res.best.tw, res.best.fuse)
assert res.best.measured_s <= res.default.measured_s   # beats or ties default
print(f"tuned config for n=64, bw=8 on this host: tw={tuned.tw} "
      f"fuse={tuned.fuse} max_batch={tuned.max_batch}")
print("OK")

# --- 7. async serving: concurrent requests -> micro-batched buckets ----------
# (DESIGN.md §12)  Callers from any thread (or asyncio task) submit and get a
# future; the engine aggregates concurrent same-shape requests into one
# batched pipeline call per bucket — the batch axis of section 3, fed by
# traffic instead of one caller.  Deadlines, per-request error surfacing, and
# multi-device dispatch (REPRO_SERVE_MESH) ride along; eng.metrics counts
# queue depth, batch-fill ratio, and bucket hit-rate.
import threading
from repro.serve import AsyncSVDEngine, SVDRequest

serve_cfg = PipelineConfig.resolve(bw=4, tw=2, backend="ref",
                                   dtype=np.float64, max_batch=4)
futs, futs_lock = {}, threading.Lock()
with AsyncSVDEngine(serve_cfg, batch_window_s=0.005) as eng:
    def client(t, k=24):
        for j in range(3):
            uid = t * 3 + j
            f = eng.submit(SVDRequest(
                uid=uid, matrix=rng.standard_normal((k, k)), bw=4))
            with futs_lock:
                futs[uid] = f
    threads = [threading.Thread(target=client, args=(t,)) for t in range(4)]
    [t.start() for t in threads]
    [t.join() for t in threads]
    done = {uid: f.result() for uid, f in futs.items()}
worst = max(np.abs(r.sigma - np.linalg.svd(r.matrix, compute_uv=False)).max()
            for r in done.values())
snap = eng.metrics.snapshot()
print(f"async serve: {len(done)} concurrent requests in "
      f"{snap['batches']} batched calls "
      f"(fill={snap['batch_fill_ratio']:.2f}), max err {worst:.2e}")
assert len(done) == 12 and worst < 1e-10
assert snap["completed"] == 12 and snap["failed"] == 0
print("OK")

# --- 8. fused small-n tier: the whole pipeline as ONE dispatch ---------------
# (DESIGN.md §13)  Below the fused crossover the staged pipeline's per-stage
# dispatches are pure overhead on a VMEM-resident problem: backend
# "fused_small" runs band reduction, the whole bulge chase, and the Sturm
# bisection in a single kernel dispatch per (B, n, n) stack.  The serve
# engines route n <= fused_n_max buckets there automatically (tuned via
# `python -m repro.autotune --fused-crossover`); metrics attribute every
# dispatch per tier.
fcfg = PipelineConfig.resolve(bw=8, dtype=jnp.float64, n=k,
                              backend="fused_small")
sigma8 = np.asarray(svd_batched(jnp.asarray(stack), config=fcfg))
print(f"fused_small tier: max |sigma - staged| = "
      f"{np.abs(sigma8 - sigma3).max():.2e}")
assert np.abs(sigma8 - sigma3).max() < 1e-12

with AsyncSVDEngine(serve_cfg, batch_window_s=0.005) as eng:
    f = eng.submit(SVDRequest(uid=0, matrix=rng.standard_normal((24, 24)),
                              bw=4))
    f.result()
snap = eng.metrics.snapshot()
tier = next(iter(snap["bucket_tiers"].values()))
print(f"serve routing: n=24 bucket -> tier={tier['tier']!r} "
      f"(backend={tier['backend']}), fused batches = "
      f"{snap['tiers']['fused']['batches']}")
assert tier["tier"] == "fused" and snap["tiers"]["fused"]["batches"] >= 1
print("OK")

# --- 9. divide-and-conquer stage 3: the large-n end (DESIGN.md §14) ----------
# The Sturm bisection's critical path grows like n (every sweep is a
# sequential depth-2n recurrence); Cuppen's D&C replaces it with log2(n/32)
# secular merge levels whose deflated blocks are skipped at run time, so past
# the measured crossover (~2048 on a CPU host, fp64) it wins outright —
# stage3="auto" resolves the choice per problem through the autotune cache
# (`python -m repro.autotune --stage3-crossover`).  This section times both
# solvers on one n=4096 bidiagonal, so it takes ~a minute; everything above
# runs in seconds.
import time
from repro.core.bidiag_dc import bidiag_dc_singular_values
from repro.core.bidiag_svd import bidiag_singular_values

n9 = 4096
d9 = jnp.asarray(rng.standard_normal(n9))
e9 = jnp.asarray(rng.standard_normal(n9))     # e[0] unused: e[i] = B[i-1,i]

auto9 = PipelineConfig.resolve(bw=32, dtype=jnp.float64, stage3="auto")
print(f"stage3='auto' resolves: n=256 -> {auto9.stage3_for(256)!r}, "
      f"n={n9} -> {auto9.stage3_for(n9)!r}")

sig_bi = jax.block_until_ready(bidiag_singular_values(d9, e9))   # + compile
sig_dc = jax.block_until_ready(bidiag_dc_singular_values(d9, e9))
t0 = time.perf_counter()
jax.block_until_ready(bidiag_singular_values(d9, e9))
t_bi = time.perf_counter() - t0
t0 = time.perf_counter()
jax.block_until_ready(bidiag_dc_singular_values(d9, e9))
t_dc = time.perf_counter() - t0
agree9 = float(jnp.max(jnp.abs(sig_dc - sig_bi)) / sig_bi[0])
print(f"stage 3 at n={n9}: bisect {t_bi:.2f}s, dc {t_dc:.2f}s "
      f"({t_bi / t_dc:.2f}x), sigma agreement {agree9:.1e}")
assert agree9 < 1e-12
print("OK")

# --- 10. fault tolerance: injected faults, absorbed (DESIGN.md §15) ----------
# A serving tier that only works when nothing fails is a benchmark, not a
# service.  Inject a deterministic fault plan — the FIRST dispatch raises,
# and the next result comes back NaN-poisoned — and watch the fabric absorb
# both: the dispatch error retries with backoff, the NaN trips the
# numerical-health guard (NumericalFault), is retried once, and the request
# is re-served on the degraded ref tier if the poison persists.  Every
# caller still gets the correct spectrum; nothing surfaces as an error.
from repro.serve import FaultPlan, RetryPolicy, SVDEngine

plan = FaultPlan(seed=7, dispatch_errors_at=(0,), nan_at=(1, 2))
eng10 = SVDEngine(backend="ref",
                  faults=plan,
                  retry=RetryPolicy(backoff_base_s=1e-3, backoff_max_s=1e-2))
mats10 = [rng.standard_normal((24, 24)) for _ in range(3)]
for i, m in enumerate(mats10):
    eng10.submit(SVDRequest(uid=i, matrix=m, bw=4))
done10 = eng10.run()

for r in done10:
    assert r.error is None, r.error            # zero client-visible failures
    ref10 = np.linalg.svd(r.matrix, compute_uv=False)
    assert np.abs(np.asarray(r.sigma) - ref10).max() < 1e-10 * ref10[0]

health = eng10.metrics.health()
snap10 = eng10.metrics.snapshot()
print(f"injected: {plan.snapshot()['dispatch_error']} dispatch error(s), "
      f"{plan.snapshot()['nan']} NaN corruption(s)")
print(f"absorbed: retried={snap10['retried']} degraded={snap10['degraded']} "
      f"(degraded-ref batches = "
      f"{snap10['tiers'].get('degraded-ref', {}).get('batches', 0)})")
print(f"health: status={health['status']!r} "
      f"client_error_rate={health['client_error_rate']:.2f} — every sigma "
      f"correct")
assert health["client_error_rate"] == 0.0
assert snap10["retried"] + snap10["degraded"] >= 1
print("OK")

# --- 11. tracing: where does one svd_batched call spend its time? ------------
# (DESIGN.md §16)  Pass a Tracer into any core.svd entry point and get a
# fenced span tree: per-stage durations with jit compile time split out on
# the first dispatch (JAX hides it inside the first call otherwise).  The
# traced path runs the same jitted stages — sigma is bit-identical.
from repro.obs import Tracer

tr = Tracer("quickstart")
mats11 = jnp.asarray(rng.standard_normal((4, 32, 32)))
cfg11 = PipelineConfig.resolve(n=32, bw=4, backend="ref", dtype=np.float64)
sig11 = svd_batched(mats11, cfg11, trace=tr)
np.testing.assert_array_equal(np.asarray(sig11),
                              np.asarray(svd_batched(mats11, cfg11)))

(root11,) = tr.roots
print(f"\nper-stage breakdown of one traced svd_batched call "
      f"(compile split out):")
print(tr.format(min_ms=0.01))
stage_ms = {c.name: c.dur_s * 1e3 for c in root11.children}
coverage = root11.total_child_seconds() / root11.dur_s
print(f"stage spans cover {coverage:.1%} of the {root11.dur_s * 1e3:.1f} ms "
      f"root ({', '.join(f'{k}={v:.1f}ms' for k, v in stage_ms.items())})")
assert coverage >= 0.90                       # the §16 acceptance bar
assert root11.find("stage1/compile")          # first dispatch: compile split
print("OK")

# --- 12. multi-host serving: router + two local worker processes -------------
# (DESIGN.md §17)  The serve tier across PROCESS boundaries: SVDRouter owns
# admission and pins each shape-bucket to one worker host (rendezvous
# hashing keeps micro-batching intact); each worker is a real subprocess
# running its own AsyncSVDEngine, speaking the stdlib-socket wire protocol.
# A dropped host is quarantined and its in-flight work requeued — zero
# client-visible failures is the design contract, CI-gated with a SIGKILL.
from repro.serve import SVDRouter
from repro.serve.worker import spawn_worker_process

router = SVDRouter()
procs = [spawn_worker_process(router.address, f"w{i}", backend="ref")
         for i in range(2)]
try:
    assert router.wait_for_hosts(2, timeout=240)
    mats12 = [rng.standard_normal((16, 16)) for _ in range(6)]
    futs12 = [router.submit(SVDRequest(uid=i, matrix=m, bw=4))
              for i, m in enumerate(mats12)]
    for m, f in zip(mats12, futs12):
        ref = np.linalg.svd(m, compute_uv=False)
        np.testing.assert_allclose(f.result(timeout=300).sigma, ref,
                                   atol=1e-12 * ref[0])
    fleet = router.fleet()
    per_host = {h: row["completed"]
                for h, row in fleet["router"]["hosts"].items()}
    print(f"\nserved {fleet['router']['completed']} requests across "
          f"{len(fleet['alive_hosts'])} worker processes: {per_host}")
    print(f"fleet merged latency p99 = "
          f"{fleet['latency']['merged_summary']['p99_ms']:.1f} ms "
          f"(per-host histograms folded via StreamingHistogram.merged)")
    assert sum(per_host.values()) == 6
finally:
    router.stop()
    for p in procs:
        p.wait(timeout=30)
print("OK")
