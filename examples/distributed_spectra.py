"""Distributed spectral analysis: batch-dispatch the paper's pipeline across a
mesh (the pod-scale production pattern: one matrix per device group, zero
collectives during the chase).

Run with fake devices to see the sharded path:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/distributed_spectra.py
"""

import numpy as np
import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from repro.core.distributed import batched_singular_values, sharded_singular_values

B, n = 8, 96
rng = np.random.default_rng(0)
mats = jnp.asarray(rng.standard_normal((B, n, n)))

if len(jax.devices()) > 1:
    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    print(f"sharding {B} matrices over {ndev} devices")
    sig = sharded_singular_values(mats, mesh, bw=16, tw=8, backend="ref")
else:
    print(f"single device: vmapped batch of {B}")
    sig = batched_singular_values(mats, bw=16, tw=8, backend="ref")

sig = np.asarray(sig)
for i in range(B):
    ref = np.linalg.svd(np.asarray(mats[i]), compute_uv=False)
    err = np.max(np.abs(sig[i] - ref)) / ref[0]
    assert err < 1e-9, (i, err)
print(f"sigma_max per matrix: {sig[:, 0].round(3)}")
print("all spectra match LAPACK to 1e-9.  OK")
