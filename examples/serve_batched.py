"""Serve a small model with batched requests (continuous batching engine).

Mixed prompt lengths and token budgets arrive in a queue; the engine packs
them into fixed KV-cache slots with per-slot positions and decodes lock-step,
refilling slots as requests finish — static shapes, no recompilation.

  PYTHONPATH=src python examples/serve_batched.py
"""

import time

import numpy as np
import jax

from repro.configs.base import smoke_of
from repro.models import build
from repro.serve import Engine, Request, ServeConfig

cfg = smoke_of("hymba-1.5b")        # hybrid attn+mamba arch, KV+state caches
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))
eng = Engine(model, params, ServeConfig(max_batch=4, max_seq=64))

rng = np.random.default_rng(7)
n_requests = 10
for uid in range(n_requests):
    plen = int(rng.integers(2, 12))
    eng.submit(Request(uid=uid,
                       prompt=list(map(int, rng.integers(1, cfg.vocab, plen))),
                       max_new_tokens=int(rng.integers(4, 12))))

t0 = time.time()
done = eng.run()
dt = time.time() - t0
ntok = sum(len(r.output) for r in done)
for r in sorted(done, key=lambda r: r.uid)[:5]:
    print(f"req {r.uid:2d} ({len(r.output):2d} tokens): {r.output}")
print(f"{len(done)} requests, {ntok} tokens in {dt:.1f}s "
      f"({ntok / max(dt, 1e-9):.1f} tok/s on CPU smoke config)")
assert len(done) == n_requests
print("OK")
