"""End-to-end driver: train a (reduced) assigned architecture for a few
hundred steps with the paper's SVD engine in the loop.

Demonstrates: deterministic data pipeline, AdamW + cosine schedule, spectral
monitoring (banded bulge-chasing SVD of the weight matrices every N steps),
spectral gradient clipping, checkpointing with crash-restart, straggler
detection.

  PYTHONPATH=src python examples/train_with_spectral_monitor.py
"""

import tempfile

import jax
import jax.numpy as jnp

from repro.configs.base import smoke_of
from repro.models import build
from repro.train import (AdamWConfig, DataConfig, FailureInjector,
                         StragglerMonitor, Trainer, batch_at, checkpoint,
                         run_with_restarts)
from repro.train.spectral import SpectralMonitor, SpectralMonitorConfig

STEPS = 200
cfg = smoke_of("granite-3-2b")
model = build(cfg)
trainer = Trainer(model, AdamWConfig(peak_lr=2e-3, warmup_steps=10,
                                     total_steps=STEPS, spectral_clip=2.0))
dc = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=42)
monitor = SpectralMonitor(SpectralMonitorConfig(every=50, size=64, bw=16,
                                                backend="ref"))
straggler = StragglerMonitor()
jstep = jax.jit(trainer.make_train_step())

ckpt_dir = tempfile.mkdtemp(prefix="repro_example_")
print(f"checkpoints -> {ckpt_dir}")


def make_state():
    return trainer.init_state(jax.random.PRNGKey(0))


def restore_state(step, template):
    return checkpoint.restore(ckpt_dir, step, template)


def step_fn(step, state):
    batch = {k: jnp.asarray(v) for k, v in batch_at(dc, step).items()}
    monitor.maybe_refresh(step, state["params"])
    state, metrics = jstep(state, batch, monitor.sigma_max_tree())
    if step % 25 == 0:
        sm = monitor.metrics()
        srank = next((v for k, v in sm.items() if "stable_rank" in k), 0.0)
        print(f"step {step:4d}  loss {float(metrics['loss']):.4f}  "
              f"grad_norm {float(metrics['grad_norm']):.2f}  "
              f"stable_rank {srank:.1f}")
    return state, {"loss": float(metrics["loss"])}


# inject a crash at step 120 — the restart loop restores and the final
# trajectory is identical to an uninterrupted run (pure-function data +
# atomic checkpoints)
state, history, restarts = run_with_restarts(
    total_steps=STEPS, ckpt_dir=ckpt_dir, make_state=make_state,
    restore_state=restore_state, step_fn=step_fn, save_every=40,
    injector=FailureInjector(fail_at=(120,)), monitor=straggler)

losses = [m["loss"] for _, m in history]
print(f"done: {len(history)} recorded steps, {restarts} restart(s), "
      f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
assert restarts == 1 and losses[-1] < losses[0]
print("OK")
