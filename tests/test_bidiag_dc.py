"""Tests for the divide-and-conquer stage-3 solver (core/bidiag_dc.py,
DESIGN.md §14): sigma agreement with bisection/LAPACK across hostile
spectra, the stage3= pipeline policy, the autotune crossover plumbing,
and the serve engine's staged-dc tier."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.autotune import cache as at_cache
from repro.autotune import search as at_search
from repro.core import svd as svdmod
from repro.core import tuning
from repro.core.bidiag_dc import (DEFAULT_DC_LEAF_N, bidiag_dc_singular_values,
                                  bidiag_dc_svd)
from repro.core.bidiag_svd import bidiag_singular_values, bidiag_svd
from repro.serve.engine import SVDEngine, SVDRequest


def dense_bidiag(d, e):
    """Dense (n, n) upper bidiagonal from the repo's (d, e) convention:
    e is length n with e[0] UNUSED (e[i] = B[i-1, i])."""
    n = len(d)
    b = np.diag(np.asarray(d, float))
    if n > 1:
        b += np.diag(np.asarray(e, float)[1:], 1)
    return b


def lapack_sigma(d, e):
    return np.linalg.svd(dense_bidiag(d, e), compute_uv=False)


# ---------------------------------------------------------------------------
# sigma agreement: random, clustered, extreme-scale, deflation-heavy
# ---------------------------------------------------------------------------

def test_dc_matches_lapack_random():
    rng = np.random.default_rng(0)
    n = 100                                   # 7 leaves of 16 -> 3 merge levels
    d = rng.standard_normal(n)
    e = rng.standard_normal(n)
    s = np.asarray(bidiag_dc_singular_values(jnp.asarray(d), jnp.asarray(e),
                                             leaf_n=16))
    s0 = lapack_sigma(d, e)
    np.testing.assert_allclose(s, s0, rtol=0, atol=1e-13 * s0[0])


def test_dc_leaf_shortcircuit_matches_bisection():
    # n <= leaf_n takes the pure-bisection path: bit-identical by construction
    rng = np.random.default_rng(1)
    d, e = rng.standard_normal(20), rng.standard_normal(20)
    s_dc = bidiag_dc_singular_values(jnp.asarray(d), jnp.asarray(e), leaf_n=32)
    s_bi = bidiag_singular_values(jnp.asarray(d), jnp.asarray(e))
    np.testing.assert_array_equal(np.asarray(s_dc), np.asarray(s_bi))


def test_dc_clustered_sigma():
    # near-identical diagonal with tiny couplings: the secular solver has to
    # separate roots pinned between nearly-coincident poles
    n = 96
    d = np.ones(n) + 1e-14 * np.arange(n)
    e = np.full(n, 1e-13)
    s = np.asarray(bidiag_dc_singular_values(jnp.asarray(d), jnp.asarray(e),
                                             leaf_n=16))
    s0 = lapack_sigma(d, e)
    np.testing.assert_allclose(s, s0, rtol=0, atol=1e-13 * s0[0])


def test_dc_extreme_dynamic_range():
    # sigma spanning ~1e-300 .. 1e300: the prescaled GK path must not
    # overflow the squares into inf/nan, and the solver keeps the NORMWISE
    # contract |s - s0| <= tol * s0[0] (elementwise-relative accuracy for
    # sigma hundreds of decades below the norm is a bisection-only
    # property — same trade as LAPACK bdsdc vs bdsqr)
    n = 64
    rng = np.random.default_rng(2)
    d = np.logspace(-300, 300, n) * np.sign(rng.standard_normal(n))
    e = 0.5 * np.logspace(-300, 300, n)
    s = np.asarray(bidiag_dc_singular_values(jnp.asarray(d), jnp.asarray(e),
                                             leaf_n=16))
    s0 = lapack_sigma(d, e)
    assert np.isfinite(s).all()
    np.testing.assert_allclose(s, s0, rtol=0, atol=1e-13 * s0[0])
    np.testing.assert_allclose(s[0], s0[0], rtol=1e-12)


def test_dc_heavy_deflation():
    # mostly-zero couplings -> block-diagonal problem, nearly everything
    # deflates at every merge level
    n = 128
    rng = np.random.default_rng(3)
    d = rng.standard_normal(n)
    e = np.zeros(n)
    e[::7] = rng.standard_normal(len(e[::7])) * 1e-3
    s = np.asarray(bidiag_dc_singular_values(jnp.asarray(d), jnp.asarray(e),
                                             leaf_n=16))
    s0 = lapack_sigma(d, e)
    np.testing.assert_allclose(s, s0, rtol=0, atol=1e-13 * s0[0])


def test_dc_degenerates():
    # n=1: sigma = |d|
    s = np.asarray(bidiag_dc_singular_values(jnp.asarray([-3.0]),
                                             jnp.asarray([0.0])))
    np.testing.assert_allclose(s, [3.0], atol=0)
    # diagonal matrix (all couplings zero): sigma = sorted |d|
    d = np.array([1.0, -4.0, 2.0, 0.0, -0.5] * 16)
    e = np.zeros_like(d)
    s = np.asarray(bidiag_dc_singular_values(jnp.asarray(d), jnp.asarray(e),
                                             leaf_n=8))
    np.testing.assert_allclose(s, np.sort(np.abs(d))[::-1], atol=1e-14)


def test_dc_batched_vmap_contract():
    rng = np.random.default_rng(4)
    d = rng.standard_normal((3, 48))
    e = rng.standard_normal((3, 48))
    s = np.asarray(bidiag_dc_singular_values(jnp.asarray(d), jnp.asarray(e),
                                             leaf_n=16))
    assert s.shape == (3, 48)
    for i in range(3):
        s0 = lapack_sigma(d[i], e[i])
        np.testing.assert_allclose(s[i], s0, rtol=0, atol=1e-13 * s0[0])


def test_dc_svd_reconstructs():
    rng = np.random.default_rng(5)
    n = 80
    d, e = rng.standard_normal(n), rng.standard_normal(n)
    u, s, vt = bidiag_dc_svd(jnp.asarray(d), jnp.asarray(e), leaf_n=16)
    u, s, vt = np.asarray(u), np.asarray(s), np.asarray(vt)
    b = dense_bidiag(d, e)
    np.testing.assert_allclose(u @ np.diag(s) @ vt, b, atol=1e-12 * s[0])
    # inverse iteration from few-ulp sigma: orthogonality degrades a little
    # for near-degenerate pairs (same machinery as the bisection uv path)
    np.testing.assert_allclose(u.T @ u, np.eye(n), atol=1e-10)
    np.testing.assert_allclose(vt @ vt.T, np.eye(n), atol=1e-10)


def test_leaf_n_validation():
    d = jnp.ones(4)
    with pytest.raises(ValueError, match="leaf_n"):
        bidiag_dc_singular_values(d, d, leaf_n=1)
    with pytest.raises(ValueError, match="leaf_n"):
        bidiag_dc_svd(d, d, leaf_n=0)


def test_max_iter_validation():
    # the old ``max_iter: int = 0`` footgun (0 silently meant "no sweeps",
    # returning garbage brackets) is now an explicit error; None = auto
    d = jnp.ones(4)
    with pytest.raises(ValueError, match="max_iter"):
        bidiag_singular_values(d, d, max_iter=0)
    with pytest.raises(ValueError, match="max_iter"):
        bidiag_svd(d, d, max_iter=-3)
    s_auto = bidiag_singular_values(d, d)                  # None = dtype auto
    s_expl = bidiag_singular_values(d, d, max_iter=60)
    np.testing.assert_allclose(np.asarray(s_auto), np.asarray(s_expl),
                               atol=1e-14)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 90), st.integers(0, 2**31 - 1))
def test_dc_agrees_with_bisection_property(n, seed):
    rng = np.random.default_rng(seed)
    d = rng.standard_normal(n)
    e = rng.standard_normal(n)
    s_dc = np.asarray(bidiag_dc_singular_values(jnp.asarray(d),
                                                jnp.asarray(e), leaf_n=16))
    s_bi = np.asarray(bidiag_singular_values(jnp.asarray(d), jnp.asarray(e)))
    np.testing.assert_allclose(s_dc, s_bi, rtol=0, atol=1e-12 * s_bi[0])


# ---------------------------------------------------------------------------
# stage3= pipeline policy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stage3", ["bisect", "dc"])
def test_pipeline_stage3_backends_agree(stage3):
    rng = np.random.default_rng(6)
    n = 48
    a = rng.standard_normal((n, n))
    cfg = tuning.PipelineConfig.resolve(bw=4, tw=2, backend="ref",
                                        dtype=np.float64, n=n,
                                        stage3=stage3, dc_n_min=1,
                                        dc_leaf_n=16)
    s = np.asarray(svdmod.singular_values(jnp.asarray(a), config=cfg))
    s0 = np.linalg.svd(a, compute_uv=False)
    np.testing.assert_allclose(s, s0, rtol=0, atol=1e-11 * s0[0])


def test_pipeline_stage3_dc_uv_path():
    rng = np.random.default_rng(7)
    n = 32
    a = rng.standard_normal((n, n))
    cfg = tuning.PipelineConfig.resolve(bw=4, tw=2, backend="ref",
                                        dtype=np.float64, n=n,
                                        compute_uv=True, stage3="dc",
                                        dc_n_min=1, dc_leaf_n=8)
    u, s, vt = svdmod.svd(jnp.asarray(a), config=cfg)
    u, s, vt = np.asarray(u), np.asarray(s), np.asarray(vt)
    np.testing.assert_allclose(u @ np.diag(s) @ vt, a, atol=1e-10 * s[0])


def test_stage3_auto_resolution():
    # with n known, "auto" collapses at resolve time by the dc_n_min threshold
    lo = tuning.PipelineConfig.resolve(bw=4, dtype=np.float64, n=64,
                                       stage3="auto", dc_n_min=128)
    hi = tuning.PipelineConfig.resolve(bw=4, dtype=np.float64, n=256,
                                       stage3="auto", dc_n_min=128)
    assert lo.stage3 == "bisect" and hi.stage3 == "dc"
    # n-free resolve keeps the policy; stage3_for collapses per problem
    free = tuning.PipelineConfig.resolve(bw=4, dtype=np.float64,
                                         stage3="auto", dc_n_min=128)
    assert free.stage3 == "auto"
    assert free.stage3_for(64) == "bisect" and free.stage3_for(128) == "dc"
    # explicit choices pass through stage3_for untouched
    assert lo.stage3_for(10_000) == "bisect"
    with pytest.raises(ValueError, match="stage3"):
        tuning.PipelineConfig.resolve(bw=4, stage3="qr")


def test_stage3_defaults_from_bidiag_dc():
    cfg = tuning.PipelineConfig.resolve(bw=4, dtype=np.float64)
    assert cfg.stage3 == "bisect"
    assert cfg.dc_leaf_n == DEFAULT_DC_LEAF_N


# ---------------------------------------------------------------------------
# autotune: cache round-trip + measured crossover search
# ---------------------------------------------------------------------------

def test_cache_stage3_roundtrip(tmp_path):
    p = str(tmp_path / "tune.json")
    assert at_cache.lookup_stage3(device_kind="cpu", dtype="float64",
                                  compute_uv=False, path=p) is None
    at_cache.store_stage3({"dc_n_min": 1536}, device_kind="cpu",
                          dtype="float64", compute_uv=False, path=p)
    assert at_cache.lookup_stage3(device_kind="cpu", dtype="float64",
                                  compute_uv=False, path=p) == 1536
    # uv axis is part of the key: the values-path entry must not leak
    assert at_cache.lookup_stage3(device_kind="cpu", dtype="float64",
                                  compute_uv=True, path=p) is None
    # and the resolver consumes it for dc_n_min when autotune is on
    cfg = tuning.PipelineConfig.resolve(bw=4, dtype=np.float64, n=2048,
                                        stage3="auto", autotune=True,
                                        autotune_cache=p)
    assert cfg.dc_n_min == 1536 and cfg.stage3 == "dc"


def test_search_stage3_crossover_injected():
    def fake_measure(n, dc):
        # dc wins from 512 up; perfect agreement
        return (1e-3 if (dc and n >= 512) or (not dc and n < 512)
                else 2e-3), 1e-16
    res = at_search.search_stage3_crossover(ns=(128, 256, 512, 1024),
                                            measure_fn=fake_measure)
    assert res.dc_n_min == 512
    entry = res.to_entry()
    assert entry["dc_n_min"] == 512 and len(entry["points"]) == 4


def test_search_stage3_crossover_never_wins_sentinel():
    res = at_search.search_stage3_crossover(
        ns=(128, 256), measure_fn=lambda n, dc: (2e-3 if dc else 1e-3, 1e-16))
    assert res.dc_n_min == 257          # beyond-any-measured-n sentinel


# ---------------------------------------------------------------------------
# serve engine: staged-dc tier
# ---------------------------------------------------------------------------

def _run_engine(dc_n_min):
    rng = np.random.default_rng(8)
    eng = SVDEngine(tuning.PipelineConfig.resolve(bw=4, tw=2, backend="ref",
                                                  dtype=np.float64,
                                                  max_batch=4),
                    fused_n_max=0, dc_n_min=dc_n_min)
    mats = rng.standard_normal((3, 24, 24))
    for uid, m in enumerate(mats):
        eng.submit(SVDRequest(uid=uid, matrix=m, bw=4))
    done = eng.run()
    assert len(done) == 3 and all(r.done and r.error is None for r in done)
    for r in done:
        s0 = np.linalg.svd(mats[r.uid], compute_uv=False)
        np.testing.assert_allclose(r.sigma, s0, atol=1e-11 * s0[0])
    return eng.metrics.snapshot()


def test_engine_staged_dc_tier():
    snap = _run_engine(dc_n_min=1)       # pin crossover below every n
    tiers = {v["tier"] for v in snap["bucket_tiers"].values()}
    assert tiers == {"staged-dc"}
    assert snap["tiers"]["staged-dc"]["batches"] > 0


def test_engine_dc_disabled():
    snap = _run_engine(dc_n_min=0)       # 0 = pin bisection
    tiers = {v["tier"] for v in snap["bucket_tiers"].values()}
    assert tiers == {"staged"}
    assert "staged-dc" not in snap["tiers"]
