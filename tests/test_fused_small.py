"""Fused small-n SVD tier (DESIGN.md §13): numerics, routing, tuning.

Layers under test:

  1. kernel numerics — fused sigma vs the staged pipeline, the dense
     reference oracle, and LAPACK, across n (1 .. 256), bw edges (bw
     clamped from 0; bw = n-1), and both dtypes;
  2. compute_uv — exact reconstruction A = U diag(s) V^T and orthogonality
     from the fused reduction + one batched bidiag_svd;
  3. backend registry — "fused_small" is a complete backend; the Pallas
     kernel in interpret mode is BIT-IDENTICAL to the jnp twin (shared
     reduction body);
  4. VMEM budget — infeasible n fails loudly at config resolution;
  5. crossover tuning — model prediction, measured search (injected
     timer), cache round-trip;
  6. serve routing — both engines route n <= crossover buckets to the
     fused tier, attribute dispatches per tier, honor pins and the tuned
     cache, and fall back to staged above the crossover;
  7. hypothesis-randomized property sweep (skips without the optional dep).
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import reference, tuning
from repro.core import svd as svdmod
from repro.core.bidiag_svd import bidiag_singular_values
from repro.kernels import fused_small, ops
from repro.kernels import ref as kref
from repro.autotune import cache as at_cache
from repro.autotune import model as at_model
from repro.autotune import search as at_search
from repro.serve import AsyncSVDEngine, SVDEngine, SVDRequest


def dense(n, batch=1, seed=0, dtype=np.float64):
    a = np.random.default_rng(seed).standard_normal((batch, n, n))
    return a.astype(dtype)


def lapack_sigma(a):
    return np.linalg.svd(np.asarray(a, np.float64), compute_uv=False)


# ---------------------------------------------------------------------------
# 1. values numerics: fused vs staged vs oracle vs LAPACK
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 2, 16, 64])
@pytest.mark.parametrize("bw", [0, 1, 4, "full"])
def test_fused_values_match_staged_and_lapack(n, bw):
    bw = (n - 1) if bw == "full" else bw       # bw=n-1 edge; bw=0 clamps to 1
    a = dense(n, batch=3, seed=n * 31 + max(bw, 0))
    sig = np.asarray(kref.fused_small_svd_ref(jnp.asarray(a), bw=bw))
    s0 = lapack_sigma(a)
    tol = 1e-12 * max(1.0, float(s0.max()))
    np.testing.assert_allclose(sig, s0, atol=tol)
    # vs the STAGED pipeline at the same (clamped) bandwidth
    bw_eff = fused_small.effective_bw(n, bw)
    if n >= 2:
        staged = np.asarray(svdmod.svd_batched(
            jnp.asarray(a), bw=bw_eff, backend="ref"))
        np.testing.assert_allclose(sig, staged, atol=tol)


def test_fused_values_n256():
    n, bw = 256, 16
    a = dense(n, batch=2, seed=7)
    sig = np.asarray(kref.fused_small_svd_ref(jnp.asarray(a), bw=bw))
    s0 = lapack_sigma(a)
    np.testing.assert_allclose(sig, s0, atol=1e-12 * s0.max())


def test_fused_matches_dense_reference_oracle():
    """On a banded input (in-kernel stage 1 is a no-op) the fused reduction
    reproduces reference.py's loop-nest oracle: same |bidiagonal| entries,
    same sigma.  The fused phase 2 is ONE SBR stage at tw = bw - 1, exactly
    the oracle's single-stage plan."""
    n, bw = 24, 5
    rng = np.random.default_rng(3)
    a = np.triu(rng.standard_normal((n, n)))
    a = np.triu(a) - np.triu(a, bw + 1)
    d_ref, e_ref, _ = reference.bidiagonalize_dense_ref(a.copy(), bw, bw - 1)
    _, _, _, d, e = fused_small._reduce_single(jnp.asarray(a), bw=bw,
                                               compute_uv=False)
    np.testing.assert_allclose(np.abs(np.asarray(d)), np.abs(d_ref),
                               atol=1e-10)
    np.testing.assert_allclose(np.abs(np.asarray(e))[1:], np.abs(e_ref),
                               atol=1e-10)
    sig = np.asarray(bidiag_singular_values(d[None], e[None]))[0]
    np.testing.assert_allclose(sig, lapack_sigma(a[None])[0],
                               atol=1e-12 * sig.max())


def test_fused_banded_input_noop_stage1():
    """Already-banded inputs pass through the in-kernel stage 1 as exact
    no-ops (tau = 0 on zero tails): fused banded == staged banded."""
    n, bw = 20, 4
    rng = np.random.default_rng(5)
    a = np.triu(rng.standard_normal((2, n, n)))
    a = np.triu(a) - np.triu(a, bw + 1)
    sig = np.asarray(kref.fused_small_svd_ref(jnp.asarray(a), bw=bw))
    staged = np.asarray(svdmod.banded_singular_values(
        jnp.asarray(a), bw=bw, backend="ref"))
    np.testing.assert_allclose(sig, staged, atol=1e-12 * staged.max())
    np.testing.assert_allclose(sig, lapack_sigma(a),
                               atol=1e-12 * staged.max())


@pytest.mark.parametrize("dtype,tol", [(np.float32, 5e-4), (np.float64, 1e-12)])
def test_fused_values_dtypes(dtype, tol):
    n, bw = 32, 8
    a = dense(n, batch=2, seed=11, dtype=dtype)
    sig = np.asarray(kref.fused_small_svd_ref(jnp.asarray(a), bw=bw))
    assert sig.dtype == dtype
    s0 = lapack_sigma(a)
    np.testing.assert_allclose(sig, s0, atol=tol * s0.max())


# ---------------------------------------------------------------------------
# 2. compute_uv: reconstruction + orthogonality, sigma unchanged
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,bw", [(2, 1), (16, 4), (33, 7)])
def test_fused_uv_reconstruction(n, bw):
    a = dense(n, batch=2, seed=n)
    cfg = tuning.PipelineConfig.resolve(bw=bw, dtype=np.float64, n=n,
                                        backend="fused_small",
                                        compute_uv=True)
    u, sig, vt = svdmod.svd(jnp.asarray(a), config=cfg, compute_uv=True)
    u, sig, vt = np.asarray(u), np.asarray(sig), np.asarray(vt)
    smax = max(1.0, float(sig.max()))
    for i in range(len(a)):
        np.testing.assert_allclose(u[i] @ (sig[i][:, None] * vt[i]), a[i],
                                   atol=1e-11 * smax)
        np.testing.assert_allclose(u[i] @ u[i].T, np.eye(n), atol=1e-11)
        np.testing.assert_allclose(vt[i] @ vt[i].T, np.eye(n), atol=1e-11)
    np.testing.assert_allclose(sig, lapack_sigma(a), atol=1e-12 * smax)


def test_fused_uv_sigma_matches_values_mode():
    n, bw = 16, 4
    a = jnp.asarray(dense(n, batch=2, seed=2))
    sig_v = np.asarray(kref.fused_small_svd_ref(a, bw=bw))
    cfg = tuning.PipelineConfig.resolve(bw=bw, dtype=np.float64, n=n,
                                        backend="fused_small",
                                        compute_uv=True)
    _, sig_uv, _ = svdmod.svd(a, config=cfg, compute_uv=True)
    np.testing.assert_allclose(sig_v, np.asarray(sig_uv),
                               atol=1e-13 * max(1.0, float(sig_v.max())))


# ---------------------------------------------------------------------------
# 3. registry + Pallas interpret twin
# ---------------------------------------------------------------------------

def test_fused_small_is_complete_backend():
    assert "fused_small" in ops.backend_names()
    for op in ("chase_cycle", "hh_block_apply", "tape_apply",
               "flash_attention", "fused_svd"):
        assert ops._impl(op, "fused_small") is not None


def test_ops_fused_svd_backends_agree():
    a = jnp.asarray(dense(12, batch=2, seed=9))
    s_ref = np.asarray(ops.fused_svd(a, bw=3, backend="ref"))
    s_fsd = np.asarray(ops.fused_svd(a, bw=3, backend="fused_small"))
    np.testing.assert_array_equal(s_ref, s_fsd)   # same impl off-TPU


@pytest.mark.parametrize("compute_uv", [False, True])
def test_pallas_interpret_bit_identical_to_twin(compute_uv):
    """The Pallas kernel and the jnp twin share the reduction body — in
    interpret mode the outputs are bit-identical, not merely close."""
    n, bw = 8, 3
    a = jnp.asarray(dense(n, batch=2, seed=1))
    if compute_uv:
        d_p, e_p, u_p, vt_p = fused_small.fused_small_svd_pallas(
            a, bw=bw, compute_uv=True, interpret=True)
        red = jax.vmap(lambda m: fused_small._reduce_single(
            m, bw=bw, compute_uv=True))
        _, u_r, v_r, d_r, e_r = red(a)
        np.testing.assert_array_equal(np.asarray(d_p), np.asarray(d_r))
        np.testing.assert_array_equal(np.asarray(e_p), np.asarray(e_r))
        np.testing.assert_array_equal(np.asarray(u_p), np.asarray(u_r))
        np.testing.assert_array_equal(np.asarray(vt_p),
                                      np.asarray(jnp.swapaxes(v_r, -1, -2)))
    else:
        s_p = fused_small.fused_small_svd_pallas(a, bw=bw, interpret=True)
        s_r = kref.fused_small_svd_ref(a, bw=bw)
        np.testing.assert_array_equal(np.asarray(s_p), np.asarray(s_r))


# ---------------------------------------------------------------------------
# 4. VMEM budget
# ---------------------------------------------------------------------------

def test_fused_vmem_budget():
    assert tuning.fused_working_set_bytes(64, np.float32) == \
        2 * 64 * 64 * 4 + 12 * 64 * 4
    assert tuning.fused_working_set_bytes(64, np.float32, compute_uv=True) \
        > 2 * tuning.fused_working_set_bytes(64, np.float32)
    tuning.check_fused_vmem_budget(256, np.float32)
    with pytest.raises(ValueError, match="staged"):
        tuning.check_fused_vmem_budget(4096, np.float32)
    # resolution-time enforcement for fused_small configs
    with pytest.raises(ValueError):
        tuning.PipelineConfig.resolve(bw=32, dtype=np.float32, n=4096,
                                      backend="fused_small")
    cfg = tuning.PipelineConfig.resolve(bw=32, dtype=np.float32, n=256,
                                        backend="fused_small")
    assert cfg.backend == "fused_small"


# ---------------------------------------------------------------------------
# 5. crossover: model, search, cache
# ---------------------------------------------------------------------------

def test_model_fused_cost_and_crossover():
    c16 = at_model.fused_cost(16, 8, dtype=np.float64)
    c256 = at_model.fused_cost(256, 8, dtype=np.float64)
    assert 0 < c16.seconds < c256.seconds
    # uv triples the in-kernel cycle work; at n large enough for the cycle
    # term to dominate the Sturm solve, the uv figure must exceed values.
    assert (at_model.fused_cost(256, 8, compute_uv=True).seconds
            > c256.seconds)
    x = at_model.predicted_crossover(8, dtype=np.float64)
    assert x >= 16                               # fused must win the tiny end


def test_search_fused_crossover_injected():
    def fake(n, fused):                          # fused wins up to n=32
        return (1e-3 if fused else 2e-3) if n <= 32 else (2e-3 if fused
                                                          else 1e-3)
    res = at_search.search_fused_crossover(8, ns=(16, 32, 64), batch=4,
                                           measure_fn=fake)
    assert res.fused_n_max == 32
    assert [p[0] for p in res.points] == [16, 32, 64]
    entry = res.to_entry()
    assert entry["fused_n_max"] == 32 and entry["schema"] == 1
    assert "fused crossover" in res.table()


def test_crossover_cache_roundtrip(tmp_path):
    path = str(tmp_path / "cache.json")
    kw = dict(device_kind="cpu", dtype="float64", compute_uv=False)
    assert at_cache.lookup_crossover(**kw, path=path) is None
    at_cache.store_crossover({"fused_n_max": 48}, **kw, bw=8, path=path)
    assert at_cache.lookup_crossover(**kw, bw=8, path=path) == 48
    # no wide entry yet: a different bw misses the specific key AND the wide
    assert at_cache.lookup_crossover(**kw, bw=16, path=path) is None
    at_cache.store_crossover({"fused_n_max": 96}, **kw, path=path)
    assert at_cache.lookup_crossover(**kw, bw=16, path=path) == 96
    assert at_cache.lookup_crossover(**kw, bw=8, path=path) == 48  # specific
    # corrupt entries read as a miss, never as a crossover
    at_cache.store_crossover({"fused_n_max": 7}, **kw, bw=4, path=path)
    doc = at_cache.load(path)
    doc["entries"][at_cache.crossover_key(**kw, bw=4)] = {"fused_n_max": "x"}
    import json
    with open(path, "w") as f:
        json.dump(doc, f)
    assert at_cache.lookup_crossover(**kw, bw=4, path=path) == 96  # wide


# ---------------------------------------------------------------------------
# 6. serve routing + per-tier metrics attribution
# ---------------------------------------------------------------------------

def _engine(**kw):
    return SVDEngine(tuning.PipelineConfig.resolve(bw=8, dtype=np.float64),
                     **kw)


def test_engine_routes_small_buckets_fused():
    eng = _engine()
    rng = np.random.default_rng(0)
    for i in range(3):
        eng.submit(SVDRequest(uid=i, matrix=rng.standard_normal((16, 16)),
                              bw=8))
    done = eng.run()
    assert all(r.error is None for r in done)
    snap = eng.metrics.snapshot()
    assert snap["tiers"]["fused"]["batches"] >= 1
    assert all(v["tier"] == "fused" and v["backend"] == "fused_small"
               for v in snap["bucket_tiers"].values())
    # sigma identical to a fused-disabled engine
    eng0 = _engine(fused_n_max=0)
    m = rng.standard_normal((16, 16))
    r0 = SVDRequest(uid=0, matrix=m.copy(), bw=8)
    r1 = SVDRequest(uid=0, matrix=m.copy(), bw=8)
    eng0.submit(r0); eng0.run()
    eng1 = _engine(); eng1.submit(r1); eng1.run()
    np.testing.assert_allclose(r0.sigma, r1.sigma, atol=1e-12)
    snap0 = eng0.metrics.snapshot()
    assert "fused" not in snap0["tiers"]
    assert all(v["tier"] == "staged" for v in snap0["bucket_tiers"].values())


def test_engine_pinned_crossover_splits_tiers():
    eng = _engine(fused_n_max=32)
    rng = np.random.default_rng(1)
    for i, n in enumerate([16, 16, 48, 48]):
        eng.submit(SVDRequest(uid=i, matrix=rng.standard_normal((n, n)),
                              bw=8))
    done = eng.run()
    assert all(r.error is None for r in done)
    snap = eng.metrics.snapshot()
    tiers = {v["n"]: v["tier"] for v in snap["bucket_tiers"].values()}
    assert tiers == {16: "fused", 48: "staged"}
    assert snap["tiers"]["fused"]["batches"] >= 1
    assert snap["tiers"]["staged"]["batches"] >= 1
    # per-tier slots sum to the global dispatch counters
    assert (sum(t["served_slots"] for t in snap["tiers"].values())
            == snap["served_slots"])
    assert (sum(t["padded_slots"] for t in snap["tiers"].values())
            == snap["padded_slots"])


def test_engine_honors_tuned_crossover(tmp_path):
    path = str(tmp_path / "cache.json")
    at_cache.store_crossover(
        {"fused_n_max": 20}, device_kind=at_model.device_kind(),
        dtype="float64", compute_uv=False, path=path)
    eng = _engine(autotune=True, autotune_cache=path)
    rng = np.random.default_rng(2)
    for i, n in enumerate([16, 24]):
        eng.submit(SVDRequest(uid=i, matrix=rng.standard_normal((n, n)),
                              bw=8))
    eng.run()
    tiers = {v["n"]: v["tier"]
             for v in eng.metrics.snapshot()["bucket_tiers"].values()}
    assert tiers == {16: "fused", 24: "staged"}     # 20 from the cache
    # autotune off: the static default (256) routes both fused
    eng2 = _engine()
    assert eng2._fused_n_max_for((16, 8, "float64", False, False)) \
        == tuning.DEFAULT_FUSED_CROSSOVER


def test_engine_fused_vmem_fallback_to_staged():
    """n under the pinned crossover but over the fused VMEM budget must be
    served (staged), not failed."""
    eng = _engine(fused_n_max=10_000)
    big = 4096
    assert pytest.raises(
        ValueError, tuning.check_fused_vmem_budget, big, np.float64)
    key = (big, 8, "float64", False, False)
    cfg = eng._cfg_for(key)
    assert cfg.backend != "fused_small"
    snap = eng.metrics.snapshot()
    # n=4096 sits past the stage-3 D&C crossover, so the staged fallback
    # is attributed to the "staged-dc" tier (DESIGN.md §14).
    from repro.serve import bucket_key_str
    assert snap["bucket_tiers"][bucket_key_str(key)]["tier"] == "staged-dc"


def test_async_engine_fused_roundtrip():
    eng = AsyncSVDEngine(tuning.PipelineConfig.resolve(bw=8,
                                                       dtype=np.float64),
                         fused_n_max=32, batch_window_s=0.0)
    eng.start()
    try:
        rng = np.random.default_rng(3)
        mats = [rng.standard_normal((16, 16)) for _ in range(4)]
        futs = [eng.submit(SVDRequest(uid=i, matrix=m, bw=8))
                for i, m in enumerate(mats)]
        for f, m in zip(futs, mats):
            r = f.result(timeout=60)
            assert r.error is None
            np.testing.assert_allclose(r.sigma, lapack_sigma(m[None])[0],
                                       atol=1e-11)
    finally:
        eng.stop()
    snap = eng.metrics.snapshot()
    assert snap["tiers"]["fused"]["batches"] >= 1
    assert all(v["tier"] == "fused"
               for v in snap["bucket_tiers"].values())


# ---------------------------------------------------------------------------
# 7. hypothesis-randomized property sweep (skips without the optional dep)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(1, 40), st.integers(0, 10), st.integers(0, 2**31 - 1))
def test_fused_property_randomized(n, bw, seed):
    a = dense(n, batch=1, seed=seed)
    sig = np.asarray(kref.fused_small_svd_ref(jnp.asarray(a), bw=bw))
    s0 = lapack_sigma(a)
    np.testing.assert_allclose(sig, s0, atol=1e-11 * max(1.0, s0.max()))
    assert np.all(np.diff(sig[0]) <= 1e-12)       # descending
