"""Unit + property tests for the core numerics: Householder reflectors,
packed band storage, and the Golub-Kahan stage-3 bisection."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import band as bandmod
from repro.core import householder as hh
from repro.core.bidiag_svd import bidiag_singular_values, sturm_count, gk_offdiag


# ---------------------------------------------------------------------------
# Householder
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(2, 24), st.integers(0, 2**31 - 1))
def test_reflector_annihilates(L, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(L))
    v, tau, beta = hh.make_reflector(x)
    y = hh.apply_left(v, tau, x[:, None])[:, 0]
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(beta), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(y[1:]), 0, atol=1e-12 * float(jnp.abs(x).max()))
    # norm preserved (orthogonality)
    np.testing.assert_allclose(float(jnp.linalg.norm(y)), float(jnp.linalg.norm(x)),
                               rtol=1e-12)
    assert float(v[0]) == 1.0


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 16))
def test_reflector_zero_tail_is_identity(L):
    x = jnp.zeros(L).at[0].set(3.5)
    v, tau, beta = hh.make_reflector(x)
    assert float(tau) == 0.0 and float(beta) == 3.5


def test_reflector_matrix_orthogonal():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(9))
    v, tau, _ = hh.make_reflector(x)
    q = hh.reflector_matrix(v, tau)
    np.testing.assert_allclose(np.asarray(q @ q.T), np.eye(9), atol=1e-12)


def test_reflector_bf16_tolerates_low_precision():
    x = jnp.asarray(np.random.default_rng(1).standard_normal(16), jnp.bfloat16)
    v, tau, beta = hh.make_reflector(x)
    y = hh.apply_left(v, tau, x[:, None])[:, 0]
    assert abs(float(y[0]) - float(beta)) < 0.05
    assert float(jnp.max(jnp.abs(y[1:]))) < 0.05


# ---------------------------------------------------------------------------
# Band storage
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(4, 40), st.integers(1, 8), st.integers(0, 4),
       st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(n, bw, tw, seed):
    bw = min(bw, n - 1)
    rng = np.random.default_rng(seed)
    a = np.triu(rng.standard_normal((n, n)))
    a = np.triu(a) - np.triu(a, bw + 1)          # upper banded, bandwidth bw
    packed = bandmod.pack(jnp.asarray(a), bw, tw)
    assert packed.shape == (bandmod.band_height(bw, tw), n)
    back = bandmod.unpack(packed, bw, tw, n)
    np.testing.assert_allclose(np.asarray(back), a, atol=0)


def test_bandwidth_of():
    a = np.zeros((8, 8))
    a[0, 3] = 1.0
    assert int(bandmod.bandwidth_of(jnp.asarray(a))) == 3


def test_band_diag_helpers():
    n, bw, tw = 10, 3, 1
    a = np.triu(np.random.default_rng(2).standard_normal((n, n)))
    a = np.triu(a) - np.triu(a, bw + 1)
    packed = bandmod.pack(jnp.asarray(a), bw, tw)
    d = bandmod.band_extract_diag(packed, tw, 0, n)
    e = bandmod.band_extract_diag(packed, tw, 1, n)
    np.testing.assert_allclose(np.asarray(d), np.diag(a))
    np.testing.assert_allclose(np.asarray(e)[1:], np.diag(a, 1))


# ---------------------------------------------------------------------------
# Stage 3 (Golub-Kahan bisection)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(2, 60), st.integers(0, 2**31 - 1))
def test_bidiag_singular_values_match_lapack(n, seed):
    rng = np.random.default_rng(seed)
    d = rng.standard_normal(n)
    e = rng.standard_normal(n)
    e[0] = 0.0
    B = np.diag(d) + np.diag(e[1:], 1)
    s_ref = np.linalg.svd(B, compute_uv=False)
    s = np.asarray(bidiag_singular_values(jnp.asarray(d), jnp.asarray(e)))
    np.testing.assert_allclose(s, s_ref, atol=1e-12 * max(1.0, s_ref[0]))


def test_sturm_count_monotone_and_bounded():
    rng = np.random.default_rng(3)
    d, e = rng.standard_normal(20), rng.standard_normal(20)
    e[0] = 0
    z = gk_offdiag(jnp.asarray(d), jnp.asarray(e))
    lams = jnp.linspace(0.01, 10.0, 17)
    counts = np.asarray(jax.vmap(lambda l: sturm_count(z, l))(lams))
    assert (np.diff(counts) >= 0).all()
    assert counts[-1] <= 40


def test_bidiag_sv_fp32():
    rng = np.random.default_rng(4)
    n = 48
    d = rng.standard_normal(n).astype(np.float32)
    e = rng.standard_normal(n).astype(np.float32)
    e[0] = 0
    B = np.diag(d.astype(np.float64)) + np.diag(e[1:].astype(np.float64), 1)
    s_ref = np.linalg.svd(B, compute_uv=False)
    s = np.asarray(bidiag_singular_values(jnp.asarray(d), jnp.asarray(e)))
    np.testing.assert_allclose(s, s_ref, rtol=2e-5, atol=2e-6 * s_ref[0])
