"""Batch-native pipeline tests: scheduling invariants, (B, ...) equivalence
against the sequential dense oracle and the per-matrix path, the unified
PipelineConfig/backend-registry layer, and the serve-layer bucketed path.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import band as bandmod
from repro.core import bidiag_svd
from repro.core import bulge_chasing as bc
from repro.core import tuning
from repro.core import svd as svdmod
from repro.core.stage1 import band_reduce
from repro.core.tuning import PipelineConfig
from repro.kernels import ops
from repro.serve import SVDEngine, SVDRequest


def banded_random(n, bw, seed, dtype=np.float64):
    rng = np.random.default_rng(seed)
    a = np.triu(rng.standard_normal((n, n)))
    return (np.triu(a) - np.triu(a, bw + 1)).astype(dtype)


# ---------------------------------------------------------------------------
# Scheduling invariants (paper §III-A dependency analysis, deterministic)
# ---------------------------------------------------------------------------

SCHED_CASES = [(16, 2, 1), (24, 4, 2), (32, 8, 4), (33, 7, 6), (48, 5, 2),
               (57, 9, 4), (100, 16, 8), (200, 32, 16), (8, 3, 1)]


@pytest.mark.parametrize("n,b_in,tw", SCHED_CASES)
def test_wavefront_windows_pairwise_disjoint(n, b_in, tw):
    """Every global cycle: all active slots own pairwise-disjoint windows
    (pivot stride >= window width W), so the fused scatter is race-free."""
    nsweeps, total, G = bc.stage_schedule(n, b_in, tw)
    if nsweeps == 0:
        return
    W = b_in + tw + 1
    g = np.arange(G)
    for t in range(total):
        _, _, p, active, _ = bc.chase_cycle_indices(t, g, n, b_in, tw)
        ps = np.sort(np.asarray(p)[np.asarray(active)])
        if len(ps) > 1:
            assert (np.diff(ps) >= W).all(), (t, ps, W)


@pytest.mark.parametrize("fuse", [1, 2, 4, 8])
@pytest.mark.parametrize("n,b_in,tw", SCHED_CASES)
def test_fused_wavefront_windows_pairwise_disjoint(n, b_in, tw, fuse):
    """Generalized (fuse-K) schedule, DESIGN.md §9: every super-cycle's
    active slots own pairwise-disjoint FUSED windows — base-pivot stride
    >= W_K = K*b_in + tw + 1, so the contiguous column-block scatter is
    race-free.  K=1 degenerates to the 3-cycle rule proven above."""
    nsweeps, total, G = bc.stage_schedule(n, b_in, tw, fuse)
    if nsweeps == 0:
        return
    WK = fuse * b_in + tw + 1
    sep = tuning.sweep_separation(fuse)
    assert sep * fuse * b_in - 1 >= WK      # the schedule's design inequality
    g = np.arange(G)
    for t in range(total):
        _, _, p, active, _ = bc.chase_cycle_indices(t, g, n, b_in, tw, fuse)
        ps = np.sort(np.asarray(p)[np.asarray(active)])
        if len(ps) > 1:
            assert (np.diff(ps) >= WK).all(), (t, ps, WK)


@pytest.mark.parametrize("n", [8, 16, 33, 57, 100, 200])
@pytest.mark.parametrize("b_in", [2, 4, 8, 16])
def test_stage_schedule_concurrency_matches_tuning(n, b_in):
    """stage_schedule's wavefront width == tuning.max_concurrent_sweeps."""
    for tw in {1, max(1, b_in // 2), b_in - 1}:
        if tw < 1:
            continue
        _, _, conc = bc.stage_schedule(n, b_in, tw)
        assert conc == tuning.max_concurrent_sweeps(n, b_in)


def test_stage_plan_is_tw_schedule():
    for bw in range(2, 40):
        for tw in (1, 3, 8, 31):
            assert list(tuning.stage_plan(bw, tw)) == bc.tw_schedule(bw, tw)


# ---------------------------------------------------------------------------
# Batched band storage
# ---------------------------------------------------------------------------

def test_batched_pack_unpack_roundtrip():
    n, bw, tw, B = 20, 5, 2, 3
    mats = np.stack([banded_random(n, bw, s) for s in range(B)])
    packed = bandmod.pack(jnp.asarray(mats), bw, tw)
    assert packed.shape == (B, bandmod.band_height(bw, tw), n)
    back = np.asarray(bandmod.unpack(packed, bw, tw, n))
    np.testing.assert_array_equal(back, mats)
    # batched path == per-matrix path, bit-exact
    for b in range(B):
        one = bandmod.pack(jnp.asarray(mats[b]), bw, tw)
        np.testing.assert_array_equal(np.asarray(packed[b]), np.asarray(one))
    widths = np.asarray(bandmod.bandwidth_of(jnp.asarray(mats)))
    assert widths.shape == (B,) and (widths <= bw).all()


# ---------------------------------------------------------------------------
# Batched wavefront stage vs looped vs sequential dense oracle
# ---------------------------------------------------------------------------

def test_batched_stage_equals_looped_and_oracle():
    n, bw, tw, B = 33, 7, 3, 5
    mats = np.stack([banded_random(n, bw, 10 + s) for s in range(B)])
    packed = bandmod.pack(jnp.asarray(mats), bw, tw)
    out = bc.reduce_stage_packed(packed, n=n, b_in=bw, tw=tw, backend="ref")
    for b in range(B):
        looped = bc.reduce_stage_packed(packed[b], n=n, b_in=bw, tw=tw,
                                        backend="ref")
        np.testing.assert_array_equal(np.asarray(out[b]), np.asarray(looped))
        ref = bc.reduce_stage_dense_ref(mats[b], bw, tw)
        dense = np.asarray(bandmod.unpack(out[b], bw, tw, n))
        np.testing.assert_allclose(dense, ref, atol=1e-11)


def test_batched_bidiagonalize_matches_dense_oracle():
    n, bw, tw, B = 28, 6, 2, 4
    mats = np.stack([banded_random(n, bw, 20 + s) for s in range(B)])
    d, e = bc.bidiagonalize(jnp.asarray(mats), bw=bw, tw=tw, backend="ref")
    assert d.shape == (B, n) and e.shape == (B, n)
    for b in range(B):
        dref, eref, _ = bc.bidiagonalize_dense_ref(mats[b], bw, tw)
        np.testing.assert_allclose(np.asarray(d[b]), dref, atol=1e-10)
        np.testing.assert_allclose(np.asarray(e[b])[1:], eref, atol=1e-10)


def test_batched_band_reduce_structure_and_sigma():
    n, nb, B = 40, 8, 3
    mats = np.random.default_rng(1).standard_normal((B, n, n))
    out = np.asarray(band_reduce(jnp.asarray(mats), nb=nb))
    assert out.shape == (B, n, n)
    for b in range(B):
        assert np.abs(np.tril(out[b], -1)).max() == 0.0
        assert np.abs(np.triu(out[b], nb + 1)).max() == 0.0
        s0 = np.linalg.svd(mats[b], compute_uv=False)
        s1 = np.linalg.svd(out[b], compute_uv=False)
        np.testing.assert_allclose(s1, s0, atol=1e-12 * s0[0])


def test_batched_bidiag_singular_values():
    n, B = 24, 4
    rng = np.random.default_rng(2)
    d = rng.standard_normal((B, n))
    e = rng.standard_normal((B, n))
    e[:, 0] = 0.0
    sig = np.asarray(bidiag_svd.bidiag_singular_values(jnp.asarray(d),
                                                       jnp.asarray(e)))
    assert sig.shape == (B, n)
    for b in range(B):
        Bmat = np.diag(d[b]) + np.diag(e[b][1:], 1)
        s_ref = np.linalg.svd(Bmat, compute_uv=False)
        np.testing.assert_allclose(sig[b], s_ref, atol=1e-12 * max(1, s_ref[0]))


# ---------------------------------------------------------------------------
# Acceptance sweep: batched == per-matrix, B in {1, 3, 8}, fp32/fp64,
# two (n, bw) shapes, ref + pallas(interpret) backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5), (jnp.float64, 1e-10)])
@pytest.mark.parametrize("n,bw", [(24, 4), (32, 8)])
def test_batched_matches_per_matrix(n, bw, dtype, tol, backend):
    tw = max(1, bw // 2)
    rng = np.random.default_rng(n * 10 + bw)
    mats = rng.standard_normal((8, n, n)).astype(np.float64)
    stacked = jnp.asarray(mats, dtype)
    per = np.stack([
        np.asarray(svdmod.singular_values(stacked[b], bw=bw, tw=tw,
                                          backend=backend), np.float64)
        for b in range(8)])
    smax = max(1.0, per.max())
    # fp64 oracle: the batched path must stay within oracle tolerance of LAPACK
    if dtype == jnp.float64:
        oracle = np.stack([np.linalg.svd(mats[b], compute_uv=False)
                           for b in range(8)])
        np.testing.assert_allclose(per, oracle, atol=1e-10 * smax)
    for B in (1, 3, 8):
        sig = np.asarray(
            svdmod.batched_singular_values(stacked[:B], bw=bw, tw=tw,
                                           backend=backend), np.float64)
        assert sig.shape == (B, n)
        np.testing.assert_allclose(sig, per[:B], atol=tol * smax)


def test_svd_batched_config_entry_point():
    n, bw, B = 24, 4, 3
    mats = np.random.default_rng(3).standard_normal((B, n, n))
    cfg = PipelineConfig.resolve(bw=bw, tw=2, backend="ref",
                                 dtype=np.float64, n=n)
    sig = np.asarray(svdmod.svd_batched(jnp.asarray(mats), config=cfg))
    legacy = np.asarray(svdmod.batched_singular_values(
        jnp.asarray(mats), bw=bw, tw=2, backend="ref"))
    np.testing.assert_array_equal(sig, legacy)


# ---------------------------------------------------------------------------
# PipelineConfig + backend registry
# ---------------------------------------------------------------------------

def test_multi_leading_batch_axes():
    """The (..., n, n) contract holds beyond one batch axis (e.g. stacked
    scan-layer weights (L, B, n, n))."""
    mats = np.random.default_rng(5).standard_normal((2, 3, 16, 16))
    sig = np.asarray(svdmod.singular_values(jnp.asarray(mats), bw=4, tw=2,
                                            backend="ref"))
    assert sig.shape == (2, 3, 16)
    for i in range(2):
        for j in range(3):
            s0 = np.linalg.svd(mats[i, j], compute_uv=False)
            np.testing.assert_allclose(sig[i, j], s0, atol=1e-10 * s0[0])


def test_config_conflicts_raise():
    cfg = PipelineConfig.resolve(bw=8, tw=4, backend="ref", dtype=np.float64)
    mats = jnp.zeros((1, 16, 16), jnp.float64)
    with pytest.raises(ValueError, match="conflicts"):
        svdmod.batched_singular_values(mats, bw=16, config=cfg)
    with pytest.raises(ValueError, match="conflicts"):
        svdmod.batched_singular_values(mats, tw=2, config=cfg)
    with pytest.raises(ValueError, match="conflicts"):
        svdmod.batched_singular_values(mats, backend="pallas", config=cfg)
    with pytest.raises(ValueError, match="conflicts"):
        svdmod.batched_singular_values(mats.astype(jnp.float32), config=cfg)
    # matching kwargs are fine
    svdmod.batched_singular_values(mats, bw=8, tw=4, backend="ref", config=cfg)


def test_config_cache_key_ignores_max_batch():
    """Configs differing only in serve-side bucket sizing must not recompile
    the numeric pipeline (kernel() normalization)."""
    import dataclasses
    cfg1 = PipelineConfig.resolve(bw=4, tw=2, backend="ref", dtype=np.float64)
    cfg2 = dataclasses.replace(cfg1, max_batch=cfg1.max_batch + 7)
    assert cfg1.kernel() == cfg2.kernel()
    mats = jnp.asarray(np.random.default_rng(8).standard_normal((2, 12, 12)))
    s1_ = svdmod.svd_batched(mats, config=cfg1)
    misses0 = svdmod._three_stage._cache_size()
    s2_ = svdmod.svd_batched(mats, config=cfg2)
    assert svdmod._three_stage._cache_size() == misses0   # no new trace
    np.testing.assert_array_equal(np.asarray(s1_), np.asarray(s2_))


def test_stage1_config_backend_routes_pallas():
    """A resolved pallas config drives stage 1 through the WY kernel too —
    bit-exact vs the ref backend, including batched (vmapped pallas_call)."""
    rng = np.random.default_rng(7)
    a = rng.standard_normal((2, 32, 32))
    cfg = PipelineConfig.resolve(bw=8, backend="pallas", interpret=True,
                                 dtype=np.float64)
    b_ref = np.asarray(band_reduce(jnp.asarray(a), nb=8, backend="ref"))
    b_cfg = np.asarray(band_reduce(jnp.asarray(a), nb=8, config=cfg))
    np.testing.assert_array_equal(b_cfg, b_ref)
    # explicit backend kwarg wins over the config
    b_exp = np.asarray(band_reduce(jnp.asarray(a), nb=8, backend="ref",
                                   config=cfg))
    np.testing.assert_array_equal(b_exp, b_ref)


def test_pipeline_config_resolution():
    cfg = PipelineConfig.resolve(bw=16, dtype=jnp.float32)
    assert cfg.backend in ops.backend_names()          # never "auto"
    assert cfg.tw == tuning.default_tilewidth(16, jnp.float32)
    assert cfg.plan == tuning.stage_plan(cfg.bw, cfg.tw)
    assert cfg.dtype == "float32"
    assert hash(cfg) == hash(PipelineConfig.resolve(bw=16, dtype=jnp.float32))
    # per-stage view agrees with the legacy ChaseConfig
    ch = cfg.chase(256)
    assert ch.tw == cfg.tw and ch.b_in == cfg.bw
    # explicit tw is clamped to the band
    assert PipelineConfig.resolve(bw=4, tw=99).tw == 3


def test_registry_resolution_and_errors():
    name, interp = ops.resolve_backend("auto")
    assert name in ops.backend_names()
    assert {"ref", "pallas"} <= set(ops.backend_names())
    with pytest.raises(ValueError):
        ops.resolve_backend("nope")
    with pytest.raises(ValueError):
        ops.chase_cycle(jnp.zeros((1, 8, 6)), jnp.zeros((1,), bool),
                        b_in=3, tw=2, backend="nope")


def test_default_bucket_batch_fills_wavefront():
    for n, bw in [(24, 4), (32, 8), (256, 32), (4096, 32)]:
        B = tuning.default_bucket_batch(n, bw)
        assert 1 <= B <= 64
        # batching must reach the occupancy target a single matrix may miss
        assert B * tuning.max_concurrent_sweeps(n, bw) >= 16 or B == 64
    # big matrices already saturate: no batching needed
    assert tuning.default_bucket_batch(100_000, 32) == 1


# ---------------------------------------------------------------------------
# Serve layer: bucketed path == direct batched calls
# ---------------------------------------------------------------------------

def test_serve_bucketed_matches_direct_batched():
    rng = np.random.default_rng(4)
    small = rng.standard_normal((5, 24, 24))           # bucket (24, 4, f64)
    large = rng.standard_normal((3, 32, 32))           # bucket (32, 8, f64)
    eng = SVDEngine(PipelineConfig.resolve(bw=4, tw=2, backend="ref",
                                           dtype=np.float64, max_batch=4))
    uid = 0
    for m in small:
        eng.submit(SVDRequest(uid=uid, matrix=m, bw=4)); uid += 1
    for m in large:
        eng.submit(SVDRequest(uid=uid, matrix=m, bw=8)); uid += 1
    done = eng.run()
    assert len(done) == 8 and all(r.done for r in done)
    assert eng.calls == 3                   # ceil(5/4) + ceil(3/4) flushes
    assert eng.pending() == 0
    by_uid = {r.uid: r for r in done}
    direct_small = np.asarray(svdmod.batched_singular_values(
        jnp.asarray(small), bw=4, tw=2, backend="ref"))
    direct_large = np.asarray(svdmod.batched_singular_values(
        jnp.asarray(large), bw=8, tw=2, backend="ref"))
    for i in range(5):
        np.testing.assert_allclose(by_uid[i].sigma, direct_small[i],
                                   rtol=0, atol=1e-12)
    for i in range(3):
        np.testing.assert_allclose(by_uid[5 + i].sigma, direct_large[i],
                                   rtol=0, atol=1e-12)
    # and against the fp64 oracle
    for i in range(5):
        s0 = np.linalg.svd(small[i], compute_uv=False)
        np.testing.assert_allclose(by_uid[i].sigma, s0, atol=1e-10 * s0[0])


def test_serve_banded_requests():
    n, bw = 32, 6
    mats = [banded_random(n, bw, 30 + s) for s in range(3)]
    eng = SVDEngine(PipelineConfig.resolve(bw=bw, tw=3, backend="ref",
                                           dtype=np.float64, max_batch=4))
    for i, m in enumerate(mats):
        eng.submit(SVDRequest(uid=i, matrix=m, bw=bw, banded=True))
    done = eng.run()
    assert len(done) == 3
    for r in done:
        s0 = np.linalg.svd(mats[r.uid], compute_uv=False)
        np.testing.assert_allclose(r.sigma, s0, atol=1e-10 * s0[0])
