"""System tests for the paper's stage-2: wavefront bulge chasing.

The key invariants (hypothesis property tests + fixed cases):
  1. packed wavefront result == sequential dense oracle (element-exact
     modulo fp ordering — tight tolerance);
  2. singular values invariant under the whole reduction;
  3. bandwidth actually shrinks stage by stage, bulge space drains to zero;
  4. the 3-cycle wavefront schedule itself: concurrent windows are disjoint.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import band as bandmod
from repro.core import bulge_chasing as bc


def banded_random(n, bw, seed):
    rng = np.random.default_rng(seed)
    a = np.triu(rng.standard_normal((n, n)))
    return np.triu(a) - np.triu(a, bw + 1)


# ---------------------------------------------------------------------------
# wavefront == oracle
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(st.integers(12, 56), st.integers(2, 10), st.integers(1, 6),
       st.integers(0, 2**31 - 1))
def test_stage_matches_sequential_oracle(n, bw, tw, seed):
    bw = min(bw, n - 2)
    tw = min(tw, bw - 1) if bw > 1 else 1
    if bw <= 1:
        return
    a = banded_random(n, bw, seed)
    ref = bc.reduce_stage_dense_ref(a, bw, tw)
    packed = bandmod.pack(jnp.asarray(a), bw, tw)
    out = bc.reduce_stage_packed(packed, n=n, b_in=bw, tw=tw, backend="ref")
    dense = np.asarray(bandmod.unpack(out, bw, tw, n))
    np.testing.assert_allclose(dense, ref, atol=1e-11 * max(1.0, np.abs(ref).max()))


@pytest.mark.parametrize("n,bw,tw", [(24, 5, 2), (48, 4, 3), (33, 7, 6),
                                     (64, 12, 4), (20, 2, 1), (57, 9, 4)])
def test_stage_fixed_cases(n, bw, tw):
    a = banded_random(n, bw, seed=n * 100 + bw)
    ref = bc.reduce_stage_dense_ref(a, bw, tw)
    packed = bandmod.pack(jnp.asarray(a), bw, tw)
    out = bc.reduce_stage_packed(packed, n=n, b_in=bw, tw=tw, backend="ref")
    dense = np.asarray(bandmod.unpack(out, bw, tw, n))
    np.testing.assert_allclose(dense, ref, atol=1e-11)
    # bandwidth reduced, bulge space drained
    assert int(bandmod.bandwidth_of(jnp.asarray(dense), tol=1e-10)) <= bw - tw
    assert np.abs(np.tril(dense, -1)).max() < 1e-10


# ---------------------------------------------------------------------------
# full reduction: singular values preserved
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(16, 48), st.integers(2, 12), st.integers(1, 8),
       st.integers(0, 2**31 - 1))
def test_full_reduction_preserves_sigma(n, bw, tw, seed):
    bw = min(bw, n - 2)
    if bw < 1:
        return
    a = banded_random(n, bw, seed)
    d, e = bc.bidiagonalize(jnp.asarray(a), bw=bw, tw=tw, backend="ref")
    B = np.diag(np.asarray(d)) + np.diag(np.asarray(e)[1:], 1)
    s0 = np.linalg.svd(a, compute_uv=False)
    s1 = np.linalg.svd(B, compute_uv=False)
    np.testing.assert_allclose(s1, s0, atol=1e-10 * max(1.0, s0[0]))


def test_full_matches_dense_oracle_bidiagonal():
    n, bw, tw = 40, 6, 2
    a = banded_random(n, bw, 7)
    d, e = bc.bidiagonalize(jnp.asarray(a), bw=bw, tw=tw, backend="ref")
    dref, eref, _ = bc.bidiagonalize_dense_ref(a, bw, tw)
    np.testing.assert_allclose(np.asarray(d), dref, atol=1e-10)
    np.testing.assert_allclose(np.asarray(e)[1:], eref, atol=1e-10)


def test_already_bidiagonal_passthrough():
    n = 16
    a = np.diag(np.arange(1.0, n + 1)) + np.diag(np.ones(n - 1), 1)
    d, e = bc.bidiagonalize(jnp.asarray(a), bw=1, tw=4, backend="ref")
    np.testing.assert_allclose(np.asarray(d), np.arange(1.0, n + 1))
    np.testing.assert_allclose(np.asarray(e)[1:], np.ones(n - 1))


# ---------------------------------------------------------------------------
# schedule properties (paper §III-A dependency analysis)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(8, 200), st.integers(2, 32), st.integers(1, 16))
def test_wavefront_windows_disjoint(n, b_in, tw):
    tw = min(tw, b_in - 1)
    if tw < 1:
        return
    nsweeps, total, G = bc.stage_schedule(n, b_in, tw)
    if nsweeps == 0:
        return
    W = b_in + tw + 1
    g = np.arange(G)
    for t in range(0, total, max(1, total // 17)):
        _, _, p, active, _ = bc.chase_cycle_indices(t, g, n, b_in, tw)
        ps = np.sort(np.asarray(p)[np.asarray(active)])
        if len(ps) > 1:
            assert (np.diff(ps) >= W).all(), (t, ps, W)


@settings(max_examples=30, deadline=None)
@given(st.integers(8, 120), st.integers(2, 16), st.integers(1, 8))
def test_every_sweep_cycle_is_scheduled_once(n, b_in, tw):
    tw = min(tw, b_in - 1)
    if tw < 1:
        return
    nsweeps, total, G = bc.stage_schedule(n, b_in, tw)
    seen = set()
    g = np.arange(G)
    for t in range(total):
        R, j, p, active, _ = bc.chase_cycle_indices(t, g, n, b_in, tw)
        for Rv, jv, av in zip(np.asarray(R), np.asarray(j), np.asarray(active)):
            if av:
                key = (int(Rv), int(jv))
                assert key not in seen
                seen.add(key)
    # every (sweep, cycle) pair with a valid pivot must have been scheduled
    b_out = b_in - tw
    expected = {(R, j) for R in range(nsweeps)
                for j in range((n - 1 - R - b_out) // b_in + 1)}
    assert expected <= seen


def test_tw_schedule_reaches_bidiagonal():
    assert bc.tw_schedule(6, 2) == [(6, 2), (4, 2), (2, 1)]
    assert bc.tw_schedule(128, 32) == [(128, 32), (96, 32), (64, 32), (32, 31)]
    assert bc.tw_schedule(1, 32) == []
    for bw in range(2, 70):
        plan = bc.tw_schedule(bw, 8)
        assert plan[0][0] == bw
        left = bw - sum(tw for _, tw in plan)
        assert left == 1


def test_vector_accumulation_uv():
    """Beyond-paper (paper §VII future work): accumulate U, V during the
    chase so that U^T A V == B (bidiagonal), U/V orthogonal."""
    n, bw, tw = 36, 6, 2
    a = banded_random(n, bw, 13)
    d, e, u, v = bc.bidiagonalize_dense_ref_uv(a, bw, tw)
    B = u.T @ a @ v
    np.testing.assert_allclose(np.diag(B), d, atol=1e-11)
    np.testing.assert_allclose(np.diag(B, 1), e, atol=1e-11)
    off = B - np.diag(np.diag(B)) - np.diag(np.diag(B, 1), 1)
    assert np.abs(off).max() < 1e-11
    np.testing.assert_allclose(u.T @ u, np.eye(n), atol=1e-12)
    np.testing.assert_allclose(v.T @ v, np.eye(n), atol=1e-12)
    # and the bidiagonal carries the right singular values
    s0 = np.linalg.svd(a, compute_uv=False)
    s1 = np.linalg.svd(np.diag(d) + np.diag(e, 1), compute_uv=False)
    np.testing.assert_allclose(s1, s0, atol=1e-11 * s0[0])
