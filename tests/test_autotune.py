"""Autotune subsystem tests (DESIGN.md §11).

Four groups:

* cost-model properties — strictly cheaper with fuse until the VMEM cliff,
  monotone in n / bw / dtype byte-width, exact units vs a hand-computed
  small case;
* cache — round trip, atomicity contract (merge keeps other keys),
  corruption tolerance (garbage file reads as empty, half-written entries
  never half-configure);
* search — CPU ref end-to-end smoke: the returned config beats or ties
  the static default on measured time, the model ranks the measured best
  within top-K, injectable-measure unit behavior;
* integration — the acceptance loop: ``python -m repro.autotune`` (in
  process) persists an entry that ``PipelineConfig.resolve(autotune=True)``
  then picks up, including through ``SVDEngine``'s per-bucket resolution;
  plus the degenerate-edge guards (``default_fuse_depth`` floor,
  ``check_vmem_budget`` raising instead of silently mis-tiling).
"""

import json
import math
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.autotune import cache as at_cache
from repro.autotune import measure as at_measure
from repro.autotune import model as at_model
from repro.autotune import search as at_search
from repro.autotune.__main__ import main as autotune_main, parse_shapes
from repro.core import tuning

CPU = at_model.PROFILES["cpu"]


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------

class TestCostModel:
    def test_cost_strictly_decreases_with_fuse_until_vmem_cliff(self):
        # A budget that admits K in {1, 2, 4} but not 8: costs must fall
        # strictly while feasible, then hit the cliff (inf).
        budget = tuning.vmem_working_set_bytes(32, 8, fuse=4) + 1
        prof = at_model.DeviceProfile("t", mem_bw=CPU.mem_bw,
                                      launch_overhead_s=CPU.launch_overhead_s,
                                      fast_mem_bytes=budget,
                                      execution_units=1)
        costs = [at_model.stage_cost(1024, 32, 8, fuse=k, profile=prof)
                 for k in (1, 2, 4, 8)]
        assert costs[0].seconds > costs[1].seconds > costs[2].seconds
        assert math.isinf(costs[3].seconds) and not costs[3].feasible
        assert all(c.feasible for c in costs[:3])

    def test_monotone_in_n(self):
        costs = [at_model.stage_cost(n, 32, 8, profile=CPU).seconds
                 for n in (128, 256, 512, 1024)]
        assert costs == sorted(costs) and len(set(costs)) == len(costs)

    def test_monotone_in_bw_pipeline(self):
        # Whole bw -> 1 reduction: more bandwidth is strictly more work.
        costs = [at_model.pipeline_cost(512, bw, 8, profile=CPU)
                 for bw in (16, 32, 64)]
        assert costs == sorted(costs) and len(set(costs)) == len(costs)

    def test_monotone_in_dtype_bytes(self):
        f32 = at_model.stage_cost(512, 32, 8, dtype=jnp.float32, profile=CPU)
        f64 = at_model.stage_cost(512, 32, 8, dtype=jnp.float64, profile=CPU)
        assert f64.seconds > f32.seconds
        assert f64.bytes_moved == 2 * f32.bytes_moved

    def test_units_sanity_hand_computed(self):
        # n=16, b_in=4, tw=2, fuse=1, batch=1 on a 1 GB/s, 1 us-launch,
        # single-unit device.  By hand: H=9, W=7; cycles = sum_{r<13}
        # ((13-r)//4 + 1) = 31; bytes = 31 * 2*9*7 * 4 = 15624;
        # supercycles = 3*12 + 1 = 37.
        prof = at_model.DeviceProfile("hand", mem_bw=1e9,
                                      launch_overhead_s=1e-6,
                                      fast_mem_bytes=1 << 30,
                                      execution_units=1)
        c = at_model.stage_cost(16, 4, 2, profile=prof)
        assert c.cycles == 31
        assert c.bytes_moved == 15624.0
        assert c.supercycles == 37
        assert c.mem_seconds == pytest.approx(15624.0 / 1e9)
        assert c.launch_seconds == pytest.approx(37e-6)
        assert c.seconds == pytest.approx(c.mem_seconds + c.launch_seconds)

    def test_total_chase_cycles_matches_schedule_sum(self):
        # Against an independent enumeration of the wavefront schedule.
        n, b_in, tw = 64, 8, 3
        from repro.core import bulge_chasing as bc
        _, T, G = bc.stage_schedule(n, b_in, tw)
        executed = 0
        for t in range(T):
            for g in range(G):
                _, _, _, active, _ = bc.chase_cycle_indices(t, g, n, b_in, tw)
                executed += bool(active)
        assert at_model.total_chase_cycles(n, b_in, tw) == executed

    def test_occupancy_rewards_batch_until_saturation(self):
        prof = at_model.DeviceProfile("occ", mem_bw=1e9,
                                      launch_overhead_s=0.0,
                                      fast_mem_bytes=1 << 30,
                                      execution_units=256)
        per1 = at_model.stage_cost(64, 8, 3, batch=1, profile=prof)
        per8 = at_model.stage_cost(64, 8, 3, batch=8, profile=prof)
        # Under-occupied: 8x the work in less than 8x the time.
        assert per8.seconds < 8 * per1.seconds
        assert per8.occupancy == pytest.approx(8 * per1.occupancy)

    def test_profile_for_matches_and_falls_back(self):
        assert at_model.profile_for("TPU v5e").device_kind == "tpu v5e"
        assert at_model.profile_for("TPU v5 litepod-16") \
            .device_kind == "tpu v5e"
        assert at_model.profile_for("NVIDIA H100").device_kind == "gpu"
        assert at_model.profile_for("weird-accelerator").device_kind == "cpu"
        # The live device resolves to something in the table.
        assert at_model.profile_for() in at_model.PROFILES.values()


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------

KEY = dict(device_kind="testdev", n=128, bw=16, dtype="float32",
           compute_uv=False, backend="ref")


class TestCache:
    def test_round_trip(self, tmp_path):
        p = str(tmp_path / "cache.json")
        entry = {"tw": 8, "fuse": 2, "max_batch": 4, "measured_us": 12.5}
        assert at_cache.lookup(**KEY, path=p) is None
        at_cache.store(entry, **KEY, path=p)
        got = at_cache.lookup(**KEY, path=p)
        assert got["tw"] == 8 and got["fuse"] == 2 and got["max_batch"] == 4
        assert "tuned_at_unix" in got

    def test_merge_keeps_other_keys(self, tmp_path):
        p = str(tmp_path / "cache.json")
        other = dict(KEY, n=256)
        at_cache.store({"tw": 8, "fuse": 2, "max_batch": 4}, **KEY, path=p)
        at_cache.store({"tw": 4, "fuse": 1, "max_batch": 2}, **other, path=p)
        assert at_cache.lookup(**KEY, path=p)["tw"] == 8
        assert at_cache.lookup(**other, path=p)["tw"] == 4

    def test_corrupt_file_reads_empty_and_recovers(self, tmp_path):
        p = str(tmp_path / "cache.json")
        with open(p, "w") as f:
            f.write("{not json at all")
        assert at_cache.load(p)["entries"] == {}
        assert at_cache.lookup(**KEY, path=p) is None
        # store() over the corrupt file recovers it
        at_cache.store({"tw": 8, "fuse": 2, "max_batch": 4}, **KEY, path=p)
        assert at_cache.lookup(**KEY, path=p)["tw"] == 8
        json.load(open(p))                        # file is valid JSON again

    def test_wrong_schema_and_partial_entries_rejected(self, tmp_path):
        p = str(tmp_path / "cache.json")
        doc = {"version": 999, "entries": {at_cache.make_key(**KEY):
                                           {"tw": 8, "fuse": 2,
                                            "max_batch": 4}}}
        with open(p, "w") as f:
            json.dump(doc, f)
        assert at_cache.lookup(**KEY, path=p) is None   # version mismatch
        # Valid version but half-written entry (missing fuse): rejected.
        doc["version"] = at_cache.SCHEMA_VERSION
        doc["entries"][at_cache.make_key(**KEY)] = {"tw": 8, "max_batch": 4}
        with open(p, "w") as f:
            json.dump(doc, f)
        assert at_cache.lookup(**KEY, path=p) is None

    def test_env_var_overrides_path(self, tmp_path, monkeypatch):
        p = str(tmp_path / "env-cache.json")
        monkeypatch.setenv(at_cache.ENV_VAR, p)
        assert at_cache.cache_path() == p
        at_cache.store({"tw": 8, "fuse": 2, "max_batch": 4}, **KEY)
        assert os.path.exists(p)
        assert at_cache.lookup(**KEY)["tw"] == 8


# ---------------------------------------------------------------------------
# Search
# ---------------------------------------------------------------------------

class TestSearch:
    def test_grid_contains_anchors(self):
        grid = at_search.candidate_grid(512, 32)
        tws = {t for t, _, _ in grid}
        assert {1, 2, 4, 8, 16, 31} <= tws
        assert tuning.default_tilewidth(32, jnp.float32) in tws
        assert all(1 <= t <= 31 for t in tws)

    def test_model_pruning_with_injected_measure(self):
        # A fake measurement that inverts the model's opinion of fuse: the
        # search must still return the measured best, and the validation
        # table must expose the disagreement via the rank.
        calls = []

        def fake_measure(tw, fuse, batch):
            calls.append((tw, fuse, batch))
            return 1.0 + fuse * 0.5 + abs(tw - 8) * 0.01

        res = at_search.search(256, 16, backend="ref", top_k=3,
                               profile=CPU, measure_fn=fake_measure)
        # Only top-K (+ default if outside) measured — pruning is real.
        assert len(calls) == len(res.measured) <= 3 + 1
        best_by_fake = min(res.measured,
                           key=lambda c: fake_measure(c.tw, c.fuse, c.batch))
        assert (res.best.tw, res.best.fuse) == (best_by_fake.tw,
                                                best_by_fake.fuse)
        assert 1 <= res.model_rank_of_best() <= len(res.candidates)
        table = res.table()
        assert "measured_us" in table and "<- best" in table

    def test_default_always_measured_and_never_beaten_silently(self):
        def fake_measure(tw, fuse, batch):
            d_tw = tuning.default_tilewidth(16, jnp.float32)
            return 0.5 if (tw, fuse) == (d_tw, 1) else 1.0    # default wins

        res = at_search.search(256, 16, backend="ref", top_k=2,
                               profile=CPU, measure_fn=fake_measure)
        assert res.default in res.measured
        assert (res.best.tw, res.best.fuse) == (res.default.tw,
                                                res.default.fuse)
        assert res.best.measured_s <= res.default.measured_s

    def test_search_smoke_cpu_beats_or_ties_static_default(self):
        # Real measurements on the ref path, tiny shape: the tuned config
        # must beat or tie the static default (it is in the measured set).
        res = at_search.search(64, 8, backend="ref", top_k=2,
                               fuses=(1, 2), warmup=1, iters=1)
        assert res.best.measured_s is not None
        assert res.default.measured_s is not None
        assert res.best.measured_s <= res.default.measured_s
        assert res.model_rank_of_best() <= len(res.candidates)
        entry = res.to_entry()
        assert entry["tw"] >= 1 and entry["fuse"] >= 1
        # batches=(1,) means the batch axis was never searched: persisting
        # max_batch=1 would serialize serve bucketing, so it is omitted.
        assert "max_batch" not in entry

    def test_to_entry_round_trips_through_cache(self, tmp_path):
        res = at_search.search(256, 16, backend="ref", top_k=2, profile=CPU,
                               measure_fn=lambda tw, fuse, batch: 1.0)
        p = str(tmp_path / "cache.json")
        at_cache.store(res.to_entry(), device_kind="testdev", n=256, bw=16,
                       dtype="float32", compute_uv=False, backend="ref",
                       path=p)
        got = at_cache.lookup(device_kind="testdev", n=256, bw=16,
                              dtype="float32", compute_uv=False,
                              backend="ref", path=p)
        assert got["tw"] == res.best.tw and got["fuse"] == res.best.fuse

    def test_batch_searched_grid_persists_max_batch(self):
        res = at_search.search(256, 16, backend="ref", top_k=3, profile=CPU,
                               batches=(1, 2, 4),
                               measure_fn=lambda tw, fuse, batch:
                                   1.0 / (1 + 0.1 * batch))
        assert res.batch_searched
        assert res.to_entry()["max_batch"] == res.best.batch >= 1

    def test_empty_batches_raises_clearly(self):
        with pytest.raises(ValueError, match="non-empty"):
            at_search.search(64, 8, backend="ref", batches=(),
                             measure_fn=lambda *a: 1.0)
        with pytest.raises(SystemExit, match="batches"):
            autotune_main(["--shapes", "n=64:bw=8", "--backend", "ref",
                           "--batches", ","])


# ---------------------------------------------------------------------------
# Degenerate tuning edges (satellite bugfix)
# ---------------------------------------------------------------------------

class TestDegenerateEdges:
    def test_default_fuse_depth_never_below_one(self):
        for budget in (0, 1, -5, 100):
            assert tuning.default_fuse_depth(32, 8,
                                             budget_bytes=budget) == 1
        assert tuning.default_fuse_depth(32, 8, cap=0) == 1
        assert tuning.default_fuse_depth(32, 8, cap=-3) == 1

    def test_check_vmem_budget_raises_clearly(self):
        with pytest.raises(ValueError, match="fast memory"):
            tuning.check_vmem_budget(32, 8, budget_bytes=16)
        # Success returns the working-set size.
        need = tuning.check_vmem_budget(32, 8)
        assert need == tuning.vmem_working_set_bytes(32, 8)

    def test_pipeline_resolve_raises_on_infeasible_window(self):
        with pytest.raises(ValueError, match="fast memory"):
            tuning.PipelineConfig.resolve(bw=4096, tw=1024, n=8192,
                                          backend="ref")

    def test_chase_config_resolve_raises_on_infeasible_window(self):
        with pytest.raises(ValueError, match="fast memory"):
            tuning.ChaseConfig.resolve(8192, 4096, tw=1024)

    def test_normal_shapes_still_resolve(self):
        cfg = tuning.PipelineConfig.resolve(bw=64, n=1024, backend="ref",
                                            fuse=None)
        assert cfg.fuse >= 1
        tuning.ChaseConfig.resolve(1024, 64)


# ---------------------------------------------------------------------------
# Integration: CLI -> cache -> resolve(autotune=True) -> engine
# ---------------------------------------------------------------------------

class TestIntegration:
    def test_parse_shapes(self):
        assert parse_shapes("n=512:bw=32") == [(512, 32)]
        assert parse_shapes("n=512:bw=32, n=256:bw=16") == [(512, 32),
                                                            (256, 16)]
        with pytest.raises(SystemExit):
            parse_shapes("n=512")
        with pytest.raises(SystemExit):
            parse_shapes("")

    def test_cli_tunes_and_resolve_picks_up(self, tmp_path, monkeypatch,
                                            capsys):
        # The acceptance loop of ISSUE 4 on a CI-sized shape (the identical
        # command with n=512:bw=32 is exercised by the slow variant below
        # and the CI autotune smoke step).
        p = str(tmp_path / "cache.json")
        monkeypatch.setenv(at_cache.ENV_VAR, p)
        rc = autotune_main(["--shapes", "n=64:bw=8", "--backend", "ref",
                            "--top-k", "2", "--iters", "1"])
        assert rc == 0
        out = capsys.readouterr().out
        assert "predicted_us" in out and "measured_us" in out   # validation
        assert os.path.exists(p)
        entry = at_cache.lookup(device_kind=at_model.device_kind(), n=64,
                                bw=8, dtype="float32", compute_uv=False,
                                backend="ref", path=p)
        assert entry is not None

        cfg = tuning.PipelineConfig.resolve(n=64, bw=8, backend="ref",
                                            autotune=True)
        assert (cfg.tw, cfg.fuse) == (entry["tw"], entry["fuse"])
        # The default CLI grid has batches=(1,): max_batch is NOT tuned and
        # the Eq.-1 analytic bucket default must stay in charge.
        assert "max_batch" not in entry
        assert cfg.max_batch == tuning.default_bucket_batch(64, 8)
        # Model validation is printed and honest: the measured best sits
        # within the measured top-K by construction — assert the table
        # reports a finite rank.
        assert "model rank of measured best:" in out

    @pytest.mark.skipif(not os.environ.get("REPRO_AUTOTUNE_ACCEPT"),
                        reason="slow acceptance shape (n=512, minutes on "
                               "the CPU ref path); set "
                               "REPRO_AUTOTUNE_ACCEPT=1 to run")
    def test_cli_acceptance_shape_n512_bw32(self, tmp_path, monkeypatch):
        p = str(tmp_path / "cache.json")
        monkeypatch.setenv(at_cache.ENV_VAR, p)
        rc = autotune_main(["--shapes", "n=512:bw=32", "--backend", "ref",
                            "--top-k", "2", "--iters", "1"])
        assert rc == 0
        cfg = tuning.PipelineConfig.resolve(n=512, bw=32, backend="ref",
                                            autotune=True)
        entry = at_cache.lookup(device_kind=at_model.device_kind(), n=512,
                                bw=32, dtype="float32", compute_uv=False,
                                backend="ref", path=p)
        assert entry is not None and cfg.tw == entry["tw"]

    def test_resolve_explicit_kwargs_beat_cache(self, tmp_path, monkeypatch):
        p = str(tmp_path / "cache.json")
        monkeypatch.setenv(at_cache.ENV_VAR, p)
        at_cache.store({"tw": 3, "fuse": 4, "max_batch": 7},
                       device_kind=at_model.device_kind(), n=128, bw=16,
                       dtype="float32", compute_uv=False, backend="ref",
                       path=p)
        cfg = tuning.PipelineConfig.resolve(n=128, bw=16, backend="ref",
                                            autotune=True)
        assert (cfg.tw, cfg.fuse, cfg.max_batch) == (3, 4, 7)
        cfg2 = tuning.PipelineConfig.resolve(n=128, bw=16, backend="ref",
                                             tw=8, fuse=2, max_batch=2,
                                             autotune=True)
        assert (cfg2.tw, cfg2.fuse, cfg2.max_batch) == (8, 2, 2)

    def test_resolve_miss_falls_back_to_analytic_defaults(self, tmp_path,
                                                          monkeypatch):
        monkeypatch.setenv(at_cache.ENV_VAR, str(tmp_path / "empty.json"))
        with_at = tuning.PipelineConfig.resolve(n=128, bw=16, backend="ref",
                                                autotune=True)
        without = tuning.PipelineConfig.resolve(n=128, bw=16, backend="ref")
        assert with_at == without

    def test_resolve_entry_without_max_batch_keeps_eq1_default(
            self, tmp_path, monkeypatch):
        p = str(tmp_path / "cache.json")
        monkeypatch.setenv(at_cache.ENV_VAR, p)
        at_cache.store({"tw": 3, "fuse": 4},        # batch axis not searched
                       device_kind=at_model.device_kind(), n=128, bw=16,
                       dtype="float32", compute_uv=False, backend="ref",
                       path=p)
        cfg = tuning.PipelineConfig.resolve(n=128, bw=16, backend="ref",
                                            autotune=True)
        assert (cfg.tw, cfg.fuse) == (3, 4)
        assert cfg.max_batch == tuning.default_bucket_batch(128, 16)

    def test_engine_resolves_tuned_config_per_bucket(self, tmp_path,
                                                     monkeypatch):
        from repro.serve.engine import SVDEngine, SVDRequest
        p = str(tmp_path / "cache.json")
        monkeypatch.setenv(at_cache.ENV_VAR, p)
        n, bw = 24, 4
        at_cache.store({"tw": 2, "fuse": 2, "max_batch": 2},
                       device_kind=at_model.device_kind(), n=n, bw=bw,
                       dtype="float32", compute_uv=False, backend="ref",
                       path=p)
        rng = np.random.default_rng(0)
        a = np.triu(rng.standard_normal((n, n)).astype(np.float32))
        a = np.triu(a) - np.triu(a, bw + 1)

        eng = SVDEngine(backend="ref", autotune=True)
        for uid in range(3):
            eng.submit(SVDRequest(uid=uid, matrix=a, bw=bw))
        key = (n, bw, "float32", False, False)
        cfg = eng._cfg_for(key)
        assert (cfg.tw, cfg.fuse, cfg.max_batch) == (2, 2, 2)
        assert eng._cfg_for(key) is cfg          # memoized per bucket
        done = eng.run()
        assert len(done) == 3 and eng.calls == 2  # 3 reqs / bucket of 2
        ref = np.linalg.svd(a.astype(np.float64), compute_uv=False)
        np.testing.assert_allclose(done[0].sigma, ref, atol=1e-4)

    def test_engine_autotune_miss_matches_default_engine(self, tmp_path,
                                                         monkeypatch):
        from repro.serve.engine import SVDEngine
        monkeypatch.setenv(at_cache.ENV_VAR, str(tmp_path / "none.json"))
        key = (24, 4, "float32", False, False)
        tuned = SVDEngine(backend="ref", autotune=True)._cfg_for(key)
        plain = SVDEngine(backend="ref")._cfg_for(key)
        assert tuned == plain

    def test_engine_autotune_miss_keeps_explicit_config(self, tmp_path,
                                                        monkeypatch):
        # An explicitly-configured engine with an empty cache must not have
        # its tw/fuse silently replaced by the analytic defaults.
        from repro.serve.engine import SVDEngine
        monkeypatch.setenv(at_cache.ENV_VAR, str(tmp_path / "none.json"))
        base = tuning.PipelineConfig.resolve(bw=16, tw=4, fuse=2,
                                             backend="ref")
        cfg = SVDEngine(base, autotune=True)._cfg_for(
            (128, 16, "float32", False, False))
        assert (cfg.tw, cfg.fuse) == (4, 2)


# ---------------------------------------------------------------------------
# Shared timing harness
# ---------------------------------------------------------------------------

class TestMeasure:
    def test_measure_seconds_median(self):
        calls = []

        def fn():
            calls.append(1)
            return jnp.zeros(())

        t = at_measure.measure_seconds(fn, warmup=2, iters=3)
        assert t >= 0.0 and len(calls) == 5

    def test_time_stage2_runs_and_is_positive(self):
        t = at_measure.time_stage2(24, 4, tw=2, backend="ref",
                                   warmup=0, iters=1)
        assert t > 0.0

    def test_banded_input_shape_and_bandwidth(self):
        from repro.core import band as bandmod
        a = at_measure.banded_input(16, 3, batch=2)
        assert a.shape == (2, 16, 16)
        assert int(jnp.max(bandmod.bandwidth_of(a))) <= 3
        assert bool(jnp.all(jnp.tril(a[0], -1) == 0))

    def test_benchmarks_common_delegates_here(self):
        import importlib.util
        spec = importlib.util.spec_from_file_location(
            "bench_common", os.path.join(os.path.dirname(__file__), "..",
                                         "benchmarks", "common.py"))
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        assert mod.measure_seconds is at_measure.measure_seconds
