"""Serving-tier tests: admission edge cases, FIFO/error regressions for the
sync ``SVDEngine``, and the async micro-batching ``AsyncSVDEngine``
(futures, deadlines, thread-safety, queue bounds, mesh dispatch)."""

import asyncio
import threading
import time

import numpy as np
import jax
import pytest

from repro.core.tuning import PipelineConfig
from repro.serve import (AsyncSVDEngine, QueueFullError, SVDEngine,
                         SVDRequest)

needs_axis_type = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="jax.sharding.AxisType unavailable on this jax "
           "(pre-existing seed failure, DESIGN.md §10)")


def cfg4(max_batch=4):
    return PipelineConfig.resolve(bw=4, tw=2, backend="ref",
                                  dtype=np.float64, max_batch=max_batch)


def dense(seed, n=16):
    return np.random.default_rng(seed).standard_normal((n, n))


def check_sigma(req, atol_scale=1e-10):
    s0 = np.linalg.svd(req.matrix, compute_uv=False)
    np.testing.assert_allclose(req.sigma, s0, atol=atol_scale * s0[0])


# ---------------------------------------------------------------------------
# sync engine: admission edges + FIFO/error regressions
# ---------------------------------------------------------------------------

def test_empty_step_is_noop():
    eng = SVDEngine(cfg4())
    assert eng.step() == 0
    assert eng.calls == 0 and eng.finished == []
    assert eng.metrics.snapshot()["batches"] == 0


def test_oversize_bucket_splits_at_max_batch():
    eng = SVDEngine(cfg4(max_batch=4))
    for i in range(10):
        eng.submit(SVDRequest(uid=i, matrix=dense(i), bw=4))
    done = eng.run()
    assert len(done) == 10 and eng.calls == 3          # 4 + 4 + 2
    snap = eng.metrics.snapshot()
    assert snap["served_slots"] == 10 and snap["padded_slots"] == 2
    for r in done:
        check_sigma(r)


def test_fifo_completion_order_within_bucket():
    """Regression: results complete in submission order, across splits."""
    eng = SVDEngine(cfg4(max_batch=4))
    for i in range(9):
        eng.submit(SVDRequest(uid=i, matrix=dense(i), bw=4))
    done = eng.run()
    assert [r.uid for r in done] == list(range(9))


def test_mixed_dtype_requests_never_share_a_bucket():
    eng = SVDEngine(cfg4(max_batch=8))
    for i in range(2):
        eng.submit(SVDRequest(uid=i, matrix=dense(i), bw=4))
    for i in range(2, 4):
        eng.submit(SVDRequest(uid=i, matrix=dense(i).astype(np.float32),
                              bw=4))
    assert len(eng.buckets) == 2                      # dtype splits the key
    done = eng.run()
    assert eng.calls == 2 and len(done) == 4          # one flush per dtype
    for r in done:
        assert r.sigma.dtype == r.matrix.dtype
        check_sigma(r, atol_scale=1e-10 if r.matrix.dtype == np.float64
                    else 1e-5)


def test_per_request_error_surfaces_on_request_not_step():
    """Regression: an un-servable bucket (VMEM-infeasible bw) must fail its
    OWN requests via ``req.error`` — never raise out of step()/run() or
    poison other buckets, and never silently drop requests."""
    eng = SVDEngine(cfg4())
    bad = SVDRequest(uid=7, matrix=np.zeros((4096, 4096), np.float32),
                     bw=4096)
    eng.submit(SVDRequest(uid=0, matrix=dense(0), bw=4))
    eng.submit(bad)
    eng.submit(SVDRequest(uid=1, matrix=dense(1), bw=4))
    done = eng.run()
    assert len(done) == 3 and eng.pending() == 0
    assert bad.done and isinstance(bad.error, ValueError)
    assert bad.sigma is None
    good = [r for r in done if r.error is None]
    assert [r.uid for r in good] == [0, 1]            # FIFO kept around error
    for r in good:
        check_sigma(r)
    snap = eng.metrics.snapshot()
    assert snap["completed"] == 2 and snap["failed"] == 1


# ---------------------------------------------------------------------------
# async engine
# ---------------------------------------------------------------------------

def test_async_burst_all_futures_resolve():
    with AsyncSVDEngine(cfg4(), batch_window_s=0.003) as eng:
        futs = [eng.submit(SVDRequest(uid=i, matrix=dense(i), bw=4))
                for i in range(9)]
        done = [f.result(timeout=300) for f in futs]
    for r in done:
        assert r.done and r.error is None
        check_sigma(r)
    snap = eng.metrics.snapshot()
    assert snap["completed"] == 9 and snap["queue_depth"] == 0


def test_async_submit_from_many_threads_exactly_once():
    """Thread-safety + exactly-once delivery: every future resolves with
    its own request, and completion callbacks fire once per future."""
    eng = AsyncSVDEngine(cfg4(), batch_window_s=0.002)
    nthreads, per = 6, 5
    futs = {}
    fired = []
    lock = threading.Lock()

    def client(t):
        for j in range(per):
            uid = t * per + j
            f = eng.submit(SVDRequest(uid=uid, matrix=dense(uid), bw=4))
            f.add_done_callback(lambda _f: fired.append(1))
            with lock:
                futs[uid] = f

    threads = [threading.Thread(target=client, args=(t,))
               for t in range(nthreads)]
    [t.start() for t in threads]
    [t.join() for t in threads]
    done = {uid: f.result(timeout=300) for uid, f in futs.items()}
    eng.stop()
    assert len(done) == nthreads * per
    for uid, r in done.items():
        assert r.uid == uid and r.error is None       # own request came back
        check_sigma(r)
    assert len(fired) == nthreads * per               # one callback per future
    snap = eng.metrics.snapshot()
    assert snap["submitted"] == nthreads * per
    assert snap["completed"] == nthreads * per
    assert snap["failed"] == snap["timed_out"] == snap["rejected"] == 0
    assert snap["served_slots"] == nthreads * per


def test_async_deadline_times_out_queued_request():
    eng = AsyncSVDEngine(cfg4(), batch_window_s=30.0)   # never ripe
    fut = eng.submit(SVDRequest(uid=0, matrix=dense(0), bw=4),
                     timeout_s=0.05)
    with pytest.raises(TimeoutError):
        fut.result(timeout=60)
    eng.stop()
    req_done = eng.finished[0]
    assert isinstance(req_done.error, TimeoutError) and req_done.done
    snap = eng.metrics.snapshot()
    assert snap["timed_out"] == 1 and snap["completed"] == 0
    assert snap["failed"] == 0                          # timeout != failure


def test_async_queue_full_rejects_at_admission():
    eng = AsyncSVDEngine(cfg4(max_batch=8), batch_window_s=30.0,
                         max_pending=2)
    f1 = eng.submit(SVDRequest(uid=0, matrix=dense(0), bw=4))
    f2 = eng.submit(SVDRequest(uid=1, matrix=dense(1), bw=4))
    f3 = eng.submit(SVDRequest(uid=2, matrix=dense(2), bw=4))
    with pytest.raises(QueueFullError):
        f3.result(timeout=60)
    eng.stop(drain=True)                                # serves the queue
    assert f1.result(timeout=60).error is None
    assert f2.result(timeout=60).error is None
    assert eng.metrics.snapshot()["rejected"] == 1


def test_async_nonsquare_rejected_via_future():
    eng = AsyncSVDEngine(cfg4())
    fut = eng.submit(SVDRequest(uid=0, matrix=np.zeros((4, 6)), bw=2))
    with pytest.raises(ValueError, match="square"):
        fut.result(timeout=60)
    eng.stop()


def test_async_stop_without_drain_cancels_pending():
    eng = AsyncSVDEngine(cfg4(), batch_window_s=30.0)
    fut = eng.submit(SVDRequest(uid=0, matrix=dense(0), bw=4))
    eng.stop(drain=False)
    with pytest.raises(Exception):                      # CancelledError
        fut.result(timeout=60)
    f2 = eng.submit(SVDRequest(uid=1, matrix=dense(1), bw=4))
    with pytest.raises(RuntimeError, match="stopped"):
        f2.result(timeout=60)


def test_async_asyncio_bridge():
    async def drive():
        with AsyncSVDEngine(cfg4(), batch_window_s=0.002) as eng:
            aws = [eng.submit_async(SVDRequest(uid=i, matrix=dense(i), bw=4))
                   for i in range(5)]
            return await asyncio.gather(*aws)

    done = asyncio.run(drive())
    assert len(done) == 5
    for r in done:
        check_sigma(r)


def test_async_window_expired_bucket_beats_full_bucket():
    """Fairness: a request past its batch_window_s dispatches before a
    continuously-full hot bucket — the window is a latency BOUND, not a
    hint (no worker started: _admit_locked is exercised directly)."""
    eng = AsyncSVDEngine(cfg4(max_batch=4), batch_window_s=0.2)
    now = time.monotonic()
    lone = SVDRequest(uid=99, matrix=dense(99, n=24), bw=4)
    lone.arrived = now - 1.0                    # long past the window
    SVDEngine.submit(eng, lone)
    for i in range(4):                          # hot bucket at capacity
        r = SVDRequest(uid=i, matrix=dense(i), bw=4)
        r.arrived = now
        SVDEngine.submit(eng, r)
    key, _cfg, reqs, _delay, to_fail = eng._admit_locked(now)
    assert reqs is not None and [r.uid for r in reqs] == [99], (key, reqs)
    assert not to_fail


def test_async_micro_batch_window_aggregates():
    """Requests trickling in faster than the window flushes co-batch: far
    fewer pipeline calls than requests."""
    eng = AsyncSVDEngine(cfg4(max_batch=8), batch_window_s=0.25)
    futs = []
    for i in range(8):
        futs.append(eng.submit(SVDRequest(uid=i, matrix=dense(i), bw=4)))
        time.sleep(0.005)
    [f.result(timeout=300) for f in futs]
    eng.stop()
    snap = eng.metrics.snapshot()
    assert snap["batches"] <= 3                         # not 8 serial calls
    assert snap["batch_fill_ratio"] >= 0.3


# ---------------------------------------------------------------------------
# mesh plumbing
# ---------------------------------------------------------------------------

def test_shard_pad():
    from repro.core.distributed import shard_pad
    assert shard_pad(8, 4) == 0
    assert shard_pad(9, 4) == 3
    assert shard_pad(1, 8) == 7
    assert shard_pad(5, 1) == 0


def test_serve_mesh_unset_env_is_none(monkeypatch):
    from repro.launch.mesh import serve_mesh
    monkeypatch.delenv("REPRO_SERVE_MESH", raising=False)
    assert serve_mesh() is None


def test_serve_mesh_single_device_degrades_to_none(monkeypatch):
    # On a 1-device host (or a pre-AxisType jax) the sharded path is
    # unreachable; the engine must get None and serve locally.
    from repro.launch.mesh import serve_mesh
    monkeypatch.setenv("REPRO_SERVE_MESH", "1")
    assert serve_mesh() is None
    monkeypatch.setenv("REPRO_SERVE_MESH", "")
    assert serve_mesh() is None


@needs_axis_type
@pytest.mark.distributed
def test_async_sharded_dispatch_8dev(subproc):
    """Full buckets batch-shard across 8 (fake) devices: results match the
    oracle, padding to shard divisibility is sliced off, and the metrics
    record the mesh path."""
    code = """
import os, numpy as np, jax
jax.config.update("jax_enable_x64", True)
os.environ["REPRO_SERVE_MESH"] = "auto"
from repro.core.tuning import PipelineConfig
from repro.launch.mesh import serve_mesh
from repro.serve import AsyncSVDEngine, SVDRequest
mesh = serve_mesh()
assert mesh is not None and mesh.devices.size == 8, mesh
cfg = PipelineConfig.resolve(bw=4, tw=2, backend="ref", dtype=np.float64,
                             max_batch=6)   # 6 reqs -> pad 2 for 8 shards
rng = np.random.default_rng(0)
with AsyncSVDEngine(cfg, mesh=mesh, batch_window_s=0.005) as eng:
    futs = [eng.submit(SVDRequest(uid=i,
                                  matrix=rng.standard_normal((16, 16)),
                                  bw=4))
            for i in range(6)]
    done = [f.result(timeout=600) for f in futs]
for r in done:
    s0 = np.linalg.svd(r.matrix, compute_uv=False)
    assert np.abs(r.sigma - s0).max() < 1e-10 * s0[0]
snap = eng.metrics.snapshot()
assert snap["sharded_batches"] >= 1, snap
print("SHARDED_SERVE_OK", snap["sharded_batches"])
"""
    r = subproc(code, devices=8, timeout=600)
    assert "SHARDED_SERVE_OK" in r.stdout, (r.stdout[-500:], r.stderr[-2000:])
