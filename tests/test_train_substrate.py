"""Training-substrate tests: optimizer, data determinism, checkpoint
atomicity/restore, crash-restart bit-exactness, straggler detection,
spectral monitor, compression error feedback."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import smoke_of
from repro.models import build
from repro.train import (AdamWConfig, DataConfig, FailureInjector,
                         StragglerMonitor, Trainer, batch_at, checkpoint,
                         run_with_restarts)
from repro.train.optimizer import cosine_lr, global_norm
from repro.train.spectral import SpectralMonitor, SpectralMonitorConfig, spectral_metrics

# Known seed failure (DESIGN.md §10): the gradient-compression loop shards
# through jax.shard_map over a mesh built with jax.sharding.AxisType — API
# surface the pinned jax 0.4.37 does not have.  Condition-based so a jax
# upgrade turns the tests back on without edits.
needs_axis_type = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="jax.sharding.AxisType unavailable on this jax "
           "(pre-existing seed failure, DESIGN.md §10)")


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_cosine_schedule_shape():
    cfg = AdamWConfig(peak_lr=1.0, min_lr=0.1, warmup_steps=10, total_steps=100)
    lrs = [float(cosine_lr(cfg, jnp.asarray(s))) for s in [0, 5, 10, 55, 100]]
    assert lrs[0] == 0.0 and abs(lrs[2] - 1.0) < 1e-6
    assert lrs[1] == pytest.approx(0.5)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, abs=1e-6)


def test_training_reduces_loss():
    cfg = smoke_of("granite-3-2b")
    model = build(cfg)
    tr = Trainer(model, AdamWConfig(peak_lr=3e-3, warmup_steps=2,
                                    total_steps=100, clip_norm=1.0))
    state = tr.init_state(jax.random.PRNGKey(0))
    step = jax.jit(tr.make_train_step())
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=3)
    batch = {k: jnp.asarray(v) for k, v in batch_at(dc, 0).items()}
    first = None
    for t in range(20):                      # overfit one batch
        state, m = step(state, batch)
        first = first if first is not None else float(m["loss"])
    assert float(m["loss"]) < first - 0.5


def test_grad_accumulation_matches_full_batch():
    cfg = smoke_of("granite-3-2b")
    model = build(cfg)
    dc = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4, seed=5)
    batch = {k: jnp.asarray(v) for k, v in batch_at(dc, 0).items()}
    opt = AdamWConfig(peak_lr=1e-3, warmup_steps=0, total_steps=10, clip_norm=0)
    params = Trainer(model, opt).init_state(jax.random.PRNGKey(0))["params"]
    grads = []
    for accum in (1, 2):
        tr = Trainer(model, opt, accum=accum)
        _, _, g = jax.jit(lambda p, b, tr=tr: tr._grads(p, b))(params, batch)
        grads.append(g)
    scale = max(float(global_norm(grads[0])), 1.0)
    for x, y in zip(jax.tree_util.tree_leaves(grads[0]),
                    jax.tree_util.tree_leaves(grads[1])):
        np.testing.assert_allclose(np.asarray(x, np.float64),
                                   np.asarray(y, np.float64),
                                   atol=1e-5 * scale)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_is_pure_function_of_step():
    dc = DataConfig(vocab=1000, seq_len=64, global_batch=8, seed=11)
    b1, b2 = batch_at(dc, 42), batch_at(dc, 42)
    for k in b1:
        np.testing.assert_array_equal(b1[k], b2[k])
    b3 = batch_at(dc, 43)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_host_slice_partitions():
    from repro.train.data import host_slice
    dc = DataConfig(vocab=100, seq_len=8, global_batch=8, seed=0)
    full = batch_at(dc, 0)
    parts = [host_slice(full, h, 4) for h in range(4)]
    glued = np.concatenate([p["tokens"] for p in parts])
    np.testing.assert_array_equal(glued, full["tokens"])


def test_prefetcher_orders_steps():
    from repro.train.data import Prefetcher
    dc = DataConfig(vocab=50, seq_len=4, global_batch=2, seed=1)
    pf = Prefetcher(dc, start_step=5)
    try:
        s0, b0 = pf.next()
        s1, _ = pf.next()
        assert (s0, s1) == (5, 6)
        ref = batch_at(dc, 5)
        np.testing.assert_array_equal(np.asarray(b0["tokens"]), ref["tokens"])
    finally:
        pf.close()


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_keep(tmp_path):
    state = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.asarray(7)}}
    for s in (1, 2, 3, 4):
        checkpoint.save(str(tmp_path), s, state, keep=2)
    assert checkpoint.latest_step(str(tmp_path)) == 4
    assert sorted(checkpoint._complete_steps(str(tmp_path))) == [3, 4]
    out = checkpoint.restore(str(tmp_path), 4, state)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(state["a"]))
    assert int(out["b"]["c"]) == 7


def test_incomplete_checkpoint_ignored(tmp_path):
    state = {"x": jnp.ones(3)}
    checkpoint.save(str(tmp_path), 1, state)
    # fake a torn write: directory without DONE
    os.makedirs(tmp_path / "step_00000002")
    np.savez(tmp_path / "step_00000002" / "state.npz", x=np.ones(3))
    assert checkpoint.latest_step(str(tmp_path)) == 1


def test_restart_bit_exact(tmp_path):
    """Crash at step 7 -> restore -> final params identical to a clean run."""
    cfg = smoke_of("granite-3-2b")
    model = build(cfg)
    dc = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=2, seed=9)
    opt = AdamWConfig(peak_lr=1e-3, warmup_steps=1, total_steps=20)

    def driver(ckpt_dir, injector):
        tr = Trainer(model, opt)
        jstep = jax.jit(tr.make_train_step())

        def make_state():
            return tr.init_state(jax.random.PRNGKey(0))

        def restore_state(step, template):
            return checkpoint.restore(ckpt_dir, step, template)

        def step_fn(step, state):
            batch = {k: jnp.asarray(v) for k, v in batch_at(dc, step).items()}
            return jstep(state, batch)

        return run_with_restarts(
            total_steps=12, ckpt_dir=ckpt_dir, make_state=make_state,
            restore_state=restore_state, step_fn=step_fn, save_every=5,
            injector=injector)

    clean, _, r0 = driver(str(tmp_path / "clean"), FailureInjector())
    crash, _, r1 = driver(str(tmp_path / "crash"), FailureInjector(fail_at=(7,)))
    assert r0 == 0 and r1 == 1
    for x, y in zip(jax.tree_util.tree_leaves(clean["params"]),
                    jax.tree_util.tree_leaves(crash["params"])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_async_checkpointer(tmp_path):
    from repro.train.checkpoint import AsyncCheckpointer
    ac = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in range(4):
        ac.submit(s, {"w": jnp.full((4,), float(s))})
    ac.close()
    last = checkpoint.latest_step(str(tmp_path))
    assert last is not None
    out = checkpoint.restore(str(tmp_path), last, {"w": jnp.zeros(4)})
    np.testing.assert_array_equal(np.asarray(out["w"]), np.full(4, float(last)))


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_straggler_monitor_flags():
    mon = StragglerMonitor(threshold=2.0)
    for s in range(10):
        mon.record(s, 1.0)
    assert mon.record(10, 5.0) is True
    assert mon.flagged == [10]
    assert mon.record(11, 1.1) is False


# ---------------------------------------------------------------------------
# spectral monitor (the paper's kernel in the training loop)
# ---------------------------------------------------------------------------

def test_spectral_monitor_and_metrics():
    rng = np.random.default_rng(0)
    w = rng.standard_normal((48, 48))
    params = {"layer": {"w": jnp.asarray(w)}, "bias": jnp.zeros(8)}
    mon = SpectralMonitor(SpectralMonitorConfig(every=5, size=48, bw=8,
                                                backend="ref"))
    assert mon.maybe_refresh(0, params)
    assert not mon.maybe_refresh(3, params)
    assert mon.maybe_refresh(5, params)
    sig = mon.sigma_tree["layer"]["w"]
    s_ref = np.linalg.svd(w, compute_uv=False)
    np.testing.assert_allclose(np.asarray(sig), s_ref, atol=1e-8 * s_ref[0])
    sm = mon.sigma_max_tree()
    assert float(sm["layer"]["w"]) == pytest.approx(s_ref[0], rel=1e-9)
    assert sm["bias"] is None
    m = spectral_metrics(jnp.asarray(s_ref))
    assert m["stable_rank"] > 1.0
    mets = mon.metrics()
    assert any("sigma_max" in k for k in mets)


def _compress_loop(g, rank, iters):
    import functools
    from jax.sharding import PartitionSpec as P
    from repro.parallel.compression import (CompressionConfig,
                                            compression_init,
                                            compress_and_sync)
    cfgc = CompressionConfig(rank=rank, min_dim=16)
    state = compression_init(cfgc, {"w": g})
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    fn = functools.partial(compress_and_sync, cfg=cfgc, axis_names=("data",))
    shfn = jax.shard_map(fn, mesh=mesh,
                         in_specs=({"w": P()}, {"w": {"q": P(), "err": P("data")}}),
                         out_specs=({"w": P()}, {"w": {"q": P(), "err": P("data")}},
                                    P()),
                         check_vma=False)
    total = jnp.zeros_like(g)
    for _ in range(iters):
        ghat, state, stats = shfn({"w": g}, state)
        total = total + ghat["w"]
    return total / iters, stats


@needs_axis_type
def test_compression_recovers_low_rank_gradient():
    """Warm-started subspace iteration locks onto a rank-4 gradient: the
    reconstruction becomes near-exact and the telescoped EF residual -> 0."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal((64, 4)) @ rng.standard_normal((4, 96)),
                    jnp.float32)
    avg, stats = _compress_loop(g, rank=4, iters=8)
    rel = float(jnp.linalg.norm(avg - g) / jnp.linalg.norm(g))
    assert rel < 1e-3, rel
    assert stats["compression_ratio"] > 5


@needs_axis_type
def test_compression_error_feedback_telescopes():
    """Full-rank (white-noise) gradient: the time-averaged compressed signal
    still drifts toward g (EF telescoping), even though per-step rank-4
    capture is small."""
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.standard_normal((64, 96)), jnp.float32)
    avg4, _ = _compress_loop(g, rank=4, iters=4)
    avg12, _ = _compress_loop(g, rank=4, iters=12)
    rel4 = float(jnp.linalg.norm(avg4 - g) / jnp.linalg.norm(g))
    rel12 = float(jnp.linalg.norm(avg12 - g) / jnp.linalg.norm(g))
    assert rel12 < rel4 < 1.0
