"""Per-architecture smoke tests (reduced configs, CPU): one forward + one
gradient step, output shapes + finiteness; decode-vs-prefill consistency;
MoE routing invariants."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import list_configs, smoke_of, get_config
from repro.configs.shapes import cells
from repro.models import build

ARCHS = list_configs()


def make_batch(cfg, b, s, rng):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
             "mask": jnp.ones((b, s), jnp.float32)}
    if cfg.kind == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.enc_seq, cfg.d_model)), jnp.float32)
    if cfg.n_img_tokens:
        batch["images"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_img_tokens, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_grad(arch):
    cfg = smoke_of(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, s = 2, 16
    batch = make_batch(cfg, b, s, rng)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, metrics = model.loss_fn(params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
    gnorm = float(jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in flat)))
    assert gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    cfg = smoke_of(arch)
    if cfg.n_experts:   # capacity drops are prefill-only; disable for parity
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    b, s = 2, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    batch = {"tokens": toks}
    if cfg.kind == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.enc_seq, cfg.d_model)), jnp.float32)
    full, _ = model.forward(params, batch)
    caches = model.init_caches(b, s)
    if cfg.kind == "encdec":
        from repro.models.encdec import fill_cross_cache
        caches = fill_cross_cache(params, cfg, batch["frames"], caches)
    step = jax.jit(model.decode_step)
    for t in range(s):
        logits, caches = step(params, toks[:, t : t + 1], caches, t)
        err = float(jnp.max(jnp.abs(logits[:, 0] - full[:, t])))
        assert err < 2e-3, (arch, t, err)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_dims(arch):
    """The registered full configs carry the exact assigned dimensions."""
    cfg = get_config(arch)
    assert cfg.d_model > 0 and cfg.n_layers > 0 and cfg.vocab > 0
    suite_names = {s.name for s in cells(cfg)}
    if cfg.subquadratic:
        assert "long_500k" in suite_names
    else:
        assert "long_500k" not in suite_names
    assert {"train_4k", "prefill_32k", "decode_32k"} <= suite_names


EXPECTED = {
    "llama3-8b": dict(n_layers=32, d_model=4096, n_heads=32, n_kv=8,
                      d_ff=14336, vocab=128256),
    "granite-3-2b": dict(n_layers=40, d_model=2048, n_heads=32, n_kv=8,
                         d_ff=8192, vocab=49155),
    "codeqwen1.5-7b": dict(n_layers=32, d_model=4096, n_heads=32, n_kv=32,
                           d_ff=13440, vocab=92416),
    "phi3-medium-14b": dict(n_layers=40, d_model=5120, n_heads=40, n_kv=10,
                            d_ff=17920, vocab=100352),
    "granite-moe-3b-a800m": dict(n_layers=32, d_model=1536, n_heads=24,
                                 n_kv=8, d_ff=512, vocab=49155, n_experts=40,
                                 top_k=8),
    "deepseek-moe-16b": dict(n_layers=28, d_model=2048, n_heads=16, n_kv=16,
                             d_ff=1408, vocab=102400, n_experts=64, top_k=6,
                             n_shared_experts=2),
    "hymba-1.5b": dict(n_layers=32, d_model=1600, n_heads=25, n_kv=5,
                       d_ff=5504, vocab=32001, ssm_state=16),
    "pixtral-12b": dict(n_layers=40, d_model=5120, n_heads=32, n_kv=8,
                        d_ff=14336, vocab=131072),
    "rwkv6-1.6b": dict(n_layers=24, d_model=2048, d_ff=7168, vocab=65536),
    "whisper-medium": dict(n_layers=24, d_model=1024, n_heads=16, n_kv=16,
                           d_ff=4096, vocab=51865),
}


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_assigned_dims_exact(arch):
    cfg = get_config(arch)
    for k, v in EXPECTED[arch].items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_moe_routing_invariants():
    from repro.models.moe import _route_one
    rng = np.random.default_rng(2)
    s, k, e, cap = 32, 2, 8, 10
    gi = jnp.asarray(rng.integers(0, e, (s, k)), jnp.int32)
    gv = jnp.asarray(rng.random((s, k)), jnp.float32)
    tok, w, valid = _route_one(None, gi, gv, e=e, cap=cap)
    assert tok.shape == (e, cap) and valid.shape == (e, cap)
    # every valid slot's token really routed to that expert
    gi_np, tok_np, valid_np = map(np.asarray, (gi, tok, valid))
    for ei in range(e):
        for c in range(cap):
            if valid_np[ei, c]:
                assert ei in gi_np[tok_np[ei, c]]
    # no expert over capacity, total kept slots <= s*k
    assert valid_np.sum() <= s * k
