"""Fault-tolerance tests (DESIGN.md §15): the FaultPlan/RetryPolicy/
BucketQuarantine primitives, the numerical-health guards in ``core.svd``,
and the engines' retry/backoff/quarantine/degraded dispatch ladder — plus
the sharded shard-loss re-dispatch (bitwise-identical recovery)."""

import time

import numpy as np
import jax
import pytest

from repro.core import svd as svdmod
from repro.core.svd import NumericalFault
from repro.core.tuning import PipelineConfig
from repro.serve import (AsyncSVDEngine, BucketQuarantine, FaultPlan,
                         InjectedDispatchError, RetryPolicy, SVDEngine,
                         SVDRequest)

needs_axis_type = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="jax.sharding.AxisType unavailable on this jax "
           "(pre-existing seed failure, DESIGN.md §10)")


def cfg4(max_batch=4):
    return PipelineConfig.resolve(bw=4, tw=2, backend="ref",
                                  dtype=np.float64, max_batch=max_batch)


def dense(seed, n=16):
    return np.random.default_rng(seed).standard_normal((n, n))


def check_sigma(req, atol_scale=1e-10):
    s0 = np.linalg.svd(req.matrix, compute_uv=False)
    np.testing.assert_allclose(req.sigma, s0, atol=atol_scale * s0[0])


FAST = RetryPolicy(backoff_base_s=1e-4, backoff_max_s=1e-3)


# ---------------------------------------------------------------------------
# FaultPlan: determinism, scripting, budget
# ---------------------------------------------------------------------------

def test_fault_plan_deterministic_across_instances():
    """Same seed + knobs -> the i-th hook call injects the same fault (and
    corrupts the same sigma entry) on every instantiation."""
    def drive(plan):
        events = []
        for i in range(40):
            try:
                plan.before_dispatch(key=("k", i))
                events.append("ok")
            except InjectedDispatchError:
                events.append("err")
            sig = plan.corrupt_sigma(np.linspace(9.0, 1.0, 5))
            events.append(tuple(np.where(~np.isfinite(sig))[0]))
        return events, plan.snapshot()

    def mk():
        return FaultPlan(seed=7, dispatch_error_rate=0.3, nan_rate=0.25,
                         inf_rate=0.1)

    ev1, snap1 = drive(mk())
    ev2, snap2 = drive(mk())
    assert ev1 == ev2 and snap1 == snap2
    assert snap1["dispatch_error"] > 0 and snap1["nan"] + snap1["inf"] > 0


def test_fault_plan_scripted_ordinals_fire_regardless_of_rates():
    plan = FaultPlan(seed=0, dispatch_errors_at=(2,), nan_at=(1,))
    plan.before_dispatch()                        # ordinal 0: clean
    plan.before_dispatch()                        # ordinal 1: clean
    with pytest.raises(InjectedDispatchError, match="dispatch 2"):
        plan.before_dispatch()                    # ordinal 2: scripted
    s0 = plan.corrupt_sigma(np.array([3.0, 2.0, 1.0]))
    assert np.isfinite(s0).all()                  # result ordinal 0: clean
    s1 = plan.corrupt_sigma(np.array([3.0, 2.0, 1.0]))
    assert np.isnan(s1).sum() == 1                # result ordinal 1: scripted
    assert plan.snapshot()["nan"] == 1


def test_fault_plan_max_faults_budget():
    plan = FaultPlan(seed=0, dispatch_error_rate=1.0, max_faults=2)
    for _ in range(2):
        with pytest.raises(InjectedDispatchError):
            plan.before_dispatch()
    for _ in range(5):                            # budget exhausted: clean
        plan.before_dispatch()
    assert plan.snapshot()["dispatch_error"] == 2


def test_fault_plan_corrupt_never_mutates_input():
    plan = FaultPlan(seed=0, nan_rate=1.0)
    sig = np.array([3.0, 2.0, 1.0])
    out = plan.corrupt_sigma(sig)
    assert np.isfinite(sig).all() and np.isnan(out).sum() == 1


# ---------------------------------------------------------------------------
# RetryPolicy + BucketQuarantine state machines
# ---------------------------------------------------------------------------

def test_retry_policy_backoff_caps_and_respects_deadline():
    pol = RetryPolicy(backoff_base_s=0.01, backoff_factor=4.0,
                      backoff_max_s=0.05)
    assert pol.backoff_for(1, deadline=None, now=0.0) == 0.01
    assert pol.backoff_for(2, deadline=None, now=0.0) == 0.04
    assert pol.backoff_for(3, deadline=None, now=0.0) == 0.05   # capped
    # Deadline-aware: a sleep landing at/past the deadline is refused.
    assert pol.backoff_for(1, deadline=100.02, now=100.0) == 0.01
    assert pol.backoff_for(2, deadline=100.02, now=100.0) is None


def test_retry_policy_numerical_faults_get_fewer_attempts():
    pol = RetryPolicy(max_attempts=4, numerical_max_attempts=2)
    assert pol.attempts_for(RuntimeError("x")) == 4
    assert pol.attempts_for(NumericalFault("nan sigma")) == 2


def test_quarantine_trip_cooldown_halfopen_recover():
    t = [0.0]
    q = BucketQuarantine(threshold=3, cooldown_s=10.0, clock=lambda: t[0])
    key = ("bucket",)
    assert not q.record_failure(key) and not q.record_failure(key)
    assert not q.active(key)
    assert q.record_failure(key)                  # third failure: trips OPEN
    assert q.active(key) and q.open_keys() == [key]
    t[0] = 5.0
    assert q.active(key)                          # still cooling down
    t[0] = 11.0
    assert not q.active(key)                      # HALF-OPEN: one trial flows
    assert not q.record_failure(key)              # trial failed: re-arm, not
    assert q.active(key)                          # a "new" trip
    t[0] = 22.0
    assert not q.active(key)
    assert q.record_success(key)                  # trial succeeded: recovered
    assert not q.active(key) and q.open_keys() == []
    assert not q.record_success(key)              # already CLOSED


def test_quarantine_success_resets_consecutive_count():
    q = BucketQuarantine(threshold=3, cooldown_s=10.0)
    key = "k"
    q.record_failure(key)
    q.record_failure(key)
    q.record_success(key)                         # streak broken
    assert not q.record_failure(key)              # 1, not 3
    assert not q.active(key)


# ---------------------------------------------------------------------------
# numerical-health guards (core.svd)
# ---------------------------------------------------------------------------

def test_validate_sigma_accepts_clean_rejects_poisoned():
    good = np.array([[5.0, 3.0, 1.0, 0.0]])
    svdmod.validate_sigma(good)                   # no raise
    with pytest.raises(NumericalFault, match="non-finite"):
        svdmod.validate_sigma(np.array([5.0, np.nan, 1.0]))
    with pytest.raises(NumericalFault, match="non-finite"):
        svdmod.validate_sigma(np.array([np.inf, 3.0, 1.0]))
    with pytest.raises(NumericalFault, match="negative"):
        svdmod.validate_sigma(np.array([5.0, 3.0, -1.0]))
    with pytest.raises(NumericalFault, match="descending"):
        svdmod.validate_sigma(np.array([3.0, 5.0, 1.0]))
    # tolerance slack: tiny negative / tiny inversions are rounding, not rot
    eps = np.finfo(np.float64).eps
    svdmod.validate_sigma(np.array([5.0, 3.0, -eps]))


def test_svd_check_flag_passes_clean_input():
    a = np.random.default_rng(0).standard_normal((2, 16, 16))
    sig = svdmod.svd_batched(a, config=cfg4(), check=True)
    s0 = np.linalg.svd(a, compute_uv=False)
    np.testing.assert_allclose(np.asarray(sig), s0, atol=1e-10 * s0.max())


def test_spot_check_svd_catches_wrong_factors():
    a = np.random.default_rng(1).standard_normal((16, 16))
    u, s, vt = np.linalg.svd(a)
    svdmod.spot_check_svd(a[None], u[None], s[None], vt[None])   # no raise
    with pytest.raises(NumericalFault, match="residual"):
        svdmod.spot_check_svd(a[None], np.roll(u, 3, axis=1)[None],
                              s[None], vt[None])


# ---------------------------------------------------------------------------
# engine ladder: retry -> degrade -> quarantine (sync)
# ---------------------------------------------------------------------------

def test_dispatch_error_retried_to_success():
    plan = FaultPlan(seed=0, dispatch_errors_at=(0,))
    eng = SVDEngine(cfg4(), faults=plan, retry=FAST)
    eng.submit(SVDRequest(uid=0, matrix=dense(0), bw=4))
    (r,) = eng.run()
    assert r.error is None
    check_sigma(r)
    snap = eng.metrics.snapshot()
    assert snap["completed"] == 1 and snap["failed"] == 0
    assert snap["retried"] >= 1 and snap["degraded"] == 0
    assert plan.snapshot()["dispatch_error"] == 1
    assert snap["bucket_errors"]                  # last_error attribution


def test_batch_fault_isolates_per_request_and_all_succeed():
    """A failed BATCH dispatch splits per-request; every request completes
    with the right answer through its own retry ladder, FIFO order kept."""
    plan = FaultPlan(seed=0, dispatch_errors_at=(0,))
    eng = SVDEngine(cfg4(max_batch=4), faults=plan, retry=FAST)
    for i in range(4):
        eng.submit(SVDRequest(uid=i, matrix=dense(i), bw=4))
    done = eng.run()
    assert [r.uid for r in done] == [0, 1, 2, 3]
    for r in done:
        assert r.error is None
        check_sigma(r)
    assert eng.metrics.snapshot()["completed"] == 4


def test_nan_corruption_retries_once_then_succeeds():
    plan = FaultPlan(seed=0, nan_at=(0,))
    eng = SVDEngine(cfg4(), faults=plan, retry=FAST)
    eng.submit(SVDRequest(uid=0, matrix=dense(0), bw=4))
    (r,) = eng.run()
    assert r.error is None
    check_sigma(r)
    snap = eng.metrics.snapshot()
    assert snap["retried"] == 1 and snap["degraded"] == 0
    (err_row,) = snap["bucket_errors"].values()
    assert "NumericalFault" in err_row["last_error"]


def test_persistent_nan_degrades_to_ref_tier():
    """NumericalFault is retried ONCE (numerical_max_attempts=2); a second
    poisoned result routes the request to the degraded ref tier, which
    still returns the correct spectrum."""
    plan = FaultPlan(seed=0, nan_at=(0, 1))
    eng = SVDEngine(cfg4(), faults=plan, retry=FAST)
    eng.submit(SVDRequest(uid=0, matrix=dense(0), bw=4))
    (r,) = eng.run()
    assert r.error is None
    check_sigma(r)
    snap = eng.metrics.snapshot()
    assert snap["degraded"] == 1
    assert snap["tiers"]["degraded-ref"]["batches"] == 1
    assert plan.snapshot()["nan"] == 2            # degraded path not injected


def test_quarantine_trips_routes_degraded_and_recovers():
    plan = FaultPlan(seed=0, dispatch_errors_at=(0, 1, 2))
    policy = RetryPolicy(max_attempts=1, backoff_base_s=1e-4,
                         quarantine_threshold=3)
    eng = SVDEngine(cfg4(), faults=plan, retry=policy)
    t = [0.0]
    eng.quarantine = BucketQuarantine(threshold=3, cooldown_s=30.0,
                                      clock=lambda: t[0])
    for i in range(3):                            # each: 1 failure -> degrade
        eng.submit(SVDRequest(uid=i, matrix=dense(i), bw=4))
        eng.run()
    snap = eng.metrics.snapshot()
    assert snap["quarantined"] == 1               # tripped exactly once
    assert snap["quarantined_buckets"]
    assert snap["degraded"] == 3
    assert eng.metrics.health()["status"] == "degraded"
    # OPEN: traffic routes straight to the degraded tier, primary path
    # untouched (the plan's dispatch ordinal must not advance).
    before = plan.snapshot()["dispatches"]
    eng.submit(SVDRequest(uid=10, matrix=dense(10), bw=4))
    (r,) = eng.run()[-1:]
    assert r.error is None
    check_sigma(r)
    assert plan.snapshot()["dispatches"] == before
    # Cooldown elapses -> HALF-OPEN: one primary trial (no fault scripted
    # anymore) succeeds and CLOSES the breaker.
    t[0] = 31.0
    eng.submit(SVDRequest(uid=11, matrix=dense(11), bw=4))
    (r,) = eng.run()[-1:]
    assert r.error is None
    check_sigma(r)
    snap = eng.metrics.snapshot()
    assert snap["quarantined_buckets"] == []
    assert plan.snapshot()["dispatches"] == before + 1
    assert eng.quarantine.open_keys() == []
    for req in eng.finished:
        assert req.error is None                  # zero client-visible fails


def test_backoff_never_sleeps_past_deadline():
    """A retry backoff that would outlive the request's deadline is skipped
    entirely: the request degrades immediately instead of burning its
    budget asleep (the 300 s base backoff would time the test out)."""
    plan = FaultPlan(seed=0, dispatch_errors_at=(0,))
    policy = RetryPolicy(max_attempts=3, backoff_base_s=300.0,
                         backoff_max_s=300.0)
    eng = SVDEngine(cfg4(), faults=plan, retry=policy)
    req = SVDRequest(uid=0, matrix=dense(0), bw=4)
    eng.submit(req)
    req.deadline = time.monotonic() + 30.0
    t0 = time.monotonic()
    (r,) = eng.run()
    elapsed = time.monotonic() - t0
    assert elapsed < 30.0                         # never slept the backoff
    assert r.error is None                        # served (degraded), on time
    check_sigma(r)
    snap = eng.metrics.snapshot()
    assert snap["retried"] == 0 and snap["degraded"] == 1


def test_deadline_rechecked_at_completion_sync():
    """Satellite regression: a request admitted in time but COMPLETED past
    its deadline resolves as TimeoutError (counted timed_out), with the
    late results kept on the request object."""
    eng = SVDEngine(cfg4())
    warm = SVDRequest(uid=-1, matrix=dense(99), bw=4)
    eng.submit(warm)
    eng.run()                                     # compile outside the test
    req = SVDRequest(uid=0, matrix=dense(0), bw=4)
    req.deadline = time.monotonic()               # already passed
    eng.submit(req)
    eng.run()
    assert isinstance(req.error, TimeoutError) and req.done
    assert req.sigma is not None                  # late answer preserved
    snap = eng.metrics.snapshot()
    assert snap["timed_out"] == 1 and snap["failed"] == 0


# ---------------------------------------------------------------------------
# async engine under injected faults
# ---------------------------------------------------------------------------

def test_async_burst_absorbs_dispatch_and_nan_faults():
    plan = FaultPlan(seed=0, dispatch_errors_at=(0,), nan_at=(1,))
    with AsyncSVDEngine(cfg4(), batch_window_s=0.003, faults=plan,
                        retry=FAST) as eng:
        futs = [eng.submit(SVDRequest(uid=i, matrix=dense(i), bw=4))
                for i in range(6)]
        done = [f.result(timeout=300) for f in futs]
    for r in done:
        assert r.error is None
        check_sigma(r)
    snap = eng.metrics.snapshot()
    assert snap["completed"] == 6 and snap["failed"] == 0
    assert snap["retried"] + snap["degraded"] >= 1
    fired = plan.snapshot()
    assert fired["dispatch_error"] >= 1 and fired["nan"] >= 1
    assert eng.metrics.health()["client_error_rate"] == 0.0


def test_async_deadline_rechecked_at_completion():
    """A request whose deadline expires while its batch is ON DEVICE gets
    TimeoutError at completion — not a silent late success."""
    plan = FaultPlan(seed=0, latency_rate=1.0, latency_s=0.3)
    eng = AsyncSVDEngine(cfg4(), batch_window_s=0.001, faults=plan,
                         retry=FAST)
    warm = eng.submit(SVDRequest(uid=-1, matrix=dense(99), bw=4),
                      timeout_s=float("inf"))
    warm.result(timeout=300)                      # compiled; 0.3s > 0.1s now
    fut = eng.submit(SVDRequest(uid=0, matrix=dense(0), bw=4),
                     timeout_s=0.1)
    with pytest.raises(TimeoutError):
        fut.result(timeout=300)
    eng.stop()
    late = [r for r in eng.finished if r.uid == 0][0]
    assert late.sigma is not None                 # late answer preserved
    assert eng.metrics.snapshot()["timed_out"] == 1


# ---------------------------------------------------------------------------
# sharded dispatch: shard loss -> bitwise-identical re-dispatch
# ---------------------------------------------------------------------------

@needs_axis_type
@pytest.mark.distributed
def test_sharded_shard_loss_redispatch_bitwise_identical(subproc):
    code = """
import os, numpy as np, jax
jax.config.update("jax_enable_x64", True)
os.environ["REPRO_SERVE_MESH"] = "auto"
import jax.numpy as jnp
from repro.core.distributed import sharded_pipeline_dispatch
from repro.core.tuning import PipelineConfig
from repro.launch.mesh import serve_mesh
from repro.serve import FaultPlan
mesh = serve_mesh()
assert mesh is not None and mesh.devices.size == 8, mesh
cfg = PipelineConfig.resolve(bw=4, tw=2, backend="ref", dtype=np.float64,
                             max_batch=16)
mats = jnp.asarray(np.random.default_rng(0).standard_normal((16, 16, 16)))
clean = np.asarray(sharded_pipeline_dispatch(mats, mesh, config=cfg))
retries = []
plan = FaultPlan(shard_loss_at=(0,))          # lose shard 0 of dispatch 0
out = np.asarray(sharded_pipeline_dispatch(
    mats, mesh, config=cfg, faults=plan, on_shard_retry=retries.append))
assert plan.snapshot()["shard_loss"] == 1, plan.snapshot()
assert sum(retries) == 1, retries
assert np.isfinite(out).all()
assert np.array_equal(clean, out), np.abs(clean - out).max()
print("SHARD_LOSS_BITWISE_OK")
"""
    r = subproc(code, devices=8, timeout=600)
    assert "SHARD_LOSS_BITWISE_OK" in r.stdout, (r.stdout[-500:],
                                                 r.stderr[-2000:])


@needs_axis_type
@pytest.mark.distributed
def test_async_sharded_engine_survives_shard_loss(subproc):
    """End-to-end: the async engine on a mesh, with per-shard losses
    injected — every request completes with the oracle spectrum and the
    re-dispatches are counted in sharded_retries."""
    code = """
import os, numpy as np, jax
jax.config.update("jax_enable_x64", True)
os.environ["REPRO_SERVE_MESH"] = "auto"
from repro.core.tuning import PipelineConfig
from repro.launch.mesh import serve_mesh
from repro.serve import AsyncSVDEngine, FaultPlan, SVDRequest
mesh = serve_mesh()
assert mesh is not None and mesh.devices.size == 8, mesh
cfg = PipelineConfig.resolve(bw=4, tw=2, backend="ref", dtype=np.float64,
                             max_batch=8)
plan = FaultPlan(shard_loss_at=(0, 1))
rng = np.random.default_rng(0)
with AsyncSVDEngine(cfg, mesh=mesh, batch_window_s=0.005,
                    faults=plan) as eng:
    futs = [eng.submit(SVDRequest(uid=i,
                                  matrix=rng.standard_normal((16, 16)),
                                  bw=4))
            for i in range(8)]
    done = [f.result(timeout=600) for f in futs]
for r in done:
    s0 = np.linalg.svd(r.matrix, compute_uv=False)
    assert r.error is None
    assert np.abs(r.sigma - s0).max() < 1e-10 * s0[0]
snap = eng.metrics.snapshot()
assert snap["sharded_retries"] >= 1, snap
assert snap["failed"] == 0 and snap["completed"] == 8, snap
print("SHARDED_FAULT_SERVE_OK", snap["sharded_retries"])
"""
    r = subproc(code, devices=8, timeout=600)
    assert "SHARDED_FAULT_SERVE_OK" in r.stdout, (r.stdout[-500:],
                                                  r.stderr[-2000:])


# ---------------------------------------------------------------------------
# metrics health view
# ---------------------------------------------------------------------------

def test_health_status_transitions():
    from repro.serve import ServeMetrics
    m = ServeMetrics()
    assert m.health()["status"] == "ok"
    m.add(submitted=2, completed=1, retried=1)
    assert m.health()["status"] == "ok"           # healed retries stay ok
    m.add(degraded=1)
    assert m.health()["status"] == "degraded"
    m.add(failed=1)
    h = m.health()
    assert h["status"] == "failing"
    assert h["client_error_rate"] == pytest.approx(0.5)
