"""Sharding rules, roofline HLO walker, serving engine, and subprocess
integration tests (sharded trainer on 8 fake devices; one real dry-run cell
with the 512-device production mesh)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import smoke_of
from repro.models import build
from repro.parallel.sharding import AxisRules, _SINGLE, _MULTI
from repro.roofline.hlo_parse import parse_module
from repro.serve import Engine, Request, ServeConfig

# Known seed failure (DESIGN.md §10): the mesh construction used by the
# multi-device paths (launch/mesh.py and the subprocess snippets below)
# targets the jax.sharding.AxisType / jax.shard_map API surface, which the
# pinned jax 0.4.37 does not have.  Condition-based so a jax upgrade turns
# the tests back on without edits.
needs_axis_type = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="jax.sharding.AxisType unavailable on this jax "
           "(pre-existing seed failure, DESIGN.md §10)")


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_rules_spec_resolution():
    r = AxisRules(_SINGLE)
    assert r.spec(("batch", None, None)) == P(("data",), None, None)
    assert r.spec((None, "model_out")) == P(None, "model")
    # duplicate physical axis is dropped on second use
    assert r.spec(("heads", "kv_heads")) == P("model", None)
    # unknown logical name -> replicated
    assert r.spec(("nope",)) == P(None)


def test_multipod_rules_batch_axes():
    r = AxisRules(_MULTI)
    assert r.spec(("batch",)) == P(("pod", "data"))


@needs_axis_type
def test_prune_spec_divisibility():
    from repro.launch.dryrun import _prune_spec
    mesh = jax.make_mesh((1,), ("model",),
                         axis_types=(jax.sharding.AxisType.Auto,))

    class FakeMesh:
        shape = {"model": 16, "data": 4}
    spec = _prune_spec(P("model", "data", None), (32, 9, 7), FakeMesh())
    assert spec == P("model", None, None)      # 9 % 4 != 0 -> dropped


# ---------------------------------------------------------------------------
# loop-aware HLO walker
# ---------------------------------------------------------------------------

FAKE_HLO = """\
HloModule test

%body.1 (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %lhs = f32[8,4]{1,0} parameter(1)
  %rhs = f32[4,16]{1,0} parameter(2)
  %dot.1 = f32[8,16]{1,0} dot(%lhs, %rhs), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[2,4]<=[8]
}

%cond.1 (p2: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]) parameter(0)
  %c = s32[] constant(5)
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16]{1,0} parameter(0)
  %t = (s32[], f32[8,16]) tuple(%a)
  %w = (s32[], f32[8,16]) while(%t), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
  %ag = f32[8,16]{1,0} all-gather(%a), channel_id=2, replica_groups=[1,8]<=[8], dimensions={0}
}
"""


def test_parser_scales_loops_and_collectives():
    mc = parse_module(FAKE_HLO)
    assert mc.n_while == 1
    # dot flops: 2*8*16*4 = 1024, x5 trips
    assert mc.dot_flops == pytest.approx(1024 * 5)
    # all-reduce: 8*16*4B * 2*(4-1)/4 factor, x5
    assert mc.coll_bytes["all-reduce"] == pytest.approx(512 * 1.5 * 5)
    # all-gather: result 512B, operand 512/8, receives (8-1) shards
    assert mc.coll_bytes["all-gather"] == pytest.approx(512 / 8 * 7)
    assert mc.coll_counts["all-reduce"] == 5
    assert mc.coll_counts["all-gather"] == 1


def test_parser_fusion_bodies_keep_flops_drop_bytes():
    hlo = """\
HloModule t

%fused_computation (p0: f32[4,4], p1: f32[4,4]) -> f32[4,4] {
  %p0 = f32[4,4]{1,0} parameter(0)
  %p1 = f32[4,4]{1,0} parameter(1)
  %dot.9 = f32[4,4]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

ENTRY %main (x: f32[4,4]) -> f32[4,4] {
  %x = f32[4,4]{1,0} parameter(0)
  %f = f32[4,4]{1,0} fusion(%x, %x), kind=kOutput, calls=%fused_computation
}
"""
    mc = parse_module(hlo)
    assert mc.dot_flops == pytest.approx(2 * 4 * 4 * 4)
    # bytes: only the fusion op at the call site (result 64B + operands 2x64B)
    assert mc.hbm_bytes == pytest.approx(64 * 3)


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

def test_engine_matches_offline_decode():
    cfg = smoke_of("granite-3-2b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    req = Request(uid=1, prompt=[5, 7, 9], max_new_tokens=5)
    eng = Engine(model, params, ServeConfig(max_batch=2, max_seq=32))
    eng.submit(req)
    eng.run()
    # offline reference, batch 1
    caches = model.init_caches(1, 32)
    step = jax.jit(model.decode_step)
    toks, out, cur, k, t = [5, 7, 9], [], 5, 1, 0
    while len(out) < 5:
        logits, caches = step(params, jnp.asarray([[cur]], jnp.int32), caches,
                              jnp.asarray([t]))
        t += 1
        if k < len(toks):
            cur = toks[k]
            k += 1
            continue
        cur = int(jnp.argmax(logits[0, 0, : cfg.vocab]))
        out.append(cur)
    assert req.output == out


def test_engine_continuous_batching_refills():
    cfg = smoke_of("rwkv6-1.6b")          # state-cache arch (attention-free)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(2))
    eng = Engine(model, params, ServeConfig(max_batch=2, max_seq=24))
    rng = np.random.default_rng(0)
    for uid in range(5):
        eng.submit(Request(uid=uid,
                           prompt=list(map(int, rng.integers(1, cfg.vocab, 3))),
                           max_new_tokens=4))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.output) == 4 for r in done)


# ---------------------------------------------------------------------------
# subprocess integration: sharded trainer + production-mesh dry-run
# ---------------------------------------------------------------------------

@needs_axis_type
@pytest.mark.distributed
def test_sharded_train_step_8dev(subproc):
    code = """
import jax, jax.numpy as jnp
from repro.configs.base import smoke_of
from repro.models import build
from repro.train import Trainer, AdamWConfig
from repro.train.data import DataConfig, batch_at
from repro.parallel.sharding import AxisRules, _SINGLE
from repro.configs.shapes import SUITES
mesh = jax.make_mesh((4, 2), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
rules = AxisRules(_SINGLE, mesh=mesh)
cfg = smoke_of("llama3-8b")
model = build(cfg)
tr = Trainer(model, AdamWConfig(warmup_steps=2, total_steps=20), mesh=mesh, rules=rules)
with mesh:
    state = tr.init_state(jax.random.PRNGKey(0))
    step = tr.jit_train_step(SUITES["train_4k"], state)
    dc = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=8, seed=1)
    for t in range(2):
        batch = {k: jnp.asarray(v) for k, v in batch_at(dc, t).items()}
        state, m = step(state, batch)
assert float(m["loss"]) > 0
print("SHARDED_OK", float(m["loss"]))
"""
    r = subproc(code, devices=8, timeout=600)
    assert "SHARDED_OK" in r.stdout, r.stderr[-2000:]


@needs_axis_type
@pytest.mark.distributed
def test_compressed_train_step_8dev(subproc):
    code = """
import jax, jax.numpy as jnp, re
from repro.configs.base import smoke_of
from repro.models import build
from repro.train import Trainer, AdamWConfig
from repro.train.data import DataConfig, batch_at
from repro.parallel.sharding import AxisRules, _SINGLE
from repro.parallel.compression import CompressionConfig
mesh = jax.make_mesh((4, 2), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
rules = AxisRules(_SINGLE, mesh=mesh)
cfg = smoke_of("llama3-8b")
model = build(cfg)
tr = Trainer(model, AdamWConfig(warmup_steps=2, total_steps=20), mesh=mesh,
             rules=rules, compression=CompressionConfig(rank=4, min_dim=32))
with mesh:
    state = tr.init_state(jax.random.PRNGKey(0))
    dc = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=8, seed=1)
    batch = {k: jnp.asarray(v) for k, v in batch_at(dc, 0).items()}
    step = jax.jit(tr.make_train_step())
    state, m = step(state, batch)
    txt = jax.jit(tr.make_train_step()).lower(state, batch).compile().as_text()
# no full-weight-gradient all-reduce: stacked layer grads f32[2,64,...] and
# embed grads must never cross DP at full size
big = [l for l in txt.splitlines() if "all-reduce(" in l
       and ("f32[2,64,160]" in l or "f32[2,64,320]" in l or "f32[512,64]" in l)]
assert not big, big[:2]
assert float(m["compression_ratio"]) > 3, m["compression_ratio"]
print("COMPRESS_OK", float(m["compression_ratio"]))
"""
    r = subproc(code, devices=8, timeout=600)
    assert "COMPRESS_OK" in r.stdout, r.stderr[-2000:]


@needs_axis_type
@pytest.mark.distributed
def test_dryrun_cell_production_mesh(subproc):
    """One real cell through the actual 512-device dry-run path."""
    code = """
import repro.launch.dryrun as dr
import tempfile
out = dr.run_cell("rwkv6-1.6b", "long_500k", "multi", force=True,
                  out_dir=tempfile.mkdtemp())
assert out["status"] == "ok", out
assert out["chips"] == 512
assert out["t_memory"] > 0
print("DRYRUN_OK", out["bottleneck"])
"""
    r = subproc(code, timeout=900)
    assert "DRYRUN_OK" in r.stdout, (r.stdout[-500:], r.stderr[-2000:])


def test_engine_whisper_cross_attention():
    """Enc-dec serving: per-request frames fill the cross-KV cache."""
    cfg = smoke_of("whisper-medium")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(3))
    eng = Engine(model, params, ServeConfig(max_batch=2, max_seq=24))
    rng = np.random.default_rng(1)
    frames = [rng.standard_normal((cfg.enc_seq, cfg.d_model)).astype("f")
              for _ in range(2)]
    for uid in range(2):
        eng.submit(Request(uid=uid, prompt=[3, 5], max_new_tokens=4,
                           frames=frames[uid]))
    done = eng.run()
    assert len(done) == 2 and all(len(r.output) == 4 for r in done)
    # different audio must generally produce different continuations
    # (not guaranteed, but with random weights collisions are ~impossible)
    assert done[0].output != done[1].output


@needs_axis_type
@pytest.mark.distributed
def test_elastic_reshard_restore(subproc, tmp_path):
    """Checkpoint written on 1 device restores onto an 8-device mesh with
    explicit shardings and continues training (elastic scaling)."""
    import jax.numpy as jnp2
    from repro.train import AdamWConfig, Trainer, checkpoint
    from repro.train.data import DataConfig, batch_at
    cfg = smoke_of("granite-3-2b")
    model = build(cfg)
    tr = Trainer(model, AdamWConfig(warmup_steps=1, total_steps=10))
    state = tr.init_state(jax.random.PRNGKey(0))
    dc = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=8, seed=4)
    step = jax.jit(tr.make_train_step())
    batch = {k: jnp2.asarray(v) for k, v in batch_at(dc, 0).items()}
    state, m0 = step(state, batch)
    checkpoint.save(str(tmp_path), 1, state)
    code = f"""
import jax, jax.numpy as jnp
from repro.configs.base import smoke_of
from repro.models import build
from repro.train import Trainer, AdamWConfig, checkpoint
from repro.train.data import DataConfig, batch_at
from repro.parallel.sharding import AxisRules, _SINGLE, param_shardings
mesh = jax.make_mesh((4, 2), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
rules = AxisRules(_SINGLE, mesh=mesh)
cfg = smoke_of("granite-3-2b")
model = build(cfg)
tr = Trainer(model, AdamWConfig(warmup_steps=1, total_steps=10), mesh=mesh, rules=rules)
with mesh:
    template = tr.init_state(jax.random.PRNGKey(0))
    shardings = tr.state_shardings(template)
    state = checkpoint.restore({str(tmp_path)!r}, 1, template, shardings)
    dc = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=8, seed=4)
    batch = {{k: jnp.asarray(v) for k, v in batch_at(dc, 1).items()}}
    step = tr.jit_train_step()
    state, m = step(state, batch)
print("ELASTIC_OK", float(m["loss"]))
"""
    r = subproc(code, devices=8, timeout=600)
    assert "ELASTIC_OK" in r.stdout, (r.stdout[-400:], r.stderr[-2000:])


@needs_axis_type
@pytest.mark.distributed
def test_distributed_halo_chase_8dev(subproc):
    """Beyond-paper: single-matrix bulge chase sharded column-wise over 8
    devices with collective_permute halo exchange — bit-exact vs local."""
    code = """
import numpy as np, jax, jax.numpy as jnp
jax.config.update("jax_enable_x64", True)
from repro.core import band as bandmod, bulge_chasing as bc
from repro.core.distributed import reduce_stage_sharded, bidiagonalize_sharded
mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(0)
n, bw, tw = 96, 8, 3
a = np.triu(rng.standard_normal((n, n))); a = np.triu(a) - np.triu(a, bw+1)
w = bw + tw + 1
ncols = -(-(n + w) // 8) * 8
packed = bandmod.pad_columns(bandmod.pack(jnp.asarray(a), bw, tw), ncols - n)
out_sh = reduce_stage_sharded(packed, n=n, b_in=bw, tw=tw, mesh=mesh)
ref = bc.reduce_stage_packed(bandmod.pack(jnp.asarray(a), bw, tw), n=n, b_in=bw, tw=tw, backend="ref")
err = float(jnp.max(jnp.abs(out_sh[:, :n] - ref[:, :n])))
assert err < 1e-11, err
d, e = bidiagonalize_sharded(jnp.asarray(a), bw=bw, tw=tw, mesh=mesh)
B = np.diag(np.asarray(d)) + np.diag(np.asarray(e)[1:], 1)
s0 = np.linalg.svd(a, compute_uv=False); s1 = np.linalg.svd(B, compute_uv=False)
assert np.abs(s0 - s1).max() / s0[0] < 1e-11
print("DIST_CHASE_OK", err)
"""
    r = subproc(code, devices=8, timeout=600)
    assert "DIST_CHASE_OK" in r.stdout, (r.stdout[-400:], r.stderr[-2000:])
