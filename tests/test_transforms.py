"""Reflector-tape pipeline tests: full SVD (U, sigma, V^T) through every layer.

Verified against the fp64 dense oracle (``bidiagonalize_dense_ref_uv``) and
first principles:

  1. chase-tape replay reproduces the oracle's transforms (U^T A V bidiagonal,
     matching the packed chase's (d, e));
  2. vector properties of the public surface — reconstruction
     ``||U S V^T - A||``, orthogonality ``||U^T U - I||`` / ``||V^T V - I||``
     — across dtypes, batch shapes, and both backends (ref + pallas
     interpret), with sigma BIT-identical to the values-only path;
  3. stage-3 inverse iteration (``bidiag_svd``) in isolation;
  4. the serve engine's compute_uv buckets;
  5. the n = 1 / bw = 0 degenerate edge (regression, satellite);
  6. hypothesis-randomized property sweep (skips without the optional dep).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bulge_chasing as bc
from repro.core import bidiag_svd as s3
from repro.core import transforms
from repro.core import svd as svdmod
from repro.core.tuning import PipelineConfig


def banded_random(n, bw, seed):
    rng = np.random.default_rng(seed)
    a = np.triu(rng.standard_normal((n, n)))
    return np.triu(a) - np.triu(a, bw + 1)


def check_svd(a, u, s, vt, tol):
    """Reconstruction + orthogonality + descending order, all in fp64."""
    n = a.shape[-1]
    a, u, s, vt = (np.asarray(x, np.float64) for x in (a, u, s, vt))
    scale = max(1.0, float(np.max(s)))
    recon = np.abs(np.einsum("...ij,...j,...jk->...ik", u, s, vt) - a).max()
    eye = np.eye(n)
    uerr = np.abs(np.einsum("...ji,...jk->...ik", u, u) - eye).max()
    verr = np.abs(np.einsum("...ij,...kj->...ik", vt, vt) - eye).max()
    assert recon < tol * scale, ("reconstruction", recon)
    assert uerr < tol, ("U orthogonality", uerr)
    assert verr < tol, ("V orthogonality", verr)
    assert np.all(np.diff(s, axis=-1) <= 1e-12 * scale), "sigma not descending"


# ---------------------------------------------------------------------------
# 1. chase-tape replay == dense oracle transforms
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,bw,tw", [(36, 6, 2), (24, 5, 3), (33, 7, 6)])
def test_chase_tape_replay_matches_oracle(n, bw, tw):
    a = banded_random(n, bw, seed=n + bw)
    d, e, tapes = bc.bidiagonalize(jnp.asarray(a), bw=bw, tw=tw,
                                   backend="ref", tape=True)
    u, vt = transforms.accumulate_transforms(n, chase_tapes=tapes,
                                             dtype=jnp.float64)
    u, vt = np.asarray(u), np.asarray(vt)
    B = u.T @ a @ vt.T
    np.testing.assert_allclose(np.diag(B), np.asarray(d), atol=1e-11)
    np.testing.assert_allclose(np.diag(B, 1), np.asarray(e)[1:], atol=1e-11)
    off = B - np.diag(np.diag(B)) - np.diag(np.diag(B, 1), 1)
    assert np.abs(off).max() < 1e-11
    assert np.abs(u.T @ u - np.eye(n)).max() < 1e-12
    assert np.abs(vt @ vt.T - np.eye(n)).max() < 1e-12
    # the oracle agrees on the bidiagonal itself
    dref, eref, _, _ = bc.bidiagonalize_dense_ref_uv(a, bw, tw)
    np.testing.assert_allclose(np.abs(np.asarray(d)), np.abs(dref), atol=1e-10)


def test_tape_mode_leaves_band_arithmetic_untouched():
    """(d, e) must be BIT-identical with and without the tape."""
    n, bw, tw = 40, 6, 2
    a = jnp.asarray(banded_random(n, bw, 3))
    d0, e0 = bc.bidiagonalize(a, bw=bw, tw=tw, backend="ref")
    d1, e1, _ = bc.bidiagonalize(a, bw=bw, tw=tw, backend="ref", tape=True)
    assert np.array_equal(np.asarray(d0), np.asarray(d1))
    assert np.array_equal(np.asarray(e0), np.asarray(e1))


# ---------------------------------------------------------------------------
# 2. public surface: svd / svd_batched across dtypes, batches, backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("dtype,tol", [(jnp.float64, 1e-10),
                                       (jnp.float32, 5e-4)])
def test_svd_dense_roundtrip(backend, dtype, tol):
    n, bw, tw = 32, 8, 4
    a = np.random.default_rng(11).standard_normal((n, n))
    aj = jnp.asarray(a, dtype)
    u, s, vt = svdmod.svd(aj, bw=bw, tw=tw, backend=backend)
    check_svd(np.asarray(aj), u, s, vt, tol)
    # sigma bit-identical to the values-only path
    s_only = svdmod.singular_values(aj, bw=bw, tw=tw, backend=backend)
    assert np.array_equal(np.asarray(s), np.asarray(s_only))


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_svd_batched_roundtrip(backend):
    B, n, bw, tw = 3, 24, 6, 3
    mats = np.random.default_rng(2).standard_normal((B, n, n))
    cfg = PipelineConfig.resolve(bw=bw, tw=tw, backend=backend,
                                 dtype=np.float64, n=n)
    u, s, vt = svdmod.svd_batched(jnp.asarray(mats), config=cfg,
                                  compute_uv=True)
    check_svd(mats, u, s, vt, 1e-10)
    for b in range(B):
        s0 = np.linalg.svd(mats[b], compute_uv=False)
        np.testing.assert_allclose(np.asarray(s)[b], s0, atol=1e-9 * s0[0])
    # batched sigma bit-identical to the values-only batched path
    s_only = svdmod.svd_batched(jnp.asarray(mats), config=cfg)
    assert np.array_equal(np.asarray(s), np.asarray(s_only))
    # config-default threading: compute_uv=True in the config alone suffices
    import dataclasses
    cfg_uv = dataclasses.replace(cfg, compute_uv=True)
    res = svdmod.svd_batched(jnp.asarray(mats), config=cfg_uv)
    assert isinstance(res, tuple) and len(res) == 3


def test_banded_svd_roundtrip():
    n, bw, tw = 40, 6, 2
    a = banded_random(n, bw, 9)
    u, s, vt = svdmod.banded_svd(jnp.asarray(a), bw=bw, tw=tw, backend="ref")
    check_svd(a, u, s, vt, 1e-10)
    s0 = np.linalg.svd(a, compute_uv=False)
    np.testing.assert_allclose(np.asarray(s), s0, atol=1e-9 * s0[0])


# ---------------------------------------------------------------------------
# 3. stage-3 vectors in isolation
# ---------------------------------------------------------------------------

def test_bidiag_svd_stage3():
    n = 24
    rng = np.random.default_rng(4)
    d = rng.standard_normal(n)
    e = np.concatenate([[0.0], rng.standard_normal(n - 1)])
    B = np.diag(d) + np.diag(e[1:], 1)
    u, s, vt = s3.bidiag_svd(jnp.asarray(d), jnp.asarray(e))
    check_svd(B, u, s, vt, 1e-10)
    # values bit-identical to the bisection entry point
    s_only = s3.bidiag_singular_values(jnp.asarray(d), jnp.asarray(e))
    assert np.array_equal(np.asarray(s), np.asarray(s_only))
    # batched stacking vmaps
    ds = jnp.asarray(np.stack([d, 2 * d]))
    es = jnp.asarray(np.stack([e, e]))
    ub, sb, vtb = s3.bidiag_svd(ds, es)
    assert ub.shape == (2, n, n) and sb.shape == (2, n)
    np.testing.assert_allclose(np.asarray(sb)[0], np.asarray(s), atol=0)


def test_svd_degenerate_spectra():
    """Repeated/clustered sigma: inverse iteration alone gives non-orthogonal
    vectors inside a cluster — the stein-style reorthogonalization +
    u = Bv/||Bv|| re-pairing must recover a valid SVD."""
    rng = np.random.default_rng(1)
    q, _ = np.linalg.qr(rng.standard_normal((8, 8)))
    lowrank = rng.standard_normal((8, 3)) @ rng.standard_normal((3, 8))
    cases = [
        ("identity", np.eye(8)),
        ("orthogonal", q),                       # all sigma = 1
        ("repeated", np.diag([3.0, 2.0, 2.0, 1.0])),
        ("near-degenerate", np.diag([1.0, 1.0 + 1e-9, 0.5, 0.3])),
        ("rank-deficient", lowrank),             # sigma = 0 cluster
        ("zero", np.zeros((6, 6))),
    ]
    for name, a in cases:
        n = a.shape[0]
        bw = max(2, n // 4)
        u, s, vt = svdmod.svd(jnp.asarray(a), bw=bw, tw=max(1, bw // 2),
                              backend="ref")
        check_svd(a, u, s, vt, 1e-10)
        s0 = np.linalg.svd(a, compute_uv=False)
        np.testing.assert_allclose(np.asarray(s), s0, atol=1e-9 * max(s0[0], 1),
                                   err_msg=name)


# ---------------------------------------------------------------------------
# 4. serve engine compute_uv buckets
# ---------------------------------------------------------------------------

def test_engine_compute_uv_bucketing():
    from repro.serve.engine import SVDEngine, SVDRequest
    rng = np.random.default_rng(8)
    eng = SVDEngine(PipelineConfig.resolve(bw=6, tw=2, backend="ref",
                                           dtype=np.float64))
    mats = [rng.standard_normal((20, 20)) for _ in range(6)]
    for i, m in enumerate(mats):
        eng.submit(SVDRequest(uid=i, matrix=m, bw=6, compute_uv=(i % 2 == 0)))
    done = eng.run()
    assert len(done) == 6
    for r in done:
        s0 = np.linalg.svd(mats[r.uid], compute_uv=False)
        np.testing.assert_allclose(r.sigma, s0, atol=1e-8 * s0[0])
        if r.compute_uv:
            check_svd(mats[r.uid], r.u, r.sigma, r.vt, 1e-9)
        else:
            assert r.u is None and r.vt is None


# ---------------------------------------------------------------------------
# 5. degenerate edges: n = 1 and bw = 0  (regression, satellite)
# ---------------------------------------------------------------------------

def test_degenerate_n1_and_bw0():
    # gk_offdiag (2n-1,) fast path
    z = s3.gk_offdiag(jnp.asarray([3.0]), jnp.asarray([0.0]))
    assert z.shape == (1,) and float(z[0]) == 3.0
    np.testing.assert_allclose(
        np.asarray(s3.bidiag_singular_values(jnp.asarray([-2.0]),
                                             jnp.asarray([0.0]))), [2.0])
    # singular_values / svd_batched on 1x1 problems
    np.testing.assert_allclose(
        np.asarray(svdmod.singular_values(jnp.asarray([[-4.0]]))), [4.0])
    stack = jnp.asarray(np.array([[[2.0]], [[-5.0]]]))
    np.testing.assert_allclose(np.asarray(svdmod.svd_batched(stack)),
                               [[2.0], [5.0]])
    u, s, vt = svdmod.svd_batched(stack, compute_uv=True)
    np.testing.assert_allclose(
        np.asarray(u) * np.asarray(s)[..., None] * np.asarray(vt),
        np.asarray(stack))
    # bw = 0 resolves to a working (clamped) config
    cfg = PipelineConfig.resolve(bw=0, dtype=np.float64, n=4)
    assert cfg.bw >= 1
    a = np.random.default_rng(0).standard_normal((4, 4))
    s0 = np.linalg.svd(a, compute_uv=False)
    np.testing.assert_allclose(
        np.asarray(svdmod.singular_values(jnp.asarray(a), config=cfg)),
        s0, atol=1e-10 * s0[0])
    u4, s4, vt4 = svdmod.svd(jnp.asarray(a), config=cfg)
    check_svd(a, u4, s4, vt4, 1e-10)


# ---------------------------------------------------------------------------
# 6. hypothesis-randomized property sweep (optional dep; skip-shim otherwise)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(8, 40), st.integers(2, 8), st.integers(1, 5),
       st.integers(0, 2**31 - 1))
def test_svd_property_randomized(n, bw, tw, seed):
    bw = min(bw, n - 2)
    if bw < 2:
        return
    tw = min(tw, bw - 1)
    a = np.random.default_rng(seed).standard_normal((n, n))
    u, s, vt = svdmod.svd(jnp.asarray(a), bw=bw, tw=tw, backend="ref")
    check_svd(a, u, s, vt, 1e-9)
    s0 = np.linalg.svd(a, compute_uv=False)
    np.testing.assert_allclose(np.asarray(s), s0, atol=1e-9 * max(s0[0], 1))
