"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref as kref
from repro.kernels import ops
from repro.kernels.bulge_chase import chase_cycle_pallas
from repro.kernels.hh_apply import hh_block_apply_pallas

CHASE_SHAPES = [(4, 2, 3), (6, 2, 4), (8, 3, 5), (12, 4, 3), (16, 8, 2),
                (32, 8, 2), (5, 4, 6), (2, 1, 8)]
DTYPES = [(jnp.float32, 3e-5), (jnp.float64, 1e-12), (jnp.bfloat16, 8e-2)]


@pytest.mark.parametrize("b_in,tw,G", CHASE_SHAPES)
@pytest.mark.parametrize("dtype,tol", DTYPES)
def test_chase_kernel_matches_ref(b_in, tw, G, dtype, tol):
    H, W = b_in + 2 * tw + 1, b_in + tw + 1
    rng = np.random.default_rng(b_in * 1000 + tw)
    win = jnp.asarray(rng.standard_normal((G, H, W)), dtype)
    first = jnp.asarray([i % 2 == 0 for i in range(G)])
    a = kref.chase_cycle_ref(win, first, b_in=b_in, tw=tw)
    b = chase_cycle_pallas(win, first, b_in=b_in, tw=tw, interpret=True)
    scale = max(1.0, float(jnp.max(jnp.abs(a)).astype(jnp.float32)))
    np.testing.assert_allclose(np.asarray(b, np.float64), np.asarray(a, np.float64),
                               atol=tol * scale)


@pytest.mark.parametrize("b_in,tw", [(6, 2), (12, 4)])
def test_chase_kernel_zero_window_noop(b_in, tw):
    """Padding semantics: all-zero windows must stay exactly zero."""
    H, W = b_in + 2 * tw + 1, b_in + tw + 1
    win = jnp.zeros((3, H, W), jnp.float32)
    first = jnp.asarray([True, False, True])
    out = chase_cycle_pallas(win, first, b_in=b_in, tw=tw, interpret=True)
    assert float(jnp.max(jnp.abs(out))) == 0.0


WY_SHAPES = [(64, 8, 100), (128, 16, 64), (33, 4, 7), (256, 32, 512), (16, 1, 5)]


@pytest.mark.parametrize("m,k,n", WY_SHAPES)
@pytest.mark.parametrize("dtype,tol", DTYPES)
def test_wy_kernel_matches_ref(m, k, n, dtype, tol):
    rng = np.random.default_rng(m + k + n)
    v = np.tril(rng.standard_normal((m, k)), -1)
    v[np.arange(k), np.arange(k)] = 1.0
    t = np.triu(rng.standard_normal((k, k))) * 0.2
    c = rng.standard_normal((m, n))
    v, t, c = (jnp.asarray(x, dtype) for x in (v, t, c))
    a = kref.hh_block_apply_ref(v, t, c)
    b = hh_block_apply_pallas(v, t, c, interpret=True, block_cols=64)
    scale = max(1.0, float(jnp.max(jnp.abs(a)).astype(jnp.float32)))
    np.testing.assert_allclose(np.asarray(b, np.float64), np.asarray(a, np.float64),
                               atol=tol * scale * max(1, k // 4))


def test_ops_dispatch_ref_equals_pallas():
    b_in, tw, G = 8, 3, 4
    H, W = b_in + 2 * tw + 1, b_in + tw + 1
    rng = np.random.default_rng(0)
    win = jnp.asarray(rng.standard_normal((G, H, W)), jnp.float32)
    first = jnp.zeros((G,), bool)
    a = ops.chase_cycle(win, first, b_in=b_in, tw=tw, backend="ref")
    b = ops.chase_cycle(win, first, b_in=b_in, tw=tw, backend="pallas")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


def test_ops_unknown_backend_raises():
    with pytest.raises(ValueError):
        ops.chase_cycle(jnp.zeros((1, 8, 6)), jnp.zeros((1,), bool),
                        b_in=3, tw=2, backend="nope")


# ---------------------------------------------------------------------------
# flash attention (A4 kernel) + stage-1 pallas integration
# ---------------------------------------------------------------------------

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ref import flash_attention_ref

# Known seed failure (DESIGN.md §10): jax < 0.5 pallas interpret mode cannot
# discharge the flash kernel's masked loads (`_load_discharge_rule` receives a
# plain int index -> AttributeError: 'int' object has no attribute 'shape').
# The chase kernels never hit this path; the flash tests xfail (non-strict, so
# a jax upgrade that fixes interpret mode turns them back on silently).
_JAX_VERSION = tuple(int(p) for p in jax.__version__.split(".")[:3]
                     if p.isdigit())
flash_interpret_xfail = pytest.mark.xfail(
    _JAX_VERSION < (0, 5), strict=False,
    reason="jax<0.5 pallas interpret bug: masked-load discharge fails "
           "(pre-existing seed failure, DESIGN.md §10)")

FLASH_SHAPES = [(4, 256, 64, 64, 64), (2, 128, 32, 32, 64),
                (2, 256, 64, 128, 32), (1, 64, 16, 64, 64),
                (3, 192, 64, 64, 32)]


@flash_interpret_xfail
@pytest.mark.parametrize("bh,s,d,bq,bk", FLASH_SHAPES)
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 3e-6), (jnp.bfloat16, 3e-2)])
def test_flash_attention_matches_ref(bh, s, d, bq, bk, dtype, tol):
    rng = np.random.default_rng(s + d)
    q, k, v = (jnp.asarray(rng.standard_normal((bh, s, d)), dtype)
               for _ in range(3))
    a = flash_attention_ref(q, k, v)
    b = flash_attention_pallas(q, k, v, block_q=bq, block_k=bk, interpret=True)
    err = float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
    assert err < tol, err


@flash_interpret_xfail
def test_flash_attention_is_causal():
    """Perturbing future tokens must not change earlier outputs."""
    rng = np.random.default_rng(0)
    q, k, v = (jnp.asarray(rng.standard_normal((1, 128, 32)), jnp.float32)
               for _ in range(3))
    o1 = flash_attention_pallas(q, k, v, block_q=32, block_k=32, interpret=True)
    k2 = k.at[:, 96:].add(5.0)
    v2 = v.at[:, 96:].add(5.0)
    o2 = flash_attention_pallas(q, k2, v2, block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(o1[:, :96]), np.asarray(o2[:, :96]),
                               atol=1e-6)
    assert float(jnp.max(jnp.abs(o1[:, 96:] - o2[:, 96:]))) > 1e-3


def test_stage1_pallas_backend_bit_exact():
    from repro.core.stage1 import band_reduce
    rng = np.random.default_rng(7)
    a = rng.standard_normal((48, 48))
    b_ref = np.asarray(band_reduce(jnp.asarray(a), nb=8, backend="ref"))
    b_pal = np.asarray(band_reduce(jnp.asarray(a), nb=8, backend="pallas"))
    np.testing.assert_array_equal(b_pal, b_ref)
