"""Cycle-fused chase super-steps (DESIGN.md §9).

Covers the fused stage-2 stack end to end:

  1. the generalized wavefront schedule: every (sweep, local cycle) executes
     exactly once at any fuse depth, and the super-cycle count matches the
     closed form (window disjointness itself is asserted exhaustively next
     to the K=1 proof in tests/test_batched.py);
  2. fused-vs-unfused equivalence of the stage output AND the reflector
     tape — same reflectors in the same per-sweep order — for
     K in {1, 2, 4} x both backends x batched/unbatched x tape on/off;
  3. full-SVD equivalence: sigma bit-identical, U/V^T within fp64 noise,
     for fused configs through the public ``svd_batched`` surface;
  4. the VMEM performance model: monotonicity in fuse depth, the K=1
     fallback, and ``PipelineConfig`` fuse resolution;
  5. a hypothesis-randomized property sweep (skips without the optional
     dependency).
"""

import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import band as bandmod
from repro.core import bulge_chasing as bc
from repro.core import svd as svdmod
from repro.core import tuning
from repro.core.tuning import PipelineConfig


def banded_random(n, bw, seed, lead=()):
    rng = np.random.default_rng(seed)
    a = np.triu(rng.standard_normal(lead + (n, n)))
    return np.triu(a) - np.triu(a, bw + 1)


def sweep_cycles(n, b_in, tw):
    """All (sweep, local cycle) pairs of one stage, from the definition."""
    b_out = b_in - tw
    return [(R, j) for R in range(max(n - 1 - b_out, 0))
            for j in range((n - 1 - R - b_out) // b_in + 1)]


def tape_at(tv, tt, n, b_in, tw, fuse, R, j):
    """(v pair, tau pair) of sweep R's local cycle j in a fuse-K tape."""
    sep = tuning.sweep_separation(fuse)
    ts, g, i = sep * R + j // fuse, (j // fuse) // sep, j % fuse
    if fuse == 1:
        return tv[ts, g], tt[ts, g]
    return tv[ts, g, i], tt[ts, g, i]


# ---------------------------------------------------------------------------
# 1. generalized schedule
# ---------------------------------------------------------------------------

SCHED_CASES = [(16, 2, 1), (24, 4, 2), (32, 8, 4), (33, 7, 6), (48, 5, 2),
               (57, 9, 4), (100, 16, 8), (8, 3, 1)]


@pytest.mark.parametrize("fuse", [1, 2, 4, 8])
@pytest.mark.parametrize("n,b_in,tw", SCHED_CASES)
def test_fused_schedule_executes_every_cycle_once(n, b_in, tw, fuse):
    """The super-step schedule is a partition of the sequential cycle list:
    each (R, j) appears in exactly one (super-cycle, slot, fused index)."""
    nsweeps, total, G = bc.stage_schedule(n, b_in, tw, fuse)
    expected = sweep_cycles(n, b_in, tw)
    assert nsweeps == max(n - 1 - (b_in - tw), 0)
    seen = []
    g = np.arange(G)
    for t in range(total):
        R, j, p, active, is_first = bc.chase_cycle_indices(t, g, n, b_in, tw,
                                                           fuse)
        R, j, p = map(np.asarray, (R, j, p))
        for s in range(G):
            if not np.asarray(active)[s]:
                continue
            assert bool(np.asarray(is_first)[s]) == (j[s] == 0)
            for i in range(fuse):
                if p[s] + i * b_in <= n - 1:
                    seen.append((int(R[s]), int(j[s]) + i))
    assert sorted(seen) == expected, (n, b_in, tw, fuse)
    assert len(seen) == len(set(seen))


@pytest.mark.parametrize("fuse", [2, 4, 8])
def test_fused_schedule_shrinks_supercycles_and_slots(fuse):
    n, b_in, tw = 1024, 32, 8
    _, t1, g1 = bc.stage_schedule(n, b_in, tw, 1)
    _, tk, gk = bc.stage_schedule(n, b_in, tw, fuse)
    assert tk < t1                      # fewer kernel launches
    assert gk <= g1                     # no dead wavefront slots
    sep = tuning.sweep_separation(fuse)
    nsweeps = n - 1 - (b_in - tw)
    jmax_last = (n - 1 - (nsweeps - 1) - (b_in - tw)) // b_in
    assert tk == sep * (nsweeps - 1) + -(-(jmax_last + 1) // fuse)


# ---------------------------------------------------------------------------
# 2. fused == unfused: stage output and reflector tape
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("batched", [False, True])
@pytest.mark.parametrize("tape", [False, True])
def test_fused_stage_matches_unfused(backend, batched, tape):
    n, bw, tw = 26, 5, 2
    lead = (3,) if batched else ()
    mats = banded_random(n, bw, seed=7, lead=lead)
    packed = bandmod.pack(jnp.asarray(mats), bw, tw)
    kw = dict(n=n, b_in=bw, tw=tw, backend=backend)
    if tape:
        base, v1, t1 = bc.reduce_stage_packed(packed, tape=True, **kw)
    else:
        base = bc.reduce_stage_packed(packed, **kw)
    for K in (1, 2, 4):
        if tape:
            out, vK, tK = bc.reduce_stage_packed(packed, tape=True, fuse=K,
                                                 **kw)
        else:
            out = bc.reduce_stage_packed(packed, fuse=K, **kw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   rtol=0, atol=1e-12)
        if not tape:
            continue
        # same reflectors in the same per-sweep order
        v1n, t1n = np.asarray(v1), np.asarray(t1)
        vKn, tKn = np.asarray(vK), np.asarray(tK)
        if not batched:
            v1n, t1n, vKn, tKn = (x[None] for x in (v1n, t1n, vKn, tKn))
        for R, j in sweep_cycles(n, bw, tw):
            for b in range(v1n.shape[0]):
                va, ta = tape_at(v1n[b], t1n[b], n, bw, tw, 1, R, j)
                vb, tb = tape_at(vKn[b], tKn[b], n, bw, tw, K, R, j)
                np.testing.assert_allclose(vb, va, rtol=0, atol=1e-12)
                np.testing.assert_allclose(tb, ta, rtol=0, atol=1e-12)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_fused_bidiagonalize_matches(backend):
    """Full bw -> 1 reduction (multi-stage plan) is fuse-invariant."""
    n, bw, tw = 30, 6, 3
    a = jnp.asarray(banded_random(n, bw, seed=1))
    d0, e0 = bc.bidiagonalize(a, bw=bw, tw=tw, backend=backend)
    for K in (2, 4):
        dK, eK = bc.bidiagonalize(a, bw=bw, tw=tw, backend=backend, fuse=K)
        np.testing.assert_allclose(np.asarray(dK), np.asarray(d0),
                                   rtol=0, atol=1e-12)
        np.testing.assert_allclose(np.asarray(eK), np.asarray(e0),
                                   rtol=0, atol=1e-12)


# ---------------------------------------------------------------------------
# 3. full SVD through the public surface
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_fused_full_svd_matches(backend):
    B, n, bw, tw = 2, 20, 4, 2
    mats = np.random.default_rng(5).standard_normal((B, n, n))
    cfg1 = PipelineConfig.resolve(bw=bw, tw=tw, backend=backend,
                                  dtype=np.float64, n=n)
    u1, s1, vt1 = svdmod.svd_batched(jnp.asarray(mats), config=cfg1,
                                     compute_uv=True)
    for K in (2, 4):
        cfgK = dataclasses.replace(cfg1, fuse=K)
        uK, sK, vtK = svdmod.svd_batched(jnp.asarray(mats), config=cfgK,
                                         compute_uv=True)
        # every cycle applies the same reflector to the same values, so the
        # spectra agree to the last few ulps (bit-identity across fuse
        # depths is not promised — they are different compiled programs)
        np.testing.assert_allclose(np.asarray(sK), np.asarray(s1),
                                   rtol=0, atol=1e-12)
        np.testing.assert_allclose(np.asarray(uK), np.asarray(u1),
                                   rtol=0, atol=1e-12)
        np.testing.assert_allclose(np.asarray(vtK), np.asarray(vt1),
                                   rtol=0, atol=1e-12)
        # and the reconstruction holds on its own
        recon = np.einsum("bij,bj,bjk->bik", np.asarray(uK), np.asarray(sK),
                          np.asarray(vtK))
        assert np.abs(recon - mats).max() < 1e-10 * np.asarray(sK).max()


def test_fused_values_only_matches_batched_surface():
    B, n, bw = 3, 24, 4
    mats = np.random.default_rng(9).standard_normal((B, n, n))
    cfg1 = PipelineConfig.resolve(bw=bw, tw=2, backend="ref",
                                  dtype=np.float64, n=n)
    s1 = svdmod.svd_batched(jnp.asarray(mats), config=cfg1)
    s4 = svdmod.svd_batched(jnp.asarray(mats),
                            config=dataclasses.replace(cfg1, fuse=4))
    np.testing.assert_allclose(np.asarray(s4), np.asarray(s1),
                               rtol=0, atol=1e-12)


def test_serve_engine_forwards_fuse():
    """The serve layer must run bucket flushes at the configured fuse depth
    (regression: _cfg_for used to rebuild bucket configs without fuse)."""
    from repro.serve import SVDEngine, SVDRequest

    eng = SVDEngine(PipelineConfig.resolve(bw=4, tw=2, backend="ref",
                                           dtype=np.float64, max_batch=4,
                                           fuse=4))
    mats = np.random.default_rng(6).standard_normal((3, 20, 20))
    for i, m in enumerate(mats):
        eng.submit(SVDRequest(uid=i, matrix=m, bw=4))
    assert eng._cfg_for(next(iter(eng.buckets))).fuse == 4
    for r in eng.run():
        s0 = np.linalg.svd(mats[r.uid], compute_uv=False)
        np.testing.assert_allclose(r.sigma, s0, atol=1e-10 * s0[0])


# ---------------------------------------------------------------------------
# 4. VMEM performance model + config resolution
# ---------------------------------------------------------------------------

def test_vmem_model_monotone_in_fuse():
    for b_in, tw in [(32, 8), (64, 16), (8, 3), (2, 1)]:
        sizes = [tuning.vmem_working_set_bytes(b_in, tw, jnp.float32, fuse=k)
                 for k in range(1, 17)]
        assert all(a < b for a, b in zip(sizes, sizes[1:])), (b_in, tw)
        # the tape adds output blocks on top, never subtracts
        taped = [tuning.vmem_working_set_bytes(b_in, tw, jnp.float32, fuse=k,
                                               tape=True)
                 for k in range(1, 17)]
        assert all(t > s for s, t in zip(sizes, taped))
    # wider precision costs more VMEM for the same window
    assert (tuning.vmem_working_set_bytes(32, 8, jnp.float64, fuse=4) >
            tuning.vmem_working_set_bytes(32, 8, jnp.float32, fuse=4))


def test_default_fuse_depth_budget_and_fallback():
    # a tiny budget always falls back to K = 1 (the pre-rolled-window path)
    assert tuning.default_fuse_depth(32, 8, jnp.float32, budget_bytes=1) == 1
    # a huge budget saturates the cap
    assert tuning.default_fuse_depth(32, 8, jnp.float32,
                                     budget_bytes=1 << 40, cap=8) == 8
    # the chosen depth actually fits, and depth+1 would not
    for b_in, tw, budget in [(32, 8, 200_000), (64, 16, 600_000),
                             (128, 32, 400_000)]:
        k = tuning.default_fuse_depth(b_in, tw, jnp.float32,
                                      budget_bytes=budget, cap=16)
        assert tuning.vmem_working_set_bytes(b_in, tw, jnp.float32,
                                             fuse=k) <= budget or k == 1
        if k < 16:
            assert tuning.vmem_working_set_bytes(
                b_in, tw, jnp.float32, fuse=k + 1) > budget
    # monotone: a bigger band never earns a deeper default fuse
    ks = [tuning.default_fuse_depth(b, b // 4, jnp.float32, cap=16)
          for b in (16, 32, 64, 128, 256)]
    assert all(a >= b for a, b in zip(ks, ks[1:]))


def test_pipeline_config_fuse_resolution():
    cfg = PipelineConfig.resolve(bw=16, tw=8, backend="ref",
                                 dtype=np.float64)
    assert cfg.fuse == 1                       # conservative default
    auto = PipelineConfig.resolve(bw=16, tw=8, backend="ref",
                                  dtype=np.float64, fuse=None)
    assert auto.fuse == tuning.default_fuse_depth(16, 8, jnp.float64)
    assert PipelineConfig.resolve(bw=16, backend="ref", fuse=0).fuse == 1
    # fuse is part of the kernel cache key (it changes the traced pipeline)
    assert cfg.kernel() != dataclasses.replace(cfg, fuse=4).kernel()


def test_tight_fused_wavefront_bound_is_sufficient():
    """max_concurrent_sweeps(fuse, tw) >= every slot index the schedule
    ever populates (the tight duration-based bound, not the stride one)."""
    for n, b_in, tw in SCHED_CASES:
        for fuse in (2, 4, 8):
            G = tuning.max_concurrent_sweeps(n, b_in, fuse, tw)
            sep = tuning.sweep_separation(fuse)
            jmax0 = max((n - 1 - (b_in - tw)) // b_in, 0)
            dur0 = -(-(jmax0 + 1) // fuse)
            assert G == max(1, (dur0 - 1) // sep + 1)
            # exhaustive: the hosting rule never needs a slot >= G
            nsweeps, total, _ = bc.stage_schedule(n, b_in, tw, fuse)
            for t in range(total):
                for R in range(nsweeps):
                    js = t - sep * R
                    if 0 <= js < dur0 and R + (b_in - tw) + js * fuse * b_in <= n - 1:
                        assert js // sep < G, (n, b_in, tw, fuse, t, R)


# ---------------------------------------------------------------------------
# 5. hypothesis-randomized property sweep
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(8, 36), st.integers(2, 7), st.data(),
       st.sampled_from([2, 3, 4, 8]), st.booleans())
def test_fused_stage_matches_unfused_randomized(n, bw, data, fuse, batched):
    tw = data.draw(st.integers(1, bw - 1), label="tw")
    seed = data.draw(st.integers(0, 2 ** 16), label="seed")
    lead = (2,) if batched else ()
    mats = banded_random(n, bw, seed=seed, lead=lead)
    packed = bandmod.pack(jnp.asarray(mats), bw, tw)
    base = bc.reduce_stage_packed(packed, n=n, b_in=bw, tw=tw, backend="ref")
    out = bc.reduce_stage_packed(packed, n=n, b_in=bw, tw=tw, backend="ref",
                                 fuse=fuse)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               rtol=0, atol=1e-12)
