"""Observability layer tests (DESIGN.md §16): span tracer semantics,
streaming histogram fidelity/merge/serialization, JSONL trace round-trip,
the Prometheus exposition endpoint, compile-vs-run attribution, the
acceptance-bar span coverage of one traced ``svd_batched`` call, and the
bounded-memory property of the serve-tier latency histograms."""

import json
import re
import threading
import urllib.request

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import obs
from repro.core import svd as svdmod
from repro.core.tuning import PipelineConfig
from repro.obs import (JsonlExporter, MetricsServer, StreamingHistogram,
                       Tracer, load_jsonl, render_serve_metrics)
from repro.serve import ServeMetrics, SVDEngine, SVDRequest, bucket_key_str


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

def test_span_nesting_attrs_and_timing():
    tr = Tracer("t")
    with tr.span("root", n=8) as root:
        with tr.span("child_a") as a:
            a.set(bw=4)
        with tr.span("child_b"):
            pass
    assert [r.name for r in tr.roots] == ["root"]
    assert [c.name for c in root.children] == ["child_a", "child_b"]
    assert root.attrs["n"] == 8
    assert root.children[0].attrs["bw"] == 4
    assert root.dur_s >= root.total_child_seconds() > 0.0
    assert root.find("child_b") == [root.children[1]]


def test_span_exception_safety():
    """An exception inside a span must close it (duration recorded, stack
    popped, error attribute set) and propagate unswallowed."""
    tr = Tracer("t")
    with pytest.raises(ValueError, match="boom"):
        with tr.span("outer"):
            with tr.span("inner"):
                raise ValueError("boom")
    (outer,) = tr.roots
    assert outer.dur_s is not None
    (inner,) = outer.children
    assert "boom" in inner.attrs["error"]
    assert "boom" in outer.attrs["error"]
    # the thread-local stack is clean: a new span becomes a fresh root
    with tr.span("after"):
        pass
    assert [r.name for r in tr.roots] == ["outer", "after"]


def test_ambient_tracer_and_null_span():
    """obs.span() is a no-op without an active tracer and records when one
    is activated; activation is scoped."""
    with obs.span("orphan") as sp:
        sp.set(x=1)                      # must not raise on the null span
    tr = Tracer("ambient")
    with obs.activated(tr):
        assert obs.current() is tr
        with obs.span("seen"):
            pass
    assert obs.current() is not tr
    assert [r.name for r in tr.roots] == ["seen"]


def test_spans_are_noop_under_jit_tracing():
    """Host spans inside jitted code must not fire at trace time."""
    tr = Tracer("t")

    @jax.jit
    def f(x):
        with obs.span("inside-jit"):
            return x * 2

    with obs.activated(tr):
        np.testing.assert_allclose(f(jnp.ones(3)), 2.0)
    assert tr.roots == []


# ---------------------------------------------------------------------------
# histogram
# ---------------------------------------------------------------------------

def test_histogram_percentiles_within_one_bucket():
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=-4.0, sigma=1.2, size=5000)
    h = StreamingHistogram()
    h.extend(samples)
    r = h.bucket_width_ratio()
    for q in (50, 95, 99):
        exact = float(np.percentile(samples, q, method="higher"))
        approx = h.percentile(q)
        assert exact / r <= approx <= exact * r, (q, exact, approx)
    assert h.count == samples.size
    assert h.min == samples.min() and h.max == samples.max()
    np.testing.assert_allclose(h.mean, samples.mean())


def test_histogram_concurrent_merge_matches_numpy():
    """N threads each fill a private histogram; the merge must equal one
    histogram over all samples, and its percentiles must sit within one
    bucket width of numpy's exact ones."""
    rng = np.random.default_rng(1)
    chunks = [rng.lognormal(mean=-5.0, sigma=1.0, size=2000)
              for _ in range(4)]
    hists = [StreamingHistogram() for _ in chunks]

    def fill(h, vals):
        for v in vals:
            h.add(v)

    threads = [threading.Thread(target=fill, args=(h, c))
               for h, c in zip(hists, chunks)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    merged = hists[0]
    for h in hists[1:]:
        merged.merge(h)
    allv = np.concatenate(chunks)
    assert merged.count == allv.size
    one = StreamingHistogram()
    one.extend(allv)
    np.testing.assert_array_equal(merged.counts(), one.counts())
    r = merged.bucket_width_ratio()
    for q in (50, 95, 99):
        exact = float(np.percentile(allv, q, method="higher"))
        assert exact / r <= merged.percentile(q) <= exact * r


def test_histogram_merge_scheme_mismatch_raises():
    with pytest.raises(ValueError, match="bucket schemes"):
        StreamingHistogram().merge(StreamingHistogram(buckets_per_decade=5))


def test_histogram_dict_roundtrip():
    h = StreamingHistogram()
    h.extend([1e-4, 3e-3, 3e-3, 0.2, 7.0])
    h2 = StreamingHistogram.from_dict(
        json.loads(json.dumps(h.to_dict())))
    np.testing.assert_array_equal(h.counts(), h2.counts())
    assert (h.count, h.sum, h.min, h.max) == (h2.count, h2.sum,
                                              h2.min, h2.max)
    for q in (50, 95, 99):
        assert h.percentile(q) == h2.percentile(q)


def test_histogram_bounded_memory_10k():
    """10k observations through the ServeMetrics latency surface must not
    grow any per-sample state: bucket arrays stay at their fixed size and
    the only O(N) quantity is the integer count."""
    m = ServeMetrics()
    key = (64, 8, "float64", False, False)
    m.set_bucket_tier(key, "staged", n=64, backend="ref")
    rng = np.random.default_rng(2)
    lats = rng.lognormal(mean=-5.0, sigma=0.8, size=10_000)
    for lat in lats:
        m.observe_latency("staged", key, float(lat))
        m.observe_queue_age(float(lat) / 4)
    hists = m.histograms()
    th = hists["tiers"]["staged"]
    bh = hists["buckets"][bucket_key_str(key)]
    for h in (th, bh, hists["queue_age"]):
        assert h.count == 10_000
        assert h.counts().size == h.num_buckets  # fixed, sample-independent
        assert h.num_buckets == StreamingHistogram().num_buckets
    r = th.bucket_width_ratio()
    for q in (50, 95, 99):
        exact = float(np.percentile(lats, q, method="higher"))
        assert exact / r <= th.percentile(q) <= exact * r
    snap = m.snapshot()
    assert snap["latency"]["tiers"]["staged"]["count"] == 10_000
    assert m.health()["latency_p99_ms"]["staged"] > 0


# ---------------------------------------------------------------------------
# JSONL export
# ---------------------------------------------------------------------------

def test_jsonl_roundtrip(tmp_path):
    path = tmp_path / "trace.jsonl"
    tr = Tracer("t", jsonl=str(path))
    with tr.span("root", n=4) as root:
        with tr.span("leaf", stage=1):
            pass
    roots = load_jsonl(str(path))
    assert [r.name for r in roots] == ["root"]
    (rec,) = roots
    assert rec.attrs["n"] == 4
    (leaf,) = rec.children
    assert leaf.name == "leaf" and leaf.attrs["stage"] == 1
    assert rec.dur_s == pytest.approx(root.dur_s)
    assert rec.total_child_seconds() == pytest.approx(
        root.total_child_seconds())


def test_jsonl_exporter_threaded(tmp_path):
    path = tmp_path / "t.jsonl"
    tr = Tracer("t", jsonl=str(path))

    def work(i):
        with tr.span(f"w{i}"):
            with tr.span("inner"):
                pass

    threads = [threading.Thread(target=work, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    roots = load_jsonl(str(path))
    assert sorted(r.name for r in roots) == [f"w{i}" for i in range(8)]
    assert all(len(r.children) == 1 for r in roots)


# ---------------------------------------------------------------------------
# compile-vs-run attribution
# ---------------------------------------------------------------------------

def test_jit_call_compile_split_on_fresh_jit():
    calls = {"n": 0}

    @jax.jit
    def f(x):
        calls["n"] += 1                  # python body runs only on compile
        return (x * x).sum()

    tr = Tracer("t")
    x = jnp.arange(8, dtype=jnp.float32)
    with tr.span("outer"):
        out1 = tr.jit_call("f", f, x)
    with tr.span("outer"):
        out2 = tr.jit_call("f", f, x)
    np.testing.assert_allclose(out1, out2)
    first, second = tr.roots
    assert [c.name for c in first.children] == ["f/compile", "f/run"]
    # steady state reuses the memoized executable with zero span overhead
    assert second.children == []
    assert calls["n"] == 1               # python body ran only at compile
    (compile_sp,) = first.find("f/compile")
    assert compile_sp.dur_s > 0


def test_traced_jit_call_falls_back_without_lower():
    tr = Tracer("t")
    with tr.span("outer") as sp:
        out = tr.jit_call("plain", lambda x: x + 1, 2)
    assert out == 3
    assert sp.attrs.get("compile") == "unsplit"


# ---------------------------------------------------------------------------
# metrics endpoint
# ---------------------------------------------------------------------------

def test_metrics_server_scrape():
    m = ServeMetrics()
    m.add(submitted=3, completed=3, batches=1, served_slots=3)
    m.add_tier("fused", batches=1, served_slots=3, padded_slots=1)
    key = (16, 4, "float64", False, False)
    m.set_bucket_tier(key, "fused", n=16, backend="fused_small")
    for lat in (0.002, 0.004, 0.008):
        m.observe_latency("fused", key, lat)
        m.observe_queue_age(lat / 2)
    srv = MetricsServer(port=0)
    try:
        srv.register("svd", m)
        with urllib.request.urlopen(srv.url, timeout=10) as resp:
            assert resp.status == 200
            assert "version=0.0.4" in resp.headers["Content-Type"]
            text = resp.read().decode("utf-8")
    finally:
        srv.stop()
    assert 'repro_serve_requests_total{engine="svd",event="submitted"} 3' \
        in text
    assert 'tier="fused"' in text
    assert f'bucket="{bucket_key_str(key)}"' in text
    assert "repro_serve_queue_age_seconds_count" in text
    assert "repro_serve_health_status" in text
    # every sample line parses as `name{labels} value`, cumulative buckets
    # are monotone, and the +Inf bucket equals _count
    by_series = {}
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        name_part, _, value = line.rpartition(" ")
        float(value)                     # parses
        assert name_part
        if "_bucket{" in name_part:
            # series identity = name + labels minus the `le` bound
            series = re.sub(r'le="[^"]*",?', "", name_part)
            by_series.setdefault(series, []).append(float(value))
    for series, counts in by_series.items():
        assert counts == sorted(counts), series
    assert ('repro_serve_latency_seconds_count{engine="svd",tier="fused"} 3'
            in text)


def test_render_matches_histogram_counts():
    m = ServeMetrics()
    key = (8, 4, "float64", False, False)
    m.observe_latency("staged", key, 0.5)
    text = render_serve_metrics(m, engine="e2")
    assert 'repro_serve_latency_seconds_bucket{engine="e2",le="+Inf",' \
           'tier="staged"} 1' in text


# ---------------------------------------------------------------------------
# pipeline acceptance: traced svd_batched span coverage
# ---------------------------------------------------------------------------

def test_svd_batched_trace_coverage_and_compile_split():
    """The ISSUE acceptance bar: one traced svd_batched call yields a span
    tree whose stage children account for >= 90%% of the root duration,
    with compile time attributed separately on the first dispatch — and
    the traced path returns bit-identical sigma to the untraced one."""
    cfg = PipelineConfig.resolve(n=24, bw=4, tw=3, backend="ref",
                                 dtype=np.float64)
    rng = np.random.default_rng(0)
    mats = jnp.asarray(rng.standard_normal((3, 24, 24)))
    ref = np.asarray(svdmod.svd_batched(mats, config=cfg))

    tr = Tracer("svd")
    sig = np.asarray(svdmod.svd_batched(mats, config=cfg, trace=tr))
    np.testing.assert_array_equal(sig, ref)

    (root,) = tr.roots
    # svd_batched delegates to singular_values, which opens the root span
    assert root.name == "singular_values"
    assert root.attrs["n"] == 24 and root.attrs["batch"] == 3
    stages = [c.name for c in root.children]
    assert stages == ["stage1", "stage2", "stage3"]
    coverage = root.total_child_seconds() / root.dur_s
    assert coverage >= 0.90, f"stage spans cover {coverage:.1%} of root"
    # first dispatch: compile attributed separately somewhere in the tree
    assert root.find("stage1/compile")
    assert root.find("stage1/run")

    # steady state: second call with the AOT memo shared — no fresh
    # compile spans, coverage still holds
    tr2 = Tracer("svd2")
    tr2._compiled = tr._compiled
    sig2 = np.asarray(svdmod.svd_batched(mats, config=cfg, trace=tr2))
    np.testing.assert_array_equal(sig2, ref)
    (root2,) = tr2.roots
    assert not root2.find("stage1/compile")
    assert root2.total_child_seconds() / root2.dur_s >= 0.90


def test_svd_uv_trace_has_replay_children():
    cfg = PipelineConfig.resolve(n=16, bw=4, tw=3, backend="ref",
                                 dtype=np.float64)
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((16, 16)))
    tr = Tracer("uv")
    u, s, vt = svdmod.svd(a, config=cfg, compute_uv=True, trace=tr)
    np.testing.assert_allclose(
        np.asarray(u) @ np.diag(np.asarray(s)) @ np.asarray(vt),
        np.asarray(a), atol=1e-8)
    (root,) = tr.roots
    names = [c.name for c in root.children]
    for expected in ("stage1", "stage2", "replay", "compose"):
        assert expected in names, names
    (replay,) = root.find("replay")
    assert replay.find("replay_stage1")


# ---------------------------------------------------------------------------
# serve-tier spans
# ---------------------------------------------------------------------------

def test_engine_dispatch_spans_and_latency_histograms():
    tr = Tracer("serve")
    eng = SVDEngine(backend="ref", tracer=tr)
    rng = np.random.default_rng(3)
    for i in range(4):
        eng.submit(SVDRequest(uid=i, matrix=rng.standard_normal((16, 16)),
                              bw=4))
    done = eng.run()
    assert all(r.error is None for r in done)
    names = [r.name for r in tr.roots]
    assert "serve/dispatch" in names
    disp = next(r for r in tr.roots if r.name == "serve/dispatch")
    assert disp.attrs["bucket"] == bucket_key_str(
        (16, 4, "float64", False, False))
    snap = eng.metrics.snapshot()
    assert sum(row["count"]
               for row in snap["latency"]["tiers"].values()) == 4
    assert snap["latency"]["queue_age"]["count"] == 4
