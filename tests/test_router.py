"""Multi-host serve fabric tests (DESIGN.md §17): the wire protocol, the
cross-process router (admission, rendezvous affinity, host-drop requeue,
fleet observability), and the supporting primitives (``FaultPlan.lose_host``
determinism, ``StreamingHistogram.merged``, per-host ``ServeMetrics``
attribution, the ``serve_mesh`` local-devices fix).

Tier-1 tests drive the router with IN-PROCESS workers (daemon threads
dialing the router's real TCP socket — full protocol, no interpreter
spawn); the ``distributed``-marked tests use real worker subprocesses,
including a SIGKILL mid-flight and the ``jax.distributed`` bootstrap.
"""

import signal
import socket
import time

import numpy as np
import pytest

from repro.obs import StreamingHistogram
from repro.serve import (FaultPlan, HostDownError, QueueFullError,
                         ServeMetrics, SVDRequest, SVDRouter)
from repro.serve.wire import WireClosed, recv_msg, send_msg
from repro.serve.worker import spawn_worker_process, start_inprocess_worker

BW = 4
FAST_ENGINE = dict(backend="ref", batch_window_s=0.005)


def dense(seed, n=12):
    return np.random.default_rng(seed).standard_normal((n, n))


def check_sigma(req, matrix):
    ref = np.linalg.svd(matrix, compute_uv=False)
    err = float(np.abs(np.asarray(req.sigma) - ref).max() / ref.max())
    assert err < 1e-12, err


def key_of(n, uv=False):
    return (n, BW, "float64", False, uv)


def make_fleet(nhosts=2, *, engine_kwargs=FAST_ENGINE, **router_kwargs):
    router = SVDRouter(**router_kwargs)
    workers = [start_inprocess_worker(router.address, f"w{i}",
                                      engine_kwargs=dict(engine_kwargs))
               for i in range(nhosts)]
    assert router.wait_for_hosts(nhosts, timeout=60)
    return router, workers


# ---------------------------------------------------------------------------
# wire protocol
# ---------------------------------------------------------------------------

def test_wire_roundtrip_bit_exact():
    a, b = socket.socketpair()
    try:
        arrays = {"m": np.random.default_rng(0).standard_normal((7, 7)),
                  "v": np.arange(5, dtype=np.float32)}
        send_msg(a, {"type": "req", "rid": 3, "flag": True}, arrays)
        header, got = recv_msg(b)
        assert (header["type"], header["rid"], header["flag"]) == \
            ("req", 3, True)
        for name, arr in arrays.items():
            assert got[name].dtype == arr.dtype
            assert got[name].shape == arr.shape
            # fp64 must cross the wire BIT-exactly (the sigma oracle
            # downstream is 1e-12 relative; the transport adds zero).
            np.testing.assert_array_equal(got[name], arr)
    finally:
        a.close()
        b.close()


def test_wire_closed_on_eof():
    a, b = socket.socketpair()
    a.close()
    with pytest.raises(WireClosed):
        recv_msg(b)
    b.close()


def test_wire_noncontiguous_array_roundtrip():
    a, b = socket.socketpair()
    try:
        m = np.arange(36, dtype=np.float64).reshape(6, 6)[::2, 1::2]
        assert not m.flags.c_contiguous
        send_msg(a, {"type": "req"}, {"m": m})
        _, got = recv_msg(b)
        np.testing.assert_array_equal(got["m"], m)
    finally:
        a.close()
        b.close()


# ---------------------------------------------------------------------------
# router + in-process workers: serving, affinity, admission
# ---------------------------------------------------------------------------

def test_router_serves_and_attributes_hosts():
    router, _ = make_fleet(2)
    try:
        mats = [dense(i) for i in range(6)]
        futs = [router.submit(SVDRequest(uid=i, matrix=m, bw=BW))
                for i, m in enumerate(mats)]
        for m, f in zip(mats, futs):
            check_sigma(f.result(timeout=120), m)
        snap = router.metrics.snapshot()
        assert snap["completed"] == 6 and snap["failed"] == 0
        # Per-host attribution sums to the router totals, and the fleet
        # merged histogram's count is exactly the per-host sum.
        assert sum(h["completed"] for h in snap["hosts"].values()) == 6
        fleet = router.fleet()
        per_host = fleet["latency"]["per_host_summary"]
        assert (sum(s["count"] for s in per_host.values())
                == fleet["latency"]["merged_summary"]["count"] == 6)
        assert sorted(fleet["alive_hosts"]) == ["w0", "w1"]
    finally:
        router.stop()


def test_rendezvous_affinity_pins_buckets():
    router, _ = make_fleet(2)
    try:
        owner = router.owner_of(key_of(12))
        assert owner in ("w0", "w1")
        futs = [router.submit(SVDRequest(uid=i, matrix=dense(i), bw=BW))
                for i in range(4)]
        [f.result(timeout=120) for f in futs]
        snap = router.metrics.snapshot()
        # Every same-bucket request landed on the rendezvous owner.
        assert snap["hosts"][owner]["dispatched"] == 4
        other = "w1" if owner == "w0" else "w0"
        assert snap["hosts"].get(other, {}).get("dispatched", 0) == 0
        # The owner is a pure function of (host set, key).
        assert router.owner_of(key_of(12)) == owner
    finally:
        router.stop()


def test_admission_refusals_resolve_futures():
    router = SVDRouter(max_pending=1)
    try:
        bad = router.submit(SVDRequest(uid=0, matrix=np.zeros((3, 4)),
                                       bw=BW))
        with pytest.raises(ValueError):
            bad.result(timeout=5)
        # No hosts: the first submit parks unrouted (counts toward the
        # fleet-wide cap), the second is refused at admission.
        ok = router.submit(SVDRequest(uid=1, matrix=dense(1), bw=BW))
        full = router.submit(SVDRequest(uid=2, matrix=dense(2), bw=BW))
        with pytest.raises(QueueFullError):
            full.result(timeout=5)
        snap = router.metrics.snapshot()
        assert snap["rejected"] == 2 and snap["submitted"] == 1
        assert not ok.done()
    finally:
        router.stop(drain=False)


def test_submit_after_stop_rejects():
    router = SVDRouter()
    router.stop()
    fut = router.submit(SVDRequest(uid=0, matrix=dense(0), bw=BW))
    with pytest.raises(RuntimeError):
        fut.result(timeout=5)


def test_unrouted_request_drains_when_host_arrives():
    router = SVDRouter()
    try:
        m = dense(3)
        fut = router.submit(SVDRequest(uid=0, matrix=m, bw=BW))
        assert router.pending() == 1 and not fut.done()
        start_inprocess_worker(router.address, "w0",
                               engine_kwargs=dict(FAST_ENGINE))
        check_sigma(fut.result(timeout=120), m)
    finally:
        router.stop()


# ---------------------------------------------------------------------------
# host-drop degradation (the §17 requeue guarantee)
# ---------------------------------------------------------------------------

def test_host_drop_requeues_inflight_exactly_once():
    # FaultPlan is deterministic: replaying the same seeded plan against
    # the same host list PREDICTS the victim, so the test can park a
    # burst on the victim's engine (long micro-batch window) before the
    # scripted heartbeat tick fires.
    victim = FaultPlan(seed=11, host_loss_at=(0,)).lose_host(["w0", "w1"])
    plan = FaultPlan(seed=11, host_loss_at=(0,))
    router, _ = make_fleet(
        2, engine_kwargs=dict(backend="ref", batch_window_s=0.75),
        heartbeat_s=60.0, heartbeat_timeout_s=120.0, faults=plan)
    try:
        n = next(c for c in range(8, 64)
                 if router.owner_of(key_of(c)) == victim)
        mats = [dense(i, n) for i in range(5)]
        futs, resolutions = [], []
        for i, m in enumerate(mats):
            f = router.submit(SVDRequest(uid=i, matrix=m, bw=BW))
            f.add_done_callback(lambda _f: resolutions.append(1))
            futs.append(f)
        time.sleep(0.1)          # land in the victim's batch window
        assert router.pending() == 5
        router._heartbeat_tick()     # deterministic tick (no wall clock)
        for m, f in zip(mats, futs):
            check_sigma(f.result(timeout=120), m)
        assert len(resolutions) == 5     # every future exactly once
        snap = router.metrics.snapshot()
        survivor = "w0" if victim == "w1" else "w1"
        assert snap["retried"] == 5
        assert snap["quarantined"] == 1
        assert f"host:{victim}" in snap["quarantined_buckets"]
        assert snap["hosts"][survivor]["requeued"] == 5
        assert snap["hosts"][survivor]["completed"] == 5
        assert victim not in router.alive_hosts()
        assert victim in router.fleet()["dead_hosts"]
        assert plan.snapshot()["host_loss"] == 1
    finally:
        router.stop()


def test_host_down_error_type():
    assert issubclass(HostDownError, ConnectionError)


def test_fault_plan_lose_host_deterministic():
    hosts = ["a", "b", "c"]
    p1 = FaultPlan(seed=5, host_loss_rate=0.5)
    p2 = FaultPlan(seed=5, host_loss_rate=0.5)
    seq1 = [p1.lose_host(hosts) for _ in range(20)]
    seq2 = [p2.lose_host(hosts) for _ in range(20)]
    assert seq1 == seq2
    assert any(v is not None for v in seq1)
    # Scripted ordinals consume the SAME draw count as probabilistic
    # ticks: a plan with no losses still advances its stream identically.
    p3 = FaultPlan(seed=5, host_loss_rate=0.0)
    for _ in range(7):
        assert p3.lose_host(hosts) is None
    assert p3.snapshot()["host_ticks"] == 7


# ---------------------------------------------------------------------------
# fleet observability
# ---------------------------------------------------------------------------

def test_hist_merged_mixed_and_empty():
    h1, h2 = StreamingHistogram(), StreamingHistogram()
    for v in (0.01, 0.02, 0.04):
        h1.add(v)
    h2.add(0.08)
    merged = StreamingHistogram.merged([h1, h2.to_dict()])
    assert merged.count == 4
    assert StreamingHistogram.merged([]).count == 0
    with pytest.raises(ValueError):
        StreamingHistogram.merged(
            [h1, StreamingHistogram(buckets_per_decade=3)])


def test_serve_metrics_host_attribution():
    m = ServeMetrics()
    m.add_host("w0", dispatched=2, completed=1)
    m.add_host("w1", requeued=3)
    snap = m.snapshot()
    assert snap["hosts"]["w0"] == {"dispatched": 2, "completed": 1,
                                   "failed": 0, "requeued": 0}
    assert snap["hosts"]["w1"]["requeued"] == 3


def test_collect_host_stats_and_fleet_render():
    router, _ = make_fleet(2)
    try:
        futs = [router.submit(SVDRequest(uid=i, matrix=dense(i), bw=BW))
                for i in range(3)]
        [f.result(timeout=120) for f in futs]
        stats = router.collect_host_stats(timeout=30)
        assert sorted(stats) == ["w0", "w1"]
        for payload in stats.values():
            assert "snapshot" in payload and "histograms" in payload
        from repro.obs import render_fleet_metrics
        text = render_fleet_metrics(router.fleet())
        assert 'repro_fleet_host_up{host="w0"} 1' in text
        assert "repro_fleet_hosts_alive 2" in text
        for line in text.splitlines():
            if line and not line.startswith("#"):
                name, _, value = line.rpartition(" ")
                float(value)
                assert name
    finally:
        router.stop()


def test_metrics_server_fleet_provider():
    import urllib.request
    from repro.obs import MetricsServer, render_fleet_metrics
    router, _ = make_fleet(1)
    server = MetricsServer(port=0)
    try:
        server.register("router", router.metrics)
        server.register_provider(
            "fleet", lambda: render_fleet_metrics(router.fleet()))
        router.submit(SVDRequest(uid=0, matrix=dense(0),
                                 bw=BW)).result(timeout=120)
        with urllib.request.urlopen(server.url, timeout=10) as resp:
            text = resp.read().decode("utf-8")
        assert "repro_fleet_hosts_alive 1" in text
        assert "repro_serve_requests_total" in text
    finally:
        server.stop()
        router.stop()


# ---------------------------------------------------------------------------
# serve_mesh: local-devices fix (unit-level — installed jax may predate
# shard_map/AxisType, and multi-process init needs real peers)
# ---------------------------------------------------------------------------

def test_serve_mesh_builds_from_local_devices(monkeypatch):
    import jax
    from repro.launch import mesh as meshmod

    local = [object(), object()]
    calls = {}

    class FakeAxisType:
        Auto = "auto"

    def fake_make_mesh(shape, axes, devices=None, axis_types=None):
        calls.update(shape=shape, axes=axes, devices=devices)
        return "MESH"

    monkeypatch.setattr(jax, "shard_map", object(), raising=False)
    monkeypatch.setattr(jax.sharding, "AxisType", FakeAxisType,
                        raising=False)
    # The multi-process regime the fix targets: 2 local, 4 global.
    monkeypatch.setattr(jax, "local_devices", lambda: list(local))
    monkeypatch.setattr(jax, "device_count", lambda: 4)
    monkeypatch.setattr(jax, "make_mesh", fake_make_mesh, raising=False)
    monkeypatch.setenv("REPRO_SERVE_MESH", "auto")
    assert meshmod.serve_mesh() == "MESH"
    # Built from jax.local_devices(), NEVER the global count: a mesh of 4
    # here would double-count the remote host's devices.
    assert calls["shape"] == (2,)
    assert calls["devices"] == local

    monkeypatch.setenv("REPRO_SERVE_MESH", "8")   # clamped to local count
    meshmod.serve_mesh()
    assert calls["shape"] == (2,)


def test_init_distributed_unconfigured_is_noop(monkeypatch):
    from repro.launch import mesh as meshmod
    for var in ("REPRO_DIST_COORDINATOR", "REPRO_DIST_NUM_PROCESSES",
                "REPRO_DIST_PROCESS_ID"):
        monkeypatch.delenv(var, raising=False)
    assert meshmod.init_distributed() is False
    assert meshmod.init_distributed(coordinator="127.0.0.1:1",
                                    num_processes=1,
                                    process_id=0) is False


# ---------------------------------------------------------------------------
# real worker subprocesses (CI's dedicated `distributed` step)
# ---------------------------------------------------------------------------

@pytest.mark.distributed
def test_subprocess_worker_roundtrip():
    router = SVDRouter()
    proc = spawn_worker_process(router.address, "w0", backend="ref")
    try:
        assert router.wait_for_hosts(1, timeout=240)
        mats = [dense(i) for i in range(3)]
        futs = [router.submit(SVDRequest(uid=i, matrix=m, bw=BW))
                for i, m in enumerate(mats)]
        for m, f in zip(mats, futs):
            check_sigma(f.result(timeout=300), m)
        info = router.fleet()["hosts"]["w0"]
        assert info["alive"] and info["devices"] >= 1
    finally:
        router.stop()
        try:
            proc.wait(timeout=30)
        except Exception:
            proc.kill()


@pytest.mark.distributed
def test_subprocess_sigkill_requeues_to_survivor():
    router = SVDRouter(heartbeat_s=0.25, heartbeat_timeout_s=2.0)
    procs = {f"w{i}": spawn_worker_process(router.address, f"w{i}",
                                           backend="ref", window_ms=500.0)
             for i in range(2)}
    try:
        assert router.wait_for_hosts(2, timeout=240)
        # Broadcast-warm so the survivor never compiles under load.
        router.warm([SVDRequest(uid=-1, matrix=dense(99), bw=BW)],
                    timeout=300)
        victim = router.owner_of(key_of(12))
        mats = [dense(i) for i in range(4)]
        futs = [router.submit(SVDRequest(uid=i, matrix=m, bw=BW))
                for i, m in enumerate(mats)]
        procs[victim].send_signal(signal.SIGKILL)
        for m, f in zip(mats, futs):
            check_sigma(f.result(timeout=300), m)
        snap = router.metrics.snapshot()
        assert snap["retried"] >= 1
        assert victim in router.fleet()["dead_hosts"]
        assert procs[victim].wait(timeout=30) is not None
    finally:
        router.stop()
        for p in procs.values():
            try:
                p.wait(timeout=30)
            except Exception:
                p.kill()


@pytest.mark.distributed
def test_jax_distributed_bootstrap_two_processes():
    # The workers join ONE multi-process jax via the coordination service
    # (no kill chaos here — a dead peer fatally cascades, DESIGN.md §17).
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        coordinator = f"127.0.0.1:{s.getsockname()[1]}"
    router = SVDRouter()
    procs = [spawn_worker_process(router.address, f"w{i}", backend="ref",
                                  devices=2, coordinator=coordinator,
                                  num_processes=2, process_id=i)
             for i in range(2)]
    try:
        assert router.wait_for_hosts(2, timeout=240)
        hosts = router.fleet()["hosts"]
        local_total = sum(v["devices"] for v in hosts.values())
        idx = sorted(v["process_index"] for v in hosts.values())
        assert idx == [0, 1]
        for v in hosts.values():
            assert v["processes"] == 2
            assert v["devices"] == 2
            assert v["global_devices"] == local_total == 4
        m = dense(7)
        check_sigma(router.submit(
            SVDRequest(uid=0, matrix=m, bw=BW)).result(timeout=300), m)
    finally:
        router.stop()
        for p in procs:
            try:
                p.wait(timeout=30)
            except Exception:
                p.kill()
