"""Three-stage pipeline tests, incl. the paper's Fig. 3 accuracy protocol:
synthetic A = U diag(sigma) V^T with prescribed spectra (arithmetic /
logarithmic / quarter-circle), reduced-precision stage 2, fp64 stage 3."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.stage1 import band_reduce
from repro.core.svd import singular_values, banded_singular_values
from repro.core.bidiag_svd import bidiag_singular_values
from repro.core import bulge_chasing as bc
from repro.core.distributed import batched_singular_values, square_embed


def synthetic_with_spectrum(n, profile, seed):
    rng = np.random.default_rng(seed)
    u, _ = np.linalg.qr(rng.standard_normal((n, n)))
    v, _ = np.linalg.qr(rng.standard_normal((n, n)))
    if profile == "arithmetic":
        s = np.linspace(1.0, 1.0 / n, n)
    elif profile == "logarithmic":
        s = np.logspace(0, -5, n)
    elif profile == "quartercircle":
        x = (np.arange(n) + 0.5) / n
        s = np.sqrt(1 - x**2)
    else:
        raise ValueError(profile)
    return u @ np.diag(s) @ v.T, s


def test_stage1_structure_and_sigma():
    n, nb = 96, 16
    a = np.random.default_rng(0).standard_normal((n, n))
    b = np.asarray(band_reduce(jnp.asarray(a), nb=nb))
    assert np.abs(np.tril(b, -1)).max() == 0.0
    assert np.abs(np.triu(b, nb + 1)).max() == 0.0
    s0 = np.linalg.svd(a, compute_uv=False)
    s1 = np.linalg.svd(b, compute_uv=False)
    np.testing.assert_allclose(s1, s0, atol=1e-12 * s0[0])


@pytest.mark.parametrize("n,bw,tw", [(64, 8, 4), (96, 16, 8), (80, 32, 8)])
def test_pipeline_matches_lapack(n, bw, tw):
    a = np.random.default_rng(n).standard_normal((n, n))
    s = np.asarray(singular_values(jnp.asarray(a), bw=bw, tw=tw, backend="ref"))
    s0 = np.linalg.svd(a, compute_uv=False)
    np.testing.assert_allclose(s, s0, atol=1e-10 * s0[0])


@pytest.mark.parametrize("profile", ["arithmetic", "logarithmic", "quartercircle"])
@pytest.mark.parametrize("dtype,tol", [(jnp.float64, 1e-12), (jnp.float32, 5e-5),
                                       (jnp.bfloat16, 5e-2)])
def test_accuracy_vs_precision_fig3(profile, dtype, tol):
    """Paper Fig. 3: stage 2 in reduced precision, stage 3 in fp64; relative
    error ||sigma - sigma_true|| / ||sigma_true|| stays within precision."""
    n, bw, tw = 48, 8, 4
    a, s_true = synthetic_with_spectrum(n, profile, seed=11)
    banded = np.asarray(band_reduce(jnp.asarray(a), nb=bw))      # fp64 stage 1
    d, e = bc.bidiagonalize(jnp.asarray(banded, dtype), bw=bw, tw=tw, backend="ref")
    s = np.asarray(bidiag_singular_values(jnp.asarray(d, jnp.float64),
                                          jnp.asarray(e, jnp.float64)))
    rel = np.linalg.norm(s - s_true) / np.linalg.norm(s_true)
    assert rel < tol, (profile, dtype, rel)


def test_banded_entry_point():
    n, bw = 64, 6
    rng = np.random.default_rng(5)
    a = np.triu(rng.standard_normal((n, n)))
    a = np.triu(a) - np.triu(a, bw + 1)
    s = np.asarray(banded_singular_values(jnp.asarray(a), bw=bw, tw=2, backend="ref"))
    s0 = np.linalg.svd(a, compute_uv=False)
    np.testing.assert_allclose(s, s0, atol=1e-10 * s0[0])


def test_batched_and_square_embed():
    rng = np.random.default_rng(6)
    mats = rng.standard_normal((3, 32, 32))
    s = np.asarray(batched_singular_values(jnp.asarray(mats), bw=8, tw=4,
                                           backend="ref"))
    for i in range(3):
        s0 = np.linalg.svd(mats[i], compute_uv=False)
        np.testing.assert_allclose(s[i], s0, atol=1e-10 * s0[0])
    # rectangular embed preserves sigma
    w = rng.standard_normal((20, 32))
    sq = np.asarray(square_embed(jnp.asarray(w), 32))
    s0 = np.linalg.svd(w, compute_uv=False)
    s1 = np.linalg.svd(sq, compute_uv=False)
    np.testing.assert_allclose(s1[:20], s0, atol=1e-12)
    np.testing.assert_allclose(s1[20:], 0, atol=1e-12)
